//! Offline stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links the XLA runtime and is only available in build
//! environments where its closure has been vendored. This stub exposes
//! the same API surface used by `msgp::runtime`, but
//! [`PjRtClient::cpu`] always fails — so `Runtime::load` returns an
//! error and the serving coordinator degrades to the native Rust engine
//! (which it does gracefully by design). Swap the `xla` path dependency
//! in the workspace `Cargo.toml` for the vendored xla-rs to enable the
//! compiled PJRT artifacts.

use std::path::Path;

/// Error type mirroring xla-rs (`Debug`-formatted at call sites).
#[derive(Debug)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "XLA runtime not vendored in this build (stub crate); using native engine".to_string(),
    ))
}

/// Element types transferable to/from [`Literal`]s.
pub trait NativeType: Copy + Default + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// A parsed HLO module (stub: never constructed successfully).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file (stub: always fails).
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self, XlaError> {
        unavailable()
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Host-side literal (tensor) value.
#[derive(Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape (stub: fails — only reachable with a live client).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    /// Destructure a 1-tuple.
    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        unavailable()
    }

    /// Destructure a 2-tuple.
    pub fn to_tuple2(&self) -> Result<(Literal, Literal), XlaError> {
        unavailable()
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }

    /// First element of the flattened literal.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T, XlaError> {
        unavailable()
    }
}

impl From<f32> for Literal {
    fn from(_v: f32) -> Self {
        Literal { _private: () }
    }
}

impl From<f64> for Literal {
    fn from(_v: f64) -> Self {
        Literal { _private: () }
    }
}

/// Device-side buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Transfer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Compiled executable (stub: never constructed).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// CPU client (stub: always fails; callers degrade to native).
    pub fn cpu() -> Result<Self, XlaError> {
        unavailable()
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("stub"));
    }
}
