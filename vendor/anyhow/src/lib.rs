//! Minimal offline shim of the `anyhow` crate.
//!
//! The build environment has no network access, so the real crates.io
//! `anyhow` cannot be fetched. This shim provides the subset the `msgp`
//! crate uses — [`Result`], [`Error`], and the `anyhow!` / `bail!` /
//! `ensure!` macros — with the same call-site syntax, so swapping the
//! path dependency for the real crate is a one-line `Cargo.toml` change.

use std::fmt;

/// A string-backed error type (the shim keeps no source chain).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from a rendered message.
    pub fn new(msg: String) -> Self {
        Error { msg }
    }

    /// `anyhow::Error::msg` compatibility constructor.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket conversion from
// every std error type coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::new(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::new(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: {}",
                ::std::stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {}", flag);
        Ok(7)
    }

    #[test]
    fn macros_render_messages() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
        let e2 = anyhow!(String::from("plain"));
        assert_eq!(format!("{e2:?}"), "plain");
        assert!(fails(true).is_ok());
        assert_eq!(format!("{}", fails(false).unwrap_err()), "flag was false");
    }

    #[test]
    fn std_errors_convert() {
        fn io_fail() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))?;
            Ok(())
        }
        assert_eq!(format!("{}", io_fail().unwrap_err()), "boom");
    }
}
