//! Gradient-based optimizers for marginal-likelihood hyperparameter
//! learning (Eq. 3) and SVI (the Big-Data-GP baseline).

/// Adam (Kingma & Ba) with the usual bias correction.
#[derive(Clone, Debug)]
pub struct Adam {
    /// Step size.
    pub lr: f64,
    /// First-moment decay.
    pub beta1: f64,
    /// Second-moment decay.
    pub beta2: f64,
    /// Numerical floor.
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: usize,
}

impl Adam {
    /// New optimizer for `n` parameters.
    pub fn new(n: usize, lr: f64) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// Apply one ascent step (`params += step` for gradient `grad` of the
    /// objective being *maximized*).
    pub fn step(&mut self, params: &mut [f64], grad: &[f64]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] += self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }

    /// Reset moments (e.g. after a parameterization change).
    pub fn reset(&mut self) {
        self.m.fill(0.0);
        self.v.fill(0.0);
        self.t = 0;
    }
}

/// Numerically-safe central finite-difference gradient of `f` at `x`.
/// Used by the baseline models (FITC/SSGP), where the paper also times
/// "the marginal likelihood and all relevant derivatives": FD keeps the
/// same asymptotic complexity (a constant factor of `2 |theta|`).
pub fn fd_gradient(mut f: impl FnMut(&[f64]) -> f64, x: &[f64], eps: f64) -> Vec<f64> {
    let mut g = vec![0.0; x.len()];
    let mut xp = x.to_vec();
    for i in 0..x.len() {
        let h = eps * (1.0 + x[i].abs());
        xp[i] = x[i] + h;
        let fp = f(&xp);
        xp[i] = x[i] - h;
        let fm = f(&xp);
        xp[i] = x[i];
        g[i] = (fp - fm) / (2.0 * h);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_maximizes_quadratic() {
        // maximize -(x-3)^2 - (y+1)^2
        let mut p = vec![0.0, 0.0];
        let mut opt = Adam::new(2, 0.1);
        for _ in 0..500 {
            let g = vec![-2.0 * (p[0] - 3.0), -2.0 * (p[1] + 1.0)];
            opt.step(&mut p, &g);
        }
        assert!((p[0] - 3.0).abs() < 1e-2, "{p:?}");
        assert!((p[1] + 1.0).abs() < 1e-2, "{p:?}");
    }

    #[test]
    fn fd_gradient_matches_analytic() {
        let f = |x: &[f64]| x[0] * x[0] * x[1] + x[1].sin();
        let x = [1.5, -0.7];
        let g = fd_gradient(f, &x, 1e-6);
        assert!((g[0] - 2.0 * x[0] * x[1]).abs() < 1e-6);
        assert!((g[1] - (x[0] * x[0] + x[1].cos())).abs() < 1e-6);
    }
}
