//! `loadgen` — reproducible load generator for the HTTP front door.
//!
//! Modes:
//!
//! * `loadgen --smoke` — the CI sweep: boots sharded servers on
//!   loopback, runs the fixed seeded closed-loop mix for two
//!   (shards, clients) configs plus a tracing-overhead measurement, and
//!   writes `BENCH_fig9_serving.json` (under `MSGP_BENCH_DIR`, default
//!   `.`) through the bench recorder.
//! * `loadgen --serve [--port P] [--shards S]` — boot a sharded demo
//!   server and keep it up for manual poking (`curl`/external loadgen).
//! * `loadgen --addr HOST:PORT [...]` — drive an already-running front
//!   door and print the latency/throughput report. With
//!   `--peer-kill-at SEC --peer-kill-pid PID` it doubles as a cluster
//!   chaos driver: `SIGKILL` the given peer process that many seconds
//!   into the run while the load keeps flowing — against a
//!   `msgp::cluster` door the report must stay error-free (surviving
//!   nodes answer from replicas with a staleness bound; see
//!   `docs/CLUSTER.md`).

use std::net::SocketAddr;
use std::path::Path;
use std::sync::Arc;

use msgp::bench::loadgen::{run, smoke, LoadConfig};
use msgp::coordinator::{BatcherConfig, HttpConfig, HttpServer, Server};
use msgp::data::gen_stress_1d;
use msgp::gp::msgp::{KernelSpec, MsgpConfig};
use msgp::grid::{Grid, GridAxis};
use msgp::kernels::{KernelType, ProductKernel};
use msgp::shard::{ShardConfig, ShardedTrainer};

fn usage() -> ! {
    eprintln!(
        "usage:\n  loadgen --smoke\n  loadgen --serve [--port P] [--shards S]\n  \
         loadgen --addr HOST:PORT [--clients N] [--requests N] [--qps Q] [--read-frac F]\n          \
         [--batch B] [--dim D] [--seed S]\n          \
         [--peer-kill-at SEC --peer-kill-pid PID]   # SIGKILL a cluster peer mid-run"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--smoke") {
        return run_smoke();
    }
    if args.iter().any(|a| a == "--serve") {
        return run_serve(&args);
    }
    run_external(&args)
}

fn run_smoke() -> anyhow::Result<()> {
    let dir = std::env::var("MSGP_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    let path = smoke(Path::new(&dir))?;
    let text = std::fs::read_to_string(&path)?;
    println!("# recorded -> {}", path.display());
    println!("{text}");
    Ok(())
}

fn run_serve(args: &[String]) -> anyhow::Result<()> {
    let mut port = 8080u16;
    let mut shards = 2usize;
    let mut iter = args.iter();
    while let Some(a) = iter.next() {
        match a.as_str() {
            "--serve" => {}
            "--port" => port = iter.next().and_then(|v| v.parse().ok()).unwrap_or(port),
            "--shards" => shards = iter.next().and_then(|v| v.parse().ok()).unwrap_or(shards),
            _ => usage(),
        }
    }
    let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
    let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]);
    let cfg = ShardConfig {
        shards,
        refresh_every: 4096,
        msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: 4, ..Default::default() },
        ..Default::default()
    };
    let trainer = ShardedTrainer::start(kernel, 0.01, grid, cfg);
    let warm = gen_stress_1d(2000, 0.05, 3);
    trainer.ingest_batch(&warm.x, &warm.y);
    trainer.flush();
    let server = Arc::new(Server::start_sharded(trainer, BatcherConfig::default()));
    let http = HttpServer::bind(server, &format!("127.0.0.1:{port}"), HttpConfig::default())?;
    let addr = http.local_addr();
    println!("serving on http://{addr} ({shards} shards); try:");
    println!("  curl -s -X POST http://{addr}/predict -d '{{\"points\": [0.5, 1.5]}}'");
    println!("  curl -s 'http://{addr}/metrics?format=prom' | head");
    println!("  curl -s 'http://{addr}/shards?verbose=1'");
    println!("ctrl-c to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn run_external(args: &[String]) -> anyhow::Result<()> {
    let mut cfg = LoadConfig::default();
    let mut addr: Option<SocketAddr> = None;
    let mut kill_at: Option<f64> = None;
    let mut kill_pid: Option<u32> = None;
    let mut iter = args.iter();
    while let Some(a) = iter.next() {
        let mut take = || iter.next().cloned().unwrap_or_default();
        match a.as_str() {
            "--addr" => addr = take().parse().ok(),
            "--clients" => cfg.clients = take().parse().unwrap_or(cfg.clients),
            "--requests" => {
                cfg.requests_per_client = take().parse().unwrap_or(cfg.requests_per_client)
            }
            "--qps" => cfg.target_qps = take().parse().unwrap_or(cfg.target_qps),
            "--read-frac" => cfg.read_frac = take().parse().unwrap_or(cfg.read_frac),
            "--batch" => cfg.predict_batch = take().parse().unwrap_or(cfg.predict_batch),
            "--dim" => cfg.dim = take().parse().unwrap_or(cfg.dim),
            "--seed" => cfg.seed = take().parse().unwrap_or(cfg.seed),
            "--peer-kill-at" => kill_at = take().parse().ok(),
            "--peer-kill-pid" => kill_pid = take().parse().ok(),
            _ => usage(),
        }
    }
    let Some(addr) = addr else { usage() };
    cfg.addr = addr;
    match (kill_at, kill_pid) {
        // Chaos knob: hard-kill a cluster peer mid-run. The load keeps
        // flowing at the driven door the whole time, so the report's
        // error count is the verdict on fault-tolerant serving.
        (Some(at), Some(pid)) => {
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_secs_f64(at.max(0.0)));
                println!("# chaos: SIGKILL peer pid {pid} at t={at:.1}s");
                match std::process::Command::new("kill").args(["-9", &pid.to_string()]).status() {
                    Ok(st) if st.success() => {}
                    Ok(st) => eprintln!("# chaos: kill exited with {st}"),
                    Err(e) => eprintln!("# chaos: kill failed: {e}"),
                }
            });
        }
        (None, None) => {}
        _ => {
            eprintln!("--peer-kill-at and --peer-kill-pid must be given together");
            usage();
        }
    }
    let mode = if cfg.target_qps > 0.0 {
        format!("open loop @ {:.0} req/s", cfg.target_qps)
    } else {
        "closed loop".to_string()
    };
    println!(
        "# driving {addr}: {} clients x {} requests, {mode}, read_frac={}",
        cfg.clients, cfg.requests_per_client, cfg.read_frac
    );
    let report = run(&cfg);
    println!("{}", report.summary_line());
    Ok(())
}
