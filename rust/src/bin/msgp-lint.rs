//! `msgp-lint` — the in-tree correctness analyzer, run as a blocking
//! CI gate (`cargo run --release --bin msgp-lint`).
//!
//! Walks the crate's own source (`rust/src`, or a root passed as the
//! first argument) and enforces the five rule families from
//! [`msgp::analysis`]: unsafe-audit (+ registry census),
//! atomic-ordering audit, hot-path allocation lint, lock-order
//! audit, and the serving-path unwrap audit. Prints a per-family
//! summary and every finding; exits non-zero when findings exist, so
//! CI fails closed.

use msgp::analysis::rules::UNWRAP_AUDIT_PREFIXES;
use msgp::analysis::{analyze_crate, HANDOFF_FILES, LOCK_ORDER};
use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let root = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/src")
    });
    let report = match analyze_crate(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("msgp-lint: cannot walk {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    println!("msgp-lint: scanned {} files under {}", report.files.len(), root.display());
    println!(
        "  unsafe sites (non-test): {} across {} file(s), registry-checked",
        report.unsafe_total,
        report.files.iter().filter(|f| f.unsafe_count > 0).count()
    );
    let o = report.ordering_total;
    println!(
        "  atomic orderings (non-test): {} total — SeqCst {}, AcqRel {}, Acquire {}, Release {}, Relaxed {}",
        o.total(),
        o.seqcst,
        o.acqrel,
        o.acquire,
        o.release,
        o.relaxed
    );
    println!("  handoff modules (all orderings annotated): {}", HANDOFF_FILES.join(", "));
    println!("  lock-order table: {} receivers", LOCK_ORDER.len());
    println!("  unwrap-audit scope: {}", UNWRAP_AUDIT_PREFIXES.join(", "));

    if report.findings.is_empty() {
        println!("msgp-lint: clean");
        return ExitCode::SUCCESS;
    }
    println!("msgp-lint: {} finding(s):", report.findings.len());
    for f in &report.findings {
        println!("  {f}");
    }
    ExitCode::FAILURE
}
