//! Typed metric primitives and Prometheus text exposition.
//!
//! [`Counter`], [`Gauge`] and [`LogHistogram`] are thin wrappers over
//! `AtomicU64` that carry their metric *kind* in the type — the
//! coordinator's [`crate::coordinator::metrics::Metrics`] registry is
//! built from them, so the Prometheus renderer ([`PromWriter`]) can
//! emit the right `# TYPE` line per family and the hand-rolled legacy
//! one-line summary keeps reading the same wait-free atomics. `Counter`
//! and `Gauge` deliberately expose the `fetch_add` / `load` / `store`
//! signatures of `AtomicU64`, so swapping field types is source
//! compatible for every existing call site.
//!
//! The histogram is the serving layer's 64-bucket log₂-scale latency
//! histogram with an exact running sum/count, renderable both as the
//! legacy `p50<=`/`p99<=` quantile pair and as a proper Prometheus
//! `_bucket`/`_sum`/`_count` series with cumulative monotone buckets.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of log₂-scale latency buckets (1 µs .. 2⁶³ µs; the top bucket
/// is the overflow bucket with no finite upper edge).
pub const NBUCKETS: usize = 64;

/// A monotonically increasing counter (wait-free).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    /// Counter starting at `v`.
    pub const fn new(v: u64) -> Self {
        Counter(AtomicU64::new(v))
    }

    /// Add `v`; returns the previous value (AtomicU64-compatible).
    pub fn fetch_add(&self, v: u64, order: Ordering) -> u64 {
        self.0.fetch_add(v, order)
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Current value (AtomicU64-compatible).
    pub fn load(&self, order: Ordering) -> u64 {
        self.0.load(order)
    }

    /// Current value with relaxed ordering.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge: a value that can move both ways (wait-free).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    /// Gauge starting at `v`.
    pub const fn new(v: u64) -> Self {
        Gauge(AtomicU64::new(v))
    }

    /// Set the value (AtomicU64-compatible).
    pub fn store(&self, v: u64, order: Ordering) {
        self.0.store(v, order)
    }

    /// Add `v`; returns the previous value (AtomicU64-compatible).
    pub fn fetch_add(&self, v: u64, order: Ordering) -> u64 {
        self.0.fetch_add(v, order)
    }

    /// Subtract `v`; returns the previous value (AtomicU64-compatible).
    pub fn fetch_sub(&self, v: u64, order: Ordering) -> u64 {
        self.0.fetch_sub(v, order)
    }

    /// Current value (AtomicU64-compatible).
    pub fn load(&self, order: Ordering) -> u64 {
        self.0.load(order)
    }

    /// Current value with relaxed ordering.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A 64-bucket log₂-scale histogram of microsecond values with an exact
/// running sum and count. Bucket `i` holds values in `[2^i, 2^(i+1))`
/// µs (values below 1 µs clamp into bucket 0; the last bucket is the
/// overflow bucket). All operations are wait-free.
#[derive(Debug)]
pub struct LogHistogram {
    buckets: [AtomicU64; NBUCKETS],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

/// A point-in-time copy of a [`LogHistogram`] (one relaxed load per
/// word; buckets/sum/count may be mutually torn under concurrent
/// writes, like any scrape of live counters).
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    /// Per-bucket counts.
    pub buckets: [u64; NBUCKETS],
    /// Exact sum of recorded values, microseconds.
    pub sum_us: u64,
    /// Total recorded values.
    pub count: u64,
}

impl HistogramSnapshot {
    /// Finite upper bucket edge in µs, or `None` for the overflow
    /// bucket (rendered as `+Inf`).
    pub fn upper_edge_us(i: usize) -> Option<u64> {
        if i + 1 >= NBUCKETS {
            None
        } else {
            Some(1u64 << (i + 1))
        }
    }
}

impl LogHistogram {
    /// Fresh (empty) histogram.
    pub fn new() -> Self {
        Self::default()
    }

    fn bucket(us: u64) -> usize {
        (63 - us.max(1).leading_zeros() as usize).min(NBUCKETS - 1)
    }

    /// Record one value in microseconds.
    pub fn record_us(&self, us: u64) {
        self.buckets[Self::bucket(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one duration (sub-µs durations clamp to 1 µs, matching
    /// the bucket floor so `_sum`/`_count` stay consistent with the
    /// buckets).
    pub fn record(&self, d: Duration) {
        self.record_us((d.as_micros() as u64).max(1));
    }

    /// Approximate quantile as an upper bucket edge in microseconds.
    /// `0` when empty. Values that landed in the overflow bucket have
    /// no finite upper edge, so a quantile that falls there saturates
    /// to `u64::MAX` — consistently, whether the scan stops at the last
    /// bucket or exhausts the loop.
    pub fn quantile_upper_us(&self, q: f64) -> u64 {
        let snap = self.snapshot();
        let total = snap.count_from_buckets();
        if total == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in snap.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::upper_or_saturate(i);
            }
        }
        u64::MAX
    }

    fn upper_or_saturate(i: usize) -> u64 {
        match HistogramSnapshot::upper_edge_us(i) {
            Some(edge) => edge,
            None => u64::MAX,
        }
    }

    /// Point-in-time copy (relaxed loads).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

impl HistogramSnapshot {
    /// Total count derived from the buckets (used by the quantile scan
    /// so one snapshot is internally consistent even under concurrent
    /// writes).
    pub fn count_from_buckets(&self) -> u64 {
        self.buckets.iter().sum()
    }
}

/// Escape a Prometheus label *value*: `\` → `\\`, `"` → `\"`, newline →
/// `\n` (the exposition-format rules).
pub fn escape_label_value(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape `# HELP` text: `\` → `\\`, newline → `\n`.
pub fn escape_help(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Label set: `(name, value)` pairs, rendered as
/// `{name="escaped-value",...}` (empty set renders nothing).
pub type Labels<'a> = [(&'a str, String)];

fn write_labels(out: &mut String, labels: &Labels<'_>) {
    if labels.is_empty() {
        return;
    }
    out.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        out.push_str(&escape_label_value(v));
        out.push('"');
    }
    out.push('}');
}

fn write_header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(&escape_help(help));
    out.push('\n');
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

/// Incremental Prometheus text-exposition writer. One `counter` /
/// `gauge` / `histogram` call renders one metric *family* (`# HELP` +
/// `# TYPE` + all its label-set samples), so per-shard series share one
/// header.
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    /// Fresh writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// One counter family: `samples` are `(labels, value)` pairs.
    pub fn counter(&mut self, name: &str, help: &str, samples: &[(&Labels<'_>, u64)]) {
        write_header(&mut self.out, name, help, "counter");
        for (labels, v) in samples {
            self.sample(name, labels, *v as f64);
        }
    }

    /// One gauge family.
    pub fn gauge(&mut self, name: &str, help: &str, samples: &[(&Labels<'_>, u64)]) {
        write_header(&mut self.out, name, help, "gauge");
        for (labels, v) in samples {
            self.sample(name, labels, *v as f64);
        }
    }

    /// One histogram family from a [`HistogramSnapshot`]: cumulative
    /// `_bucket{le=...}` series (the overflow bucket folds into
    /// `+Inf`), then `_sum` and `_count`.
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &Labels<'_>,
        snap: &HistogramSnapshot,
    ) {
        self.histogram_family(name, help, &[(labels, snap)]);
    }

    /// One histogram family with *multiple* label sets (e.g. one series
    /// per HTTP route) under a single `# HELP`/`# TYPE` header — the
    /// exposition format forbids repeating the header per series.
    pub fn histogram_family(
        &mut self,
        name: &str,
        help: &str,
        series: &[(&Labels<'_>, &HistogramSnapshot)],
    ) {
        write_header(&mut self.out, name, help, "histogram");
        for (labels, snap) in series {
            self.histogram_series(name, labels, snap);
        }
    }

    fn histogram_series(&mut self, name: &str, labels: &Labels<'_>, snap: &HistogramSnapshot) {
        let mut acc = 0u64;
        for (i, &c) in snap.buckets.iter().enumerate() {
            acc += c;
            let Some(edge) = HistogramSnapshot::upper_edge_us(i) else { break };
            let mut with_le: Vec<(&str, String)> = labels.to_vec();
            with_le.push(("le", edge.to_string()));
            self.named_sample(&format!("{name}_bucket"), &with_le, acc as f64);
        }
        let total = snap.count_from_buckets();
        let mut with_inf: Vec<(&str, String)> = labels.to_vec();
        with_inf.push(("le", "+Inf".to_string()));
        self.named_sample(&format!("{name}_bucket"), &with_inf, total as f64);
        self.named_sample(&format!("{name}_sum"), labels, snap.sum_us as f64);
        self.named_sample(&format!("{name}_count"), labels, snap.count as f64);
    }

    fn sample(&mut self, name: &str, labels: &Labels<'_>, v: f64) {
        self.named_sample(name, labels, v);
    }

    fn named_sample(&mut self, name: &str, labels: &Labels<'_>, v: f64) {
        self.out.push_str(name);
        write_labels(&mut self.out, labels);
        self.out.push(' ');
        if v.fract() == 0.0 && v.abs() < 9e15 {
            self.out.push_str(&format!("{}", v as i64));
        } else {
            self.out.push_str(&format!("{v}"));
        }
        self.out.push('\n');
    }

    /// Finish and return the exposition text.
    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_bracket_values() {
        let h = LogHistogram::new();
        for _ in 0..100 {
            h.record(Duration::from_micros(100));
        }
        for _ in 0..5 {
            h.record(Duration::from_millis(10));
        }
        let p50 = h.quantile_upper_us(0.5);
        let p99 = h.quantile_upper_us(0.99);
        assert!(p50 >= 100 && p50 < 1000, "p50 {p50}");
        assert!(p99 >= 8_000, "p99 {p99}");
        assert!(p50 <= p99);
        assert_eq!(h.snapshot().count, 105);
        assert_eq!(h.snapshot().sum_us, 100 * 100 + 5 * 10_000);
    }

    #[test]
    fn overflow_bucket_saturates_to_max_consistently() {
        // A value at/above 2^63 µs lands in the overflow bucket, which
        // has no finite upper edge: every quantile that falls there
        // must report u64::MAX (not a silent 2^63).
        let h = LogHistogram::new();
        h.record_us(u64::MAX);
        assert_eq!(h.quantile_upper_us(0.5), u64::MAX);
        assert_eq!(h.quantile_upper_us(1.0), u64::MAX);
        // One bucket below the overflow bucket still reports its finite
        // edge 2^63 — the saturation is exactly at the top.
        let h2 = LogHistogram::new();
        h2.record_us(1u64 << 62);
        assert_eq!(h2.quantile_upper_us(0.5), 1u64 << 63);
    }

    #[test]
    fn prom_histogram_is_cumulative_and_consistent() {
        let h = LogHistogram::new();
        h.record_us(3);
        h.record_us(300);
        h.record_us(300_000);
        let mut w = PromWriter::new();
        w.histogram("request_latency_us", "Latency.", &[], &h.snapshot());
        let text = w.finish();
        assert!(text.contains("# TYPE request_latency_us histogram"), "{text}");
        assert!(text.contains("request_latency_us_bucket{le=\"+Inf\"} 3"), "{text}");
        assert!(text.contains("request_latency_us_count 3"), "{text}");
        assert!(text.contains("request_latency_us_sum 300303"), "{text}");
        // Cumulative monotone bucket counts.
        let mut prev = 0i64;
        for line in text.lines().filter(|l| l.starts_with("request_latency_us_bucket")) {
            let v: i64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "{text}");
            prev = v;
        }
    }

    #[test]
    fn histogram_family_shares_one_header_across_series() {
        let ha = LogHistogram::new();
        ha.record_us(3);
        let hb = LogHistogram::new();
        hb.record_us(700);
        hb.record_us(900);
        let la: Vec<(&str, String)> = vec![("route", "predict".to_string())];
        let lb: Vec<(&str, String)> = vec![("route", "ingest".to_string())];
        let (sa, sb) = (ha.snapshot(), hb.snapshot());
        let mut w = PromWriter::new();
        w.histogram_family("h", "Help.", &[(&la[..], &sa), (&lb[..], &sb)]);
        let text = w.finish();
        assert_eq!(text.matches("# TYPE h histogram").count(), 1, "{text}");
        assert!(text.contains("h_bucket{route=\"predict\",le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("h_bucket{route=\"ingest\",le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("h_count{route=\"ingest\"} 2"), "{text}");
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label_value("a\\b\"c\nd"), "a\\\\b\\\"c\\nd");
        let mut w = PromWriter::new();
        let labels: Vec<(&str, String)> = vec![("shard", "a\"0".to_string())];
        w.gauge("g", "Help with \\ and\nnewline.", &[(&labels[..], 7)]);
        let text = w.finish();
        assert!(text.contains("g{shard=\"a\\\"0\"} 7"), "{text}");
        assert!(text.contains("# HELP g Help with \\\\ and\\nnewline."), "{text}");
    }
}
