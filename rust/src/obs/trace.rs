//! Always-on-capable tracing: RAII span guards feeding per-thread
//! lock-free ring buffers, drained into Chrome trace-event JSON.
//!
//! Design constraints, in order:
//!
//! 1. **Disabled cost is one branch.** [`SpanGuard::enter`] loads one
//!    `Relaxed` [`AtomicBool`] and returns an inert guard when tracing
//!    is off — no clock read, no thread-local touch. The numeric hot
//!    paths (`fftn_batch`, `cg_solve_block`) carry spans permanently
//!    because of this.
//! 2. **Enabled cost is tens of nanoseconds and wait-free.** Each
//!    thread owns one ring ([`RING_CAP`] slots of five `AtomicU64`
//!    words); recording a span is a handful of `Relaxed` stores plus
//!    two `Release` stores — no locks, no allocation after the ring
//!    exists. Overflow overwrites the oldest events (a trace is a
//!    window, not a log).
//! 3. **Draining never stops the world.** [`dump_json`] snapshots every
//!    ring through a per-slot sequence word (seqlock discipline, all
//!    words atomic so there is no UB to discuss): a slot overwritten
//!    mid-read fails its sequence check and is skipped.
//!
//! Span names are interned once per call site: the [`span!`] /
//! [`span_arg!`](crate::span_arg) macros expand to a `static`
//! [`SpanSite`] whose id is registered on first traced use, so the
//! per-event payload is a few integers (`span_arg!` adds one `u64`
//! argument — e.g. an HTTP request id — exported as `args.id`).
//!
//! The exported JSON is the Chrome trace-event format (`ph: "X"`
//! complete events with microsecond `ts`/`dur`) — load it at
//! `chrome://tracing` or <https://ui.perfetto.dev>. Nesting needs no
//! explicit parent links: events on one `tid` nest by time containment.

use std::cell::{Cell, OnceCell};
use std::sync::atomic::{fence, AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Events retained per thread (power of two; ~0.3 MiB per ring). A full
/// refresh cycle emits well under a hundred spans, so the window covers
/// many cycles even with the FFT hot-path spans firing.
pub const RING_CAP: usize = 8192;

/// Words per ring slot: sequence, packed id/depth, start, duration,
/// user argument (e.g. the HTTP request id; 0 = none).
const WORDS: usize = 5;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Enable or disable span capture process-wide. Spans already recorded
/// stay in their rings (use [`clear`] to discard them).
pub fn set_enabled(on: bool) {
    // ORDERING: Relaxed — a standalone on/off flag guarding no other
    // memory; every recorded event is published by the ring's own
    // seqlock protocol, not by this store.
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether span capture is on. This is the whole disabled-path cost of
/// an instrumented scope.
#[inline(always)]
pub fn enabled() -> bool {
    // ORDERING: Relaxed — pairs with the Relaxed store in
    // `set_enabled`; a stale read only starts/stops capture one event
    // late, which the seqlock makes harmless.
    ENABLED.load(Ordering::Relaxed)
}

/// Enable tracing when the `MSGP_TRACE` env var is set to anything but
/// `0` / empty. Called by the server start paths; safe to call often.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("MSGP_TRACE") {
        if !v.is_empty() && v != "0" {
            set_enabled(true);
        }
    }
}

/// Process-wide trace epoch: every timestamp is nanoseconds since the
/// first call. Shared with the metrics layer (`last_refresh_at_us`) so
/// trace timestamps and gauge ages agree.
fn epoch() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the trace epoch.
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Microseconds since the trace epoch (the gauge-friendly unit).
#[inline]
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Interned span names, 1-based (id 0 = unregistered sentinel).
fn names() -> &'static Mutex<Vec<&'static str>> {
    static NAMES: OnceLock<Mutex<Vec<&'static str>>> = OnceLock::new();
    NAMES.get_or_init(|| Mutex::new(Vec::new()))
}

/// One instrumented call site: a `&'static` name plus its lazily
/// assigned intern id. Created by the [`span!`] macro as a `static`, so
/// after the first traced pass a span records no string work at all.
pub struct SpanSite {
    name: &'static str,
    id: AtomicU32,
}

impl SpanSite {
    /// Const constructor (the macro places these in `static`s).
    pub const fn new(name: &'static str) -> Self {
        SpanSite { name, id: AtomicU32::new(0) }
    }

    /// Intern id, registering the name on first use.
    fn id(&self) -> u32 {
        // ORDERING: Relaxed — the id is a self-contained integer; the
        // name it indexes lives behind the `names()` mutex, and drains
        // tolerate an id they cannot resolve yet by skipping the event.
        let id = self.id.load(Ordering::Relaxed);
        if id != 0 {
            return id;
        }
        let mut v = names().lock().unwrap();
        // Re-check under the lock: another thread may have registered
        // this site while we waited.
        // ORDERING: Relaxed — the registration lock is held, so this
        // read cannot race the store below.
        let id = self.id.load(Ordering::Relaxed);
        if id != 0 {
            return id;
        }
        v.push(self.name);
        let id = v.len() as u32;
        // ORDERING: Relaxed — publication of the name itself happens
        // through the mutex; this store only caches the index.
        self.id.store(id, Ordering::Relaxed);
        id
    }
}

/// One thread's event ring. Single writer (the owning thread); any
/// thread may read via the per-slot sequence words.
struct Ring {
    /// Stable reader-facing thread index (registration order).
    tid: u32,
    /// Monotone count of events ever pushed.
    head: AtomicU64,
    /// Events below this absolute index are hidden from drains
    /// (advanced by [`clear`]).
    floor: AtomicU64,
    /// `RING_CAP * WORDS` atomics; slot `e % RING_CAP` holds
    /// `[seq, id<<16|depth, start_ns, dur_ns, arg]` with `seq = 2*(e+1)`
    /// once stable and odd while being written.
    slots: Box<[AtomicU64]>,
}

impl Ring {
    fn new(tid: u32) -> Self {
        Ring {
            tid,
            head: AtomicU64::new(0),
            floor: AtomicU64::new(0),
            slots: (0..RING_CAP * WORDS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Record one completed span. Wait-free; called only by the owning
    /// thread.
    // lint:hot
    fn push(&self, id: u32, depth: u16, start_ns: u64, dur_ns: u64, arg: u64) {
        // ORDERING: Relaxed — `head` is written only by this (owning)
        // thread, so its own last store is always visible here.
        let e = self.head.load(Ordering::Relaxed);
        let base = (e as usize & (RING_CAP - 1)) * WORDS;
        let s = &self.slots;
        // Seqlock write (Boehm fence discipline): odd marker, release
        // fence, relaxed payload, even generation marker. The fence
        // pairs with the reader's Acquire fence via the payload
        // atomics: a reader observing any payload word written after
        // the fence also observes the odd marker at its re-check, so a
        // torn read is always detected. A Release store on the odd
        // marker alone would NOT order it before later payload stores.
        // ORDERING: Relaxed odd marker, ordered by the fence below.
        s[base].store(2 * e + 1, Ordering::Relaxed);
        // ORDERING: Release fence — see the seqlock note above.
        fence(Ordering::Release);
        // ORDERING: Relaxed payload — fenced above, published below.
        s[base + 1].store(((id as u64) << 16) | depth as u64, Ordering::Relaxed);
        s[base + 2].store(start_ns, Ordering::Relaxed);
        s[base + 3].store(dur_ns, Ordering::Relaxed);
        s[base + 4].store(arg, Ordering::Relaxed);
        // ORDERING: Release pairs with the reader's Acquire load of the
        // sequence word: a reader that sees `2*(e+1)` sees the whole
        // payload written above.
        s[base].store(2 * (e + 1), Ordering::Release);
        // ORDERING: Release pairs with the Acquire head load in
        // `drain`/`clear`, publishing every slot at index < head.
        self.head.store(e + 1, Ordering::Release);
    }
}

/// Ring registry: one entry per thread that ever recorded a span.
/// Locked only on thread registration and drain — never on the record
/// path.
fn registry() -> &'static Mutex<Vec<std::sync::Arc<Ring>>> {
    static REG: OnceLock<Mutex<Vec<std::sync::Arc<Ring>>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    /// This thread's ring (registered on first recorded span).
    static RING: OnceCell<std::sync::Arc<Ring>> = const { OnceCell::new() };
    /// Live span nesting depth on this thread.
    static DEPTH: Cell<u16> = const { Cell::new(0) };
}

fn with_ring(f: impl FnOnce(&Ring)) {
    RING.with(|cell| {
        let ring = cell.get_or_init(|| {
            let mut reg = registry().lock().unwrap();
            let ring = std::sync::Arc::new(Ring::new(reg.len() as u32));
            reg.push(ring.clone());
            ring
        });
        f(ring)
    });
}

/// RAII span: records `[enter, drop)` into the owning thread's ring on
/// drop. Construct through the [`span!`] macro.
pub struct SpanGuard {
    /// `None` when tracing was disabled at entry (the guard is inert).
    live: Option<(&'static SpanSite, u64, u64)>,
}

impl SpanGuard {
    /// Begin a span at `site`. One atomic load when tracing is off.
    #[inline]
    pub fn enter(site: &'static SpanSite) -> SpanGuard {
        Self::enter_with(site, 0)
    }

    /// Begin a span at `site` carrying a user argument (e.g. the HTTP
    /// request id; `0` = no argument). Exported in the Chrome trace as
    /// `args.id`, so every slice of one request is greppable by id.
    #[inline]
    pub fn enter_with(site: &'static SpanSite, arg: u64) -> SpanGuard {
        if !enabled() {
            return SpanGuard { live: None };
        }
        DEPTH.with(|d| d.set(d.get().saturating_add(1)));
        SpanGuard { live: Some((site, arg, now_ns())) }
    }
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if let Some((site, arg, start)) = self.live {
            let dur = now_ns().saturating_sub(start);
            let depth = DEPTH.with(|d| {
                let v = d.get();
                d.set(v.saturating_sub(1));
                v
            });
            with_ring(|r| r.push(site.id(), depth, start, dur, arg));
        }
    }
}

/// Open a traced span for the rest of the enclosing scope:
/// `let _s = span!("refresh.block_solve");`. Cost when tracing is
/// disabled: one relaxed atomic load and a branch.
#[macro_export]
macro_rules! span {
    ($name:literal) => {{
        static __MSGP_SPAN_SITE: $crate::obs::trace::SpanSite =
            $crate::obs::trace::SpanSite::new($name);
        $crate::obs::trace::SpanGuard::enter(&__MSGP_SPAN_SITE)
    }};
}

/// Like [`span!`], but carries a `u64` argument (request / connection
/// id) into the recorded event: `let _s = span_arg!("http.request", id);`
#[macro_export]
macro_rules! span_arg {
    ($name:literal, $arg:expr) => {{
        static __MSGP_SPAN_SITE: $crate::obs::trace::SpanSite =
            $crate::obs::trace::SpanSite::new($name);
        $crate::obs::trace::SpanGuard::enter_with(&__MSGP_SPAN_SITE, ($arg) as u64)
    }};
}

/// One drained span event (decoded ring slot).
#[derive(Clone, Debug, PartialEq)]
pub struct SpanEvent {
    /// Interned span name.
    pub name: &'static str,
    /// Reader-facing thread index (ring registration order).
    pub tid: u32,
    /// Nesting depth at record time (1 = top level).
    pub depth: u16,
    /// Start, nanoseconds since the trace epoch.
    pub start_ns: u64,
    /// Duration, nanoseconds.
    pub dur_ns: u64,
    /// User argument (request / connection id; 0 = none).
    pub arg: u64,
}

/// Snapshot every ring (newest [`RING_CAP`] events per thread), sorted
/// by start time. Slots overwritten while being read are skipped.
pub fn drain() -> Vec<SpanEvent> {
    let names: Vec<&'static str> = names().lock().unwrap().clone();
    let rings: Vec<std::sync::Arc<Ring>> = registry().lock().unwrap().clone();
    let mut events = Vec::new();
    for ring in &rings {
        // ORDERING: Acquire pairs with the Release head store in
        // `push`: every slot at index < head is fully published.
        let head = ring.head.load(Ordering::Acquire);
        // ORDERING: Acquire pairs with the Release floor store in
        // `clear`; a stale floor only un-hides already-valid events.
        let lo = head.saturating_sub(RING_CAP as u64).max(ring.floor.load(Ordering::Acquire));
        for e in lo..head {
            let base = (e as usize & (RING_CAP - 1)) * WORDS;
            let want = 2 * (e + 1);
            // ORDERING: Acquire pairs with the writer's Release even-
            // marker store: seeing `want` publishes the payload words.
            let seq1 = ring.slots[base].load(Ordering::Acquire);
            if seq1 != want {
                continue; // being overwritten (or already lapped)
            }
            // ORDERING: Relaxed payload, bracketed by seq Acquire + fence.
            let meta = ring.slots[base + 1].load(Ordering::Relaxed);
            let start_ns = ring.slots[base + 2].load(Ordering::Relaxed);
            let dur_ns = ring.slots[base + 3].load(Ordering::Relaxed);
            let arg = ring.slots[base + 4].load(Ordering::Relaxed);
            // ORDERING: Acquire fence + Relaxed re-check pair with the
            // writer's odd-marker + Release fence: a torn payload read
            // above cannot miss the changed sequence value here.
            fence(Ordering::Acquire);
            if ring.slots[base].load(Ordering::Relaxed) != want {
                continue; // overwritten mid-read: payload untrusted
            }
            let id = (meta >> 16) as usize;
            let Some(&name) = names.get(id.wrapping_sub(1)) else { continue };
            let depth = (meta & 0xffff) as u16;
            events.push(SpanEvent { name, tid: ring.tid, depth, start_ns, dur_ns, arg });
        }
    }
    events.sort_by_key(|e| (e.start_ns, std::cmp::Reverse(e.dur_ns)));
    events
}

/// Hide everything recorded so far from future drains (rings are not
/// freed; writers are unaffected).
pub fn clear() {
    let rings: Vec<std::sync::Arc<Ring>> = registry().lock().unwrap().clone();
    for ring in &rings {
        // ORDERING: Acquire head read (pairs with push's Release) and
        // Release floor store (pairs with drain's Acquire floor load).
        ring.floor.store(ring.head.load(Ordering::Acquire), Ordering::Release);
    }
}

/// Render the current trace window as a Chrome trace-event JSON
/// document (`chrome://tracing` / Perfetto loadable). Timestamps and
/// durations are microseconds (fractional) since the trace epoch.
pub fn dump_json() -> String {
    let events: Vec<Json> = drain()
        .into_iter()
        .map(|e| {
            let mut args = vec![("depth", Json::Num(e.depth as f64))];
            if e.arg != 0 {
                args.push(("id", Json::Num(e.arg as f64)));
            }
            Json::obj(vec![
                ("name", Json::Str(e.name.to_string())),
                ("cat", Json::Str("msgp".to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(e.start_ns as f64 / 1e3)),
                ("dur", Json::Num(e.dur_ns as f64 / 1e3)),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(e.tid as f64)),
                ("args", Json::obj(args)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
    .to_string()
}

/// Facade matching the issue-facing API (`Tracer::dump_json`); all
/// methods forward to the module functions.
pub struct Tracer;

impl Tracer {
    /// See [`set_enabled`].
    pub fn set_enabled(on: bool) {
        set_enabled(on)
    }

    /// See [`enabled`].
    pub fn enabled() -> bool {
        enabled()
    }

    /// See [`dump_json`].
    pub fn dump_json() -> String {
        dump_json()
    }

    /// See [`drain`].
    pub fn drain() -> Vec<SpanEvent> {
        drain()
    }

    /// See [`clear`].
    pub fn clear() {
        clear()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The enable flag is process-global; serialize the tests that
    /// toggle it so parallel test threads cannot interleave windows.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = lock();
        set_enabled(false);
        clear();
        {
            let _s = crate::span!("test.disabled");
        }
        assert!(drain().iter().all(|e| e.name != "test.disabled"));
    }

    #[test]
    fn spans_nest_by_time_containment() {
        let _g = lock();
        set_enabled(true);
        {
            let _outer = crate::span!("test.outer");
            std::thread::sleep(std::time::Duration::from_millis(2));
            {
                let _inner = crate::span!("test.inner");
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        set_enabled(false);
        let events = drain();
        let outer = events.iter().find(|e| e.name == "test.outer").expect("outer recorded");
        let inner = events.iter().find(|e| e.name == "test.inner").expect("inner recorded");
        assert_eq!(inner.tid, outer.tid);
        assert!(inner.depth > outer.depth, "{} vs {}", inner.depth, outer.depth);
        assert!(inner.start_ns >= outer.start_ns);
        assert!(inner.start_ns + inner.dur_ns <= outer.start_ns + outer.dur_ns);
        clear();
    }

    #[test]
    fn dump_json_is_chrome_trace_shaped() {
        let _g = lock();
        set_enabled(true);
        {
            let _s = crate::span!("test.json");
        }
        set_enabled(false);
        let doc = Json::parse(&dump_json()).expect("valid JSON");
        let events = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");
        let ev = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("test.json"))
            .expect("span present");
        assert_eq!(ev.get("ph").and_then(|p| p.as_str()), Some("X"));
        assert!(ev.get("ts").and_then(|t| t.as_f64()).is_some());
        assert!(ev.get("dur").and_then(|t| t.as_f64()).is_some());
        assert!(ev.get("tid").and_then(|t| t.as_f64()).is_some());
        clear();
    }

    #[test]
    fn span_arg_carries_id_into_drain_and_dump() {
        let _g = lock();
        set_enabled(true);
        {
            let _s = crate::span_arg!("test.arg", 42u64);
        }
        {
            let _s = crate::span!("test.noarg");
        }
        set_enabled(false);
        let events = drain();
        let with = events.iter().find(|e| e.name == "test.arg").expect("arg span recorded");
        assert_eq!(with.arg, 42);
        let without = events.iter().find(|e| e.name == "test.noarg").expect("plain span");
        assert_eq!(without.arg, 0);
        let doc = Json::parse(&dump_json()).expect("valid JSON");
        let dumped = doc.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");
        let ev = dumped
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("test.arg"))
            .expect("span present");
        let id = ev.get("args").and_then(|a| a.get("id")).and_then(|v| v.as_f64());
        assert_eq!(id, Some(42.0));
        let plain = dumped
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("test.noarg"))
            .expect("plain span present");
        assert!(plain.get("args").and_then(|a| a.get("id")).is_none(), "no id for arg=0");
        clear();
    }

    #[test]
    fn ring_overflow_keeps_newest_events() {
        let _g = lock();
        set_enabled(true);
        // Miri interprets every atomic store; flooding a full ring
        // would dominate the run, and 64 events already exercise the
        // push/drain protocol end to end.
        let flood = if cfg!(miri) { 64 } else { RING_CAP + 64 };
        for _ in 0..flood {
            let _s = crate::span!("test.flood");
        }
        {
            let _last = crate::span!("test.flood_last");
        }
        set_enabled(false);
        let events = drain();
        assert!(events.iter().any(|e| e.name == "test.flood_last"));
        assert!(events.iter().filter(|e| e.name == "test.flood").count() <= RING_CAP);
        clear();
    }
}
