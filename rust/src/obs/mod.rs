//! Observability: tracing, metric primitives, and leveled logging.
//!
//! Three dependency-free layers, designed so the serving hot paths pay
//! (close to) nothing when observability is off:
//!
//! - [`trace`] — `span!("refresh.block_solve")`-style RAII spans on
//!   thread-local lock-free ring buffers. Disabled cost is one relaxed
//!   `AtomicBool` load and a branch; enabled cost is two `Instant`
//!   reads and four atomic stores per span. [`trace::Tracer::dump_json`]
//!   exports Chrome trace-event JSON loadable in `chrome://tracing` /
//!   Perfetto, also served by the coordinator's `/trace` route. Enable
//!   with `MSGP_TRACE=1` or `Tracer::set_enabled(true)`.
//! - [`metrics`] — typed [`metrics::Counter`] / [`metrics::Gauge`] /
//!   [`metrics::LogHistogram`] primitives (drop-in `AtomicU64`
//!   signatures) plus a Prometheus text-exposition writer used by
//!   `/metrics?format=prom`.
//! - [`log`] — `log_warn!`-style leveled stderr logging gated by the
//!   `MSGP_LOG` env var (default `warn`).
//!
//! See `docs/METRICS.md` for the metric-name reference and a tracing
//! walkthrough.

pub mod log;
pub mod metrics;
pub mod trace;

pub use trace::{now_us, SpanEvent, SpanGuard, Tracer};
