//! A tiny leveled logger gated by the `MSGP_LOG` environment variable.
//!
//! The serving stack used to fall back to bare once-per-process
//! `eprintln!` calls for diagnostics (preconditioner degradation, PJRT
//! unavailability, stream re-optimization failures). Those paths now go
//! through [`log_error!`] / [`log_warn!`] / [`log_info!`] /
//! [`log_debug!`], which print to stderr with a level + module prefix
//! and are filtered by a process-wide level parsed **once** from
//! `MSGP_LOG` (`off`, `error`, `warn` (default), `info`, `debug`; a
//! bare number 0–4 also works). The per-call cost when filtered out is
//! one relaxed atomic load and a compare — cheap enough for refresh
//! threads. The level can also be set programmatically with
//! [`set_level`] (tests use this).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

/// Log verbosity, ordered: messages at or below the current level
/// print.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Nothing prints.
    Off = 0,
    /// Hard failures only.
    Error = 1,
    /// Degradations worth knowing about (default).
    Warn = 2,
    /// Lifecycle events.
    Info = 3,
    /// Everything.
    Debug = 4,
}

impl Level {
    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Off,
            1 => Level::Error,
            2 => Level::Warn,
            3 => Level::Info,
            _ => Level::Debug,
        }
    }

    /// Parse a level name (case-insensitive) or a bare digit.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "none" | "0" => Some(Level::Off),
            "error" | "1" => Some(Level::Error),
            "warn" | "warning" | "2" => Some(Level::Warn),
            "info" | "3" => Some(Level::Info),
            "debug" | "trace" | "4" => Some(Level::Debug),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Off => "OFF",
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);
static INIT: Once = Once::new();

/// Parse `MSGP_LOG` once per process; later calls are no-ops. Invoked
/// lazily by [`enabled`], so call sites never need explicit init.
pub fn init_from_env() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("MSGP_LOG") {
            if let Some(level) = Level::parse(&v) {
                LEVEL.store(level as u8, Ordering::Relaxed);
            }
        }
    });
}

/// Override the level programmatically (also marks env init done).
pub fn set_level(level: Level) {
    INIT.call_once(|| {});
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current level.
pub fn level() -> Level {
    init_from_env();
    Level::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// Would a message at `at` print right now?
pub fn enabled(at: Level) -> bool {
    at <= level() && at != Level::Off
}

/// Print one formatted record to stderr (called by the macros after
/// the level check passed).
pub fn emit(at: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    eprintln!("[{:<5} {}] {}", at.tag(), module, msg);
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Error,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    };
}

/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Warn,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    };
}

/// Log at [`Level::Info`].
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Info,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    };
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::emit(
                $crate::obs::log::Level::Debug,
                module_path!(),
                format_args!($($arg)*),
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_names_and_digits() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("0"), Some(Level::Off));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_ordering_gates_messages() {
        assert!(Level::Error <= Level::Warn);
        assert!(Level::Debug > Level::Info);
        // enabled() is monotone in the configured level; Off never
        // prints regardless.
        set_level(Level::Info);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Off);
        assert!(!enabled(Level::Error));
        set_level(Level::Warn); // restore default for other tests
    }
}
