//! Worker supervision policy: capped exponential backoff with jitter
//! and poison-after-N-failures-in-a-window.
//!
//! The [`Supervisor`] is deliberately pure policy — it decides *what* to
//! do after a failure ([`Verdict`]), while the owning loop performs the
//! `catch_unwind`, the sleep, and the metric increments. The serving
//! stack wraps three worker kinds with it (see `docs/RELIABILITY.md`):
//! the unsharded ingest/refresh thread, every shard worker, and the
//! HTTP connection workers. All of them supervise **per iteration with
//! retained state**: a panic is caught at an operation boundary (one
//! ingest batch, one message, one connection), the in-flight operation
//! is abandoned, and the worker's accumulated state survives — the
//! failpoints and panics the chaos suite injects all fire *between*
//! statistic updates, and a worker whose state could be torn mid-update
//! must poison itself rather than restart.

use std::time::{Duration, Instant};

/// Restart policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorPolicy {
    /// Poison the worker after this many failures inside [`Self::window`].
    pub max_failures: u32,
    /// Sliding window for the failure count.
    pub window: Duration,
    /// First restart delay; doubles per consecutive recent failure.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
}

impl Default for SupervisorPolicy {
    fn default() -> Self {
        SupervisorPolicy {
            max_failures: 5,
            window: Duration::from_secs(30),
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_secs(2),
        }
    }
}

/// What the owning loop should do after a caught failure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Sleep the given backoff, then resume the worker loop.
    Restart(Duration),
    /// Stop restarting: flip the worker's poisoned gauge (which takes
    /// `/healthz` to 503) and exit the loop.
    Poison,
}

/// Per-worker failure tracker (owned by the worker's thread; no locks).
#[derive(Debug)]
pub struct Supervisor {
    policy: SupervisorPolicy,
    /// Recent failure instants within the policy window.
    recent: Vec<Instant>,
    /// Total failures over the worker's lifetime.
    pub failures_total: u64,
    /// Jitter stream state (SplitMix64; seeded per worker so two workers
    /// panicking together do not thundering-herd their restarts).
    jitter: u64,
}

impl Supervisor {
    /// New tracker; `seed` decorrelates jitter across workers (any
    /// stable per-worker value — an id, a name hash).
    pub fn new(policy: SupervisorPolicy, seed: u64) -> Self {
        Supervisor { policy, recent: Vec::new(), failures_total: 0, jitter: seed }
    }

    /// Record a failure at `now` and decide. Exposed with an explicit
    /// clock for deterministic tests; production loops call
    /// [`Self::on_failure`].
    pub fn on_failure_at(&mut self, now: Instant) -> Verdict {
        self.failures_total += 1;
        let window = self.policy.window;
        self.recent.retain(|&t| now.duration_since(t) < window);
        self.recent.push(now);
        if self.recent.len() as u32 > self.policy.max_failures {
            return Verdict::Poison;
        }
        // Capped exponential backoff on the recent-failure streak.
        let exp = (self.recent.len() as u32).saturating_sub(1).min(20);
        let base = self.policy.backoff_base.as_millis() as u64;
        let cap = self.policy.backoff_cap.as_millis() as u64;
        let raw = base.saturating_mul(1u64 << exp).min(cap);
        // Jitter in [0.5, 1.5) — desynchronizes co-panicking workers.
        let jitter_ms = (raw as f64 * (0.5 + self.next_uniform())) as u64;
        Verdict::Restart(Duration::from_millis(jitter_ms.min(cap)))
    }

    /// Record a failure now and decide.
    pub fn on_failure(&mut self) -> Verdict {
        self.on_failure_at(Instant::now())
    }

    /// Failures currently inside the sliding window (diagnostics).
    pub fn recent_failures(&self) -> usize {
        self.recent.len()
    }

    fn next_uniform(&mut self) -> f64 {
        self.jitter = self.jitter.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.jitter;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> SupervisorPolicy {
        SupervisorPolicy {
            max_failures: 3,
            window: Duration::from_secs(10),
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(500),
        }
    }

    #[test]
    fn backoff_grows_then_poisons_within_window() {
        let mut s = Supervisor::new(policy(), 42);
        let t0 = Instant::now();
        let mut delays = Vec::new();
        for k in 0..3 {
            match s.on_failure_at(t0 + Duration::from_millis(k)) {
                Verdict::Restart(d) => delays.push(d),
                Verdict::Poison => panic!("poisoned too early at failure {k}"),
            }
        }
        // Jitter is [0.5, 1.5)x, so consecutive raw doublings still
        // order: 10ms*[0.5,1.5) < 40ms*0.5 is not guaranteed pairwise,
        // but first (5..15ms) vs third (20..60ms) must order.
        assert!(delays[0] < delays[2], "{delays:?}");
        assert!(delays.iter().all(|d| *d <= Duration::from_millis(500)));
        assert_eq!(
            s.on_failure_at(t0 + Duration::from_millis(5)),
            Verdict::Poison,
            "4th failure in the window must poison"
        );
        assert_eq!(s.failures_total, 4);
    }

    #[test]
    fn old_failures_age_out_of_the_window() {
        let mut s = Supervisor::new(policy(), 7);
        let t0 = Instant::now();
        for k in 0..3 {
            assert!(matches!(
                s.on_failure_at(t0 + Duration::from_millis(k)),
                Verdict::Restart(_)
            ));
        }
        // Outside the 10s window the streak resets: no poison, and the
        // backoff restarts from the base tier.
        let later = t0 + Duration::from_secs(11);
        match s.on_failure_at(later) {
            Verdict::Restart(d) => assert!(d < Duration::from_millis(20), "{d:?}"),
            Verdict::Poison => panic!("aged-out failures must not poison"),
        }
        assert_eq!(s.recent_failures(), 1);
    }

    #[test]
    fn backoff_respects_the_cap() {
        let mut s = Supervisor::new(
            SupervisorPolicy {
                max_failures: 50,
                window: Duration::from_secs(600),
                backoff_base: Duration::from_millis(100),
                backoff_cap: Duration::from_millis(300),
            },
            9,
        );
        let t0 = Instant::now();
        for k in 0..20 {
            match s.on_failure_at(t0 + Duration::from_millis(k)) {
                Verdict::Restart(d) => assert!(d <= Duration::from_millis(300), "{d:?}"),
                Verdict::Poison => panic!("under max_failures"),
            }
        }
    }
}
