//! Dependency-free failpoint injection.
//!
//! A `failpoint!("name")` site compiles to **one relaxed atomic load**
//! when the framework is disarmed — the same zero-cost-when-off
//! discipline as [`crate::span!`] — so the hazardous-site registry can
//! stay compiled into release builds and the chaos suite (and CI) can
//! arm it at runtime. Sites are armed either from the environment
//! (`MSGP_FAILPOINTS`, read once at server start) or live over HTTP
//! (`GET /failpoints?set=...`), with four actions:
//!
//! | action      | effect at the site                                  |
//! |-------------|-----------------------------------------------------|
//! | `panic`     | `panic!` (exercises the supervisors)                |
//! | `error`     | takes the site's error arm (`failpoint!(name, ..)`) |
//! | `sleep(ms)` | blocks the calling thread `ms` milliseconds         |
//! | `off`       | removes the failpoint                               |
//!
//! Grammar (both `=` and `:` separate name from action, so the spec
//! survives URL query strings unencoded):
//!
//! ```text
//! spec     := entry (';' entry)*
//! entry    := name ('=' | ':') action ('@' probability)?
//! action   := 'panic' | 'error' | 'sleep(' millis ')' | 'off'
//! ```
//!
//! e.g. `MSGP_FAILPOINTS='shard.refresh=panic@0.1;ckpt.rename=error'`.
//! Probabilities are sampled from a dedicated lock-free SplitMix64
//! stream (never the model RNGs, so arming a failpoint cannot perturb
//! statistical reproducibility). Registered site names are listed in
//! `docs/RELIABILITY.md`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// Global arm flag: `true` iff at least one failpoint is configured.
static ARMED: AtomicBool = AtomicBool::new(false);

/// Is any failpoint configured? This is the only cost a `failpoint!`
/// site pays when the framework is idle.
#[inline(always)]
pub fn armed() -> bool {
    // ORDERING: Relaxed — a standalone on/off flag with no associated
    // payload to publish; the registry mutex inside `hit` provides the
    // synchronization for the configuration itself.
    ARMED.load(Ordering::Relaxed)
}

/// What a configured failpoint does when its probability gate passes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FpAction {
    /// Panic at the site (supervision / restart drills).
    Panic,
    /// Make the site's `failpoint!(name, on_error)` arm run.
    Error,
    /// Block the calling thread (latency / deadline drills).
    Sleep(u64),
}

impl FpAction {
    fn name(self) -> String {
        match self {
            FpAction::Panic => "panic".to_string(),
            FpAction::Error => "error".to_string(),
            FpAction::Sleep(ms) => format!("sleep({ms})"),
        }
    }
}

#[derive(Clone, Debug)]
struct FpEntry {
    action: FpAction,
    /// Firing probability in `[0, 1]`; 1.0 = every hit.
    prob: f64,
    /// Times the site was reached while configured.
    hits: u64,
    /// Times the action actually fired (passed the probability gate).
    fires: u64,
}

/// One row of the `/failpoints` status listing.
#[derive(Clone, Debug)]
pub struct FpStatus {
    pub name: String,
    pub action: String,
    pub prob: f64,
    pub hits: u64,
    pub fires: u64,
}

/// The configured-failpoint table. Leaf lock (see
/// [`crate::analysis::LOCK_ORDER`]): never held across a site's action
/// or any other lock acquisition.
fn fp_registry() -> &'static Mutex<HashMap<String, FpEntry>> {
    static REG: OnceLock<Mutex<HashMap<String, FpEntry>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Lock-free uniform sample for probability gates: a SplitMix64 stream
/// advanced by atomic fetch-add, independent of every model RNG.
fn sample_uniform() -> f64 {
    static FP_SEED: AtomicU64 = AtomicU64::new(0x5eed_fa11_9097_u64);
    // ORDERING: Relaxed — the counter only needs uniqueness per call,
    // not ordering against any other memory.
    let mut z = FP_SEED.fetch_add(0x9E3779B97F4A7C15, Ordering::Relaxed);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Runtime entry of an armed `failpoint!` site. Returns `true` when the
/// configured action is [`FpAction::Error`] and the probability gate
/// passed — the macro's second form runs its error arm on `true`.
/// `Panic`/`Sleep` are performed here (after the registry lock is
/// released, so a sleeping or unwinding site never holds it).
pub fn hit(name: &str) -> bool {
    let fired = {
        let mut reg = fp_registry().lock().unwrap_or_else(|e| e.into_inner());
        match reg.get_mut(name) {
            Some(e) => {
                e.hits += 1;
                if e.prob < 1.0 && sample_uniform() >= e.prob {
                    None
                } else {
                    e.fires += 1;
                    Some(e.action)
                }
            }
            None => None,
        }
    };
    match fired {
        Some(FpAction::Panic) => panic!("failpoint `{name}` fired: injected panic"),
        Some(FpAction::Sleep(ms)) => {
            std::thread::sleep(Duration::from_millis(ms));
            false
        }
        Some(FpAction::Error) => true,
        None => false,
    }
}

/// Parse and install a failpoint spec (see the [module docs](self) for
/// the grammar), merging into the current table; `name=off` removes an
/// entry. Returns the number of entries now configured. On a malformed
/// entry nothing before it is rolled back (each entry applies as it
/// parses) and the error describes the offending fragment.
pub fn configure(spec: &str) -> Result<usize, String> {
    let mut reg = fp_registry().lock().unwrap_or_else(|e| e.into_inner());
    for part in spec.split(';') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, rhs) = part
            .split_once('=')
            .or_else(|| part.split_once(':'))
            .ok_or_else(|| format!("failpoint entry `{part}` missing `=` or `:`"))?;
        let (name, rhs) = (name.trim(), rhs.trim());
        if name.is_empty() {
            return Err(format!("failpoint entry `{part}` has an empty name"));
        }
        let (action_s, prob) = match rhs.split_once('@') {
            Some((a, p)) => {
                let prob: f64 = p
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad probability `{p}` in `{part}`"))?;
                if !(0.0..=1.0).contains(&prob) {
                    return Err(format!("probability {prob} outside [0, 1] in `{part}`"));
                }
                (a.trim(), prob)
            }
            None => (rhs, 1.0),
        };
        if action_s == "off" {
            reg.remove(name);
            continue;
        }
        let action = if action_s == "panic" {
            FpAction::Panic
        } else if action_s == "error" {
            FpAction::Error
        } else if let Some(ms) = action_s
            .strip_prefix("sleep(")
            .and_then(|r| r.strip_suffix(')'))
        {
            let ms: u64 =
                ms.trim().parse().map_err(|_| format!("bad sleep millis in `{part}`"))?;
            FpAction::Sleep(ms)
        } else {
            return Err(format!(
                "unknown failpoint action `{action_s}` (want panic | error | sleep(ms) | off)"
            ));
        };
        reg.insert(
            name.to_string(),
            FpEntry { action, prob, hits: 0, fires: 0 },
        );
    }
    let count = reg.len();
    // ORDERING: Relaxed — see `armed`; the registry mutex (still held
    // here) orders the table contents.
    ARMED.store(count > 0, Ordering::Relaxed);
    Ok(count)
}

/// Remove every configured failpoint and disarm the framework.
pub fn clear_all() {
    let mut reg = fp_registry().lock().unwrap_or_else(|e| e.into_inner());
    reg.clear();
    // ORDERING: Relaxed — see `armed`.
    ARMED.store(false, Ordering::Relaxed);
}

/// Current table with hit/fire counters, sorted by name (for
/// `/failpoints` and test assertions).
pub fn snapshot() -> Vec<FpStatus> {
    let reg = fp_registry().lock().unwrap_or_else(|e| e.into_inner());
    let mut out: Vec<FpStatus> = reg
        .iter()
        .map(|(name, e)| FpStatus {
            name: name.clone(),
            action: e.action.name(),
            prob: e.prob,
            hits: e.hits,
            fires: e.fires,
        })
        .collect();
    out.sort_by(|a, b| a.name.cmp(&b.name));
    out
}

/// Arm failpoints from `MSGP_FAILPOINTS` (no-op when unset or empty;
/// a malformed spec logs and leaves the framework disarmed rather than
/// half-armed). Called by the server start paths.
pub fn init_from_env() {
    if let Ok(spec) = std::env::var("MSGP_FAILPOINTS") {
        if spec.trim().is_empty() {
            return;
        }
        if let Err(e) = configure(&spec) {
            clear_all();
            crate::log_warn!("ignoring MSGP_FAILPOINTS: {e}");
        }
    }
}

/// Declare a failpoint site.
///
/// * `failpoint!("name")` — statement form: performs `panic` / `sleep`
///   actions when armed and configured; `error` is a no-op here.
/// * `failpoint!("name", expr)` — error form: additionally runs `expr`
///   (typically an early `return Err(..)` or a state poke) when the
///   configured action is `error` and the probability gate passes.
///
/// Disarmed cost: one relaxed atomic load and a never-taken branch.
#[macro_export]
macro_rules! failpoint {
    ($name:literal) => {{
        if $crate::fault::armed() {
            let _ = $crate::fault::hit($name);
        }
    }};
    ($name:literal, $on_error:expr) => {{
        if $crate::fault::armed() && $crate::fault::hit($name) {
            $on_error
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global; serialize tests that mutate it.
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disarmed_sites_do_nothing() {
        let _g = guard();
        clear_all();
        assert!(!armed());
        let mut touched = false;
        crate::failpoint!("test.nowhere", touched = true);
        assert!(!touched);
    }

    #[test]
    fn spec_parses_and_error_action_fires() {
        let _g = guard();
        clear_all();
        let n = configure("test.err=error; test.zero:error@0.0").unwrap();
        assert_eq!(n, 2);
        assert!(armed());
        let mut fired = 0;
        for _ in 0..5 {
            crate::failpoint!("test.err", fired += 1);
        }
        assert_eq!(fired, 5);
        // Probability 0 never fires but still counts hits.
        let mut zero_fired = false;
        for _ in 0..50 {
            crate::failpoint!("test.zero", zero_fired = true);
        }
        assert!(!zero_fired);
        let snap = snapshot();
        let z = snap.iter().find(|s| s.name == "test.zero").unwrap();
        assert_eq!(z.hits, 50);
        assert_eq!(z.fires, 0);
        let e = snap.iter().find(|s| s.name == "test.err").unwrap();
        assert_eq!((e.hits, e.fires), (5, 5));
        // `off` removes; an empty table disarms.
        configure("test.err=off; test.zero=off").unwrap();
        assert!(!armed());
        clear_all();
    }

    #[test]
    fn panic_action_panics_and_sleep_sleeps() {
        let _g = guard();
        clear_all();
        configure("test.panic=panic").unwrap();
        let caught = std::panic::catch_unwind(|| hit("test.panic"));
        assert!(caught.is_err());
        configure("test.panic=off; test.sleep=sleep(30)").unwrap();
        let t0 = std::time::Instant::now();
        assert!(!hit("test.sleep"));
        assert!(t0.elapsed() >= Duration::from_millis(25));
        clear_all();
    }

    #[test]
    fn malformed_specs_are_rejected() {
        let _g = guard();
        clear_all();
        assert!(configure("noseparator").is_err());
        assert!(configure("a=explode").is_err());
        assert!(configure("a=error@1.5").is_err());
        assert!(configure("a=sleep(abc)").is_err());
        assert!(configure("=error").is_err());
        clear_all();
    }

    #[test]
    fn probability_gate_is_roughly_calibrated() {
        let _g = guard();
        clear_all();
        configure("test.half=error@0.5").unwrap();
        let mut fired = 0u32;
        for _ in 0..2000 {
            if hit("test.half") {
                fired += 1;
            }
        }
        assert!((600..1400).contains(&fired), "fired {fired} of 2000 at p=0.5");
        clear_all();
    }
}
