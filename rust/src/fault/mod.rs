//! Fault tolerance: failpoint injection, worker supervision, and
//! crash-safe checkpoint/restore of the SKI sufficient statistics.
//!
//! The additive statistics the streaming subsystem maintains (`W^T y`,
//! the banded Gram, probe accumulators — see [`crate::stream`]) are
//! designed to merge and replay, which makes durability cheap: a
//! checkpoint is just the accumulators plus the hypers, grid, and RNG
//! state, and recovery is "load and keep adding". This module supplies
//! the three layers the serving stack's reliability pass is built on:
//!
//! * [`failpoint`] — a dependency-free `failpoint!("name")` macro
//!   (one relaxed atomic load when disarmed) with env/HTTP-configured
//!   panic / error / sleep actions and probabilities, registered at the
//!   hazardous sites across refresh, sharding, checkpointing, HTTP, and
//!   CG. The chaos suite (`rust/tests/robustness.rs`) drives it.
//! * [`supervisor`] — restart policy for the serving workers: capped
//!   exponential backoff with per-worker jitter, and a
//!   poison-after-N-failures-in-a-window verdict that flips `/healthz`
//!   to 503 instead of restart-looping forever.
//! * [`codec`] — the versioned, length-prefixed, checksummed binary
//!   encoding of checkpoints and peer-replication [`codec::Frame`]s
//!   (the ROADMAP direction-2 wire format, now live in
//!   [`crate::cluster`]), atomic tmp+fsync+rename writes with rotation,
//!   and newest-valid recovery.
//!
//! Operational reference: `docs/RELIABILITY.md` and `docs/CLUSTER.md`.

pub mod codec;
pub mod failpoint;
pub mod supervisor;

pub use codec::{
    load, load_newest, read_frame, write_atomic, write_frame, Checkpoint, CkptConfig, CkptTrigger,
    CodecError, Frame,
};
pub use failpoint::{armed, clear_all, configure, hit, init_from_env, snapshot, FpStatus};
pub use supervisor::{Supervisor, SupervisorPolicy, Verdict};
