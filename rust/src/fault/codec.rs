//! Versioned binary checkpoint codec for the additive SKI statistics —
//! the first cut of the ROADMAP direction-2 wire format.
//!
//! The streaming state worth durably persisting is exactly the
//! merge-friendly sufficient statistics: `W^T y`, the banded Gram
//! `W^T W`, per-cell counts, the probe accumulators, the decay-weighted
//! scalar sums, plus the hypers, grid, and the ingest RNG state (so a
//! restored process replays the *identical* probe-noise sequence — the
//! 1e-10 crash-recovery parity guarantee rests on it). Reservoir
//! contents are deliberately NOT checkpointed: they only seed hyper
//! re-optimization and refill within one `reopt_every` period.
//!
//! ## Bytes on the wire (version 1)
//!
//! All integers little-endian; all `f64` as IEEE-754 bit patterns
//! (`to_bits`), so round-trips are bit-exact. Layout (see
//! `docs/RELIABILITY.md` for the field-by-field table):
//!
//! ```text
//! magic    "MSGPCKPT"                  8 bytes
//! version  u32                         = 1
//! len      u64                         payload byte count
//! payload  [len bytes]                 see below
//! checksum u64                         FNV-1a 64 over payload
//! ```
//!
//! Payload: `seq u64 | sigma2 f64 | kernel | ski_count u32 | ski*`.
//! A kernel is `tag u8` (0 = product, 1 = iso) followed by the variant
//! fields; a kernel *type* is `tag u8` (0 SE, 1 Matérn-1/2, 2 Matérn-3/2,
//! 3 Matérn-5/2, 4 RQ + `alpha_milli u32`). Each ski block:
//!
//! ```text
//! grid       dim u32, then per axis: lo f64, step f64, n u64
//! scalars    margin_cells u64, n u64, weight f64, sum_y f64, sum_y2 f64
//! rng        s[0..4] u64 x4, spare tag u8 (0|1), spare f64 if tag = 1
//! wty        u64 len + f64 x len
//! counts     u64 len + f64 x len
//! bands      u32 count, then per band: u64 len + f64 x len
//! probes     u32 count, then per probe: u64 len + f64 x len
//! ```
//!
//! Decoding validates every length against the decoded grid (via
//! [`IncrementalSki::from_parts`]) and bounds every allocation by the
//! bytes actually remaining, so corrupted or truncated files produce a
//! typed [`CodecError`] — never a panic, never a silently empty state.

use std::fmt;
use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::gp::msgp::KernelSpec;
use crate::grid::{Grid, GridAxis};
use crate::kernels::{KernelType, ProductKernel};
use crate::stream::IncrementalSki;
use crate::util::Rng;

const MAGIC: &[u8; 8] = b"MSGPCKPT";
/// Current format version. History: 1 = initial layout (this PR).
pub const VERSION: u32 = 1;

/// Why a checkpoint could not be read or written.
#[derive(Debug)]
pub enum CodecError {
    /// The file does not start with the `MSGPCKPT` magic.
    BadMagic,
    /// A version this build does not speak.
    BadVersion(u32),
    /// The file ends before the declared payload + checksum.
    Truncated,
    /// The payload checksum does not match (torn or corrupted write).
    ChecksumMismatch,
    /// Structurally invalid payload (bad tag, length mismatch, ...).
    Malformed(String),
    /// An injected failpoint failure (`ckpt.write` / `ckpt.rename`).
    Injected(&'static str),
    /// Underlying filesystem error.
    Io(std::io::Error),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::BadMagic => write!(f, "not a MSGP checkpoint (bad magic)"),
            CodecError::BadVersion(v) => write!(f, "unsupported checkpoint version {v}"),
            CodecError::Truncated => write!(f, "checkpoint truncated"),
            CodecError::ChecksumMismatch => write!(f, "checkpoint checksum mismatch"),
            CodecError::Malformed(m) => write!(f, "malformed checkpoint: {m}"),
            CodecError::Injected(fp) => write!(f, "injected failure at failpoint `{fp}`"),
            CodecError::Io(e) => write!(f, "checkpoint io error: {e}"),
        }
    }
}

impl std::error::Error for CodecError {}

impl From<std::io::Error> for CodecError {
    fn from(e: std::io::Error) -> Self {
        CodecError::Io(e)
    }
}

/// FNV-1a 64 over `bytes` — dependency-free, byte-order independent.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A durable snapshot of one trainer's (or one shard's) statistics.
#[derive(Clone)]
pub struct Checkpoint {
    /// Monotone checkpoint sequence (also the decay-epoch marker: it
    /// advances on every write, so a restored process knows how stale
    /// its statistics are relative to the last good write).
    pub seq: u64,
    /// Kernel hypers at checkpoint time.
    pub kernel: KernelSpec,
    /// Noise variance at checkpoint time.
    pub sigma2: f64,
    /// The accumulators: one for the unsharded trainer, `[own, halo]`
    /// for a shard worker.
    pub skis: Vec<IncrementalSki>,
}

// ---------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f64(x);
        }
    }
    fn ktype(&mut self, k: KernelType) {
        match k {
            KernelType::SE => self.u8(0),
            KernelType::Matern12 => self.u8(1),
            KernelType::Matern32 => self.u8(2),
            KernelType::Matern52 => self.u8(3),
            KernelType::RQ { alpha_milli } => {
                self.u8(4);
                self.u32(alpha_milli);
            }
        }
    }
    fn kernel(&mut self, k: &KernelSpec) {
        match k {
            KernelSpec::Product(p) => {
                self.u8(0);
                self.u32(p.types.len() as u32);
                for &t in &p.types {
                    self.ktype(t);
                }
                self.f64s(&p.log_ell);
                self.f64(p.log_sf2);
            }
            KernelSpec::Iso { ktype, log_ell, log_sf2, dim } => {
                self.u8(1);
                self.ktype(*ktype);
                self.f64(*log_ell);
                self.f64(*log_sf2);
                self.u32(*dim as u32);
            }
        }
    }
    fn ski(&mut self, s: &IncrementalSki) {
        let grid = s.grid();
        self.u32(grid.dim() as u32);
        for ax in &grid.axes {
            self.f64(ax.lo);
            self.f64(ax.step);
            self.u64(ax.n as u64);
        }
        self.u64(s.margin_cells() as u64);
        self.u64(s.n() as u64);
        self.f64(s.weight());
        self.f64(s.sum_y());
        self.f64(s.sum_y2());
        let (rs, spare) = s.rng_state();
        for w in rs {
            self.u64(w);
        }
        match spare {
            None => self.u8(0),
            Some(v) => {
                self.u8(1);
                self.f64(v);
            }
        }
        self.f64s(s.wty());
        self.f64s(s.counts());
        self.u32(s.bands().len() as u32);
        for b in s.bands() {
            self.f64s(b);
        }
        self.u32(s.probes().len() as u32);
        for q in s.probes() {
            self.f64s(q);
        }
    }
}

impl Checkpoint {
    /// Serialize to the framed wire format (header + payload + checksum).
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc { buf: Vec::new() };
        e.u64(self.seq);
        e.f64(self.sigma2);
        e.kernel(&self.kernel);
        e.u32(self.skis.len() as u32);
        for s in &self.skis {
            e.ski(s);
        }
        let payload = e.buf;
        let mut out = Vec::with_capacity(payload.len() + 28);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        let sum = fnv1a64(&payload);
        out.extend_from_slice(&payload);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse a framed checkpoint, validating magic, version, length,
    /// checksum, and every structural invariant.
    pub fn decode(bytes: &[u8]) -> Result<Self, CodecError> {
        if bytes.len() < 8 || &bytes[..8] != MAGIC {
            return Err(CodecError::BadMagic);
        }
        if bytes.len() < 20 {
            return Err(CodecError::Truncated);
        }
        // PANIC-OK: fixed 4-byte slice of a length-checked buffer.
        let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
        if version != VERSION {
            return Err(CodecError::BadVersion(version));
        }
        // PANIC-OK: fixed 8-byte slice of a length-checked buffer.
        let plen = u64::from_le_bytes(bytes[12..20].try_into().expect("8 bytes")) as usize;
        let Some(end) = plen.checked_add(20) else {
            return Err(CodecError::Truncated);
        };
        if bytes.len() < end + 8 {
            return Err(CodecError::Truncated);
        }
        let payload = &bytes[20..end];
        // PANIC-OK: fixed 8-byte slice of a length-checked buffer.
        let sum = u64::from_le_bytes(bytes[end..end + 8].try_into().expect("8 bytes"));
        if fnv1a64(payload) != sum {
            return Err(CodecError::ChecksumMismatch);
        }
        let mut d = Dec { b: payload, pos: 0 };
        let seq = d.u64()?;
        let sigma2 = d.f64()?;
        if !(sigma2.is_finite() && sigma2 >= 0.0) {
            return Err(CodecError::Malformed(format!("bad sigma2 {sigma2}")));
        }
        let kernel = d.kernel()?;
        let nski = d.u32()? as usize;
        if nski == 0 || nski > 1024 {
            return Err(CodecError::Malformed(format!("implausible ski count {nski}")));
        }
        let mut skis = Vec::with_capacity(nski);
        for _ in 0..nski {
            skis.push(d.ski()?);
        }
        if d.pos != payload.len() {
            return Err(CodecError::Malformed(format!(
                "{} trailing payload bytes",
                payload.len() - d.pos
            )));
        }
        Ok(Checkpoint { seq, kernel, sigma2, skis })
    }
}

// ---------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        let end = self.pos.checked_add(n).ok_or(CodecError::Truncated)?;
        if end > self.b.len() {
            return Err(CodecError::Truncated);
        }
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, CodecError> {
        // PANIC-OK: take(4) returned exactly 4 bytes.
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }
    fn u64(&mut self) -> Result<u64, CodecError> {
        // PANIC-OK: take(8) returned exactly 8 bytes.
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }
    /// Length-prefixed f64 array; the allocation is bounded by the bytes
    /// actually remaining, so a corrupted length cannot OOM.
    fn f64s(&mut self) -> Result<Vec<f64>, CodecError> {
        let len = self.u64()? as usize;
        let need = len.checked_mul(8).ok_or(CodecError::Truncated)?;
        match self.pos.checked_add(need) {
            Some(end) if end <= self.b.len() => {}
            _ => return Err(CodecError::Truncated),
        }
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.f64()?);
        }
        Ok(v)
    }
    fn ktype(&mut self) -> Result<KernelType, CodecError> {
        Ok(match self.u8()? {
            0 => KernelType::SE,
            1 => KernelType::Matern12,
            2 => KernelType::Matern32,
            3 => KernelType::Matern52,
            4 => KernelType::RQ { alpha_milli: self.u32()? },
            t => return Err(CodecError::Malformed(format!("unknown kernel type tag {t}"))),
        })
    }
    fn kernel(&mut self) -> Result<KernelSpec, CodecError> {
        match self.u8()? {
            0 => {
                let dim = self.u32()? as usize;
                if dim == 0 || dim > 16 {
                    return Err(CodecError::Malformed(format!("implausible kernel dim {dim}")));
                }
                let mut types = Vec::with_capacity(dim);
                for _ in 0..dim {
                    types.push(self.ktype()?);
                }
                let log_ell = self.f64s()?;
                if log_ell.len() != dim {
                    return Err(CodecError::Malformed(format!(
                        "kernel log_ell length {} != dim {dim}",
                        log_ell.len()
                    )));
                }
                let log_sf2 = self.f64()?;
                Ok(KernelSpec::Product(ProductKernel { types, log_ell, log_sf2 }))
            }
            1 => {
                let ktype = self.ktype()?;
                let log_ell = self.f64()?;
                let log_sf2 = self.f64()?;
                let dim = self.u32()? as usize;
                if dim == 0 || dim > 16 {
                    return Err(CodecError::Malformed(format!("implausible kernel dim {dim}")));
                }
                Ok(KernelSpec::Iso { ktype, log_ell, log_sf2, dim })
            }
            t => Err(CodecError::Malformed(format!("unknown kernel tag {t}"))),
        }
    }
    fn grid(&mut self) -> Result<Grid, CodecError> {
        let dim = self.u32()? as usize;
        if dim == 0 || dim > 8 {
            return Err(CodecError::Malformed(format!("implausible grid dim {dim}")));
        }
        let mut axes = Vec::with_capacity(dim);
        let mut m: usize = 1;
        for _ in 0..dim {
            let lo = self.f64()?;
            let step = self.f64()?;
            let n = self.u64()? as usize;
            if !(lo.is_finite() && step.is_finite() && step > 0.0) || n == 0 {
                return Err(CodecError::Malformed(format!(
                    "bad grid axis (lo {lo}, step {step}, n {n})"
                )));
            }
            m = m.checked_mul(n).ok_or_else(|| {
                CodecError::Malformed("grid cell count overflows".to_string())
            })?;
            axes.push(GridAxis { lo, step, n });
        }
        if m > (1 << 28) {
            return Err(CodecError::Malformed(format!("implausible grid size m = {m}")));
        }
        Ok(Grid { axes })
    }
    fn ski(&mut self) -> Result<IncrementalSki, CodecError> {
        let grid = self.grid()?;
        let margin_cells = self.u64()? as usize;
        let n = self.u64()? as usize;
        let weight = self.f64()?;
        let sum_y = self.f64()?;
        let sum_y2 = self.f64()?;
        let mut s = [0u64; 4];
        for w in &mut s {
            *w = self.u64()?;
        }
        let spare = match self.u8()? {
            0 => None,
            1 => Some(self.f64()?),
            t => return Err(CodecError::Malformed(format!("bad rng spare tag {t}"))),
        };
        let rng = Rng::from_state(s, spare);
        let wty = self.f64s()?;
        let counts = self.f64s()?;
        let nbands = self.u32()? as usize;
        if nbands == 0 || nbands > 7usize.pow(8) {
            return Err(CodecError::Malformed(format!("implausible band count {nbands}")));
        }
        let mut bands = Vec::with_capacity(nbands);
        for _ in 0..nbands {
            bands.push(self.f64s()?);
        }
        let nprobes = self.u32()? as usize;
        if nprobes > 4096 {
            return Err(CodecError::Malformed(format!("implausible probe count {nprobes}")));
        }
        let mut probes = Vec::with_capacity(nprobes);
        for _ in 0..nprobes {
            probes.push(self.f64s()?);
        }
        IncrementalSki::from_parts(
            grid,
            wty,
            bands,
            counts,
            probes,
            margin_cells,
            n,
            weight,
            sum_y,
            sum_y2,
            rng,
        )
        .map_err(CodecError::Malformed)
    }
}

// ---------------------------------------------------------------------
// Atomic file persistence + recovery
// ---------------------------------------------------------------------

/// Rotated (previous-good) sibling of a checkpoint path: `X.ckpt.1`.
pub fn rotated(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".1");
    PathBuf::from(os)
}

/// Write `ckpt` to `path` crash-safely: serialize to `path.tmp`, fsync,
/// rotate the current file to `path.1`, rename the tmp into place, and
/// best-effort fsync the directory. At every interruption point the
/// previous checkpoint (at `path` or `path.1`) remains valid —
/// [`load_newest`] picks up whichever survived. Failpoints `ckpt.write`
/// (before fsync) and `ckpt.rename` (after rotation, before the final
/// rename — the "crash mid-rename" window) inject the two interesting
/// crashes.
pub fn write_atomic(path: &Path, ckpt: &Checkpoint) -> Result<(), CodecError> {
    let bytes = ckpt.encode();
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    {
        let mut f = File::create(&tmp)?;
        f.write_all(&bytes)?;
        crate::failpoint!("ckpt.write", {
            drop(f);
            let _ = std::fs::remove_file(&tmp);
            return Err(CodecError::Injected("ckpt.write"));
        });
        f.sync_all()?;
    }
    if path.exists() {
        // Keep the previous good file reachable until the new rename
        // lands; a crash here leaves `path.1` as the newest valid.
        let _ = std::fs::rename(path, rotated(path));
    }
    crate::failpoint!("ckpt.rename", {
        let _ = std::fs::remove_file(&tmp);
        return Err(CodecError::Injected("ckpt.rename"));
    });
    std::fs::rename(&tmp, path)?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// Read and decode the checkpoint at `path`.
pub fn load(path: &Path) -> Result<Checkpoint, CodecError> {
    let mut bytes = Vec::new();
    File::open(path)?.read_to_end(&mut bytes)?;
    Checkpoint::decode(&bytes)
}

/// Recover the newest *valid* checkpoint: `path` first, then the
/// rotated `path.1`. Invalid or unreadable candidates are skipped with
/// a warning (a torn final write falls back to the previous good file).
/// `None` when neither exists or neither validates.
pub fn load_newest(path: &Path) -> Option<(Checkpoint, PathBuf)> {
    for cand in [path.to_path_buf(), rotated(path)] {
        if !cand.exists() {
            continue;
        }
        match load(&cand) {
            Ok(c) => return Some((c, cand)),
            Err(e) => {
                crate::log_warn!("skipping invalid checkpoint {}: {e}", cand.display());
            }
        }
    }
    None
}

// ---------------------------------------------------------------------
// Cluster wire frames
// ---------------------------------------------------------------------

/// Magic prefix of a peer-replication frame (distinct from the
/// checkpoint magic so a frame can never be mistaken for a file).
pub const FRAME_MAGIC: &[u8; 8] = b"MSGPFRAM";

/// Frame payload cap (64 MiB): a `len` beyond this is treated as
/// corruption instead of an allocation request.
const FRAME_MAX_PAYLOAD: u64 = 64 * 1024 * 1024;

/// One peer-replication message (see `docs/CLUSTER.md`). The statistic
/// payloads reuse the checkpoint ski block byte-for-byte, wrapped in a
/// `FRAME_MAGIC | version u32 | kind u8 | len u64 | payload | fnv1a64`
/// envelope, so a delta survives the same corruption battery as a
/// checkpoint.
#[derive(Clone, Debug)]
pub enum Frame {
    /// Connection preamble: the sending node introduces itself.
    Hello {
        /// Sender's node id.
        node: u32,
    },
    /// Liveness beacon sent when the outbound queue idles.
    Heartbeat {
        /// Sender's node id.
        node: u32,
    },
    /// Additive statistic increment for one shard: the receiver folds
    /// `ski` into its replica via `accumulate_shifted`. `epoch` is the
    /// owner's cut counter; the receiver applies the frame only when
    /// `epoch` exceeds its per-shard watermark, so replays and
    /// reordered retries are no-ops.
    Delta {
        /// Owning node of `shard`.
        origin: u32,
        /// Global shard id.
        shard: u32,
        /// Owner's cut counter at the time this delta was cut.
        epoch: u64,
        /// The increment, represented as statistics on the shard's
        /// local grid (scalars are increments, not totals).
        ski: Box<IncrementalSki>,
    },
    /// Full-state snapshot of one shard (connection resync and rejoin
    /// catch-up). Replaces the receiver's replica when `epoch` exceeds
    /// its watermark.
    Full {
        /// Owning node of `shard`.
        origin: u32,
        /// Global shard id.
        shard: u32,
        /// Owner's cut counter covering this snapshot.
        epoch: u64,
        /// The complete accumulator on the shard's local grid.
        ski: Box<IncrementalSki>,
    },
    /// A rejoining node asks a peer for `Full` frames of every shard
    /// the peer knows (its own and its replicas).
    SyncRequest {
        /// Requester's node id.
        node: u32,
    },
    /// Terminates a `SyncRequest` response stream.
    SyncDone {
        /// Responder's node id.
        node: u32,
        /// Number of `Full` frames that preceded this marker.
        shards: u32,
    },
}

impl Frame {
    fn kind(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 0,
            Frame::Heartbeat { .. } => 1,
            Frame::Delta { .. } => 2,
            Frame::Full { .. } => 3,
            Frame::SyncRequest { .. } => 4,
            Frame::SyncDone { .. } => 5,
        }
    }

    /// Human-readable frame kind (logs and metrics labels).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "hello",
            Frame::Heartbeat { .. } => "heartbeat",
            Frame::Delta { .. } => "delta",
            Frame::Full { .. } => "full",
            Frame::SyncRequest { .. } => "sync_request",
            Frame::SyncDone { .. } => "sync_done",
        }
    }

    /// Serialize to the framed wire format (envelope + payload +
    /// checksum).
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc { buf: Vec::new() };
        match self {
            Frame::Hello { node } | Frame::Heartbeat { node } | Frame::SyncRequest { node } => {
                e.u32(*node);
            }
            Frame::SyncDone { node, shards } => {
                e.u32(*node);
                e.u32(*shards);
            }
            Frame::Delta { origin, shard, epoch, ski }
            | Frame::Full { origin, shard, epoch, ski } => {
                e.u32(*origin);
                e.u32(*shard);
                e.u64(*epoch);
                e.ski(ski);
            }
        }
        let payload = e.buf;
        let mut out = Vec::with_capacity(payload.len() + 29);
        out.extend_from_slice(FRAME_MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.kind());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        let sum = fnv1a64(&payload);
        out.extend_from_slice(&payload);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Parse one framed message, validating magic, version, length,
    /// checksum, and every structural invariant of the payload.
    pub fn decode(bytes: &[u8]) -> Result<Frame, CodecError> {
        if bytes.len() < 8 || &bytes[..8] != FRAME_MAGIC {
            return Err(CodecError::BadMagic);
        }
        if bytes.len() < 21 {
            return Err(CodecError::Truncated);
        }
        // PANIC-OK: fixed 4-byte slice of a length-checked buffer.
        let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
        if version != VERSION {
            return Err(CodecError::BadVersion(version));
        }
        let kind = bytes[12];
        // PANIC-OK: fixed 8-byte slice of a length-checked buffer.
        let plen = u64::from_le_bytes(bytes[13..21].try_into().expect("8 bytes"));
        if plen > FRAME_MAX_PAYLOAD {
            return Err(CodecError::Malformed(format!("implausible frame length {plen}")));
        }
        let plen = plen as usize;
        let Some(end) = plen.checked_add(21) else {
            return Err(CodecError::Truncated);
        };
        if bytes.len() < end + 8 {
            return Err(CodecError::Truncated);
        }
        let payload = &bytes[21..end];
        // PANIC-OK: fixed 8-byte slice of a length-checked buffer.
        let sum = u64::from_le_bytes(bytes[end..end + 8].try_into().expect("8 bytes"));
        if fnv1a64(payload) != sum {
            return Err(CodecError::ChecksumMismatch);
        }
        let mut d = Dec { b: payload, pos: 0 };
        let frame = match kind {
            0 => Frame::Hello { node: d.u32()? },
            1 => Frame::Heartbeat { node: d.u32()? },
            4 => Frame::SyncRequest { node: d.u32()? },
            5 => Frame::SyncDone { node: d.u32()?, shards: d.u32()? },
            2 | 3 => {
                let origin = d.u32()?;
                let shard = d.u32()?;
                let epoch = d.u64()?;
                let ski = Box::new(d.ski()?);
                if kind == 2 {
                    Frame::Delta { origin, shard, epoch, ski }
                } else {
                    Frame::Full { origin, shard, epoch, ski }
                }
            }
            t => return Err(CodecError::Malformed(format!("unknown frame kind {t}"))),
        };
        if d.pos != payload.len() {
            return Err(CodecError::Malformed(format!(
                "{} trailing frame bytes",
                payload.len() - d.pos
            )));
        }
        Ok(frame)
    }
}

/// Write one frame to a stream (a TCP socket with a write timeout; the
/// caller treats any error as a dead connection and resyncs after
/// reconnecting).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    w.write_all(&frame.encode())?;
    w.flush()
}

/// Read one frame from a stream. `Ok(None)` on clean EOF at a frame
/// boundary; mid-frame EOF, timeouts, and corruption are errors (the
/// caller drops the connection, and the peer full-resyncs on
/// reconnect).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>, CodecError> {
    let mut head = [0u8; 21];
    let mut got = 0usize;
    while got < head.len() {
        match r.read(&mut head[got..]) {
            Ok(0) => {
                return if got == 0 { Ok(None) } else { Err(CodecError::Truncated) };
            }
            Ok(k) => got += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(CodecError::Io(e)),
        }
    }
    if &head[..8] != FRAME_MAGIC {
        return Err(CodecError::BadMagic);
    }
    // PANIC-OK: fixed 8-byte slice of a fixed-size header buffer.
    let plen = u64::from_le_bytes(head[13..21].try_into().expect("8 bytes"));
    if plen > FRAME_MAX_PAYLOAD {
        return Err(CodecError::Malformed(format!("implausible frame length {plen}")));
    }
    let rest = plen as usize + 8;
    let mut buf = Vec::with_capacity(head.len() + rest);
    buf.extend_from_slice(&head);
    buf.resize(head.len() + rest, 0);
    let mut pos = head.len();
    while pos < buf.len() {
        match r.read(&mut buf[pos..]) {
            Ok(0) => return Err(CodecError::Truncated),
            Ok(k) => pos += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(CodecError::Io(e)),
        }
    }
    Frame::decode(&buf).map(Some)
}

/// Checkpointing configuration, from the environment:
/// `MSGP_CKPT_DIR` enables it (directory is created if missing);
/// `MSGP_CKPT_EVERY_POINTS` (default 4096) and `MSGP_CKPT_EVERY_MS`
/// (default 5000) bound the write cadence — a write triggers when
/// *either* threshold is crossed since the last one.
#[derive(Clone, Debug, Default)]
pub struct CkptConfig {
    /// Checkpoint directory; `None` disables checkpointing.
    pub dir: Option<PathBuf>,
    /// Ingested-point threshold between writes.
    pub every_points: usize,
    /// Wall-clock threshold between writes (milliseconds).
    pub every_ms: u64,
}

impl CkptConfig {
    /// Read the `MSGP_CKPT_*` knobs.
    pub fn from_env() -> Self {
        let dir = std::env::var("MSGP_CKPT_DIR")
            .ok()
            .filter(|s| !s.trim().is_empty())
            .map(PathBuf::from);
        let every_points = std::env::var("MSGP_CKPT_EVERY_POINTS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(4096);
        let every_ms = std::env::var("MSGP_CKPT_EVERY_MS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(5000);
        CkptConfig { dir, every_points, every_ms }
    }

    /// Checkpointing enabled?
    pub fn enabled(&self) -> bool {
        self.dir.is_some()
    }

    /// Checkpoint file path for the unsharded trainer.
    pub fn unsharded_path(&self) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join("ski.ckpt"))
    }

    /// Checkpoint file path for shard `id`.
    pub fn shard_path(&self, id: usize) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("ski-shard{id}.ckpt")))
    }

    /// Checkpoint file path for cluster node `id` (all shards the node
    /// owns, in shard order).
    pub fn node_path(&self, id: usize) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("ski-node{id}.ckpt")))
    }
}

/// Write-cadence tracker (owned by the writing thread).
#[derive(Debug)]
pub struct CkptTrigger {
    points_since: usize,
    last_write: std::time::Instant,
}

impl Default for CkptTrigger {
    fn default() -> Self {
        CkptTrigger { points_since: 0, last_write: std::time::Instant::now() }
    }
}

impl CkptTrigger {
    /// Account `k` freshly ingested points.
    pub fn note_points(&mut self, k: usize) {
        self.points_since += k;
    }

    /// Should a checkpoint be written now? (Only meaningful when points
    /// have arrived since the last write — an idle stream never
    /// rewrites an identical file.)
    pub fn due(&self, cfg: &CkptConfig) -> bool {
        self.points_since > 0
            && (self.points_since >= cfg.every_points
                || self.last_write.elapsed().as_millis() as u64 >= cfg.every_ms)
    }

    /// Reset after a successful write.
    pub fn note_written(&mut self) {
        self.points_since = 0;
        self.last_write = std::time::Instant::now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gp::msgp::MsgpConfig;

    fn sample_ski(seed: u64, dim: usize, npts: usize) -> IncrementalSki {
        let axes: Vec<GridAxis> =
            (0..dim).map(|a| GridAxis { lo: -2.0 - a as f64, step: 0.5, n: 8 + a }).collect();
        let mut ski = IncrementalSki::new(Grid { axes }, 4, 2, seed);
        let mut rng = Rng::new(seed.wrapping_add(99));
        for i in 0..npts {
            let x: Vec<f64> = (0..dim).map(|_| rng.uniform_in(-1.5, 1.5)).collect();
            ski.ingest(&x, (i as f64 * 0.3).sin() + rng.normal() * 0.1);
        }
        ski
    }

    fn sample_ckpt(dim: usize) -> Checkpoint {
        Checkpoint {
            seq: 17,
            kernel: KernelSpec::Product(ProductKernel::iso(KernelType::SE, dim, 0.3, 0.9)),
            sigma2: 0.05,
            skis: vec![sample_ski(5, dim, 60), sample_ski(6, dim, 20)],
        }
    }

    fn assert_ski_eq(a: &IncrementalSki, b: &IncrementalSki) {
        assert_eq!(a.grid(), b.grid());
        assert_eq!(a.n(), b.n());
        assert_eq!(a.margin_cells(), b.margin_cells());
        assert_eq!(a.weight().to_bits(), b.weight().to_bits());
        assert_eq!(a.sum_y().to_bits(), b.sum_y().to_bits());
        assert_eq!(a.sum_y2().to_bits(), b.sum_y2().to_bits());
        assert_eq!(a.rng_state(), b.rng_state());
        assert_eq!(a.wty(), b.wty());
        assert_eq!(a.counts(), b.counts());
        assert_eq!(a.bands(), b.bands());
        assert_eq!(a.probes(), b.probes());
    }

    /// Property: random states in 1D/2D/3D — including decayed mass and
    /// auto-expanded grids — round-trip bit-exactly through the codec.
    #[test]
    fn round_trip_is_bit_exact_across_dims() {
        for dim in 1..=3usize {
            let mut c = sample_ckpt(dim);
            // Exercise decay (fractional statistics) and expansion
            // (out-of-box ingest) on the first accumulator.
            c.skis[0].decay(0.875);
            let far = vec![9.5; dim];
            assert!(c.skis[0].ingest(&far, 1.25).is_some(), "expected a grid expansion");
            let bytes = c.encode();
            let back = Checkpoint::decode(&bytes).expect("decode");
            assert_eq!(back.seq, c.seq);
            assert_eq!(back.sigma2.to_bits(), c.sigma2.to_bits());
            assert_eq!(back.skis.len(), c.skis.len());
            for (a, b) in c.skis.iter().zip(&back.skis) {
                assert_ski_eq(a, b);
            }
        }
    }

    /// The restored RNG replays the identical probe-noise stream: both
    /// copies ingest the same continuation and stay bit-identical.
    #[test]
    fn restored_rng_replays_the_same_continuation() {
        let c = sample_ckpt(2);
        let mut orig = c.skis[0].clone();
        let mut back = Checkpoint::decode(&c.encode()).expect("decode").skis.remove(0);
        let mut rng = Rng::new(4242);
        for i in 0..40 {
            let x = [rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0)];
            let y = (i as f64 * 0.11).cos();
            orig.ingest(&x, y);
            back.ingest(&x, y);
        }
        assert_ski_eq(&orig, &back);
    }

    /// Iso kernels and every kernel-type tag round-trip.
    #[test]
    fn kernel_specs_round_trip() {
        for ktype in [
            KernelType::SE,
            KernelType::Matern12,
            KernelType::Matern32,
            KernelType::Matern52,
            KernelType::rq(1.5),
        ] {
            let c = Checkpoint {
                seq: 1,
                kernel: KernelSpec::Iso { ktype, log_ell: -0.7, log_sf2: 0.2, dim: 2 },
                sigma2: 0.01,
                skis: vec![sample_ski(3, 2, 10)],
            };
            let back = Checkpoint::decode(&c.encode()).expect("decode");
            match (&c.kernel, &back.kernel) {
                (
                    KernelSpec::Iso { ktype: k1, log_ell: e1, log_sf2: s1, dim: d1 },
                    KernelSpec::Iso { ktype: k2, log_ell: e2, log_sf2: s2, dim: d2 },
                ) => {
                    assert_eq!(k1, k2);
                    assert_eq!(e1.to_bits(), e2.to_bits());
                    assert_eq!(s1.to_bits(), s2.to_bits());
                    assert_eq!(d1, d2);
                }
                _ => panic!("kernel variant changed in round trip"),
            }
        }
    }

    /// Corruption property: flipping any byte, truncating at any prefix,
    /// or bumping the version yields a clean typed error — never a panic
    /// and never a silently decoded state.
    #[test]
    fn corrupted_and_truncated_files_fail_cleanly() {
        let bytes = sample_ckpt(1).encode();
        // Wrong magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(Checkpoint::decode(&bad), Err(CodecError::BadMagic)));
        // Wrong version.
        let mut bad = bytes.clone();
        bad[8] = 0xEE;
        assert!(matches!(Checkpoint::decode(&bad), Err(CodecError::BadVersion(_))));
        // Every truncation length fails (stride keeps the test fast).
        for cut in (0..bytes.len()).step_by(7) {
            assert!(
                Checkpoint::decode(&bytes[..cut]).is_err(),
                "truncation at {cut} must fail"
            );
        }
        // Payload bit flips are caught by the checksum.
        for at in (20..bytes.len() - 8).step_by(13) {
            let mut bad = bytes.clone();
            bad[at] ^= 0x10;
            assert!(
                matches!(Checkpoint::decode(&bad), Err(CodecError::ChecksumMismatch)),
                "flip at {at} must fail the checksum"
            );
        }
        // A corrupted *length* field with a recomputed checksum must be
        // caught structurally, not by allocation blow-up.
        let c = sample_ckpt(1);
        let payload_start = 20;
        let mut raw = c.encode();
        let payload_end = raw.len() - 8;
        // seq is the first payload field; overwrite the wty length region
        // deep in the payload with an absurd value and re-checksum.
        let mid = payload_start + (payload_end - payload_start) / 2;
        raw[mid..mid + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        let sum = fnv1a64(&raw[payload_start..payload_end]);
        let len = raw.len();
        raw[len - 8..].copy_from_slice(&sum.to_le_bytes());
        assert!(Checkpoint::decode(&raw).is_err());
    }

    /// Atomic write + rotation: a failed final rename (mid-rename crash)
    /// leaves the previous checkpoint recoverable via `load_newest`.
    #[test]
    fn write_rotation_and_mid_rename_crash_recovery() {
        let dir = std::env::temp_dir().join(format!("msgp-codec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("temp dir");
        let path = dir.join("ski.ckpt");
        let mut c = sample_ckpt(2);
        c.seq = 1;
        write_atomic(&path, &c).expect("first write");
        let (got, from) = load_newest(&path).expect("recover");
        assert_eq!(got.seq, 1);
        assert_eq!(from, path);
        c.seq = 2;
        write_atomic(&path, &c).expect("second write");
        assert_eq!(load(&path).expect("load").seq, 2);
        assert_eq!(load(&rotated(&path)).expect("rotated").seq, 1, "rotation keeps previous");
        // Crash mid-rename: the current file was already rotated away,
        // so recovery falls back to `path.1` (= seq 2).
        crate::fault::clear_all();
        crate::fault::configure("ckpt.rename=error").expect("arm");
        c.seq = 3;
        let err = write_atomic(&path, &c).expect_err("injected rename crash");
        assert!(matches!(err, CodecError::Injected("ckpt.rename")), "{err}");
        crate::fault::clear_all();
        let (got, from) = load_newest(&path).expect("fallback recovery");
        assert_eq!(got.seq, 2, "previous good checkpoint must survive");
        assert_eq!(from, rotated(&path));
        // A garbage primary file also falls back.
        std::fs::write(&path, b"MSGPCKPTgarbage").expect("write garbage");
        let (got, _) = load_newest(&path).expect("skip garbage");
        assert_eq!(got.seq, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The trigger fires on either threshold and only after points.
    #[test]
    fn trigger_cadence() {
        let cfg = CkptConfig {
            dir: Some(PathBuf::from("/tmp")),
            every_points: 10,
            every_ms: 60_000,
        };
        let mut t = CkptTrigger::default();
        assert!(!t.due(&cfg), "no points, not due");
        t.note_points(9);
        assert!(!t.due(&cfg));
        t.note_points(1);
        assert!(t.due(&cfg), "point threshold crossed");
        t.note_written();
        assert!(!t.due(&cfg));
        let cfg_ms = CkptConfig { every_ms: 0, ..cfg };
        t.note_points(1);
        assert!(t.due(&cfg_ms), "elapsed threshold crossed");
    }

    /// MsgpConfig's probe count matches what the serving stack
    /// checkpoints (sanity coupling for the restore path).
    #[test]
    fn default_probe_count_is_checkpointable() {
        let cfg = MsgpConfig::default();
        assert!(cfg.n_var_samples <= 4096, "codec probe-count bound too tight");
    }

    /// Every frame kind round-trips bit-exactly through the wire codec.
    #[test]
    fn frames_round_trip() {
        let ski = sample_ski(11, 2, 30);
        let frames = vec![
            Frame::Hello { node: 3 },
            Frame::Heartbeat { node: 0 },
            Frame::SyncRequest { node: 2 },
            Frame::SyncDone { node: 1, shards: 7 },
            Frame::Delta { origin: 1, shard: 5, epoch: 42, ski: Box::new(ski.clone()) },
            Frame::Full { origin: 0, shard: 2, epoch: u64::MAX, ski: Box::new(ski.clone()) },
        ];
        for f in &frames {
            let back = Frame::decode(&f.encode()).expect("decode");
            assert_eq!(back.kind_name(), f.kind_name());
            match (f, &back) {
                (Frame::Hello { node: a }, Frame::Hello { node: b })
                | (Frame::Heartbeat { node: a }, Frame::Heartbeat { node: b })
                | (Frame::SyncRequest { node: a }, Frame::SyncRequest { node: b }) => {
                    assert_eq!(a, b)
                }
                (
                    Frame::SyncDone { node: a, shards: sa },
                    Frame::SyncDone { node: b, shards: sb },
                ) => {
                    assert_eq!((a, sa), (b, sb))
                }
                (
                    Frame::Delta { origin: o1, shard: s1, epoch: e1, ski: k1 },
                    Frame::Delta { origin: o2, shard: s2, epoch: e2, ski: k2 },
                )
                | (
                    Frame::Full { origin: o1, shard: s1, epoch: e1, ski: k1 },
                    Frame::Full { origin: o2, shard: s2, epoch: e2, ski: k2 },
                ) => {
                    assert_eq!((o1, s1, e1), (o2, s2, e2));
                    assert_ski_eq(k1, k2);
                }
                _ => panic!("frame variant changed in round trip"),
            }
        }
    }

    /// Frame corruption battery: flipped bytes, truncation at every
    /// prefix, wrong magic/version/kind, and implausible lengths all
    /// fail with a typed error — never a panic, never a wrong decode.
    #[test]
    fn corrupted_frames_fail_cleanly() {
        let good =
            Frame::Delta { origin: 0, shard: 1, epoch: 9, ski: Box::new(sample_ski(7, 1, 25)) }
                .encode();
        assert!(matches!(Frame::decode(b"NOTAFRAM rest"), Err(CodecError::BadMagic)));
        let mut v = good.clone();
        v[8] ^= 0xFF; // version field
        assert!(matches!(Frame::decode(&v), Err(CodecError::BadVersion(_))));
        let mut k = good.clone();
        k[12] = 200; // frame kind
        assert!(matches!(Frame::decode(&k), Err(CodecError::Malformed(_))));
        let mut l = good.clone();
        l[13..21].copy_from_slice(&u64::MAX.to_le_bytes()); // payload length
        assert!(matches!(Frame::decode(&l), Err(CodecError::Malformed(_))));
        for cut in 0..good.len() {
            assert!(Frame::decode(&good[..cut]).is_err(), "truncation at {cut} must fail");
        }
        for i in 21..good.len() {
            let mut bad = good.clone();
            bad[i] ^= 0x10;
            assert!(Frame::decode(&bad).is_err(), "payload flip at byte {i} must fail");
        }
    }

    /// Stream framing: several frames written back-to-back read out in
    /// order, then a clean EOF yields `None`; a mid-frame EOF errors.
    #[test]
    fn read_frame_handles_streams_and_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Hello { node: 1 }).expect("write");
        write_frame(&mut buf, &Frame::Heartbeat { node: 1 }).expect("write");
        write_frame(
            &mut buf,
            &Frame::Delta { origin: 1, shard: 0, epoch: 3, ski: Box::new(sample_ski(9, 2, 12)) },
        )
        .expect("write");
        let full_len = buf.len();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut r), Ok(Some(Frame::Hello { node: 1 }))));
        assert!(matches!(read_frame(&mut r), Ok(Some(Frame::Heartbeat { node: 1 }))));
        assert!(matches!(read_frame(&mut r), Ok(Some(Frame::Delta { epoch: 3, .. }))));
        assert!(matches!(read_frame(&mut r), Ok(None)), "clean EOF is None");
        // Truncate mid-frame: the reader must error, not hang or None.
        let trunc = r.into_inner()[..full_len - 5].to_vec();
        let mut r = std::io::Cursor::new(trunc);
        let _ = read_frame(&mut r).expect("first frame intact");
        let _ = read_frame(&mut r).expect("second frame intact");
        assert!(read_frame(&mut r).is_err(), "mid-frame EOF must error");
    }

    /// A delta cut from two accumulator states re-applies onto a copy of
    /// the older state and lands bit-close to the newer one (the
    /// replication invariant: ship diffs, add them, converge).
    #[test]
    fn delta_cut_and_apply_converges() {
        let mut newer = sample_ski(21, 2, 40);
        let older = newer.clone();
        let mut rng = Rng::new(77);
        for i in 0..25 {
            let x = [rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0)];
            newer.ingest(&x, (i as f64 * 0.2).sin());
        }
        let delta = crate::cluster::diff_ski(&newer, &older).expect("same grid, diffable");
        // Round-trip the delta through the wire format first.
        let frame = Frame::Delta { origin: 0, shard: 0, epoch: 1, ski: Box::new(delta) };
        let Frame::Delta { ski: delta, .. } = Frame::decode(&frame.encode()).expect("decode")
        else {
            panic!("kind changed");
        };
        let mut replica = older.clone();
        replica.accumulate_shifted(&delta);
        assert_eq!(replica.n(), newer.n());
        for (a, b) in replica.wty().iter().zip(newer.wty()) {
            assert!((a - b).abs() < 1e-12, "wty drift {a} vs {b}");
        }
        for (ba, bb) in replica.bands().iter().zip(newer.bands()) {
            for (a, b) in ba.iter().zip(bb) {
                assert!((a - b).abs() < 1e-12, "band drift {a} vs {b}");
            }
        }
    }
}
