//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them on the CPU PJRT client from the Rust hot path.
//!
//! Python never runs at serving time: the HLO text is parsed and compiled
//! by XLA inside this process (`HloModuleProto::from_text_file` →
//! `client.compile` → `execute`), one executable per (graph, batch
//! bucket) pair as listed in `artifacts/manifest.json`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Metadata for one compiled artifact (a row of `manifest.json`).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Artifact name, e.g. `predict_meanvar_1d_b32`.
    pub name: String,
    /// Relative file name.
    pub file: String,
    /// Graph kind: `predict_meanvar`, `predict_mean`, `whittle_logdet`,
    /// `kski_matvec`.
    pub kind: String,
    /// Input dimensionality (1 or 2).
    pub dim: usize,
    /// Batch bucket this executable was compiled for.
    pub batch: usize,
    /// Grid size(s).
    pub m: Vec<usize>,
}

/// A loaded artifact: metadata + compiled PJRT executable.
pub struct LoadedArtifact {
    /// Manifest metadata.
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: a CPU client plus all compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: HashMap<String, LoadedArtifact>,
    dir: PathBuf,
}

impl Runtime {
    /// Load every artifact listed in `<dir>/manifest.json` and compile it
    /// on a fresh CPU PJRT client.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .map_err(|e| anyhow::anyhow!("reading {manifest_path:?}: {e} (run `make artifacts`)"))?;
        let manifest = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        let mut artifacts = HashMap::new();
        for entry in manifest
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts[]"))?
        {
            let meta = parse_meta(entry)?;
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("HLO parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("XLA compile {}: {e:?}", meta.name))?;
            artifacts.insert(meta.name.clone(), LoadedArtifact { meta, exe });
        }
        Ok(Runtime { client, artifacts, dir })
    }

    /// Artifact directory this runtime was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of loaded artifacts.
    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    /// True when no artifacts are loaded.
    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Option<&LoadedArtifact> {
        self.artifacts.get(name)
    }

    /// All artifacts of a given kind and input dimension, sorted by batch.
    pub fn by_kind(&self, kind: &str, dim: usize) -> Vec<&LoadedArtifact> {
        let mut v: Vec<&LoadedArtifact> = self
            .artifacts
            .values()
            .filter(|a| a.meta.kind == kind && a.meta.dim == dim)
            .collect();
        v.sort_by_key(|a| a.meta.batch);
        v
    }

    /// Execute a fused mean+variance prediction artifact.
    ///
    /// `points` are grid-unit coordinates, length `batch * dim` (already
    /// padded to the artifact's bucket); `u_mean`/`nu_u` are the grid
    /// precomputes (f32, length `prod(m)`).
    pub fn predict_meanvar(
        &self,
        name: &str,
        points: &[f32],
        u_mean: &[f32],
        nu_u: &[f32],
        kss: f32,
        sigma2: f32,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>)> {
        let art = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not loaded"))?;
        let b = art.meta.batch;
        anyhow::ensure!(points.len() == b * art.meta.dim, "points len vs bucket");
        let mtot: usize = art.meta.m.iter().product();
        anyhow::ensure!(u_mean.len() == mtot && nu_u.len() == mtot, "grid vec len");
        let points_lit = if art.meta.dim == 1 {
            xla::Literal::vec1(points)
        } else {
            xla::Literal::vec1(points)
                .reshape(&[b as i64, art.meta.dim as i64])
                .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?
        };
        let grid_shape: Vec<i64> = art.meta.m.iter().map(|&v| v as i64).collect();
        let um = xla::Literal::vec1(u_mean)
            .reshape(&grid_shape)
            .map_err(|e| anyhow::anyhow!("reshape u_mean: {e:?}"))?;
        let nu = xla::Literal::vec1(nu_u)
            .reshape(&grid_shape)
            .map_err(|e| anyhow::anyhow!("reshape nu_u: {e:?}"))?;
        let kss_lit = xla::Literal::from(kss);
        let s2_lit = xla::Literal::from(sigma2);
        let result = art
            .exe
            .execute::<xla::Literal>(&[points_lit, um, nu, kss_lit, s2_lit])
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let (mean_l, var_l) = result
            .to_tuple2()
            .map_err(|e| anyhow::anyhow!("tuple2: {e:?}"))?;
        let mean = mean_l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let var = var_l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        Ok((mean, var))
    }

    /// Execute a mean-only prediction artifact.
    pub fn predict_mean(
        &self,
        name: &str,
        points: &[f32],
        u_mean: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        let art = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not loaded"))?;
        anyhow::ensure!(points.len() == art.meta.batch * art.meta.dim, "points len");
        let points_lit = xla::Literal::vec1(points);
        let um = xla::Literal::vec1(u_mean);
        let result = art
            .exe
            .execute::<xla::Literal>(&[points_lit, um])
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let mean_l = result.to_tuple1().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        mean_l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
    }

    /// Execute the spectral log-det artifact.
    pub fn whittle_logdet(&self, name: &str, col: &[f32], sigma2: f32) -> anyhow::Result<f32> {
        let art = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not loaded"))?;
        let result = art
            .exe
            .execute::<xla::Literal>(&[xla::Literal::vec1(col), xla::Literal::from(sigma2)])
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let l = result.to_tuple1().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        l.get_first_element::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
    }

    /// Execute the SKI-MVM demo artifact.
    pub fn kski_matvec(
        &self,
        name: &str,
        v: &[f32],
        points: &[f32],
        embed_col: &[f32],
        sigma2: f32,
    ) -> anyhow::Result<Vec<f32>> {
        let art = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not loaded"))?;
        let result = art
            .exe
            .execute::<xla::Literal>(&[
                xla::Literal::vec1(v),
                xla::Literal::vec1(points),
                xla::Literal::vec1(embed_col),
                xla::Literal::from(sigma2),
            ])
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let l = result.to_tuple1().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))
    }
}

fn parse_meta(entry: &Json) -> anyhow::Result<ArtifactMeta> {
    let get_str = |k: &str| {
        entry
            .get(k)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("manifest entry missing {k}"))
    };
    let name = get_str("name")?;
    let file = get_str("file")?;
    let kind = get_str("kind")?;
    let dim = entry
        .get("dim")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow::anyhow!("missing dim"))?;
    let batch = entry
        .get("batch")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow::anyhow!("missing batch"))?;
    let m = match entry.get("m") {
        Some(Json::Num(x)) => vec![*x as usize],
        Some(Json::Arr(v)) => v.iter().filter_map(|x| x.as_usize()).collect(),
        _ => anyhow::bail!("missing m"),
    };
    Ok(ArtifactMeta { name, file, kind, dim, batch, m })
}
