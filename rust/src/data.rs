//! Synthetic workload generators matching the paper's experiments
//! (section 6): the 1-D stress-test function, random projections for the
//! section-6.2 consistency study, and GP samples on low-dimensional
//! subspaces.

use crate::linalg::cholesky::Chol;
use crate::linalg::Mat;
use crate::kernels::ProductKernel;
use crate::util::Rng;

/// The paper's 1-D stress-test target: `f(x) = sin(x) exp(-x^2 / (2*5^2))`.
pub fn stress_fn(x: f64) -> f64 {
    x.sin() * (-x * x / 50.0).exp()
}

/// A regression dataset: row-major inputs and targets.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Inputs, row-major `n x d`.
    pub x: Vec<f64>,
    /// Input dimensionality.
    pub d: usize,
    /// Targets, length `n`.
    pub y: Vec<f64>,
}

impl Dataset {
    /// Number of points.
    pub fn n(&self) -> usize {
        self.y.len()
    }

    /// Input row `i`.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.x[i * self.d..(i + 1) * self.d]
    }
}

/// Section 6.1 workload: `n` inputs uniform in `[-10, 10]` (no grid
/// structure), targets `stress_fn(x) + N(0, noise^2)`.
pub fn gen_stress_1d(n: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let xi = rng.uniform_in(-10.0, 10.0);
        let eps = rng.normal();
        x.push(xi);
        y.push(stress_fn(xi) + noise * eps);
    }
    Dataset { x, d: 1, y }
}

/// 2-D variant for the BTTB experiments: inputs uniform in a box, targets
/// from a smooth non-separable function plus noise.
pub fn gen_stress_2d(n: usize, noise: f64, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut x = Vec::with_capacity(2 * n);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let a = rng.uniform_in(-5.0, 5.0);
        let b = rng.uniform_in(-5.0, 5.0);
        let r = (a * a + b * b).sqrt();
        let eps = rng.normal();
        x.push(a);
        x.push(b);
        y.push(r.cos() * (-r / 6.0).exp() + noise * eps);
    }
    Dataset { x, d: 2, y }
}

/// Standard-normal matrix (row-major `rows x cols`).
pub fn randn_mat(rows: usize, cols: usize, rng: &mut Rng) -> Mat {
    Mat::from_vec(rows, cols, rng.normal_vec(rows * cols))
}

/// Section 6.2 workload: sample a `d x bigd` projection `P`, draw `n`
/// inputs `x ~ N(0, I_bigd)`, project to `x' = P x`, and sample targets
/// from a GP with kernel `kern` on the projected inputs (exact sampling
/// via dense Cholesky — used at n <= a few thousand as in the paper).
pub struct ProjectionData {
    /// Ground-truth projection (`d x bigd`).
    pub p_true: Mat,
    /// High-dimensional inputs (`n x bigd`).
    pub data: Dataset,
    /// Low-dimensional projected inputs (`n x d`).
    pub x_low: Vec<f64>,
}

/// Generate the projection-consistency dataset of section 6.2.
pub fn gen_projection_data(
    n: usize,
    bigd: usize,
    d: usize,
    kern: &ProductKernel,
    noise: f64,
    seed: u64,
) -> ProjectionData {
    let mut rng = Rng::new(seed);
    let p_true = randn_mat(d, bigd, &mut rng);
    let x = rng.normal_vec(n * bigd);
    // Project.
    let mut x_low = vec![0.0; n * d];
    for i in 0..n {
        for r in 0..d {
            let mut s = 0.0;
            for c in 0..bigd {
                s += p_true[(r, c)] * x[i * bigd + c];
            }
            x_low[i * d + r] = s;
        }
    }
    // Exact GP sample on the projected inputs.
    let mut kmat = Mat::from_fn(n, n, |i, j| {
        kern.eval(&x_low[i * d..(i + 1) * d], &x_low[j * d..(j + 1) * d])
    });
    for i in 0..n {
        kmat[(i, i)] += 1e-8;
    }
    let ch = Chol::new(&kmat).expect("kernel matrix PSD");
    let z = rng.normal_vec(n);
    let f = ch.l.matvec(&z);
    let y: Vec<f64> = f.iter().map(|&fi| fi + noise * rng.normal()).collect();
    ProjectionData { p_true, data: Dataset { x, d: bigd, y }, x_low }
}

/// Standardized mean absolute error: `MAE(pred, y) / MAE(mean(y), y)` —
/// the paper's accuracy metric (section 6.1).
pub fn smae(pred: &[f64], y: &[f64]) -> f64 {
    assert_eq!(pred.len(), y.len());
    let n = y.len() as f64;
    let mean = y.iter().sum::<f64>() / n;
    let mae: f64 = pred.iter().zip(y).map(|(p, t)| (p - t).abs()).sum::<f64>() / n;
    let base: f64 = y.iter().map(|t| (t - mean).abs()).sum::<f64>() / n;
    mae / base
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::KernelType;

    #[test]
    fn stress_data_in_range() {
        let ds = gen_stress_1d(500, 0.1, 42);
        assert_eq!(ds.n(), 500);
        for i in 0..ds.n() {
            assert!(ds.row(i)[0] >= -10.0 && ds.row(i)[0] <= 10.0);
            assert!(ds.y[i].abs() < 2.0);
        }
    }

    #[test]
    fn projection_data_shapes() {
        let kern = ProductKernel::iso(KernelType::SE, 2, 1.0, 1.0);
        let pd = gen_projection_data(50, 7, 2, &kern, 0.05, 1);
        assert_eq!(pd.p_true.rows, 2);
        assert_eq!(pd.p_true.cols, 7);
        assert_eq!(pd.data.n(), 50);
        assert_eq!(pd.data.d, 7);
        assert_eq!(pd.x_low.len(), 100);
    }

    #[test]
    fn smae_of_perfect_prediction_is_zero() {
        let y = vec![1.0, 2.0, 3.0];
        assert!(smae(&y, &y) < 1e-15);
        // Predicting the mean gives SMAE 1.
        let mean = vec![2.0, 2.0, 2.0];
        assert!((smae(&mean, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = gen_stress_1d(10, 0.1, 7);
        let b = gen_stress_1d(10, 0.1, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
