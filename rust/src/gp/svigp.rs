//! The Big Data GP (Hensman, Fusi & Lawrence, 2013) — stochastic
//! variational inference over inducing points; the `BDGP` baseline of
//! Figures 2–3.
//!
//! The variational posterior `q(u) = N(mu, L L^T)` is optimized by Adam
//! on the minibatch ELBO:
//!
//! `ELBO = sum_i E_q[log N(y_i; k_i^T K_uu^{-1} u, sigma^2)] - KL(q || p)`
//!
//! Each step costs O(b m^2 + m^3) — the O(m^3) per-step scaling the paper
//! contrasts with MSGP's near-linear-in-m behaviour.

use crate::data::Dataset;
use crate::kernels::ProductKernel;
use crate::linalg::cholesky::Chol;
use crate::linalg::Mat;
use crate::opt::Adam;
use crate::util::Rng;

/// Configuration for SVI training.
#[derive(Clone, Debug)]
pub struct SvigpConfig {
    /// Minibatch size (the paper's stress test uses 300).
    pub batch: usize,
    /// Adam step size (the paper uses 0.01).
    pub lr: f64,
    /// Maximum optimization steps (the paper caps at 5000).
    pub max_steps: usize,
    /// Stop when the smoothed ELBO has not improved by `patience_delta`
    /// within `patience_steps` (the paper: 0.1 within 50 steps).
    pub patience_steps: usize,
    /// See `patience_steps`.
    pub patience_delta: f64,
    /// RNG seed.
    pub seed: u64,
    /// Also adapt hyperparameters (lengthscale etc.) jointly.
    pub learn_hypers: bool,
}

impl Default for SvigpConfig {
    fn default() -> Self {
        SvigpConfig {
            batch: 300,
            lr: 0.01,
            max_steps: 5000,
            patience_steps: 50,
            patience_delta: 0.1,
            seed: 0,
            learn_hypers: true,
        }
    }
}

/// A fitted Big-Data GP.
pub struct Svigp {
    /// Kernel.
    pub kernel: ProductKernel,
    /// Noise variance.
    pub sigma2: f64,
    /// Inducing inputs, row-major `m x d`.
    pub u: Vec<f64>,
    /// Variational mean (m).
    pub mu: Vec<f64>,
    /// Variational Cholesky factor (m x m, lower).
    pub l: Mat,
    /// Steps actually taken.
    pub steps_taken: usize,
    data_d: usize,
    chol_kuu: Chol,
}

impl Svigp {
    /// Train with inducing points on a regular 1-D grid.
    pub fn train_grid_1d(
        kernel: ProductKernel,
        sigma2: f64,
        data: &Dataset,
        m: usize,
        lo: f64,
        hi: f64,
        cfg: SvigpConfig,
    ) -> anyhow::Result<Self> {
        let u: Vec<f64> =
            (0..m).map(|i| lo + (hi - lo) * i as f64 / (m - 1) as f64).collect();
        Self::train(kernel, sigma2, data, u, cfg)
    }

    /// Train with explicit inducing inputs.
    pub fn train(
        mut kernel: ProductKernel,
        mut sigma2: f64,
        data: &Dataset,
        u: Vec<f64>,
        cfg: SvigpConfig,
    ) -> anyhow::Result<Self> {
        let d = data.d;
        let n = data.n();
        let m = u.len() / d;
        let mut rng = Rng::new(cfg.seed);
        // Variational params: mu (m), diag-ish L (m x m lower, init 0.1 I).
        let mut mu = vec![0.0f64; m];
        let mut l = Mat::zeros(m, m);
        for i in 0..m {
            l[(i, i)] = 0.1;
        }
        let nhyp = if cfg.learn_hypers { kernel.n_params() + 1 } else { 0 };
        let nvar = m + m * (m + 1) / 2;
        let mut opt = Adam::new(nvar + nhyp, cfg.lr);
        let mut best = f64::NEG_INFINITY;
        let mut since_best = 0usize;
        let mut steps = 0usize;
        let mut chol_kuu = Self::factor_kuu(&kernel, &u, d, m)?;
        for step in 0..cfg.max_steps {
            steps = step + 1;
            // Minibatch indices.
            let b = cfg.batch.min(n);
            let idx: Vec<usize> = (0..b).map(|_| rng.below(n)).collect();
            // ELBO gradient by finite differences over a *fixed* batch
            // would be too slow; use analytic gradients for mu and the
            // diagonal of L, plus (optionally) FD for the few hypers.
            let (elbo, gmu, gl) =
                Self::elbo_and_grads(&kernel, sigma2, data, &u, &chol_kuu, &mu, &l, &idx, n);
            // Pack gradients.
            let mut theta = Vec::with_capacity(nvar + nhyp);
            theta.extend_from_slice(&mu);
            for r in 0..m {
                for c in 0..=r {
                    theta.push(l[(r, c)]);
                }
            }
            let mut grad = Vec::with_capacity(nvar + nhyp);
            grad.extend_from_slice(&gmu);
            for r in 0..m {
                for c in 0..=r {
                    grad.push(gl[(r, c)]);
                }
            }
            if cfg.learn_hypers {
                let mut hp = kernel.params();
                hp.push(sigma2.ln());
                theta.extend_from_slice(&hp);
                // Cheap FD on the batch ELBO for the hypers (3 params).
                let ghyp = crate::opt::fd_gradient(
                    |p| {
                        let mut k2 = kernel.clone();
                        let nk = k2.n_params();
                        k2.set_params(&p[..nk]);
                        let s2 = p[nk].exp();
                        match Self::factor_kuu(&k2, &u, d, m) {
                            Ok(ch) => {
                                Self::elbo_and_grads(&k2, s2, data, &u, &ch, &mu, &l, &idx, n).0
                            }
                            Err(_) => f64::NEG_INFINITY,
                        }
                    },
                    &hp,
                    1e-4,
                );
                grad.extend_from_slice(&ghyp);
            }
            opt.step(&mut theta, &grad);
            // Unpack.
            mu.copy_from_slice(&theta[..m]);
            let mut k = m;
            for r in 0..m {
                for c in 0..=r {
                    l[(r, c)] = theta[k];
                    k += 1;
                }
            }
            for i in 0..m {
                if l[(i, i)].abs() < 1e-6 {
                    l[(i, i)] = 1e-6;
                }
            }
            if cfg.learn_hypers {
                let nk = kernel.n_params();
                kernel.set_params(&theta[nvar..nvar + nk]);
                sigma2 = theta[nvar + nk].exp().max(1e-8);
                chol_kuu = Self::factor_kuu(&kernel, &u, d, m)?;
            }
            // Early stopping on the (noisy) batch ELBO.
            if elbo > best + cfg.patience_delta {
                best = elbo;
                since_best = 0;
            } else {
                since_best += 1;
                if since_best >= cfg.patience_steps {
                    break;
                }
            }
        }
        Ok(Svigp { kernel, sigma2, u, mu, l, steps_taken: steps, data_d: d, chol_kuu })
    }

    fn factor_kuu(kernel: &ProductKernel, u: &[f64], d: usize, m: usize) -> anyhow::Result<Chol> {
        let mut kuu = Mat::from_fn(m, m, |i, j| {
            kernel.eval(&u[i * d..(i + 1) * d], &u[j * d..(j + 1) * d])
        });
        let jit = 1e-6 * kernel.sf2();
        for i in 0..m {
            kuu[(i, i)] += jit;
        }
        Chol::new(&kuu).ok_or_else(|| anyhow::anyhow!("K_UU not PD"))
    }

    /// Minibatch ELBO and analytic gradients for `mu` and `L`.
    #[allow(clippy::too_many_arguments)]
    fn elbo_and_grads(
        kernel: &ProductKernel,
        sigma2: f64,
        data: &Dataset,
        u: &[f64],
        chol_kuu: &Chol,
        mu: &[f64],
        l: &Mat,
        idx: &[usize],
        n: usize,
    ) -> (f64, Vec<f64>, Mat) {
        let d = data.d;
        let m = mu.len();
        let b = idx.len();
        let scale = n as f64 / b as f64;
        let kss = kernel.sf2();
        let mut elbo = 0.0;
        let mut gmu = vec![0.0; m];
        let mut gl = Mat::zeros(m, m);
        let mut kxs = vec![0.0; m];
        for &i in idx {
            let x = data.row(i);
            for j in 0..m {
                kxs[j] = kernel.eval(x, &u[j * d..(j + 1) * d]);
            }
            // a_i = K_UU^{-1} k_i
            let a = chol_kuu.solve(&kxs);
            let mean: f64 = a.iter().zip(mu).map(|(p, q)| p * q).sum();
            // var terms: ktilde = k** - k^T a ; s = a^T L L^T a
            let ktilde = (kss - kxs.iter().zip(&a).map(|(p, q)| p * q).sum::<f64>()).max(0.0);
            let lta = l.tmatvec(&a);
            let s: f64 = lta.iter().map(|v| v * v).sum();
            let resid = data.y[i] - mean;
            elbo += -0.5 * (2.0 * std::f64::consts::PI * sigma2).ln()
                - 0.5 * resid * resid / sigma2
                - 0.5 * (ktilde + s) / sigma2;
            // grads
            for j in 0..m {
                gmu[j] += resid / sigma2 * a[j];
            }
            // d(-1/2 a^T L L^T a / s2)/dL = -(a a^T L)/s2
            let ala = l.tmatvec(&a); // L^T a, length m
            for r in 0..m {
                let ar = a[r];
                if ar == 0.0 {
                    continue;
                }
                for c in 0..=r {
                    gl[(r, c)] -= ar * ala[c] / sigma2;
                }
            }
        }
        elbo *= scale;
        for g in gmu.iter_mut() {
            *g *= scale;
        }
        gl.scale(scale);
        // KL(q || p) with p = N(0, K_UU):
        // 0.5 [ tr(K^{-1} S) + mu^T K^{-1} mu - m + log|K| - log|S| ]
        let kinv_mu = chol_kuu.solve(mu);
        let quad: f64 = mu.iter().zip(&kinv_mu).map(|(p, q)| p * q).sum();
        // tr(K^{-1} L L^T) = sum_c ||chol_solve column paths||; compute via
        // solving K Z = L and tr(L^T Z).
        let z = chol_kuu.solve_mat(l);
        let mut tr = 0.0;
        for r in 0..m {
            for c in 0..m {
                tr += l[(r, c)] * z[(r, c)];
            }
        }
        let logdet_s: f64 = (0..m).map(|i| (l[(i, i)].abs().max(1e-12)).ln() * 2.0).sum();
        let kl = 0.5 * (tr + quad - m as f64 + chol_kuu.logdet() - logdet_s);
        elbo -= kl;
        // KL gradients.
        // d/dmu = -K^{-1} mu ; d/dL = -(K^{-1} L - L^{-T}) (lower part)
        for j in 0..m {
            gmu[j] -= kinv_mu[j];
        }
        for r in 0..m {
            for c in 0..=r {
                gl[(r, c)] -= z[(r, c)];
            }
        }
        for i in 0..m {
            gl[(i, i)] += 1.0 / l[(i, i)].max(1e-12).max(-1e300);
        }
        (elbo, gmu, gl)
    }

    /// Predictive mean: O(m) per point (after an O(m^2) solve per point
    /// for the interpolation vector).
    pub fn predict_mean(&self, xs: &[f64]) -> Vec<f64> {
        let d = self.data_d;
        let m = self.mu.len();
        let ns = xs.len() / d;
        let mut out = vec![0.0; ns];
        let mut kxs = vec![0.0; m];
        for (s, o) in out.iter_mut().enumerate() {
            let x = &xs[s * d..(s + 1) * d];
            for j in 0..m {
                kxs[j] = self.kernel.eval(x, &self.u[j * d..(j + 1) * d]);
            }
            let a = self.chol_kuu.solve(&kxs);
            *o = a.iter().zip(&self.mu).map(|(p, q)| p * q).sum();
        }
        out
    }

    /// Latent predictive variance.
    pub fn predict_var(&self, xs: &[f64]) -> Vec<f64> {
        let d = self.data_d;
        let m = self.mu.len();
        let ns = xs.len() / d;
        let kss = self.kernel.sf2();
        let mut out = vec![0.0; ns];
        let mut kxs = vec![0.0; m];
        for (s, o) in out.iter_mut().enumerate() {
            let x = &xs[s * d..(s + 1) * d];
            for j in 0..m {
                kxs[j] = self.kernel.eval(x, &self.u[j * d..(j + 1) * d]);
            }
            let a = self.chol_kuu.solve(&kxs);
            let ktilde = kss - kxs.iter().zip(&a).map(|(p, q)| p * q).sum::<f64>();
            let lta = self.l.tmatvec(&a);
            let sv: f64 = lta.iter().map(|v| v * v).sum();
            *o = (ktilde + sv).max(0.0);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_stress_1d, smae};
    use crate::kernels::KernelType;

    #[test]
    fn svi_learns_the_stress_function() {
        let data = gen_stress_1d(600, 0.05, 10);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
        let cfg = SvigpConfig {
            batch: 128,
            lr: 0.02,
            max_steps: 600,
            learn_hypers: false,
            ..Default::default()
        };
        let model =
            Svigp::train_grid_1d(kernel, 0.01, &data, 40, -11.0, 11.0, cfg).unwrap();
        let test = gen_stress_1d(200, 0.0, 123);
        let pred = model.predict_mean(&test.x);
        let err = smae(&pred, &test.y);
        assert!(err < 0.35, "SMAE {err}");
    }

    #[test]
    fn elbo_increases_during_training() {
        let data = gen_stress_1d(300, 0.05, 20);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
        // Evaluate the full-data ELBO before and after a few steps.
        let u: Vec<f64> = (0..20).map(|i| -11.0 + 22.0 * i as f64 / 19.0).collect();
        let chol = Svigp::factor_kuu(&kernel, &u, 1, 20).unwrap();
        let idx: Vec<usize> = (0..data.n()).collect();
        let mu0 = vec![0.0; 20];
        let mut l0 = Mat::zeros(20, 20);
        for i in 0..20 {
            l0[(i, i)] = 0.1;
        }
        let (e0, _, _) =
            Svigp::elbo_and_grads(&kernel, 0.01, &data, &u, &chol, &mu0, &l0, &idx, data.n());
        let cfg = SvigpConfig {
            batch: 100,
            lr: 0.05,
            max_steps: 200,
            learn_hypers: false,
            ..Default::default()
        };
        let model = Svigp::train(kernel.clone(), 0.01, &data, u.clone(), cfg).unwrap();
        let (e1, _, _) = Svigp::elbo_and_grads(
            &kernel,
            0.01,
            &data,
            &u,
            &chol,
            &model.mu,
            &model.l,
            &idx,
            data.n(),
        );
        assert!(e1 > e0, "ELBO did not improve: {e0} -> {e1}");
    }

    #[test]
    fn variance_positive_and_bounded() {
        let data = gen_stress_1d(200, 0.05, 30);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
        let cfg = SvigpConfig {
            batch: 64,
            lr: 0.02,
            max_steps: 150,
            learn_hypers: false,
            ..Default::default()
        };
        let model = Svigp::train_grid_1d(kernel, 0.01, &data, 25, -11.0, 11.0, cfg).unwrap();
        for v in model.predict_var(&data.x) {
            assert!(v >= 0.0 && v < 3.0, "v={v}");
        }
    }
}
