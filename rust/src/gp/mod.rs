//! Gaussian-process models: the MSGP contribution (section 5) and the
//! baselines it is compared against in section 6 (exact GP, FITC, SSGP,
//! and the Big-Data GP / SVI).
pub mod exact;
pub mod msgp;
pub mod fitc;
pub mod ssgp;
pub mod svigp;
