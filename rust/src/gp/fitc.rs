//! FITC (Snelson & Ghahramani, 2006) — the classical inducing-point
//! baseline of Figures 2–3. O(n m^2) training, O(m)/O(m^2) predictions.
//!
//! The marginal likelihood and predictions use the standard
//! Quiñonero-Candela & Rasmussen (2005) formulation:
//! `Q_ab = K_aU K_UU^{-1} K_Ub`, train covariance
//! `Q_XX + diag(K_XX - Q_XX) + sigma^2 I`.

use crate::data::Dataset;
use crate::kernels::ProductKernel;
use crate::linalg::cholesky::Chol;
use crate::linalg::Mat;

/// A fitted FITC model.
pub struct Fitc {
    /// Kernel.
    pub kernel: ProductKernel,
    /// Noise variance.
    pub sigma2: f64,
    /// Inducing inputs, row-major `m x d`.
    pub u: Vec<f64>,
    /// Training data.
    pub data: Dataset,
    /// `Lambda^{-1}` diagonal (per-point).
    lam_inv: Vec<f64>,
    /// Cholesky of `A = K_UU + K_UX Lambda^{-1} K_XU`.
    chol_a: Chol,
    /// Cholesky of `K_UU` (jittered).
    chol_kuu: Chol,
    /// `A^{-1} K_UX Lambda^{-1} y` — the m-dimensional predictive weights.
    beta: Vec<f64>,
    /// Cached log marginal likelihood.
    lml: f64,
}

impl Fitc {
    /// Fit with given inducing inputs.
    pub fn fit(
        kernel: ProductKernel,
        sigma2: f64,
        data: Dataset,
        u: Vec<f64>,
    ) -> anyhow::Result<Self> {
        let d = data.d;
        let n = data.n();
        let m = u.len() / d;
        anyhow::ensure!(m >= 1 && u.len() % d == 0, "bad inducing inputs");
        let jitter = 1e-8 * kernel.sf2();
        let mut kuu = Mat::from_fn(m, m, |i, j| {
            kernel.eval(&u[i * d..(i + 1) * d], &u[j * d..(j + 1) * d])
        });
        for i in 0..m {
            kuu[(i, i)] += jitter;
        }
        let chol_kuu =
            Chol::new(&kuu).ok_or_else(|| anyhow::anyhow!("K_UU not PD"))?;
        // K_XU (n x m).
        let kxu = Mat::from_fn(n, m, |i, j| {
            kernel.eval(data.row(i), &u[j * d..(j + 1) * d])
        });
        // q_ii = k_iU K_UU^{-1} k_Ui ; Lambda_ii = k_ii - q_ii + sigma2.
        let mut lam_inv = vec![0.0; n];
        let kss = kernel.sf2();
        for i in 0..n {
            let v = chol_kuu.forward(kxu.row(i));
            let qii: f64 = v.iter().map(|x| x * x).sum();
            let lam = (kss - qii).max(0.0) + sigma2;
            lam_inv[i] = 1.0 / lam;
        }
        // A = K_UU + K_UX Lambda^{-1} K_XU.
        let mut a = kuu.clone();
        for i in 0..n {
            let li = lam_inv[i];
            let row = kxu.row(i);
            for p in 0..m {
                let rp = row[p] * li;
                for q in 0..m {
                    a[(p, q)] += rp * row[q];
                }
            }
        }
        let chol_a = Chol::new(&a).ok_or_else(|| anyhow::anyhow!("FITC A not PD"))?;
        // beta = A^{-1} K_UX Lambda^{-1} y.
        let mut kux_liy = vec![0.0; m];
        for i in 0..n {
            let w = lam_inv[i] * data.y[i];
            let row = kxu.row(i);
            for p in 0..m {
                kux_liy[p] += row[p] * w;
            }
        }
        let beta = chol_a.solve(&kux_liy);
        // LML: -1/2 [ y^T Sigma^{-1} y + log|Sigma| + n log 2pi ],
        // Sigma^{-1} y = Lambda^{-1} y - Lambda^{-1} K_XU beta (Woodbury),
        // log|Sigma| = log|A| - log|K_UU| + sum log Lambda_ii.
        let mut fit = 0.0;
        for i in 0..n {
            let row = kxu.row(i);
            let pred: f64 = row.iter().zip(&beta).map(|(a, b)| a * b).sum();
            fit += data.y[i] * lam_inv[i] * (data.y[i] - pred);
        }
        let logdet = chol_a.logdet() - chol_kuu.logdet()
            - lam_inv.iter().map(|l| l.ln()).sum::<f64>();
        let lml = -0.5 * (fit + logdet + n as f64 * (2.0 * std::f64::consts::PI).ln());
        Ok(Fitc { kernel, sigma2, u, data, lam_inv, chol_a, chol_kuu, beta, lml })
    }

    /// Fit with inducing inputs on a regular 1-D grid over `[lo, hi]`
    /// (the paper's setup).
    pub fn fit_grid_1d(
        kernel: ProductKernel,
        sigma2: f64,
        data: Dataset,
        m: usize,
        lo: f64,
        hi: f64,
    ) -> anyhow::Result<Self> {
        let u: Vec<f64> = (0..m).map(|i| lo + (hi - lo) * i as f64 / (m - 1) as f64).collect();
        Self::fit(kernel, sigma2, data, u)
    }

    /// Log marginal likelihood.
    pub fn lml(&self) -> f64 {
        self.lml
    }

    /// LML and a central-finite-difference gradient over
    /// `[log_ell.., log_sf2, log_sigma2]` (keeps FITC's O(n m^2) shape up
    /// to a constant; the Figure-2 timing includes this).
    pub fn lml_fd_grad(&self) -> super::exact::NlmlGrad {
        let mut p0 = self.kernel.params();
        p0.push(self.sigma2.ln());
        let data = &self.data;
        let u = &self.u;
        let grad = crate::opt::fd_gradient(
            |p| {
                let mut k = self.kernel.clone();
                let nk = k.n_params();
                k.set_params(&p[..nk]);
                Fitc::fit(k, p[nk].exp(), data.clone(), u.clone())
                    .map(|f| f.lml())
                    .unwrap_or(f64::NEG_INFINITY)
            },
            &p0,
            1e-5,
        );
        super::exact::NlmlGrad { lml: self.lml, grad }
    }

    /// Predictive mean: O(m) per test point.
    pub fn predict_mean(&self, xs: &[f64]) -> Vec<f64> {
        let d = self.data.d;
        let m = self.u.len() / d;
        let ns = xs.len() / d;
        let mut out = vec![0.0; ns];
        for (s, o) in out.iter_mut().enumerate() {
            let xstar = &xs[s * d..(s + 1) * d];
            let mut acc = 0.0;
            for j in 0..m {
                acc += self.kernel.eval(xstar, &self.u[j * d..(j + 1) * d]) * self.beta[j];
            }
            *o = acc;
        }
        out
    }

    /// Latent predictive variance: O(m^2) per test point.
    pub fn predict_var(&self, xs: &[f64]) -> Vec<f64> {
        let d = self.data.d;
        let m = self.u.len() / d;
        let ns = xs.len() / d;
        let kss = self.kernel.sf2();
        let mut out = vec![0.0; ns];
        let mut kxs = vec![0.0; m];
        for (s, o) in out.iter_mut().enumerate() {
            let xstar = &xs[s * d..(s + 1) * d];
            for j in 0..m {
                kxs[j] = self.kernel.eval(xstar, &self.u[j * d..(j + 1) * d]);
            }
            // var = k** - k*U K_UU^{-1} kU* + k*U A^{-1} kU*
            let v1 = self.chol_kuu.forward(&kxs);
            let q: f64 = v1.iter().map(|x| x * x).sum();
            let a_inv_k = self.chol_a.solve(&kxs);
            let corr: f64 = kxs.iter().zip(&a_inv_k).map(|(a, b)| a * b).sum();
            *o = (kss - q + corr).max(0.0);
        }
        out
    }

    /// Number of inducing points.
    pub fn m(&self) -> usize {
        self.u.len() / self.data.d
    }

    /// Access the per-point `Lambda^{-1}` (for tests).
    pub fn lam_inv(&self) -> &[f64] {
        &self.lam_inv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_stress_1d, smae};
    use crate::gp::exact::ExactGp;
    use crate::kernels::KernelType;

    #[test]
    fn with_inducing_equal_training_matches_exact_gp() {
        let data = gen_stress_1d(80, 0.05, 2);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
        // Inducing = training inputs -> FITC == exact GP (its fixed point).
        let fitc = Fitc::fit(kernel.clone(), 0.01, data.clone(), data.x.clone()).unwrap();
        let exact = ExactGp::fit(kernel, 0.01, data).unwrap();
        assert!(
            (fitc.lml() - exact.lml()).abs() < 0.5,
            "fitc {} vs exact {}",
            fitc.lml(),
            exact.lml()
        );
        let xs: Vec<f64> = (0..60).map(|i| -9.0 + 0.3 * i as f64).collect();
        let pf = fitc.predict_mean(&xs);
        let pe = exact.predict_mean(&xs);
        assert!(smae(&pf, &pe) < 0.05, "smae {}", smae(&pf, &pe));
    }

    #[test]
    fn grid_inducing_points_give_sensible_fit() {
        let data = gen_stress_1d(300, 0.05, 14);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
        let fitc = Fitc::fit_grid_1d(kernel, 0.01, data.clone(), 60, -11.0, 11.0).unwrap();
        let test = gen_stress_1d(150, 0.0, 99);
        let pred = fitc.predict_mean(&test.x);
        let err = smae(&pred, &test.y);
        assert!(err < 0.25, "SMAE {err}");
        // Variance positive and bounded by prior + slack.
        for v in fitc.predict_var(&test.x) {
            assert!(v >= 0.0 && v < 1.5);
        }
    }

    #[test]
    fn fd_gradient_is_finite_and_ascendable() {
        let data = gen_stress_1d(100, 0.1, 3);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 0.5, 0.8);
        let fitc = Fitc::fit_grid_1d(kernel.clone(), 0.05, data.clone(), 30, -11.0, 11.0).unwrap();
        let g = fitc.lml_fd_grad();
        assert!(g.grad.iter().all(|x| x.is_finite()));
        // One small ascent step improves the LML.
        let mut p = fitc.kernel.params();
        p.push(fitc.sigma2.ln());
        let norm: f64 = g.grad.iter().map(|x| x * x).sum::<f64>().sqrt();
        for (pi, gi) in p.iter_mut().zip(&g.grad) {
            *pi += 1e-3 * gi / norm.max(1e-12);
        }
        let mut k2 = kernel;
        k2.set_params(&p[..2]);
        let f2 = Fitc::fit_grid_1d(k2, p[2].exp(), data, 30, -11.0, 11.0).unwrap();
        assert!(f2.lml() >= fitc.lml(), "{} < {}", f2.lml(), fitc.lml());
    }
}
