//! MSGP — the paper's contribution (section 5).
//!
//! The model approximates the training covariance with structured kernel
//! interpolation (Eq. 5), `K_{X,X} ~= W K_{U,U} W^T`, where `W` is the
//! sparse local cubic interpolation matrix and `U` a rectilinear grid:
//!
//! * **Inference** is linear conjugate gradients on
//!   `(W K_{U,U} W^T + sigma^2 I) alpha = y`; every MVM costs
//!   O(n 4^D + m log m).
//! * **Kernel learning** uses the circulant (Whittle) approximation of
//!   section 5.2 (Kronecker-of-Toeplitz grids) or its BCCB generalization
//!   of section 5.3 (non-separable kernels) for O(m log m)
//!   log-determinants — with *analytic* hyperparameter gradients computed
//!   in the same spectral domain.
//! * **Fast predictions** (section 5.1) precompute
//!   `u_mean = K_{U,U} W^T alpha` and the stochastic explained-variance
//!   grid vector `nu_U` (Papandreou & Yuille estimator), after which a
//!   mean or variance prediction is a single sparse `W_*` row product —
//!   O(1) per test point.
//! * **Projections** (section 5.4): see [`ProjMsgp`], which learns a
//!   supervised linear map `P` into the grid space jointly with the
//!   kernel hyperparameters, through the same marginal likelihood.

use crate::data::Dataset;
use crate::grid::Grid;
use crate::interp::SparseInterp;
use crate::kernels::{KernelType, ProductKernel};
use crate::linalg::fft::Workspace as FftWorkspace;
use crate::linalg::Mat;
use crate::solver::{
    cg_solve, cg_solve_block, BlockCgResult, BlockCgWorkspace, CgOptions, CgResult, CgWorkspace,
    Preconditioner,
};
use crate::structure::bttb::{Bccb, Bttb};
use crate::structure::circulant::CirculantKind;
use crate::structure::kronecker::KronToeplitz;
use crate::structure::toeplitz::SymToeplitz;
use crate::util::Rng;

/// How `log |K_SKI + sigma^2 I|` is approximated during kernel learning.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogdetMethod {
    /// Circulant spectra per Toeplitz factor (the MSGP approach, 5.2).
    Circulant(CirculantKind),
    /// Classical O(m^2) Levinson–Durbin Toeplitz log-determinants per
    /// factor — the "MSGP with Toeplitz" ablation of Figure 2.
    /// Only changes the *log-det eigenvalue* pathway; MVMs stay FFT-based.
    ToeplitzExact,
}

/// MSGP configuration.
#[derive(Clone, Debug)]
pub struct MsgpConfig {
    /// Inducing grid points per dimension.
    pub n_per_dim: Vec<usize>,
    /// Margin (in grid cells) added around the data's bounding box.
    pub margin_cells: usize,
    /// Whittle periodic-summation wraps.
    pub wraps: usize,
    /// Log-determinant method.
    pub logdet: LogdetMethod,
    /// CG options for training solves.
    pub cg: CgOptions,
    /// Number of probe samples `n_s` for the stochastic variance
    /// estimator (the paper uses 20).
    pub n_var_samples: usize,
    /// RNG seed for the variance estimator.
    pub seed: u64,
}

impl Default for MsgpConfig {
    fn default() -> Self {
        MsgpConfig {
            n_per_dim: vec![512],
            margin_cells: 3,
            wraps: 3,
            logdet: LogdetMethod::Circulant(CirculantKind::Whittle),
            // The preconditioner choice is consumed by the streaming /
            // sharded m-domain refresh paths only (batch n-domain solves
            // ignore it); Spectral is the coordinator default.
            cg: CgOptions {
                tol: 1e-6,
                max_iter: 400,
                warm_start: false,
                precondition: Preconditioner::Spectral,
                deadline: None,
            },
            n_var_samples: 20,
            seed: 0,
        }
    }
}

/// Kernel specification: separable kernels ride the Kronecker-of-Toeplitz
/// path; isotropic (non-separable) kernels ride the BTTB/BCCB path.
#[derive(Clone, Debug)]
pub enum KernelSpec {
    /// Product kernel across dimensions (Kronecker structure, Eq. 11).
    Product(ProductKernel),
    /// Isotropic kernel of the Euclidean lag (BTTB structure, 5.3).
    Iso {
        /// Kernel family.
        ktype: KernelType,
        /// Log lengthscale.
        log_ell: f64,
        /// Log signal variance.
        log_sf2: f64,
        /// Input dimensionality.
        dim: usize,
    },
}

impl KernelSpec {
    /// Input dimensionality.
    pub fn dim(&self) -> usize {
        match self {
            KernelSpec::Product(k) => k.dim(),
            KernelSpec::Iso { dim, .. } => *dim,
        }
    }

    /// Signal variance.
    pub fn sf2(&self) -> f64 {
        match self {
            KernelSpec::Product(k) => k.sf2(),
            KernelSpec::Iso { log_sf2, .. } => log_sf2.exp(),
        }
    }

    /// Unit-variance correlation between two points.
    pub fn corr(&self, x: &[f64], z: &[f64]) -> f64 {
        match self {
            KernelSpec::Product(k) => {
                let mut c = 1.0;
                for d in 0..k.dim() {
                    c *= k.corr_d(d, x[d] - z[d]);
                }
                c
            }
            KernelSpec::Iso { ktype, log_ell, .. } => {
                let r = x.iter().zip(z).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
                ktype.corr(r, log_ell.exp())
            }
        }
    }

    /// Full kernel value.
    pub fn eval(&self, x: &[f64], z: &[f64]) -> f64 {
        self.sf2() * self.corr(x, z)
    }

    /// Hyperparameters `[shape params.., log_sf2]`.
    pub fn params(&self) -> Vec<f64> {
        match self {
            KernelSpec::Product(k) => k.params(),
            KernelSpec::Iso { log_ell, log_sf2, .. } => vec![*log_ell, *log_sf2],
        }
    }

    /// Set hyperparameters from a flat vector.
    pub fn set_params(&mut self, p: &[f64]) {
        match self {
            KernelSpec::Product(k) => k.set_params(p),
            KernelSpec::Iso { log_ell, log_sf2, .. } => {
                *log_ell = p[0];
                *log_sf2 = p[1];
            }
        }
    }

    /// Number of kernel hyperparameters.
    pub fn n_params(&self) -> usize {
        match self {
            KernelSpec::Product(k) => k.n_params(),
            KernelSpec::Iso { .. } => 2,
        }
    }
}

/// The grid operator `K_{U,U}` (unit signal variance; `sf2` is applied at
/// the model level).
enum Kuu {
    Kron(KronToeplitz),
    Bttb {
        op: Bttb,
        bccb: Bccb,
    },
}

impl Kuu {
    fn m(&self) -> usize {
        match self {
            Kuu::Kron(k) => k.m(),
            Kuu::Bttb { op, .. } => op.m(),
        }
    }

    fn matvec(&self, v: &[f64]) -> Vec<f64> {
        match self {
            Kuu::Kron(k) => k.matvec(v),
            Kuu::Bttb { op, .. } => op.matvec(v),
        }
    }

    fn sqrt_matvec(&self, v: &[f64]) -> Vec<f64> {
        match self {
            Kuu::Kron(k) => k.sqrt_matvec(v),
            Kuu::Bttb { bccb, .. } => bccb.sqrt_matvec(v),
        }
    }

    fn matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut FftWorkspace) {
        match self {
            Kuu::Kron(k) => k.matvec_batch(block, out, ws),
            Kuu::Bttb { op, .. } => op.matvec_batch(block, out, ws),
        }
    }

    fn sqrt_matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut FftWorkspace) {
        match self {
            Kuu::Kron(k) => k.sqrt_matvec_batch(block, out, ws),
            Kuu::Bttb { bccb, .. } => bccb.sqrt_matvec_batch(block, out, ws),
        }
    }
}

/// Public handle to the structured grid operator `K_{U,U}` (unit signal
/// variance): FFT-based MVMs plus the symmetric-PSD circulant square
/// root. The batch model builds this internally; the streaming subsystem
/// ([`crate::stream`]) builds it standalone so it can rebuild the
/// operator after grid auto-expansion or a hyperparameter re-opt without
/// refitting a whole [`MsgpModel`].
pub struct GridKernel {
    kuu: Kuu,
}

impl GridKernel {
    /// Build the operator for a kernel spec on a grid. Only
    /// `cfg.logdet` (circulant kind) and `cfg.wraps` are consulted.
    pub fn new(kernel: &KernelSpec, grid: &Grid, cfg: &MsgpConfig) -> Self {
        let kuu = match kernel {
            KernelSpec::Product(k) => Kuu::Kron(build_kron(k, grid, cfg)),
            KernelSpec::Iso { ktype, log_ell, .. } => {
                let (op, bccb) = build_bttb(*ktype, *log_ell, grid, cfg.wraps);
                Kuu::Bttb { op, bccb }
            }
        };
        GridKernel { kuu }
    }

    /// Grid size `m`.
    pub fn m(&self) -> usize {
        self.kuu.m()
    }

    /// `K_{U,U} v` (unit variance).
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        self.kuu.matvec(v)
    }

    /// Symmetric PSD `K_{U,U}^{1/2} v` (per-factor circulant square
    /// roots; `S S v` equals the Whittle circulant MVM, the section-5.2
    /// approximation of `K_{U,U} v`).
    pub fn sqrt_matvec(&self, v: &[f64]) -> Vec<f64> {
        self.kuu.sqrt_matvec(v)
    }

    /// Batched `K_{U,U} Y` over a row-major `b x m` block (two RHS per
    /// complex transform; see the batched engine in
    /// [`crate::linalg::fft`]).
    pub fn matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut FftWorkspace) {
        self.kuu.matvec_batch(block, out, ws)
    }

    /// Batched `K_{U,U}^{1/2} Y` over a row-major `b x m` block — the
    /// operator core of the block-CG m-domain refresh, which applies `S`
    /// to the mean and every variance probe in one call.
    pub fn sqrt_matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut FftWorkspace) {
        self.kuu.sqrt_matvec_batch(block, out, ws)
    }

    /// Grid shape (per-dimension sizes, row-major tensor layout).
    pub fn shape(&self) -> Vec<usize> {
        match &self.kuu {
            Kuu::Kron(k) => k.shape(),
            Kuu::Bttb { op, .. } => op.shape.clone(),
        }
    }

    /// Clipped eigenvalues (row-major tensor order over [`Self::shape`])
    /// of the multi-level circulant approximation `C = S S` of
    /// `K_{U,U}`: the Kronecker product of the per-factor circulant
    /// spectra on the separable path, the BCCB spectrum on the isotropic
    /// path. Both are diagonal in the multi-dimensional DFT basis, which
    /// is what lets the spectral refresh preconditioner
    /// ([`crate::solver::Preconditioner::Spectral`]) invert
    /// `sigma^2 I + a C` exactly in O(m log m).
    pub fn circulant_eigenvalues(&self) -> Vec<f64> {
        match &self.kuu {
            Kuu::Kron(k) => k.approx_eigenvalues(),
            Kuu::Bttb { bccb, .. } => bccb.eigenvalues_clipped(),
        }
    }
}

/// A trained MSGP model.
pub struct MsgpModel {
    /// Kernel spec (hyperparameters).
    pub kernel: KernelSpec,
    /// Noise variance.
    pub sigma2: f64,
    /// Configuration.
    pub cfg: MsgpConfig,
    /// Inducing grid.
    pub grid: Grid,
    /// Training data.
    pub data: Dataset,
    w: SparseInterp,
    kuu: Kuu,
    /// CG solution `alpha = (K_SKI + sigma^2 I)^{-1} y`.
    pub alpha: Vec<f64>,
    /// Fast-prediction precompute `u_mean = sf2 * K_{U,U} W^T alpha` (m).
    pub u_mean: Vec<f64>,
    /// Stochastic explained-variance grid vector (m), built on demand.
    pub nu_u: Option<Vec<f64>>,
    /// Diagnostics from the last training solve.
    pub last_cg: CgResult,
}

/// Per-output results of a multi-target block fit
/// ([`MsgpModel::fit_multi`]): training solutions, fast-mean caches,
/// and the lockstep solve diagnostics (per-column iteration counts,
/// compacted operator-work accounting).
pub struct MultiFit {
    /// `alpha_j = (K_SKI + sigma^2 I)^{-1} y_j` per output (`cols x n`).
    pub alphas: Vec<Vec<f64>>,
    /// Fast-mean caches `u_mean_j = sf2 K_UU W^T alpha_j` (`cols x m`).
    pub u_means: Vec<Vec<f64>>,
    /// Block-CG diagnostics for the single training solve.
    pub block: BlockCgResult,
}

/// Build the unit-variance per-dimension Toeplitz columns and the Whittle
/// (or other) circulant approximations for a product kernel on a grid.
fn build_kron(kernel: &ProductKernel, grid: &Grid, cfg: &MsgpConfig) -> KronToeplitz {
    let d = kernel.dim();
    let kind = match cfg.logdet {
        LogdetMethod::Circulant(k) => k,
        LogdetMethod::ToeplitzExact => CirculantKind::Whittle, // unused for logdet
    };
    let mut cols = Vec::with_capacity(d);
    for p in 0..d {
        let ax = &grid.axes[p];
        let col: Vec<f64> = (0..ax.n).map(|i| kernel.corr_d(p, i as f64 * ax.step)).collect();
        cols.push(col);
    }
    if kind == CirculantKind::Whittle {
        // Periodic summation needs the kernel tail beyond the grid.
        let tails: Vec<Box<dyn Fn(usize) -> f64>> = (0..d)
            .map(|p| {
                let step = grid.axes[p].step;
                let t = kernel.types[p];
                let ell = kernel.ell(p);
                Box::new(move |lag: usize| t.corr(lag as f64 * step, ell)) as Box<dyn Fn(usize) -> f64>
            })
            .collect();
        let tail_refs: Vec<&dyn Fn(usize) -> f64> = tails.iter().map(|b| b.as_ref()).collect();
        KronToeplitz::new_whittle(cols, cfg.wraps, &tail_refs)
    } else {
        KronToeplitz::new_with_kind(cols, kind)
    }
}

/// Build the BTTB operator + BCCB Whittle approximation for an isotropic
/// kernel on a grid (lags arrive in grid steps; scale to physical units).
fn build_bttb(ktype: KernelType, log_ell: f64, grid: &Grid, wraps: usize) -> (Bttb, Bccb) {
    let steps: Vec<f64> = grid.axes.iter().map(|a| a.step).collect();
    let ell = log_ell.exp();
    let kfn = move |lag: &[f64]| -> f64 {
        let r = lag.iter().zip(&steps).map(|(l, s)| (l * s) * (l * s)).sum::<f64>().sqrt();
        ktype.corr(r, ell)
    };
    let shape = grid.shape();
    let op = Bttb::new(&shape, &kfn);
    let bccb = Bccb::whittle(&shape, wraps, &kfn);
    (op, bccb)
}

impl MsgpModel {
    /// Fit with the grid chosen automatically to cover the data.
    pub fn fit(kernel: KernelSpec, sigma2: f64, data: Dataset, cfg: MsgpConfig) -> anyhow::Result<Self> {
        let d = data.d;
        anyhow::ensure!(kernel.dim() == d, "kernel dim {} vs data dim {}", kernel.dim(), d);
        anyhow::ensure!(cfg.n_per_dim.len() == d, "n_per_dim len vs data dim");
        let grid = Grid::covering(&data.x, d, &cfg.n_per_dim, cfg.margin_cells);
        Self::fit_with_grid(kernel, sigma2, data, grid, cfg)
    }

    /// Fit several outputs observed at the **same inputs** (multi-output
    /// regression, or restarts against perturbed targets) with **one
    /// lockstep block-CG training solve**: the grid, `W`, and `K_{U,U}`
    /// are built once, all `(K_SKI + sigma^2 I) alpha_j = y_j` systems
    /// advance together through [`cg_solve_block`] (batched real-FFT
    /// operator applies, active-column compaction as targets converge),
    /// and every output's fast-mean cache `u_mean_j` comes from one
    /// batched `K_{U,U}` apply. Per-output results match independent
    /// [`Self::fit`] calls on the shared grid (each column runs the
    /// identical scalar CG recurrence).
    ///
    /// Returns the model holding output 0's caches plus a [`MultiFit`]
    /// with every output's `alpha_j` / `u_mean_j`; predict other
    /// outputs by swapping their `u_mean` in (the interpolation weights
    /// `W_*` are output-independent).
    pub fn fit_multi(
        kernel: KernelSpec,
        sigma2: f64,
        x: Vec<f64>,
        d: usize,
        targets: &[Vec<f64>],
        cfg: MsgpConfig,
    ) -> anyhow::Result<(Self, MultiFit)> {
        anyhow::ensure!(!targets.is_empty(), "fit_multi needs at least one target");
        let n = targets[0].len();
        anyhow::ensure!(n > 0, "fit_multi needs at least one observation");
        anyhow::ensure!(
            targets.iter().all(|t| t.len() == n),
            "all targets must share the input rows"
        );
        anyhow::ensure!(x.len() == n * d, "x is n x d row-major");
        anyhow::ensure!(kernel.dim() == d, "kernel dim {} vs data dim {}", kernel.dim(), d);
        anyhow::ensure!(cfg.n_per_dim.len() == d, "n_per_dim len vs data dim");
        let grid = Grid::covering(&x, d, &cfg.n_per_dim, cfg.margin_cells);
        let data = Dataset { x, d, y: targets[0].clone() };
        let mut model = Self::build_unsolved(kernel, sigma2, data, grid, cfg);
        let m = model.m();
        let cols = targets.len();
        let mut ystack = vec![0.0; cols * n];
        for (c, t) in targets.iter().enumerate() {
            ystack[c * n..(c + 1) * n].copy_from_slice(t);
        }
        let mut alphas_flat = vec![0.0; cols * n];
        let mut wt = vec![0.0; cols * m];
        let mut ku = vec![0.0; cols * m];
        let mut fft_ws = FftWorkspace::new();
        let mut bws = BlockCgWorkspace::new(n, cols);
        let block = {
            let this: &Self = &model;
            cg_solve_block(
                |v, out| this.mvm_a_batch(v, out, &mut wt, &mut ku, &mut fft_ws),
                |v, out| out.copy_from_slice(v),
                &ystack,
                &mut alphas_flat,
                n,
                model.cfg.cg,
                &mut bws,
            )
        };
        anyhow::ensure!(
            block.rel_residuals.iter().all(|r| r.is_finite()),
            "block CG diverged ({:?})",
            block.rel_residuals
        );
        // Every output's fast-mean cache from ONE batched K_UU apply:
        // u_mean_j = sf2 * K_UU W^T alpha_j.
        let sf2 = model.kernel.sf2();
        for c in 0..cols {
            model
                .w
                .tmatvec_into(&alphas_flat[c * n..(c + 1) * n], &mut wt[c * m..(c + 1) * m]);
        }
        model.kuu.matvec_batch(&wt[..cols * m], &mut ku[..cols * m], &mut fft_ws);
        let alphas: Vec<Vec<f64>> =
            (0..cols).map(|c| alphas_flat[c * n..(c + 1) * n].to_vec()).collect();
        let u_means: Vec<Vec<f64>> = (0..cols)
            .map(|c| ku[c * m..(c + 1) * m].iter().map(|&v| sf2 * v).collect())
            .collect();
        model.alpha = alphas[0].clone();
        model.u_mean = u_means[0].clone();
        model.last_cg = CgResult {
            iters: block.col_iters[0],
            rel_residual: block.rel_residuals[0],
            converged: block.rel_residuals[0] <= model.cfg.cg.tol,
        };
        Ok((model, MultiFit { alphas, u_means, block }))
    }

    /// Fit with an explicit grid (e.g. the paper's `[-12, 13]` stress grid).
    pub fn fit_with_grid(
        kernel: KernelSpec,
        sigma2: f64,
        data: Dataset,
        grid: Grid,
        cfg: MsgpConfig,
    ) -> anyhow::Result<Self> {
        let mut model = Self::build_unsolved(kernel, sigma2, data, grid, cfg);
        model.solve_alpha()?;
        Ok(model)
    }

    /// Construct the model skeleton (grid, `W`, `K_{U,U}`) without
    /// running the training solve — shared by [`Self::fit_with_grid`]
    /// (scalar CG on one target) and [`Self::fit_multi`] (one block-CG
    /// solve across all targets).
    fn build_unsolved(
        kernel: KernelSpec,
        sigma2: f64,
        data: Dataset,
        grid: Grid,
        cfg: MsgpConfig,
    ) -> Self {
        let w = SparseInterp::build(&data.x, &grid);
        let kuu = match &kernel {
            KernelSpec::Product(k) => Kuu::Kron(build_kron(k, &grid, &cfg)),
            KernelSpec::Iso { ktype, log_ell, .. } => {
                let (op, bccb) = build_bttb(*ktype, *log_ell, &grid, cfg.wraps);
                Kuu::Bttb { op, bccb }
            }
        };
        MsgpModel {
            kernel,
            sigma2,
            cfg,
            grid,
            data,
            w,
            kuu,
            alpha: Vec::new(),
            u_mean: Vec::new(),
            nu_u: None,
            last_cg: CgResult { iters: 0, rel_residual: 0.0, converged: true },
        }
    }

    /// Number of training points.
    pub fn n(&self) -> usize {
        self.data.n()
    }

    /// Number of inducing points.
    pub fn m(&self) -> usize {
        self.kuu.m()
    }

    /// MVM with the SKI training covariance:
    /// `out = sf2 * W K_{U,U} W^T v + sigma2 * v`.
    pub fn mvm_a(&self, v: &[f64]) -> Vec<f64> {
        let sf2 = self.kernel.sf2();
        let wt = self.w.tmatvec(v);
        let ku = self.kuu.matvec(&wt);
        let mut out = self.w.matvec(&ku);
        for (o, &vi) in out.iter_mut().zip(v) {
            *o = sf2 * *o + self.sigma2 * vi;
        }
        out
    }

    /// Batched SKI covariance MVM over a row-major `k x n` block:
    /// `out_c = sf2 W K_{U,U} W^T v_c + sigma2 v_c` per column, with the
    /// FFT-dominant grid-operator part applied through the batched
    /// real-FFT engine (rfft half spectra + thread-pool fan-out) instead
    /// of once per column. `wt` / `ku` are caller-owned `>= k x m`
    /// scratch blocks; the block width is keyed off `v.len()`, so
    /// block-CG compaction can pass any `k <= cols`. Allocation-free:
    /// the sparse interpolation applies go through the `*_into` forms.
    pub fn mvm_a_batch(
        &self,
        v: &[f64],
        out: &mut [f64],
        wt: &mut [f64],
        ku: &mut [f64],
        ws: &mut FftWorkspace,
    ) {
        let n = self.n();
        let m = self.m();
        assert!(n > 0 && v.len() % n == 0, "v is k x n row-major");
        let k = v.len() / n;
        assert_eq!(out.len(), v.len());
        assert!(wt.len() >= k * m && ku.len() >= k * m, "scratch too small");
        let sf2 = self.kernel.sf2();
        for c in 0..k {
            self.w.tmatvec_into(&v[c * n..(c + 1) * n], &mut wt[c * m..(c + 1) * m]);
        }
        self.kuu.matvec_batch(&wt[..k * m], &mut ku[..k * m], ws);
        for c in 0..k {
            // W applies straight into the output column (matvec_into
            // overwrites every element), then the noise shift folds in.
            let oc = &mut out[c * n..(c + 1) * n];
            self.w.matvec_into(&ku[c * m..(c + 1) * m], oc);
            for (o, &vi) in oc.iter_mut().zip(&v[c * n..(c + 1) * n]) {
                *o = sf2 * *o + self.sigma2 * vi;
            }
        }
    }

    fn solve_alpha(&mut self) -> anyhow::Result<()> {
        let n = self.n();
        let mut alpha = vec![0.0; n];
        let mut ws = CgWorkspace::new(n);
        let y = self.data.y.clone();
        let res = {
            let this: &Self = self;
            let mut apply = |v: &[f64], out: &mut [f64]| {
                let r = this.mvm_a(v);
                out.copy_from_slice(&r);
            };
            cg_solve(
                &mut apply,
                |v, out| out.copy_from_slice(v),
                &y,
                &mut alpha,
                self.cfg.cg,
                &mut ws,
            )
        };
        anyhow::ensure!(
            res.rel_residual.is_finite(),
            "CG diverged (residual {})",
            res.rel_residual
        );
        self.alpha = alpha;
        // u_mean = sf2 * K_UU W^T alpha — fast-mean precompute (5.1.1).
        let wt = self.w.tmatvec(&self.alpha);
        let mut u = self.kuu.matvec(&wt);
        let sf2 = self.kernel.sf2();
        for v in u.iter_mut() {
            *v *= sf2;
        }
        self.u_mean = u;
        self.last_cg = res;
        self.nu_u = None;
        Ok(())
    }

    /// Approximate eigenvalues of `sf2 * K_{U,U}` (unsorted), used in the
    /// KISS-GP log-det approximation. With [`LogdetMethod::ToeplitzExact`]
    /// the per-factor spectra come from dense Jacobi eigendecompositions
    /// fed by O(m^2)-cost Levinson checks — the Figure-2 ablation.
    fn kuu_eigenvalues(&self) -> Vec<f64> {
        let sf2 = self.kernel.sf2();
        let mut eigs = match (&self.kuu, self.cfg.logdet) {
            (Kuu::Kron(k), LogdetMethod::Circulant(_)) => k.approx_eigenvalues(),
            (Kuu::Kron(k), LogdetMethod::ToeplitzExact) => {
                // Exact per-factor spectra via dense symmetric eigen. For
                // factors beyond ~300 points this is prohibitive — which
                // is exactly why the 1-D log-det below special-cases the
                // Levinson O(m^2) path; eigenvalues are only materialized
                // here for small multi-dimensional factors.
                // Factors beyond ~512 points fall back to the circulant
                // spectra for the *eigenvalue pairing* used by gradients —
                // the O(m^2) ablation cost enters through `logdet()`'s
                // Levinson branch, which `lml()`/`lml_grad()` always call.
                if k.factors.iter().any(|f| f.m() > 512) {
                    return {
                        let mut eigs = k.approx_eigenvalues();
                        for e in eigs.iter_mut() {
                            *e *= sf2;
                        }
                        eigs
                    };
                }
                let mut vals = vec![1.0f64];
                for f in &k.factors {
                    let md = f.m();
                    let dense = Mat::from_fn(md, md, |i, j| f.k[i.abs_diff(j)]);
                    let e = crate::linalg::eigen::sym_eig(&dense);
                    let mut next = Vec::with_capacity(vals.len() * md);
                    for &a in &vals {
                        for &b in &e.vals {
                            next.push(a * b.max(0.0));
                        }
                    }
                    vals = next;
                }
                vals
            }
            (Kuu::Bttb { bccb, .. }, _) => bccb.eigenvalues_clipped(),
        };
        for e in eigs.iter_mut() {
            *e *= sf2;
        }
        eigs
    }

    /// KISS-GP log-determinant approximation:
    /// `log|K_SKI + s^2 I| ~= sum_{i<=n'} log((n/m) g_i + s^2) + (n-n') log s^2`
    /// with `g` the top `n' = min(n, m)` approximate eigenvalues of
    /// `sf2 K_{U,U}`.
    ///
    /// With [`LogdetMethod::ToeplitzExact`] on a 1-D grid with `m <= n`,
    /// the sum over all `m` eigenvalues collapses to the exact identity
    /// `m log(n sf2 / m) + log|K_UU + (m / (n sf2)) s^2 I|`, which the
    /// classical Levinson–Durbin recursion evaluates in O(m^2) — the
    /// traditional Toeplitz pathway whose cost the Figure-2 ablation
    /// measures.
    pub fn logdet(&self) -> f64 {
        if self.cfg.logdet == LogdetMethod::ToeplitzExact {
            if let (Kuu::Kron(k), true, 1) = (&self.kuu, self.m() <= self.n(), self.grid.dim()) {
                let n = self.n() as f64;
                let m = self.m() as f64;
                let sf2 = self.kernel.sf2();
                let scale = n * sf2 / m;
                let shifted = self.sigma2 / scale;
                if let Some(ld) = k.factors[0].logdet_levinson(shifted) {
                    return m * scale.ln() + ld;
                }
                // Fall through to the spectral path on PD failure.
            }
        }
        let (eigs, _) = self.sorted_eigs();
        self.logdet_from(&eigs)
    }

    fn sorted_eigs(&self) -> (Vec<f64>, Vec<usize>) {
        let eigs = self.kuu_eigenvalues();
        let mut idx: Vec<usize> = (0..eigs.len()).collect();
        idx.sort_by(|&a, &b| eigs[b].partial_cmp(&eigs[a]).unwrap());
        let sorted: Vec<f64> = idx.iter().map(|&i| eigs[i]).collect();
        (sorted, idx)
    }

    fn logdet_from(&self, sorted_eigs: &[f64]) -> f64 {
        let n = self.n();
        let m = self.m();
        let np = n.min(m);
        let scale = n as f64 / m as f64;
        let mut ld = 0.0;
        for &g in &sorted_eigs[..np] {
            ld += (scale * g + self.sigma2).ln();
        }
        ld += (n - np) as f64 * self.sigma2.ln();
        ld
    }

    /// Log marginal likelihood (Eq. 3) under the SKI + spectral
    /// approximations.
    pub fn lml(&self) -> f64 {
        let n = self.n() as f64;
        let fit: f64 = self.data.y.iter().zip(&self.alpha).map(|(y, a)| y * a).sum();
        -0.5 * (fit + self.logdet() + n * (2.0 * std::f64::consts::PI).ln())
    }

    /// Analytic gradient of the log marginal likelihood with respect to
    /// `[kernel params.., log_sigma2]`.
    ///
    /// * fit term: `d(y^T A^{-1} y)/dt = -alpha^T (dA/dt) alpha`, with
    ///   `dA/dt = W dK_{U,U}/dt W^T` an MVM in the same structure;
    /// * log-det term: differentiated in the spectral domain,
    ///   `d g_i/dt` being the (Kronecker product of) circulant spectra of
    ///   the derivative kernel columns.
    pub fn lml_grad(&self) -> super::exact::NlmlGrad {
        let nk = self.kernel.n_params();
        let mut grad = vec![0.0; nk + 1];
        let n = self.n();
        let m = self.m();
        let np = n.min(m);
        let scale = n as f64 / m as f64;
        let sf2 = self.kernel.sf2();

        let (eigs_sorted, perm) = self.sorted_eigs();
        // Common factors for the log-det gradient.
        let denom: Vec<f64> = eigs_sorted[..np]
            .iter()
            .map(|&g| 1.0 / (scale * g + self.sigma2))
            .collect();

        let wt_alpha = self.w.tmatvec(&self.alpha);

        // --- kernel shape parameters (lengthscales) ---
        match (&self.kernel, &self.kuu) {
            (KernelSpec::Product(kern), Kuu::Kron(kt)) => {
                let d = kern.dim();
                for p in 0..d {
                    // Derivative column for factor p.
                    let ax = &self.grid.axes[p];
                    let dcol: Vec<f64> = (0..ax.n)
                        .map(|i| kern.types[p].dcorr_dlog_ell(i as f64 * ax.step, kern.ell(p)))
                        .collect();
                    // fit: -alpha^T W (sf2 * dK) W^T alpha with factor p replaced.
                    let quad = {
                        let dt = SymToeplitz::new(dcol.clone());
                        let v = kron_matvec_replaced(kt, p, &dt, &wt_alpha);
                        sf2 * crate::linalg::dense::dot(&wt_alpha, &v)
                    };
                    // log-det: d g = sf2 * (lam_1 x .. dlam_p .. x lam_D).
                    let dlam_p = whittle_spectrum_of(
                        &dcol,
                        self.cfg.wraps,
                        |lag| kern.types[p].dcorr_dlog_ell(lag as f64 * ax.step, kern.ell(p)),
                    );
                    let deigs = kron_spectrum_replaced(kt, p, &dlam_p, sf2);
                    let mut ld = 0.0;
                    for (rank, &src) in perm[..np].iter().enumerate() {
                        ld += scale * deigs[src] * denom[rank];
                    }
                    grad[p] = 0.5 * quad - 0.5 * ld;
                }
            }
            (KernelSpec::Iso { ktype, log_ell, .. }, Kuu::Bttb { .. }) => {
                let steps: Vec<f64> = self.grid.axes.iter().map(|a| a.step).collect();
                let ell = log_ell.exp();
                let kt = *ktype;
                let dkfn = move |lag: &[f64]| -> f64 {
                    let r = lag.iter().zip(&steps).map(|(l, s)| (l * s) * (l * s)).sum::<f64>().sqrt();
                    kt.dcorr_dlog_ell(r, ell)
                };
                let shape = self.grid.shape();
                let dop = Bttb::new(&shape, &dkfn);
                let quad = {
                    let v = dop.matvec(&wt_alpha);
                    sf2 * crate::linalg::dense::dot(&wt_alpha, &v)
                };
                let dbccb = Bccb::whittle(&shape, self.cfg.wraps, &dkfn);
                // NOTE: derivative spectra are not clipped (they can be
                // negative); pair with the clipped primal spectrum.
                let deigs: Vec<f64> = dbccb.eigs.iter().map(|&e| sf2 * e).collect();
                let mut ld = 0.0;
                for (rank, &src) in perm[..np].iter().enumerate() {
                    ld += scale * deigs[src] * denom[rank];
                }
                grad[0] = 0.5 * quad - 0.5 * ld;
            }
            _ => unreachable!("kernel spec and kuu structure always match"),
        }

        // --- signal variance: dK = sf2 K_UU (i.e. d g = g) ---
        let isf2 = nk - 1;
        {
            let v = self.kuu.matvec(&wt_alpha);
            let quad = sf2 * crate::linalg::dense::dot(&wt_alpha, &v);
            let mut ld = 0.0;
            for (rank, &g) in eigs_sorted[..np].iter().enumerate() {
                ld += scale * g * denom[rank];
            }
            grad[isf2] = 0.5 * quad - 0.5 * ld;
        }

        // --- noise: dA = sigma2 I ---
        {
            let quad = self.sigma2 * crate::linalg::dense::dot(&self.alpha, &self.alpha);
            let mut ld = 0.0;
            for dn in denom.iter() {
                ld += self.sigma2 * dn;
            }
            ld += (n - np) as f64; // d/dlog s2 of (n - n') log s2
            grad[nk] = 0.5 * quad - 0.5 * ld;
        }

        super::exact::NlmlGrad { lml: self.lml(), grad }
    }

    /// Precompute the stochastic explained-variance grid vector `nu_U`
    /// (section 5.1.2, Eq. 9-10): draw `n_s` probes
    /// `r_i = A^{-1}(W K^{1/2} g_m + sigma g_n)` and average
    /// `(K_{U,U} W^T r_i)^2`.
    pub fn precompute_variance(&mut self) {
        let n = self.n();
        let m = self.m();
        let ns = self.cfg.n_var_samples.max(1);
        let sf2 = self.kernel.sf2();
        let mut rng = Rng::new(self.cfg.seed ^ 0x5eed_u64);
        let mut acc = vec![0.0f64; m];
        let mut ws = CgWorkspace::new(n);
        for _ in 0..ns {
            let gm = rng.normal_vec(m);
            let gn = rng.normal_vec(n);
            // rhs = W (sqrt(sf2) K^{1/2} g_m) + sigma g_n
            let mut s = self.kuu.sqrt_matvec(&gm);
            let rsf = sf2.sqrt();
            for v in s.iter_mut() {
                *v *= rsf;
            }
            let mut rhs = self.w.matvec(&s);
            let sig = self.sigma2.sqrt();
            for (r, &g) in rhs.iter_mut().zip(&gn) {
                *r += sig * g;
            }
            // Solve A r = rhs.
            let mut r = vec![0.0; n];
            {
                let this: &Self = self;
                let mut apply = |v: &[f64], out: &mut [f64]| {
                    let av = this.mvm_a(v);
                    out.copy_from_slice(&av);
                };
                cg_solve(
                    &mut apply,
                    |v, out| out.copy_from_slice(v),
                    &rhs,
                    &mut r,
                    self.cfg.cg,
                    &mut ws,
                );
            }
            // t = sf2 K_UU W^T r; acc += t^2.
            let wt = self.w.tmatvec(&r);
            let mut t = self.kuu.matvec(&wt);
            for v in t.iter_mut() {
                *v *= sf2;
            }
            for (a, &ti) in acc.iter_mut().zip(&t) {
                *a += ti * ti;
            }
        }
        for a in acc.iter_mut() {
            *a /= ns as f64;
        }
        self.nu_u = Some(acc);
    }

    /// Fast O(1)-per-point predictive mean (Eq. 7): `W_* u_mean`.
    pub fn predict_mean(&self, xs: &[f64]) -> Vec<f64> {
        let ws = SparseInterp::build(xs, &self.grid);
        ws.matvec(&self.u_mean)
    }

    /// "Slow" predictive mean: exact cross-covariances against all `n`
    /// training points — O(n) per test point (the Figure 3 baseline).
    pub fn predict_mean_slow(&self, xs: &[f64]) -> Vec<f64> {
        let d = self.data.d;
        let ns = xs.len() / d;
        let mut out = vec![0.0; ns];
        for (s, o) in out.iter_mut().enumerate() {
            let xstar = &xs[s * d..(s + 1) * d];
            let mut acc = 0.0;
            for i in 0..self.n() {
                acc += self.kernel.eval(xstar, self.data.row(i)) * self.alpha[i];
            }
            *o = acc;
        }
        out
    }

    /// Fast O(1)-per-point latent predictive variance (Eq. 10):
    /// `max(0, k_** - W_* nu_U)`. Requires [`Self::precompute_variance`]
    /// (called lazily here if needed).
    pub fn predict_var(&mut self, xs: &[f64]) -> Vec<f64> {
        if self.nu_u.is_none() {
            self.precompute_variance();
        }
        let nu = self.nu_u.as_ref().unwrap();
        let ws = SparseInterp::build(xs, &self.grid);
        let explained = ws.matvec(nu);
        let kss = self.kernel.sf2();
        explained.iter().map(|&e| (kss - e).max(0.0)).collect()
    }

    /// "Slow" latent predictive variance: one CG solve per test point
    /// against the SKI covariance — O(n) per test point.
    pub fn predict_var_slow(&self, xs: &[f64]) -> Vec<f64> {
        let d = self.data.d;
        let ns = xs.len() / d;
        let n = self.n();
        let sf2 = self.kernel.sf2();
        let wstar = SparseInterp::build(xs, &self.grid);
        let mut out = vec![0.0; ns];
        let mut ws = CgWorkspace::new(n);
        for s in 0..ns {
            // k_* = sf2 W K_UU w_*^T  (n-vector under SKI)
            let mut e = vec![0.0; ns];
            e[s] = 1.0;
            let wte = wstar.tmatvec(&e);
            let ku = self.kuu.matvec(&wte);
            let mut kstar = self.w.matvec(&ku);
            for v in kstar.iter_mut() {
                *v *= sf2;
            }
            let mut z = vec![0.0; n];
            {
                let this: &Self = self;
                let mut apply = |v: &[f64], out: &mut [f64]| {
                    let av = this.mvm_a(v);
                    out.copy_from_slice(&av);
                };
                cg_solve(
                    &mut apply,
                    |v, out| out.copy_from_slice(v),
                    &kstar,
                    &mut z,
                    self.cfg.cg,
                    &mut ws,
                );
            }
            let explained = crate::linalg::dense::dot(&kstar, &z);
            out[s] = (sf2 - explained).max(0.0);
        }
        out
    }

    /// Hyperparameters `[kernel params.., log_sigma2]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.kernel.params();
        p.push(self.sigma2.ln());
        p
    }

    /// Refit with new hyperparameters (rebuilds `K_{U,U}` and re-solves;
    /// the grid and `W` are reused — they do not depend on hypers).
    pub fn refit(&mut self, params: &[f64]) -> anyhow::Result<()> {
        let nk = self.kernel.n_params();
        self.kernel.set_params(&params[..nk]);
        self.sigma2 = params[nk].exp();
        self.kuu = match &self.kernel {
            KernelSpec::Product(k) => Kuu::Kron(build_kron(k, &self.grid, &self.cfg)),
            KernelSpec::Iso { ktype, log_ell, .. } => {
                let (op, bccb) = build_bttb(*ktype, *log_ell, &self.grid, self.cfg.wraps);
                Kuu::Bttb { op, bccb }
            }
        };
        self.solve_alpha()
    }

    /// Train by Adam ascent on the marginal likelihood. Returns the LML
    /// trace (one entry per iteration).
    pub fn train(&mut self, iters: usize, lr: f64) -> anyhow::Result<Vec<f64>> {
        let mut params = self.params();
        let mut opt = crate::opt::Adam::new(params.len(), lr);
        let mut trace = Vec::with_capacity(iters);
        for _ in 0..iters {
            let g = self.lml_grad();
            trace.push(g.lml);
            opt.step(&mut params, &g.grad);
            self.refit(&params)?;
        }
        Ok(trace)
    }
}

/// MVM with the Kronecker operator where factor `p` is replaced by `dt`.
fn kron_matvec_replaced(kt: &KronToeplitz, p: usize, dt: &SymToeplitz, x: &[f64]) -> Vec<f64> {
    let shape: Vec<usize> = kt.factors.iter().map(|f| f.m()).collect();
    let mut data = x.to_vec();
    for (axis, f) in kt.factors.iter().enumerate() {
        let op: &SymToeplitz = if axis == p { dt } else { f };
        crate::structure::kronecker::apply_along_axis(&mut data, &shape, axis, |line, out| {
            let r = op.matvec(line);
            out.copy_from_slice(&r);
        });
    }
    data
}

/// Kronecker-product spectrum with factor `p`'s spectrum replaced by
/// `dlam` (not clipped — derivative spectra can be negative). Primal
/// factors use clipped circulant spectra, matching the forward log-det.
fn kron_spectrum_replaced(kt: &KronToeplitz, p: usize, dlam: &[f64], sf2: f64) -> Vec<f64> {
    let mut vals = vec![sf2];
    for (axis, c) in kt.circulants.iter().enumerate() {
        let lam: Vec<f64> = if axis == p {
            dlam.to_vec()
        } else {
            c.eigs.iter().map(|&e| e.max(0.0)).collect()
        };
        let mut next = Vec::with_capacity(vals.len() * lam.len());
        for &a in &vals {
            for &b in &lam {
                next.push(a * b);
            }
        }
        vals = next;
    }
    vals
}

/// Whittle circulant spectrum of a derivative column: periodic summation
/// with the derivative tail, then FFT (no clipping).
fn whittle_spectrum_of(col: &[f64], wraps: usize, tail: impl Fn(usize) -> f64) -> Vec<f64> {
    let m = col.len();
    let get = |lag: usize| -> f64 {
        if lag < m {
            col[lag]
        } else {
            tail(lag)
        }
    };
    let mut c = vec![0.0; m];
    for (i, ci) in c.iter_mut().enumerate() {
        let mut s = get(i);
        for j in 1..=wraps.max(1) {
            s += get(j * m + i);
            s += get(j * m - i);
        }
        *ci = s;
    }
    crate::linalg::fft::rfft(&c).into_iter().map(|z| z.re).collect()
}

/// Supervised-projection MSGP (section 5.4): learns a linear map
/// `P in R^{d x D}` from the high-dimensional input space into the grid
/// space, jointly with the kernel hyperparameters, by marginal-likelihood
/// ascent. `P` is consumed with unit row scaling
/// (`Q = diag(1/sqrt(diag(P P^T))) P`), the constraint the paper found
/// sufficient to avoid lengthscale/projection degeneracies.
pub struct ProjMsgp {
    /// Raw (unconstrained) projection, `d x D`.
    pub p: Mat,
    /// The grid-space model over projected inputs.
    pub model: MsgpModel,
    /// High-dimensional training data.
    pub data_high: Dataset,
    /// Fixed grid in the projected space. Unit row scaling bounds the
    /// projected coordinates, so a generously sized grid built from the
    /// initial projection stays valid throughout training (points that
    /// escape are clamped one cell inside).
    pub grid: Grid,
    cfg: MsgpConfig,
}

/// Unit row scaling: `Q = diag(1/||P_row||) P`.
pub fn unit_scale(p: &Mat) -> Mat {
    let mut q = p.clone();
    for r in 0..p.rows {
        let norm = crate::linalg::dense::dot(p.row(r), p.row(r)).sqrt().max(1e-12);
        for c in 0..p.cols {
            q[(r, c)] = p[(r, c)] / norm;
        }
    }
    q
}

/// Chain rule through unit scaling (appendix A.1): given `G = d psi/dQ`,
/// return `d psi/dP`.
pub fn unit_scale_chain(p: &Mat, g: &Mat) -> Mat {
    let mut out = Mat::zeros(p.rows, p.cols);
    for r in 0..p.rows {
        let norm2 = crate::linalg::dense::dot(p.row(r), p.row(r)).max(1e-24);
        let pr = 1.0 / norm2.sqrt();
        let gp: f64 = g.row(r).iter().zip(p.row(r)).map(|(a, b)| a * b).sum();
        for c in 0..p.cols {
            out[(r, c)] = pr * g[(r, c)] - pr.powi(3) * p[(r, c)] * gp;
        }
    }
    out
}

impl ProjMsgp {
    /// Project high-dimensional rows through the unit-scaled `P`.
    pub fn project(p: &Mat, data: &Dataset) -> Vec<f64> {
        let q = unit_scale(p);
        let n = data.n();
        let d = q.rows;
        let mut out = vec![0.0; n * d];
        for i in 0..n {
            let row = data.row(i);
            for r in 0..d {
                out[i * d + r] = crate::linalg::dense::dot(q.row(r), row);
            }
        }
        out
    }

    /// Informed initialization for the projection: the first row is the
    /// ridge-regression direction `(X^T X + reg I)^{-1} X^T y` (the
    /// target's linear trend almost always has a component inside the
    /// true subspace, giving the optimizer a foothold), remaining rows
    /// are random. Greatly improves convergence at D >= 10 over a fully
    /// random start.
    pub fn informed_init(d: usize, data: &Dataset, seed: u64) -> Mat {
        let bigd = data.d;
        let n = data.n();
        let mut rng = Rng::new(seed);
        let mut p = crate::data::randn_mat(d, bigd, &mut rng);
        // Ridge solve in the (small) D x D space.
        let mut xtx = Mat::zeros(bigd, bigd);
        let mut xty = vec![0.0; bigd];
        for i in 0..n {
            let row = data.row(i);
            for a in 0..bigd {
                xty[a] += row[a] * data.y[i];
                for b in 0..bigd {
                    xtx[(a, b)] += row[a] * row[b];
                }
            }
        }
        for a in 0..bigd {
            xtx[(a, a)] += 1e-3 * n as f64;
        }
        if let Some(w) = xtx.solve(&xty) {
            let norm = crate::linalg::dense::dot(&w, &w).sqrt();
            if norm > 1e-9 {
                for b in 0..bigd {
                    p[(0, b)] = w[b] / norm * (bigd as f64).sqrt();
                }
            }
        }
        p
    }

    /// Fit with an initial projection (e.g. random) and kernel. The grid
    /// is built once from the initial projected inputs, expanded by 40%
    /// on each side, and held fixed for the lifetime of the model.
    pub fn fit(
        p0: Mat,
        kernel: ProductKernel,
        sigma2: f64,
        data_high: Dataset,
        cfg: MsgpConfig,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(kernel.dim() == p0.rows, "kernel dim vs projection rows");
        anyhow::ensure!(p0.cols == data_high.d, "projection cols vs data dim");
        let d = p0.rows;
        let x_low = Self::project(&p0, &data_high);
        // Expanded bounding box -> fixed grid.
        let mut axes = Vec::with_capacity(d);
        for a in 0..d {
            let mut lo = f64::INFINITY;
            let mut hi = f64::NEG_INFINITY;
            for i in 0..data_high.n() {
                let v = x_low[i * d + a];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let pad = 0.4 * (hi - lo).max(1e-6);
            axes.push(crate::grid::GridAxis::span(lo - pad, hi + pad, cfg.n_per_dim[a]));
        }
        let grid = Grid::new(axes);
        Self::fit_with_grid(p0, kernel, sigma2, data_high, grid, cfg)
    }

    /// Fit with an explicit (fixed) grid in the projected space.
    pub fn fit_with_grid(
        p0: Mat,
        kernel: ProductKernel,
        sigma2: f64,
        data_high: Dataset,
        grid: Grid,
        cfg: MsgpConfig,
    ) -> anyhow::Result<Self> {
        let x_low = clamp_to_grid(&Self::project(&p0, &data_high), &grid);
        let low = Dataset { x: x_low, d: p0.rows, y: data_high.y.clone() };
        let model =
            MsgpModel::fit_with_grid(KernelSpec::Product(kernel), sigma2, low, grid.clone(), cfg.clone())?;
        Ok(ProjMsgp { p: p0, model, data_high, grid, cfg })
    }

    /// Gradient of the LML with respect to the *unit-scaled* projection
    /// entries, then pulled back through the scaling to raw `P`.
    pub fn grad_p(&self) -> Mat {
        let d = self.model.data.d;
        let bigd = self.data_high.d;
        let n = self.model.n();
        // dW rows with respect to the projected coordinates.
        let (_, grads) = SparseInterp::build_with_grad(&self.model.data.x, &self.model.grid);
        // G[a][b] = sum_i alpha_i * (dW_a row_i . u_mean) * x_high[i][b]
        let mut g_q = Mat::zeros(d, bigd);
        for a in 0..d {
            for i in 0..n {
                let t = grads[a].row_dot(i, &self.model.u_mean);
                let coeff = self.model.alpha[i] * t;
                if coeff == 0.0 {
                    continue;
                }
                let xi = self.data_high.row(i);
                for b in 0..bigd {
                    g_q[(a, b)] += coeff * xi[b];
                }
            }
        }
        unit_scale_chain(&self.p, &g_q)
    }

    /// Joint training: Adam over `[kernel params, log_sigma2, vec(P)]`.
    /// The grid and `W` are rebuilt every iteration because the projected
    /// inputs move with `P`. Returns the LML trace.
    pub fn train(&mut self, iters: usize, lr: f64) -> anyhow::Result<Vec<f64>> {
        self.train_with(iters, lr, false)
    }

    /// [`Self::train`] with the option to freeze the noise variance.
    /// Freezing sigma2 during the first training phase prevents the
    /// "explain everything as noise" local optimum that otherwise traps
    /// high-D projection learning before `P` finds the subspace.
    pub fn train_with(
        &mut self,
        iters: usize,
        lr: f64,
        freeze_noise: bool,
    ) -> anyhow::Result<Vec<f64>> {
        let nk = self.model.kernel.n_params();
        let nhyp = nk + 1;
        let np = self.p.rows * self.p.cols;
        let mut params = self.model.params();
        params.extend_from_slice(&self.p.data);
        let mut opt = crate::opt::Adam::new(nhyp + np, lr);
        let mut trace = Vec::with_capacity(iters);
        for _ in 0..iters {
            let hg = self.model.lml_grad();
            let pg = self.grad_p();
            trace.push(hg.lml);
            let mut grad = hg.grad.clone();
            if freeze_noise {
                grad[nk] = 0.0;
            }
            grad.extend_from_slice(&pg.data);
            opt.step(&mut params, &grad);
            // Unpack.
            self.p.data.copy_from_slice(&params[nhyp..]);
            let x_low = clamp_to_grid(&Self::project(&self.p, &self.data_high), &self.grid);
            let low = Dataset { x: x_low, d: self.p.rows, y: self.data_high.y.clone() };
            let mut kernel = match &self.model.kernel {
                KernelSpec::Product(k) => k.clone(),
                _ => unreachable!(),
            };
            kernel.set_params(&params[..nk]);
            let sigma2 = params[nk].exp();
            self.model = MsgpModel::fit_with_grid(
                KernelSpec::Product(kernel),
                sigma2,
                low,
                self.grid.clone(),
                self.cfg.clone(),
            )?;
        }
        Ok(trace)
    }

    /// Predict (fast mean) at high-dimensional test inputs.
    pub fn predict_mean(&self, xs_high: &[f64]) -> Vec<f64> {
        let ns = xs_high.len() / self.data_high.d;
        let tmp = Dataset { x: xs_high.to_vec(), d: self.data_high.d, y: vec![0.0; ns] };
        let xs_low = Self::project(&self.p, &tmp);
        // Test points can project outside the training grid; fall back to
        // the slow path for those rows (rare; the grid margin covers most).
        self.model.predict_mean(&clamp_to_grid(&xs_low, &self.model.grid))
    }

    /// Subspace distance between the learned and a reference projection
    /// (Eq. 13): spectral norm of the difference of the orthogonal
    /// projectors onto the two row spaces.
    pub fn subspace_error(&self, p_ref: &Mat) -> f64 {
        subspace_dist(&self.p, p_ref)
    }
}

/// Clamp projected points into the grid's covered box (used for test-time
/// inputs that fall outside the training grid).
fn clamp_to_grid(xs: &[f64], grid: &Grid) -> Vec<f64> {
    let d = grid.dim();
    let mut out = xs.to_vec();
    for i in 0..out.len() / d {
        for a in 0..d {
            let ax = &grid.axes[a];
            let lo = ax.lo + ax.step; // one cell inside
            let hi = ax.coord(ax.n - 2);
            out[i * d + a] = out[i * d + a].clamp(lo, hi);
        }
    }
    out
}

/// `dist(P_1, P_2) = ||G_1 - G_2||_2` (Eq. 13) where `G_i` is the
/// orthogonal projector onto the row space of `P_i`; in `[0, 1]`.
pub fn subspace_dist(p1: &Mat, p2: &Mat) -> f64 {
    let g1 = row_space_projector(p1);
    let g2 = row_space_projector(p2);
    let mut diff = g1;
    diff.axpy(-1.0, &g2);
    crate::linalg::eigen::sym_norm2(&diff)
}

/// Orthogonal projector onto the row space of `P` (`D x D`):
/// `G = P^T (P P^T)^{-1} P`.
fn row_space_projector(p: &Mat) -> Mat {
    let ppt = p.matmul(&p.t());
    let inv = crate::linalg::cholesky::Chol::new(&ppt)
        .expect("P P^T must be PD (full row rank)")
        .inverse();
    p.t().matmul(&inv).matmul(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_stress_1d, gen_stress_2d, smae};
    use crate::gp::exact::ExactGp;

    fn cfg_1d(m: usize) -> MsgpConfig {
        MsgpConfig { n_per_dim: vec![m], ..Default::default() }
    }

    fn fit_1d(n: usize, m: usize) -> MsgpModel {
        let data = gen_stress_1d(n, 0.05, 11);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        MsgpModel::fit(kernel, 0.01, data, cfg_1d(m)).unwrap()
    }

    #[test]
    fn ski_mvm_close_to_exact_kernel_mvm() {
        let n = 120;
        let data = gen_stress_1d(n, 0.05, 4);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.5, 1.0);
        let model = MsgpModel::fit(
            KernelSpec::Product(kernel.clone()),
            0.01,
            data.clone(),
            cfg_1d(400),
        )
        .unwrap();
        let v: Vec<f64> = (0..n).map(|i| ((i * 7 % 13) as f64) - 6.0).collect();
        let got = model.mvm_a(&v);
        // Exact dense MVM.
        let kmat = Mat::from_fn(n, n, |i, j| kernel.eval(data.row(i), data.row(j)));
        let mut want = kmat.matvec(&v);
        for (w, &vi) in want.iter_mut().zip(&v) {
            *w += 0.01 * vi;
        }
        let num: f64 = got.iter().zip(&want).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        let den: f64 = want.iter().map(|b| b * b).sum::<f64>().sqrt();
        assert!(num / den < 1e-3, "rel err {}", num / den);
    }

    #[test]
    fn fast_mean_matches_exact_gp() {
        let model = fit_1d(400, 512);
        let exact = ExactGp::fit(
            ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0),
            0.01,
            model.data.clone(),
        )
        .unwrap();
        let xs: Vec<f64> = (0..200).map(|i| -9.5 + i as f64 * 0.095).collect();
        let fast = model.predict_mean(&xs);
        let gold = exact.predict_mean(&xs);
        let err = smae(&fast, &gold);
        assert!(err < 0.02, "SMAE vs exact {err}");
    }

    #[test]
    fn fast_mean_matches_slow_mean() {
        // The paper: fast interpolated mean is "essentially
        // indistinguishable" from the slow SKI mean.
        let model = fit_1d(300, 512);
        let xs: Vec<f64> = (0..100).map(|i| -9.0 + i as f64 * 0.18).collect();
        let fast = model.predict_mean(&xs);
        let slow = model.predict_mean_slow(&xs);
        for (f, s) in fast.iter().zip(&slow) {
            assert!((f - s).abs() < 0.02, "{f} vs {s}");
        }
    }

    #[test]
    fn fast_var_tracks_exact_var_on_signal_scale() {
        // The stochastic estimator has relative error ~sqrt(2/n_s) on
        // nu_U (the paper quotes 0.36 at n_s = 20), so compare on the
        // signal-variance scale, not relative to near-zero exact values.
        let mut model = fit_1d(400, 256);
        model.cfg.n_var_samples = 100;
        let exact = ExactGp::fit(
            ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0),
            0.01,
            model.data.clone(),
        )
        .unwrap();
        let xs: Vec<f64> = (0..50).map(|i| -8.0 + i as f64 * 0.32).collect();
        let fast = model.predict_var(&xs);
        let gold = exact.predict_var(&xs);
        let sf2 = model.kernel.sf2();
        let mean_abs: f64 =
            fast.iter().zip(&gold).map(|(f, g)| (f - g).abs()).sum::<f64>() / xs.len() as f64;
        assert!(mean_abs / sf2 < 0.2, "mean abs var err / sf2 = {}", mean_abs / sf2);
        // Ordering sanity: a point far outside the data range has much
        // larger predicted variance than interior points.
        let far = model.predict_var(&[11.5])[0];
        let near = fast.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(far > 5.0 * near.max(1e-4), "far {far} near {near}");
    }

    #[test]
    fn stochastic_nu_matches_deterministic_nu() {
        // nu_U = diag(Ktilde_UX A^{-1} Ktilde_XU) computed exactly column
        // by column vs the Papandreou–Yuille estimator with many samples.
        let n = 120;
        let data = gen_stress_1d(n, 0.05, 19);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let mut cfg = cfg_1d(32);
        cfg.n_var_samples = 800;
        cfg.cg = CgOptions { tol: 1e-10, max_iter: 2000, ..Default::default() };
        let mut model = MsgpModel::fit(kernel, 0.05, data, cfg).unwrap();
        model.precompute_variance();
        let est = model.nu_u.clone().unwrap();
        let m = model.m();
        let sf2 = model.kernel.sf2();
        // Deterministic: for each grid column j, b_j = sf2 W K_UU e_j,
        // nu_j = b_j^T A^{-1} b_j.
        let mut ws = CgWorkspace::new(n);
        let mut det = vec![0.0; m];
        for j in 0..m {
            let mut e = vec![0.0; m];
            e[j] = 1.0;
            let ku = model.kuu.matvec(&e);
            let mut b = model.w.matvec(&ku);
            for v in b.iter_mut() {
                *v *= sf2;
            }
            let mut z = vec![0.0; n];
            {
                let this: &MsgpModel = &model;
                let mut apply = |v: &[f64], out: &mut [f64]| {
                    let av = this.mvm_a(v);
                    out.copy_from_slice(&av);
                };
                cg_solve(
                    &mut apply,
                    |v, out| out.copy_from_slice(v),
                    &b,
                    &mut z,
                    model.cfg.cg,
                    &mut ws,
                );
            }
            det[j] = crate::linalg::dense::dot(&b, &z);
        }
        // Compare on the interior (boundary grid cells see no data).
        let lo = m / 8;
        let hi = m - m / 8;
        let num: f64 = (lo..hi).map(|j| (est[j] - det[j]).powi(2)).sum::<f64>().sqrt();
        let den: f64 = (lo..hi).map(|j| det[j].powi(2)).sum::<f64>().sqrt();
        assert!(num / den < 0.15, "rel err {}", num / den);
    }

    #[test]
    fn logdet_close_to_exact_logdet() {
        let n = 300;
        let data = gen_stress_1d(n, 0.05, 21);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
        let model = MsgpModel::fit(
            KernelSpec::Product(kernel.clone()),
            0.05,
            data.clone(),
            cfg_1d(600),
        )
        .unwrap();
        let approx = model.logdet();
        let mut kmat = Mat::from_fn(n, n, |i, j| kernel.eval(data.row(i), data.row(j)));
        for i in 0..n {
            kmat[(i, i)] += 0.05;
        }
        let exact = crate::linalg::cholesky::Chol::new(&kmat).unwrap().logdet();
        let rel = (approx - exact).abs() / exact.abs();
        assert!(rel < 0.15, "logdet rel err {rel} ({approx} vs {exact})");
    }

    #[test]
    fn lml_grad_matches_finite_differences() {
        let n = 150;
        let data = gen_stress_1d(n, 0.1, 31);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.2, 0.8));
        let mut model = MsgpModel::fit(kernel, 0.05, data, cfg_1d(128)).unwrap();
        model.cfg.cg = CgOptions { tol: 1e-12, max_iter: 3000, ..Default::default() };
        model.refit(&model.params().clone()).unwrap();
        let g = model.lml_grad();
        let p0 = model.params();
        let fd = crate::opt::fd_gradient(
            |p| {
                model.refit(p).unwrap();
                model.lml()
            },
            &p0,
            1e-5,
        );
        for (i, (a, b)) in g.grad.iter().zip(&fd).enumerate() {
            assert!(
                (a - b).abs() < 2e-3 * (1.0 + b.abs()),
                "param {i}: analytic {a} vs fd {b}"
            );
        }
    }

    #[test]
    fn training_improves_lml_and_fit() {
        let data = gen_stress_1d(400, 0.05, 5);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 0.3, 0.4));
        let mut model = MsgpModel::fit(kernel, 0.1, data, cfg_1d(256)).unwrap();
        let before = model.lml();
        let trace = model.train(25, 0.1).unwrap();
        assert!(model.lml() > before, "{} !> {before}", model.lml());
        assert!(trace.len() == 25);
        // Prediction quality on held-out points.
        let test = gen_stress_1d(200, 0.0, 77);
        let pred = model.predict_mean(&test.x);
        let err = smae(&pred, &test.y);
        assert!(err < 0.2, "SMAE {err}");
    }

    #[test]
    fn bttb_model_fits_2d_data() {
        let data = gen_stress_2d(300, 0.05, 6);
        let kernel = KernelSpec::Iso {
            ktype: KernelType::SE,
            log_ell: 1.0f64.ln(),
            log_sf2: 0.0,
            dim: 2,
        };
        let cfg = MsgpConfig { n_per_dim: vec![48, 48], ..Default::default() };
        let model = MsgpModel::fit(kernel, 0.01, data.clone(), cfg).unwrap();
        let pred = model.predict_mean(&data.x);
        let err = smae(&pred, &data.y);
        assert!(err < 0.35, "train SMAE {err}");
    }

    #[test]
    fn bttb_grad_matches_fd() {
        let data = gen_stress_2d(120, 0.1, 8);
        let kernel = KernelSpec::Iso {
            ktype: KernelType::SE,
            log_ell: 0.9f64.ln(),
            log_sf2: (0.7f64).ln(),
            dim: 2,
        };
        let cfg = MsgpConfig {
            n_per_dim: vec![24, 24],
            cg: CgOptions { tol: 1e-12, max_iter: 3000, ..Default::default() },
            ..Default::default()
        };
        let mut model = MsgpModel::fit(kernel, 0.05, data, cfg).unwrap();
        let g = model.lml_grad();
        let p0 = model.params();
        let fd = crate::opt::fd_gradient(
            |p| {
                model.refit(p).unwrap();
                model.lml()
            },
            &p0,
            1e-5,
        );
        for (i, (a, b)) in g.grad.iter().zip(&fd).enumerate() {
            assert!(
                (a - b).abs() < 5e-3 * (1.0 + b.abs()),
                "param {i}: analytic {a} vs fd {b}"
            );
        }
    }

    /// Acceptance (satellite): the multi-output block fit matches
    /// independent per-target fits on the shared grid — same alphas,
    /// same fast-mean caches — while running ONE compacted block solve
    /// (operator-work accounting strictly below the uncompacted
    /// lockstep whenever targets converge unevenly).
    #[test]
    fn fit_multi_matches_per_target_fits() {
        let n = 250;
        let data = gen_stress_1d(n, 0.05, 23);
        // Three outputs over the same inputs with different structure.
        let y0 = data.y.clone();
        let y1: Vec<f64> = data
            .x
            .iter()
            .map(|&x| (0.7 * x).cos() * 0.8 + 0.1)
            .collect();
        let y2: Vec<f64> = data.x.iter().map(|&x| 0.5 * (0.3 * x).sin() - 0.2).collect();
        let targets = vec![y0, y1, y2];
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let cfg = MsgpConfig {
            n_per_dim: vec![128],
            cg: CgOptions { tol: 1e-10, max_iter: 3000, ..Default::default() },
            ..Default::default()
        };
        let (model, multi) =
            MsgpModel::fit_multi(kernel.clone(), 0.01, data.x.clone(), 1, &targets, cfg.clone())
                .unwrap();
        assert!(multi.block.converged, "{:?}", multi.block.rel_residuals);
        assert_eq!(multi.alphas.len(), targets.len());
        assert_eq!(multi.block.col_iters.len(), targets.len());
        // Operator-work accounting: never more than the uncompacted
        // lockstep block.
        assert!(multi.block.apply_cols <= (multi.block.block_iters + 1) * targets.len());
        // Per-target reference fits on the identical grid.
        for (c, y) in targets.iter().enumerate() {
            let single = MsgpModel::fit_with_grid(
                kernel.clone(),
                0.01,
                Dataset { x: data.x.clone(), d: 1, y: y.clone() },
                model.grid.clone(),
                cfg.clone(),
            )
            .unwrap();
            for (a, b) in multi.alphas[c].iter().zip(&single.alpha) {
                assert!((a - b).abs() < 1e-6, "output {c} alpha: {a} vs {b}");
            }
            for (a, b) in multi.u_means[c].iter().zip(&single.u_mean) {
                assert!((a - b).abs() < 1e-6, "output {c} u_mean: {a} vs {b}");
            }
        }
        // The returned model carries output 0's caches.
        for (a, b) in model.alpha.iter().zip(&multi.alphas[0]) {
            assert!((a - b).abs() == 0.0, "{a} vs {b}");
        }
    }

    #[test]
    fn toeplitz_ablation_agrees_with_circulant_at_large_m() {
        let data = gen_stress_1d(200, 0.05, 13);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let circ = MsgpModel::fit(kernel.clone(), 0.05, data.clone(), cfg_1d(256)).unwrap();
        let mut cfg = cfg_1d(256);
        cfg.logdet = LogdetMethod::ToeplitzExact;
        let toep = MsgpModel::fit(kernel, 0.05, data, cfg).unwrap();
        let a = circ.logdet();
        let b = toep.logdet();
        assert!((a - b).abs() / b.abs() < 0.05, "{a} vs {b}");
    }

    #[test]
    fn unit_scale_rows_have_unit_norm() {
        let p = Mat::from_vec(2, 3, vec![3.0, 4.0, 0.0, 1.0, 1.0, 1.0]);
        let q = unit_scale(&p);
        for r in 0..2 {
            let n2: f64 = q.row(r).iter().map(|v| v * v).sum();
            assert!((n2 - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn unit_scale_chain_matches_fd() {
        let p = Mat::from_vec(2, 3, vec![0.5, -1.0, 2.0, 1.5, 0.3, -0.7]);
        // psi(Q) = sum of Q element squares weighted (arbitrary smooth fn).
        let weights: Vec<f64> = (0..6).map(|i| (i as f64 + 1.0) * 0.3).collect();
        let psi = |pm: &Mat| -> f64 {
            let q = unit_scale(pm);
            q.data.iter().zip(&weights).map(|(v, w)| v * v * w + v.sin() * 0.1).sum()
        };
        // dpsi/dQ at Q(P):
        let q = unit_scale(&p);
        let g_q = Mat::from_vec(
            2,
            3,
            q.data
                .iter()
                .zip(&weights)
                .map(|(v, w)| 2.0 * v * w + v.cos() * 0.1)
                .collect(),
        );
        let an = unit_scale_chain(&p, &g_q);
        for idx in 0..6 {
            let eps = 1e-6;
            let mut pp = p.clone();
            pp.data[idx] += eps;
            let mut pm = p.clone();
            pm.data[idx] -= eps;
            let fd = (psi(&pp) - psi(&pm)) / (2.0 * eps);
            assert!((an.data[idx] - fd).abs() < 1e-6, "{idx}: {} vs {fd}", an.data[idx]);
        }
    }

    #[test]
    fn subspace_dist_identical_and_orthogonal() {
        let p = Mat::from_vec(2, 4, vec![1., 0., 0., 0., 0., 1., 0., 0.]);
        assert!(subspace_dist(&p, &p) < 1e-10);
        let q = Mat::from_vec(2, 4, vec![0., 0., 1., 0., 0., 0., 0., 1.]);
        assert!((subspace_dist(&p, &q) - 1.0).abs() < 1e-10);
        // Invariance to row scaling and mixing.
        let mixed = Mat::from_vec(2, 4, vec![2., 1., 0., 0., -1., 3., 0., 0.]);
        assert!(subspace_dist(&p, &mixed) < 1e-10);
    }

    #[test]
    fn proj_grad_p_matches_fd() {
        use crate::data::gen_projection_data;
        let kern = ProductKernel::iso(KernelType::SE, 2, 0.8, 1.0);
        let pd = gen_projection_data(80, 5, 2, &kern, 0.1, 17);
        let p0 = {
            let mut rng = Rng::new(3);
            crate::data::randn_mat(2, 5, &mut rng)
        };
        let cfg = MsgpConfig {
            n_per_dim: vec![24, 24],
            cg: CgOptions { tol: 1e-12, max_iter: 3000, ..Default::default() },
            ..Default::default()
        };
        // Hold the grid fixed across FD perturbations (it is fixed during
        // training too).
        let base =
            ProjMsgp::fit(p0.clone(), kern.clone(), 0.05, pd.data.clone(), cfg.clone()).unwrap();
        let grid = base.grid.clone();
        let an = base.grad_p();
        for &idx in &[0usize, 3, 7, 9] {
            let eps = 1e-5;
            let mut pp = p0.clone();
            pp.data[idx] += eps;
            let lp = ProjMsgp::fit_with_grid(
                pp,
                kern.clone(),
                0.05,
                pd.data.clone(),
                grid.clone(),
                cfg.clone(),
            )
            .unwrap()
            .model
            .lml();
            let mut pm2 = p0.clone();
            pm2.data[idx] -= eps;
            let lm = ProjMsgp::fit_with_grid(
                pm2,
                kern.clone(),
                0.05,
                pd.data.clone(),
                grid.clone(),
                cfg.clone(),
            )
            .unwrap()
            .model
            .lml();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (an.data[idx] - fd).abs() < 0.05 * (1.0 + fd.abs()),
                "entry {idx}: analytic {} vs fd {fd}",
                an.data[idx]
            );
        }
    }
}
