//! Exact Gaussian-process regression (paper section 2): dense Cholesky
//! inference, analytic marginal-likelihood gradients, and the standard
//! O(n) / O(n^2) per-test-point predictive equations.
//!
//! This is the gold-standard baseline for the accuracy comparisons
//! (Figure 4) and the `GP Full` / `GP True` lines of Figure 5; its cubic
//! cost is exactly what MSGP removes.

use crate::data::Dataset;
use crate::kernels::ProductKernel;
use crate::linalg::cholesky::Chol;
use crate::linalg::Mat;

/// A trained exact GP.
pub struct ExactGp {
    /// Kernel (hyperparameters live here).
    pub kernel: ProductKernel,
    /// Noise variance.
    pub sigma2: f64,
    /// Training data.
    pub data: Dataset,
    chol: Chol,
    alpha: Vec<f64>,
}

/// Marginal likelihood value and gradient.
#[derive(Clone, Debug)]
pub struct NlmlGrad {
    /// Log marginal likelihood (Eq. 3, including the `-n/2 log 2 pi` term).
    pub lml: f64,
    /// Gradient with respect to `[log_ell.., log_sf2, log_sigma2]`.
    pub grad: Vec<f64>,
}

impl ExactGp {
    /// Factor the training covariance and precompute `alpha`.
    pub fn fit(kernel: ProductKernel, sigma2: f64, data: Dataset) -> anyhow::Result<Self> {
        let n = data.n();
        let d = data.d;
        assert_eq!(kernel.dim(), d, "kernel dim vs data dim");
        let mut k = Mat::from_fn(n, n, |i, j| kernel.eval(data.row(i), data.row(j)));
        for i in 0..n {
            k[(i, i)] += sigma2;
        }
        let chol = Chol::new(&k).ok_or_else(|| anyhow::anyhow!("K + sigma2 I not PD"))?;
        let alpha = chol.solve(&data.y);
        Ok(ExactGp { kernel, sigma2, data, chol, alpha })
    }

    /// Log marginal likelihood of the training targets.
    pub fn lml(&self) -> f64 {
        let n = self.data.n() as f64;
        let fit: f64 = self.data.y.iter().zip(&self.alpha).map(|(y, a)| y * a).sum();
        -0.5 * (fit + self.chol.logdet() + n * (2.0 * std::f64::consts::PI).ln())
    }

    /// Log marginal likelihood and its analytic gradient with respect to
    /// `[log_ell_1..log_ell_D, log_sf2, log_sigma2]`.
    ///
    /// `d lml/d theta = 1/2 alpha^T dK alpha - 1/2 tr(K^{-1} dK)`; the trace
    /// uses the explicit inverse, keeping the O(n^3) cost the paper times
    /// in Figure 2.
    pub fn lml_grad(&self) -> NlmlGrad {
        let n = self.data.n();
        let d = self.data.d;
        let kinv = self.chol.inverse();
        let mut grad = vec![0.0; d + 2];
        // Per-dimension lengthscales.
        for p in 0..d {
            let mut quad = 0.0;
            let mut tr = 0.0;
            for i in 0..n {
                for j in 0..n {
                    let xi = self.data.row(i);
                    let xj = self.data.row(j);
                    // dK_ij/dlog ell_p = sf2 * dcorr_p * prod_{q != p} corr_q
                    let mut v = self.kernel.sf2();
                    for q in 0..d {
                        let r = xi[q] - xj[q];
                        if q == p {
                            v *= self.kernel.types[q].dcorr_dlog_ell(r, self.kernel.ell(q));
                        } else {
                            v *= self.kernel.corr_d(q, r);
                        }
                    }
                    quad += self.alpha[i] * v * self.alpha[j];
                    tr += kinv[(i, j)] * v;
                }
            }
            grad[p] = 0.5 * quad - 0.5 * tr;
        }
        // Signal variance: dK/dlog sf2 = K_f (noise-free kernel).
        let mut quad = 0.0;
        let mut tr = 0.0;
        for i in 0..n {
            for j in 0..n {
                let v = self.kernel.eval(self.data.row(i), self.data.row(j));
                quad += self.alpha[i] * v * self.alpha[j];
                tr += kinv[(i, j)] * v;
            }
        }
        grad[d] = 0.5 * quad - 0.5 * tr;
        // Noise: dK/dlog sigma2 = sigma2 I.
        let mut quad_n = 0.0;
        let mut tr_n = 0.0;
        for i in 0..n {
            quad_n += self.alpha[i] * self.alpha[i];
            tr_n += kinv[(i, i)];
        }
        grad[d + 1] = 0.5 * self.sigma2 * (quad_n - tr_n);
        NlmlGrad { lml: self.lml(), grad }
    }

    /// Predictive mean at test inputs (row-major `n* x d`): O(n) each.
    pub fn predict_mean(&self, xs: &[f64]) -> Vec<f64> {
        let d = self.data.d;
        let ns = xs.len() / d;
        let n = self.data.n();
        let mut out = vec![0.0; ns];
        for (s, o) in out.iter_mut().enumerate() {
            let xstar = &xs[s * d..(s + 1) * d];
            let mut acc = 0.0;
            for i in 0..n {
                acc += self.kernel.eval(xstar, self.data.row(i)) * self.alpha[i];
            }
            *o = acc;
        }
        out
    }

    /// Predictive latent variance at test inputs: O(n^2) each.
    pub fn predict_var(&self, xs: &[f64]) -> Vec<f64> {
        let d = self.data.d;
        let ns = xs.len() / d;
        let n = self.data.n();
        let mut out = vec![0.0; ns];
        let mut kx = vec![0.0; n];
        for (s, o) in out.iter_mut().enumerate() {
            let xstar = &xs[s * d..(s + 1) * d];
            for i in 0..n {
                kx[i] = self.kernel.eval(xstar, self.data.row(i));
            }
            let v = self.chol.solve(&kx);
            let explained: f64 = kx.iter().zip(&v).map(|(a, b)| a * b).sum();
            *o = (self.kernel.sf2() - explained).max(0.0);
        }
        out
    }

    /// Hyperparameters as a flat vector `[log_ell.., log_sf2, log_sigma2]`.
    pub fn params(&self) -> Vec<f64> {
        let mut p = self.kernel.params();
        p.push(self.sigma2.ln());
        p
    }

    /// Refit with new hyperparameters (same data).
    pub fn refit(self, params: &[f64]) -> anyhow::Result<Self> {
        let mut kernel = self.kernel;
        let d = kernel.dim();
        kernel.set_params(&params[..d + 1]);
        let sigma2 = params[d + 1].exp();
        ExactGp::fit(kernel, sigma2, self.data)
    }
}

/// Train an exact GP by Adam ascent on the marginal likelihood.
pub fn train_exact(
    kernel: ProductKernel,
    sigma2: f64,
    data: Dataset,
    iters: usize,
    lr: f64,
) -> anyhow::Result<ExactGp> {
    let mut gp = ExactGp::fit(kernel, sigma2, data)?;
    let mut params = gp.params();
    let mut opt = crate::opt::Adam::new(params.len(), lr);
    for _ in 0..iters {
        let g = gp.lml_grad();
        opt.step(&mut params, &g.grad);
        gp = gp.refit(&params)?;
    }
    Ok(gp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_stress_1d;
    use crate::kernels::KernelType;

    fn small_gp() -> ExactGp {
        let data = gen_stress_1d(60, 0.05, 3);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
        ExactGp::fit(kernel, 0.01, data).unwrap()
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let gp = small_gp();
        let g = gp.lml_grad();
        let p0 = gp.params();
        let data = gp.data.clone();
        let f = |params: &[f64]| {
            let mut k = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
            k.set_params(&params[..2]);
            ExactGp::fit(k, params[2].exp(), data.clone()).unwrap().lml()
        };
        let fd = crate::opt::fd_gradient(f, &p0, 1e-5);
        for (a, b) in g.grad.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn interpolates_training_data_with_small_noise() {
        let gp = small_gp();
        let pred = gp.predict_mean(&gp.data.x);
        let err: f64 = pred
            .iter()
            .zip(&gp.data.y)
            .map(|(p, y)| (p - y).abs())
            .sum::<f64>()
            / pred.len() as f64;
        assert!(err < 0.05, "mean abs err {err}");
    }

    #[test]
    fn variance_shrinks_near_data() {
        let gp = small_gp();
        let near = gp.predict_var(&[gp.data.x[0]])[0];
        let far = gp.predict_var(&[55.0])[0];
        assert!(near < 0.05 * far, "near {near} far {far}");
        // Far from data the latent variance approaches sf2.
        assert!((far - gp.kernel.sf2()).abs() < 1e-3);
    }

    #[test]
    fn training_improves_lml() {
        let data = gen_stress_1d(50, 0.05, 9);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 0.3, 0.5);
        let before = ExactGp::fit(kernel.clone(), 0.05, data.clone()).unwrap().lml();
        let gp = train_exact(kernel, 0.05, data, 30, 0.08).unwrap();
        assert!(gp.lml() > before, "{} !> {before}", gp.lml());
    }
}
