//! Sparse Spectrum GP (Lázaro-Gredilla et al., 2010) — the finite-basis
//! baseline of Figures 2–3.
//!
//! The kernel is approximated by `m/2` random spectral frequencies
//! `w_r ~ N(0, diag(1/ell^2))`, giving the feature map
//! `phi(x) = sqrt(sf2 / (m/2)) [cos(w_r^T x); sin(w_r^T x)]_r` and a
//! Bayesian linear model whose evidence needs an `m x m` solve:
//! O(n m^2) training, O(m)/O(m^2) per-test-point predictions.

use crate::data::Dataset;
use crate::kernels::ProductKernel;
use crate::linalg::cholesky::Chol;
use crate::linalg::Mat;
use crate::util::Rng;

/// A fitted sparse-spectrum GP.
pub struct Ssgp {
    /// Kernel whose spectrum is sampled.
    pub kernel: ProductKernel,
    /// Noise variance.
    pub sigma2: f64,
    /// Spectral frequencies, row-major `(m/2) x d` (unit-lengthscale;
    /// scaled by `1/ell` at feature time so hypers can change without
    /// resampling).
    pub freqs: Vec<f64>,
    /// Training data.
    pub data: Dataset,
    /// Cholesky of `Phi^T Phi + sigma2 I` (m x m).
    chol: Chol,
    /// Posterior weight mean (m).
    wmean: Vec<f64>,
    /// Cached LML.
    lml: f64,
}

impl Ssgp {
    /// Number of basis functions (2 x number of frequencies).
    pub fn m(&self) -> usize {
        2 * self.freqs.len() / self.data.d
    }

    /// Sample `m/2` frequencies and fit.
    pub fn fit(
        kernel: ProductKernel,
        sigma2: f64,
        data: Dataset,
        m: usize,
        seed: u64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(m % 2 == 0 && m >= 2, "m must be even");
        let d = data.d;
        let mut rng = Rng::new(seed);
        let freqs = rng.normal_vec(m / 2 * d);
        Self::fit_with_freqs(kernel, sigma2, data, freqs)
    }

    /// Fit with fixed (unit-lengthscale) frequencies.
    pub fn fit_with_freqs(
        kernel: ProductKernel,
        sigma2: f64,
        data: Dataset,
        freqs: Vec<f64>,
    ) -> anyhow::Result<Self> {
        let d = data.d;
        let n = data.n();
        let half = freqs.len() / d;
        let m = 2 * half;
        // Phi: n x m.
        let phi = features(&kernel, &freqs, &data.x, d);
        // A = Phi^T Phi + sigma2 I (scaled formulation: weights have unit
        // prior; the sf2/(m/2) scaling is inside phi).
        let mut a = Mat::zeros(m, m);
        for i in 0..n {
            let row = phi.row(i);
            for p in 0..m {
                let rp = row[p];
                if rp == 0.0 {
                    continue;
                }
                for q in p..m {
                    a[(p, q)] += rp * row[q];
                }
            }
        }
        for p in 0..m {
            for q in 0..p {
                a[(p, q)] = a[(q, p)];
            }
            a[(p, p)] += sigma2;
        }
        let chol = Chol::new(&a).ok_or_else(|| anyhow::anyhow!("SSGP A not PD"))?;
        let phity = phi.tmatvec(&data.y);
        let wmean = chol.solve(&phity);
        // Evidence (Lázaro-Gredilla Eq. 10):
        // lml = -1/2sigma2 (y^T y - y^T Phi A^{-1} Phi^T y)
        //       - 1/2 log|A| + m/2 log sigma2 - n/2 log(2 pi sigma2)
        let yty: f64 = data.y.iter().map(|v| v * v).sum();
        let expl: f64 = phity.iter().zip(&wmean).map(|(a, b)| a * b).sum();
        let lml = -0.5 / sigma2 * (yty - expl) - 0.5 * chol.logdet()
            + 0.5 * m as f64 * sigma2.ln()
            - 0.5 * n as f64 * (2.0 * std::f64::consts::PI * sigma2).ln();
        Ok(Ssgp { kernel, sigma2, freqs, data, chol, wmean, lml })
    }

    /// Log marginal likelihood (evidence).
    pub fn lml(&self) -> f64 {
        self.lml
    }

    /// LML + finite-difference gradient over `[log_ell.., log_sf2,
    /// log_sigma2]`, holding the sampled frequencies fixed (as the SSGP
    /// paper does during optimization).
    pub fn lml_fd_grad(&self) -> super::exact::NlmlGrad {
        let mut p0 = self.kernel.params();
        p0.push(self.sigma2.ln());
        let grad = crate::opt::fd_gradient(
            |p| {
                let mut k = self.kernel.clone();
                let nk = k.n_params();
                k.set_params(&p[..nk]);
                Ssgp::fit_with_freqs(k, p[nk].exp(), self.data.clone(), self.freqs.clone())
                    .map(|s| s.lml())
                    .unwrap_or(f64::NEG_INFINITY)
            },
            &p0,
            1e-5,
        );
        super::exact::NlmlGrad { lml: self.lml, grad }
    }

    /// Predictive mean: O(m) per point.
    pub fn predict_mean(&self, xs: &[f64]) -> Vec<f64> {
        let phi = features(&self.kernel, &self.freqs, xs, self.data.d);
        phi.matvec(&self.wmean)
    }

    /// Latent predictive variance: O(m^2) per point.
    pub fn predict_var(&self, xs: &[f64]) -> Vec<f64> {
        let phi = features(&self.kernel, &self.freqs, xs, self.data.d);
        let ns = phi.rows;
        let mut out = vec![0.0; ns];
        for s in 0..ns {
            let row = phi.row(s);
            let ainv_row = self.chol.solve(row);
            let v: f64 = row.iter().zip(&ainv_row).map(|(a, b)| a * b).sum();
            out[s] = (self.sigma2 * v).max(0.0);
        }
        out
    }
}

/// Feature matrix `Phi` (`n x m`): scaled cos/sin pairs of the projected
/// frequencies. Lengthscales divide the frequencies; `sqrt(sf2/(m/2))`
/// scales the amplitude so `phi(x)^T phi(x') ~ k(x, x')`.
fn features(kernel: &ProductKernel, freqs: &[f64], xs: &[f64], d: usize) -> Mat {
    let half = freqs.len() / d;
    let m = 2 * half;
    let n = xs.len() / d;
    let amp = (kernel.sf2() / half as f64).sqrt();
    let ells: Vec<f64> = (0..d).map(|p| kernel.ell(p)).collect();
    let mut phi = Mat::zeros(n, m);
    for i in 0..n {
        let x = &xs[i * d..(i + 1) * d];
        for r in 0..half {
            let mut arg = 0.0;
            for p in 0..d {
                arg += freqs[r * d + p] / ells[p] * x[p];
            }
            phi[(i, 2 * r)] = amp * arg.cos();
            phi[(i, 2 * r + 1)] = amp * arg.sin();
        }
    }
    phi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_stress_1d, smae};
    use crate::gp::exact::ExactGp;
    use crate::kernels::KernelType;

    #[test]
    fn feature_covariance_approximates_kernel() {
        // phi(x)^T phi(z) -> k(x, z) as m grows (Monte Carlo average of
        // cos(w^T(x - z)) over w ~ N(0, 1/ell^2)).
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.3, 0.9);
        let mut rng = Rng::new(5);
        let freqs = rng.normal_vec(4000);
        let xs = [0.0f64, 0.7, 2.0];
        let phi = features(&kernel, &freqs, &xs, 1);
        for i in 0..3 {
            for j in 0..3 {
                let approx: f64 =
                    phi.row(i).iter().zip(phi.row(j)).map(|(a, b)| a * b).sum();
                let exact = kernel.eval(&xs[i..i + 1], &xs[j..j + 1]);
                assert!((approx - exact).abs() < 0.05, "({i},{j}): {approx} vs {exact}");
            }
        }
    }

    #[test]
    fn large_m_matches_exact_gp_predictions() {
        let data = gen_stress_1d(150, 0.05, 8);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
        let ssgp = Ssgp::fit(kernel.clone(), 0.01, data.clone(), 400, 11).unwrap();
        let exact = ExactGp::fit(kernel, 0.01, data).unwrap();
        let xs: Vec<f64> = (0..80).map(|i| -9.0 + 0.225 * i as f64).collect();
        let ps = ssgp.predict_mean(&xs);
        let pe = exact.predict_mean(&xs);
        assert!(smae(&ps, &pe) < 0.1, "smae {}", smae(&ps, &pe));
    }

    #[test]
    fn lml_is_finite_and_grad_ascendable() {
        let data = gen_stress_1d(120, 0.1, 4);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 0.6, 0.7);
        let ssgp = Ssgp::fit(kernel, 0.05, data, 100, 2).unwrap();
        assert!(ssgp.lml().is_finite());
        let g = ssgp.lml_fd_grad();
        assert!(g.grad.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn variance_grows_away_from_data() {
        let data = gen_stress_1d(200, 0.05, 6);
        let kernel = ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0);
        let ssgp = Ssgp::fit(kernel, 0.01, data, 200, 3).unwrap();
        let near = ssgp.predict_var(&[0.0])[0];
        // SSGP is periodic-ish far away, so compare against a moderately
        // extrapolated point rather than a far one.
        let off = ssgp.predict_var(&[14.0])[0];
        assert!(off > near, "off {off} near {near}");
    }
}
