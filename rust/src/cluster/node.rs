//! One cluster node: shard ownership, local ingest with delta cuts,
//! frame receive/apply with epoch idempotency, catch-up after restart,
//! and always-local serving from the merged replica view.
//!
//! Threads per node (all supervised, all bounded-wait):
//!
//! * **listener** — non-blocking accept loop; one receive thread per
//!   inbound peer connection.
//! * **sender ×(nodes-1)** — see [`super::peer`]; owns the outbound
//!   connection and its bounded queue.
//! * **monitor** — 20 ms tick: peer liveness gauges, deadline-driven
//!   delta cuts when ingest idles, the recovery watchdog, and the
//!   merge-and-publish of a fresh [`ServingModel`] whenever statistics
//!   changed (panic-isolated behind the restart supervisor).

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::{diff_ski, peer, ClusterConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::state::{ModelSlot, ServingModel};
use crate::fault::codec::{load_newest, read_frame, write_atomic, Checkpoint, CkptTrigger, Frame};
use crate::fault::{Supervisor, SupervisorPolicy, Verdict};
use crate::gp::msgp::KernelSpec;
use crate::obs::now_us;
use crate::shard::{merge_owned, ShardPlan};
use crate::stream::{IncrementalSki, StreamConfig, StreamTrainer};
use crate::util::json::Json;

/// Outbound frame queue for one peer: a bounded channel plus the
/// overflow/loss flag that forces the sender into a full resync.
pub(crate) struct OutQueue {
    pub(crate) tx: SyncSender<Arc<Vec<u8>>>,
    /// Set by enqueue overflow (frames were dropped) — the sender must
    /// reconnect and replay full state before trusting deltas again.
    pub(crate) needs_resync: Arc<AtomicBool>,
    /// Frames currently queued (mirrored into the `peer_queue_depth`
    /// gauge by the monitor).
    pub(crate) depth: Arc<AtomicU64>,
}

/// One shard this node owns: the live accumulator plus the snapshot at
/// the last cut (`prev`), whose difference is the next shipped delta.
pub(crate) struct OwnedShard {
    pub(crate) shard: usize,
    pub(crate) ski: IncrementalSki,
    pub(crate) prev: IncrementalSki,
    /// Epoch of the newest state adopted for this shard during
    /// catch-up (checkpoint seq at restore time).
    pub(crate) synced_epoch: u64,
}

/// Everything guarded by the `owned` lock (rank 12 — see
/// `analysis::LOCK_ORDER`).
pub(crate) struct OwnedState {
    /// Owned shards in ascending shard-id order.
    pub(crate) skis: Vec<OwnedShard>,
    pub(crate) points_since_cut: usize,
    pub(crate) last_cut: Instant,
    pub(crate) ckpt_trigger: CkptTrigger,
}

/// Replica of a foreign shard, advanced idempotently by epoch.
pub(crate) struct Replica {
    pub(crate) ski: IncrementalSki,
    /// Watermark: the owner's cut epoch this replica has applied
    /// through. Frames at or below it are ignored.
    pub(crate) epoch: u64,
    pub(crate) updated_at_us: u64,
}

/// Everything guarded by the `replicas` lock (rank 16).
#[derive(Default)]
pub(crate) struct ReplicaTable {
    /// Foreign shard id -> replica.
    pub(crate) map: HashMap<usize, Replica>,
}

/// State shared by every thread of one cluster node.
pub(crate) struct Shared {
    pub(crate) cfg: ClusterConfig,
    pub(crate) kernel: KernelSpec,
    pub(crate) sigma2: f64,
    pub(crate) stream: StreamConfig,
    pub(crate) plan: ShardPlan,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) slot: Arc<ModelSlot>,
    /// Lock rank 12.
    pub(crate) owned: Mutex<OwnedState>,
    /// Lock rank 16.
    pub(crate) replicas: Mutex<ReplicaTable>,
    /// Outbound queue per node id (`None` at our own index).
    pub(crate) outs: Vec<Option<OutQueue>>,
    /// Last traffic from each node (µs since trace epoch; 0 = never).
    pub(crate) last_seen_us: Vec<AtomicU64>,
    /// Node-wide cut epoch, stamped into every shipped frame.
    pub(crate) epoch: AtomicU64,
    /// Statistics changed since the last publish.
    pub(crate) dirty: AtomicBool,
    pub(crate) quit: AtomicBool,
    pub(crate) started: Instant,
}

impl Shared {
    pub(crate) fn nodes(&self) -> usize {
        self.cfg.nodes()
    }

    fn note_seen(&self, node: usize) {
        if node < self.last_seen_us.len() {
            self.last_seen_us[node].store(now_us().max(1), Ordering::Relaxed);
        }
    }

    /// Has `node` produced traffic within the liveness window
    /// (4 heartbeat intervals)?
    pub(crate) fn peer_is_up(&self, node: usize) -> bool {
        if node == self.cfg.node_id {
            return true;
        }
        let seen = self.last_seen_us[node].load(Ordering::Relaxed);
        seen != 0 && now_us().saturating_sub(seen) < 4 * self.cfg.hb_ms * 1000
    }

    /// Queue `bytes` toward `node`. Overflow drops the frame and flags
    /// the sender for a reconnect-with-resync — bounded memory beats a
    /// perfect stream, and the resync repairs the loss.
    pub(crate) fn enqueue_to(&self, node: usize, bytes: Arc<Vec<u8>>) {
        if let Some(out) = &self.outs[node] {
            match out.tx.try_send(bytes) {
                Ok(()) => {
                    out.depth.fetch_add(1, Ordering::Relaxed);
                }
                Err(TrySendError::Full(_)) => {
                    out.needs_resync.store(true, Ordering::Relaxed);
                    self.metrics.peers[node].send_errors.inc();
                }
                Err(TrySendError::Disconnected(_)) => {}
            }
        }
    }

    fn broadcast(&self, bytes: Arc<Vec<u8>>) {
        for p in 0..self.nodes() {
            if p != self.cfg.node_id {
                self.enqueue_to(p, bytes.clone());
            }
        }
    }

    /// Full-state frames for every owned shard at the current epoch —
    /// what a (re)connecting sender replays before any delta.
    pub(crate) fn snapshot_owned_fulls(&self) -> Vec<Arc<Vec<u8>>> {
        let origin = self.cfg.node_id as u32;
        let owned = self.owned.lock().unwrap_or_else(|e| e.into_inner());
        // The epoch must be read under the `owned` lock (it is only
        // advanced while `owned` is held): a load taken before the lock
        // could stamp this Full older than the state it snapshots, and
        // the concurrently cut delta at the newer epoch would then pass
        // the receiver's watermark and be double-applied.
        let epoch = self.epoch.load(Ordering::Relaxed);
        owned
            .skis
            .iter()
            .map(|os| {
                Arc::new(
                    Frame::Full {
                        origin,
                        shard: os.shard as u32,
                        epoch,
                        ski: Box::new(os.ski.clone()),
                    }
                    .encode(),
                )
            })
            .collect()
    }

    /// Answer a `SyncRequest`: our owned shards at the current epoch,
    /// every replica we hold at its watermark (stamped with the true
    /// owner as origin, so a rejoining node recovers shards whose owner
    /// is still down), and a terminating `SyncDone`.
    fn answer_sync_request(&self, requester: usize) {
        let mut frames = self.snapshot_owned_fulls();
        {
            let reps = self.replicas.lock().unwrap_or_else(|e| e.into_inner());
            for (&s, rep) in reps.map.iter() {
                frames.push(Arc::new(
                    Frame::Full {
                        origin: self.plan.node_of(s, self.nodes()) as u32,
                        shard: s as u32,
                        epoch: rep.epoch,
                        ski: Box::new(rep.ski.clone()),
                    }
                    .encode(),
                ));
            }
        }
        let n = frames.len() as u32;
        frames.push(Arc::new(
            Frame::SyncDone { node: self.cfg.node_id as u32, shards: n }.encode(),
        ));
        for f in frames {
            self.enqueue_to(requester, f);
        }
    }

    /// Apply one received frame. `from` is the connection's peer id
    /// (learned from `Hello`). An `Err` closes the connection, which
    /// forces the sending side into reconnect + full resync — the
    /// repair path for any lost or unorderable frame.
    pub(crate) fn on_frame(&self, frame: Frame, from: &mut Option<u32>) -> Result<(), String> {
        self.metrics.peer_frames_recv_total.inc();
        if let Some(f) = *from {
            self.note_seen(f as usize);
        }
        match frame {
            Frame::Hello { node } => {
                if node as usize >= self.nodes() {
                    return Err(format!("hello from unknown node {node}"));
                }
                *from = Some(node);
                self.note_seen(node as usize);
                Ok(())
            }
            Frame::Heartbeat { node } => {
                self.note_seen(node as usize);
                self.metrics.peer_heartbeats_total.inc();
                Ok(())
            }
            Frame::Delta { origin, shard, epoch, ski } => {
                self.apply_delta(origin as usize, shard as usize, epoch, *ski)
            }
            Frame::Full { origin, shard, epoch, ski } => {
                self.apply_full(origin as usize, shard as usize, epoch, *ski)
            }
            Frame::SyncRequest { node } => {
                if node as usize >= self.nodes() {
                    return Err(format!("sync request from unknown node {node}"));
                }
                self.answer_sync_request(node as usize);
                Ok(())
            }
            Frame::SyncDone { node, shards } => {
                if self.metrics.recovering.get() == 1 {
                    self.metrics.recovering.store(0, Ordering::Relaxed);
                    crate::log_info!(
                        "cluster node {}: catch-up complete ({shards} shards from node {node})",
                        self.cfg.node_id
                    );
                }
                Ok(())
            }
        }
    }

    fn apply_delta(
        &self,
        origin: usize,
        shard: usize,
        epoch: u64,
        delta: IncrementalSki,
    ) -> Result<(), String> {
        if shard >= self.plan.shards() || self.plan.node_of(shard, self.nodes()) != origin {
            return Err(format!("delta for shard {shard} misrouted from node {origin}"));
        }
        if origin == self.cfg.node_id {
            // Echo of our own shard — nothing to apply.
            self.metrics.peer_deltas_ignored_total.inc();
            return Ok(());
        }
        let mut reps = self.replicas.lock().unwrap_or_else(|e| e.into_inner());
        match reps.map.get_mut(&shard) {
            None => Err(format!("delta for shard {shard} without a replica base")),
            Some(rep) if epoch <= rep.epoch => {
                // Replay (retry, reorder, or post-resync leftovers):
                // the watermark makes it a no-op.
                self.metrics.peer_deltas_ignored_total.inc();
                Ok(())
            }
            Some(rep) if delta.grid() != rep.ski.grid() => {
                Err(format!("delta for shard {shard} on an advanced grid — need full state"))
            }
            Some(rep) => {
                rep.ski.accumulate_shifted(&delta);
                rep.epoch = epoch;
                rep.updated_at_us = now_us();
                self.metrics.peer_deltas_applied_total.inc();
                self.dirty.store(true, Ordering::Relaxed);
                Ok(())
            }
        }
    }

    fn apply_full(
        &self,
        origin: usize,
        shard: usize,
        epoch: u64,
        ski: IncrementalSki,
    ) -> Result<(), String> {
        if shard >= self.plan.shards() || self.plan.node_of(shard, self.nodes()) != origin {
            return Err(format!("full state for shard {shard} misrouted from node {origin}"));
        }
        if origin == self.cfg.node_id {
            // A peer's replica of one of OUR shards: adopt it only
            // while catching up after a restart, and only if it is
            // newer than everything we have adopted for that shard.
            if self.metrics.recovering.get() != 1 {
                self.metrics.peer_deltas_ignored_total.inc();
                return Ok(());
            }
            let mut owned = self.owned.lock().unwrap_or_else(|e| e.into_inner());
            // Re-check under the lock: `recovering` can clear while we
            // wait for it, and `ingest` admits points as soon as it
            // does (also under this lock) — adopting a peer snapshot
            // after that would silently overwrite them.
            if self.metrics.recovering.get() != 1 {
                self.metrics.peer_deltas_ignored_total.inc();
                return Ok(());
            }
            if let Some(os) = owned.skis.iter_mut().find(|o| o.shard == shard) {
                if epoch > os.synced_epoch {
                    os.prev = ski.clone();
                    os.ski = ski;
                    os.synced_epoch = epoch;
                    self.epoch.fetch_max(epoch, Ordering::Relaxed);
                    self.dirty.store(true, Ordering::Relaxed);
                } else {
                    self.metrics.peer_deltas_ignored_total.inc();
                }
            }
            return Ok(());
        }
        let mut reps = self.replicas.lock().unwrap_or_else(|e| e.into_inner());
        match reps.map.get_mut(&shard) {
            Some(rep) if epoch < rep.epoch => {
                self.metrics.peer_deltas_ignored_total.inc();
            }
            Some(rep) => {
                rep.ski = ski;
                rep.epoch = epoch;
                rep.updated_at_us = now_us();
                self.dirty.store(true, Ordering::Relaxed);
            }
            None => {
                reps.map.insert(shard, Replica { ski, epoch, updated_at_us: now_us() });
                self.dirty.store(true, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    /// Cut the pending increments: bump the node epoch, ship a delta
    /// (or a full snapshot after grid growth) per changed owned shard,
    /// roll `prev` forward, and checkpoint when due.
    pub(crate) fn cut_and_ship(&self, owned: &mut OwnedState) {
        let changed: Vec<usize> = owned
            .skis
            .iter()
            .enumerate()
            .filter(|(_, os)| os.ski.n() != os.prev.n() || os.ski.grid() != os.prev.grid())
            .map(|(i, _)| i)
            .collect();
        owned.points_since_cut = 0;
        owned.last_cut = Instant::now();
        if !changed.is_empty() {
            let epoch = self.epoch.fetch_add(1, Ordering::Relaxed) + 1;
            let origin = self.cfg.node_id as u32;
            for i in changed {
                let os = &mut owned.skis[i];
                let frame = match diff_ski(&os.ski, &os.prev) {
                    Some(delta) => Frame::Delta {
                        origin,
                        shard: os.shard as u32,
                        epoch,
                        ski: Box::new(delta),
                    },
                    // Grid expanded since the last cut: deltas cannot
                    // express that, so ship the whole accumulator.
                    None => Frame::Full {
                        origin,
                        shard: os.shard as u32,
                        epoch,
                        ski: Box::new(os.ski.clone()),
                    },
                };
                self.broadcast(Arc::new(frame.encode()));
                os.prev = os.ski.clone();
            }
            self.dirty.store(true, Ordering::Relaxed);
        }
        if owned.ckpt_trigger.due(&self.cfg.ckpt) {
            self.write_checkpoint(owned);
        }
    }

    fn write_checkpoint(&self, owned: &mut OwnedState) {
        let Some(path) = self.cfg.ckpt.node_path(self.cfg.node_id) else {
            return;
        };
        let seq = self.epoch.load(Ordering::Relaxed);
        let t0 = Instant::now();
        let ckpt = Checkpoint {
            seq,
            kernel: self.kernel.clone(),
            sigma2: self.sigma2,
            skis: owned.skis.iter().map(|os| os.ski.clone()).collect(),
        };
        match write_atomic(&path, &ckpt) {
            Ok(()) => {
                owned.ckpt_trigger.note_written();
                self.metrics.record_ckpt_write(seq, t0.elapsed());
            }
            Err(e) => {
                self.metrics.ckpt_write_errors_total.inc();
                crate::log_warn!("cluster node {}: checkpoint failed: {e}", self.cfg.node_id);
            }
        }
    }

    /// Merge owned + replica statistics into a fresh model and publish
    /// it into the serving slot. Called from the monitor thread and
    /// from synchronous `flush`.
    pub(crate) fn publish_now(&self) {
        let t0 = Instant::now();
        let mut parts: Vec<(usize, IncrementalSki)> = {
            let owned = self.owned.lock().unwrap_or_else(|e| e.into_inner());
            owned.skis.iter().map(|os| (os.shard, os.ski.clone())).collect()
        };
        {
            let reps = self.replicas.lock().unwrap_or_else(|e| e.into_inner());
            for (&s, rep) in reps.map.iter() {
                parts.push((s, rep.ski.clone()));
            }
        }
        if parts.is_empty() {
            return;
        }
        // Deterministic fold order (ascending shard id) so every node
        // publishes bitwise-identical merges of the same statistics.
        parts.sort_by_key(|(s, _)| *s);
        let skis: Vec<IncrementalSki> = parts.into_iter().map(|(_, k)| k).collect();
        let merged = merge_owned(self.plan.global().clone(), self.stream.msgp.seed, &skis);
        let mut trainer =
            StreamTrainer::from_stats(self.kernel.clone(), self.sigma2, self.stream.clone(), merged);
        let model = trainer.serving_model();
        self.slot.swap(model);
        self.metrics.record_refresh(t0.elapsed());
    }
}

/// Error returned by [`ClusterNode::ingest`] while the node is still
/// catching up after a (re)start. Points accepted in that window would
/// be silently lost — catch-up adoption overwrites the owned
/// accumulators with a peer replica that cannot contain them, and
/// deltas cut at epochs at or below the peers' watermarks are discarded
/// as replays — so the node refuses them instead (the HTTP front door
/// answers 503, mirroring `/healthz`). Callers gate on
/// [`ClusterNode::recovering`] and retry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Recovering;

impl std::fmt::Display for Recovering {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("node is recovering (catching up from peers); retry once /healthz clears")
    }
}

impl std::error::Error for Recovering {}

/// Handle to a running cluster node (see the [`super`] module docs).
pub struct ClusterNode {
    shared: Arc<Shared>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl ClusterNode {
    /// Start a node: restore its own checkpoint if one is readable,
    /// bind the peer listener (or adopt a pre-bound one — tests pick
    /// ephemeral ports this way), publish an initial model, mark the
    /// node `recovering` until a peer answers its `SyncRequest`, and
    /// spawn the listener/sender/monitor threads.
    pub fn start(
        kernel: KernelSpec,
        sigma2: f64,
        stream: StreamConfig,
        plan: ShardPlan,
        cfg: ClusterConfig,
        listener: Option<TcpListener>,
    ) -> std::io::Result<Arc<ClusterNode>> {
        let nodes = cfg.nodes();
        let node_id = cfg.node_id;
        let listener = match listener {
            Some(l) => l,
            None => TcpListener::bind(cfg.peers[node_id].as_str())?,
        };
        let metrics = Arc::new(Metrics::with_cluster(plan.shards(), nodes));
        let ns = stream.msgp.n_var_samples.max(1);
        let seed = stream.msgp.seed;

        // Owned accumulators, seeded exactly like the in-process shard
        // workers so the merged statistics are bitwise comparable.
        let mut skis = Vec::new();
        for s in cfg.owned_shards(&plan) {
            let ski = IncrementalSki::new(plan.local_grid(s), ns, 1, seed ^ (2 * s as u64));
            skis.push(OwnedShard { shard: s, prev: ski.clone(), ski, synced_epoch: 0 });
        }

        // Restore our own shards from the newest valid node checkpoint
        // (the rotated `.1` fallback lives inside `load_newest`).
        let mut epoch0 = 0u64;
        if let Some(path) = cfg.ckpt.node_path(node_id) {
            if let Some((ck, from)) = load_newest(&path) {
                let shape_ok =
                    ck.skis.len() == skis.len() && ck.skis.iter().all(|k| k.probes().len() == ns);
                if shape_ok {
                    for (os, k) in skis.iter_mut().zip(ck.skis.into_iter()) {
                        os.ski = k.clone();
                        os.prev = k;
                        os.synced_epoch = ck.seq;
                    }
                    epoch0 = ck.seq;
                    metrics.ckpt_restores_total.inc();
                    metrics.ckpt_last_seq.store(ck.seq, Ordering::Relaxed);
                    crate::log_info!(
                        "cluster node {node_id}: restored {} shards at epoch {} from {}",
                        skis.len(),
                        ck.seq,
                        from.display()
                    );
                } else {
                    crate::log_warn!(
                        "cluster node {node_id}: checkpoint shape mismatch at {} — cold start",
                        from.display()
                    );
                }
            }
        }

        // Initial model from whatever we restored (possibly empty).
        let slot = {
            let parts: Vec<IncrementalSki> = skis.iter().map(|os| os.ski.clone()).collect();
            let mut trainer = if parts.is_empty() {
                StreamTrainer::new(kernel.clone(), sigma2, plan.global().clone(), stream.clone())
            } else {
                let merged = merge_owned(plan.global().clone(), seed, &parts);
                StreamTrainer::from_stats(kernel.clone(), sigma2, stream.clone(), merged)
            };
            Arc::new(ModelSlot::new(trainer.serving_model()))
        };

        let mut outs = Vec::with_capacity(nodes);
        let mut rxs: Vec<(usize, Receiver<Arc<Vec<u8>>>)> = Vec::new();
        for p in 0..nodes {
            if p == node_id {
                outs.push(None);
                continue;
            }
            let (tx, rx) = sync_channel(cfg.queue_cap);
            outs.push(Some(OutQueue {
                tx,
                needs_resync: Arc::new(AtomicBool::new(false)),
                depth: Arc::new(AtomicU64::new(0)),
            }));
            rxs.push((p, rx));
        }

        if nodes > 1 {
            metrics.recovering.store(1, Ordering::Relaxed);
        }
        metrics.peers[node_id].up.store(1, Ordering::Relaxed);

        let shared = Arc::new(Shared {
            kernel,
            sigma2,
            stream,
            plan,
            metrics,
            slot,
            owned: Mutex::new(OwnedState {
                skis,
                points_since_cut: 0,
                last_cut: Instant::now(),
                ckpt_trigger: CkptTrigger::default(),
            }),
            replicas: Mutex::new(ReplicaTable::default()),
            outs,
            last_seen_us: (0..nodes).map(|_| AtomicU64::new(0)).collect(),
            epoch: AtomicU64::new(epoch0),
            dirty: AtomicBool::new(false),
            quit: AtomicBool::new(false),
            started: Instant::now(),
            cfg,
        });

        // The monitor thread asks peers for full state (`SyncRequest`)
        // until the first `SyncDone` clears `recovering` — requests are
        // re-broadcast periodically because a reconnecting sender
        // drains its queue before the snapshot, so any single enqueued
        // request (or answer) can be legitimately discarded.

        let mut handles = Vec::new();
        {
            let sh = shared.clone();
            handles.push(std::thread::spawn(move || run_listener(sh, listener)));
        }
        for (p, rx) in rxs {
            let sh = shared.clone();
            handles.push(std::thread::spawn(move || peer::run_sender(sh, p, rx)));
        }
        {
            let sh = shared.clone();
            handles.push(std::thread::spawn(move || run_monitor(sh)));
        }
        Ok(Arc::new(ClusterNode { shared, handles: Mutex::new(handles) }))
    }

    /// Ingest a flat batch, keeping only points whose owner shard this
    /// node owns (callers fan the stream to every node; each keeps its
    /// stripe). Returns the locally accepted count, or [`Recovering`]
    /// while the node is still catching up — accepting points then
    /// would lose them to the catch-up adoption (see [`Recovering`]).
    pub fn ingest(&self, xs: &[f64], ys: &[f64]) -> Result<usize, Recovering> {
        let sh = &self.shared;
        let dim = sh.plan.global().dim();
        let nodes = sh.nodes();
        let mut accepted = 0usize;
        let mut owned = sh.owned.lock().unwrap_or_else(|e| e.into_inner());
        // Checked under the `owned` lock, like the catch-up adoption in
        // `apply_full`: `recovering` only ever transitions 1 -> 0, so
        // once a point is admitted here no adoption can overwrite it.
        if sh.metrics.recovering.get() == 1 {
            return Err(Recovering);
        }
        for (i, &y) in ys.iter().enumerate() {
            let x = &xs[i * dim..(i + 1) * dim];
            let s = sh.plan.owner_of(x);
            if sh.plan.node_of(s, nodes) != sh.cfg.node_id {
                continue;
            }
            if let Some(os) = owned.skis.iter_mut().find(|o| o.shard == s) {
                os.ski.ingest(x, y);
                accepted += 1;
            }
        }
        if accepted > 0 {
            owned.points_since_cut += accepted;
            owned.ckpt_trigger.note_points(accepted);
            sh.metrics.ingested_points_total.fetch_add(accepted as u64, Ordering::Relaxed);
            if owned.points_since_cut >= sh.cfg.ship_every
                || owned.last_cut.elapsed().as_millis() as u64 >= sh.cfg.ship_ms
            {
                sh.cut_and_ship(&mut owned);
            }
            sh.dirty.store(true, Ordering::Relaxed);
        }
        Ok(accepted)
    }

    /// Synchronously cut + ship pending increments and publish a fresh
    /// merged model (the `/flush` route).
    pub fn flush(&self) {
        let sh = &self.shared;
        {
            let mut owned = sh.owned.lock().unwrap_or_else(|e| e.into_inner());
            sh.cut_and_ship(&mut owned);
        }
        sh.dirty.store(false, Ordering::Relaxed);
        sh.publish_now();
    }

    /// Predict one point from the local merged model (never blocks on
    /// the network). The second value is the bounded-staleness report:
    /// `Some(age_ms)` when the point's owner node is down and we served
    /// from a replica, `None` when the owner is this node or alive.
    pub fn predict_one(&self, x: &[f64]) -> (f64, f64, Option<u64>) {
        let sh = &self.shared;
        let model = sh.slot.get();
        let (mean, var) = model.predict_batch(x);
        let (m, v) = (mean[0], var[0]);
        let s = sh.plan.owner_of(x);
        let owner = sh.plan.node_of(s, sh.nodes());
        if owner == sh.cfg.node_id || sh.peer_is_up(owner) {
            return (m, v, None);
        }
        let age_ms = {
            let reps = sh.replicas.lock().unwrap_or_else(|e| e.into_inner());
            match reps.map.get(&s) {
                Some(rep) => now_us().saturating_sub(rep.updated_at_us) / 1000,
                // Never replicated: staleness is our whole lifetime.
                None => sh.started.elapsed().as_millis() as u64,
            }
        };
        (m, v, Some(age_ms))
    }

    /// `/cluster` body: identity, epoch, recovery state, owned shard
    /// point counts, and the replica table with ages.
    pub fn cluster_summary(&self) -> Json {
        let sh = &self.shared;
        let owned: Vec<Json> = {
            let o = sh.owned.lock().unwrap_or_else(|e| e.into_inner());
            o.skis
                .iter()
                .map(|os| {
                    Json::obj(vec![
                        ("shard", Json::Num(os.shard as f64)),
                        ("n", Json::Num(os.ski.n() as f64)),
                        ("m", Json::Num(os.ski.grid().m() as f64)),
                    ])
                })
                .collect()
        };
        let replicas: Vec<Json> = {
            let r = sh.replicas.lock().unwrap_or_else(|e| e.into_inner());
            let mut rows: Vec<(usize, Json)> = r
                .map
                .iter()
                .map(|(&s, rep)| {
                    (
                        s,
                        Json::obj(vec![
                            ("shard", Json::Num(s as f64)),
                            ("epoch", Json::Num(rep.epoch as f64)),
                            ("n", Json::Num(rep.ski.n() as f64)),
                            (
                                "age_ms",
                                Json::Num(
                                    (now_us().saturating_sub(rep.updated_at_us) / 1000) as f64,
                                ),
                            ),
                        ]),
                    )
                })
                .collect();
            rows.sort_by_key(|(s, _)| *s);
            rows.into_iter().map(|(_, j)| j).collect()
        };
        Json::obj(vec![
            ("node", Json::Num(sh.cfg.node_id as f64)),
            ("nodes", Json::Num(sh.nodes() as f64)),
            ("epoch", Json::Num(sh.epoch.load(Ordering::Relaxed) as f64)),
            ("recovering", Json::Bool(sh.metrics.recovering.get() == 1)),
            ("owned", Json::Arr(owned)),
            ("replicas", Json::Arr(replicas)),
        ])
    }

    /// `/peers` body: per-node liveness and replication transport
    /// counters.
    pub fn peers_summary(&self) -> Json {
        let sh = &self.shared;
        let rows: Vec<Json> = (0..sh.nodes())
            .map(|p| {
                let pm = &sh.metrics.peers[p];
                let seen = sh.last_seen_us[p].load(Ordering::Relaxed);
                let age = if p == sh.cfg.node_id {
                    0
                } else if seen == 0 {
                    u64::MAX / 1000
                } else {
                    now_us().saturating_sub(seen) / 1000
                };
                Json::obj(vec![
                    ("node", Json::Num(p as f64)),
                    ("addr", Json::Str(sh.cfg.peers[p].clone())),
                    ("is_self", Json::Bool(p == sh.cfg.node_id)),
                    ("up", Json::Bool(sh.peer_is_up(p))),
                    ("last_seen_age_ms", Json::Num(age as f64)),
                    ("queue_depth", Json::Num(pm.queue_depth.get() as f64)),
                    ("sent", Json::Num(pm.sent.get() as f64)),
                    ("send_errors", Json::Num(pm.send_errors.get() as f64)),
                    ("reconnects", Json::Num(pm.reconnects.get() as f64)),
                    ("full_syncs", Json::Num(pm.full_syncs.get() as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("node", Json::Num(sh.cfg.node_id as f64)),
            ("peers", Json::Arr(rows)),
        ])
    }

    /// Shared metrics registry (the node's `/metricsz` source).
    pub fn metrics(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// The live serving slot.
    pub fn slot(&self) -> Arc<ModelSlot> {
        self.shared.slot.clone()
    }

    /// Input dimensionality.
    pub fn dim(&self) -> usize {
        self.shared.plan.global().dim()
    }

    /// This node's id.
    pub fn node_id(&self) -> usize {
        self.shared.cfg.node_id
    }

    /// Current cut epoch.
    pub fn epoch(&self) -> u64 {
        self.shared.epoch.load(Ordering::Relaxed)
    }

    /// Still catching up after a (re)start?
    pub fn recovering(&self) -> bool {
        self.shared.metrics.recovering.get() == 1
    }

    /// Number of peers currently failing the liveness check.
    pub fn peers_down(&self) -> usize {
        let sh = &self.shared;
        (0..sh.nodes()).filter(|&p| p != sh.cfg.node_id && !sh.peer_is_up(p)).count()
    }

    /// Stop every thread and wait for them. Idempotent.
    pub fn shutdown(&self) {
        self.shared.quit.store(true, Ordering::Relaxed);
        let handles = std::mem::take(
            &mut *self.handles.lock().unwrap_or_else(|e| e.into_inner()),
        );
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for ClusterNode {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Non-blocking accept loop; one detached receive thread per inbound
/// connection (they exit on read timeout/error once `quit` is set).
fn run_listener(shared: Arc<Shared>, listener: TcpListener) {
    if let Err(e) = listener.set_nonblocking(true) {
        crate::log_warn!("cluster node {}: listener setup failed: {e}", shared.cfg.node_id);
        return;
    }
    while !shared.quit.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let sh = shared.clone();
                std::thread::spawn(move || run_receiver(sh, stream));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// One inbound connection: decode frames until error/EOF and apply
/// them. Any decode or application error closes the connection — the
/// sending side reconnects with a full resync, which repairs whatever
/// the error lost.
fn run_receiver(shared: Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_nonblocking(false);
    // The read timeout must comfortably exceed the sender's heartbeat
    // cadence, whatever the knob combination: with `hb_ms >= timeout`
    // every idle connection would otherwise time out between
    // heartbeats and collapse into a perpetual reconnect + full-resync
    // loop.
    let idle = Duration::from_millis(shared.cfg.hb_ms.saturating_mul(4));
    let _ = stream.set_read_timeout(Some(shared.cfg.timeout.max(idle)));
    let mut from: Option<u32> = None;
    loop {
        if shared.quit.load(Ordering::Relaxed) {
            return;
        }
        crate::failpoint!("peer.recv", {
            // Injected receive fault: drop the connection, exactly like
            // a torn read. The peer's resync repairs the stream.
            return;
        });
        let frame = match read_frame(&mut stream) {
            Ok(Some(f)) => f,
            Ok(None) => return,
            Err(_) => return,
        };
        if let Err(e) = shared.on_frame(frame, &mut from) {
            crate::log_warn!(
                "cluster node {}: closing peer connection: {e}",
                shared.cfg.node_id
            );
            return;
        }
    }
}

/// 20 ms housekeeping tick: liveness gauges, deadline cuts, the
/// recovery watchdog, and panic-isolated publish of dirty statistics.
fn run_monitor(shared: Arc<Shared>) {
    let node_id = shared.cfg.node_id;
    let mut sup = Supervisor::new(SupervisorPolicy::default(), 0xC105 ^ node_id as u64);
    // If no peer answers our SyncRequest within 40 heartbeats, stop
    // reporting `recovering` — we are alone (or first up) and our
    // restored state is the best state there is.
    let recover_deadline = Instant::now() + Duration::from_millis(shared.cfg.hb_ms * 40);
    // While recovering, re-broadcast the catch-up request every few
    // heartbeats: a reconnecting sender drains its queue before the
    // snapshot, so one enqueued request (or a peer's enqueued answer)
    // can be dropped — the retry is idempotent and repairs that.
    let sync_req = Arc::new(Frame::SyncRequest { node: node_id as u32 }.encode());
    let sync_req_every = Duration::from_millis(shared.cfg.hb_ms * 4);
    let mut last_sync_req: Option<Instant> = None;
    // After a publish panic, defer only the next publish attempt — the
    // liveness gauges, deadline cuts, and SyncRequest re-broadcast must
    // keep ticking through the backoff window.
    let mut publish_retry_at: Option<Instant> = None;
    while !shared.quit.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(20));
        if shared.metrics.recovering.get() == 1
            && !last_sync_req.is_some_and(|t| t.elapsed() < sync_req_every)
        {
            shared.broadcast(sync_req.clone());
            last_sync_req = Some(Instant::now());
        }
        for p in 0..shared.nodes() {
            if p == node_id {
                continue;
            }
            shared.metrics.peers[p].up.store(u64::from(shared.peer_is_up(p)), Ordering::Relaxed);
            if let Some(out) = &shared.outs[p] {
                shared.metrics.peers[p]
                    .queue_depth
                    .store(out.depth.load(Ordering::Relaxed), Ordering::Relaxed);
            }
        }
        if shared.metrics.recovering.get() == 1
            && Instant::now() >= recover_deadline
            && !(0..shared.nodes()).any(|p| p != node_id && shared.peer_is_up(p))
        {
            shared.metrics.recovering.store(0, Ordering::Relaxed);
            crate::log_warn!("cluster node {node_id}: no live peers — serving restored state as-is");
        }
        {
            let mut owned = shared.owned.lock().unwrap_or_else(|e| e.into_inner());
            if owned.points_since_cut > 0
                && owned.last_cut.elapsed().as_millis() as u64 >= shared.cfg.ship_ms
            {
                shared.cut_and_ship(&mut owned);
            }
        }
        if !publish_retry_at.is_some_and(|t| Instant::now() < t)
            && shared.dirty.swap(false, Ordering::Relaxed)
        {
            let sh = shared.clone();
            if catch_unwind(AssertUnwindSafe(|| sh.publish_now())).is_err() {
                shared.dirty.store(true, Ordering::Relaxed);
                let delay = match sup.on_failure() {
                    Verdict::Restart(d) => {
                        crate::log_warn!("cluster node {node_id}: publish panicked; retry in {d:?}");
                        d
                    }
                    Verdict::Poison => {
                        // Serving continues on the last good model; a
                        // transport peer may recover and change the
                        // inputs, so reset rather than stop forever.
                        crate::log_warn!("cluster node {node_id}: publish poisoned; backing off");
                        sup = Supervisor::new(SupervisorPolicy::default(), 0xC105 ^ node_id as u64);
                        SupervisorPolicy::default().backoff_cap
                    }
                };
                publish_retry_at = Some(Instant::now() + delay);
            } else {
                publish_retry_at = None;
            }
        }
    }
}
