//! Multi-process cluster: peer replication of the additive SKI
//! sufficient statistics (ROADMAP direction 2, landed).
//!
//! Each node owns an interleaved stripe of the [`crate::shard`] slabs
//! ([`ShardPlan::node_of`]), ingests its owned points locally, and
//! streams framed statistic deltas ([`crate::fault::codec::Frame`]) to
//! every peer over plain TCP — no runtime, no external dependency. The
//! statistics are *additive* (`W^T y`, the banded Gram, probe
//! accumulators, counts; see [`crate::stream`]), which is what makes
//! replication trivial to reason about: shipping diffs commutes, so
//! correctness survives retries, reordering, and replays.
//!
//! The robustness layer is the point, not an afterthought:
//!
//! * **Idempotent application** — every delta carries the owner's cut
//!   `epoch`; receivers keep a per-shard watermark and apply a frame
//!   only when its epoch exceeds it, so replays are no-ops.
//! * **Self-healing transport** — each ordered node pair has one
//!   outbound connection (see [`peer`]); any send error, queue
//!   overflow, or injected `peer.*` failpoint tears the connection
//!   down, and the reconnect always begins with a full-state resync,
//!   so lost frames can never silently skew a replica.
//! * **Failure detection** — heartbeats flip per-peer `peer_up`
//!   gauges; predictions keep answering from local replicas with a
//!   staleness bound surfaced as `X-Msgp-Staleness`.
//! * **Rejoin with catch-up** — a restarted node restores its own
//!   checkpoint, asks any peer for full state (`SyncRequest`), and
//!   replays the delta stream from there; `/healthz` reports
//!   `recovering` until the first `SyncDone` lands.
//!
//! Operational reference: `docs/CLUSTER.md`.

pub mod node;
pub mod peer;

use std::time::Duration;

use crate::fault::CkptConfig;
use crate::shard::ShardPlan;
use crate::stream::IncrementalSki;
use crate::util::Rng;

pub use node::{ClusterNode, Recovering};

/// Cluster membership + transport knobs (see `docs/CLUSTER.md` for the
/// environment-variable reference).
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// This node's id (an index into [`Self::peers`]).
    pub node_id: usize,
    /// Every node's listen address, indexed by node id —
    /// `peers[node_id]` is our own bind address.
    pub peers: Vec<String>,
    /// Connect/read/write timeout for peer sockets
    /// (`MSGP_PEER_TIMEOUT_MS`, default 1000).
    pub timeout: Duration,
    /// Cut + ship a delta after this many locally ingested points
    /// (`MSGP_PEER_SHIP_EVERY`, default 256).
    pub ship_every: usize,
    /// ... or after this many milliseconds with pending points
    /// (`MSGP_PEER_SHIP_MS`, default 100).
    pub ship_ms: u64,
    /// Heartbeat cadence on idle connections; a peer is declared down
    /// after `4 x` this without traffic (`MSGP_PEER_HB_MS`,
    /// default 250).
    pub hb_ms: u64,
    /// Bounded outbound queue depth per peer (`MSGP_PEER_QUEUE`,
    /// default 1024); overflow forces a reconnect-with-resync instead
    /// of unbounded buffering.
    pub queue_cap: usize,
    /// Checkpoint cadence/location for this node's owned statistics
    /// (`ski-node{id}.ckpt` under `MSGP_CKPT_DIR`).
    pub ckpt: CkptConfig,
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse::<u64>().ok()).unwrap_or(default)
}

impl ClusterConfig {
    /// Knob defaults for `node_id` of a `peers` membership.
    pub fn new(node_id: usize, peers: Vec<String>) -> Self {
        assert!(node_id < peers.len(), "node_id {node_id} outside membership {peers:?}");
        ClusterConfig {
            node_id,
            peers,
            timeout: Duration::from_millis(1000),
            ship_every: 256,
            ship_ms: 100,
            hb_ms: 250,
            queue_cap: 1024,
            ckpt: CkptConfig { dir: None, every_points: 256, every_ms: 1_000 },
        }
    }

    /// Membership from `MSGP_PEERS` (comma-separated addresses, index =
    /// node id) + `MSGP_NODE_ID`, knobs from `MSGP_PEER_*`, checkpoint
    /// location from `MSGP_CKPT_DIR`. `None` when `MSGP_PEERS` is
    /// unset; `Err` when it is set but inconsistent.
    pub fn from_env() -> Option<Result<Self, String>> {
        let peers_raw = std::env::var("MSGP_PEERS").ok()?;
        let peers: Vec<String> =
            peers_raw.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
        if peers.len() < 2 {
            return Some(Err(format!("MSGP_PEERS needs >= 2 addresses, got {peers_raw:?}")));
        }
        let node_id = match std::env::var("MSGP_NODE_ID").ok().and_then(|v| v.parse::<usize>().ok())
        {
            Some(id) if id < peers.len() => id,
            other => {
                return Some(Err(format!(
                    "MSGP_NODE_ID must index MSGP_PEERS (0..{}), got {other:?}",
                    peers.len()
                )))
            }
        };
        let mut cfg = ClusterConfig::new(node_id, peers);
        cfg.timeout = Duration::from_millis(env_u64("MSGP_PEER_TIMEOUT_MS", 1000).max(10));
        cfg.ship_every = env_u64("MSGP_PEER_SHIP_EVERY", 256).max(1) as usize;
        cfg.ship_ms = env_u64("MSGP_PEER_SHIP_MS", 100).max(1);
        cfg.hb_ms = env_u64("MSGP_PEER_HB_MS", 250).max(10);
        cfg.queue_cap = env_u64("MSGP_PEER_QUEUE", 1024).max(8) as usize;
        cfg.ckpt = CkptConfig::from_env();
        Some(Ok(cfg))
    }

    /// Number of nodes in the membership.
    pub fn nodes(&self) -> usize {
        self.peers.len()
    }

    /// Shard ids this node owns under `plan` (ascending).
    pub fn owned_shards(&self, plan: &ShardPlan) -> Vec<usize> {
        (0..plan.shards()).filter(|&s| plan.node_of(s, self.nodes()) == self.node_id).collect()
    }
}

/// Cut the additive difference `cur - prev` as a shippable increment:
/// a statistics bundle on `cur`'s grid whose `accumulate_shifted` onto
/// a replica of `prev` reproduces `cur` (to f64 rounding). Returns
/// `None` when the two states are not diffable — the grid expanded or
/// the probe layout changed — in which case the caller ships a `Full`
/// snapshot instead.
pub fn diff_ski(cur: &IncrementalSki, prev: &IncrementalSki) -> Option<IncrementalSki> {
    if cur.grid() != prev.grid()
        || cur.probes().len() != prev.probes().len()
        || cur.margin_cells() != prev.margin_cells()
        || cur.n() < prev.n()
    {
        return None;
    }
    let sub = |a: &[f64], b: &[f64]| -> Vec<f64> { a.iter().zip(b).map(|(x, y)| x - y).collect() };
    let (s, spare) = cur.rng_state();
    IncrementalSki::from_parts(
        cur.grid().clone(),
        sub(cur.wty(), prev.wty()),
        cur.bands().iter().zip(prev.bands()).map(|(a, b)| sub(a, b)).collect(),
        sub(cur.counts(), prev.counts()),
        cur.probes().iter().zip(prev.probes()).map(|(a, b)| sub(a, b)).collect(),
        cur.margin_cells(),
        cur.n() - prev.n(),
        cur.weight() - prev.weight(),
        cur.sum_y() - prev.sum_y(),
        cur.sum_y2() - prev.sum_y2(),
        Rng::from_state(s, spare),
    )
    .ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::{Grid, GridAxis};

    fn sample(seed: u64, npts: usize) -> IncrementalSki {
        let grid = Grid::new(vec![GridAxis::span(-2.0, 2.0, 16)]);
        let mut ski = IncrementalSki::new(grid, 3, 1, seed);
        let mut rng = Rng::new(seed ^ 7);
        for i in 0..npts {
            ski.ingest(&[rng.uniform_in(-1.5, 1.5)], (i as f64 * 0.3).sin());
        }
        ski
    }

    #[test]
    fn diff_plus_prev_reproduces_cur() {
        let prev = sample(3, 40);
        let mut cur = prev.clone();
        let mut rng = Rng::new(99);
        for i in 0..30 {
            cur.ingest(&[rng.uniform_in(-1.5, 1.5)], (i as f64 * 0.2).cos());
        }
        let delta = diff_ski(&cur, &prev).expect("same grid is diffable");
        assert_eq!(delta.n(), 30);
        let mut replica = prev.clone();
        replica.accumulate_shifted(&delta);
        assert_eq!(replica.n(), cur.n());
        for (a, b) in replica.wty().iter().zip(cur.wty()) {
            assert!((a - b).abs() < 1e-12);
        }
        for (a, b) in replica.counts().iter().zip(cur.counts()) {
            assert!((a - b).abs() < 1e-12);
        }
        assert!((replica.weight() - cur.weight()).abs() < 1e-12);
        assert!((replica.sum_y2() - cur.sum_y2()).abs() < 1e-12);
    }

    #[test]
    fn diff_refuses_grid_or_probe_mismatch() {
        let prev = sample(3, 10);
        let mut expanded = prev.clone();
        // Out-of-box ingest expands the grid: not diffable any more.
        assert!(expanded.ingest(&[9.0], 1.0).is_some());
        assert!(diff_ski(&expanded, &prev).is_none());
        // Probe-count mismatch is also refused.
        let grid = Grid::new(vec![GridAxis::span(-2.0, 2.0, 16)]);
        let other = IncrementalSki::new(grid, 2, 1, 5);
        assert!(diff_ski(&other, &prev).is_none());
        // A shrunk point count (retired state) is refused, not wrapped.
        assert!(diff_ski(&sample(3, 5), &sample(3, 10)).is_none());
    }

    #[test]
    fn config_env_parsing_validates_membership() {
        // from_env reads process-global env vars; run the variants in
        // one test to avoid races with parallel test threads.
        let lock = ["MSGP_PEERS", "MSGP_NODE_ID"];
        let clear = || {
            for k in lock {
                std::env::remove_var(k);
            }
        };
        clear();
        assert!(ClusterConfig::from_env().is_none(), "unset MSGP_PEERS means no cluster");
        std::env::set_var("MSGP_PEERS", "127.0.0.1:7101");
        assert!(matches!(ClusterConfig::from_env(), Some(Err(_))), "one node is not a cluster");
        std::env::set_var("MSGP_PEERS", "127.0.0.1:7101,127.0.0.1:7102");
        std::env::set_var("MSGP_NODE_ID", "2");
        assert!(matches!(ClusterConfig::from_env(), Some(Err(_))), "id outside membership");
        std::env::set_var("MSGP_NODE_ID", "1");
        let cfg = ClusterConfig::from_env()
            .and_then(|r| r.ok())
            // PANIC-OK: test assertion — the env vars were just set.
            .expect("valid cluster env");
        assert_eq!(cfg.node_id, 1);
        assert_eq!(cfg.nodes(), 2);
        clear();
    }

    #[test]
    fn owned_shards_follow_the_stripe() {
        let grid = Grid::new(vec![GridAxis::span(0.0, 100.0, 101)]);
        let plan = ShardPlan::new(grid, 6, 4, 2);
        let cfg = ClusterConfig::new(1, vec!["a".into(), "b".into(), "c".into()]);
        assert_eq!(cfg.owned_shards(&plan), vec![1, 4]);
    }
}
