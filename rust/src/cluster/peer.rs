//! Outbound replication to one peer: a supervised connect/resync/drain
//! loop over a plain `TcpStream`.
//!
//! Invariant the receiver relies on: **every (re)connection starts
//! with `Hello` followed by a full-state snapshot of our owned
//! shards**, before any queued delta. That makes connection teardown
//! the universal repair action — lost frames, overflowed queues,
//! injected `peer.send`/`peer.connect`/`peer.recv` faults, and torn
//! reads all collapse to "reconnect, resync, continue".
//!
//! Backoff is the [`crate::fault::supervisor`] policy: capped
//! exponential with jitter. A `Poison` verdict (sustained failure)
//! sleeps the cap and resets the window instead of giving up — a dead
//! peer may be restarted any moment, and the queue stays bounded
//! regardless.

use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::Duration;

use super::node::{OutQueue, Shared};
use crate::fault::codec::Frame;
use crate::fault::{Supervisor, SupervisorPolicy, Verdict};

/// Connect to `peer` within the configured timeout. The `peer.connect`
/// failpoint injects refusal here — upstream of the real socket — so
/// chaos tests exercise the genuine backoff path.
fn connect(shared: &Shared, peer: usize) -> std::io::Result<TcpStream> {
    crate::failpoint!("peer.connect", {
        return Err(std::io::Error::new(
            std::io::ErrorKind::ConnectionRefused,
            "injected peer.connect",
        ));
    });
    let addrs = shared.cfg.peers[peer].to_socket_addrs()?;
    let mut last = std::io::Error::new(std::io::ErrorKind::AddrNotAvailable, "no address");
    for addr in addrs {
        match TcpStream::connect_timeout(&addr, shared.cfg.timeout) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                let _ = s.set_write_timeout(Some(shared.cfg.timeout));
                return Ok(s);
            }
            Err(e) => last = e,
        }
    }
    Err(last)
}

/// Write one encoded frame. The `peer.send` failpoint injects a broken
/// pipe, indistinguishable from a peer dying mid-write.
fn send_bytes(stream: &mut TcpStream, bytes: &[u8]) -> std::io::Result<()> {
    crate::failpoint!("peer.send", {
        return Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "injected peer.send"));
    });
    stream.write_all(bytes)?;
    stream.flush()
}

/// Discard everything queued (stale relative to the snapshot we are
/// about to send) and keep the depth gauge honest.
fn drain(rx: &Receiver<Arc<Vec<u8>>>, out: &OutQueue) {
    while rx.try_recv().is_ok() {
        decrement_depth(out);
    }
}

fn decrement_depth(out: &OutQueue) {
    // `fetch_update` instead of `fetch_sub`: the producer's
    // try_send/fetch_add pair is not atomic with ours, so clamp at 0
    // rather than wrapping the gauge to u64::MAX.
    let _ = out.depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
        Some(d.saturating_sub(1))
    });
}

/// Sleep the supervisor's verdict in small slices so shutdown stays
/// prompt. `Poison` sleeps the cap and resets — transport workers are
/// never permanently poisoned (see module docs).
fn backoff(shared: &Shared, sup: &mut Supervisor, seed: u64) {
    let d = match sup.on_failure() {
        Verdict::Restart(d) => d,
        Verdict::Poison => {
            *sup = Supervisor::new(SupervisorPolicy::default(), seed);
            SupervisorPolicy::default().backoff_cap
        }
    };
    let deadline = std::time::Instant::now() + d;
    while std::time::Instant::now() < deadline {
        if shared.quit.load(Ordering::Relaxed) {
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// The sender loop for one peer. Owns the receive side of the bounded
/// outbound queue created in [`super::node::ClusterNode::start`].
pub(crate) fn run_sender(shared: Arc<Shared>, peer: usize, rx: Receiver<Arc<Vec<u8>>>) {
    let node_id = shared.cfg.node_id;
    let seed = 0x5e4d ^ ((node_id as u64) << 16) ^ peer as u64;
    let mut sup = Supervisor::new(SupervisorPolicy::default(), seed);
    let hello = Frame::Hello { node: node_id as u32 }.encode();
    let heartbeat = Frame::Heartbeat { node: node_id as u32 }.encode();
    let hb_wait = Duration::from_millis(shared.cfg.hb_ms);
    let pm = &shared.metrics.peers[peer];
    let out = shared.outs[peer]
        .as_ref()
        // PANIC-OK: start() creates a queue for every peer it spawns a
        // sender for; a missing one is a construction bug.
        .expect("sender spawned without an out queue");

    'reconnect: while !shared.quit.load(Ordering::Relaxed) {
        let mut stream = match connect(&shared, peer) {
            Ok(s) => s,
            Err(_) => {
                pm.send_errors.inc();
                backoff(&shared, &mut sup, seed);
                continue;
            }
        };
        pm.reconnects.inc();

        // Hello, then the full-state resync every fresh connection
        // starts with. Clear the overflow flag first: the snapshot we
        // are about to send supersedes whatever was lost.
        out.needs_resync.store(false, Ordering::Relaxed);
        drain(&rx, out);
        let mut frames = vec![Arc::new(hello.clone())];
        frames.extend(shared.snapshot_owned_fulls());
        pm.full_syncs.inc();
        for f in &frames {
            if send_bytes(&mut stream, f).is_err() {
                pm.send_errors.inc();
                backoff(&shared, &mut sup, seed);
                continue 'reconnect;
            }
            pm.sent.inc();
        }

        // Drain queued frames; heartbeat on idle. Any error or
        // overflow flag tears the connection down for a fresh resync.
        loop {
            if shared.quit.load(Ordering::Relaxed) {
                return;
            }
            if out.needs_resync.load(Ordering::Relaxed) {
                // Queue overflowed: deltas were dropped, the stream is
                // no longer trustworthy. Reconnect with a snapshot.
                continue 'reconnect;
            }
            let bytes = match rx.recv_timeout(hb_wait) {
                Ok(bytes) => {
                    decrement_depth(out);
                    bytes
                }
                Err(RecvTimeoutError::Timeout) => Arc::new(heartbeat.clone()),
                Err(RecvTimeoutError::Disconnected) => return,
            };
            if send_bytes(&mut stream, &bytes).is_err() {
                pm.send_errors.inc();
                backoff(&shared, &mut sup, seed);
                continue 'reconnect;
            }
            pm.sent.inc();
        }
    }
}
