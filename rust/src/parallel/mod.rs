//! In-tree data-parallel execution layer: a dependency-free scoped
//! thread pool for the FFT/CG hot paths.
//!
//! Every MSGP hot path — circulant/Toeplitz/BTTB/BCCB MVMs, the
//! spectral preconditioner, and the block-CG refresh — funnels through
//! the batched engine in [`crate::linalg::fft`], whose batch axis is
//! embarrassingly parallel: lines (and cache-blocked panels of lines)
//! are independent transforms over disjoint slices. This module supplies
//! the thread pool those kernels dispatch onto:
//!
//! * **`std::thread` workers, no dependencies.** A fixed set of worker
//!   threads parks on a condvar; a parallel region publishes one
//!   type-erased job (`&dyn Fn(task_index)`) plus a chunked work queue
//!   (an index counter under the same lock), and workers plus the
//!   submitting thread claim task indices until the queue drains. The
//!   submitter returns only after every claimed task has finished, so
//!   borrowed data outlives all worker access (the classic scoped-pool
//!   contract).
//! * **Deterministic by construction.** Tasks write disjoint outputs and
//!   each task performs bit-identical arithmetic regardless of which
//!   thread runs it, so results are *identical* across `MSGP_THREADS=1`
//!   and `MSGP_THREADS=N` — not merely close. The test suite pins this
//!   for `fftn_batch` and the streaming refresh.
//! * **Graceful fallback.** With one thread configured, zero tasks, a
//!   busy pool (another region in flight), or when called from inside a
//!   pool task (nested parallelism), the region runs inline on the
//!   calling thread. Nested regions therefore compose safely: S shard
//!   workers can all call into the batched engine — whichever enters
//!   first gets the pool, the rest run serially, and nobody
//!   oversubscribes the machine.
//! * **Configuration.** `MSGP_THREADS` (environment) sets the default
//!   thread count; [`configure`] overrides it at runtime (used by the
//!   `fig8_parallel` bench to sweep thread counts in-process). `0`
//!   means "auto": `std::thread::available_parallelism()`, capped at
//!   [`MAX_WORKERS`].
//!
//! A panic inside a task is caught on the worker, recorded, and
//! re-thrown on the submitting thread after the region completes — a
//! poisoned refresh panics its own caller instead of deadlocking the
//! pool or killing an unrelated worker.

use std::any::Any;
use std::cell::Cell;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Hard cap on pool worker threads (the FFT hot paths are memory-bound
/// well before this; an `MSGP_THREADS=10000` typo must not fork-bomb).
pub const MAX_WORKERS: usize = 16;

/// Runtime override for the pool's thread count.
#[derive(Clone, Copy, Debug, Default)]
pub struct ParallelConfig {
    /// Threads to use for parallel regions (including the submitting
    /// thread). `0` re-resolves the default: `MSGP_THREADS` if set,
    /// else `available_parallelism()`, capped at [`MAX_WORKERS`].
    pub threads: usize,
}

/// Resolved thread count; `0` = not yet resolved.
static ACTIVE_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Apply a runtime thread-count override (see [`ParallelConfig`]).
/// Results of parallel regions are identical at every setting — this
/// only changes how many cores do the work.
pub fn configure(cfg: ParallelConfig) {
    let t = if cfg.threads == 0 { resolve_default() } else { cfg.threads.clamp(1, MAX_WORKERS) };
    // ORDERING: Relaxed — a standalone config cell; it guards no other
    // memory, and each region re-reads it at submit time. Racing
    // configure/threads calls just resolve the same default twice.
    ACTIVE_THREADS.store(t, Ordering::Relaxed);
}

/// The effective thread count for parallel regions (>= 1). Resolves and
/// caches the `MSGP_THREADS` / hardware default on first call.
pub fn threads() -> usize {
    // ORDERING: Relaxed — see `configure`: the cell is self-contained,
    // so no acquire/release pairing is needed to read or cache it.
    match ACTIVE_THREADS.load(Ordering::Relaxed) {
        0 => {
            let t = resolve_default();
            // ORDERING: Relaxed — idempotent cache fill (same value on
            // every thread that races here).
            ACTIVE_THREADS.store(t, Ordering::Relaxed);
            t
        }
        t => t,
    }
}

fn resolve_default() -> usize {
    if let Ok(v) = std::env::var("MSGP_THREADS") {
        if let Ok(t) = v.trim().parse::<usize>() {
            if t >= 1 {
                return t.min(MAX_WORKERS);
            }
        }
    }
    hardware_threads()
}

fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_WORKERS)
}

thread_local! {
    /// True while this thread is executing a pool task — nested parallel
    /// regions detect it and run inline.
    static IN_POOL_TASK: Cell<bool> = const { Cell::new(false) };
}

/// True when a parallel region started *now* would actually fan out
/// (more than one thread configured and not already inside a pool
/// task). Cheap pre-check for callers that want to skip staging work.
pub fn available() -> bool {
    threads() > 1 && !IN_POOL_TASK.with(|c| c.get())
}

/// Guard that restores the previous `IN_POOL_TASK` value (unwind-safe).
struct TaskFlagGuard {
    prev: bool,
}

impl TaskFlagGuard {
    fn enter() -> Self {
        let prev = IN_POOL_TASK.with(|c| c.replace(true));
        TaskFlagGuard { prev }
    }
}

impl Drop for TaskFlagGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        IN_POOL_TASK.with(|c| c.set(prev));
    }
}

/// Run `n_tasks` independent tasks, `f(i)` for `i in 0..n_tasks`,
/// returning `true` when the pool actually fanned out (and `false` when
/// the region ran inline: one thread configured, a single task, a
/// nested region, or a busy pool). Blocks until every task completed;
/// a task panic is re-thrown here after the region drains.
pub fn run_tasks(n_tasks: usize, f: &(dyn Fn(usize) + Sync)) -> bool {
    if n_tasks == 0 {
        return false;
    }
    let t = threads();
    if t <= 1 || n_tasks == 1 || IN_POOL_TASK.with(|c| c.get()) {
        run_inline(n_tasks, f);
        return false;
    }
    let pool = global_pool();
    if !pool.try_acquire() {
        // Another region is in flight (e.g. a sibling shard worker);
        // composing serially keeps the machine exactly subscribed.
        run_inline(n_tasks, f);
        return false;
    }
    // `try_acquire` succeeded: we own the pool until `run_owned` returns
    // (its guard releases on every path, including unwind).
    pool.run_owned(n_tasks, t - 1, f);
    true
}

fn run_inline(n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
    let _guard = TaskFlagGuard::enter();
    for i in 0..n_tasks {
        f(i);
    }
}

/// Split `total` items into at most `max_tasks` near-even contiguous
/// ranges and run `f(range)` for each (in parallel when the pool is
/// free). Returns the number of tasks the pool fanned out (`0` when the
/// region ran inline) — the FFT engine feeds this straight into its
/// dispatch counter.
pub fn for_each_range(total: usize, max_tasks: usize, f: &(dyn Fn(Range<usize>) + Sync)) -> usize {
    if total == 0 {
        return 0;
    }
    let n_tasks = max_tasks.clamp(1, total);
    let chunk = total.div_ceil(n_tasks);
    let fanned = run_tasks(n_tasks, &|i| {
        let start = i * chunk;
        if start < total {
            f(start..(start + chunk).min(total));
        }
    });
    if fanned {
        n_tasks
    } else {
        0
    }
}

/// A scope collecting heterogeneous closures to run as one parallel
/// region (the `scope(|s| ...)`-style API over the same pool).
pub struct Scope<'env> {
    tasks: Vec<Box<dyn FnOnce() + Send + 'env>>,
}

impl<'env> Scope<'env> {
    /// Queue one task; all queued tasks run when the scope closes.
    pub fn spawn(&mut self, f: impl FnOnce() + Send + 'env) {
        self.tasks.push(Box::new(f));
    }
}

/// Run a scoped parallel region: `f` queues tasks on the [`Scope`], all
/// of which execute (in parallel when the pool is free) before `scope`
/// returns — so tasks may borrow from the enclosing stack frame.
pub fn scope<'env, R>(f: impl FnOnce(&mut Scope<'env>) -> R) -> R {
    let mut s = Scope { tasks: Vec::new() };
    let out = f(&mut s);
    if !s.tasks.is_empty() {
        let slots: Vec<Mutex<Option<Box<dyn FnOnce() + Send + 'env>>>> =
            s.tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        run_tasks(slots.len(), &|i| {
            let task = slots[i].lock().unwrap().take().expect("scope task runs once");
            task();
        });
    }
    out
}

/// Shareable raw view over a mutable slice, for tasks that write
/// **disjoint** elements of one output buffer. The pool guarantees all
/// tasks finish before the region returns, so the underlying borrow is
/// never outlived; disjointness is the caller's obligation (hence the
/// `unsafe` accessors).
pub struct SendSlicePtr<T> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: the pointer is derived from an exclusive `&mut [T]` borrow
// that the scoped-pool contract keeps alive (and un-aliased by the
// owner) until every task finished; sending it to pool threads is
// sound for `T: Send` because element accesses stay disjoint.
unsafe impl<T: Send> Send for SendSlicePtr<T> {}
// SAFETY: shared use from several tasks is sound under the same
// disjointness contract — each element is touched by at most one task,
// so `&SendSlicePtr` hands out no overlapping `&mut` views.
unsafe impl<T: Send> Sync for SendSlicePtr<T> {}

impl<T> SendSlicePtr<T> {
    /// Capture a slice for disjoint-range task writes.
    pub fn new(s: &mut [T]) -> Self {
        SendSlicePtr { ptr: s.as_mut_ptr(), len: s.len() }
    }

    /// Length of the captured slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the captured slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Exclusive sub-slice `r` of the captured buffer.
    ///
    /// # Safety
    /// Concurrent tasks must use non-overlapping ranges, and `r` must be
    /// in bounds of the captured slice.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range(&self, r: Range<usize>) -> &mut [T] {
        debug_assert!(r.start <= r.end && r.end <= self.len);
        // SAFETY: `r` is in bounds of the captured allocation per the
        // function contract, and range-disjointness across tasks means
        // this `&mut` view aliases no other live reference.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(r.start), r.end - r.start) }
    }

    /// Read element `i`.
    ///
    /// # Safety
    /// `i` must be in bounds; no concurrent task may be writing `i`.
    pub unsafe fn read(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        // SAFETY: `i` is in bounds per the function contract and no
        // concurrent task writes it, so the read is valid and unraced.
        unsafe { *self.ptr.add(i) }
    }

    /// Write element `i`.
    ///
    /// # Safety
    /// `i` must be in bounds and written by at most one concurrent task.
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        // SAFETY: `i` is in bounds per the function contract and owned
        // by this task alone, so the write aliases no other access.
        unsafe { *self.ptr.add(i) = v }
    }
}

/// One published job: a type-erased `&dyn Fn(task_index)` with its
/// lifetime erased. Sound because the submitter blocks in
/// [`ThreadPool::run_owned`] until every task completed, so the
/// referent outlives all dereferences.
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync),
}

// SAFETY: the pointee is `Sync` (shared calls from any thread are
// fine), and the submitter keeps it alive until the region drains, so
// shipping the raw pointer to workers cannot outlive the referent.
unsafe impl Send for Job {}

/// Pool state behind one mutex: the current job, its chunked work queue
/// (an index counter), and completion accounting.
struct State {
    job: Option<Job>,
    n_tasks: usize,
    next_task: usize,
    /// Tasks claimed-or-unclaimed but not yet finished.
    pending: usize,
    /// Workers currently enrolled in the running job.
    workers_in_job: usize,
    /// Helper-worker cap for the running job (`threads() - 1` at submit
    /// time, so a runtime `configure` takes effect per region).
    allowed: usize,
    /// Bumped per job so late-waking workers never join a stale epoch.
    epoch: u64,
    /// First panic payload from any task, re-thrown on the submitter.
    panic: Option<Box<dyn Any + Send>>,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new job epoch.
    work_cv: Condvar,
    /// The submitter waits here for `pending == 0`.
    done_cv: Condvar,
    /// Submitter slot: one region owns the pool at a time; the rest run
    /// inline (see [`run_tasks`]).
    busy: AtomicBool,
}

/// The scoped thread pool. One global instance serves the whole
/// process; worker threads are spawned lazily on first use and park on
/// a condvar between jobs.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Spawned helper workers (the submitter is thread `workers + 1`).
    workers: usize,
}

fn global_pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(ThreadPool::spawn)
}

impl ThreadPool {
    /// Spawn the global pool's helper workers: enough for the hardware
    /// (or a larger `MSGP_THREADS` request), minus the submitting
    /// thread, capped at [`MAX_WORKERS`]. Idle workers cost one parked
    /// thread each.
    fn spawn() -> Self {
        let target = hardware_threads().max(threads()).min(MAX_WORKERS);
        let workers = target.saturating_sub(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                n_tasks: 0,
                next_task: 0,
                pending: 0,
                workers_in_job: 0,
                allowed: 0,
                epoch: 0,
                panic: None,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            busy: AtomicBool::new(false),
        });
        for id in 0..workers {
            let sh = shared.clone();
            std::thread::Builder::new()
                .name(format!("msgp-par-{id}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn pool worker");
        }
        ThreadPool { shared, workers }
    }

    /// Helper workers available to parallel regions.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Claim the submitter slot; `false` when another region is running.
    fn try_acquire(&self) -> bool {
        // ORDERING: Acquire on success pairs with the Release store in
        // `BusyGuard::drop`, so a new owner observes all pool-state
        // writes of the previous region; Relaxed on failure — the loser
        // runs inline and reads no pool state.
        self.shared.busy.compare_exchange(false, true, Ordering::Acquire, Ordering::Relaxed).is_ok()
    }

    /// Run one job on the acquired pool: publish it, participate in the
    /// task loop, wait for stragglers, release the pool, re-throw any
    /// task panic. Caller must hold the submitter slot (`try_acquire`).
    fn run_owned(&self, n_tasks: usize, helpers: usize, f: &(dyn Fn(usize) + Sync)) {
        struct BusyGuard<'a>(&'a Shared);
        impl Drop for BusyGuard<'_> {
            fn drop(&mut self) {
                // ORDERING: Release pairs with the Acquire
                // compare-exchange in `try_acquire`, publishing this
                // region's pool-state writes to the next owner.
                self.0.busy.store(false, Ordering::Release);
            }
        }
        let _busy = BusyGuard(&self.shared);
        // SAFETY: `f`'s lifetime is erased to publish it to workers; the
        // wait loop below does not return until `pending == 0`, i.e.
        // until no task (hence no dereference of `f`) remains.
        let job = Job {
            f: unsafe {
                std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
            },
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none(), "acquired pool must be idle");
            st.job = Some(job);
            st.n_tasks = n_tasks;
            st.next_task = 0;
            st.pending = n_tasks;
            st.workers_in_job = 0;
            st.allowed = helpers.min(self.workers);
            st.epoch = st.epoch.wrapping_add(1);
            st.panic = None;
        }
        self.shared.work_cv.notify_all();
        // Participate: claim and run tasks alongside the workers.
        let flag = TaskFlagGuard::enter();
        loop {
            let t = {
                let mut st = self.shared.state.lock().unwrap();
                if st.next_task >= st.n_tasks {
                    break;
                }
                let t = st.next_task;
                st.next_task += 1;
                t
            };
            run_one(&self.shared, job, t);
        }
        drop(flag);
        // Wait for workers still finishing claimed tasks.
        let panic_payload = {
            let mut st = self.shared.state.lock().unwrap();
            while st.pending > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            st.panic.take()
        };
        if let Some(p) = panic_payload {
            resume_unwind(p);
        }
    }
}

/// Execute task `t` of `job`, recording a panic instead of unwinding
/// through the pool, then mark it finished.
fn run_one(shared: &Shared, job: Job, t: usize) {
    // SAFETY: the submitter keeps the closure alive until `pending`
    // reaches zero, and `pending` is decremented only after this call.
    let res = catch_unwind(AssertUnwindSafe(|| unsafe { (*job.f)(t) }));
    let mut st = shared.state.lock().unwrap();
    if let Err(p) = res {
        // Keep the first payload and cancel the unclaimed tail of the
        // queue — the cancelled tasks will never run, so they must come
        // off `pending` too or the submitter would wait forever.
        if st.panic.is_none() {
            st.panic = Some(p);
        }
        st.pending -= st.n_tasks - st.next_task;
        st.next_task = st.n_tasks;
    }
    st.pending -= 1;
    if st.pending == 0 {
        shared.done_cv.notify_all();
    }
}

fn worker_loop(shared: &Shared) {
    let _flag = TaskFlagGuard::enter(); // workers only ever run pool tasks
    loop {
        // Enroll in a job epoch with spare capacity and unclaimed tasks.
        let (job, epoch) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = st.job {
                    if st.next_task < st.n_tasks && st.workers_in_job < st.allowed {
                        st.workers_in_job += 1;
                        break (job, st.epoch);
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // Task loop: claim indices until this epoch's queue drains.
        loop {
            let t = {
                let mut st = shared.state.lock().unwrap();
                if st.epoch != epoch || st.job.is_none() || st.next_task >= st.n_tasks {
                    // Only undo this worker's own enrollment: if the
                    // epoch moved on, the counter was reset at publish
                    // time and belongs to the new job.
                    if st.epoch == epoch {
                        st.workers_in_job = st.workers_in_job.saturating_sub(1);
                    }
                    break;
                }
                let t = st.next_task;
                st.next_task += 1;
                t
            };
            run_one(shared, job, t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// Tasks over disjoint ranges fill a buffer completely and exactly,
    /// whatever mix of workers ran them.
    #[test]
    fn run_tasks_fills_disjoint_ranges() {
        // Shrunk under Miri so the interpreted run stays fast while the
        // disjoint-write aliasing pattern is still fully exercised.
        let total = if cfg!(miri) { 512 } else { 10_000 };
        let mut out = vec![0u64; total];
        let ptr = SendSlicePtr::new(&mut out);
        for_each_range(total, 8, &|r| {
            let s = unsafe { ptr.range(r.clone()) };
            for (k, v) in s.iter_mut().enumerate() {
                *v = (r.start + k) as u64 + 1;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64 + 1);
        }
    }

    /// Zero-sized regions are a no-op, single-task regions run inline.
    #[test]
    fn zero_and_single_task_regions() {
        assert!(!run_tasks(0, &|_| panic!("must not run")));
        let hits = AtomicU64::new(0);
        let fanned = run_tasks(1, &|i| {
            assert_eq!(i, 0);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert!(!fanned, "single task must run inline");
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    /// Nested regions run inline (no deadlock, every task executes).
    #[test]
    fn nested_scope_runs_inline() {
        let outer_hits = AtomicU64::new(0);
        let inner_hits = AtomicU64::new(0);
        run_tasks(4, &|_| {
            outer_hits.fetch_add(1, Ordering::SeqCst);
            let fanned = run_tasks(4, &|_| {
                inner_hits.fetch_add(1, Ordering::SeqCst);
            });
            assert!(!fanned, "nested region must run inline");
        });
        assert_eq!(outer_hits.load(Ordering::SeqCst), 4);
        assert_eq!(inner_hits.load(Ordering::SeqCst), 16);
    }

    /// The scope API runs every spawned closure (borrowing the stack)
    /// before returning.
    #[test]
    fn scope_runs_all_spawned_tasks() {
        let mut parts = vec![0u64; 6];
        {
            let slots: Vec<Mutex<&mut u64>> = parts.iter_mut().map(Mutex::new).collect();
            scope(|s| {
                for (i, slot) in slots.iter().enumerate() {
                    s.spawn(move || {
                        **slot.lock().unwrap() = (i as u64 + 1) * 10;
                    });
                }
            });
        }
        assert_eq!(parts, vec![10, 20, 30, 40, 50, 60]);
    }

    /// A panicking task propagates to the submitter, and the pool stays
    /// usable afterwards.
    #[test]
    fn panic_in_task_propagates_and_pool_survives() {
        let res = std::panic::catch_unwind(|| {
            run_tasks(4, &|i| {
                if i == 2 {
                    panic!("task exploded");
                }
            });
        });
        assert!(res.is_err(), "panic must propagate to the submitter");
        // Pool still works.
        let hits = AtomicU64::new(0);
        run_tasks(8, &|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    /// `configure` clamps and `threads()` always reports >= 1; results
    /// are identical at every setting (spot check with a reduction).
    #[test]
    fn configure_round_trips_and_results_match() {
        let sum_with = |t: usize| -> u64 {
            configure(ParallelConfig { threads: t });
            assert!(threads() >= 1);
            let total = if cfg!(miri) { 256 } else { 4096 };
            let mut out = vec![0u64; total];
            let ptr = SendSlicePtr::new(&mut out);
            for_each_range(total, 8, &|r| {
                let s = unsafe { ptr.range(r.clone()) };
                for (k, v) in s.iter_mut().enumerate() {
                    *v = ((r.start + k) as u64).wrapping_mul(2654435761);
                }
            });
            out.iter().sum()
        };
        let s1 = sum_with(1);
        let s4 = sum_with(4);
        assert_eq!(s1, s4);
        configure(ParallelConfig { threads: 0 }); // restore default
    }
}
