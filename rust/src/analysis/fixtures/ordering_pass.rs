// Fixture: annotated orderings pass; bare Relaxed counters are fine in
// an ordinary (non-handoff) module.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

pub fn publish(flag: &AtomicBool, hits: &AtomicUsize) {
    hits.fetch_add(1, Ordering::Relaxed);
    // ORDERING: Release pairs with the Acquire load in `consume`; it
    // publishes every write sequenced before this store.
    flag.store(true, Ordering::Release);
}

pub fn consume(flag: &AtomicBool) -> bool {
    // ORDERING: Acquire pairs with the Release store in `publish`.
    flag.load(Ordering::Acquire)
}

pub fn fence_all(flag: &AtomicBool) {
    // ORDERING: SeqCst is required here because this flag arbitrates
    // between two independent store-load races (Dekker-style).
    flag.store(true, Ordering::SeqCst);
}
