// Fixture: locks acquired in declared rank order (ops before
// reservoir before hypers), guards released by scope or by drop, and a
// leaf lock taken alone.

pub fn ordered(&self) {
    let _ops = self.ops.lock().unwrap();
    let res = self.reservoir.lock().unwrap();
    let n = res.len();
    drop(res);
    let hy = self.hypers.lock().unwrap();
    let _ = (n, hy.len());
}

pub fn leaf_only(&self) {
    let st = self.state.lock().unwrap();
    let _ = st.len();
}

pub fn temporary_under_facade(&self) {
    let _ops = self.ops.lock().unwrap();
    let snapshot = self.hypers.lock().unwrap().clone();
    let _ = snapshot;
}
