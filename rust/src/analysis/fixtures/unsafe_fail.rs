// Fixture: unsafe sites with no SAFETY justification must be flagged.

pub fn read_first(xs: &[f64]) -> f64 {
    unsafe { *xs.get_unchecked(0) }
}

pub unsafe fn read_at(xs: &[f64], i: usize) -> f64 {
    *xs.get_unchecked(i)
}
