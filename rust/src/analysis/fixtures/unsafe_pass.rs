// Fixture: every unsafe site carries a SAFETY justification.

pub fn read_first(xs: &[f64]) -> f64 {
    // SAFETY: callers guarantee `xs` is non-empty (checked at the API
    // boundary), so index 0 is in bounds.
    unsafe { *xs.get_unchecked(0) }
}

/// # Safety
/// `i` must be in bounds for `xs`.
pub unsafe fn read_at(xs: &[f64], i: usize) -> f64 {
    // SAFETY: the function contract requires `i < xs.len()`.
    unsafe { *xs.get_unchecked(i) }
}
