// Fixture: acquiring a lower-ranked lock while a higher rank is held
// must be flagged, as must nesting a lock the table does not declare.

pub fn inverted(&self) {
    let hy = self.hypers.lock().unwrap();
    let res = self.reservoir.lock().unwrap();
    let _ = (hy.len(), res.len());
}

pub fn undeclared_nested(&self) {
    let st = self.state.lock().unwrap();
    let q = self.mystery_queue.lock().unwrap();
    let _ = (st.len(), q.len());
}
