// Fixture: a hot function in the sanctioned buffer-reuse idiom passes;
// an audited allocation passes under an explicit allow escape.

// lint:hot
pub fn hot_kernel(xs: &[f64], buf: &mut Vec<f64>, out: &mut [f64]) -> f64 {
    buf.resize(xs.len(), 0.0);
    buf.fill(0.0);
    let mut acc = 0.0;
    for (o, x) in out.iter_mut().zip(xs) {
        *o = *x * 2.0;
        acc += *o;
    }
    let snapshot = buf.to_vec(); // lint:allow(alloc, "audited: snapshot handed to caller")
    acc + snapshot.len() as f64
}

pub fn cold_assemble(xs: &[f64]) -> Vec<f64> {
    xs.to_vec()
}
