// Fixture: bare SeqCst and unannotated acquire/release must be flagged.

use std::sync::atomic::{AtomicBool, Ordering};

pub fn publish(flag: &AtomicBool) {
    flag.store(true, Ordering::SeqCst);
}

pub fn consume(flag: &AtomicBool) -> bool {
    flag.load(Ordering::Acquire)
}
