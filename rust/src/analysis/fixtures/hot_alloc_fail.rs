// Fixture: allocations inside a hot function must be flagged, while
// the cold function further down (line 20+) allocates freely.

// lint:hot
pub fn hot_kernel(xs: &[f64]) -> f64 {
    let tmp = vec![0.0f64; xs.len()];
    let copied = xs.to_vec();
    let cloned = copied.clone();
    let doubled: Vec<f64> = xs.iter().map(|v| v * 2.0).collect();
    let boxed = Box::new(doubled);
    tmp.len() as f64 + cloned[0] + boxed[0]
}

// Padding so the cold function sits at a known line for the rule test.
//
//
//
//

pub fn cold_assemble(xs: &[f64]) -> Vec<f64> {
    let mut out = xs.to_vec();
    out.clone()
}
