//! The five rule families enforced by `msgp-lint`.
//!
//! Each rule consumes a scanned [`SourceFile`] and appends
//! [`Finding`]s. All rules skip `#[cfg(test)]` regions — test code may
//! allocate, take locks in odd orders, and use `SeqCst` freely; the
//! production invariants are what the gate protects. See
//! `docs/ANALYSIS.md` for the policy rationale and the marker grammar.

use super::scan::{find_word, SourceFile};
use super::{Finding, LOCK_ORDER};

/// How many preceding lines an annotation marker covers (inclusive of
/// the site line itself).
pub const ANNOTATION_WINDOW: usize = 4;

/// Allocation-adjacent patterns denied inside `lint:hot` functions.
/// `.resize(` / `.fill(` / `.clear(` are deliberately absent: growing a
/// *reusable* buffer to a steady-state size is the crate's sanctioned
/// idiom for allocation-free hot paths.
pub const HOT_DENY: &[&str] = &[
    "Vec::new",
    "vec!",
    ".to_vec(",
    ".clone(",
    ".collect",
    "Box::new",
    "String::new",
    ".to_string(",
    "format!",
    "with_capacity",
];

/// Per-variant `Ordering::*` call-site counts for the summary report.
#[derive(Debug, Default, Clone, Copy)]
pub struct OrderingCounts {
    pub seqcst: usize,
    pub acqrel: usize,
    pub acquire: usize,
    pub release: usize,
    pub relaxed: usize,
}

impl OrderingCounts {
    pub fn total(&self) -> usize {
        self.seqcst + self.acqrel + self.acquire + self.release + self.relaxed
    }
    pub fn add(&mut self, other: &OrderingCounts) {
        self.seqcst += other.seqcst;
        self.acqrel += other.acqrel;
        self.acquire += other.acquire;
        self.release += other.release;
        self.relaxed += other.relaxed;
    }
}

fn window_comments<'a>(
    file: &'a SourceFile,
    line_idx: usize,
) -> impl Iterator<Item = &'a str> {
    let lo = line_idx.saturating_sub(ANNOTATION_WINDOW);
    file.lines[lo..=line_idx].iter().map(|l| l.comment.as_str())
}

/// True when a comment within the window carries the given marker as
/// its leading token (leading-position match keeps prose *mentions* of
/// a marker from arming or satisfying a rule).
fn window_has_leading(file: &SourceFile, line_idx: usize, marker: &str) -> bool {
    window_comments(file, line_idx).any(|c| c.trim_start().starts_with(marker))
}

/// True when a comment within the window contains the marker anywhere
/// (used for `SAFETY:` / `ORDERING:`, where multi-sentence comments and
/// `/// # Safety` doc sections both count).
fn window_contains(file: &SourceFile, line_idx: usize, marker: &str) -> bool {
    window_comments(file, line_idx).any(|c| c.contains(marker))
}

/// Rule 1 — unsafe-audit: every standalone `unsafe` token (block, fn,
/// impl) outside test code must have a `SAFETY:` comment (or a
/// `# Safety` doc section) within the annotation window. Returns the
/// number of non-test unsafe tokens found, for the registry check.
pub fn unsafe_audit(file: &SourceFile, findings: &mut Vec<Finding>) -> usize {
    let mut count = 0usize;
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let mut from = 0usize;
        while let Some(at) = find_word(&line.code, "unsafe", from) {
            count += 1;
            from = at + "unsafe".len();
            if !window_contains(file, idx, "SAFETY:")
                && !window_contains(file, idx, "# Safety")
            {
                findings.push(Finding {
                    file: file.rel_path.clone(),
                    line: idx + 1,
                    rule: "unsafe-audit",
                    msg: "unsafe site without a SAFETY: justification within 4 lines"
                        .to_string(),
                });
            }
        }
    }
    count
}

/// Rule 2 — atomic-ordering audit. Policy:
///
/// * `SeqCst` is denied by default everywhere: either relax it to the
///   ordering the algorithm actually needs, or keep it with an
///   `ORDERING:` comment explaining why sequential consistency is
///   required.
/// * `Acquire` / `Release` / `AcqRel` are by definition cross-thread
///   handoff: they require an `ORDERING:` comment naming their pairing
///   partner, in every file.
/// * `Relaxed` is free in ordinary counter/gauge code, but inside
///   declared handoff modules (`is_handoff`, e.g. the seqlock ring and
///   the thread pool) *every* ordering — Relaxed included — must be
///   annotated, because there Relaxed is a claim that the surrounding
///   fences/operations provide the synchronization.
pub fn ordering_audit(
    file: &SourceFile,
    is_handoff: bool,
    findings: &mut Vec<Finding>,
) -> OrderingCounts {
    let mut counts = OrderingCounts::default();
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        let mut from = 0usize;
        while let Some(at) = line.code[from..].find("Ordering::") {
            let start = from + at + "Ordering::".len();
            let variant: String = line.code[start..]
                .chars()
                .take_while(|c| c.is_alphanumeric())
                .collect();
            from = start;
            let needs_annotation = match variant.as_str() {
                "SeqCst" => {
                    counts.seqcst += 1;
                    true
                }
                "AcqRel" => {
                    counts.acqrel += 1;
                    true
                }
                "Acquire" => {
                    counts.acquire += 1;
                    true
                }
                "Release" => {
                    counts.release += 1;
                    true
                }
                "Relaxed" => {
                    counts.relaxed += 1;
                    is_handoff
                }
                _ => continue,
            };
            if needs_annotation && !window_contains(file, idx, "ORDERING:") {
                let why = if variant == "SeqCst" {
                    "bare SeqCst denied: relax it or justify with an ORDERING: comment"
                } else {
                    "handoff ordering requires an ORDERING: comment naming its pairing"
                };
                findings.push(Finding {
                    file: file.rel_path.clone(),
                    line: idx + 1,
                    rule: "atomic-ordering",
                    msg: format!("Ordering::{variant}: {why}"),
                });
            }
        }
    }
    counts
}

/// Rule 3 — hot-path allocation lint: a `lint:hot` marker arms the next
/// `fn`; inside its body every [`HOT_DENY`] pattern is an error unless
/// the line carries a `lint:allow(alloc, "...")` escape within the
/// annotation window.
pub fn hot_alloc(file: &SourceFile, findings: &mut Vec<Finding>) {
    let mut armed = false;
    // Depth the hot fn's signature sits at; `None` = not in a hot fn.
    let mut hot_base: Option<u32> = None;
    let mut body_opened = false;
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        if line.comment.trim_start().starts_with("lint:hot") {
            armed = true;
        }
        if hot_base.is_none() && armed && find_word(&line.code, "fn", 0).is_some() {
            hot_base = Some(line.depth_start);
            body_opened = false;
            armed = false;
        }
        if let Some(base) = hot_base {
            if line.code.contains('{') {
                body_opened = true;
            }
            for pat in HOT_DENY {
                if find_word(&line.code, pat, 0).is_some()
                    && !window_has_leading(file, idx, "lint:allow(alloc")
                {
                    findings.push(Finding {
                        file: file.rel_path.clone(),
                        line: idx + 1,
                        rule: "hot-alloc",
                        msg: format!(
                            "`{pat}` inside a lint:hot function (allocation-free \
                             invariant); reuse a buffer or add lint:allow(alloc, ...)"
                        ),
                    });
                }
            }
            if body_opened && line.depth_end <= base {
                hot_base = None;
            }
        }
    }
}

/// Rule 4 — lock-order audit: `.lock()` receivers must be acquired in
/// strictly increasing rank per the [`LOCK_ORDER`] table. Guards held
/// across statements (a `let g = recv.lock().unwrap();`-shaped binding)
/// stay on a per-file stack until their scope closes or they are
/// `drop`ped; chained temporaries (`recv.lock().unwrap().clone()`)
/// are checked against the held stack but not pushed. Receivers absent
/// from the table are only an error when taken while another lock is
/// held. Known limitation (documented): calls into functions that
/// themselves lock are not traced — the table must be kept coarse
/// enough that each function's direct acquisitions tell the story.
pub fn lock_order(file: &SourceFile, findings: &mut Vec<Finding>) {
    // (receiver, rank-or-None, depth at acquisition)
    let mut held: Vec<(String, Option<u32>, u32)> = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            held.clear();
            continue;
        }
        // Scopes that closed before this line release their guards.
        held.retain(|&(_, _, d)| line.depth_start >= d);
        // Explicit drop(guard) releases by name (else the top guard).
        if let Some(p) = line.code.find("drop(") {
            let name: String = line.code[p + "drop(".len()..]
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            if let Some(pos) = held.iter().rposition(|(n, _, _)| *n == name) {
                held.remove(pos);
            } else if !held.is_empty() {
                held.pop();
            }
        }
        let mut from = 0usize;
        while let Some(at) = line.code[from..].find(".lock()") {
            let at = from + at;
            from = at + ".lock()".len();
            let recv = receiver_before(&line.code, at);
            let rank = LOCK_ORDER
                .iter()
                .find(|(n, _)| *n == recv)
                .map(|&(_, r)| r);
            if let Some((top_name, top_rank, _)) = held.last() {
                let ordered = match (rank, top_rank) {
                    (Some(r), Some(t)) => r > *t,
                    // A lock outside the table nested under anything,
                    // or anything nested under an unranked lock, is a
                    // violation: the table must name every lock that
                    // participates in nesting.
                    _ => false,
                };
                if !ordered && !window_has_leading(file, idx, "lint:allow(lock_order") {
                    findings.push(Finding {
                        file: file.rel_path.clone(),
                        line: idx + 1,
                        rule: "lock-order",
                        msg: format!(
                            "lock `{recv}` (rank {rank:?}) acquired while `{top_name}` \
                             (rank {top_rank:?}) is held; declared order violated"
                        ),
                    });
                }
            }
            if is_held_binding(&line.code, from) {
                held.push((recv, rank, line.depth_end));
            }
        }
    }
}

/// Source-path prefixes where rule 5 (unwrap-audit) applies: the
/// serving path, where an unjustified panic takes down a worker thread
/// (or, pre-supervision, the whole deployment).
pub const UNWRAP_AUDIT_PREFIXES: &[&str] =
    &["cluster/", "coordinator/", "shard/", "stream/", "fault/"];

/// Panic-on-Err/None patterns rule 5 denies. `.unwrap_or_else(` does
/// not match `.unwrap()` — converting a poisoned lock with
/// `unwrap_or_else(|e| e.into_inner())` is the sanctioned recovery.
pub const UNWRAP_DENY: &[&str] = &[".unwrap()", ".expect("];

/// Rule 5 — unwrap-audit: `.unwrap()` / `.expect(` in non-test code
/// under the serving-path prefixes must carry a leading `PANIC-OK:`
/// comment within the annotation window justifying why panicking (and
/// riding the supervisor's restart/poison policy) beats handling the
/// error. Everything else should propagate the error or recover.
pub fn unwrap_audit(file: &SourceFile, findings: &mut Vec<Finding>) {
    if !UNWRAP_AUDIT_PREFIXES.iter().any(|p| file.rel_path.starts_with(p)) {
        return;
    }
    for (idx, line) in file.lines.iter().enumerate() {
        if line.in_test {
            continue;
        }
        for pat in UNWRAP_DENY {
            let mut from = 0usize;
            while let Some(at) = line.code[from..].find(pat) {
                from += at + pat.len();
                if !window_has_leading(file, idx, "PANIC-OK:") {
                    findings.push(Finding {
                        file: file.rel_path.clone(),
                        line: idx + 1,
                        rule: "unwrap-audit",
                        msg: format!(
                            "`{pat}` in serving-path code without a PANIC-OK: \
                             justification within {ANNOTATION_WINDOW} lines; \
                             propagate the error, recover the poison \
                             (`unwrap_or_else(|e| e.into_inner())`), or justify"
                        ),
                    });
                }
            }
        }
    }
}

/// Extract the receiver identifier immediately before a `.lock()` call
/// at byte offset `dot`: walks back over balanced `()` / `[]` groups
/// and path/field chains, returning the last path component
/// (`self.reservoir` → `reservoir`, `registry()` → `registry`).
fn receiver_before(code: &str, dot: usize) -> String {
    let bytes = code.as_bytes();
    let mut i = dot;
    // Walk left over one balanced trailing group, e.g. `registry()`.
    while i > 0 && (bytes[i - 1] == b')' || bytes[i - 1] == b']') {
        let close = bytes[i - 1];
        let open = if close == b')' { b'(' } else { b'[' };
        let mut depth = 0i32;
        while i > 0 {
            i -= 1;
            if bytes[i] == close {
                depth += 1;
            } else if bytes[i] == open {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
        }
    }
    let end = i;
    while i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_') {
        i -= 1;
    }
    code[i..end].to_string()
}

/// True when the `.lock()` call at hand is a guard *binding*: the line
/// is a `let` statement and the lock is immediately unwrapped and bound
/// (`.unwrap();` or `.unwrap_or_else(..);`), so the guard outlives the
/// statement. Anything else (further chained calls, expression
/// position) is a temporary whose guard dies at the semicolon.
fn is_held_binding(code: &str, after_lock: usize) -> bool {
    if !code.trim_start().starts_with("let ") {
        return false;
    }
    let rest = &code[after_lock..];
    for unwrap in [".unwrap()", ".expect(\"\")"] {
        if let Some(r) = rest.strip_prefix(unwrap) {
            return r.trim_start().starts_with(';');
        }
    }
    if let Some(r) = rest.strip_prefix(".unwrap_or_else(") {
        // Skip the balanced closure argument.
        let bytes = r.as_bytes();
        let mut depth = 1i32;
        for (j, &b) in bytes.iter().enumerate() {
            if b == b'(' {
                depth += 1;
            } else if b == b')' {
                depth -= 1;
                if depth == 0 {
                    return r[j + 1..].trim_start().starts_with(';');
                }
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::scan;

    #[test]
    fn receiver_extraction() {
        let code = "let g = self.reservoir.lock().unwrap();";
        let at = code.find(".lock()").unwrap();
        assert_eq!(receiver_before(code, at), "reservoir");
        let code2 = "let mut reg = registry().lock().unwrap();";
        assert_eq!(receiver_before(code2, code2.find(".lock()").unwrap()), "registry");
        let code3 = "slots[i].lock().unwrap();";
        assert_eq!(receiver_before(code3, code3.find(".lock()").unwrap()), "slots");
    }

    #[test]
    fn held_vs_temporary_bindings() {
        let code = "let g = self.hypers.lock().unwrap();";
        let after = code.find(".lock()").unwrap() + ".lock()".len();
        assert!(is_held_binding(code, after));
        let tmp = "let h = self.hypers.lock().unwrap().clone();";
        let after = tmp.find(".lock()").unwrap() + ".lock()".len();
        assert!(!is_held_binding(tmp, after));
        let poisoned = "let rx = rx.lock().unwrap_or_else(|e| e.into_inner());";
        let after = poisoned.find(".lock()").unwrap() + ".lock()".len();
        assert!(is_held_binding(poisoned, after));
        let expr = "self.state.lock().unwrap().pending += 1;";
        let after = expr.find(".lock()").unwrap() + ".lock()".len();
        assert!(!is_held_binding(expr, after));
    }

    #[test]
    fn ordering_counts_accumulate() {
        let f = scan(
            "t.rs",
            "a.store(1, Ordering::Relaxed);\nb.load(Ordering::Acquire); // ORDERING: pairs with store",
        );
        let mut out = Vec::new();
        let c = ordering_audit(&f, false, &mut out);
        assert_eq!(c.relaxed, 1);
        assert_eq!(c.acquire, 1);
        assert!(out.is_empty(), "{out:?}");
    }
}
