//! # In-tree correctness analyzer (`msgp-lint`)
//!
//! A dependency-free static-analysis pass over the crate's own source,
//! run as a blocking CI gate via the `msgp-lint` binary and as the
//! in-crate self-check test. It enforces the concurrency and hot-path
//! invariants the engine relies on but `rustc` cannot see:
//!
//! 1. **unsafe-audit** — every `unsafe` token carries a `SAFETY:`
//!    justification, and the per-file census must match the checked-in
//!    registry (`unsafe_registry.txt`), so new unsafe is an explicit
//!    reviewed diff.
//! 2. **atomic-ordering** — `SeqCst` is denied by default; acquire/
//!    release sites need an `ORDERING:` comment naming their pairing;
//!    inside declared handoff modules even `Relaxed` must be justified.
//! 3. **hot-alloc** — functions marked hot must stay allocation-free
//!    (the PR 3–5 refresh/CG/FFT invariant), with a narrow
//!    `lint:allow(alloc, ...)` escape for audited result assembly.
//! 4. **lock-order** — nested `.lock()` scopes must follow the
//!    declared [`LOCK_ORDER`] ranking.
//! 5. **unwrap-audit** — `.unwrap()` / `.expect(` in serving-path code
//!    (`coordinator/`, `shard/`, `stream/`, `fault/`) must carry a
//!    `PANIC-OK:` justification; unjustified panics either crash a
//!    supervised worker (burning restart budget) or, pre-supervision,
//!    the deployment. See `docs/RELIABILITY.md`.
//!
//! The scanner ([`scan`]) is lexical, not a parser: strings and
//! comments are split off so rule patterns never fire on look-alikes,
//! and `#[cfg(test)]` modules are exempt. See `docs/ANALYSIS.md`.

pub mod rules;
pub mod scan;

use rules::OrderingCounts;
use std::fs;
use std::io;
use std::path::Path;

/// One analyzer diagnostic.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path relative to the crate source root (`/`-separated).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Rule family id (`unsafe-audit`, `atomic-ordering`, `hot-alloc`,
    /// `lock-order`, `unsafe-registry`).
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Files whose atomics form cross-thread handoff protocols: here every
/// ordering — `Relaxed` included — must carry an `ORDERING:` comment.
pub const HANDOFF_FILES: &[&str] = &["parallel/mod.rs", "obs/trace.rs"];

/// The declared lock acquisition order, as (receiver name, rank).
/// A lock may only be taken while locks of *strictly lower* rank are
/// held. Receivers sharing a rank must never nest with each other.
/// Names are the `.lock()` receiver's last path component
/// (`self.reservoir.lock()` → `reservoir`).
pub const LOCK_ORDER: &[(&str, u32)] = &[
    // Shard facade: serializes public ShardedTrainer entry points and
    // is taken before any per-shard state.
    ("ops", 10),
    // Cluster node state: owned-shard statistics, then the replica
    // table. Snapshot/merge paths take them in scoped blocks, never
    // nested — the ranks document the only legal nesting direction.
    ("owned", 12),
    ("replicas", 16),
    // Reservoir snapshots (stream trainer + per-shard workers).
    ("reservoir", 20),
    ("reservoirs", 20),
    // Hyperparameter cells: broadcast under `ops` after reservoirs.
    ("hypers", 30),
    // Leaf locks — never hold anything else while these are held.
    ("state", 40),    // thread-pool scope state
    ("names", 50),    // trace span-site interning
    ("registry", 60), // trace ring registry
    ("rx", 70),       // http worker receive end
    ("slots", 80),    // scope-API slot store
    ("slot", 80),
    // Failpoint registry: a leaf — actions run after the guard drops.
    ("fp_registry", 90),
];

/// True when `rel_path` is a declared handoff module for the
/// atomic-ordering rule.
pub fn is_handoff(rel_path: &str) -> bool {
    HANDOFF_FILES.iter().any(|h| rel_path == *h)
}

/// The checked-in census of audited unsafe sites.
pub const UNSAFE_REGISTRY: &str = include_str!("unsafe_registry.txt");

/// Per-file analysis result.
#[derive(Debug)]
pub struct FileReport {
    pub rel_path: String,
    pub findings: Vec<Finding>,
    /// Non-test `unsafe` tokens in the file.
    pub unsafe_count: usize,
    pub ordering: OrderingCounts,
}

/// Whole-crate analysis result.
#[derive(Debug)]
pub struct CrateReport {
    pub files: Vec<FileReport>,
    /// All findings: per-file rule findings plus registry mismatches.
    pub findings: Vec<Finding>,
    pub unsafe_total: usize,
    pub ordering_total: OrderingCounts,
}

/// Run the five per-file rules on one source text.
pub fn analyze_source(rel_path: &str, src: &str) -> FileReport {
    let file = scan::scan(rel_path, src);
    let mut findings = Vec::new();
    let unsafe_count = rules::unsafe_audit(&file, &mut findings);
    let ordering = rules::ordering_audit(&file, is_handoff(&file.rel_path), &mut findings);
    rules::hot_alloc(&file, &mut findings);
    rules::lock_order(&file, &mut findings);
    rules::unwrap_audit(&file, &mut findings);
    FileReport { rel_path: file.rel_path, findings, unsafe_count, ordering }
}

/// Compare the measured per-file unsafe census against a registry text
/// (`path count` lines, `#` comments). Any drift — new unsafe files,
/// removed files, changed counts — is a finding, so the diff to
/// `unsafe_registry.txt` is always explicit in review.
pub fn check_registry(
    registry: &str,
    counts: &[(String, usize)],
    findings: &mut Vec<Finding>,
) {
    let mut expected: Vec<(&str, usize)> = Vec::new();
    for raw in registry.lines() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(path), Some(n)) = (parts.next(), parts.next()) else {
            continue;
        };
        if let Ok(n) = n.parse::<usize>() {
            expected.push((path, n));
        }
    }
    for &(path, want) in &expected {
        let got = counts
            .iter()
            .find(|(p, _)| p == path)
            .map(|&(_, n)| n)
            .unwrap_or(0);
        if got != want {
            findings.push(Finding {
                file: path.to_string(),
                line: 0,
                rule: "unsafe-registry",
                msg: format!(
                    "registry expects {want} unsafe site(s), source has {got}; \
                     audit the change and update unsafe_registry.txt"
                ),
            });
        }
    }
    for (path, got) in counts {
        if *got > 0 && !expected.iter().any(|(p, _)| p == path) {
            findings.push(Finding {
                file: path.clone(),
                line: 0,
                rule: "unsafe-registry",
                msg: format!(
                    "{got} unsafe site(s) in a file not in unsafe_registry.txt; \
                     audit them and register the file"
                ),
            });
        }
    }
}

/// Walk `src_root` (the crate's `rust/src`), analyze every `.rs` file,
/// and run the registry check. Fixture snippets under
/// `analysis/fixtures/` are rule test-vectors, not crate code, and are
/// skipped.
pub fn analyze_crate(src_root: &Path) -> io::Result<CrateReport> {
    let mut rel_paths = Vec::new();
    collect_rs(src_root, Path::new(""), &mut rel_paths)?;
    rel_paths.sort();
    let mut files = Vec::new();
    let mut findings = Vec::new();
    let mut unsafe_total = 0usize;
    let mut ordering_total = OrderingCounts::default();
    let mut counts = Vec::new();
    for rel in &rel_paths {
        let src = fs::read_to_string(src_root.join(rel))?;
        let report = analyze_source(rel, &src);
        findings.extend(report.findings.iter().cloned());
        unsafe_total += report.unsafe_count;
        ordering_total.add(&report.ordering);
        counts.push((report.rel_path.clone(), report.unsafe_count));
        files.push(report);
    }
    check_registry(UNSAFE_REGISTRY, &counts, &mut findings);
    Ok(CrateReport { files, findings, unsafe_total, ordering_total })
}

fn collect_rs(
    root: &Path,
    rel: &Path,
    out: &mut Vec<String>,
) -> io::Result<()> {
    let dir = root.join(rel);
    for entry in fs::read_dir(&dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy().into_owned();
        let child = rel.join(&name);
        let child_str = child.to_string_lossy().replace('\\', "/");
        if entry.file_type()?.is_dir() {
            if child_str == "analysis/fixtures" {
                continue;
            }
            collect_rs(root, &child, out)?;
        } else if name.ends_with(".rs") {
            out.push(child_str);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings_for(rel: &str, src: &str) -> Vec<Finding> {
        analyze_source(rel, src).findings
    }

    fn rules_hit(findings: &[Finding]) -> Vec<&'static str> {
        let mut r: Vec<_> = findings.iter().map(|f| f.rule).collect();
        r.dedup();
        r
    }

    #[test]
    fn fixture_unsafe_pass() {
        let f = findings_for("fx/unsafe_pass.rs", include_str!("fixtures/unsafe_pass.rs"));
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn fixture_unsafe_fail() {
        let f = findings_for("fx/unsafe_fail.rs", include_str!("fixtures/unsafe_fail.rs"));
        assert!(rules_hit(&f).contains(&"unsafe-audit"), "{f:?}");
    }

    #[test]
    fn fixture_ordering_pass() {
        let f = findings_for(
            "fx/ordering_pass.rs",
            include_str!("fixtures/ordering_pass.rs"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn fixture_ordering_fail() {
        let f = findings_for(
            "fx/ordering_fail.rs",
            include_str!("fixtures/ordering_fail.rs"),
        );
        assert!(rules_hit(&f).contains(&"atomic-ordering"), "{f:?}");
        // Both the bare SeqCst and the unannotated Acquire must fire.
        assert!(f.len() >= 2, "{f:?}");
    }

    #[test]
    fn fixture_ordering_handoff_relaxed() {
        // The same Relaxed store is clean in an ordinary file but must
        // be annotated in a declared handoff module.
        let src = include_str!("fixtures/ordering_pass.rs");
        assert!(findings_for("fx/ordering_pass.rs", src).is_empty());
        let in_handoff = analyze_source("obs/trace.rs", "fn f(a: &AtomicUsize) { a.store(1, Ordering::Relaxed); }");
        assert!(rules_hit(&in_handoff.findings).contains(&"atomic-ordering"));
    }

    #[test]
    fn fixture_hot_alloc_pass() {
        let f = findings_for(
            "fx/hot_alloc_pass.rs",
            include_str!("fixtures/hot_alloc_pass.rs"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn fixture_hot_alloc_fail() {
        let f = findings_for(
            "fx/hot_alloc_fail.rs",
            include_str!("fixtures/hot_alloc_fail.rs"),
        );
        let hits: Vec<_> = f.iter().filter(|x| x.rule == "hot-alloc").collect();
        // vec!, .to_vec(, .clone( and .collect in the hot body; the
        // cold function below the hot one allocates freely.
        assert!(hits.len() >= 4, "{f:?}");
        assert!(!f.iter().any(|x| x.line >= 20), "cold fn was flagged: {f:?}");
    }

    #[test]
    fn fixture_lock_order_pass() {
        let f = findings_for(
            "fx/lock_order_pass.rs",
            include_str!("fixtures/lock_order_pass.rs"),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn fixture_lock_order_fail() {
        let f = findings_for(
            "fx/lock_order_fail.rs",
            include_str!("fixtures/lock_order_fail.rs"),
        );
        assert!(rules_hit(&f).contains(&"lock-order"), "{f:?}");
    }

    #[test]
    fn unwrap_audit_scopes_and_annotations() {
        // Outside the audited prefixes: free.
        let ok = findings_for("solver/cg.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }");
        assert!(ok.is_empty(), "{ok:?}");
        // Inside: denied without justification, for both patterns.
        let f = findings_for("coordinator/server.rs", "fn f(x: Option<u32>) -> u32 { x.unwrap() }");
        assert!(rules_hit(&f).contains(&"unwrap-audit"), "{f:?}");
        let f = findings_for("shard/trainer.rs", "fn f(x: Option<u32>) -> u32 { x.expect(\"set\") }");
        assert!(rules_hit(&f).contains(&"unwrap-audit"), "{f:?}");
        // A leading PANIC-OK: comment within the window satisfies it.
        let src = "fn f(x: Option<u32>) -> u32 {\n    // PANIC-OK: set by construction.\n    x.unwrap()\n}\n";
        assert!(findings_for("stream/trainer.rs", src).is_empty());
        // Poison recovery is not a panic: unwrap_or_else never matches.
        let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap_or_else(|e| e.into_inner()) }";
        assert!(findings_for("fault/failpoint.rs", src).is_empty());
        // Test modules are exempt.
        let t = "#[cfg(test)]\nmod tests {\n    fn t(x: Option<u32>) { x.unwrap(); }\n}\n";
        assert!(findings_for("fault/codec.rs", t).is_empty());
    }

    #[test]
    fn registry_detects_drift_both_ways() {
        let reg = "a.rs 2\nb.rs 1\n";
        let mut f = Vec::new();
        check_registry(
            reg,
            &[("a.rs".into(), 2), ("b.rs".into(), 1)],
            &mut f,
        );
        assert!(f.is_empty(), "{f:?}");
        // Count drift.
        check_registry(reg, &[("a.rs".into(), 3), ("b.rs".into(), 1)], &mut f);
        assert_eq!(f.len(), 1);
        // New unsafe file.
        f.clear();
        check_registry(
            reg,
            &[("a.rs".into(), 2), ("b.rs".into(), 1), ("c.rs".into(), 1)],
            &mut f,
        );
        assert_eq!(f.len(), 1);
        // Registry entry with no unsafe left.
        f.clear();
        check_registry(reg, &[("a.rs".into(), 2)], &mut f);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t(a: &A) { unsafe { a.go() }; a.x.store(1, Ordering::SeqCst); }\n}\n";
        let f = findings_for("fx/t.rs", src);
        assert!(f.is_empty(), "{f:?}");
    }

    /// The gate itself: the crate's own source must be lint-clean.
    /// This is the same check CI runs via `cargo run --bin msgp-lint`.
    #[test]
    fn crate_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
        let report = analyze_crate(&root).expect("walk crate source");
        assert!(report.files.len() > 30, "suspiciously few files scanned");
        let msgs: Vec<String> =
            report.findings.iter().map(|f| f.to_string()).collect();
        assert!(msgs.is_empty(), "crate not lint-clean:\n{}", msgs.join("\n"));
        assert!(report.unsafe_total > 0, "expected audited unsafe sites");
        assert!(report.ordering_total.total() > 0);
    }
}
