//! A lightweight lexical scanner for Rust source: the substrate the
//! [`rules`](super::rules) run on.
//!
//! This is deliberately *not* a parser. The correctness analyzer needs
//! four things done exactly — comment/string stripping (so a deny
//! pattern inside a string literal or a doc comment never fires), brace
//! depth (so scopes and function bodies can be delimited), `#[cfg(test)]`
//! module tracking (test code is exempt from the production rules), and
//! per-line comment text (so `SAFETY:` / `ORDERING:` / `lint:` markers
//! can be matched) — and nothing else. Everything token-level beyond
//! that (raw strings, char-vs-lifetime `'`, nested block comments,
//! escapes) is handled so the four rule families never misfire on
//! lexical look-alikes.

/// One scanned source line.
#[derive(Debug, Clone)]
pub struct Line {
    /// The line's code with comments removed and string/char-literal
    /// *contents* blanked (delimiters kept). Rule patterns match here.
    pub code: String,
    /// The line's comment text (contents of `//`, `///`, and any
    /// `/* .. */` parts, block comments contributing to every line they
    /// span). Marker patterns match here.
    pub comment: String,
    /// Brace depth at the start of the line (code braces only).
    pub depth_start: u32,
    /// Brace depth at the end of the line.
    pub depth_end: u32,
    /// True inside a `#[cfg(test)]` module (attribute line included):
    /// production rules skip these lines.
    pub in_test: bool,
}

/// A scanned file: the unit the rules consume.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the crate source root, `/`-separated.
    pub rel_path: String,
    /// Scanned lines, in order.
    pub lines: Vec<Line>,
}

/// Cross-line lexer mode.
enum Mode {
    Code,
    /// Inside `/* .. */`, with nesting level (Rust block comments nest).
    Block(u32),
    /// Inside a `"` string literal.
    Str,
    /// Inside a raw string `r##"`, with the closing hash count.
    RawStr(u32),
}

/// Scan `src` into lines of separated code and comment text with brace
/// depth and test-module tracking.
pub fn scan(rel_path: &str, src: &str) -> SourceFile {
    let mut mode = Mode::Code;
    let mut depth: u32 = 0;
    let mut lines = Vec::new();
    // `#[cfg(test)]` seen; the next opened brace starts the test region.
    let mut pending_cfg_test = false;
    // Depth inside the active test region (`0` = none).
    let mut test_region_depth: u32 = 0;

    for raw in src.lines() {
        let depth_start = depth;
        let mut code = String::with_capacity(raw.len());
        let mut comment = String::new();
        let chars: Vec<char> = raw.chars().collect();
        let mut i = 0usize;
        while i < chars.len() {
            let c = chars[i];
            match mode {
                Mode::Code => {
                    if c == '/' && chars.get(i + 1) == Some(&'/') {
                        comment.push_str(&raw_tail(&chars, i + 2));
                        break;
                    }
                    if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(1);
                        i += 2;
                        continue;
                    }
                    if c == '"' {
                        code.push('"');
                        mode = Mode::Str;
                        i += 1;
                        continue;
                    }
                    if c == 'r' && !prev_is_ident(&code) {
                        // Possible raw string: `r"` or `r#..#"`.
                        let mut j = i + 1;
                        let mut hashes = 0u32;
                        while chars.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        if chars.get(j) == Some(&'"') {
                            code.push('"');
                            mode = Mode::RawStr(hashes);
                            i = j + 1;
                            continue;
                        }
                    }
                    if c == '\'' {
                        // Char literal vs lifetime: a backslash or a
                        // closing quote two ahead means char literal.
                        if chars.get(i + 1) == Some(&'\\') {
                            // Escaped char literal: skip to closing quote.
                            let mut j = i + 2;
                            if chars.get(j).is_some() {
                                j += 1; // the escaped character
                            }
                            while j < chars.len() && chars[j] != '\'' {
                                j += 1;
                            }
                            code.push('\'');
                            code.push('\'');
                            i = j + 1;
                            continue;
                        }
                        if chars.get(i + 2) == Some(&'\'') {
                            code.push('\'');
                            code.push('\'');
                            i += 3;
                            continue;
                        }
                        // Lifetime (or stray quote): keep as code.
                        code.push('\'');
                        i += 1;
                        continue;
                    }
                    if c == '{' {
                        depth += 1;
                    }
                    if c == '}' {
                        depth = depth.saturating_sub(1);
                        // Leaving the test region?
                        if test_region_depth > 0 && depth < test_region_depth {
                            // Mark the closing line below (flag still set
                            // when the line record is built).
                        }
                    }
                    code.push(c);
                    i += 1;
                }
                Mode::Block(level) => {
                    if c == '*' && chars.get(i + 1) == Some(&'/') {
                        if level == 1 {
                            mode = Mode::Code;
                        } else {
                            mode = Mode::Block(level - 1);
                        }
                        i += 2;
                        continue;
                    }
                    if c == '/' && chars.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(level + 1);
                        i += 2;
                        continue;
                    }
                    comment.push(c);
                    i += 1;
                }
                Mode::Str => {
                    if c == '\\' {
                        i += 2; // skip the escaped character
                        continue;
                    }
                    if c == '"' {
                        code.push('"');
                        mode = Mode::Code;
                    }
                    i += 1;
                }
                Mode::RawStr(hashes) => {
                    if c == '"' {
                        let mut ok = true;
                        for k in 0..hashes as usize {
                            if chars.get(i + 1 + k) != Some(&'#') {
                                ok = false;
                                break;
                            }
                        }
                        if ok {
                            code.push('"');
                            mode = Mode::Code;
                            i += 1 + hashes as usize;
                            continue;
                        }
                    }
                    i += 1;
                }
            }
        }

        // Test-region bookkeeping (on the stripped code).
        let mut in_test = test_region_depth > 0;
        if code.contains("#[cfg(test)]") {
            pending_cfg_test = true;
            in_test = true;
        } else if pending_cfg_test && depth > depth_start {
            // First brace after the attribute opens the test module.
            test_region_depth = depth_start + 1;
            pending_cfg_test = false;
            in_test = true;
        } else if pending_cfg_test {
            // Attribute not yet attached to a braced item (e.g. the
            // `mod tests` line split); keep waiting, mark the gap.
            in_test = true;
        }
        if test_region_depth > 0 && depth < test_region_depth {
            // This line closed the test module; it is still test code.
            in_test = true;
            test_region_depth = 0;
        }

        lines.push(Line { code, comment, depth_start, depth_end: depth, in_test });
    }
    SourceFile { rel_path: rel_path.replace('\\', "/"), lines }
}

fn raw_tail(chars: &[char], from: usize) -> String {
    chars[from.min(chars.len())..].iter().collect()
}

fn prev_is_ident(code: &str) -> bool {
    code.chars().last().is_some_and(|p| p.is_alphanumeric() || p == '_')
}

/// True when `line`'s code contains `word` as a standalone word (not a
/// substring of a longer identifier).
pub fn code_has_word(code: &str, word: &str) -> bool {
    find_word(code, word, 0).is_some()
}

/// Byte offset of the next standalone occurrence of `word` in `code` at
/// or after `from`. A boundary is only required on a side where the
/// pattern itself ends in an identifier character — `.clone(` matches
/// after any receiver, while `unsafe` must not match `not_unsafe_fn`.
pub fn find_word(code: &str, word: &str, from: usize) -> Option<usize> {
    let bytes = code.as_bytes();
    let first_ident = word.as_bytes().first().is_some_and(|&b| is_ident_byte(b));
    let last_ident = word.as_bytes().last().is_some_and(|&b| is_ident_byte(b));
    let mut start = from;
    while let Some(pos) = code.get(start..).and_then(|s| s.find(word)) {
        let at = start + pos;
        let before_ok = !first_ident || at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + word.len();
        let after_ok = !last_ident || end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return Some(at);
        }
        start = at + 1;
    }
    None
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_stripped() {
        let f = scan(
            "t.rs",
            "let x = \"unsafe Ordering::SeqCst { }\"; // unsafe in comment\nlet y = 1;",
        );
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(!f.lines[0].code.contains('{'));
        assert!(f.lines[0].comment.contains("unsafe in comment"));
        assert_eq!(f.lines[0].depth_end, 0);
        assert_eq!(f.lines[1].code.trim(), "let y = 1;");
    }

    #[test]
    fn raw_strings_and_char_literals_do_not_confuse_depth() {
        let src = "let a = r#\"{ } \"quoted\" { \"#;\nlet b = '{';\nlet c = '}';\nlet l: &'static str = \"x\";\nfn f() { let q = '\\''; }";
        let f = scan("t.rs", src);
        for l in &f.lines[..4] {
            assert_eq!(l.depth_end, 0, "line {:?}", l.code);
        }
        assert_eq!(f.lines[4].depth_end, 0);
        assert!(f.lines[3].code.contains("&'static"));
    }

    #[test]
    fn block_comments_span_lines_and_nest() {
        let src = "/* outer { /* inner } */ still } comment */ let x = 1; { }";
        let f = scan("t.rs", src);
        assert!(f.lines[0].comment.contains("still"));
        assert!(f.lines[0].code.contains("let x = 1;"));
        assert_eq!(f.lines[0].depth_end, 0);
        let f2 = scan("t.rs", "/* a\nb { }\nc */ fn g() {");
        assert_eq!(f2.lines[1].depth_end, 0);
        assert!(f2.lines[1].comment.contains('b'));
        assert_eq!(f2.lines[2].depth_end, 1);
    }

    #[test]
    fn cfg_test_modules_are_flagged() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let x = 1; }\n}\nfn prod2() {}";
        let f = scan("t.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test, "attribute line");
        assert!(f.lines[2].in_test && f.lines[3].in_test && f.lines[4].in_test);
        assert!(!f.lines[5].in_test, "code after the test module is production");
    }

    #[test]
    fn word_matching_requires_boundaries() {
        assert!(code_has_word("unsafe {", "unsafe"));
        assert!(!code_has_word("not_unsafe_fn()", "unsafe"));
        assert!(code_has_word("x.clone();", ".clone("));
        assert_eq!(find_word("a unsafe b unsafe", "unsafe", 9), Some(11));
    }
}
