//! Local cubic kernel interpolation (KISS-GP's sparse `W`, section 4).
//!
//! Each data/test point is expressed as a cubic-convolution interpolation
//! (Keys, 1981) of the `4^D` surrounding grid points, giving extremely
//! sparse interpolation matrices `W` with exactly `4^D` non-zeros per row.
//! MVMs `W v` (gather) and `W^T v` (scatter) cost O(n 4^D).
//!
//! The interpolation weights are differentiable in the (projected) input
//! coordinates — the derivative rows are what makes supervised projection
//! learning (section 5.4) tractable under SKI.

use crate::grid::Grid;

/// Keys cubic-convolution kernel with `a = -1/2` (the classical choice).
#[inline]
pub fn keys_h(s: f64) -> f64 {
    let t = s.abs();
    if t < 1.0 {
        (1.5 * t - 2.5) * t * t + 1.0
    } else if t < 2.0 {
        ((-0.5 * t + 2.5) * t - 4.0) * t + 2.0
    } else {
        0.0
    }
}

/// Derivative of [`keys_h`] with respect to `s`.
#[inline]
pub fn keys_dh(s: f64) -> f64 {
    let t = s.abs();
    let sign = if s >= 0.0 { 1.0 } else { -1.0 };
    if t < 1.0 {
        sign * ((4.5 * t - 5.0) * t)
    } else if t < 2.0 {
        sign * ((-1.5 * t + 5.0) * t - 4.0)
    } else {
        0.0
    }
}

/// Per-dimension stencil: 4 grid indices and their weights (and weight
/// derivatives with respect to the coordinate, in *grid units*).
#[derive(Clone, Copy, Debug)]
pub struct Stencil1D {
    /// Leftmost grid index of the 4-point stencil.
    pub i0: usize,
    /// Weights for taps `i0 .. i0+3`.
    pub w: [f64; 4],
    /// `dw/du` (u in grid units) for each tap.
    pub dw: [f64; 4],
}

/// Compute the 1-D cubic stencil for a coordinate `u` in grid units on an
/// axis with `n` points. The stencil is shifted inward near the boundary
/// (callers should build grids with >= 2 cells of margin so this never
/// matters for training data).
pub fn stencil_1d(u: f64, n: usize) -> Stencil1D {
    assert!(n >= 4, "cubic interpolation needs >= 4 grid points per axis");
    let i = u.floor() as isize;
    let i0 = (i - 1).clamp(0, n as isize - 4) as usize;
    let mut w = [0.0; 4];
    let mut dw = [0.0; 4];
    for j in 0..4 {
        let s = u - (i0 + j) as f64;
        w[j] = keys_h(s);
        dw[j] = keys_dh(s);
    }
    Stencil1D { i0, w, dw }
}

/// Visit the `4^D` tensor-product taps of one point's interpolation row
/// without materializing a [`SparseInterp`]: `f(flat, weight, idx)` is
/// called once per tap, where `idx` holds the per-dimension grid indices
/// of that tap. Tap order and arithmetic are identical to
/// [`SparseInterp::build`], so streaming accumulators built tap-by-tap
/// match a from-scratch batch build bit-for-bit up to summation order.
// lint:hot
pub fn for_each_tap(point: &[f64], grid: &Grid, mut f: impl FnMut(usize, f64, &[usize])) {
    /// Fixed scratch bound — keeps this per-point hot path free of heap
    /// allocation (the streaming ingester calls it once per observation).
    const MAX_D: usize = 8;
    let d = grid.dim();
    debug_assert_eq!(point.len(), d);
    assert!(d <= MAX_D, "for_each_tap supports up to {MAX_D} dimensions (got {d})");
    let nnz = 4usize.pow(d as u32);
    let mut stencils = [Stencil1D { i0: 0, w: [0.0; 4], dw: [0.0; 4] }; MAX_D];
    for (a, st) in stencils[..d].iter_mut().enumerate() {
        let u = grid.axes[a].to_units(point[a]);
        *st = stencil_1d(u, grid.axes[a].n);
    }
    let mut idx = [0usize; MAX_D];
    for t in 0..nnz {
        let mut flat = 0usize;
        let mut w = 1.0f64;
        for (a, st) in stencils[..d].iter().enumerate() {
            let j = (t >> (2 * (d - 1 - a))) & 3;
            idx[a] = st.i0 + j;
            flat = flat * grid.axes[a].n + (st.i0 + j);
            w *= st.w[j];
        }
        f(flat, w, &idx[..d]);
    }
}

/// A sparse interpolation matrix `W` (`rows x m`) with exactly `4^D`
/// non-zeros per row, stored row-compressed with fixed row width.
#[derive(Clone, Debug)]
pub struct SparseInterp {
    /// Number of rows (data/test points).
    pub rows: usize,
    /// Number of columns (grid points `m`).
    pub cols: usize,
    /// Non-zeros per row (`4^D`).
    pub nnz_per_row: usize,
    /// Column indices, `rows * nnz_per_row`.
    pub col_idx: Vec<u32>,
    /// Values, `rows * nnz_per_row`.
    pub vals: Vec<f64>,
}

impl SparseInterp {
    /// Build the interpolation matrix for `points` (row-major `rows x D`)
    /// against `grid`.
    pub fn build(points: &[f64], grid: &Grid) -> Self {
        let d = grid.dim();
        assert!(points.len() % d == 0);
        let rows = points.len() / d;
        let nnz = 4usize.pow(d as u32);
        let m = grid.m();
        let shape = grid.shape();
        let mut col_idx = vec![0u32; rows * nnz];
        let mut vals = vec![0.0f64; rows * nnz];
        let mut stencils = vec![
            Stencil1D { i0: 0, w: [0.0; 4], dw: [0.0; 4] };
            d
        ];
        for r in 0..rows {
            for (a, st) in stencils.iter_mut().enumerate() {
                let u = grid.axes[a].to_units(points[r * d + a]);
                *st = stencil_1d(u, shape[a]);
            }
            // Tensor product over the D stencils.
            let base = r * nnz;
            for t in 0..nnz {
                let mut flat = 0usize;
                let mut w = 1.0f64;
                for (a, st) in stencils.iter().enumerate() {
                    let j = (t >> (2 * (d - 1 - a))) & 3;
                    flat = flat * shape[a] + (st.i0 + j);
                    w *= st.w[j];
                }
                debug_assert!(flat < m);
                col_idx[base + t] = flat as u32;
                vals[base + t] = w;
            }
        }
        SparseInterp { rows, cols: m, nnz_per_row: nnz, col_idx, vals }
    }

    /// Build both `W` and, for each input dimension `a`, the derivative
    /// matrix `dW/du_a` (coordinate in physical units — the grid-unit
    /// derivative is scaled by `1/step_a`). Returns `(W, [dW_a])`.
    pub fn build_with_grad(points: &[f64], grid: &Grid) -> (Self, Vec<Self>) {
        let d = grid.dim();
        let rows = points.len() / d;
        let nnz = 4usize.pow(d as u32);
        let m = grid.m();
        let shape = grid.shape();
        let mut w_mat = SparseInterp {
            rows,
            cols: m,
            nnz_per_row: nnz,
            col_idx: vec![0u32; rows * nnz],
            vals: vec![0.0f64; rows * nnz],
        };
        let mut grads: Vec<SparseInterp> = (0..d).map(|_| w_mat.clone()).collect();
        let mut stencils = vec![Stencil1D { i0: 0, w: [0.0; 4], dw: [0.0; 4] }; d];
        for r in 0..rows {
            for (a, st) in stencils.iter_mut().enumerate() {
                let u = grid.axes[a].to_units(points[r * d + a]);
                *st = stencil_1d(u, shape[a]);
            }
            let base = r * nnz;
            for t in 0..nnz {
                let mut flat = 0usize;
                let mut w = 1.0f64;
                let mut taps = [0usize; 8];
                for (a, st) in stencils.iter().enumerate() {
                    let j = (t >> (2 * (d - 1 - a))) & 3;
                    taps[a] = j;
                    flat = flat * shape[a] + (st.i0 + j);
                    w *= st.w[j];
                }
                w_mat.col_idx[base + t] = flat as u32;
                w_mat.vals[base + t] = w;
                for (g, grad) in grads.iter_mut().enumerate() {
                    // Product rule: replace factor g's weight by its
                    // derivative; scale to physical units.
                    let mut dw = 1.0f64;
                    for (a, st) in stencils.iter().enumerate() {
                        let j = taps[a];
                        dw *= if a == g { st.dw[j] } else { st.w[j] };
                    }
                    grad.col_idx[base + t] = flat as u32;
                    grad.vals[base + t] = dw / grid.axes[g].step;
                }
            }
        }
        (w_mat, grads)
    }

    /// Gather MVM: `out = W v`, `v` of length `cols`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(v, &mut out);
        out
    }

    /// Allocation-free gather MVM.
    pub fn matvec_into(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.cols);
        assert_eq!(out.len(), self.rows);
        let nnz = self.nnz_per_row;
        for (r, o) in out.iter_mut().enumerate() {
            let base = r * nnz;
            let mut s = 0.0;
            for t in 0..nnz {
                s += self.vals[base + t] * v[self.col_idx[base + t] as usize];
            }
            *o = s;
        }
    }

    /// Scatter MVM: `out = W^T v`, `v` of length `rows`.
    pub fn tmatvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        self.tmatvec_into(v, &mut out);
        out
    }

    /// Allocation-free scatter MVM (zeroes `out` first).
    pub fn tmatvec_into(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(v.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        let nnz = self.nnz_per_row;
        for (r, &vr) in v.iter().enumerate() {
            let base = r * nnz;
            for t in 0..nnz {
                out[self.col_idx[base + t] as usize] += self.vals[base + t] * vr;
            }
        }
    }

    /// Dot product of row `r` with a dense vector.
    pub fn row_dot(&self, r: usize, v: &[f64]) -> f64 {
        let base = r * self.nnz_per_row;
        let mut s = 0.0;
        for t in 0..self.nnz_per_row {
            s += self.vals[base + t] * v[self.col_idx[base + t] as usize];
        }
        s
    }

    /// Sum of each row's weights (should be ~1 away from boundaries —
    /// cubic convolution is a partition of unity).
    pub fn row_sums(&self) -> Vec<f64> {
        (0..self.rows)
            .map(|r| {
                let base = r * self.nnz_per_row;
                self.vals[base..base + self.nnz_per_row].iter().sum()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridAxis;

    #[test]
    fn keys_partition_of_unity() {
        for i in 0..50 {
            let s = i as f64 * 0.02; // fractional offset in [0, 1)
            let sum = keys_h(s + 1.0) + keys_h(s) + keys_h(s - 1.0) + keys_h(s - 2.0);
            assert!((sum - 1.0).abs() < 1e-12, "s={s} sum={sum}");
        }
    }

    #[test]
    fn keys_interpolates_exactly_at_nodes() {
        assert!((keys_h(0.0) - 1.0).abs() < 1e-15);
        assert!(keys_h(1.0).abs() < 1e-15);
        assert!(keys_h(2.0).abs() < 1e-15);
    }

    #[test]
    fn keys_dh_is_derivative() {
        for &s in &[-1.7, -0.9, -0.3, 0.2, 0.7, 1.4, 1.9] {
            let eps = 1e-6;
            let fd = (keys_h(s + eps) - keys_h(s - eps)) / (2.0 * eps);
            assert!((keys_dh(s) - fd).abs() < 1e-8, "s={s}");
        }
    }

    #[test]
    fn cubic_reproduces_quadratics_1d() {
        // Keys cubic convolution (a = -1/2) is third-order accurate: it
        // reproduces polynomials up to degree 2 exactly (away from
        // boundaries), and cubics to O(h^3).
        let grid = Grid::new(vec![GridAxis::span(0.0, 10.0, 21)]);
        let f = |x: f64| -0.7 * x * x + 2.0 * x - 5.0;
        let gv: Vec<f64> = (0..21).map(|i| f(grid.axes[0].coord(i))).collect();
        let pts: Vec<f64> = (0..40).map(|i| 1.5 + i as f64 * 0.17).collect();
        let w = SparseInterp::build(&pts, &grid);
        let got = w.matvec(&gv);
        for (g, &x) in got.iter().zip(&pts) {
            assert!((g - f(x)).abs() < 1e-9, "x={x}: {g} vs {}", f(x));
        }
    }

    #[test]
    fn cubic_interp_error_is_third_order() {
        // Halving the grid step must shrink the interpolation error of a
        // smooth function by ~8x (O(h^3) convergence).
        let f = |x: f64| (1.3 * x).sin();
        let err_at = |n: usize| -> f64 {
            let grid = Grid::new(vec![GridAxis::span(0.0, 10.0, n)]);
            let gv: Vec<f64> = (0..n).map(|i| f(grid.axes[0].coord(i))).collect();
            let pts: Vec<f64> = (0..50).map(|i| 2.0 + i as f64 * 0.12).collect();
            let w = SparseInterp::build(&pts, &grid);
            w.matvec(&gv)
                .iter()
                .zip(&pts)
                .map(|(g, &x)| (g - f(x)).abs())
                .fold(0.0f64, f64::max)
        };
        let e1 = err_at(41);
        let e2 = err_at(81);
        assert!(e2 < e1 / 5.0, "e1={e1} e2={e2}");
    }

    #[test]
    fn cubic_reproduces_bilinear_2d() {
        let grid = Grid::new(vec![GridAxis::span(0.0, 4.0, 9), GridAxis::span(0.0, 4.0, 9)]);
        let f = |x: f64, y: f64| 2.0 * x - y + 0.5 * x * y + 1.0;
        let mut gv = vec![0.0; grid.m()];
        for (i, g) in gv.iter_mut().enumerate() {
            let p = grid.point(i);
            *g = f(p[0], p[1]);
        }
        let pts = vec![1.3, 2.7, 2.05, 1.15, 3.0, 3.0, 1.0, 2.5];
        let w = SparseInterp::build(&pts, &grid);
        assert_eq!(w.nnz_per_row, 16);
        let got = w.matvec(&gv);
        for (r, g) in got.iter().enumerate() {
            let (x, y) = (pts[r * 2], pts[r * 2 + 1]);
            assert!((g - f(x, y)).abs() < 1e-9);
        }
    }

    #[test]
    fn for_each_tap_matches_built_rows() {
        let grid = Grid::new(vec![GridAxis::span(0.0, 5.0, 12), GridAxis::span(-2.0, 2.0, 9)]);
        let pts = vec![1.3, -0.7, 4.1, 1.6, 0.4, 0.0];
        let w = SparseInterp::build(&pts, &grid);
        for r in 0..3 {
            let mut taps: Vec<(usize, f64)> = Vec::new();
            for_each_tap(&pts[r * 2..r * 2 + 2], &grid, |flat, wt, idx| {
                // flat must agree with the row-major multi-index.
                assert_eq!(flat, grid.flat(idx));
                taps.push((flat, wt));
            });
            assert_eq!(taps.len(), w.nnz_per_row);
            let base = r * w.nnz_per_row;
            for (t, &(flat, wt)) in taps.iter().enumerate() {
                assert_eq!(flat as u32, w.col_idx[base + t]);
                assert!((wt - w.vals[base + t]).abs() == 0.0, "tap {t} differs");
            }
        }
    }

    #[test]
    fn tmatvec_is_transpose_of_matvec() {
        let grid = Grid::new(vec![GridAxis::span(-1.0, 1.0, 8)]);
        let pts: Vec<f64> = (0..5).map(|i| -0.6 + 0.3 * i as f64).collect();
        let w = SparseInterp::build(&pts, &grid);
        // <W v, u> == <v, W^T u> for random v, u.
        let v: Vec<f64> = (0..8).map(|i| (i as f64).sin()).collect();
        let u: Vec<f64> = (0..5).map(|i| (i as f64).cos()).collect();
        let wv = w.matvec(&v);
        let wtu = w.tmatvec(&u);
        let lhs: f64 = wv.iter().zip(&u).map(|(a, b)| a * b).sum();
        let rhs: f64 = v.iter().zip(&wtu).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-12);
    }

    #[test]
    fn gradient_rows_match_finite_differences() {
        let grid = Grid::new(vec![GridAxis::span(0.0, 5.0, 12), GridAxis::span(0.0, 5.0, 12)]);
        let gv: Vec<f64> = (0..grid.m()).map(|i| ((i * 13 % 17) as f64) * 0.1).collect();
        let pt = [2.3f64, 1.7];
        let (_, grads) = SparseInterp::build_with_grad(&pt, &grid);
        for a in 0..2 {
            let eps = 1e-6;
            let mut pp = pt;
            pp[a] += eps;
            let mut pm = pt;
            pm[a] -= eps;
            let wp = SparseInterp::build(&pp, &grid).matvec(&gv)[0];
            let wm = SparseInterp::build(&pm, &grid).matvec(&gv)[0];
            let fd = (wp - wm) / (2.0 * eps);
            let an = grads[a].matvec(&gv)[0];
            assert!((an - fd).abs() < 1e-6, "dim {a}: {an} vs {fd}");
        }
    }

    #[test]
    fn row_sums_are_one_in_interior() {
        let grid = Grid::new(vec![GridAxis::span(0.0, 1.0, 16)]);
        let pts: Vec<f64> = (0..20).map(|i| 0.2 + 0.03 * i as f64).collect();
        let w = SparseInterp::build(&pts, &grid);
        for s in w.row_sums() {
            assert!((s - 1.0).abs() < 1e-12);
        }
    }
}
