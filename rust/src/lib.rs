//! # MSGP — Massively Scalable Gaussian Processes
//!
//! A Rust reproduction of *"Thoughts on Massively Scalable Gaussian
//! Processes"* (Wilson, Dann & Nickisch, 2015), built as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate provides:
//!
//! * **Structure-exploiting linear algebra** ([`structure`]): Toeplitz,
//!   circulant (with Strang / T. Chan / Tyrtyshnikov / Helgason / Whittle
//!   approximations), Kronecker, and BTTB/BCCB operators, all built on an
//!   in-crate FFT ([`linalg::fft`]) with a batched multi-RHS engine:
//!   cache-blocked panel transforms over `[batch, shape...]` tensors, a
//!   **true real-input rfft** (length-`n/2` last-axis transforms with
//!   half-form conjugate-symmetric spectra on even axes, two-for-one
//!   real-pair packing otherwise), and allocation-free `matvec_batch`
//!   paths on every operator (a size-capped thread-local plan cache
//!   keeps twiddle / bit-reversal setup amortized).
//! * **In-tree parallel execution** ([`parallel`]): a dependency-free
//!   scoped thread pool (`std::thread` workers, chunked work queue,
//!   `scope(|s| ...)`-style API, `MSGP_THREADS` / [`parallel::configure`]
//!   override). The batched FFT engine dispatches line chunks, strided
//!   panels, and real-block row splits onto it — so every structured
//!   MVM, the spectral preconditioner, and the block-CG refresh use all
//!   cores *within* one solve, composing with (not oversubscribing) the
//!   process-level shard workers. Tasks do bit-identical arithmetic on
//!   disjoint slices, so results are independent of the thread count.
//! * **Local cubic kernel interpolation** ([`interp`]) à la KISS-GP:
//!   sparse interpolation matrices `W` with `4^D` entries per row.
//! * **GP models** ([`gp`]): the MSGP model itself (SKI kernel, CG
//!   inference, Whittle log-determinant kernel learning, O(1) fast
//!   predictive mean/variance, supervised projections) plus exact-GP,
//!   FITC, SSGP and SVI (Big-Data-GP) baselines.
//! * **A serving coordinator** ([`coordinator`]): a thread-backed request
//!   router and dynamic batcher that serves trained MSGP models, backed
//!   either by the native Rust engine or by AOT-compiled JAX/Pallas
//!   artifacts executed through PJRT ([`runtime`]).
//! * **Streaming & online learning** ([`stream`]): the SKI data
//!   dependence factors through grid-local sufficient statistics
//!   (`W^T y`, the banded Gram `W^T W`, per-cell counts, and exact
//!   `N(0, W^T W)` probe accumulators), so new observations are absorbed
//!   in O(4^D) each — no pass over historical data. A push-through
//!   identity moves the training solves into the m-domain
//!   (`u_mean = sf2 S (sigma^2 I + sf2 S G S)^{-1} S W^T y` with
//!   `S = K_UU^{1/2}`), making refresh cost independent of n; CG
//!   warm-starts from the previous solution, the grid auto-expands under
//!   out-of-box points, and hyperparameters re-optimize periodically on
//!   a reservoir snapshot. The coordinator's `/ingest` route feeds a
//!   background trainer thread that atomically hot-swaps refreshed
//!   snapshots into the live [`coordinator::state::ModelSlot`], so
//!   prediction latency stays O(1) per point throughout. Non-stationary
//!   streams can down-weight history with exponential forgetting
//!   ([`stream::StreamTrainer::decay`]), and refresh solves run under a
//!   pluggable [`solver::Preconditioner`] — `Jacobi` (diagonal from the
//!   tracked `diag(W^T W)`) or `Spectral` (the default: a BCCB
//!   approximate inverse of the m-domain operator applied in
//!   O(m log m) via the multi-level circulant eigendecomposition).
//!   Each refresh solves the mean and all `n_s` variance-probe systems
//!   as **one lockstep block-CG solve** ([`solver::cg_solve_block`])
//!   with per-column convergence masking: one batched operator /
//!   preconditioner application per iteration instead of `n_s + 1`
//!   sequential solves.
//! * **Sharded data-parallel training & serving** ([`shard`]): the
//!   sufficient statistics are additive, so a [`shard::ShardPlan`]
//!   splits the inducing grid into S spatial slabs (with halo overlap
//!   for stencil exactness), a [`shard::ShardedTrainer`] runs one
//!   trainer thread per shard (refresh wall-clock O(m/S) per core),
//!   per-shard statistics merge exactly into a whole-domain snapshot
//!   for global hyper re-optimization, and [`shard::ShardedServing`]
//!   routes each prediction to its owning shard in O(1), blending
//!   across seams with partition-of-unity weights.
//!
//! * **Observability** ([`obs`]): dependency-free tracing
//!   (`span!`-guarded scopes on per-thread lock-free ring buffers,
//!   exported as Chrome trace-event JSON via `/trace` and
//!   [`obs::Tracer::dump_json`]; one atomic-load branch when disabled),
//!   typed metric primitives behind the coordinator's `/metrics` route
//!   (legacy one-line summary plus Prometheus text exposition at
//!   `/metrics?format=prom`, with per-shard labels and per-stage
//!   refresh gauges), a `/healthz` readiness probe, an `MSGP_LOG`-gated
//!   leveled logger, and a bench recorder persisting `BENCH_*.json`
//!   artifacts ([`bench::recorder`]). See `docs/METRICS.md`.
//! * **A real HTTP front door** ([`coordinator::http`]): a
//!   dependency-free HTTP/1.1 transport (`std::net::TcpListener`,
//!   worker pool, keep-alive, request pipelining, bounded accept queue
//!   with inline 503 shedding, graceful shutdown) serving every route
//!   over actual sockets — `POST /predict` / `POST /ingest` with JSON
//!   bodies, query-aware GET routes (`/metrics?format=prom`,
//!   `/shards?verbose=1`, `/trace?clear=1`). Each connection and
//!   request carries a monotone id into the trace spans (`http.accept`
//!   / `http.request`), per-route latency histograms and status/error
//!   counters land in the `http_*` metric families, and slow requests
//!   log through `MSGP_SLOW_MS`. The [`bench::loadgen`] harness (and
//!   the `loadgen` binary) drives open- or closed-loop predict/ingest
//!   mixes against it, recording p50/p99/p999 + sustained QPS into
//!   `BENCH_fig9_serving.json`. See `examples/serving.rs`.
//!
//! * **Fault tolerance** ([`fault`]): a dependency-free failpoint
//!   framework (`failpoint!` sites costing one relaxed atomic load when
//!   disarmed, armed via `MSGP_FAILPOINTS` or `GET /failpoints`),
//!   supervised serving workers (catch-unwind restart loops with capped
//!   exponential backoff + jitter, poisoning after repeated failures,
//!   `worker_restarts_total{worker}` metrics), refresh deadlines
//!   (`MSGP_REFRESH_DEADLINE_MS` aborts block-CG between iterations and
//!   keeps serving the last-good snapshot under a `degraded_mode`
//!   gauge), and crash-safe checkpoint/restore: a versioned,
//!   checksummed binary codec for the additive SKI statistics (+ hypers
//!   + grid + RNG state) written atomically on ingest-count/interval
//!   triggers, recovered newest-valid at startup — a SIGKILL'd process
//!   restarts bit-compatible with the uninterrupted run. See
//!   `docs/RELIABILITY.md`.
//! * **Multi-process clustering** ([`cluster`]): each node owns an
//!   interleaved stripe of the shard slabs and streams framed,
//!   checksummed deltas of the additive statistics to its peers over
//!   plain TCP — epoch-watermarked idempotent application, bounded
//!   outbound queues whose overflow (like any send error) triggers
//!   reconnect-with-full-resync, heartbeat failure detection with
//!   per-peer `peer_*` metrics, bounded-staleness serving from local
//!   replicas when an owner is down (`X-Msgp-Staleness`), and
//!   restart-mid-stream recovery (own checkpoint → `SyncRequest`
//!   catch-up from any peer). See `docs/CLUSTER.md`.
//! * **In-tree correctness analyzer** ([`analysis`] + the `msgp-lint`
//!   binary): a dependency-free static-analysis gate over the crate's
//!   own source enforcing the invariants `rustc` cannot — audited
//!   `unsafe` (SAFETY comments + a checked-in census), an
//!   atomic-ordering policy (no bare `SeqCst`; annotated handoff
//!   sites), allocation-free hot paths (`lint:hot` functions), and a
//!   declared lock-acquisition order. CI runs it as a blocking step
//!   and pairs it with nightly Miri / ThreadSanitizer jobs over the
//!   concurrency suite. See `docs/ANALYSIS.md`.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-reproduction results.

// Index-driven loops over grid cells frequently read clearer than
// iterator chains in the numeric kernels; keep clippy focused on the
// lints that catch real defects.
#![allow(clippy::needless_range_loop)]
// Every unsafe operation must sit in its own audited `unsafe { .. }`
// block, even inside `unsafe fn` — msgp-lint requires a SAFETY comment
// per block, so the justification granularity matches the operation.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analysis;
pub mod linalg;
pub mod parallel;
pub mod structure;
pub mod grid;
pub mod interp;
pub mod kernels;
pub mod solver;
pub mod opt;
pub mod gp;
pub mod cluster;
pub mod coordinator;
pub mod stream;
pub mod shard;
pub mod runtime;
pub mod fault;
pub mod obs;
pub mod bench;
pub mod data;
pub mod util;
