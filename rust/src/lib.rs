//! # MSGP — Massively Scalable Gaussian Processes
//!
//! A Rust reproduction of *"Thoughts on Massively Scalable Gaussian
//! Processes"* (Wilson, Dann & Nickisch, 2015), built as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The crate provides:
//!
//! * **Structure-exploiting linear algebra** ([`structure`]): Toeplitz,
//!   circulant (with Strang / T. Chan / Tyrtyshnikov / Helgason / Whittle
//!   approximations), Kronecker, and BTTB/BCCB operators, all built on an
//!   in-crate FFT ([`linalg::fft`]).
//! * **Local cubic kernel interpolation** ([`interp`]) à la KISS-GP:
//!   sparse interpolation matrices `W` with `4^D` entries per row.
//! * **GP models** ([`gp`]): the MSGP model itself (SKI kernel, CG
//!   inference, Whittle log-determinant kernel learning, O(1) fast
//!   predictive mean/variance, supervised projections) plus exact-GP,
//!   FITC, SSGP and SVI (Big-Data-GP) baselines.
//! * **A serving coordinator** ([`coordinator`]): a tokio-based request
//!   router and dynamic batcher that serves trained MSGP models, backed
//!   either by the native Rust engine or by AOT-compiled JAX/Pallas
//!   artifacts executed through PJRT ([`runtime`]).
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-reproduction results.

pub mod linalg;
pub mod structure;
pub mod grid;
pub mod interp;
pub mod kernels;
pub mod solver;
pub mod opt;
pub mod gp;
pub mod coordinator;
pub mod runtime;
pub mod bench;
pub mod data;
pub mod util;
