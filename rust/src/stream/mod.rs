//! Online streaming: incremental SKI ingestion with warm-started solves.
//!
//! The SKI decomposition `K_XX ~= W K_UU W^T` (section 5) makes the
//! model's data dependence factor through two *grid-local sufficient
//! statistics*:
//!
//! * `b = W^T y` — the interpolated target accumulator, and
//! * `G = W^T W` — the grid Gram matrix, banded with `7^D` diagonals
//!   because two interpolation rows only overlap when their points fall
//!   within 3 grid cells of each other per dimension.
//!
//! Both absorb a new observation in O(4^D) — no retraining pass over the
//! data. The push-through identity
//!
//! ```text
//! W^T (sigma^2 I + sf2 W K W^T)^{-1} = (sigma^2 I + sf2 G K)^{-1} W^T
//! ```
//!
//! then moves *every* training-time solve from the n-domain to the
//! m-domain: with `S = K^{1/2}` (the symmetric circulant square root,
//! section 5.2), the fast-prediction precompute becomes
//!
//! ```text
//! u_mean = sf2 S (sigma^2 I + sf2 S G S)^{-1} S b,
//! ```
//!
//! an SPD system whose CG iterations cost O(m log m + m 7^D) —
//! **independent of n**. The stochastic variance grid vector `nu_U`
//! (section 5.1.2) rides the same operator: the `N(0, G)`-distributed
//! probe component is accumulated exactly during ingestion
//! (`q_k += eps_ik w_i`), so the Papandreou–Yuille estimator never needs
//! the raw data either.
//!
//! Layers:
//!
//! * [`IncrementalSki`] — the sufficient-statistic core: O(4^D)
//!   per-point updates, banded `G` MVMs, and whole-cell grid
//!   auto-expansion (step-preserving, so statistics remap by an index
//!   shift) when points arrive outside the covered box.
//! * [`StreamTrainer`] — warm-started refreshes that solve the mean and
//!   all `n_s` variance-probe systems as **one lockstep block-CG solve**
//!   ([`crate::solver::cg_solve_block`], previous solutions as the
//!   per-column `x0`): per iteration, `S` and the preconditioner are
//!   applied to the whole block through the batched two-for-one FFT
//!   engine ([`crate::linalg::fft`]), with converged columns masked
//!   out (and physically compacted from the batched applies), the
//!   block's rows split across the in-tree thread pool
//!   ([`crate::parallel`], `MSGP_THREADS`) so one refresh uses all
//!   cores — intra-shard threading that composes with, and never
//!   oversubscribes against, the per-shard worker threads of
//!   [`crate::shard`]. Solves run under a pluggable
//!   [`crate::solver::Preconditioner`]: `Jacobi`
//!   scales by `diag(B) ~= sigma^2 + sf2 s0^2 diag(G)` from the
//!   tracked Gram diagonal, while `Spectral` (the default) inverts
//!   `M = sigma^2 I + sf2 rho C` exactly in O(m log m) — `C = S S` the
//!   multi-level circulant approximation of `K_UU` and
//!   `rho = trace(G) / m` the mean cell occupancy — collapsing the
//!   spectral spread that dominates CG iteration counts on smooth
//!   kernels. Plus incremental `u_mean` / `nu_U` cache rebuilds,
//!   exponential forgetting ([`StreamTrainer::decay`]) for
//!   non-stationary streams (with an effective-mass floor,
//!   [`MIN_EFFECTIVE_MASS`], below which weight-normalized statistics
//!   zero out and re-opt skips), and periodic Whittle hyperparameter
//!   re-optimization on a lock-guarded reservoir snapshot of the
//!   stream.
//! * Coordinator integration lives in [`crate::coordinator`]: the
//!   `/ingest` route, batched ingestion, and atomic
//!   [`crate::coordinator::state::ModelSlot`] snapshot swaps.
//! * Data-parallel scaling lives in [`crate::shard`]: the statistics
//!   are *additive*, so S spatial shards ingest disjoint sub-streams in
//!   parallel and merge (or serve) without ever replaying data.

pub mod incremental;
pub mod trainer;

pub use incremental::{remap_grid_vec, IncrementalSki, MIN_EFFECTIVE_MASS};
pub use trainer::{RefreshStats, Reservoir, StreamConfig, StreamTrainer};
