//! Warm-started m-domain refreshes over the incremental SKI statistics,
//! plus periodic Whittle hyperparameter re-optimization on a reservoir
//! snapshot of the stream.

use std::time::{Duration, Instant};

use crate::coordinator::state::ServingModel;
use crate::data::Dataset;
use crate::gp::msgp::{GridKernel, KernelSpec, MsgpConfig, MsgpModel};
use crate::grid::Grid;
use crate::solver::{cg_solve, CgWorkspace};
use crate::stream::incremental::{remap_grid_vec, IncrementalSki};
use crate::util::Rng;

/// Streaming configuration.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Batch-model configuration reused for the grid operator (wraps,
    /// circulant kind, CG options, `n_var_samples`, seed) and for
    /// re-optimization snapshots.
    pub msgp: MsgpConfig,
    /// Points between automatic cache refreshes + model swaps (consumed
    /// by the coordinator's ingest loop; [`StreamTrainer::refresh`] can
    /// also be called manually at any cadence).
    pub refresh_every: usize,
    /// Points between hyperparameter re-optimizations (0 disables).
    pub reopt_every: usize,
    /// Adam iterations per re-optimization.
    pub reopt_iters: usize,
    /// Adam learning rate for re-optimization.
    pub reopt_lr: f64,
    /// Reservoir-sample size for the re-optimization snapshot.
    pub reservoir: usize,
    /// Hard cap on the total grid size `m` that auto-expansion may
    /// reach. A single wild outlier (e.g. `x = 1e9` on a 0.1-step grid)
    /// would otherwise demand a multi-gigabyte statistics reallocation;
    /// points whose coverage would exceed the cap are rejected and
    /// counted in [`StreamTrainer::rejected_points`] instead.
    pub max_grid_cells: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            msgp: MsgpConfig::default(),
            refresh_every: 2048,
            reopt_every: 0,
            reopt_iters: 15,
            reopt_lr: 0.05,
            reservoir: 2048,
            max_grid_cells: 262_144,
        }
    }
}

/// Diagnostics from one refresh.
#[derive(Clone, Debug, Default)]
pub struct RefreshStats {
    /// CG iterations of the warm-started mean solve.
    pub mean_iters: usize,
    /// Total CG iterations across the variance-probe solves.
    pub var_iters_total: usize,
    /// Grid size at refresh time.
    pub m: usize,
    /// Points absorbed at refresh time.
    pub n: usize,
    /// Wall-clock time of the refresh.
    pub wall: Duration,
}

/// The streaming trainer: owns the sufficient statistics, the structured
/// grid operator, and the warm-start state for all m-domain solves.
pub struct StreamTrainer {
    /// Kernel hyperparameters (updated by [`Self::reoptimize`]).
    pub kernel: KernelSpec,
    /// Noise variance.
    pub sigma2: f64,
    /// Configuration.
    pub cfg: StreamConfig,
    ski: IncrementalSki,
    gk: GridKernel,
    /// Warm start for the mean solve (m).
    t_mean: Vec<f64>,
    /// Warm starts for the variance-probe solves (`n_s` x m).
    t_probes: Vec<Vec<f64>>,
    /// Fixed `N(0, I_m)` probe draws (`n_s` x m); new cells after an
    /// expansion get fresh normals, existing cells keep theirs.
    g_probes: Vec<Vec<f64>>,
    ws: CgWorkspace,
    probe_rng: Rng,
    // Reservoir snapshot of the stream for hyper re-optimization.
    res_x: Vec<f64>,
    res_y: Vec<f64>,
    seen: usize,
    res_rng: Rng,
    /// Fast-mean grid cache `u_mean` from the last refresh (m).
    pub u_mean: Vec<f64>,
    /// Explained-variance grid cache `nu_U` from the last refresh (m).
    pub nu_u: Vec<f64>,
    /// Diagnostics from the last refresh.
    pub last_refresh: RefreshStats,
    /// Completed refreshes.
    pub refresh_count: u64,
    /// Points absorbed since the last refresh.
    pub dirty_points: usize,
    /// Points rejected (non-finite values, or coverage beyond
    /// `cfg.max_grid_cells`).
    pub rejected_points: usize,
}

impl StreamTrainer {
    /// Fresh trainer over an initial grid (predicts the prior until data
    /// arrives).
    pub fn new(kernel: KernelSpec, sigma2: f64, grid: Grid, cfg: StreamConfig) -> Self {
        assert_eq!(kernel.dim(), grid.dim(), "kernel dim vs grid dim");
        let m = grid.m();
        let ns = cfg.msgp.n_var_samples.max(1);
        let seed = cfg.msgp.seed;
        let mut probe_rng = Rng::new(seed ^ 0x9b0b_u64);
        let gk = GridKernel::new(&kernel, &grid, &cfg.msgp);
        let ski = IncrementalSki::new(grid, ns, cfg.msgp.margin_cells, seed);
        StreamTrainer {
            g_probes: (0..ns).map(|_| probe_rng.normal_vec(m)).collect(),
            t_probes: (0..ns).map(|_| vec![0.0; m]).collect(),
            t_mean: vec![0.0; m],
            u_mean: vec![0.0; m],
            nu_u: vec![0.0; m],
            ws: CgWorkspace::new(m),
            probe_rng,
            res_x: Vec::new(),
            res_y: Vec::new(),
            seen: 0,
            res_rng: Rng::new(seed ^ 0x7e5e_u64),
            kernel,
            sigma2,
            cfg,
            ski,
            gk,
            last_refresh: RefreshStats::default(),
            refresh_count: 0,
            dirty_points: 0,
            rejected_points: 0,
        }
    }

    /// Observations absorbed.
    pub fn n(&self) -> usize {
        self.ski.n()
    }

    /// Grid size.
    pub fn m(&self) -> usize {
        self.ski.m()
    }

    /// Current grid.
    pub fn grid(&self) -> &Grid {
        self.ski.grid()
    }

    /// Sufficient-statistic core (read access for diagnostics/tests).
    pub fn ski(&self) -> &IncrementalSki {
        &self.ski
    }

    /// Absorb a batch of observations (row-major `k x D` inputs).
    /// O(4^D) per point; rebuilds the grid operator and remaps all
    /// warm-start state if the grid auto-expanded.
    pub fn ingest_batch(&mut self, xs: &[f64], ys: &[f64]) {
        let d = self.ski.grid().dim();
        assert_eq!(xs.len(), ys.len() * d, "xs is k x D row-major, ys length k");
        let old_grid = self.ski.grid().clone();
        let mut applied = 0usize;
        for (i, &y) in ys.iter().enumerate() {
            let row = &xs[i * d..(i + 1) * d];
            if !self.admit(row, y) {
                self.rejected_points += 1;
                continue;
            }
            self.ski.ingest(row, y);
            applied += 1;
            // Reservoir sample for re-optimization snapshots.
            self.seen += 1;
            if self.res_y.len() < self.cfg.reservoir {
                self.res_x.extend_from_slice(row);
                self.res_y.push(y);
            } else if self.cfg.reservoir > 0 {
                let j = self.res_rng.below(self.seen);
                if j < self.cfg.reservoir {
                    self.res_x[j * d..(j + 1) * d].copy_from_slice(row);
                    self.res_y[j] = y;
                }
            }
        }
        self.dirty_points += applied;
        if self.ski.grid() != &old_grid {
            self.on_grid_changed(&old_grid);
        }
    }

    /// Admission control for one observation: finite values only, and
    /// any required auto-expansion must keep the grid under
    /// `cfg.max_grid_cells` (computed in f64 so a wild outlier cannot
    /// overflow the size arithmetic before the check).
    fn admit(&self, row: &[f64], y: f64) -> bool {
        if !y.is_finite() || row.iter().any(|v| !v.is_finite()) {
            return false;
        }
        let grid = self.ski.grid();
        // Same effective margin as IncrementalSki (which clamps to >= 1),
        // so the cap is sized against the expansion that will actually
        // be applied.
        if let Some(exp) = grid.expansion_to_cover(row, self.cfg.msgp.margin_cells.max(1)) {
            let mut m_new = 1.0f64;
            for (a, ax) in grid.axes.iter().enumerate() {
                m_new *= (ax.n as f64) + (exp.added_lo[a] as f64) + (exp.added_hi[a] as f64);
            }
            if m_new > self.cfg.max_grid_cells as f64 {
                return false;
            }
        }
        true
    }

    fn on_grid_changed(&mut self, old_grid: &Grid) {
        let new_grid = self.ski.grid().clone();
        self.gk = GridKernel::new(&self.kernel, &new_grid, &self.cfg.msgp);
        self.t_mean = remap_grid_vec(old_grid, &new_grid, &self.t_mean);
        self.u_mean = remap_grid_vec(old_grid, &new_grid, &self.u_mean);
        self.nu_u = remap_grid_vec(old_grid, &new_grid, &self.nu_u);
        for t in self.t_probes.iter_mut() {
            *t = remap_grid_vec(old_grid, &new_grid, t);
        }
        // Probe draws: keep existing cells' normals, give new cells
        // fresh ones (zeros would bias the variance estimate low).
        let mask = {
            let ones = vec![1.0; old_grid.m()];
            remap_grid_vec(old_grid, &new_grid, &ones)
        };
        for g in self.g_probes.iter_mut() {
            let remapped = remap_grid_vec(old_grid, &new_grid, g);
            *g = remapped
                .iter()
                .zip(&mask)
                .map(|(&v, &keep)| if keep > 0.5 { v } else { self.probe_rng.normal() })
                .collect();
        }
        self.ws = CgWorkspace::new(new_grid.m());
    }

    /// Warm-started refresh of the fast-prediction caches:
    /// `u_mean = sf2 S B^{-1} S b` and the stochastic `nu_U` via the
    /// probe accumulators. Cost: `(n_s + 1)` CG solves on the m-domain
    /// operator `B = sigma^2 I + sf2 S G S` — independent of n.
    pub fn refresh(&mut self) -> RefreshStats {
        let t0 = Instant::now();
        let m = self.m();
        let sf2 = self.kernel.sf2();
        let sigma2 = self.sigma2;
        let opts = self.cfg.msgp.cg.warm();
        // Borrow the read-only operator pieces as disjoint fields so the
        // warm-start buffers and workspace stay mutably borrowable.
        let gk = &self.gk;
        let ski = &self.ski;
        let mut gbuf = vec![0.0f64; m];
        let mut apply = |v: &[f64], out: &mut [f64]| {
            let s1 = gk.sqrt_matvec(v);
            ski.g_matvec_into(&s1, &mut gbuf);
            let s3 = gk.sqrt_matvec(&gbuf);
            for ((o, &s), &vi) in out.iter_mut().zip(&s3).zip(v) {
                *o = sf2 * s + sigma2 * vi;
            }
        };
        // --- mean solve ---
        let s_b = gk.sqrt_matvec(ski.wty());
        let mean_res = cg_solve(
            &mut apply,
            |v, out| out.copy_from_slice(v),
            &s_b,
            &mut self.t_mean,
            opts,
            &mut self.ws,
        );
        let mut u = gk.sqrt_matvec(&self.t_mean);
        for v in u.iter_mut() {
            *v *= sf2;
        }
        self.u_mean = u;
        // --- variance probes ---
        let sig = sigma2.sqrt();
        let rsf = sf2.sqrt();
        let mut acc = vec![0.0f64; m];
        let mut var_iters = 0usize;
        let ns = self.g_probes.len().max(1);
        for (k, g_k) in self.g_probes.iter().enumerate() {
            // p~ = sqrt(sf2) G S g_k + sigma q_k  (the m-domain image of
            // the Papandreou–Yuille probe), then solve B t = S p~.
            let sg = gk.sqrt_matvec(g_k);
            let gsg = ski.g_matvec(&sg);
            let q = &ski.probes()[k];
            let ptilde: Vec<f64> =
                gsg.iter().zip(q).map(|(&a, &b)| rsf * a + sig * b).collect();
            let rhs = gk.sqrt_matvec(&ptilde);
            let res = cg_solve(
                &mut apply,
                |v, out| out.copy_from_slice(v),
                &rhs,
                &mut self.t_probes[k],
                opts,
                &mut self.ws,
            );
            var_iters += res.iters;
            let uk = gk.sqrt_matvec(&self.t_probes[k]);
            for (a, &v) in acc.iter_mut().zip(&uk) {
                let t = sf2 * v;
                *a += t * t;
            }
        }
        for a in acc.iter_mut() {
            *a /= ns as f64;
        }
        self.nu_u = acc;
        self.refresh_count += 1;
        self.dirty_points = 0;
        let stats = RefreshStats {
            mean_iters: mean_res.iters,
            var_iters_total: var_iters,
            m,
            n: self.n(),
            wall: t0.elapsed(),
        };
        self.last_refresh = stats.clone();
        stats
    }

    /// Freeze the current caches into a serving snapshot (refresh first
    /// if ingests happened since the last refresh).
    pub fn serving_model(&mut self) -> ServingModel {
        if self.dirty_points > 0 || self.refresh_count == 0 {
            self.refresh();
        }
        ServingModel::from_parts(
            self.ski.grid().clone(),
            self.u_mean.clone(),
            self.nu_u.clone(),
            self.kernel.sf2(),
            self.sigma2,
        )
    }

    /// Whittle hyperparameter re-optimization on the reservoir snapshot:
    /// fit a batch MSGP on the sampled points (same grid), run
    /// `reopt_iters` Adam steps on the spectral marginal likelihood,
    /// adopt the learned hypers, rebuild the grid operator, and refresh.
    /// Returns the final snapshot LML, or `None` when the reservoir is
    /// still empty.
    pub fn reoptimize(&mut self) -> anyhow::Result<Option<f64>> {
        if self.res_y.is_empty() {
            return Ok(None);
        }
        let d = self.ski.grid().dim();
        let snapshot = Dataset { x: self.res_x.clone(), d, y: self.res_y.clone() };
        let mut cfg = self.cfg.msgp.clone();
        cfg.n_per_dim = self.ski.grid().shape();
        let mut model = MsgpModel::fit_with_grid(
            self.kernel.clone(),
            self.sigma2,
            snapshot,
            self.ski.grid().clone(),
            cfg,
        )?;
        model.train(self.cfg.reopt_iters, self.cfg.reopt_lr)?;
        let lml = model.lml();
        self.kernel = model.kernel.clone();
        self.sigma2 = model.sigma2;
        self.gk = GridKernel::new(&self.kernel, self.ski.grid(), &self.cfg.msgp);
        self.refresh();
        Ok(Some(lml))
    }
}
