//! Warm-started m-domain refreshes over the incremental SKI statistics,
//! plus periodic Whittle hyperparameter re-optimization on a reservoir
//! snapshot of the stream.
//!
//! The refresh math lives in [`refresh_mdomain`] so the single-trainer
//! path here and the per-shard workers in [`crate::shard`] solve the
//! identical operator (including the optional Jacobi preconditioner
//! built from the banded Gram's diagonal).

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::state::ServingModel;
use crate::data::Dataset;
use crate::gp::msgp::{GridKernel, KernelSpec, MsgpConfig, MsgpModel};
use crate::grid::Grid;
use crate::solver::{cg_solve, CgOptions, CgResult, CgWorkspace};
use crate::stream::incremental::{remap_grid_vec, IncrementalSki};
use crate::util::Rng;

/// Streaming configuration.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Batch-model configuration reused for the grid operator (wraps,
    /// circulant kind, CG options, `n_var_samples`, seed) and for
    /// re-optimization snapshots.
    pub msgp: MsgpConfig,
    /// Points between automatic cache refreshes + model swaps (consumed
    /// by the coordinator's ingest loop; [`StreamTrainer::refresh`] can
    /// also be called manually at any cadence).
    pub refresh_every: usize,
    /// Points between hyperparameter re-optimizations (0 disables).
    pub reopt_every: usize,
    /// Adam iterations per re-optimization.
    pub reopt_iters: usize,
    /// Adam learning rate for re-optimization.
    pub reopt_lr: f64,
    /// Reservoir-sample size for the re-optimization snapshot.
    pub reservoir: usize,
    /// Hard cap on the total grid size `m` that auto-expansion may
    /// reach. A single wild outlier (e.g. `x = 1e9` on a 0.1-step grid)
    /// would otherwise demand a multi-gigabyte statistics reallocation;
    /// points whose coverage would exceed the cap are rejected and
    /// counted in [`StreamTrainer::rejected_points`] instead.
    pub max_grid_cells: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            msgp: MsgpConfig::default(),
            refresh_every: 2048,
            reopt_every: 0,
            reopt_iters: 15,
            reopt_lr: 0.05,
            reservoir: 2048,
            max_grid_cells: 262_144,
        }
    }
}

/// Diagnostics from one refresh.
#[derive(Clone, Debug, Default)]
pub struct RefreshStats {
    /// CG iterations of the warm-started mean solve.
    pub mean_iters: usize,
    /// Total CG iterations across the variance-probe solves.
    pub var_iters_total: usize,
    /// Grid size at refresh time.
    pub m: usize,
    /// Points absorbed at refresh time.
    pub n: usize,
    /// Wall-clock time of the refresh.
    pub wall: Duration,
}

/// Reservoir sample of the stream, used for hyperparameter
/// re-optimization snapshots. Lives behind a `Mutex` shared between the
/// trainer and — in sharded deployments — the facade that runs
/// whole-domain re-opts: a snapshot is taken under the same lock
/// [`StreamTrainer::decay`] (and the shard workers' decay path) holds
/// while down-weighting the accumulators, so a re-opt can never observe
/// a half-decayed trainer.
#[derive(Debug, Default)]
pub struct Reservoir {
    /// Sampled inputs, row-major `k x D`.
    pub x: Vec<f64>,
    /// Sampled targets.
    pub y: Vec<f64>,
    /// Stream length seen by the sampler.
    pub seen: usize,
}

impl Reservoir {
    /// Offer one observation to the reservoir (classic Algorithm R).
    pub(crate) fn offer(&mut self, row: &[f64], y: f64, cap: usize, rng: &mut Rng) {
        self.seen += 1;
        let d = row.len();
        if self.y.len() < cap {
            self.x.extend_from_slice(row);
            self.y.push(y);
        } else if cap > 0 {
            let j = rng.below(self.seen);
            if j < cap {
                self.x[j * d..(j + 1) * d].copy_from_slice(row);
                self.y[j] = y;
            }
        }
    }
}

/// Inputs to one m-domain cache refresh: the structured grid operator,
/// hypers, CG options, and the (possibly multi-accumulator-combined)
/// sufficient statistics.
pub(crate) struct RefreshInputs<'a> {
    /// Structured `K_UU` operator on the refresh grid.
    pub gk: &'a GridKernel,
    /// Signal variance `sf2`.
    pub sf2: f64,
    /// Noise variance.
    pub sigma2: f64,
    /// CG options (warm start + Jacobi flags included).
    pub opts: CgOptions,
    /// `b = W^T y` (combined across accumulators by the caller).
    pub wty: &'a [f64],
    /// Probe accumulators `q_k` (combined by the caller).
    pub probes_q: &'a [Vec<f64>],
    /// Fixed `N(0, I_m)` probe draws.
    pub g_probes: &'a [Vec<f64>],
    /// `diag(G)` (combined); required when `opts.precondition` is set.
    pub g_diag: Option<&'a [f64]>,
}

/// One CG solve on the m-domain operator `B = sigma^2 I + sf2 S G S`,
/// with `G v` supplied by `g_apply` and an optional Jacobi diagonal.
#[allow(clippy::too_many_arguments)]
fn solve_mdomain(
    gk: &GridKernel,
    sf2: f64,
    sigma2: f64,
    g_apply: &mut dyn FnMut(&[f64], &mut [f64]),
    gout: &mut [f64],
    diag: Option<&[f64]>,
    rhs: &[f64],
    x: &mut [f64],
    opts: CgOptions,
    ws: &mut CgWorkspace,
) -> CgResult {
    let mut apply = |v: &[f64], out: &mut [f64]| {
        let s1 = gk.sqrt_matvec(v);
        g_apply(&s1, &mut *gout);
        let s3 = gk.sqrt_matvec(&*gout);
        for ((o, &s), &vi) in out.iter_mut().zip(&s3).zip(v) {
            *o = sf2 * s + sigma2 * vi;
        }
    };
    match diag {
        Some(d) => cg_solve(
            &mut apply,
            |v: &[f64], out: &mut [f64]| {
                for ((o, &vi), &di) in out.iter_mut().zip(v).zip(d) {
                    *o = vi / di;
                }
            },
            rhs,
            x,
            opts,
            ws,
        ),
        None => cg_solve(
            &mut apply,
            |v: &[f64], out: &mut [f64]| out.copy_from_slice(v),
            rhs,
            x,
            opts,
            ws,
        ),
    }
}

/// Rebuild the fast-prediction caches from sufficient statistics:
/// `u_mean = sf2 S B^{-1} S b` and the stochastic `nu_U` via the probe
/// accumulators, where `B = sigma^2 I + sf2 S G S`. `(n_s + 1)` CG
/// solves, each O(m log m + m 7^D) — independent of n. Shared by
/// [`StreamTrainer::refresh`] and the per-shard workers (which combine
/// an owned and a halo accumulator into one `G` apply).
///
/// When `opts.precondition` is set, a Jacobi diagonal
/// `d_i = sigma^2 + sf2 s0^2 G_ii` is built from the tracked `diag(G)`
/// and the constant circulant diagonal `s0` of `S` — an O(m) setup that
/// typically cuts CG iterations well below the unpreconditioned count on
/// spatially non-uniform streams (where `diag(G)` spans orders of
/// magnitude).
///
/// Returns `(u_mean, nu_u, mean_iters, var_iters_total)`.
pub(crate) fn refresh_mdomain(
    inp: RefreshInputs<'_>,
    g_apply: &mut dyn FnMut(&[f64], &mut [f64]),
    t_mean: &mut [f64],
    t_probes: &mut [Vec<f64>],
    ws: &mut CgWorkspace,
) -> (Vec<f64>, Vec<f64>, usize, usize) {
    let m = inp.wty.len();
    let sf2 = inp.sf2;
    let sigma2 = inp.sigma2;
    let diag: Option<Vec<f64>> = if inp.opts.precondition {
        let g_diag = inp
            .g_diag
            .expect("opts.precondition requires the tracked diag(G)");
        // Circulant (and Kronecker-of-circulant) operators have a
        // constant diagonal: read it off the first column of `S`.
        let s0 = {
            let mut e0 = vec![0.0; m];
            e0[0] = 1.0;
            inp.gk.sqrt_matvec(&e0)[0]
        };
        // Every entry must stay strictly positive for an SPD
        // preconditioner; empty cells have G_ii = 0 and fall back to the
        // noise floor.
        let floor = sigma2.abs().max(1e-12);
        Some(
            g_diag
                .iter()
                .map(|&g| (sigma2 + sf2 * s0 * s0 * g).max(floor))
                .collect(),
        )
    } else {
        None
    };
    let mut gout = vec![0.0f64; m];
    // --- mean solve ---
    let s_b = inp.gk.sqrt_matvec(inp.wty);
    let mean_res = solve_mdomain(
        inp.gk,
        sf2,
        sigma2,
        &mut *g_apply,
        &mut gout,
        diag.as_deref(),
        &s_b,
        t_mean,
        inp.opts,
        ws,
    );
    let mut u_mean = inp.gk.sqrt_matvec(t_mean);
    for v in u_mean.iter_mut() {
        *v *= sf2;
    }
    // --- variance probes ---
    let sig = sigma2.sqrt();
    let rsf = sf2.sqrt();
    let mut acc = vec![0.0f64; m];
    let mut var_iters = 0usize;
    let ns = inp.g_probes.len().max(1);
    let mut gsg = vec![0.0f64; m];
    for (k, g_k) in inp.g_probes.iter().enumerate() {
        // p~ = sqrt(sf2) G S g_k + sigma q_k  (the m-domain image of
        // the Papandreou–Yuille probe), then solve B t = S p~.
        let sg = inp.gk.sqrt_matvec(g_k);
        g_apply(&sg, &mut gsg);
        let q = &inp.probes_q[k];
        let ptilde: Vec<f64> = gsg.iter().zip(q).map(|(&a, &b)| rsf * a + sig * b).collect();
        let rhs = inp.gk.sqrt_matvec(&ptilde);
        let res = solve_mdomain(
            inp.gk,
            sf2,
            sigma2,
            &mut *g_apply,
            &mut gout,
            diag.as_deref(),
            &rhs,
            &mut t_probes[k],
            inp.opts,
            ws,
        );
        var_iters += res.iters;
        let uk = inp.gk.sqrt_matvec(&t_probes[k]);
        for (a, &v) in acc.iter_mut().zip(&uk) {
            let t = sf2 * v;
            *a += t * t;
        }
    }
    for a in acc.iter_mut() {
        *a /= ns as f64;
    }
    (u_mean, acc, mean_res.iters, var_iters)
}

/// The streaming trainer: owns the sufficient statistics, the structured
/// grid operator, and the warm-start state for all m-domain solves.
pub struct StreamTrainer {
    /// Kernel hyperparameters (updated by [`Self::reoptimize`]).
    pub kernel: KernelSpec,
    /// Noise variance.
    pub sigma2: f64,
    /// Configuration.
    pub cfg: StreamConfig,
    ski: IncrementalSki,
    gk: GridKernel,
    /// Warm start for the mean solve (m).
    t_mean: Vec<f64>,
    /// Warm starts for the variance-probe solves (`n_s` x m).
    t_probes: Vec<Vec<f64>>,
    /// Fixed `N(0, I_m)` probe draws (`n_s` x m); new cells after an
    /// expansion get fresh normals, existing cells keep theirs.
    g_probes: Vec<Vec<f64>>,
    ws: CgWorkspace,
    probe_rng: Rng,
    /// Reservoir snapshot of the stream for hyper re-optimization.
    /// Shared (`Arc`) so a sharded facade can snapshot it without
    /// stopping the worker; the lock also serializes snapshots against
    /// [`Self::decay`].
    reservoir: Arc<Mutex<Reservoir>>,
    res_rng: Rng,
    /// Fast-mean grid cache `u_mean` from the last refresh (m).
    pub u_mean: Vec<f64>,
    /// Explained-variance grid cache `nu_U` from the last refresh (m).
    pub nu_u: Vec<f64>,
    /// Diagnostics from the last refresh.
    pub last_refresh: RefreshStats,
    /// Completed refreshes.
    pub refresh_count: u64,
    /// Points absorbed since the last refresh.
    pub dirty_points: usize,
    /// Points rejected (non-finite values, or coverage beyond
    /// `cfg.max_grid_cells`).
    pub rejected_points: usize,
}

impl StreamTrainer {
    /// Fresh trainer over an initial grid (predicts the prior until data
    /// arrives).
    pub fn new(kernel: KernelSpec, sigma2: f64, grid: Grid, cfg: StreamConfig) -> Self {
        assert_eq!(kernel.dim(), grid.dim(), "kernel dim vs grid dim");
        let m = grid.m();
        let ns = cfg.msgp.n_var_samples.max(1);
        let seed = cfg.msgp.seed;
        let mut probe_rng = Rng::new(seed ^ 0x9b0b_u64);
        let gk = GridKernel::new(&kernel, &grid, &cfg.msgp);
        let ski = IncrementalSki::new(grid, ns, cfg.msgp.margin_cells, seed);
        StreamTrainer {
            g_probes: (0..ns).map(|_| probe_rng.normal_vec(m)).collect(),
            t_probes: (0..ns).map(|_| vec![0.0; m]).collect(),
            t_mean: vec![0.0; m],
            u_mean: vec![0.0; m],
            nu_u: vec![0.0; m],
            ws: CgWorkspace::new(m),
            probe_rng,
            reservoir: Arc::new(Mutex::new(Reservoir::default())),
            res_rng: Rng::new(seed ^ 0x7e5e_u64),
            kernel,
            sigma2,
            cfg,
            ski,
            gk,
            last_refresh: RefreshStats::default(),
            refresh_count: 0,
            dirty_points: 0,
            rejected_points: 0,
        }
    }

    /// Trainer wrapped around pre-built sufficient statistics (the shard
    /// merge path: S owned accumulators folded into one global
    /// accumulator). The trainer refreshes and re-optimizes exactly as
    /// if it had ingested the underlying stream itself; its reservoir
    /// starts empty (the sharded facade keeps per-shard reservoirs).
    pub fn from_stats(
        kernel: KernelSpec,
        sigma2: f64,
        cfg: StreamConfig,
        ski: IncrementalSki,
    ) -> Self {
        let mut t = Self::new(kernel, sigma2, ski.grid().clone(), cfg);
        assert_eq!(
            t.g_probes.len(),
            ski.probes().len(),
            "cfg.msgp.n_var_samples must match the accumulator's probe count"
        );
        t.dirty_points = ski.n();
        t.ski = ski;
        t
    }

    /// Observations absorbed.
    pub fn n(&self) -> usize {
        self.ski.n()
    }

    /// Grid size.
    pub fn m(&self) -> usize {
        self.ski.m()
    }

    /// Current grid.
    pub fn grid(&self) -> &Grid {
        self.ski.grid()
    }

    /// Sufficient-statistic core (read access for diagnostics/tests).
    pub fn ski(&self) -> &IncrementalSki {
        &self.ski
    }

    /// Handle to the shared reservoir (the sharded facade clones this to
    /// snapshot per-shard reservoirs for whole-domain re-opts).
    pub fn reservoir_handle(&self) -> Arc<Mutex<Reservoir>> {
        self.reservoir.clone()
    }

    /// Consistent snapshot of the reservoir sample, taken under the same
    /// lock [`Self::decay`] holds while down-weighting the accumulators.
    pub fn reservoir_snapshot(&self) -> (Vec<f64>, Vec<f64>) {
        let res = self.reservoir.lock().unwrap();
        (res.x.clone(), res.y.clone())
    }

    /// Absorb a batch of observations (row-major `k x D` inputs).
    /// O(4^D) per point; rebuilds the grid operator and remaps all
    /// warm-start state if the grid auto-expanded.
    pub fn ingest_batch(&mut self, xs: &[f64], ys: &[f64]) {
        let d = self.ski.grid().dim();
        assert_eq!(xs.len(), ys.len() * d, "xs is k x D row-major, ys length k");
        let old_grid = self.ski.grid().clone();
        let mut applied = 0usize;
        let mut admitted: Vec<usize> = Vec::new();
        for (i, &y) in ys.iter().enumerate() {
            let row = &xs[i * d..(i + 1) * d];
            if !self.admit(row, y) {
                self.rejected_points += 1;
                continue;
            }
            self.ski.ingest(row, y);
            applied += 1;
            admitted.push(i);
        }
        // Lock only for the cheap reservoir offers — a concurrent
        // snapshot (via the shared handle) must not wait out the O(4^D)
        // scatter-adds or a grid-expansion remap above.
        if !admitted.is_empty() {
            let reservoir = self.reservoir.clone();
            let mut res = reservoir.lock().unwrap();
            for &i in &admitted {
                res.offer(&xs[i * d..(i + 1) * d], ys[i], self.cfg.reservoir, &mut self.res_rng);
            }
        }
        self.dirty_points += applied;
        if self.ski.grid() != &old_grid {
            self.on_grid_changed(&old_grid);
        }
    }

    /// Epoch hook for non-stationary streams: exponentially down-weight
    /// the sufficient statistics (see [`IncrementalSki::decay`]). Taken
    /// under the reservoir lock so a concurrent re-opt snapshot (sharded
    /// deployments share the reservoir handle across threads) is ordered
    /// strictly before or after the decay — never interleaved with it.
    /// Marks the caches dirty so the next [`Self::serving_model`]
    /// refreshes.
    pub fn decay(&mut self, gamma: f64) {
        let reservoir = self.reservoir.clone();
        let _guard = reservoir.lock().unwrap();
        self.ski.decay(gamma);
        if self.ski.n() > 0 {
            self.dirty_points = self.dirty_points.max(1);
        }
    }

    /// Admission control for one observation: finite values only, and
    /// any required auto-expansion must keep the grid under
    /// `cfg.max_grid_cells` (computed in f64 so a wild outlier cannot
    /// overflow the size arithmetic before the check).
    fn admit(&self, row: &[f64], y: f64) -> bool {
        if !y.is_finite() || row.iter().any(|v| !v.is_finite()) {
            return false;
        }
        let grid = self.ski.grid();
        // Same effective margin as IncrementalSki (which clamps to >= 1),
        // so the cap is sized against the expansion that will actually
        // be applied.
        if let Some(exp) = grid.expansion_to_cover(row, self.cfg.msgp.margin_cells.max(1)) {
            let mut m_new = 1.0f64;
            for (a, ax) in grid.axes.iter().enumerate() {
                m_new *= (ax.n as f64) + (exp.added_lo[a] as f64) + (exp.added_hi[a] as f64);
            }
            if m_new > self.cfg.max_grid_cells as f64 {
                return false;
            }
        }
        true
    }

    fn on_grid_changed(&mut self, old_grid: &Grid) {
        let new_grid = self.ski.grid().clone();
        self.gk = GridKernel::new(&self.kernel, &new_grid, &self.cfg.msgp);
        self.t_mean = remap_grid_vec(old_grid, &new_grid, &self.t_mean);
        self.u_mean = remap_grid_vec(old_grid, &new_grid, &self.u_mean);
        self.nu_u = remap_grid_vec(old_grid, &new_grid, &self.nu_u);
        for t in self.t_probes.iter_mut() {
            *t = remap_grid_vec(old_grid, &new_grid, t);
        }
        // Probe draws: keep existing cells' normals, give new cells
        // fresh ones (zeros would bias the variance estimate low).
        let mask = {
            let ones = vec![1.0; old_grid.m()];
            remap_grid_vec(old_grid, &new_grid, &ones)
        };
        for g in self.g_probes.iter_mut() {
            let remapped = remap_grid_vec(old_grid, &new_grid, g);
            *g = remapped
                .iter()
                .zip(&mask)
                .map(|(&v, &keep)| if keep > 0.5 { v } else { self.probe_rng.normal() })
                .collect();
        }
        self.ws = CgWorkspace::new(new_grid.m());
    }

    /// Warm-started refresh of the fast-prediction caches:
    /// `u_mean = sf2 S B^{-1} S b` and the stochastic `nu_U` via the
    /// probe accumulators. Cost: `(n_s + 1)` CG solves on the m-domain
    /// operator `B = sigma^2 I + sf2 S G S` — independent of n. With
    /// `cfg.msgp.cg.precondition` set, each solve is Jacobi-
    /// preconditioned from the tracked `diag(G)`.
    pub fn refresh(&mut self) -> RefreshStats {
        let t0 = Instant::now();
        let m = self.m();
        let opts = self.cfg.msgp.cg.warm();
        // Borrow the read-only operator pieces as disjoint fields so the
        // warm-start buffers and workspace stay mutably borrowable.
        let ski = &self.ski;
        let inputs = RefreshInputs {
            gk: &self.gk,
            sf2: self.kernel.sf2(),
            sigma2: self.sigma2,
            opts,
            wty: ski.wty(),
            probes_q: ski.probes(),
            g_probes: &self.g_probes,
            g_diag: Some(ski.g_diag()),
        };
        let mut g_apply = |v: &[f64], out: &mut [f64]| ski.g_matvec_into(v, out);
        let (u_mean, nu_u, mean_iters, var_iters) = refresh_mdomain(
            inputs,
            &mut g_apply,
            &mut self.t_mean,
            &mut self.t_probes,
            &mut self.ws,
        );
        self.u_mean = u_mean;
        self.nu_u = nu_u;
        self.refresh_count += 1;
        self.dirty_points = 0;
        let stats = RefreshStats {
            mean_iters,
            var_iters_total: var_iters,
            m,
            n: self.n(),
            wall: t0.elapsed(),
        };
        self.last_refresh = stats.clone();
        stats
    }

    /// Freeze the current caches into a serving snapshot (refresh first
    /// if ingests happened since the last refresh).
    pub fn serving_model(&mut self) -> ServingModel {
        if self.dirty_points > 0 || self.refresh_count == 0 {
            self.refresh();
        }
        ServingModel::from_parts(
            self.ski.grid().clone(),
            self.u_mean.clone(),
            self.nu_u.clone(),
            self.kernel.sf2(),
            self.sigma2,
        )
    }

    /// Whittle hyperparameter re-optimization on the reservoir snapshot:
    /// fit a batch MSGP on the sampled points (same grid), run
    /// `reopt_iters` Adam steps on the spectral marginal likelihood,
    /// adopt the learned hypers, rebuild the grid operator, and refresh.
    /// Returns the final snapshot LML, or `None` when the reservoir is
    /// still empty.
    pub fn reoptimize(&mut self) -> anyhow::Result<Option<f64>> {
        let (res_x, res_y) = self.reservoir_snapshot();
        if res_y.is_empty() {
            return Ok(None);
        }
        let d = self.ski.grid().dim();
        let snapshot = Dataset { x: res_x, d, y: res_y };
        let mut cfg = self.cfg.msgp.clone();
        cfg.n_per_dim = self.ski.grid().shape();
        let mut model = MsgpModel::fit_with_grid(
            self.kernel.clone(),
            self.sigma2,
            snapshot,
            self.ski.grid().clone(),
            cfg,
        )?;
        model.train(self.cfg.reopt_iters, self.cfg.reopt_lr)?;
        let lml = model.lml();
        self.kernel = model.kernel.clone();
        self.sigma2 = model.sigma2;
        self.gk = GridKernel::new(&self.kernel, self.ski.grid(), &self.cfg.msgp);
        self.refresh();
        Ok(Some(lml))
    }
}
