//! Warm-started m-domain refreshes over the incremental SKI statistics,
//! plus periodic Whittle hyperparameter re-optimization on a reservoir
//! snapshot of the stream.
//!
//! The refresh math lives in [`refresh_mdomain`] so the single-trainer
//! path here and the per-shard workers in [`crate::shard`] solve the
//! identical operator, including the pluggable
//! [`Preconditioner`](crate::solver::Preconditioner) for the m-domain
//! system `B = sigma^2 I + sf2 S G S`:
//!
//! * `Jacobi` scales by `diag(B) ~= sigma^2 + sf2 s0^2 diag(G)` (the
//!   banded Gram tracks its diagonal; `s0` is the constant circulant
//!   diagonal of `S`) — O(m) per application, corrects occupancy skew.
//! * `Spectral` inverts `M = sigma^2 I + sf2 rho C` exactly in
//!   O(m log m), where `C = S S` is the multi-level circulant
//!   approximation of `K_UU` and `rho = trace(G) / m` the mean cell
//!   occupancy (`G ~= rho I`). `M` shares `B`'s eigenbasis up to the
//!   `G` fluctuation, so it collapses the spectral spread that
//!   dominates CG iteration counts on smooth kernels — the circulant
//!   preconditioning the paper's section 5.2 machinery was built for.
//!
//! A requested preconditioner that cannot be built (no tracked
//! `diag(G)` supplied) degrades to unpreconditioned CG — logged once
//! per process and surfaced through the `precond_fallbacks` counters —
//! rather than panicking the background refresh thread.

use std::sync::{Arc, Mutex, Once};
use std::time::{Duration, Instant};

use crate::coordinator::state::ServingModel;
use crate::data::Dataset;
use crate::gp::msgp::{GridKernel, KernelSpec, MsgpConfig, MsgpModel};
use crate::grid::Grid;
use crate::linalg::fft::fftn;
use crate::linalg::C64;
use crate::solver::{cg_solve, CgOptions, CgResult, CgWorkspace, Preconditioner};
use crate::stream::incremental::{remap_grid_vec, IncrementalSki, MIN_EFFECTIVE_MASS};
use crate::util::Rng;

/// Streaming configuration.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Batch-model configuration reused for the grid operator (wraps,
    /// circulant kind, CG options, `n_var_samples`, seed) and for
    /// re-optimization snapshots.
    pub msgp: MsgpConfig,
    /// Points between automatic cache refreshes + model swaps (consumed
    /// by the coordinator's ingest loop; [`StreamTrainer::refresh`] can
    /// also be called manually at any cadence).
    pub refresh_every: usize,
    /// Points between hyperparameter re-optimizations (0 disables).
    pub reopt_every: usize,
    /// Adam iterations per re-optimization.
    pub reopt_iters: usize,
    /// Adam learning rate for re-optimization.
    pub reopt_lr: f64,
    /// Reservoir-sample size for the re-optimization snapshot.
    pub reservoir: usize,
    /// Hard cap on the total grid size `m` that auto-expansion may
    /// reach. A single wild outlier (e.g. `x = 1e9` on a 0.1-step grid)
    /// would otherwise demand a multi-gigabyte statistics reallocation;
    /// points whose coverage would exceed the cap are rejected and
    /// counted in [`StreamTrainer::rejected_points`] instead.
    pub max_grid_cells: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            msgp: MsgpConfig::default(),
            refresh_every: 2048,
            reopt_every: 0,
            reopt_iters: 15,
            reopt_lr: 0.05,
            reservoir: 2048,
            max_grid_cells: 262_144,
        }
    }
}

/// Diagnostics from one refresh.
#[derive(Clone, Debug, Default)]
pub struct RefreshStats {
    /// CG iterations of the warm-started mean solve.
    pub mean_iters: usize,
    /// Total CG iterations across the variance-probe solves.
    pub var_iters_total: usize,
    /// Grid size at refresh time.
    pub m: usize,
    /// Points absorbed at refresh time.
    pub n: usize,
    /// Wall-clock time of the refresh.
    pub wall: Duration,
    /// Whether a requested preconditioner could not be built and the
    /// refresh degraded to unpreconditioned CG.
    pub precond_fallback: bool,
}

/// Reservoir sample of the stream, used for hyperparameter
/// re-optimization snapshots. Lives behind a `Mutex` shared between the
/// trainer and — in sharded deployments — the facade that runs
/// whole-domain re-opts: a snapshot is taken under the same lock
/// [`StreamTrainer::decay`] (and the shard workers' decay path) holds
/// while down-weighting the accumulators, so a re-opt can never observe
/// a half-decayed trainer.
#[derive(Debug, Default)]
pub struct Reservoir {
    /// Sampled inputs, row-major `k x D`.
    pub x: Vec<f64>,
    /// Sampled targets.
    pub y: Vec<f64>,
    /// Stream length seen by the sampler.
    pub seen: usize,
}

impl Reservoir {
    /// Offer one observation to the reservoir (classic Algorithm R).
    pub(crate) fn offer(&mut self, row: &[f64], y: f64, cap: usize, rng: &mut Rng) {
        self.seen += 1;
        let d = row.len();
        if self.y.len() < cap {
            self.x.extend_from_slice(row);
            self.y.push(y);
        } else if cap > 0 {
            let j = rng.below(self.seen);
            if j < cap {
                self.x[j * d..(j + 1) * d].copy_from_slice(row);
                self.y[j] = y;
            }
        }
    }
}

/// Inputs to one m-domain cache refresh: the structured grid operator,
/// hypers, CG options, and the (possibly multi-accumulator-combined)
/// sufficient statistics.
pub(crate) struct RefreshInputs<'a> {
    /// Structured `K_UU` operator on the refresh grid.
    pub gk: &'a GridKernel,
    /// Signal variance `sf2`.
    pub sf2: f64,
    /// Noise variance.
    pub sigma2: f64,
    /// CG options (warm-start flag and [`Preconditioner`] choice
    /// included).
    pub opts: CgOptions,
    /// `b = W^T y` (combined across accumulators by the caller).
    pub wty: &'a [f64],
    /// Probe accumulators `q_k` (combined by the caller).
    pub probes_q: &'a [Vec<f64>],
    /// Fixed `N(0, I_m)` probe draws.
    pub g_probes: &'a [Vec<f64>],
    /// `diag(G)` (combined); consulted when `opts.precondition` selects
    /// `Jacobi` (the scaling itself) or `Spectral` (the mean occupancy
    /// `rho = trace(G) / m`). When absent with a preconditioner
    /// requested, the refresh degrades to unpreconditioned CG (see
    /// [`build_precond`]) instead of panicking.
    pub g_diag: Option<&'a [f64]>,
}

/// Result of one m-domain cache refresh.
pub(crate) struct RefreshOutcome {
    /// `u_mean = sf2 S B^{-1} S b`.
    pub u_mean: Vec<f64>,
    /// Stochastic explained-variance grid vector.
    pub nu_u: Vec<f64>,
    /// CG iterations of the mean solve.
    pub mean_iters: usize,
    /// Total CG iterations across the variance-probe solves.
    pub var_iters: usize,
    /// `true` when a requested preconditioner could not be built and
    /// the solves ran unpreconditioned.
    pub precond_fallback: bool,
}

/// A built preconditioner application `out = M^{-1} v` for one refresh:
/// the [`Preconditioner`] choice resolved against the statistics that
/// were actually supplied. The spectral arm precomputes the reciprocal
/// spectrum and carries a reusable m-length FFT buffer, so applying it
/// adds no per-iteration O(m) allocations to the CG hot path (on
/// multi-dimensional grids `fftn` still gathers strided axes through a
/// small line-length scratch).
pub(crate) enum PrecondApply {
    /// Unpreconditioned (`M = I`).
    Identity,
    /// Jacobi: element-wise division by `diag(B)`.
    Diag(Vec<f64>),
    /// Spectral: `(sigma^2 I + sf2 rho C)^{-1}` applied in the Fourier
    /// domain with the reciprocal spectrum precomputed at build time.
    Spectral {
        /// Grid shape (row-major tensor layout of the FFT).
        shape: Vec<usize>,
        /// `1 / (sf2 rho e_k + sigma^2)` per eigenvalue, real.
        inv: Vec<f64>,
        /// Reusable complex FFT workspace (length m).
        buf: Vec<C64>,
    },
}

impl PrecondApply {
    fn apply(&mut self, v: &[f64], out: &mut [f64]) {
        match self {
            PrecondApply::Identity => out.copy_from_slice(v),
            PrecondApply::Diag(d) => {
                for ((o, &vi), &di) in out.iter_mut().zip(v).zip(d.iter()) {
                    *o = vi / di;
                }
            }
            PrecondApply::Spectral { shape, inv, buf } => {
                for (b, &vi) in buf.iter_mut().zip(v) {
                    *b = C64::real(vi);
                }
                fftn(buf, shape, false);
                for (b, &s) in buf.iter_mut().zip(inv.iter()) {
                    *b = b.scale(s);
                }
                fftn(buf, shape, true);
                for (o, b) in out.iter_mut().zip(buf.iter()) {
                    *o = b.re;
                }
            }
        }
    }
}

/// Warn once per process when a requested preconditioner degrades (the
/// condition is a caller misconfiguration, not a per-refresh event, so
/// one line suffices and the counters carry the ongoing signal).
static PRECOND_FALLBACK_WARN: Once = Once::new();

/// Resolve the requested [`Preconditioner`] into a [`PrecondApply`].
/// Returns `(apply, fallback)` where `fallback` is `true` when a
/// preconditioner was requested but `diag(G)` was not supplied — the
/// solve then degrades to unpreconditioned CG instead of panicking the
/// refresh thread.
pub(crate) fn build_precond(inp: &RefreshInputs<'_>) -> (PrecondApply, bool) {
    let g_diag = match inp.opts.precondition {
        Preconditioner::None => return (PrecondApply::Identity, false),
        Preconditioner::Jacobi | Preconditioner::Spectral => match inp.g_diag {
            Some(g) => g,
            None => {
                PRECOND_FALLBACK_WARN.call_once(|| {
                    eprintln!(
                        "refresh preconditioner ({}) requested but diag(G) was not \
                         supplied; degrading to unpreconditioned CG",
                        inp.opts.precondition.name()
                    );
                });
                return (PrecondApply::Identity, true);
            }
        },
    };
    let m = inp.wty.len();
    let sigma2 = inp.sigma2;
    match inp.opts.precondition {
        Preconditioner::None => unreachable!("handled above"),
        Preconditioner::Jacobi => {
            // Circulant (and Kronecker-of-circulant) operators have a
            // constant diagonal: read it off the first column of `S`.
            let s0 = {
                let mut e0 = vec![0.0; m];
                e0[0] = 1.0;
                inp.gk.sqrt_matvec(&e0)[0]
            };
            // Every entry must stay strictly positive for an SPD
            // preconditioner; empty cells have G_ii = 0 and fall back to
            // the noise floor.
            let floor = sigma2.abs().max(1e-12);
            let d = g_diag
                .iter()
                .map(|&g| (sigma2 + inp.sf2 * s0 * s0 * g).max(floor))
                .collect();
            (PrecondApply::Diag(d), false)
        }
        Preconditioner::Spectral => {
            // G ~= rho I with rho = trace(G) / m, so
            // B ~= sigma^2 I + sf2 rho S S = sigma^2 I + sf2 rho C —
            // a shifted BCCB (Kronecker-of-circulants is a BCCB too),
            // invertible exactly in the Fourier domain. An empty
            // trainer has rho = 0 and M degenerates to sigma^2 I (a
            // scalar scaling: harmless and still SPD). The same
            // positivity floor as the Jacobi arm keeps every
            // reciprocal finite when sigma^2 = 0 meets a clipped
            // (exactly zero) eigenvalue.
            let rho = (g_diag.iter().sum::<f64>() / m.max(1) as f64).max(0.0);
            let a = inp.sf2 * rho;
            let floor = sigma2.abs().max(1e-12);
            let inv: Vec<f64> = inp
                .gk
                .circulant_eigenvalues()
                .iter()
                .map(|&e| 1.0 / (a * e.max(0.0) + sigma2).max(floor))
                .collect();
            let shape = inp.gk.shape();
            (PrecondApply::Spectral { shape, inv, buf: vec![C64::ZERO; m] }, false)
        }
    }
}

/// One CG solve on the m-domain operator `B = sigma^2 I + sf2 S G S`,
/// with `G v` supplied by `g_apply` and the preconditioner already
/// resolved by [`build_precond`].
#[allow(clippy::too_many_arguments)]
fn solve_mdomain(
    gk: &GridKernel,
    sf2: f64,
    sigma2: f64,
    g_apply: &mut dyn FnMut(&[f64], &mut [f64]),
    gout: &mut [f64],
    precond: &mut PrecondApply,
    rhs: &[f64],
    x: &mut [f64],
    opts: CgOptions,
    ws: &mut CgWorkspace,
) -> CgResult {
    let mut apply = |v: &[f64], out: &mut [f64]| {
        let s1 = gk.sqrt_matvec(v);
        g_apply(&s1, &mut *gout);
        let s3 = gk.sqrt_matvec(&*gout);
        for ((o, &s), &vi) in out.iter_mut().zip(&s3).zip(v) {
            *o = sf2 * s + sigma2 * vi;
        }
    };
    cg_solve(
        &mut apply,
        |v: &[f64], out: &mut [f64]| precond.apply(v, out),
        rhs,
        x,
        opts,
        ws,
    )
}

/// Rebuild the fast-prediction caches from sufficient statistics:
/// `u_mean = sf2 S B^{-1} S b` and the stochastic `nu_U` via the probe
/// accumulators, where `B = sigma^2 I + sf2 S G S`. `(n_s + 1)` CG
/// solves, each O(m log m + m 7^D) — independent of n. Shared by
/// [`StreamTrainer::refresh`] and the per-shard workers (which combine
/// an owned and a halo accumulator into one `G` apply).
///
/// `opts.precondition` selects the solve preconditioner (see the
/// [module docs](self) for the operator algebra): `Jacobi` builds the
/// O(m) diagonal from the tracked `diag(G)`; `Spectral` builds the
/// O(m log m) BCCB approximate inverse `(sigma^2 I + sf2 rho C)^{-1}`
/// from the grid operator's circulant spectrum and the mean occupancy
/// `rho`. Both typically cut CG iterations well below the
/// unpreconditioned count on spatially non-uniform streams.
pub(crate) fn refresh_mdomain(
    inp: RefreshInputs<'_>,
    g_apply: &mut dyn FnMut(&[f64], &mut [f64]),
    t_mean: &mut [f64],
    t_probes: &mut [Vec<f64>],
    ws: &mut CgWorkspace,
) -> RefreshOutcome {
    let m = inp.wty.len();
    let sf2 = inp.sf2;
    let sigma2 = inp.sigma2;
    let (mut precond, precond_fallback) = build_precond(&inp);
    let mut gout = vec![0.0f64; m];
    // --- mean solve ---
    let s_b = inp.gk.sqrt_matvec(inp.wty);
    let mean_res = solve_mdomain(
        inp.gk,
        sf2,
        sigma2,
        &mut *g_apply,
        &mut gout,
        &mut precond,
        &s_b,
        t_mean,
        inp.opts,
        ws,
    );
    let mut u_mean = inp.gk.sqrt_matvec(t_mean);
    for v in u_mean.iter_mut() {
        *v *= sf2;
    }
    // --- variance probes ---
    let sig = sigma2.sqrt();
    let rsf = sf2.sqrt();
    let mut acc = vec![0.0f64; m];
    let mut var_iters = 0usize;
    let ns = inp.g_probes.len().max(1);
    let mut gsg = vec![0.0f64; m];
    for (k, g_k) in inp.g_probes.iter().enumerate() {
        // p~ = sqrt(sf2) G S g_k + sigma q_k  (the m-domain image of
        // the Papandreou–Yuille probe), then solve B t = S p~.
        let sg = inp.gk.sqrt_matvec(g_k);
        g_apply(&sg, &mut gsg);
        let q = &inp.probes_q[k];
        let ptilde: Vec<f64> = gsg.iter().zip(q).map(|(&a, &b)| rsf * a + sig * b).collect();
        let rhs = inp.gk.sqrt_matvec(&ptilde);
        let res = solve_mdomain(
            inp.gk,
            sf2,
            sigma2,
            &mut *g_apply,
            &mut gout,
            &mut precond,
            &rhs,
            &mut t_probes[k],
            inp.opts,
            ws,
        );
        var_iters += res.iters;
        let uk = inp.gk.sqrt_matvec(&t_probes[k]);
        for (a, &v) in acc.iter_mut().zip(&uk) {
            let t = sf2 * v;
            *a += t * t;
        }
    }
    for a in acc.iter_mut() {
        *a /= ns as f64;
    }
    RefreshOutcome {
        u_mean,
        nu_u: acc,
        mean_iters: mean_res.iters,
        var_iters,
        precond_fallback,
    }
}

/// The streaming trainer: owns the sufficient statistics, the structured
/// grid operator, and the warm-start state for all m-domain solves.
pub struct StreamTrainer {
    /// Kernel hyperparameters (updated by [`Self::reoptimize`]).
    pub kernel: KernelSpec,
    /// Noise variance.
    pub sigma2: f64,
    /// Configuration.
    pub cfg: StreamConfig,
    ski: IncrementalSki,
    gk: GridKernel,
    /// Warm start for the mean solve (m).
    t_mean: Vec<f64>,
    /// Warm starts for the variance-probe solves (`n_s` x m).
    t_probes: Vec<Vec<f64>>,
    /// Fixed `N(0, I_m)` probe draws (`n_s` x m); new cells after an
    /// expansion get fresh normals, existing cells keep theirs.
    g_probes: Vec<Vec<f64>>,
    ws: CgWorkspace,
    probe_rng: Rng,
    /// Reservoir snapshot of the stream for hyper re-optimization.
    /// Shared (`Arc`) so a sharded facade can snapshot it without
    /// stopping the worker; the lock also serializes snapshots against
    /// [`Self::decay`].
    reservoir: Arc<Mutex<Reservoir>>,
    res_rng: Rng,
    /// Fast-mean grid cache `u_mean` from the last refresh (m).
    pub u_mean: Vec<f64>,
    /// Explained-variance grid cache `nu_U` from the last refresh (m).
    pub nu_u: Vec<f64>,
    /// Diagnostics from the last refresh.
    pub last_refresh: RefreshStats,
    /// Completed refreshes.
    pub refresh_count: u64,
    /// Points absorbed since the last refresh.
    pub dirty_points: usize,
    /// Points rejected (non-finite values, or coverage beyond
    /// `cfg.max_grid_cells`).
    pub rejected_points: usize,
    /// Refreshes that requested a preconditioner but had to degrade to
    /// unpreconditioned CG (mirrored into the coordinator's
    /// `precond_fallbacks` metric).
    pub precond_fallbacks: u64,
}

impl StreamTrainer {
    /// Fresh trainer over an initial grid (predicts the prior until data
    /// arrives).
    pub fn new(kernel: KernelSpec, sigma2: f64, grid: Grid, cfg: StreamConfig) -> Self {
        assert_eq!(kernel.dim(), grid.dim(), "kernel dim vs grid dim");
        let m = grid.m();
        let ns = cfg.msgp.n_var_samples.max(1);
        let seed = cfg.msgp.seed;
        let mut probe_rng = Rng::new(seed ^ 0x9b0b_u64);
        let gk = GridKernel::new(&kernel, &grid, &cfg.msgp);
        let ski = IncrementalSki::new(grid, ns, cfg.msgp.margin_cells, seed);
        StreamTrainer {
            g_probes: (0..ns).map(|_| probe_rng.normal_vec(m)).collect(),
            t_probes: (0..ns).map(|_| vec![0.0; m]).collect(),
            t_mean: vec![0.0; m],
            u_mean: vec![0.0; m],
            nu_u: vec![0.0; m],
            ws: CgWorkspace::new(m),
            probe_rng,
            reservoir: Arc::new(Mutex::new(Reservoir::default())),
            res_rng: Rng::new(seed ^ 0x7e5e_u64),
            kernel,
            sigma2,
            cfg,
            ski,
            gk,
            last_refresh: RefreshStats::default(),
            refresh_count: 0,
            dirty_points: 0,
            rejected_points: 0,
            precond_fallbacks: 0,
        }
    }

    /// Trainer wrapped around pre-built sufficient statistics (the shard
    /// merge path: S owned accumulators folded into one global
    /// accumulator). The trainer refreshes and re-optimizes exactly as
    /// if it had ingested the underlying stream itself; its reservoir
    /// starts empty (the sharded facade keeps per-shard reservoirs).
    pub fn from_stats(
        kernel: KernelSpec,
        sigma2: f64,
        cfg: StreamConfig,
        ski: IncrementalSki,
    ) -> Self {
        let mut t = Self::new(kernel, sigma2, ski.grid().clone(), cfg);
        assert_eq!(
            t.g_probes.len(),
            ski.probes().len(),
            "cfg.msgp.n_var_samples must match the accumulator's probe count"
        );
        t.dirty_points = ski.n();
        t.ski = ski;
        t
    }

    /// Observations absorbed.
    pub fn n(&self) -> usize {
        self.ski.n()
    }

    /// Grid size.
    pub fn m(&self) -> usize {
        self.ski.m()
    }

    /// Current grid.
    pub fn grid(&self) -> &Grid {
        self.ski.grid()
    }

    /// Sufficient-statistic core (read access for diagnostics/tests).
    pub fn ski(&self) -> &IncrementalSki {
        &self.ski
    }

    /// Handle to the shared reservoir (the sharded facade clones this to
    /// snapshot per-shard reservoirs for whole-domain re-opts).
    pub fn reservoir_handle(&self) -> Arc<Mutex<Reservoir>> {
        self.reservoir.clone()
    }

    /// Consistent snapshot of the reservoir sample, taken under the same
    /// lock [`Self::decay`] holds while down-weighting the accumulators.
    pub fn reservoir_snapshot(&self) -> (Vec<f64>, Vec<f64>) {
        let res = self.reservoir.lock().unwrap();
        (res.x.clone(), res.y.clone())
    }

    /// Absorb a batch of observations (row-major `k x D` inputs).
    /// O(4^D) per point; rebuilds the grid operator and remaps all
    /// warm-start state if the grid auto-expanded.
    pub fn ingest_batch(&mut self, xs: &[f64], ys: &[f64]) {
        let d = self.ski.grid().dim();
        assert_eq!(xs.len(), ys.len() * d, "xs is k x D row-major, ys length k");
        let old_grid = self.ski.grid().clone();
        let mut applied = 0usize;
        let mut admitted: Vec<usize> = Vec::new();
        for (i, &y) in ys.iter().enumerate() {
            let row = &xs[i * d..(i + 1) * d];
            if !self.admit(row, y) {
                self.rejected_points += 1;
                continue;
            }
            self.ski.ingest(row, y);
            applied += 1;
            admitted.push(i);
        }
        // Lock only for the cheap reservoir offers — a concurrent
        // snapshot (via the shared handle) must not wait out the O(4^D)
        // scatter-adds or a grid-expansion remap above.
        if !admitted.is_empty() {
            let reservoir = self.reservoir.clone();
            let mut res = reservoir.lock().unwrap();
            for &i in &admitted {
                res.offer(&xs[i * d..(i + 1) * d], ys[i], self.cfg.reservoir, &mut self.res_rng);
            }
        }
        self.dirty_points += applied;
        if self.ski.grid() != &old_grid {
            self.on_grid_changed(&old_grid);
        }
    }

    /// Epoch hook for non-stationary streams: exponentially down-weight
    /// the sufficient statistics (see [`IncrementalSki::decay`]). Taken
    /// under the reservoir lock so a concurrent re-opt snapshot (sharded
    /// deployments share the reservoir handle across threads) is ordered
    /// strictly before or after the decay — never interleaved with it.
    /// Marks the caches dirty so the next [`Self::serving_model`]
    /// refreshes.
    pub fn decay(&mut self, gamma: f64) {
        let reservoir = self.reservoir.clone();
        let _guard = reservoir.lock().unwrap();
        self.ski.decay(gamma);
        if self.ski.n() > 0 {
            self.dirty_points = self.dirty_points.max(1);
        }
    }

    /// Admission control for one observation: finite values only, and
    /// any required auto-expansion must keep the grid under
    /// `cfg.max_grid_cells` (computed in f64 so a wild outlier cannot
    /// overflow the size arithmetic before the check).
    fn admit(&self, row: &[f64], y: f64) -> bool {
        if !y.is_finite() || row.iter().any(|v| !v.is_finite()) {
            return false;
        }
        let grid = self.ski.grid();
        // Same effective margin as IncrementalSki (which clamps to >= 1),
        // so the cap is sized against the expansion that will actually
        // be applied.
        if let Some(exp) = grid.expansion_to_cover(row, self.cfg.msgp.margin_cells.max(1)) {
            let mut m_new = 1.0f64;
            for (a, ax) in grid.axes.iter().enumerate() {
                m_new *= (ax.n as f64) + (exp.added_lo[a] as f64) + (exp.added_hi[a] as f64);
            }
            if m_new > self.cfg.max_grid_cells as f64 {
                return false;
            }
        }
        true
    }

    fn on_grid_changed(&mut self, old_grid: &Grid) {
        let new_grid = self.ski.grid().clone();
        self.gk = GridKernel::new(&self.kernel, &new_grid, &self.cfg.msgp);
        self.t_mean = remap_grid_vec(old_grid, &new_grid, &self.t_mean);
        self.u_mean = remap_grid_vec(old_grid, &new_grid, &self.u_mean);
        self.nu_u = remap_grid_vec(old_grid, &new_grid, &self.nu_u);
        for t in self.t_probes.iter_mut() {
            *t = remap_grid_vec(old_grid, &new_grid, t);
        }
        // Probe draws: keep existing cells' normals, give new cells
        // fresh ones (zeros would bias the variance estimate low).
        let mask = {
            let ones = vec![1.0; old_grid.m()];
            remap_grid_vec(old_grid, &new_grid, &ones)
        };
        for g in self.g_probes.iter_mut() {
            let remapped = remap_grid_vec(old_grid, &new_grid, g);
            *g = remapped
                .iter()
                .zip(&mask)
                .map(|(&v, &keep)| if keep > 0.5 { v } else { self.probe_rng.normal() })
                .collect();
        }
        self.ws = CgWorkspace::new(new_grid.m());
    }

    /// Warm-started refresh of the fast-prediction caches:
    /// `u_mean = sf2 S B^{-1} S b` and the stochastic `nu_U` via the
    /// probe accumulators. Cost: `(n_s + 1)` CG solves on the m-domain
    /// operator `B = sigma^2 I + sf2 S G S` — independent of n. Each
    /// solve uses the preconditioner selected by
    /// `cfg.msgp.cg.precondition` (`Spectral` by default; see
    /// [`refresh_mdomain`]).
    pub fn refresh(&mut self) -> RefreshStats {
        let t0 = Instant::now();
        let m = self.m();
        let opts = self.cfg.msgp.cg.warm();
        // Borrow the read-only operator pieces as disjoint fields so the
        // warm-start buffers and workspace stay mutably borrowable.
        let ski = &self.ski;
        let inputs = RefreshInputs {
            gk: &self.gk,
            sf2: self.kernel.sf2(),
            sigma2: self.sigma2,
            opts,
            wty: ski.wty(),
            probes_q: ski.probes(),
            g_probes: &self.g_probes,
            g_diag: Some(ski.g_diag()),
        };
        let mut g_apply = |v: &[f64], out: &mut [f64]| ski.g_matvec_into(v, out);
        let out = refresh_mdomain(
            inputs,
            &mut g_apply,
            &mut self.t_mean,
            &mut self.t_probes,
            &mut self.ws,
        );
        self.u_mean = out.u_mean;
        self.nu_u = out.nu_u;
        self.refresh_count += 1;
        self.dirty_points = 0;
        if out.precond_fallback {
            self.precond_fallbacks += 1;
        }
        let stats = RefreshStats {
            mean_iters: out.mean_iters,
            var_iters_total: out.var_iters,
            m,
            n: self.n(),
            wall: t0.elapsed(),
            precond_fallback: out.precond_fallback,
        };
        self.last_refresh = stats.clone();
        stats
    }

    /// Freeze the current caches into a serving snapshot (refresh first
    /// if ingests happened since the last refresh).
    pub fn serving_model(&mut self) -> ServingModel {
        if self.dirty_points > 0 || self.refresh_count == 0 {
            self.refresh();
        }
        ServingModel::from_parts(
            self.ski.grid().clone(),
            self.u_mean.clone(),
            self.nu_u.clone(),
            self.kernel.sf2(),
            self.sigma2,
        )
    }

    /// Whittle hyperparameter re-optimization on the reservoir snapshot:
    /// fit a batch MSGP on the sampled points (same grid), run
    /// `reopt_iters` Adam steps on the spectral marginal likelihood,
    /// adopt the learned hypers, rebuild the grid operator, and refresh.
    /// Returns the final snapshot LML, or `None` when the reservoir is
    /// still empty — or when repeated decay has driven the effective
    /// sample mass below [`MIN_EFFECTIVE_MASS`] (the model has forgotten
    /// the stream the reservoir still describes, so hypers fit to that
    /// stale snapshot would be adopted against near-zero statistics).
    pub fn reoptimize(&mut self) -> anyhow::Result<Option<f64>> {
        if self.ski.weight() < MIN_EFFECTIVE_MASS {
            return Ok(None);
        }
        let (res_x, res_y) = self.reservoir_snapshot();
        if res_y.is_empty() {
            return Ok(None);
        }
        let d = self.ski.grid().dim();
        let snapshot = Dataset { x: res_x, d, y: res_y };
        let mut cfg = self.cfg.msgp.clone();
        cfg.n_per_dim = self.ski.grid().shape();
        let mut model = MsgpModel::fit_with_grid(
            self.kernel.clone(),
            self.sigma2,
            snapshot,
            self.ski.grid().clone(),
            cfg,
        )?;
        model.train(self.cfg.reopt_iters, self.cfg.reopt_lr)?;
        let lml = model.lml();
        self.kernel = model.kernel.clone();
        self.sigma2 = model.sigma2;
        self.gk = GridKernel::new(&self.kernel, self.ski.grid(), &self.cfg.msgp);
        self.refresh();
        Ok(Some(lml))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridAxis;
    use crate::kernels::{KernelType, ProductKernel};

    fn se_kernel() -> KernelSpec {
        KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0))
    }

    /// A spatially skewed accumulator: two-thirds of the mass lands in
    /// one fifth of the domain, so `diag(G)` spans orders of magnitude.
    fn skewed_ski(m: usize, n: usize) -> (Grid, IncrementalSki) {
        let grid = Grid::new(vec![GridAxis::span(-5.0, 5.0, m)]);
        let mut ski = IncrementalSki::new(grid.clone(), 3, 1, 7);
        let mut rng = Rng::new(33);
        for i in 0..n {
            let x = if i % 3 == 0 {
                rng.uniform_in(-4.5, 4.5)
            } else {
                rng.uniform_in(-4.5, -2.5)
            };
            ski.ingest(&[x], 0.2 * (x * 1.3).sin());
        }
        (grid, ski)
    }

    fn run_refresh(
        precond: Preconditioner,
        give_diag: bool,
        gk: &GridKernel,
        ski: &IncrementalSki,
    ) -> RefreshOutcome {
        let m = ski.m();
        let ns = ski.probes().len();
        // Fixed probe draws so every run solves identical systems.
        let mut rng = Rng::new(4242);
        let g_probes: Vec<Vec<f64>> = (0..ns).map(|_| rng.normal_vec(m)).collect();
        let opts = CgOptions {
            tol: 1e-12,
            max_iter: 4000,
            warm_start: false,
            precondition: precond,
        };
        let inputs = RefreshInputs {
            gk,
            sf2: 1.0,
            sigma2: 0.1,
            opts,
            wty: ski.wty(),
            probes_q: ski.probes(),
            g_probes: &g_probes,
            g_diag: if give_diag { Some(ski.g_diag()) } else { None },
        };
        let mut t_mean = vec![0.0; m];
        let mut t_probes: Vec<Vec<f64>> = (0..ns).map(|_| vec![0.0; m]).collect();
        let mut ws = CgWorkspace::new(m);
        let mut g_apply = |v: &[f64], out: &mut [f64]| ski.g_matvec_into(v, out);
        refresh_mdomain(inputs, &mut g_apply, &mut t_mean, &mut t_probes, &mut ws)
    }

    /// Satellite regression: a preconditioner request without the
    /// tracked `diag(G)` must degrade to unpreconditioned CG (same
    /// solve, fallback flagged) instead of panicking the refresh thread.
    #[test]
    fn missing_g_diag_degrades_to_unpreconditioned_cg() {
        let (grid, ski) = skewed_ski(48, 400);
        let gk = GridKernel::new(&se_kernel(), &grid, &MsgpConfig::default());
        let plain = run_refresh(Preconditioner::None, true, &gk, &ski);
        assert!(!plain.precond_fallback);
        for precond in [Preconditioner::Jacobi, Preconditioner::Spectral] {
            let degraded = run_refresh(precond, false, &gk, &ski);
            assert!(degraded.precond_fallback, "{precond:?} must flag the fallback");
            assert_eq!(
                degraded.mean_iters, plain.mean_iters,
                "degraded {precond:?} solve must be the unpreconditioned solve"
            );
            for (a, b) in degraded.u_mean.iter().zip(&plain.u_mean) {
                assert!((a - b).abs() < 1e-12, "{a} vs {b}");
            }
        }
    }

    /// The spectral BCCB preconditioner changes the iteration path, not
    /// the solution.
    #[test]
    fn spectral_precondition_preserves_the_solution() {
        let (grid, ski) = skewed_ski(48, 600);
        let gk = GridKernel::new(&se_kernel(), &grid, &MsgpConfig::default());
        let plain = run_refresh(Preconditioner::None, true, &gk, &ski);
        let spec = run_refresh(Preconditioner::Spectral, true, &gk, &ski);
        assert!(!spec.precond_fallback);
        for (a, b) in spec.u_mean.iter().zip(&plain.u_mean) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
        for (a, b) in spec.nu_u.iter().zip(&plain.nu_u) {
            assert!((a - b).abs() < 1e-6, "nu_u drifted: {a} vs {b}");
        }
    }
}
