//! Warm-started m-domain refreshes over the incremental SKI statistics,
//! plus periodic Whittle hyperparameter re-optimization on a reservoir
//! snapshot of the stream.
//!
//! A refresh solves `n_s + 1` systems — the mean and every variance
//! probe — against the *identical* operator `B = sigma^2 I + sf2 S G S`.
//! [`refresh_mdomain`] therefore runs **one lockstep block-CG solve**
//! ([`crate::solver::cg_solve_block`]): per iteration, `S` is applied to
//! the whole block through the batched real-FFT engine
//! ([`crate::linalg::fft`]) and each column keeps its own scalar CG
//! recurrence, with converged columns physically compacted out of the
//! batched applies, so results match the historical sequential path
//! (kept as [`refresh_mdomain_sequential`] for A/B validation and
//! `benches/fig7_batched.rs`) while the FFT work per iteration drops to
//! half-length rfft transforms of only the still-active columns.
//!
//! The batched operator and preconditioner applies additionally fan out
//! over the in-tree thread pool ([`crate::parallel`]): within one
//! refresh the block's rows split across workers, so a single-trainer
//! (or single-shard) refresh uses all cores. In sharded deployments
//! this composes with the process-level shard parallelism — the pool
//! serves one region at a time and nested/contended regions degrade to
//! serial, so S shard workers never oversubscribe the machine.
//! Parallel and serial paths produce bit-identical results (pinned by
//! `refresh_identical_across_thread_counts`); `RefreshStats::threads`
//! reports the configured pool width and `RefreshStats::parallel`
//! whether the fan-out actually happened.
//!
//! The refresh math lives in [`refresh_mdomain`] so the single-trainer
//! path here and the per-shard workers in [`crate::shard`] solve the
//! identical operator, including the pluggable
//! [`Preconditioner`](crate::solver::Preconditioner) for the m-domain
//! system `B = sigma^2 I + sf2 S G S`:
//!
//! * `Jacobi` scales by `diag(B) ~= sigma^2 + sf2 s0^2 diag(G)` (the
//!   banded Gram tracks its diagonal; `s0` is the constant circulant
//!   diagonal of `S`) — O(m) per application, corrects occupancy skew.
//! * `Spectral` inverts `M = sigma^2 I + sf2 rho C` exactly in
//!   O(m log m), where `C = S S` is the multi-level circulant
//!   approximation of `K_UU` and `rho = trace(G) / m` the mean cell
//!   occupancy (`G ~= rho I`). `M` shares `B`'s eigenbasis up to the
//!   `G` fluctuation, so it collapses the spectral spread that
//!   dominates CG iteration counts on smooth kernels — the circulant
//!   preconditioning the paper's section 5.2 machinery was built for.
//!
//! A requested preconditioner that cannot be built (no tracked
//! `diag(G)` supplied) degrades to unpreconditioned CG — logged once
//! per process and surfaced through the `precond_fallbacks` counters —
//! rather than panicking the background refresh thread.

use std::sync::{Arc, Mutex, Once};
use std::time::{Duration, Instant};

use crate::coordinator::state::ServingModel;
use crate::data::Dataset;
use crate::gp::msgp::{GridKernel, KernelSpec, MsgpConfig, MsgpModel};
use crate::grid::Grid;
use crate::linalg::fft::{apply_real_spectrum_batch, fftn, Workspace as FftWorkspace};
use crate::linalg::C64;
use crate::solver::{
    cg_solve, cg_solve_block, BlockCgWorkspace, CgOptions, CgResult, CgWorkspace, Preconditioner,
};
use crate::stream::incremental::{remap_grid_vec, IncrementalSki, MIN_EFFECTIVE_MASS};
use crate::util::Rng;

/// Streaming configuration.
#[derive(Clone, Debug)]
pub struct StreamConfig {
    /// Batch-model configuration reused for the grid operator (wraps,
    /// circulant kind, CG options, `n_var_samples`, seed) and for
    /// re-optimization snapshots.
    pub msgp: MsgpConfig,
    /// Points between automatic cache refreshes + model swaps (consumed
    /// by the coordinator's ingest loop; [`StreamTrainer::refresh`] can
    /// also be called manually at any cadence).
    pub refresh_every: usize,
    /// Points between hyperparameter re-optimizations (0 disables).
    pub reopt_every: usize,
    /// Adam iterations per re-optimization.
    pub reopt_iters: usize,
    /// Adam learning rate for re-optimization.
    pub reopt_lr: f64,
    /// Reservoir-sample size for the re-optimization snapshot.
    pub reservoir: usize,
    /// Hard cap on the total grid size `m` that auto-expansion may
    /// reach. A single wild outlier (e.g. `x = 1e9` on a 0.1-step grid)
    /// would otherwise demand a multi-gigabyte statistics reallocation;
    /// points whose coverage would exceed the cap are rejected and
    /// counted in [`StreamTrainer::rejected_points`] instead.
    pub max_grid_cells: usize,
    /// Soft wall-clock deadline for one refresh, in milliseconds. When
    /// the block-CG solve overruns it, the solve aborts *between*
    /// iterations ([`CgOptions::deadline`]), the refresh reports
    /// [`RefreshStats::deadline_hit`], and the trainer keeps its dirty
    /// marker so the next cycle retries — the serving layer keeps the
    /// last-good snapshot and flips its `degraded_mode` gauge instead
    /// of swapping in a half-converged cache. `None` (the default)
    /// never aborts; the coordinator seeds it from
    /// `MSGP_REFRESH_DEADLINE_MS`.
    pub refresh_deadline_ms: Option<u64>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            msgp: MsgpConfig::default(),
            refresh_every: 2048,
            reopt_every: 0,
            reopt_iters: 15,
            reopt_lr: 0.05,
            reservoir: 2048,
            max_grid_cells: 262_144,
            refresh_deadline_ms: None,
        }
    }
}

/// Diagnostics from one refresh.
#[derive(Clone, Debug, Default)]
pub struct RefreshStats {
    /// CG iterations of the warm-started mean solve (the mean column's
    /// convergence point inside the block solve).
    pub mean_iters: usize,
    /// Total CG iterations across the variance-probe solves (sum of the
    /// probe columns' convergence points).
    pub var_iters_total: usize,
    /// Lockstep block-CG iterations of the single multi-RHS solve
    /// (`0` on the sequential reference path,
    /// [`StreamTrainer::refresh_sequential`]). Converged columns are
    /// compacted out of the batched applies as the block iterates.
    pub block_iters: usize,
    /// Pool width configured at refresh time
    /// (`crate::parallel::threads()`). Mirrored to `/metrics` as
    /// `last_refresh_threads`. A width `> 1` does not by itself mean
    /// the fan-out happened (a sibling shard may have held the pool);
    /// [`Self::parallel`] reports that.
    pub threads: usize,
    /// Whether the batched FFT engine actually dispatched pool tasks
    /// while this refresh ran (observed via the engine's process-global
    /// dispatch counter, so concurrent refreshes on other threads can
    /// attribute to each other — within one trainer thread it is
    /// exact). `false` = every hot-path apply ran serially.
    pub parallel: bool,
    /// Grid size at refresh time.
    pub m: usize,
    /// Points absorbed at refresh time.
    pub n: usize,
    /// Wall-clock time of the refresh.
    pub wall: Duration,
    /// Wall-clock of the RHS-staging stage (batched `S` applies +
    /// probe assembly). Mirrored to `/metrics` as
    /// `last_refresh_stage_rhs_us` and traced as `refresh.stage_rhs`.
    pub stage_rhs: Duration,
    /// Wall-clock of the lockstep block-CG solve (the sequential
    /// reference path reports its whole solve loop here). Mirrored as
    /// `last_refresh_block_solve_us` / traced as
    /// `refresh.block_solve`.
    pub block_solve: Duration,
    /// Wall-clock of the map-back stage (batched `S` to the u-domain +
    /// probe accumulation). Mirrored as `last_refresh_map_back_us` /
    /// traced as `refresh.map_back`.
    pub map_back: Duration,
    /// Whether a requested preconditioner could not be built and the
    /// refresh degraded to unpreconditioned CG.
    pub precond_fallback: bool,
    /// Whether the block solve aborted on the soft refresh deadline
    /// ([`StreamConfig::refresh_deadline_ms`]) before every column
    /// converged. The caches still hold the partial (warm-startable)
    /// solutions, but the serving layer should keep its last-good
    /// snapshot rather than swap them in.
    pub deadline_hit: bool,
}

/// Reservoir sample of the stream, used for hyperparameter
/// re-optimization snapshots. Lives behind a `Mutex` shared between the
/// trainer and — in sharded deployments — the facade that runs
/// whole-domain re-opts: a snapshot is taken under the same lock
/// [`StreamTrainer::decay`] (and the shard workers' decay path) holds
/// while down-weighting the accumulators, so a re-opt can never observe
/// a half-decayed trainer.
#[derive(Debug, Default)]
pub struct Reservoir {
    /// Sampled inputs, row-major `k x D`.
    pub x: Vec<f64>,
    /// Sampled targets.
    pub y: Vec<f64>,
    /// Stream length seen by the sampler.
    pub seen: usize,
}

impl Reservoir {
    /// Offer one observation to the reservoir (classic Algorithm R).
    pub(crate) fn offer(&mut self, row: &[f64], y: f64, cap: usize, rng: &mut Rng) {
        self.seen += 1;
        let d = row.len();
        if self.y.len() < cap {
            self.x.extend_from_slice(row);
            self.y.push(y);
        } else if cap > 0 {
            let j = rng.below(self.seen);
            if j < cap {
                self.x[j * d..(j + 1) * d].copy_from_slice(row);
                self.y[j] = y;
            }
        }
    }
}

/// Inputs to one m-domain cache refresh: the structured grid operator,
/// hypers, CG options, and the (possibly multi-accumulator-combined)
/// sufficient statistics.
pub(crate) struct RefreshInputs<'a> {
    /// Structured `K_UU` operator on the refresh grid.
    pub gk: &'a GridKernel,
    /// Signal variance `sf2`.
    pub sf2: f64,
    /// Noise variance.
    pub sigma2: f64,
    /// CG options (warm-start flag and [`Preconditioner`] choice
    /// included).
    pub opts: CgOptions,
    /// `b = W^T y` (combined across accumulators by the caller).
    pub wty: &'a [f64],
    /// Probe accumulators `q_k` (combined by the caller).
    pub probes_q: &'a [Vec<f64>],
    /// Fixed `N(0, I_m)` probe draws.
    pub g_probes: &'a [Vec<f64>],
    /// `diag(G)` (combined); consulted when `opts.precondition` selects
    /// `Jacobi` (the scaling itself) or `Spectral` (the mean occupancy
    /// `rho = trace(G) / m`). When absent with a preconditioner
    /// requested, the refresh degrades to unpreconditioned CG (see
    /// [`build_precond`]) instead of panicking.
    pub g_diag: Option<&'a [f64]>,
}

/// Result of one m-domain cache refresh.
pub(crate) struct RefreshOutcome {
    /// `u_mean = sf2 S B^{-1} S b`.
    pub u_mean: Vec<f64>,
    /// Stochastic explained-variance grid vector.
    pub nu_u: Vec<f64>,
    /// CG iterations of the mean solve (its column's convergence point).
    pub mean_iters: usize,
    /// Total CG iterations across the variance-probe solves.
    pub var_iters: usize,
    /// Lockstep iterations of the single block solve (`0` on the
    /// sequential reference path).
    pub block_iters: usize,
    /// Total columns pushed through the batched m-domain operator
    /// (initial residual + one compacted active block per iteration;
    /// see [`crate::solver::BlockCgResult::apply_cols`]). The G-apply
    /// accounting tests pin against this. On the sequential reference
    /// path: the equivalent per-solve count, `iters + 1` per system.
    pub apply_cols: usize,
    /// `true` when a requested preconditioner could not be built and
    /// the solves ran unpreconditioned.
    pub precond_fallback: bool,
    /// Per-stage wall-clocks (stage-RHS, block-solve, map-back) — the
    /// same measurements that feed the `refresh.*` tracer spans, so
    /// gauges and traces agree. The sequential reference path reports
    /// its whole solve loop as `block_solve`.
    pub stage_wall: [Duration; 3],
    /// `true` when the block solve aborted on [`CgOptions::deadline`]
    /// (always `false` on the sequential reference path, which carries
    /// no deadline support).
    pub deadline_hit: bool,
}

/// Reusable buffers for one m-domain refresh: the lockstep block-CG
/// state, the batched-FFT workspaces (the operator and preconditioner
/// closures are alive simultaneously, so each owns one), the staged
/// RHS / solution blocks, and the sequential reference path's scalar CG
/// workspace. All buffers are `(n_s + 1) x m` and resize with the grid.
#[derive(Clone, Debug, Default)]
pub(crate) struct RefreshWorkspace {
    /// Lockstep block-CG buffers (`n_s + 1` systems of size `m`).
    cg: BlockCgWorkspace,
    /// Batched-FFT scratch for the operator closure.
    fft: FftWorkspace,
    /// Batched-FFT scratch for the preconditioner closure.
    fft_p: FftWorkspace,
    /// Staged right-hand-side block.
    rhs: Vec<f64>,
    /// Warm-start / solution block.
    xblk: Vec<f64>,
    /// Operator temporaries.
    s1: Vec<f64>,
    s2: Vec<f64>,
    /// Scalar CG workspace for the sequential reference path.
    seq: CgWorkspace,
}

impl RefreshWorkspace {
    /// Fresh (empty) workspace; buffers grow on first refresh.
    pub(crate) fn new() -> Self {
        Self::default()
    }

    fn resize(&mut self, m: usize, cols: usize) {
        let total = m * cols;
        if self.rhs.len() != total {
            self.rhs.resize(total, 0.0);
            self.xblk.resize(total, 0.0);
            self.s1.resize(total, 0.0);
            self.s2.resize(total, 0.0);
        }
    }
}

/// A built preconditioner application `out = M^{-1} v` for one refresh:
/// the [`Preconditioner`] choice resolved against the statistics that
/// were actually supplied. The spectral arm precomputes the reciprocal
/// spectrum and carries a reusable m-length FFT buffer, so applying it
/// adds no per-iteration O(m) allocations to the CG hot path (on
/// multi-dimensional grids `fftn` still gathers strided axes through a
/// small line-length scratch).
pub(crate) enum PrecondApply {
    /// Unpreconditioned (`M = I`).
    Identity,
    /// Jacobi: element-wise division by `diag(B)`.
    Diag(Vec<f64>),
    /// Spectral: `(sigma^2 I + sf2 rho C)^{-1}` applied in the Fourier
    /// domain with the reciprocal spectrum precomputed at build time.
    Spectral {
        /// Grid shape (row-major tensor layout of the FFT).
        shape: Vec<usize>,
        /// `1 / (sf2 rho e_k + sigma^2)` per eigenvalue, real.
        inv: Vec<f64>,
        /// Reusable complex FFT workspace (length m).
        buf: Vec<C64>,
    },
}

impl PrecondApply {
    /// Single-vector application (the sequential reference path).
    fn apply(&mut self, v: &[f64], out: &mut [f64]) {
        match self {
            PrecondApply::Identity => out.copy_from_slice(v),
            PrecondApply::Diag(d) => {
                for ((o, &vi), &di) in out.iter_mut().zip(v).zip(d.iter()) {
                    *o = vi / di;
                }
            }
            PrecondApply::Spectral { shape, inv, buf } => {
                for (b, &vi) in buf.iter_mut().zip(v) {
                    *b = C64::real(vi);
                }
                fftn(buf, shape, false);
                for (b, &s) in buf.iter_mut().zip(inv.iter()) {
                    *b = b.scale(s);
                }
                fftn(buf, shape, true);
                for (o, b) in out.iter_mut().zip(buf.iter()) {
                    *o = b.re;
                }
            }
        }
    }

    /// Batched application over a row-major `cols x m` block: the
    /// spectral arm runs through the two-for-one batched FFT engine
    /// (half the transforms of `cols` single applications), the Jacobi
    /// arm is a per-column elementwise divide.
    fn apply_batch(&mut self, v: &[f64], out: &mut [f64], ws: &mut FftWorkspace) {
        match self {
            PrecondApply::Identity => out.copy_from_slice(v),
            PrecondApply::Diag(d) => {
                let m = d.len();
                for (vc, oc) in v.chunks_exact(m).zip(out.chunks_exact_mut(m)) {
                    for ((o, &vi), &di) in oc.iter_mut().zip(vc).zip(d.iter()) {
                        *o = vi / di;
                    }
                }
            }
            PrecondApply::Spectral { shape, inv, .. } => {
                apply_real_spectrum_batch(v, out, shape, inv, |e| e, ws);
            }
        }
    }
}

/// Warn once per process when a requested preconditioner degrades (the
/// condition is a caller misconfiguration, not a per-refresh event, so
/// one line suffices and the counters carry the ongoing signal).
static PRECOND_FALLBACK_WARN: Once = Once::new();

/// Resolve the requested [`Preconditioner`] into a [`PrecondApply`].
/// Returns `(apply, fallback)` where `fallback` is `true` when a
/// preconditioner was requested but `diag(G)` was not supplied — the
/// solve then degrades to unpreconditioned CG instead of panicking the
/// refresh thread.
pub(crate) fn build_precond(inp: &RefreshInputs<'_>) -> (PrecondApply, bool) {
    let g_diag = match inp.opts.precondition {
        Preconditioner::None => return (PrecondApply::Identity, false),
        Preconditioner::Jacobi | Preconditioner::Spectral => match inp.g_diag {
            Some(g) => g,
            None => {
                PRECOND_FALLBACK_WARN.call_once(|| {
                    crate::log_warn!(
                        "refresh preconditioner ({}) requested but diag(G) was not \
                         supplied; degrading to unpreconditioned CG",
                        inp.opts.precondition.name()
                    );
                });
                return (PrecondApply::Identity, true);
            }
        },
    };
    let m = inp.wty.len();
    let sigma2 = inp.sigma2;
    match inp.opts.precondition {
        Preconditioner::None => unreachable!("handled above"),
        Preconditioner::Jacobi => {
            // Circulant (and Kronecker-of-circulant) operators have a
            // constant diagonal: read it off the first column of `S`.
            let s0 = {
                let mut e0 = vec![0.0; m];
                e0[0] = 1.0;
                inp.gk.sqrt_matvec(&e0)[0]
            };
            // Every entry must stay strictly positive for an SPD
            // preconditioner; empty cells have G_ii = 0 and fall back to
            // the noise floor.
            let floor = sigma2.abs().max(1e-12);
            let d = g_diag
                .iter()
                .map(|&g| (sigma2 + inp.sf2 * s0 * s0 * g).max(floor))
                .collect();
            (PrecondApply::Diag(d), false)
        }
        Preconditioner::Spectral => {
            // G ~= rho I with rho = trace(G) / m, so
            // B ~= sigma^2 I + sf2 rho S S = sigma^2 I + sf2 rho C —
            // a shifted BCCB (Kronecker-of-circulants is a BCCB too),
            // invertible exactly in the Fourier domain. An empty
            // trainer has rho = 0 and M degenerates to sigma^2 I (a
            // scalar scaling: harmless and still SPD). The same
            // positivity floor as the Jacobi arm keeps every
            // reciprocal finite when sigma^2 = 0 meets a clipped
            // (exactly zero) eigenvalue.
            let rho = (g_diag.iter().sum::<f64>() / m.max(1) as f64).max(0.0);
            let a = inp.sf2 * rho;
            let floor = sigma2.abs().max(1e-12);
            let inv: Vec<f64> = inp
                .gk
                .circulant_eigenvalues()
                .iter()
                .map(|&e| 1.0 / (a * e.max(0.0) + sigma2).max(floor))
                .collect();
            let shape = inp.gk.shape();
            (PrecondApply::Spectral { shape, inv, buf: vec![C64::ZERO; m] }, false)
        }
    }
}

/// One CG solve on the m-domain operator `B = sigma^2 I + sf2 S G S`,
/// with `G v` supplied by `g_apply` and the preconditioner already
/// resolved by [`build_precond`].
#[allow(clippy::too_many_arguments)]
fn solve_mdomain(
    gk: &GridKernel,
    sf2: f64,
    sigma2: f64,
    g_apply: &mut dyn FnMut(&[f64], &mut [f64]),
    gout: &mut [f64],
    precond: &mut PrecondApply,
    rhs: &[f64],
    x: &mut [f64],
    opts: CgOptions,
    ws: &mut CgWorkspace,
) -> CgResult {
    let mut apply = |v: &[f64], out: &mut [f64]| {
        let s1 = gk.sqrt_matvec(v);
        g_apply(&s1, &mut *gout);
        let s3 = gk.sqrt_matvec(&*gout);
        for ((o, &s), &vi) in out.iter_mut().zip(&s3).zip(v) {
            *o = sf2 * s + sigma2 * vi;
        }
    };
    cg_solve(
        &mut apply,
        |v: &[f64], out: &mut [f64]| precond.apply(v, out),
        rhs,
        x,
        opts,
        ws,
    )
}

/// Rebuild the fast-prediction caches from sufficient statistics:
/// `u_mean = sf2 S B^{-1} S b` and the stochastic `nu_U` via the probe
/// accumulators, where `B = sigma^2 I + sf2 S G S`. The mean and all
/// `n_s` probe systems share the operator, so the refresh performs
/// **exactly one lockstep block-CG solve** ([`cg_solve_block`]): per
/// iteration `S` is applied to the whole `(n_s + 1) x m` block through
/// the batched two-for-one FFT engine — `ceil((n_s + 1) / 2)` complex
/// transforms instead of `n_s + 1` — with per-column convergence
/// masking, each solve O(m log m + m 7^D) per column and independent of
/// n. Shared by [`StreamTrainer::refresh`] and the per-shard workers
/// (which combine an owned and a halo accumulator into one `G` apply).
///
/// `opts.precondition` selects the solve preconditioner (see the
/// [module docs](self) for the operator algebra): `Jacobi` builds the
/// O(m) diagonal from the tracked `diag(G)`; `Spectral` builds the
/// O(m log m) BCCB approximate inverse `(sigma^2 I + sf2 rho C)^{-1}`
/// from the grid operator's circulant spectrum and the mean occupancy
/// `rho`, applied batched through the same FFT engine. Both typically
/// cut CG iterations well below the unpreconditioned count on
/// spatially non-uniform streams.
// lint:hot
pub(crate) fn refresh_mdomain(
    inp: RefreshInputs<'_>,
    g_apply: &mut dyn FnMut(&[f64], &mut [f64]),
    t_mean: &mut [f64],
    t_probes: &mut [Vec<f64>],
    ws: &mut RefreshWorkspace,
) -> RefreshOutcome {
    let m = inp.wty.len();
    let ns = inp.g_probes.len();
    let cols = ns + 1;
    let sf2 = inp.sf2;
    let sigma2 = inp.sigma2;
    let (mut precond, precond_fallback) = build_precond(&inp);
    ws.resize(m, cols);
    let RefreshWorkspace { cg, fft, fft_p, rhs, xblk, s1, s2, .. } = ws;
    // --- stage the RHS block: one batched S over [b | g_1 .. g_ns] ---
    let t_stage = Instant::now();
    let sp_rhs = crate::span!("refresh.stage_rhs");
    crate::failpoint!("refresh.stage_rhs");
    s2[..m].copy_from_slice(inp.wty);
    for (k, g) in inp.g_probes.iter().enumerate() {
        s2[(k + 1) * m..(k + 2) * m].copy_from_slice(g);
    }
    inp.gk.sqrt_matvec_batch(&s2[..cols * m], &mut s1[..cols * m], fft);
    rhs[..m].copy_from_slice(&s1[..m]);
    // p~_k = sqrt(sf2) G S g_k + sigma q_k (the m-domain image of the
    // Papandreou–Yuille probe), staged into s2 rows 0..ns ...
    let sig = sigma2.sqrt();
    let rsf = sf2.sqrt();
    for k in 0..ns {
        g_apply(&s1[(k + 1) * m..(k + 2) * m], &mut s2[k * m..(k + 1) * m]);
        let q = &inp.probes_q[k];
        for (v, &qi) in s2[k * m..(k + 1) * m].iter_mut().zip(q) {
            *v = rsf * *v + sig * qi;
        }
    }
    // ... then rhs rows 1.. = S p~ in a second batched apply.
    if ns > 0 {
        inp.gk.sqrt_matvec_batch(&s2[..ns * m], &mut rhs[m..cols * m], fft);
    }
    drop(sp_rhs);
    let stage_rhs = t_stage.elapsed();
    // --- warm starts in, ONE block solve (mean + probes), warm starts out ---
    let t_solve = Instant::now();
    let sp_solve = crate::span!("refresh.block_solve");
    crate::failpoint!("refresh.block_solve");
    xblk[..m].copy_from_slice(t_mean);
    for (k, t) in t_probes.iter().enumerate() {
        xblk[(k + 1) * m..(k + 2) * m].copy_from_slice(t);
    }
    let gk = inp.gk;
    // Width-adaptive batched operator: block CG compacts converged
    // columns out, so the incoming block can be any `k x m` with
    // `k <= cols` — every stage keys its width off `v.len()`.
    let mut apply = |v: &[f64], out: &mut [f64]| {
        let k = v.len() / m;
        gk.sqrt_matvec_batch(v, &mut s1[..k * m], fft);
        for c in 0..k {
            g_apply(&s1[c * m..(c + 1) * m], &mut s2[c * m..(c + 1) * m]);
        }
        gk.sqrt_matvec_batch(&s2[..k * m], &mut s1[..k * m], fft);
        for ((o, &s), &vi) in out.iter_mut().zip(s1.iter()).zip(v) {
            *o = sf2 * s + sigma2 * vi;
        }
    };
    let res = cg_solve_block(
        &mut apply,
        |v: &[f64], out: &mut [f64]| precond.apply_batch(v, out, fft_p),
        rhs,
        xblk,
        m,
        inp.opts,
        cg,
    );
    t_mean.copy_from_slice(&xblk[..m]);
    for (k, t) in t_probes.iter_mut().enumerate() {
        t.copy_from_slice(&xblk[(k + 1) * m..(k + 2) * m]);
    }
    drop(sp_solve);
    let block_solve = t_solve.elapsed();
    // --- one batched S maps every solution to the u-domain ---
    let t_map = Instant::now();
    let sp_map = crate::span!("refresh.map_back");
    crate::failpoint!("refresh.map_back");
    inp.gk.sqrt_matvec_batch(&xblk[..cols * m], &mut s1[..cols * m], fft);
    // lint:allow(alloc, "result assembly: the returned snapshot owns
    // its buffers; once per refresh, not per CG iteration")
    let mut u_mean = s1[..m].to_vec();
    for v in u_mean.iter_mut() {
        *v *= sf2;
    }
    // lint:allow(alloc, "result assembly, once per refresh")
    let mut acc = vec![0.0f64; m];
    for k in 0..ns {
        for (a, &v) in acc.iter_mut().zip(&s1[(k + 1) * m..(k + 2) * m]) {
            let t = sf2 * v;
            *a += t * t;
        }
    }
    for a in acc.iter_mut() {
        *a /= ns.max(1) as f64;
    }
    drop(sp_map);
    let map_back = t_map.elapsed();
    RefreshOutcome {
        u_mean,
        nu_u: acc,
        mean_iters: res.col_iters[0],
        var_iters: res.col_iters[1..].iter().sum(),
        block_iters: res.block_iters,
        apply_cols: res.apply_cols,
        precond_fallback,
        stage_wall: [stage_rhs, block_solve, map_back],
        deadline_hit: res.deadline_hit,
    }
}

/// Reference implementation of the refresh: the historical `n_s + 1`
/// *sequential* warm-started CG solves against the identical operator.
/// Kept so the acceptance tests can pin block == sequential and so
/// `benches/fig7_batched.rs` can measure the speedup; production
/// refreshes always take the block path above.
pub(crate) fn refresh_mdomain_sequential(
    inp: RefreshInputs<'_>,
    g_apply: &mut dyn FnMut(&[f64], &mut [f64]),
    t_mean: &mut [f64],
    t_probes: &mut [Vec<f64>],
    ws: &mut RefreshWorkspace,
) -> RefreshOutcome {
    let m = inp.wty.len();
    let sf2 = inp.sf2;
    let sigma2 = inp.sigma2;
    // The sequential path interleaves staging / solving / map-back per
    // probe, so the stage split does not apply: its whole solve loop
    // reports as `block_solve` (and traces as one span).
    let t_total = Instant::now();
    let _sp = crate::span!("refresh.sequential_solves");
    let (mut precond, precond_fallback) = build_precond(&inp);
    let mut gout = vec![0.0f64; m];
    // --- mean solve ---
    let s_b = inp.gk.sqrt_matvec(inp.wty);
    let mean_res = solve_mdomain(
        inp.gk,
        sf2,
        sigma2,
        &mut *g_apply,
        &mut gout,
        &mut precond,
        &s_b,
        t_mean,
        inp.opts,
        &mut ws.seq,
    );
    let mut u_mean = inp.gk.sqrt_matvec(t_mean);
    for v in u_mean.iter_mut() {
        *v *= sf2;
    }
    // --- variance probes ---
    let sig = sigma2.sqrt();
    let rsf = sf2.sqrt();
    let mut acc = vec![0.0f64; m];
    let mut var_iters = 0usize;
    let ns = inp.g_probes.len().max(1);
    let mut gsg = vec![0.0f64; m];
    for (k, g_k) in inp.g_probes.iter().enumerate() {
        // p~ = sqrt(sf2) G S g_k + sigma q_k, then solve B t = S p~.
        let sg = inp.gk.sqrt_matvec(g_k);
        g_apply(&sg, &mut gsg);
        let q = &inp.probes_q[k];
        let ptilde: Vec<f64> = gsg.iter().zip(q).map(|(&a, &b)| rsf * a + sig * b).collect();
        let rhs = inp.gk.sqrt_matvec(&ptilde);
        let res = solve_mdomain(
            inp.gk,
            sf2,
            sigma2,
            &mut *g_apply,
            &mut gout,
            &mut precond,
            &rhs,
            &mut t_probes[k],
            inp.opts,
            &mut ws.seq,
        );
        var_iters += res.iters;
        let uk = inp.gk.sqrt_matvec(&t_probes[k]);
        for (a, &v) in acc.iter_mut().zip(&uk) {
            let t = sf2 * v;
            *a += t * t;
        }
    }
    for a in acc.iter_mut() {
        *a /= ns as f64;
    }
    // Sequential accounting mirror: each scalar solve pays `iters + 1`
    // single-column operator applies (initial residual + per iteration).
    let apply_cols = (mean_res.iters + 1) + var_iters + inp.g_probes.len();
    RefreshOutcome {
        u_mean,
        nu_u: acc,
        mean_iters: mean_res.iters,
        var_iters,
        block_iters: 0,
        apply_cols,
        precond_fallback,
        stage_wall: [Duration::ZERO, t_total.elapsed(), Duration::ZERO],
        deadline_hit: false,
    }
}

/// The streaming trainer: owns the sufficient statistics, the structured
/// grid operator, and the warm-start state for all m-domain solves.
pub struct StreamTrainer {
    /// Kernel hyperparameters (updated by [`Self::reoptimize`]).
    pub kernel: KernelSpec,
    /// Noise variance.
    pub sigma2: f64,
    /// Configuration.
    pub cfg: StreamConfig,
    ski: IncrementalSki,
    gk: GridKernel,
    /// Warm start for the mean solve (m).
    t_mean: Vec<f64>,
    /// Warm starts for the variance-probe solves (`n_s` x m).
    t_probes: Vec<Vec<f64>>,
    /// Fixed `N(0, I_m)` probe draws (`n_s` x m); new cells after an
    /// expansion get fresh normals, existing cells keep theirs.
    g_probes: Vec<Vec<f64>>,
    rws: RefreshWorkspace,
    probe_rng: Rng,
    /// Reservoir snapshot of the stream for hyper re-optimization.
    /// Shared (`Arc`) so a sharded facade can snapshot it without
    /// stopping the worker; the lock also serializes snapshots against
    /// [`Self::decay`].
    reservoir: Arc<Mutex<Reservoir>>,
    res_rng: Rng,
    /// Fast-mean grid cache `u_mean` from the last refresh (m).
    pub u_mean: Vec<f64>,
    /// Explained-variance grid cache `nu_U` from the last refresh (m).
    pub nu_u: Vec<f64>,
    /// Diagnostics from the last refresh.
    pub last_refresh: RefreshStats,
    /// Completed refreshes.
    pub refresh_count: u64,
    /// Points absorbed since the last refresh.
    pub dirty_points: usize,
    /// Points rejected (non-finite values, or coverage beyond
    /// `cfg.max_grid_cells`).
    pub rejected_points: usize,
    /// Refreshes that requested a preconditioner but had to degrade to
    /// unpreconditioned CG (mirrored into the coordinator's
    /// `precond_fallbacks` metric).
    pub precond_fallbacks: u64,
}

impl StreamTrainer {
    /// Fresh trainer over an initial grid (predicts the prior until data
    /// arrives).
    pub fn new(kernel: KernelSpec, sigma2: f64, grid: Grid, cfg: StreamConfig) -> Self {
        assert_eq!(kernel.dim(), grid.dim(), "kernel dim vs grid dim");
        let m = grid.m();
        let ns = cfg.msgp.n_var_samples.max(1);
        let seed = cfg.msgp.seed;
        let mut probe_rng = Rng::new(seed ^ 0x9b0b_u64);
        let gk = GridKernel::new(&kernel, &grid, &cfg.msgp);
        let ski = IncrementalSki::new(grid, ns, cfg.msgp.margin_cells, seed);
        StreamTrainer {
            g_probes: (0..ns).map(|_| probe_rng.normal_vec(m)).collect(),
            t_probes: (0..ns).map(|_| vec![0.0; m]).collect(),
            t_mean: vec![0.0; m],
            u_mean: vec![0.0; m],
            nu_u: vec![0.0; m],
            rws: RefreshWorkspace::new(),
            probe_rng,
            reservoir: Arc::new(Mutex::new(Reservoir::default())),
            res_rng: Rng::new(seed ^ 0x7e5e_u64),
            kernel,
            sigma2,
            cfg,
            ski,
            gk,
            last_refresh: RefreshStats::default(),
            refresh_count: 0,
            dirty_points: 0,
            rejected_points: 0,
            precond_fallbacks: 0,
        }
    }

    /// Trainer wrapped around pre-built sufficient statistics (the shard
    /// merge path: S owned accumulators folded into one global
    /// accumulator). The trainer refreshes and re-optimizes exactly as
    /// if it had ingested the underlying stream itself; its reservoir
    /// starts empty (the sharded facade keeps per-shard reservoirs).
    pub fn from_stats(
        kernel: KernelSpec,
        sigma2: f64,
        cfg: StreamConfig,
        ski: IncrementalSki,
    ) -> Self {
        let mut t = Self::new(kernel, sigma2, ski.grid().clone(), cfg);
        assert_eq!(
            t.g_probes.len(),
            ski.probes().len(),
            "cfg.msgp.n_var_samples must match the accumulator's probe count"
        );
        t.dirty_points = ski.n();
        t.ski = ski;
        t
    }

    /// Observations absorbed.
    pub fn n(&self) -> usize {
        self.ski.n()
    }

    /// Grid size.
    pub fn m(&self) -> usize {
        self.ski.m()
    }

    /// Current grid.
    pub fn grid(&self) -> &Grid {
        self.ski.grid()
    }

    /// Sufficient-statistic core (read access for diagnostics/tests).
    pub fn ski(&self) -> &IncrementalSki {
        &self.ski
    }

    /// Handle to the shared reservoir (the sharded facade clones this to
    /// snapshot per-shard reservoirs for whole-domain re-opts).
    pub fn reservoir_handle(&self) -> Arc<Mutex<Reservoir>> {
        self.reservoir.clone()
    }

    /// Consistent snapshot of the reservoir sample, taken under the same
    /// lock [`Self::decay`] holds while down-weighting the accumulators.
    pub fn reservoir_snapshot(&self) -> (Vec<f64>, Vec<f64>) {
        // Poison recovery: the reservoir holds plain sample data that
        // stays well-formed even if a supervised worker panicked while
        // holding the lock (worst case one half-updated sample row).
        let res = self.reservoir.lock().unwrap_or_else(|e| e.into_inner());
        (res.x.clone(), res.y.clone())
    }

    /// Points currently held in the reservoir (for the
    /// `reservoir_points` gauge and `/healthz`).
    pub fn reservoir_len(&self) -> usize {
        // Poison recovery: see `reservoir_snapshot`.
        self.reservoir.lock().unwrap_or_else(|e| e.into_inner()).y.len()
    }

    /// Absorb a batch of observations (row-major `k x D` inputs).
    /// O(4^D) per point; rebuilds the grid operator and remaps all
    /// warm-start state if the grid auto-expanded.
    pub fn ingest_batch(&mut self, xs: &[f64], ys: &[f64]) {
        let _sp = crate::span!("ingest.absorb");
        let d = self.ski.grid().dim();
        assert_eq!(xs.len(), ys.len() * d, "xs is k x D row-major, ys length k");
        let old_grid = self.ski.grid().clone();
        let mut applied = 0usize;
        let mut admitted: Vec<usize> = Vec::new();
        for (i, &y) in ys.iter().enumerate() {
            let row = &xs[i * d..(i + 1) * d];
            if !self.admit(row, y) {
                self.rejected_points += 1;
                continue;
            }
            self.ski.ingest(row, y);
            applied += 1;
            admitted.push(i);
        }
        // Lock only for the cheap reservoir offers — a concurrent
        // snapshot (via the shared handle) must not wait out the O(4^D)
        // scatter-adds or a grid-expansion remap above.
        if !admitted.is_empty() {
            let reservoir = self.reservoir.clone();
            // Poison recovery: see `reservoir_snapshot`.
            let mut res = reservoir.lock().unwrap_or_else(|e| e.into_inner());
            for &i in &admitted {
                res.offer(&xs[i * d..(i + 1) * d], ys[i], self.cfg.reservoir, &mut self.res_rng);
            }
        }
        self.dirty_points += applied;
        if self.ski.grid() != &old_grid {
            self.on_grid_changed(&old_grid);
        }
    }

    /// Epoch hook for non-stationary streams: exponentially down-weight
    /// the sufficient statistics (see [`IncrementalSki::decay`]). Taken
    /// under the reservoir lock so a concurrent re-opt snapshot (sharded
    /// deployments share the reservoir handle across threads) is ordered
    /// strictly before or after the decay — never interleaved with it.
    /// Marks the caches dirty so the next [`Self::serving_model`]
    /// refreshes.
    pub fn decay(&mut self, gamma: f64) {
        let reservoir = self.reservoir.clone();
        // Poison recovery: see `reservoir_snapshot`.
        let _guard = reservoir.lock().unwrap_or_else(|e| e.into_inner());
        self.ski.decay(gamma);
        if self.ski.n() > 0 {
            self.dirty_points = self.dirty_points.max(1);
        }
    }

    /// Admission control for one observation: finite values only, and
    /// any required auto-expansion must keep the grid under
    /// `cfg.max_grid_cells` (computed in f64 so a wild outlier cannot
    /// overflow the size arithmetic before the check).
    fn admit(&self, row: &[f64], y: f64) -> bool {
        if !y.is_finite() || row.iter().any(|v| !v.is_finite()) {
            return false;
        }
        let grid = self.ski.grid();
        // Same effective margin as IncrementalSki (which clamps to >= 1),
        // so the cap is sized against the expansion that will actually
        // be applied.
        if let Some(exp) = grid.expansion_to_cover(row, self.cfg.msgp.margin_cells.max(1)) {
            let mut m_new = 1.0f64;
            for (a, ax) in grid.axes.iter().enumerate() {
                m_new *= (ax.n as f64) + (exp.added_lo[a] as f64) + (exp.added_hi[a] as f64);
            }
            if m_new > self.cfg.max_grid_cells as f64 {
                return false;
            }
        }
        true
    }

    fn on_grid_changed(&mut self, old_grid: &Grid) {
        let new_grid = self.ski.grid().clone();
        self.gk = GridKernel::new(&self.kernel, &new_grid, &self.cfg.msgp);
        self.t_mean = remap_grid_vec(old_grid, &new_grid, &self.t_mean);
        self.u_mean = remap_grid_vec(old_grid, &new_grid, &self.u_mean);
        self.nu_u = remap_grid_vec(old_grid, &new_grid, &self.nu_u);
        for t in self.t_probes.iter_mut() {
            *t = remap_grid_vec(old_grid, &new_grid, t);
        }
        // Probe draws: keep existing cells' normals, give new cells
        // fresh ones (zeros would bias the variance estimate low).
        let mask = {
            let ones = vec![1.0; old_grid.m()];
            remap_grid_vec(old_grid, &new_grid, &ones)
        };
        for g in self.g_probes.iter_mut() {
            let remapped = remap_grid_vec(old_grid, &new_grid, g);
            *g = remapped
                .iter()
                .zip(&mask)
                .map(|(&v, &keep)| if keep > 0.5 { v } else { self.probe_rng.normal() })
                .collect();
        }
        self.rws = RefreshWorkspace::new();
    }

    /// Warm-started refresh of the fast-prediction caches:
    /// `u_mean = sf2 S B^{-1} S b` and the stochastic `nu_U` via the
    /// probe accumulators. Cost: **one lockstep block-CG solve** over
    /// the mean + `n_s` probe systems on the m-domain operator
    /// `B = sigma^2 I + sf2 S G S` — one batched operator apply per
    /// iteration, independent of n. Each column uses the preconditioner
    /// selected by `cfg.msgp.cg.precondition` (`Spectral` by default,
    /// applied batched; see [`refresh_mdomain`]).
    pub fn refresh(&mut self) -> RefreshStats {
        self.refresh_impl(true)
    }

    /// Reference refresh running the historical `n_s + 1` *sequential*
    /// CG solves instead of the single block solve — identical results
    /// (the acceptance tests pin agreement to 1e-8), kept public for
    /// A/B validation and the `benches/fig7_batched.rs` speedup table.
    /// Production callers want [`Self::refresh`].
    pub fn refresh_sequential(&mut self) -> RefreshStats {
        self.refresh_impl(false)
    }

    fn refresh_impl(&mut self, block: bool) -> RefreshStats {
        let t0 = Instant::now();
        let panels_before = crate::linalg::fft::parallel_panels_total();
        let m = self.m();
        let opts = self.cfg.msgp.cg.warm().with_deadline_ms(self.cfg.refresh_deadline_ms);
        // Borrow the read-only operator pieces as disjoint fields so the
        // warm-start buffers and workspace stay mutably borrowable.
        let ski = &self.ski;
        let inputs = RefreshInputs {
            gk: &self.gk,
            sf2: self.kernel.sf2(),
            sigma2: self.sigma2,
            opts,
            wty: ski.wty(),
            probes_q: ski.probes(),
            g_probes: &self.g_probes,
            g_diag: Some(ski.g_diag()),
        };
        let mut g_apply = |v: &[f64], out: &mut [f64]| ski.g_matvec_into(v, out);
        let out = if block {
            refresh_mdomain(
                inputs,
                &mut g_apply,
                &mut self.t_mean,
                &mut self.t_probes,
                &mut self.rws,
            )
        } else {
            refresh_mdomain_sequential(
                inputs,
                &mut g_apply,
                &mut self.t_mean,
                &mut self.t_probes,
                &mut self.rws,
            )
        };
        self.u_mean = out.u_mean;
        self.nu_u = out.nu_u;
        self.refresh_count += 1;
        // A deadline-aborted refresh keeps its dirty marker so the next
        // ingest cycle retries; the partial solutions stay in the warm
        // starts, so the retry resumes where the abort stopped.
        self.dirty_points = if out.deadline_hit { self.dirty_points.max(1) } else { 0 };
        if out.precond_fallback {
            self.precond_fallbacks += 1;
        }
        let stats = RefreshStats {
            mean_iters: out.mean_iters,
            var_iters_total: out.var_iters,
            block_iters: out.block_iters,
            threads: crate::parallel::threads(),
            parallel: crate::linalg::fft::parallel_panels_total() > panels_before,
            m,
            n: self.n(),
            wall: t0.elapsed(),
            stage_rhs: out.stage_wall[0],
            block_solve: out.stage_wall[1],
            map_back: out.stage_wall[2],
            precond_fallback: out.precond_fallback,
            deadline_hit: out.deadline_hit,
        };
        self.last_refresh = stats.clone();
        stats
    }

    /// Freeze the current caches into a serving snapshot (refresh first
    /// if ingests happened since the last refresh).
    pub fn serving_model(&mut self) -> ServingModel {
        if self.dirty_points > 0 || self.refresh_count == 0 {
            self.refresh();
        }
        ServingModel::from_parts(
            self.ski.grid().clone(),
            self.u_mean.clone(),
            self.nu_u.clone(),
            self.kernel.sf2(),
            self.sigma2,
        )
    }

    /// Whittle hyperparameter re-optimization on the reservoir snapshot:
    /// fit a batch MSGP on the sampled points (same grid), run
    /// `reopt_iters` Adam steps on the spectral marginal likelihood,
    /// adopt the learned hypers, rebuild the grid operator, and refresh.
    /// Returns the final snapshot LML, or `None` when the reservoir is
    /// still empty — or when repeated decay has driven the effective
    /// sample mass below [`MIN_EFFECTIVE_MASS`] (the model has forgotten
    /// the stream the reservoir still describes, so hypers fit to that
    /// stale snapshot would be adopted against near-zero statistics).
    pub fn reoptimize(&mut self) -> anyhow::Result<Option<f64>> {
        let _sp = crate::span!("reopt");
        if self.ski.weight() < MIN_EFFECTIVE_MASS {
            return Ok(None);
        }
        let (res_x, res_y) = self.reservoir_snapshot();
        if res_y.is_empty() {
            return Ok(None);
        }
        let d = self.ski.grid().dim();
        let snapshot = Dataset { x: res_x, d, y: res_y };
        let mut cfg = self.cfg.msgp.clone();
        cfg.n_per_dim = self.ski.grid().shape();
        let mut model = MsgpModel::fit_with_grid(
            self.kernel.clone(),
            self.sigma2,
            snapshot,
            self.ski.grid().clone(),
            cfg,
        )?;
        model.train(self.cfg.reopt_iters, self.cfg.reopt_lr)?;
        let lml = model.lml();
        self.kernel = model.kernel.clone();
        self.sigma2 = model.sigma2;
        self.gk = GridKernel::new(&self.kernel, self.ski.grid(), &self.cfg.msgp);
        self.refresh();
        Ok(Some(lml))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridAxis;
    use crate::kernels::{KernelType, ProductKernel};

    fn se_kernel() -> KernelSpec {
        KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0))
    }

    /// A spatially skewed accumulator: two-thirds of the mass lands in
    /// one fifth of the domain, so `diag(G)` spans orders of magnitude.
    fn skewed_ski(m: usize, n: usize) -> (Grid, IncrementalSki) {
        let grid = Grid::new(vec![GridAxis::span(-5.0, 5.0, m)]);
        let mut ski = IncrementalSki::new(grid.clone(), 3, 1, 7);
        let mut rng = Rng::new(33);
        for i in 0..n {
            let x = if i % 3 == 0 {
                rng.uniform_in(-4.5, 4.5)
            } else {
                rng.uniform_in(-4.5, -2.5)
            };
            ski.ingest(&[x], 0.2 * (x * 1.3).sin());
        }
        (grid, ski)
    }

    fn run_refresh(
        precond: Preconditioner,
        give_diag: bool,
        gk: &GridKernel,
        ski: &IncrementalSki,
    ) -> RefreshOutcome {
        let m = ski.m();
        let ns = ski.probes().len();
        // Fixed probe draws so every run solves identical systems.
        let mut rng = Rng::new(4242);
        let g_probes: Vec<Vec<f64>> = (0..ns).map(|_| rng.normal_vec(m)).collect();
        let opts = CgOptions {
            tol: 1e-12,
            max_iter: 4000,
            warm_start: false,
            precondition: precond,
            deadline: None,
        };
        let inputs = RefreshInputs {
            gk,
            sf2: 1.0,
            sigma2: 0.1,
            opts,
            wty: ski.wty(),
            probes_q: ski.probes(),
            g_probes: &g_probes,
            g_diag: if give_diag { Some(ski.g_diag()) } else { None },
        };
        let mut t_mean = vec![0.0; m];
        let mut t_probes: Vec<Vec<f64>> = (0..ns).map(|_| vec![0.0; m]).collect();
        let mut ws = RefreshWorkspace::new();
        let mut g_apply = |v: &[f64], out: &mut [f64]| ski.g_matvec_into(v, out);
        refresh_mdomain(inputs, &mut g_apply, &mut t_mean, &mut t_probes, &mut ws)
    }

    fn fixed_probes(m: usize, ns: usize) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(4242);
        (0..ns).map(|_| rng.normal_vec(m)).collect()
    }

    fn refresh_inputs<'a>(
        gk: &'a GridKernel,
        ski: &'a IncrementalSki,
        g_probes: &'a [Vec<f64>],
        opts: CgOptions,
    ) -> RefreshInputs<'a> {
        RefreshInputs {
            gk,
            sf2: 1.0,
            sigma2: 0.1,
            opts,
            wty: ski.wty(),
            probes_q: ski.probes(),
            g_probes,
            g_diag: Some(ski.g_diag()),
        }
    }

    /// Acceptance (tentpole): the single block solve reproduces the
    /// `n_s + 1` sequential `solve_mdomain` results to 1e-10 on a
    /// skewed stream — cold, warm-started, and under the Spectral
    /// preconditioner.
    #[test]
    fn block_refresh_matches_sequential_to_1e10() {
        let (grid, mut ski) = skewed_ski(48, 500);
        let gk = GridKernel::new(&se_kernel(), &grid, &MsgpConfig::default());
        let m = ski.m();
        let ns = ski.probes().len();
        let g_probes = fixed_probes(m, ns);
        let tight = CgOptions { tol: 1e-13, max_iter: 8000, ..Default::default() };
        for precond in [Preconditioner::None, Preconditioner::Spectral] {
            let opts = CgOptions { precondition: precond, ..tight };
            // --- cold start ---
            let mut tm_b = vec![0.0; m];
            let mut tp_b: Vec<Vec<f64>> = (0..ns).map(|_| vec![0.0; m]).collect();
            let mut ws_b = RefreshWorkspace::new();
            let mut tm_s = vec![0.0; m];
            let mut tp_s: Vec<Vec<f64>> = (0..ns).map(|_| vec![0.0; m]).collect();
            let mut ws_s = RefreshWorkspace::new();
            {
                let mut g_apply = |v: &[f64], out: &mut [f64]| ski.g_matvec_into(v, out);
                let blk = refresh_mdomain(
                    refresh_inputs(&gk, &ski, &g_probes, opts),
                    &mut g_apply,
                    &mut tm_b,
                    &mut tp_b,
                    &mut ws_b,
                );
                let seq = refresh_mdomain_sequential(
                    refresh_inputs(&gk, &ski, &g_probes, opts),
                    &mut g_apply,
                    &mut tm_s,
                    &mut tp_s,
                    &mut ws_s,
                );
                for (a, b) in blk.u_mean.iter().zip(&seq.u_mean) {
                    assert!((a - b).abs() < 1e-10, "{precond:?} cold u_mean: {a} vs {b}");
                }
                for (a, b) in blk.nu_u.iter().zip(&seq.nu_u) {
                    assert!((a - b).abs() < 1e-10, "{precond:?} cold nu_u: {a} vs {b}");
                }
            }
            // --- warm start: absorb more data, re-solve from the
            //     previous solutions on both paths ---
            let mut rng = Rng::new(77);
            for _ in 0..150 {
                let x = rng.uniform_in(-4.5, -2.0);
                ski.ingest(&[x], 0.3 * (x * 0.9).cos());
            }
            let warm = CgOptions { precondition: precond, ..tight }.warm();
            let mut g_apply = |v: &[f64], out: &mut [f64]| ski.g_matvec_into(v, out);
            let blk_w = refresh_mdomain(
                refresh_inputs(&gk, &ski, &g_probes, warm),
                &mut g_apply,
                &mut tm_b,
                &mut tp_b,
                &mut ws_b,
            );
            let seq_w = refresh_mdomain_sequential(
                refresh_inputs(&gk, &ski, &g_probes, warm),
                &mut g_apply,
                &mut tm_s,
                &mut tp_s,
                &mut ws_s,
            );
            assert!(blk_w.block_iters > 0 && seq_w.block_iters == 0);
            for (a, b) in blk_w.u_mean.iter().zip(&seq_w.u_mean) {
                assert!((a - b).abs() < 1e-10, "{precond:?} warm u_mean: {a} vs {b}");
            }
            for (a, b) in blk_w.nu_u.iter().zip(&seq_w.nu_u) {
                assert!((a - b).abs() < 1e-10, "{precond:?} warm nu_u: {a} vs {b}");
            }
        }
    }

    /// Acceptance: the refresh performs exactly one block CG solve with
    /// active-column compaction. Counting `G` applications pins it:
    /// `n_s` during RHS staging plus [`RefreshOutcome::apply_cols`]
    /// inside the single lockstep solve (the initial full block, then
    /// one *compacted* active block per iteration) — no per-system
    /// solve loop remains, and converged columns stop paying for
    /// operator applies.
    #[test]
    fn refresh_is_exactly_one_block_solve() {
        let (grid, ski) = skewed_ski(48, 400);
        let gk = GridKernel::new(&se_kernel(), &grid, &MsgpConfig::default());
        let m = ski.m();
        let ns = ski.probes().len();
        let g_probes = fixed_probes(m, ns);
        let opts = CgOptions { tol: 1e-10, max_iter: 4000, ..Default::default() }.spectral();
        let mut tm = vec![0.0; m];
        let mut tp: Vec<Vec<f64>> = (0..ns).map(|_| vec![0.0; m]).collect();
        let mut ws = RefreshWorkspace::new();
        let mut g_calls = 0usize;
        let mut g_apply = |v: &[f64], out: &mut [f64]| {
            g_calls += 1;
            ski.g_matvec_into(v, out)
        };
        let out = refresh_mdomain(
            refresh_inputs(&gk, &ski, &g_probes, opts),
            &mut g_apply,
            &mut tm,
            &mut tp,
            &mut ws,
        );
        assert!(out.block_iters > 0);
        assert_eq!(
            g_calls,
            ns + out.apply_cols,
            "G applications must account for exactly one (compacted) block solve"
        );
        // The compacted solve never exceeds the uncompacted lockstep
        // cost and always pays at least one column per iteration plus
        // the initial full block.
        assert!(out.apply_cols <= (out.block_iters + 1) * (ns + 1));
        assert!(out.apply_cols >= out.block_iters + (ns + 1));
        // Per-column counts stay bounded by the lockstep length.
        assert!(out.mean_iters <= out.block_iters);
        assert!(out.var_iters <= ns * out.block_iters);
    }

    /// Acceptance (tentpole): the m-domain refresh is bit-identical
    /// across thread counts — the parallel FFT fan-out changes which
    /// core does the work, never the arithmetic. Grid size and probe
    /// count are chosen to clear the engine's parallel threshold.
    #[test]
    fn refresh_identical_across_thread_counts() {
        let grid = Grid::new(vec![GridAxis::span(-5.0, 5.0, 512)]);
        let mut ski = IncrementalSki::new(grid.clone(), 6, 1, 7);
        let mut rng = Rng::new(33);
        for i in 0..1500 {
            let x = if i % 3 == 0 {
                rng.uniform_in(-4.5, 4.5)
            } else {
                rng.uniform_in(-4.5, -2.5)
            };
            ski.ingest(&[x], 0.2 * (x * 1.3).sin());
        }
        let gk = GridKernel::new(&se_kernel(), &grid, &MsgpConfig::default());
        let m = ski.m();
        let ns = ski.probes().len();
        let g_probes = fixed_probes(m, ns);
        let opts = CgOptions { tol: 1e-10, max_iter: 4000, ..Default::default() }.spectral();
        let run_with = |threads: usize| -> (Vec<f64>, Vec<f64>) {
            crate::parallel::configure(crate::parallel::ParallelConfig { threads });
            let mut tm = vec![0.0; m];
            let mut tp: Vec<Vec<f64>> = (0..ns).map(|_| vec![0.0; m]).collect();
            let mut ws = RefreshWorkspace::new();
            let mut g_apply = |v: &[f64], out: &mut [f64]| ski.g_matvec_into(v, out);
            let out = refresh_mdomain(
                refresh_inputs(&gk, &ski, &g_probes, opts),
                &mut g_apply,
                &mut tm,
                &mut tp,
                &mut ws,
            );
            (out.u_mean, out.nu_u)
        };
        let (mean_1, nu_1) = run_with(1);
        let (mean_4, nu_4) = run_with(4);
        crate::parallel::configure(crate::parallel::ParallelConfig { threads: 0 });
        for (a, b) in mean_1.iter().zip(&mean_4) {
            assert!((a - b).abs() < 1e-12, "u_mean diverged across threads: {a} vs {b}");
        }
        for (a, b) in nu_1.iter().zip(&nu_4) {
            assert!((a - b).abs() < 1e-12, "nu_u diverged across threads: {a} vs {b}");
        }
    }

    /// Satellite regression: a preconditioner request without the
    /// tracked `diag(G)` must degrade to unpreconditioned CG (same
    /// solve, fallback flagged) instead of panicking the refresh thread.
    #[test]
    fn missing_g_diag_degrades_to_unpreconditioned_cg() {
        let (grid, ski) = skewed_ski(48, 400);
        let gk = GridKernel::new(&se_kernel(), &grid, &MsgpConfig::default());
        let plain = run_refresh(Preconditioner::None, true, &gk, &ski);
        assert!(!plain.precond_fallback);
        for precond in [Preconditioner::Jacobi, Preconditioner::Spectral] {
            let degraded = run_refresh(precond, false, &gk, &ski);
            assert!(degraded.precond_fallback, "{precond:?} must flag the fallback");
            assert_eq!(
                degraded.mean_iters, plain.mean_iters,
                "degraded {precond:?} solve must be the unpreconditioned solve"
            );
            for (a, b) in degraded.u_mean.iter().zip(&plain.u_mean) {
                assert!((a - b).abs() < 1e-12, "{a} vs {b}");
            }
        }
    }

    /// Satellite (degradation tier): an already-expired refresh deadline
    /// aborts the block solve between iterations, reports
    /// `deadline_hit`, and keeps the trainer dirty so the next cycle
    /// retries — while a deadline-free rerun of the same trainer
    /// completes normally and clears both flags.
    #[test]
    fn refresh_deadline_aborts_and_keeps_the_trainer_dirty() {
        let grid = Grid::new(vec![GridAxis::span(-5.0, 5.0, 48)]);
        let mut cfg = StreamConfig::default();
        cfg.refresh_deadline_ms = Some(0);
        let mut t = StreamTrainer::new(se_kernel(), 0.1, grid, cfg);
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let x = rng.uniform_in(-4.5, 4.5);
            t.ingest_batch(&[x], &[0.2 * (x * 1.3).sin()]);
        }
        assert!(t.dirty_points > 0);
        let stats = t.refresh();
        assert!(stats.deadline_hit, "expired deadline must abort the solve");
        assert_eq!(stats.block_iters, 0);
        assert!(t.dirty_points > 0, "aborted refresh must stay dirty for retry");
        t.cfg.refresh_deadline_ms = None;
        let stats = t.refresh();
        assert!(!stats.deadline_hit);
        assert!(stats.block_iters > 0);
        assert_eq!(t.dirty_points, 0);
    }

    /// The spectral BCCB preconditioner changes the iteration path, not
    /// the solution.
    #[test]
    fn spectral_precondition_preserves_the_solution() {
        let (grid, ski) = skewed_ski(48, 600);
        let gk = GridKernel::new(&se_kernel(), &grid, &MsgpConfig::default());
        let plain = run_refresh(Preconditioner::None, true, &gk, &ski);
        let spec = run_refresh(Preconditioner::Spectral, true, &gk, &ski);
        assert!(!spec.precond_fallback);
        for (a, b) in spec.u_mean.iter().zip(&plain.u_mean) {
            assert!((a - b).abs() < 1e-8, "{a} vs {b}");
        }
        for (a, b) in spec.nu_u.iter().zip(&plain.nu_u) {
            assert!((a - b).abs() < 1e-6, "nu_u drifted: {a} vs {b}");
        }
    }
}
