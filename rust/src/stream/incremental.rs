//! The incremental SKI core: grid-local sufficient statistics with
//! O(4^D)-per-point updates and step-preserving grid auto-expansion.

use crate::grid::{Grid, GridExpansion};
use crate::interp::for_each_tap;
use crate::util::Rng;

/// Width of the banded `W^T W` Gram matrix per dimension: two cubic
/// stencils overlap iff their base cells differ by at most 3, so the
/// per-dimension index offset between coupled grid cells lies in
/// `-3 ..= 3`.
const BAND_W: usize = 7;
const BAND_HALF: i64 = 3;

/// Minimum effective sample mass for weight-normalized statistics.
/// Repeated [`IncrementalSki::decay`] with no fresh ingest drives
/// `weight` toward zero geometrically; once it underflows into the
/// subnormal range the ratios `sum_y / weight` and `sum_y2 / weight`
/// lose all precision (and become `inf`/`NaN` at exact underflow).
/// `y_mean` / `y_var` return `0.0` below this mass, and hyper
/// re-optimization is skipped entirely below it (see
/// [`crate::stream::StreamTrainer::reoptimize`]): a trainer that has
/// forgotten everything serves the prior rather than refitting to
/// numerically meaningless statistics.
pub const MIN_EFFECTIVE_MASS: f64 = 1e-12;

/// Remap a flat grid vector from `old` onto `new`, where `old` sits
/// inside `new` at a whole-cell offset with the same steps (`new` is an
/// expansion of `old`, or `old` is a shard's local sub-grid of a global
/// `new`). Cells outside `old` are zero.
pub fn remap_grid_vec(old: &Grid, new: &Grid, v: &[f64]) -> Vec<f64> {
    assert_eq!(v.len(), old.m());
    let shift = old.shift_within(new);
    let d = old.dim();
    let old_shape = old.shape();
    let new_shape = new.shape();
    // Row-major strides of the new grid.
    let mut strides = vec![1usize; d];
    for a in (0..d.saturating_sub(1)).rev() {
        strides[a] = strides[a + 1] * new_shape[a + 1];
    }
    let mut out = vec![0.0; new.m()];
    let mut idx = vec![0usize; d];
    for &val in v.iter() {
        let mut f = 0usize;
        for a in 0..d {
            f += (idx[a] + shift[a]) * strides[a];
        }
        out[f] = val;
        // Odometer over the old shape (last axis fastest, row-major).
        for a in (0..d).rev() {
            idx[a] += 1;
            if idx[a] < old_shape[a] {
                break;
            }
            idx[a] = 0;
        }
    }
    out
}

/// Streaming sufficient statistics of the SKI decomposition. See the
/// [module docs](crate::stream) for the algebra.
#[derive(Clone)]
pub struct IncrementalSki {
    grid: Grid,
    /// `b = W^T y`, length `m`.
    wty: Vec<f64>,
    /// Banded `G = W^T W`: `bands[o][i] = G[i, j]` where `j`'s
    /// multi-index is `i`'s shifted by the per-dimension deltas encoded
    /// in `o` (base-7 digits, each `delta + 3`). `7^D` bands of length
    /// `m`; both `(i, j)` and `(j, i)` entries are stored, so `G`
    /// MVMs need no symmetry bookkeeping.
    bands: Vec<Vec<f64>>,
    /// Per-cell point mass (nearest grid cell), length `m`. Whole counts
    /// until [`Self::decay`] down-weights history, fractional after.
    counts: Vec<f64>,
    /// Probe accumulators `q_k = sum_i eps_ik w_i` — exact fixed samples
    /// of `N(0, G)` for the stochastic variance estimator, maintained
    /// without retaining any raw data.
    probes: Vec<Vec<f64>>,
    /// Margin (cells) enforced around ingested points on auto-expansion.
    margin_cells: usize,
    n: usize,
    /// Effective sample mass: `+1` per ingest, scaled by every
    /// [`Self::decay`]. `y_mean`/`y_var` divide by this, so both are
    /// invariant under decay (numerator and denominator scale together).
    weight: f64,
    sum_y: f64,
    sum_y2: f64,
    rng: Rng,
    /// Reused per-point buffers — keeps the O(4^D) hot path
    /// allocation-free in steady state.
    scratch: IngestScratch,
}

#[derive(Clone, Default)]
struct IngestScratch {
    flats: Vec<usize>,
    ws: Vec<f64>,
    idxs: Vec<usize>,
    eps: Vec<f64>,
}

impl IncrementalSki {
    /// Empty statistics over an initial grid. `n_probes` fixes the
    /// number of variance-probe accumulators (the paper's `n_s`, 20 by
    /// default); `margin_cells` is the safety margin kept around points
    /// when the grid auto-expands.
    pub fn new(grid: Grid, n_probes: usize, margin_cells: usize, seed: u64) -> Self {
        let m = grid.m();
        let d = grid.dim();
        let nbands = BAND_W.pow(d as u32);
        IncrementalSki {
            grid,
            wty: vec![0.0; m],
            bands: (0..nbands).map(|_| vec![0.0; m]).collect(),
            counts: vec![0.0; m],
            probes: (0..n_probes).map(|_| vec![0.0; m]).collect(),
            margin_cells: margin_cells.max(1),
            n: 0,
            weight: 0.0,
            sum_y: 0.0,
            sum_y2: 0.0,
            rng: Rng::new(seed ^ 0x57ea3_u64),
            scratch: IngestScratch::default(),
        }
    }

    /// Reconstruct an accumulator from checkpointed parts (the inverse
    /// of the [`crate::fault::codec`] encoding). Every length invariant
    /// is validated so a corrupted checkpoint surfaces as a clean error,
    /// never as a silently inconsistent accumulator. The `rng` must be
    /// the captured ingest generator ([`Self::rng_state`]) for restored
    /// probe draws to replay the uninterrupted sequence exactly.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        grid: Grid,
        wty: Vec<f64>,
        bands: Vec<Vec<f64>>,
        counts: Vec<f64>,
        probes: Vec<Vec<f64>>,
        margin_cells: usize,
        n: usize,
        weight: f64,
        sum_y: f64,
        sum_y2: f64,
        rng: Rng,
    ) -> Result<Self, String> {
        let m = grid.m();
        let d = grid.dim();
        let nbands = BAND_W.pow(d as u32);
        if wty.len() != m {
            return Err(format!("wty length {} != m {}", wty.len(), m));
        }
        if counts.len() != m {
            return Err(format!("counts length {} != m {}", counts.len(), m));
        }
        if bands.len() != nbands {
            return Err(format!("band count {} != 7^{} = {}", bands.len(), d, nbands));
        }
        if let Some(b) = bands.iter().find(|b| b.len() != m) {
            return Err(format!("band length {} != m {}", b.len(), m));
        }
        if let Some(q) = probes.iter().find(|q| q.len() != m) {
            return Err(format!("probe length {} != m {}", q.len(), m));
        }
        if margin_cells == 0 {
            return Err("margin_cells must be >= 1".to_string());
        }
        if !(weight.is_finite() && sum_y.is_finite() && sum_y2.is_finite()) {
            return Err("non-finite scalar statistics".to_string());
        }
        Ok(IncrementalSki {
            grid,
            wty,
            bands,
            counts,
            probes,
            margin_cells,
            n,
            weight,
            sum_y,
            sum_y2,
            rng,
            scratch: IngestScratch::default(),
        })
    }

    /// Current grid.
    pub fn grid(&self) -> &Grid {
        &self.grid
    }

    /// Observations absorbed so far.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Grid size.
    pub fn m(&self) -> usize {
        self.grid.m()
    }

    /// `W^T y` accumulator.
    pub fn wty(&self) -> &[f64] {
        &self.wty
    }

    /// Per-cell point mass (whole counts until [`Self::decay`]).
    pub fn counts(&self) -> &[f64] {
        &self.counts
    }

    /// The banded Gram accumulator (`7^D` bands of length `m`; see the
    /// field docs for the delta encoding). Read access for the shard
    /// merge path and diagnostics.
    pub fn bands(&self) -> &[Vec<f64>] {
        &self.bands
    }

    /// `diag(G)`: the zero-offset band (all per-dimension deltas zero),
    /// used by the Jacobi refresh preconditioner. O(1) — the diagonal is
    /// already tracked by the banded storage.
    pub fn g_diag(&self) -> &[f64] {
        // Base-7 digits all equal to 3 (delta 0 per dimension):
        // o = 3 * (7^D - 1) / 6 = (7^D - 1) / 2.
        &self.bands[(self.bands.len() - 1) / 2]
    }

    /// Probe accumulators (`n_probes` vectors of length `m`).
    pub fn probes(&self) -> &[Vec<f64>] {
        &self.probes
    }

    /// Effective (decay-weighted) sample mass.
    pub fn weight(&self) -> f64 {
        self.weight
    }

    /// Decay-weighted running sum of the targets (checkpointed raw; use
    /// [`Self::y_mean`] for the mass-guarded ratio).
    pub fn sum_y(&self) -> f64 {
        self.sum_y
    }

    /// Decay-weighted running sum of squared targets (checkpointed raw;
    /// use [`Self::y_var`] for the mass-guarded ratio).
    pub fn sum_y2(&self) -> f64 {
        self.sum_y2
    }

    /// Expansion margin (cells) enforced around ingested points.
    pub fn margin_cells(&self) -> usize {
        self.margin_cells
    }

    /// The ingest RNG's full state (probe-noise generator). Checkpointed
    /// so a restored accumulator draws the identical `eps` sequence the
    /// uninterrupted run would have — the crash-recovery parity tests
    /// depend on this.
    pub fn rng_state(&self) -> ([u64; 4], Option<f64>) {
        self.rng.state()
    }

    /// Running (decay-weighted) mean of the targets. Returns `0.0` once
    /// decay has driven the effective mass below [`MIN_EFFECTIVE_MASS`]
    /// (the numerator decays in lockstep, so the true limit is the
    /// prior mean anyway) — the guard is what keeps the ratio from
    /// round-tripping through subnormals into `inf`/`NaN`; above it the
    /// plain division is well conditioned.
    pub fn y_mean(&self) -> f64 {
        if self.weight < MIN_EFFECTIVE_MASS {
            0.0
        } else {
            self.sum_y / self.weight
        }
    }

    /// Running (decay-weighted) second central moment of the targets
    /// (same mass guard as [`Self::y_mean`]).
    pub fn y_var(&self) -> f64 {
        if self.weight < MIN_EFFECTIVE_MASS {
            0.0
        } else {
            (self.sum_y2 / self.weight - self.y_mean().powi(2)).max(0.0)
        }
    }

    /// Exponential forgetting for non-stationary streams: scale every
    /// linear accumulator — `b = W^T y`, the banded Gram `G`, per-cell
    /// mass, and the target sums — by `gamma in (0, 1]`. Called once per
    /// epoch, this gives observation `i` an effective weight
    /// `gamma^(age_i in epochs)`. The probe accumulators scale by
    /// `sqrt(gamma)`: `q_k ~ N(0, G)` maps to a valid sample of
    /// `N(0, gamma G)` under `sqrt(gamma)`, keeping the stochastic
    /// variance estimator exact against the decayed Gram. `n` keeps
    /// counting raw ingests; `weight()` carries the decayed mass.
    pub fn decay(&mut self, gamma: f64) {
        assert!(gamma > 0.0 && gamma <= 1.0, "decay factor must be in (0, 1], got {gamma}");
        if gamma == 1.0 {
            return;
        }
        let root = gamma.sqrt();
        for v in self.wty.iter_mut() {
            *v *= gamma;
        }
        for band in self.bands.iter_mut() {
            for v in band.iter_mut() {
                *v *= gamma;
            }
        }
        for q in self.probes.iter_mut() {
            for v in q.iter_mut() {
                *v *= root;
            }
        }
        for c in self.counts.iter_mut() {
            *c *= gamma;
        }
        self.weight *= gamma;
        self.sum_y *= gamma;
        self.sum_y2 *= gamma;
    }

    /// Absorb one observation in O(4^D) (plus a remap when the grid must
    /// grow). Returns the expansion applied, if any.
    pub fn ingest(&mut self, x: &[f64], y: f64) -> Option<GridExpansion> {
        assert_eq!(x.len(), self.grid.dim());
        let expansion = self.grid.expansion_to_cover(x, self.margin_cells);
        if let Some(exp) = &expansion {
            self.apply_expansion(exp);
        }
        let d = self.grid.dim();
        let nnz = 4usize.pow(d as u32);
        // Gather the point's taps once (reused scratch: the hot path is
        // allocation-free in steady state); the pairwise Gram update
        // needs random access to them.
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.flats.clear();
        scratch.ws.clear();
        scratch.idxs.clear();
        for_each_tap(x, &self.grid, |flat, w, idx| {
            scratch.flats.push(flat);
            scratch.ws.push(w);
            scratch.idxs.extend_from_slice(idx);
        });
        debug_assert_eq!(scratch.flats.len(), nnz);
        let (flats, ws, idxs) = (&scratch.flats, &scratch.ws, &scratch.idxs);
        // b += w^T y and the probe accumulators.
        scratch.eps.clear();
        for _ in 0..self.probes.len() {
            scratch.eps.push(self.rng.normal());
        }
        for t1 in 0..nnz {
            self.wty[flats[t1]] += ws[t1] * y;
            for (q, &e) in self.probes.iter_mut().zip(&scratch.eps) {
                q[flats[t1]] += e * ws[t1];
            }
        }
        // G += w w^T (banded storage, both triangles).
        for t1 in 0..nnz {
            for t2 in 0..nnz {
                let mut o = 0usize;
                for a in 0..d {
                    let delta = idxs[t2 * d + a] as i64 - idxs[t1 * d + a] as i64;
                    debug_assert!(delta.abs() <= BAND_HALF);
                    o = o * BAND_W + (delta + BAND_HALF) as usize;
                }
                self.bands[o][flats[t1]] += ws[t1] * ws[t2];
            }
        }
        self.scratch = scratch;
        // Nearest-cell occupancy count.
        let mut cell = 0usize;
        for a in 0..d {
            let u = self.grid.axes[a].to_units(x[a]).round();
            let i = (u.max(0.0) as usize).min(self.grid.axes[a].n - 1);
            cell = cell * self.grid.axes[a].n + i;
        }
        self.counts[cell] += 1.0;
        self.n += 1;
        self.weight += 1.0;
        self.sum_y += y;
        self.sum_y2 += y * y;
        expansion
    }

    /// Absorb a batch (row-major `k x D` inputs). Returns the number of
    /// grid expansions applied.
    pub fn ingest_batch(&mut self, xs: &[f64], ys: &[f64]) -> usize {
        let d = self.grid.dim();
        assert_eq!(xs.len(), ys.len() * d, "xs is k x D row-major, ys length k");
        let mut expansions = 0;
        for (i, &y) in ys.iter().enumerate() {
            if self.ingest(&xs[i * d..(i + 1) * d], y).is_some() {
                expansions += 1;
            }
        }
        expansions
    }

    /// Banded Gram MVM `out = G v` in O(m 7^D).
    pub fn g_matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; v.len()];
        self.g_matvec_into(v, &mut out);
        out
    }

    /// Allocation-free banded Gram MVM.
    pub fn g_matvec_into(&self, v: &[f64], out: &mut [f64]) {
        let m = self.grid.m();
        assert_eq!(v.len(), m);
        assert_eq!(out.len(), m);
        let shape = self.grid.shape();
        let d = shape.len();
        let mut strides = vec![1i64; d];
        for a in (0..d.saturating_sub(1)).rev() {
            strides[a] = strides[a + 1] * shape[a + 1] as i64;
        }
        // Precompute each band's per-dim deltas and flat offset.
        let nbands = self.bands.len();
        let mut deltas = vec![0i64; nbands * d];
        let mut flat_off = vec![0i64; nbands];
        for o in 0..nbands {
            let mut rem = o;
            for a in (0..d).rev() {
                let delta = (rem % BAND_W) as i64 - BAND_HALF;
                rem /= BAND_W;
                deltas[o * d + a] = delta;
                flat_off[o] += delta * strides[a];
            }
        }
        let mut idx = vec![0i64; d];
        for (i, oi) in out.iter_mut().enumerate() {
            let mut acc = 0.0;
            for (o, band) in self.bands.iter().enumerate() {
                let bv = band[i];
                if bv == 0.0 {
                    continue;
                }
                let mut ok = true;
                for a in 0..d {
                    let ni = idx[a] + deltas[o * d + a];
                    if ni < 0 || ni >= shape[a] as i64 {
                        ok = false;
                        break;
                    }
                }
                if ok {
                    acc += bv * v[(i as i64 + flat_off[o]) as usize];
                }
            }
            *oi = acc;
            for a in (0..d).rev() {
                idx[a] += 1;
                if idx[a] < shape[a] as i64 {
                    break;
                }
                idx[a] = 0;
            }
        }
    }

    /// Dense `G` materialization (tests / small grids only).
    pub fn g_dense(&self) -> crate::linalg::Mat {
        let m = self.m();
        let mut g = crate::linalg::Mat::zeros(m, m);
        for j in 0..m {
            let mut e = vec![0.0; m];
            e[j] = 1.0;
            let col = self.g_matvec(&e);
            for i in 0..m {
                g[(i, j)] = col[i];
            }
        }
        g
    }

    fn apply_expansion(&mut self, exp: &GridExpansion) {
        let new_grid = self.grid.expanded(exp);
        let remap = |v: &[f64]| remap_grid_vec(&self.grid, &new_grid, v);
        self.wty = remap(&self.wty);
        self.bands = self.bands.iter().map(|b| remap(b)).collect();
        self.probes = self.probes.iter().map(|q| remap(q)).collect();
        self.counts = remap(&self.counts);
        self.grid = new_grid;
    }

    /// Fold another accumulator's statistics into this one. `other`'s
    /// grid must be a sub-grid of `self`'s (same steps, axes contained —
    /// exactly what a shard's local grid is relative to the global grid);
    /// every statistic is lifted by the whole-cell index shift and added.
    /// This is the shard merge primitive: sufficient statistics are
    /// additive, so S owned-shard accumulators folded into an empty
    /// global accumulator equal a single-trainer build over the union of
    /// the shards' streams.
    pub fn accumulate_shifted(&mut self, other: &IncrementalSki) {
        assert_eq!(self.grid.dim(), other.grid.dim(), "dimension mismatch");
        assert_eq!(self.bands.len(), other.bands.len());
        assert_eq!(
            self.probes.len(),
            other.probes.len(),
            "probe counts must match to merge accumulators"
        );
        let lift = |v: &[f64]| remap_grid_vec(&other.grid, &self.grid, v);
        let add = |dst: &mut [f64], src: Vec<f64>| {
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        };
        add(&mut self.wty, lift(&other.wty));
        for (band, ob) in self.bands.iter_mut().zip(&other.bands) {
            add(band, lift(ob));
        }
        for (q, oq) in self.probes.iter_mut().zip(&other.probes) {
            add(q, lift(oq));
        }
        add(&mut self.counts, lift(&other.counts));
        self.n += other.n;
        self.weight += other.weight;
        self.sum_y += other.sum_y;
        self.sum_y2 += other.sum_y2;
    }
}
