//! A small, fast, seedable RNG (xoshiro256**) with uniform and Gaussian
//! sampling. Deterministic across platforms — experiment seeds reproduce
//! exactly.

/// xoshiro256** pseudo-random generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller draw.
    spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (any seed, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()], spare: None }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0, 1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.uniform() * n as f64) as usize % n.max(1)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Full generator state: the xoshiro256** words plus the cached
    /// Box–Muller spare. Captured by the checkpoint codec so a restored
    /// stream draws the *identical* sequence the uninterrupted run would
    /// have drawn — the exact-replay property the crash-recovery parity
    /// tests pin to 1e-10 rests on this.
    pub fn state(&self) -> ([u64; 4], Option<f64>) {
        (self.s, self.spare)
    }

    /// Rebuild a generator from a captured [`Self::state`].
    pub fn from_state(s: [u64; 4], spare: Option<f64>) -> Self {
        Rng { s, spare }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(99);
        let n = 50_000;
        let xs = r.normal_vec(n);
        let mean: f64 = xs.iter().sum::<f64>() / n as f64;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
