//! Dependency-free utilities: a seedable RNG, a tiny JSON reader/writer
//! for the artifact manifest, and timing helpers.
//!
//! The build environment is offline (only the `xla` crate's closure is
//! vendored), so the usual `rand` / `serde_json` / `criterion` crates are
//! replaced by these minimal in-tree equivalents.

pub mod rng;
pub mod json;
pub mod timing;

pub use rng::Rng;
