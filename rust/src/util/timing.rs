//! Timing helpers and a tiny benchmark harness (criterion is not available
//! offline; `cargo bench` targets use [`bench_fn`] and print comparable
//! median/mean statistics).

use std::time::{Duration, Instant};

/// Time a single invocation.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Summary statistics for a benchmark run.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Label.
    pub name: String,
    /// Number of timed iterations.
    pub iters: usize,
    /// Median duration.
    pub median: Duration,
    /// Mean duration.
    pub mean: Duration,
    /// Minimum duration.
    pub min: Duration,
    /// Maximum duration.
    pub max: Duration,
}

impl BenchStats {
    /// One-line human-readable summary.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>10} {:>10} {:>10}   x{}",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.min),
            self.iters
        )
    }
}

/// Format a duration compactly.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

/// Benchmark a closure: warm up, then run timed iterations until
/// `min_time` has elapsed (at least 3, at most `max_iters`).
pub fn bench_fn(name: &str, min_time: Duration, max_iters: usize, mut f: impl FnMut()) -> BenchStats {
    // Warmup.
    f();
    let mut samples: Vec<Duration> = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < min_time || samples.len() < 3) && samples.len() < max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let iters = samples.len();
    let median = samples[iters / 2];
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchStats {
        name: name.to_string(),
        iters,
        median,
        mean,
        min: samples[0],
        max: samples[iters - 1],
    }
}

/// Print the table header matching [`BenchStats::line`].
pub fn bench_header() {
    println!(
        "{:<44} {:>10} {:>10} {:>10}   iters",
        "benchmark", "median", "mean", "min"
    );
    println!("{}", "-".repeat(84));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_fn_runs_and_reports() {
        let mut count = 0usize;
        let stats = bench_fn("noop", Duration::from_millis(5), 10_000, || {
            count += 1;
        });
        assert!(stats.iters >= 3);
        assert!(count >= stats.iters); // warmup adds one
        assert!(stats.min <= stats.median && stats.median <= stats.max);
    }

    #[test]
    fn fmt_dur_units() {
        assert!(fmt_dur(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_dur(Duration::from_micros(50)).ends_with("us"));
        assert!(fmt_dur(Duration::from_millis(50)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }
}
