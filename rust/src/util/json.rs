//! A deliberately tiny JSON value type with a recursive-descent parser and
//! writer — enough for the artifact manifest (`artifacts/manifest.json`)
//! and metrics dumps. Not a general-purpose JSON library.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// Boolean.
    Bool(bool),
    /// All JSON numbers are kept as f64.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for stable output).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// As &str.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        if self.i >= self.b.len() {
            return Err("unexpected end".into());
        }
        match self.b[self.i] {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => {
                self.i += 1;
                let mut v = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.ws();
                    v.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => return Err(format!("bad array at {}", self.i)),
                    }
                }
            }
            b'{' => {
                self.i += 1;
                let mut m = BTreeMap::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    if self.peek() != Some(b':') {
                        return Err(format!("expected ':' at {}", self.i));
                    }
                    self.i += 1;
                    self.ws();
                    let v = self.value()?;
                    m.insert(k, v);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(m));
                        }
                        _ => return Err(format!("bad object at {}", self.i)),
                    }
                }
            }
            _ => self.number(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        if self.peek() != Some(b'"') {
            return Err(format!("expected string at {}", self.i));
        }
        self.i += 1;
        let mut s = String::new();
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err("bad utf8".into());
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..end]).map_err(|_| "bad utf8")?);
                    self.i = end;
                }
            }
        }
        Err("unterminated string".into())
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad number")?;
        s.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number '{s}'"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let s = v.to_string();
        let v2 = Json::parse(&s).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("hi\nthere"));
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse(r#"{"a":}"#).is_err());
    }

    #[test]
    fn integers_written_without_fraction() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string(), "42");
    }
}
