//! Spatial shard planning: partition the inducing grid's covered box
//! into S contiguous slabs along its longest axis.
//!
//! Each shard *owns* a half-open interval of grid cells on the split
//! axis and *covers* that interval plus `halo` extra cells on each side
//! (clamped to the global box). The halo serves two purposes:
//!
//! 1. **Ingest exactness** — a point near an ownership boundary has a
//!    cubic stencil reaching up to 2 cells past the boundary; with
//!    `halo >= 2` every owned point's taps land inside the local grid
//!    unshifted, so per-shard sufficient statistics scatter-add into the
//!    global accumulator *exactly* (see [`crate::shard::merge`]).
//! 2. **Seam continuity** — shards also absorb *halo copies* of
//!    neighbor-owned points inside their coverage, so each local model
//!    is informed by all data near the seam, and serving blends the two
//!    local predictions with a partition-of-unity ramp over
//!    `[cut - blend, cut + blend]` (see
//!    [`crate::shard::serving::ShardedServing`]).

use crate::grid::{Grid, GridAxis};

/// A spatial partition of a [`Grid`] into `S` slabs along one axis.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    global: Grid,
    /// Split axis (the axis with the most grid points).
    axis: usize,
    /// Halo width, in grid cells (`>= 2`).
    halo: usize,
    /// Blend half-width, in grid cells (`0` disables blending;
    /// otherwise `<= halo - 2` so blended neighbor predictions never
    /// tap a shifted stencil).
    blend: usize,
    /// Ownership boundaries on the split axis, in grid units:
    /// shard `s` owns `[cuts[s], cuts[s+1])` (`cuts.len() == S + 1`,
    /// `cuts[0] == 0`, `cuts[S] == n - 1`; the last shard's interval is
    /// closed at the top).
    cuts: Vec<usize>,
    /// Cells owned by the first `rem` shards (`base + 1`) vs the rest
    /// (`base`) — kept for the O(1) owner lookup.
    base: usize,
    rem: usize,
}

/// C1 partition-of-unity ramp (`smoothstep`): `0 -> 0`, `1 -> 1`,
/// `sigma(t) + sigma(1 - t) = 1`.
#[inline]
fn smoothstep(t: f64) -> f64 {
    let t = t.clamp(0.0, 1.0);
    t * t * (3.0 - 2.0 * t)
}

impl ShardPlan {
    /// Plan `shards` slabs over `global`, split along its longest axis.
    ///
    /// Panics when the geometry cannot support the requested layout:
    /// every shard must own at least `halo` cells (so halo copies only
    /// ever go to the immediate neighbors), more than `2 * blend` cells
    /// (so blend zones never overlap), and every local grid must keep
    /// `>= 4` points for the cubic stencil.
    pub fn new(global: Grid, shards: usize, halo: usize, blend: usize) -> Self {
        assert!(shards >= 1, "need at least one shard");
        assert!(halo >= 2, "halo must be >= 2 cells for stencil exactness");
        assert!(
            blend == 0 || blend + 2 <= halo,
            "blend half-width ({blend}) must be <= halo - 2 ({})",
            halo.saturating_sub(2)
        );
        let axis = global
            .shape()
            .iter()
            .enumerate()
            .max_by_key(|(_, &n)| n)
            .map(|(a, _)| a)
            // PANIC-OK: a Grid always has at least one axis.
            .unwrap();
        let cells = global.axes[axis].n - 1;
        assert!(
            shards == 1 || cells / shards >= halo.max(2 * blend + 1),
            "split axis has {cells} cells; {shards} shards of >= {} cells each don't fit",
            halo.max(2 * blend + 1)
        );
        let base = cells / shards;
        let rem = cells % shards;
        let mut cuts = Vec::with_capacity(shards + 1);
        let mut acc = 0usize;
        cuts.push(0);
        for s in 0..shards {
            acc += base + usize::from(s < rem);
            cuts.push(acc);
        }
        // PANIC-OK: `cuts` was just pushed to (debug-only check).
        debug_assert_eq!(*cuts.last().unwrap(), cells);
        ShardPlan { global, axis, halo, blend, cuts, base, rem }
    }

    /// The global grid being partitioned.
    pub fn global(&self) -> &Grid {
        &self.global
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.cuts.len() - 1
    }

    /// Split axis.
    pub fn axis(&self) -> usize {
        self.axis
    }

    /// Halo width in cells.
    pub fn halo(&self) -> usize {
        self.halo
    }

    /// Blend half-width in cells.
    pub fn blend(&self) -> usize {
        self.blend
    }

    /// Ownership boundaries in grid units (length `S + 1`).
    pub fn cuts(&self) -> &[usize] {
        &self.cuts
    }

    /// Split-axis coordinate of `x` in grid units, clamped to the box.
    #[inline]
    pub fn unit(&self, x: &[f64]) -> f64 {
        let ax = &self.global.axes[self.axis];
        ax.to_units(x[self.axis]).clamp(0.0, (ax.n - 1) as f64)
    }

    /// Owning shard of `x` in O(1): invert the near-even cut layout
    /// (first `rem` shards own `base + 1` cells) by direct division.
    #[inline]
    pub fn owner_of(&self, x: &[f64]) -> usize {
        let u = self.unit(x);
        let cell = (u as usize).min(self.global.axes[self.axis].n.saturating_sub(2));
        let wide = self.rem * (self.base + 1);
        let s = if cell < wide {
            cell / (self.base + 1)
        } else if self.base > 0 {
            self.rem + (cell - wide) / self.base
        } else {
            self.rem
        };
        s.min(self.shards() - 1)
    }

    /// Owning cluster node of shard `s` in an `nodes`-node deployment:
    /// shards are striped round-robin (`s % nodes`) so every node owns
    /// an interleaved set of slabs and losing one node degrades
    /// coverage evenly instead of blacking out a contiguous region.
    /// See [`crate::cluster`].
    #[inline]
    pub fn node_of(&self, shard: usize, nodes: usize) -> usize {
        assert!(nodes >= 1, "need at least one node");
        shard % nodes
    }

    /// Owning cluster node of point `x`: [`Self::owner_of`] composed
    /// with [`Self::node_of`].
    #[inline]
    pub fn owner_node(&self, x: &[f64], nodes: usize) -> usize {
        self.node_of(self.owner_of(x), nodes)
    }

    /// Inclusive grid-point index range `[start, end]` of shard `s`'s
    /// local grid (owned slab + halo, clamped to the box).
    pub fn local_range(&self, s: usize) -> (usize, usize) {
        let n = self.global.axes[self.axis].n;
        let start = self.cuts[s].saturating_sub(self.halo);
        let end = (self.cuts[s + 1] + self.halo).min(n - 1);
        (start, end)
    }

    /// Shard `s`'s local grid: the split axis restricted to
    /// [`Self::local_range`] (identical step and point coordinates —
    /// the local grid is an exact sub-grid of the global one), all
    /// other axes in full.
    pub fn local_grid(&self, s: usize) -> Grid {
        let (start, end) = self.local_range(s);
        let axes = self
            .global
            .axes
            .iter()
            .enumerate()
            .map(|(a, ax)| {
                if a == self.axis {
                    GridAxis { lo: ax.coord(start), step: ax.step, n: end - start + 1 }
                } else {
                    ax.clone()
                }
            })
            .collect();
        let g = Grid::new(axes);
        debug_assert!(g.axes[self.axis].n >= 4, "local grid too small for cubic stencils");
        g
    }

    /// Neighbors that should absorb a *halo copy* of a point owned by
    /// `owner`: a neighbor receives the copy when the point sits at
    /// least one cell inside the neighbor's local grid on both sides
    /// (so the copy ingests without triggering grid expansion).
    pub fn halo_recipients(&self, x: &[f64], owner: usize) -> [Option<usize>; 2] {
        let u = self.unit(x);
        let mut out = [None, None];
        if owner > 0 {
            let (_, end) = self.local_range(owner - 1);
            if u <= (end - 2) as f64 {
                out[0] = Some(owner - 1);
            }
        }
        if owner + 1 < self.shards() {
            let (start, _) = self.local_range(owner + 1);
            if u >= (start + 1) as f64 {
                out[1] = Some(owner + 1);
            }
        }
        out
    }

    /// Partition-of-unity blend at `x` for its `owner`'s prediction:
    /// `Some((neighbor, owner_weight))` when `x` falls strictly inside a
    /// blend zone (`owner_weight` in `(0, 1)`, the neighbor carries
    /// `1 - owner_weight`), `None` when the owner serves it alone. The
    /// weights are C1-continuous across the seam and reach exactly
    /// `1 / 0` at the zone edges, so blended and pure-routed predictions
    /// agree there.
    pub fn blend_neighbor(&self, x: &[f64], owner: usize) -> Option<(usize, f64)> {
        if self.blend == 0 {
            return None;
        }
        let u = self.unit(x);
        let b = self.blend as f64;
        // Lower seam: boundary between owner-1 (left) and owner (right).
        if owner > 0 {
            let c = self.cuts[owner] as f64;
            if u < c + b {
                let w_left = smoothstep((c + b - u) / (2.0 * b));
                if w_left > 0.0 {
                    return Some((owner - 1, 1.0 - w_left));
                }
            }
        }
        // Upper seam: boundary between owner (left) and owner+1 (right).
        if owner + 1 < self.shards() {
            let c = self.cuts[owner + 1] as f64;
            if u > c - b {
                let w_left = smoothstep((c + b - u) / (2.0 * b));
                if w_left < 1.0 {
                    return Some((owner + 1, w_left));
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_1d(n: usize) -> Grid {
        Grid::new(vec![GridAxis::span(0.0, (n - 1) as f64, n)])
    }

    #[test]
    fn cuts_partition_the_axis() {
        let p = ShardPlan::new(grid_1d(101), 4, 4, 2);
        assert_eq!(p.cuts().first(), Some(&0));
        assert_eq!(p.cuts().last(), Some(&100));
        assert_eq!(p.shards(), 4);
        // Near-even: widths differ by at most one cell.
        let widths: Vec<usize> = p.cuts().windows(2).map(|w| w[1] - w[0]).collect();
        let (lo, hi) = (widths.iter().min().unwrap(), widths.iter().max().unwrap());
        assert!(hi - lo <= 1, "{widths:?}");
    }

    #[test]
    fn owner_lookup_matches_cut_scan() {
        for (n, s) in [(97usize, 3usize), (128, 4), (61, 5)] {
            let p = ShardPlan::new(grid_1d(n), s, 3, 0);
            for i in 0..10 * (n - 1) {
                let u = i as f64 / 10.0;
                let x = [u]; // unit-spaced grid: coordinate == unit
                let got = p.owner_of(&x);
                let want = p
                    .cuts()
                    .windows(2)
                    .position(|w| u >= w[0] as f64 && (u as usize) < w[1])
                    .unwrap_or(s - 1);
                assert_eq!(got, want, "n={n} s={s} u={u}");
            }
        }
    }

    #[test]
    fn local_grids_are_exact_subgrids() {
        let g = Grid::new(vec![
            GridAxis::span(-3.0, 7.0, 41),
            GridAxis::span(0.0, 1.0, 6),
        ]);
        let p = ShardPlan::new(g.clone(), 3, 4, 2);
        assert_eq!(p.axis(), 0, "longest axis wins");
        for s in 0..3 {
            let lg = p.local_grid(s);
            let (start, end) = p.local_range(s);
            assert_eq!(lg.axes[0].n, end - start + 1);
            assert!((lg.axes[0].step - g.axes[0].step).abs() < 1e-15);
            for i in 0..lg.axes[0].n {
                let want = g.axes[0].coord(start + i);
                assert!((lg.axes[0].coord(i) - want).abs() < 1e-12);
            }
            assert_eq!(lg.axes[1], g.axes[1]);
        }
        // Boundary shards stop at the box; interior shards have full halos.
        assert_eq!(p.local_range(0).0, 0);
        assert_eq!(p.local_range(2).1, 40);
    }

    #[test]
    fn blend_weights_are_a_partition_of_unity_and_continuous() {
        let p = ShardPlan::new(grid_1d(65), 2, 5, 3);
        let cut = p.cuts()[1] as f64;
        let mut prev: Option<f64> = None;
        let mut du = -4.0;
        while du <= 4.0 {
            let x = [cut + du];
            let owner = p.owner_of(&x);
            let w_owner = match p.blend_neighbor(&x, owner) {
                Some((nb, w)) => {
                    assert!(nb == owner + 1 || nb + 1 == owner);
                    assert!(w > 0.0 && w < 1.0, "w={w}");
                    w
                }
                None => 1.0,
            };
            // Express as "weight of the left shard" for continuity.
            let w_left = if owner == 0 { w_owner } else { 1.0 - w_owner };
            if let Some(pl) = prev {
                assert!((w_left - pl).abs() < 0.02, "jump at du={du}");
            }
            prev = Some(w_left);
            du += 0.01;
        }
        // Outside the zone: pure routing.
        assert!(p.blend_neighbor(&[cut - 3.5], 0).is_none());
        assert!(p.blend_neighbor(&[cut + 3.5], 1).is_none());
        // At the seam: a 50/50 split.
        let (nb, w) = p.blend_neighbor(&[cut], 1).unwrap();
        assert_eq!(nb, 0);
        assert!((w - 0.5).abs() < 1e-12);
    }

    #[test]
    fn halo_recipients_cover_the_overlap_only() {
        let p = ShardPlan::new(grid_1d(65), 2, 4, 2);
        let cut = p.cuts()[1]; // 32
        // Deep interior of shard 0: no copies.
        assert_eq!(p.halo_recipients(&[2.0], 0), [None, None]);
        // Just left of the cut: shard 1's local grid starts at cut-4, so
        // the copy lands safely inside it.
        assert_eq!(p.halo_recipients(&[(cut - 1) as f64], 0), [None, Some(1)]);
        // Just right of the cut: shard 0 receives the mirror copy.
        assert_eq!(p.halo_recipients(&[(cut + 1) as f64], 1), [Some(0), None]);
        // Past the halo: no copies again.
        assert_eq!(p.halo_recipients(&[(cut + 6) as f64], 1), [None, None]);
    }

    #[test]
    #[should_panic(expected = "don't fit")]
    fn too_many_shards_panic() {
        ShardPlan::new(grid_1d(17), 8, 4, 0);
    }

    #[test]
    fn node_striping_is_round_robin_and_total() {
        let p = ShardPlan::new(grid_1d(101), 6, 4, 2);
        for nodes in 1..=4usize {
            let mut owned = vec![0usize; nodes];
            for s in 0..p.shards() {
                let n = p.node_of(s, nodes);
                assert!(n < nodes);
                assert_eq!(n, s % nodes);
                owned[n] += 1;
            }
            // Striping is near-even: ownership counts differ by <= 1.
            let (lo, hi) = (owned.iter().min().unwrap(), owned.iter().max().unwrap());
            assert!(hi - lo <= 1, "{owned:?}");
        }
        // Point routing composes owner_of with the stripe.
        let x = [50.0];
        assert_eq!(p.owner_node(&x, 3), p.node_of(p.owner_of(&x), 3));
    }
}
