//! Sharded data-parallel MSGP: spatial partitioning of the streaming
//! subsystem across worker threads.
//!
//! The SKI sufficient statistics are *additive* — `W^T y`, the banded
//! Gram `W^T W`, per-cell mass, and the variance-probe accumulators are
//! all sums over observations. That is exactly the property that lets
//! inference scale past one trainer thread: partition the inducing
//! grid's covered box into S spatial slabs, run one incremental trainer
//! per slab, and fold the per-shard statistics back together whenever a
//! whole-domain view is needed. KISS-GP's local cubic interpolation
//! keeps each shard's statistics exact on its sub-grid: a point's
//! stencil touches at most 2 cells past its ownership boundary, so a
//! `halo >= 2` of overlap cells makes every owned tap land inside the
//! local grid unshifted.
//!
//! Layers:
//!
//! * [`plan::ShardPlan`] — splits the grid along its longest axis into
//!   near-even slabs with a configurable halo; O(1) owner lookup;
//!   partition-of-unity blend weights across each seam.
//! * [`trainer::ShardedTrainer`] — one worker thread per shard, each
//!   running an owned + halo [`crate::stream::IncrementalSki`] pair and
//!   refreshing independently (O(m/S) per refresh per core instead of
//!   O(m) on one). Halo copies of seam-adjacent points keep every local
//!   model accurate through its blend zone without ever double counting
//!   in the merge.
//! * [`merge`] — folds per-shard *owned* accumulators into one global
//!   accumulator (equal to a single-trainer build to ~1e-13) and wraps
//!   it in a [`crate::stream::StreamTrainer`] for whole-domain hyper
//!   re-optimization.
//! * [`serving::ShardedServing`] — a shard-indexed
//!   [`crate::coordinator::state::ModelSlot`] table; predictions route
//!   to their owning shard in O(1) and blend mean/variance across the
//!   halo with C1 partition-of-unity weights, so the served surface is
//!   continuous at seams.
//!
//! Coordinator integration ([`crate::coordinator`]): `Server::
//! start_sharded` runs the batcher against the slot table (grouping
//! each flush by owning shard), `/ingest` routes straight to the
//! facade, `/shards` exposes per-shard introspection, and
//! [`crate::coordinator::metrics::Metrics`] carries per-shard
//! ingest/refresh/queue-depth counters.

pub mod merge;
pub mod plan;
pub mod serving;
pub mod trainer;

pub use merge::{merge_owned, merged_trainer};
pub use plan::ShardPlan;
pub use serving::ShardedServing;
pub use trainer::{ShardConfig, ShardedTrainer};
