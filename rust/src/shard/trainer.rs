//! Data-parallel sharded training: one incremental-SKI trainer per
//! spatial shard, each on its own worker thread.
//!
//! Every shard worker owns **two** accumulators over its local grid:
//!
//! * `own` — points the shard *owns* (routed by [`ShardPlan::owner_of`]).
//!   These are the statistics the additive merge folds into the global
//!   snapshot: each observation lives in exactly one `own` accumulator,
//!   so the merged sum equals a single-trainer build.
//! * `halo` — copies of neighbor-owned points that fall inside this
//!   shard's halo coverage. They never merge (that would double count);
//!   they only inform the *local* refresh, so the shard's model sees all
//!   data near its seams and blended serving stays accurate.
//!
//! Refreshes run per shard, in parallel and independently, on the
//! combined `own + halo` statistics — each solve is O(m/S) per core
//! instead of O(m) on one, which is where the 1/S refresh wall-clock
//! scaling comes from. Each worker publishes its refreshed
//! [`ServingModel`] into its slot of the shared [`ShardedServing`]
//! table; swaps are per-shard and atomic.
//!
//! **Intra-shard vs shard-level threading.** The shared
//! [`refresh_mdomain`] core additionally fans its batched FFT / CG
//! applies out over the in-tree thread pool ([`crate::parallel`]), so a
//! *single* shard refreshing on an otherwise idle machine uses all
//! cores. The pool serves one parallel region at a time and contended
//! or nested regions run serially, so when all S shard workers refresh
//! simultaneously the machine stays exactly subscribed: shard-level
//! parallelism dominates under load, intra-shard parallelism fills in
//! when shards refresh alone — the two compose without
//! oversubscription, and results are identical either way.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::metrics::{Metrics, WorkerKind};
use crate::coordinator::state::ServingModel;
use crate::data::Dataset;
use crate::fault::{
    self, Checkpoint, CkptConfig, CkptTrigger, Supervisor, SupervisorPolicy, Verdict,
};
use crate::gp::msgp::{GridKernel, KernelSpec, MsgpConfig, MsgpModel};
use crate::grid::Grid;
use crate::shard::merge;
use crate::shard::plan::ShardPlan;
use crate::shard::serving::ShardedServing;
use crate::stream::trainer::{refresh_mdomain, RefreshInputs, RefreshWorkspace, Reservoir};
use crate::stream::{IncrementalSki, StreamConfig, StreamTrainer};
use crate::util::Rng;

/// Sharded-trainer configuration.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Number of spatial shards (worker threads).
    pub shards: usize,
    /// Halo width in grid cells (`>= 2`; see [`ShardPlan`]).
    pub halo: usize,
    /// Blend half-width in cells (`0` disables seam blending).
    pub blend: usize,
    /// Owned points per shard between automatic refresh + publish
    /// cycles (halo copies count half toward the cadence).
    pub refresh_every: usize,
    /// Per-shard reservoir size for whole-domain re-optimization.
    pub reservoir: usize,
    /// Grid-operator / CG / probe configuration (shared by all shards).
    pub msgp: MsgpConfig,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: 2,
            halo: 6,
            blend: 3,
            refresh_every: 2048,
            reservoir: 1024,
            msgp: MsgpConfig::default(),
        }
    }
}

/// Control messages to a shard worker. All channels are FIFO, so a
/// `Flush` observes every ingest sent before it.
enum ShardMsg {
    Ingest {
        /// Row-major `k x D` inputs, all inside this shard's safe band.
        xs: Vec<f64>,
        /// Targets.
        ys: Vec<f64>,
        /// True for halo copies (absorbed into the `halo` accumulator,
        /// excluded from merge and the reservoir).
        halo: bool,
        /// Acked with the number of points absorbed.
        reply: Option<SyncSender<usize>>,
    },
    /// Force a refresh + publish (no-op refresh if already clean).
    Flush { reply: SyncSender<()> },
    /// Exponential forgetting on both accumulators, under the reservoir
    /// lock (so a concurrent whole-domain re-opt snapshot is ordered
    /// strictly before or after the decay).
    Decay { gamma: f64, reply: SyncSender<()> },
    /// Clone of the owned accumulator (the merge path's input).
    OwnedStats { reply: SyncSender<IncrementalSki> },
    /// Adopt re-optimized hyperparameters, rebuild the grid operator,
    /// refresh, publish.
    SetHypers { kernel: KernelSpec, sigma2: f64, reply: SyncSender<()> },
}

/// Per-shard worker state (lives entirely on the worker thread).
struct ShardWorker {
    id: usize,
    grid: Grid,
    kernel: KernelSpec,
    sigma2: f64,
    cfg: ShardConfig,
    own: IncrementalSki,
    halo: IncrementalSki,
    gk: GridKernel,
    t_mean: Vec<f64>,
    t_probes: Vec<Vec<f64>>,
    g_probes: Vec<Vec<f64>>,
    rws: RefreshWorkspace,
    reservoir: Arc<Mutex<Reservoir>>,
    res_rng: Rng,
    serving: Arc<ShardedServing>,
    metrics: Arc<Metrics>,
    /// Weighted ingests since the last refresh (owned 1.0, halo 0.5).
    dirty: f64,
    refresh_count: u64,
    /// Checkpoint policy (disabled unless `MSGP_CKPT_DIR` is set).
    ckpt: CkptConfig,
    trigger: CkptTrigger,
    /// Monotone checkpoint sequence for this shard's file.
    seq: u64,
}

impl ShardWorker {
    fn ingest(&mut self, xs: &[f64], ys: &[f64], is_halo: bool) -> usize {
        let _sp = crate::span!("shard.ingest");
        crate::failpoint!("shard.ingest");
        let d = self.grid.dim();
        let target = if is_halo { &mut self.halo } else { &mut self.own };
        for (i, &y) in ys.iter().enumerate() {
            let row = &xs[i * d..(i + 1) * d];
            let exp = target.ingest(row, y);
            debug_assert!(exp.is_none(), "routed point must not expand a shard grid");
        }
        if !is_halo && !ys.is_empty() {
            // Poison recovery: the reservoir is mutated one offer at a
            // time and stays well-formed if some holder panicked.
            let mut res = self.reservoir.lock().unwrap_or_else(|e| e.into_inner());
            for (i, &y) in ys.iter().enumerate() {
                res.offer(&xs[i * d..(i + 1) * d], y, self.cfg.reservoir, &mut self.res_rng);
            }
            self.metrics.shards[self.id]
                .reservoir_points
                .store(res.y.len() as u64, Ordering::Relaxed);
        }
        self.dirty += ys.len() as f64 * if is_halo { 0.5 } else { 1.0 };
        let counter = if is_halo {
            &self.metrics.shards[self.id].halo_ingested
        } else {
            &self.metrics.shards[self.id].ingested
        };
        counter.fetch_add(ys.len() as u64, Ordering::Relaxed);
        ys.len()
    }

    /// Refresh the fast-prediction caches from the combined
    /// `own + halo` statistics and publish the snapshot. Same math as
    /// [`StreamTrainer::refresh`] (shared [`refresh_mdomain`] core),
    /// with the Gram apply, `W^T y`, probe accumulators, and `diag(G)`
    /// each summed across the two accumulators.
    fn refresh_and_publish(&mut self) {
        let _sp = crate::span!("shard.refresh");
        crate::failpoint!("shard.refresh");
        let t0 = Instant::now();
        let m = self.grid.m();
        let has_halo = self.halo.n() > 0;
        // Combine the two accumulators only when there is halo data;
        // otherwise borrow `own`'s statistics directly and keep the
        // refresh allocation-light (matching StreamTrainer::refresh).
        let combined = if has_halo {
            let mut wty = self.own.wty().to_vec();
            let mut g_diag = self.own.g_diag().to_vec();
            let mut probes_q: Vec<Vec<f64>> = self.own.probes().to_vec();
            for (a, &b) in wty.iter_mut().zip(self.halo.wty()) {
                *a += b;
            }
            for (a, &b) in g_diag.iter_mut().zip(self.halo.g_diag()) {
                *a += b;
            }
            for (q, hq) in probes_q.iter_mut().zip(self.halo.probes()) {
                for (a, &b) in q.iter_mut().zip(hq) {
                    *a += b;
                }
            }
            Some((wty, g_diag, probes_q))
        } else {
            None
        };
        let (wty, g_diag, probes_q): (&[f64], &[f64], &[Vec<f64>]) = match &combined {
            Some((w, g, p)) => (w.as_slice(), g.as_slice(), p.as_slice()),
            None => (self.own.wty(), self.own.g_diag(), self.own.probes()),
        };
        let inputs = RefreshInputs {
            gk: &self.gk,
            sf2: self.kernel.sf2(),
            sigma2: self.sigma2,
            opts: self.cfg.msgp.cg.warm(),
            wty,
            probes_q,
            g_probes: &self.g_probes,
            g_diag: Some(g_diag),
        };
        let own = &self.own;
        let halo = &self.halo;
        let mut hbuf = vec![0.0f64; m];
        let mut g_apply = |v: &[f64], out: &mut [f64]| {
            own.g_matvec_into(v, out);
            if has_halo {
                halo.g_matvec_into(v, &mut hbuf);
                for (o, &h) in out.iter_mut().zip(&hbuf) {
                    *o += h;
                }
            }
        };
        let out = refresh_mdomain(
            inputs,
            &mut g_apply,
            &mut self.t_mean,
            &mut self.t_probes,
            &mut self.rws,
        );
        crate::failpoint!("shard.swap");
        self.serving.publish(
            self.id,
            ServingModel::from_parts(
                self.grid.clone(),
                out.u_mean,
                out.nu_u,
                self.kernel.sf2(),
                self.sigma2,
            ),
        );
        self.dirty = 0.0;
        self.refresh_count += 1;
        self.metrics.shards[self.id].refreshes.fetch_add(1, Ordering::Relaxed);
        if out.precond_fallback {
            self.metrics.precond_fallbacks.fetch_add(1, Ordering::Relaxed);
        }
        // Per-shard CG counts plus the (race-safe, cumulative) global
        // total; the global `last_refresh_*` gauges stay unsharded-only
        // — S workers racing one gauge would interleave shards of
        // different sizes into a meaningless reading.
        let iters = (out.mean_iters + out.var_iters) as u64;
        self.metrics.shards[self.id].refresh_cg_iters.fetch_add(iters, Ordering::Relaxed);
        self.metrics.refresh_cg_iters_total.fetch_add(iters, Ordering::Relaxed);
        // Per-shard wall-clock gauge (single-writer: only this worker
        // touches its slot), so the block-refresh speedup is observable
        // per shard at /metrics.
        let wall = t0.elapsed();
        self.metrics.shards[self.id]
            .last_refresh_us
            .store(wall.as_micros() as u64, Ordering::Relaxed);
        self.metrics.record_refresh(wall);
        // Process-wide value — every worker stores the same number, so
        // the multi-writer race on this gauge is benign.
        self.metrics.record_refresh_threads(crate::parallel::threads() as u64);
    }

    /// Persist this shard's accumulators (`skis[0] = own`,
    /// `skis[1] = halo`) atomically. Failures increment
    /// `ckpt_write_errors_total` — a full disk never takes a shard down.
    fn write_checkpoint(&mut self) {
        let path = match self.ckpt.shard_path(self.id) {
            Some(p) => p,
            None => return,
        };
        let t0 = Instant::now();
        let c = Checkpoint {
            seq: self.seq + 1,
            kernel: self.kernel.clone(),
            sigma2: self.sigma2,
            skis: vec![self.own.clone(), self.halo.clone()],
        };
        match fault::write_atomic(&path, &c) {
            Ok(()) => {
                self.seq += 1;
                self.trigger.note_written();
                self.metrics.record_ckpt_write(self.seq, t0.elapsed());
            }
            Err(e) => {
                self.metrics.ckpt_write_errors_total.inc();
                crate::log_warn!("shard {} checkpoint write failed: {e}", self.id);
            }
        }
    }

    /// Adopt checkpointed accumulators if they fit this worker's layout
    /// (exact grid match, same probe count for both accumulators) and
    /// replay the refresh so the restored model serves immediately. The
    /// `recovering` gauge is raised for the replay — `/healthz` answers
    /// 503 until every shard finishes.
    fn try_restore(&mut self) {
        let path = match self.ckpt.shard_path(self.id) {
            Some(p) => p,
            None => return,
        };
        let (c, from) = match fault::load_newest(&path) {
            Some(v) => v,
            None => return,
        };
        let ns = self.cfg.msgp.n_var_samples.max(1);
        let fits = c.skis.len() == 2
            && c.skis.iter().all(|s| *s.grid() == self.grid && s.probes().len() == ns);
        if !fits {
            crate::log_warn!(
                "shard {} checkpoint {} does not fit the configured layout (ignoring)",
                self.id,
                from.display()
            );
            return;
        }
        self.metrics.recovering.fetch_add(1, Ordering::Relaxed);
        let mut skis = c.skis;
        if let (Some(halo), Some(own)) = (skis.pop(), skis.pop()) {
            self.halo = halo;
            self.own = own;
        }
        self.seq = c.seq;
        crate::log_info!(
            "shard {} restored checkpoint seq={} n={} from {}",
            self.id,
            c.seq,
            self.own.n(),
            from.display()
        );
        self.refresh_and_publish();
        self.metrics.recovering.fetch_sub(1, Ordering::Relaxed);
        self.metrics.ckpt_restores_total.inc();
    }

    /// One control message. Runs under the supervisor's `catch_unwind`
    /// in [`Self::run`]: a panic here unwinds any pending reply sender,
    /// so blocked facade callers observe a channel error, not a hang.
    fn handle(&mut self, msg: ShardMsg) {
        let refresh_every = self.cfg.refresh_every.max(1) as f64;
        match msg {
            ShardMsg::Ingest { xs, ys, halo, reply } => {
                let k = self.ingest(&xs, &ys, halo);
                // Ack before any cadence-triggered refresh so a slow
                // solve never stalls the ingest caller.
                if let Some(r) = reply {
                    let _ = r.send(k);
                }
                if !halo && self.ckpt.enabled() {
                    self.trigger.note_points(k);
                }
                if self.dirty >= refresh_every {
                    self.refresh_and_publish();
                }
                if self.ckpt.enabled() && self.trigger.due(&self.ckpt) {
                    self.write_checkpoint();
                }
            }
            ShardMsg::Flush { reply } => {
                if self.dirty > 0.0 || self.refresh_count == 0 {
                    self.refresh_and_publish();
                }
                let _ = reply.send(());
            }
            ShardMsg::Decay { gamma, reply } => {
                {
                    // Same lock a whole-domain re-opt snapshot takes:
                    // the accumulators can never be observed
                    // half-decayed. Poison recovery: decay is applied
                    // whole under this guard.
                    let reservoir = self.reservoir.clone();
                    let _guard = reservoir.lock().unwrap_or_else(|e| e.into_inner());
                    self.own.decay(gamma);
                    self.halo.decay(gamma);
                }
                if self.own.n() > 0 || self.halo.n() > 0 {
                    self.dirty = self.dirty.max(1.0);
                }
                let _ = reply.send(());
            }
            ShardMsg::OwnedStats { reply } => {
                let _ = reply.send(self.own.clone());
            }
            ShardMsg::SetHypers { kernel, sigma2, reply } => {
                self.kernel = kernel;
                self.sigma2 = sigma2;
                self.gk = GridKernel::new(&self.kernel, &self.grid, &self.cfg.msgp);
                self.refresh_and_publish();
                let _ = reply.send(());
            }
        }
    }

    /// The worker loop, supervised: each message is handled under
    /// `catch_unwind`, so an injected (or organic) panic drops that one
    /// message, restarts the worker with capped exponential backoff,
    /// and — after too many failures inside the policy window — poisons
    /// it (the loop exits, `/healthz` flips unhealthy, and facade sends
    /// to this shard start failing loudly).
    fn run(mut self, rx: Receiver<ShardMsg>) {
        self.try_restore();
        let mut sup =
            Supervisor::new(SupervisorPolicy::default(), 0x5a4d ^ ((self.id as u64) << 8));
        while let Ok(msg) = rx.recv() {
            self.metrics.shards[self.id].queue_depth.fetch_sub(1, Ordering::Relaxed);
            let outcome = catch_unwind(AssertUnwindSafe(|| self.handle(msg)));
            if outcome.is_err() {
                self.metrics.record_worker_restart(WorkerKind::Shard);
                match sup.on_failure() {
                    Verdict::Restart(backoff) => {
                        crate::log_warn!(
                            "shard {} worker panicked; restarting after {}ms",
                            self.id,
                            backoff.as_millis()
                        );
                        std::thread::sleep(backoff);
                    }
                    Verdict::Poison => {
                        self.metrics.worker_poisoned.fetch_add(1, Ordering::Relaxed);
                        crate::log_error!(
                            "shard {} worker poisoned after repeated panics; /healthz now fails",
                            self.id
                        );
                        break;
                    }
                }
            }
        }
        // Graceful shutdown: persist the final statistics so a restart
        // resumes from exactly what this shard acked.
        if self.ckpt.enabled() && (self.own.n() > 0 || self.halo.n() > 0) {
            self.write_checkpoint();
        }
    }
}

/// The facade over S shard workers: routes ingest batches (with halo
/// copies), fans out control messages, merges owned statistics, and
/// runs whole-domain hyper re-optimization on the pooled reservoirs.
pub struct ShardedTrainer {
    plan: Arc<ShardPlan>,
    serving: Arc<ShardedServing>,
    /// Shared metrics (per-shard counters populated; the sharded server
    /// reuses this instance so `/metrics` sees both sides).
    pub metrics: Arc<Metrics>,
    cfg: ShardConfig,
    txs: Vec<SyncSender<ShardMsg>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    reservoirs: Vec<Arc<Mutex<Reservoir>>>,
    /// Current hyperparameters (updated by whole-domain re-opts).
    hypers: Mutex<(KernelSpec, f64)>,
    /// Serializes cross-shard facade operations (ingest routing, decay
    /// broadcasts, stats collection, re-opts). Per-worker queues already
    /// order messages *within* a shard; this lock makes multi-shard
    /// operations atomic *across* shards, so a decay epoch can never
    /// interleave with a concurrent ingest batch or merge — every point
    /// of a batch sees the same epoch on every shard, and merged
    /// statistics always correspond to one consistent epoch.
    ops: Mutex<()>,
}

impl ShardedTrainer {
    /// Plan the shards over `global` and start one worker thread per
    /// shard. Until data arrives every shard serves the prior.
    pub fn start(kernel: KernelSpec, sigma2: f64, global: Grid, cfg: ShardConfig) -> Self {
        assert_eq!(kernel.dim(), global.dim(), "kernel dim vs grid dim");
        fault::init_from_env();
        let ckpt = CkptConfig::from_env();
        if let Some(dir) = &ckpt.dir {
            // Best-effort: a missing checkpoint directory surfaces later
            // as ckpt_write_errors_total, not a startup panic.
            let _ = std::fs::create_dir_all(dir);
        }
        let plan = Arc::new(ShardPlan::new(global, cfg.shards, cfg.halo, cfg.blend));
        let s = plan.shards();
        let metrics = Arc::new(Metrics::with_shards(s));
        let initial: Vec<ServingModel> = (0..s)
            .map(|i| {
                let g = plan.local_grid(i);
                let m = g.m();
                ServingModel::from_parts(g, vec![0.0; m], vec![0.0; m], kernel.sf2(), sigma2)
            })
            .collect();
        let serving = Arc::new(ShardedServing::new(plan.clone(), initial));
        let mut txs = Vec::with_capacity(s);
        let mut handles = Vec::with_capacity(s);
        let mut reservoirs = Vec::with_capacity(s);
        for id in 0..s {
            let (tx, rx) = mpsc::sync_channel::<ShardMsg>(1024);
            let reservoir = Arc::new(Mutex::new(Reservoir::default()));
            let grid = plan.local_grid(id);
            let kernel = kernel.clone();
            let cfg = cfg.clone();
            let serving = serving.clone();
            let metrics = metrics.clone();
            let res = reservoir.clone();
            let ckpt = ckpt.clone();
            let handle = std::thread::Builder::new()
                .name(format!("msgp-shard-{id}"))
                .spawn(move || {
                    // Build the heavy state on the worker thread itself.
                    let m = grid.m();
                    let ns = cfg.msgp.n_var_samples.max(1);
                    let seed = cfg.msgp.seed;
                    let mut probe_rng = Rng::new(seed ^ (0x9b0b + 2 * id as u64));
                    let gk = GridKernel::new(&kernel, &grid, &cfg.msgp);
                    // Distinct seeds per accumulator: merged probe sums
                    // stay exact N(0, G) samples (independent draws).
                    let own = IncrementalSki::new(grid.clone(), ns, 1, seed ^ (2 * id as u64));
                    let halo =
                        IncrementalSki::new(grid.clone(), ns, 1, seed ^ (2 * id as u64 + 1));
                    let worker = ShardWorker {
                        g_probes: (0..ns).map(|_| probe_rng.normal_vec(m)).collect(),
                        t_probes: (0..ns).map(|_| vec![0.0; m]).collect(),
                        t_mean: vec![0.0; m],
                        rws: RefreshWorkspace::new(),
                        res_rng: Rng::new(seed ^ (0x7e5e + id as u64)),
                        sigma2,
                        id,
                        grid,
                        kernel,
                        cfg,
                        own,
                        halo,
                        gk,
                        reservoir: res,
                        serving,
                        metrics,
                        dirty: 0.0,
                        refresh_count: 0,
                        ckpt,
                        trigger: CkptTrigger::default(),
                        seq: 0,
                    };
                    worker.run(rx);
                })
                // PANIC-OK: startup-time spawn; nothing is serving yet.
                .expect("spawn shard worker");
            txs.push(tx);
            handles.push(handle);
            reservoirs.push(reservoir);
        }
        ShardedTrainer {
            plan,
            serving,
            metrics,
            cfg,
            txs,
            handles,
            reservoirs,
            hypers: Mutex::new((kernel, sigma2)),
            ops: Mutex::new(()),
        }
    }

    /// The shard plan.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// The serving table (shared with the coordinator's batcher).
    pub fn serving(&self) -> Arc<ShardedServing> {
        self.serving.clone()
    }

    /// Configuration.
    pub fn cfg(&self) -> &ShardConfig {
        &self.cfg
    }

    fn send(&self, shard: usize, msg: ShardMsg) {
        self.metrics.shards[shard].queue_depth.fetch_add(1, Ordering::Relaxed);
        self.txs[shard]
            .send(msg)
            // PANIC-OK: the receiver drops only when the worker was
            // poisoned (its supervisor exhausted the restart budget) —
            // the facade is unusable and /healthz already reports it;
            // failing loudly beats silently dropping data.
            .unwrap_or_else(|_| panic!("shard {shard} worker died"));
    }

    /// Route a batch of observations to their owning shards (plus halo
    /// copies to seam neighbors) and wait for the owned-ingest acks.
    /// Returns the number of points applied. Rejected (and counted in
    /// `metrics.ingest_rejected_total`): non-finite points, and points
    /// less than **one grid cell inside the global box** — the sharded
    /// path never auto-expands (the plan's geometry is fixed), and the
    /// one-cell admission margin is what lets the per-shard
    /// accumulators run with `margin_cells = 1` and never expand
    /// either. Size the global grid with a margin around the expected
    /// data range (as [`crate::grid::Grid::covering`] does) so edge
    /// data is not excluded.
    pub fn ingest_batch(&self, xs: &[f64], ys: &[f64]) -> usize {
        let d = self.plan.global().dim();
        assert_eq!(xs.len(), ys.len() * d, "xs is k x D row-major, ys length k");
        // Poison recovery: the guard protects ordering only (unit value).
        let _ops = self.ops.lock().unwrap_or_else(|e| e.into_inner());
        let s = self.plan.shards();
        let mut owned: Vec<(Vec<f64>, Vec<f64>)> = vec![(Vec::new(), Vec::new()); s];
        let mut halos: Vec<(Vec<f64>, Vec<f64>)> = vec![(Vec::new(), Vec::new()); s];
        let mut rejected = 0u64;
        for (i, &y) in ys.iter().enumerate() {
            let row = &xs[i * d..(i + 1) * d];
            let finite = y.is_finite() && row.iter().all(|v| v.is_finite());
            if !finite || !self.plan.global().covers(row, 1.0) {
                rejected += 1;
                continue;
            }
            let owner = self.plan.owner_of(row);
            owned[owner].0.extend_from_slice(row);
            owned[owner].1.push(y);
            for nb in self.plan.halo_recipients(row, owner).into_iter().flatten() {
                halos[nb].0.extend_from_slice(row);
                halos[nb].1.push(y);
            }
        }
        let (ack_tx, ack_rx) = mpsc::sync_channel::<usize>(s);
        let mut expected = 0usize;
        for shard in 0..s {
            let (hx, hy) = std::mem::take(&mut halos[shard]);
            if !hy.is_empty() {
                self.send(shard, ShardMsg::Ingest { xs: hx, ys: hy, halo: true, reply: None });
            }
            let (ox, oy) = std::mem::take(&mut owned[shard]);
            if !oy.is_empty() {
                expected += 1;
                self.send(
                    shard,
                    ShardMsg::Ingest { xs: ox, ys: oy, halo: false, reply: Some(ack_tx.clone()) },
                );
            }
        }
        drop(ack_tx);
        let mut applied = 0usize;
        for _ in 0..expected {
            // A dropped ack means that shard's ingest panicked mid-batch
            // (the supervisor restarts the worker); count the sub-batch
            // as not applied rather than hanging or panicking the
            // caller.
            match ack_rx.recv() {
                Ok(k) => applied += k,
                Err(_) => {
                    crate::log_warn!("a shard dropped its ingest ack (worker panicked mid-batch)")
                }
            }
        }
        if applied > 0 {
            self.metrics.ingested_points_total.fetch_add(applied as u64, Ordering::Relaxed);
            self.metrics.ingest_batches.fetch_add(1, Ordering::Relaxed);
        }
        self.metrics.ingest_rejected_total.fetch_add(rejected, Ordering::Relaxed);
        applied
    }

    /// Force every shard to refresh + publish, and wait. After this
    /// returns, predictions observe every previously acked ingest.
    pub fn flush(&self) {
        let (tx, rx) = mpsc::sync_channel::<()>(self.txs.len());
        for shard in 0..self.txs.len() {
            self.send(shard, ShardMsg::Flush { reply: tx.clone() });
        }
        drop(tx);
        for _ in 0..self.txs.len() {
            let _ = rx.recv();
        }
    }

    /// Broadcast an exponential-forgetting epoch to every shard (each
    /// worker decays under its reservoir lock) and wait. Atomic with
    /// respect to the other facade operations: a concurrent ingest
    /// batch or stats merge observes every shard either before or
    /// after the epoch, never a mix.
    pub fn decay(&self, gamma: f64) {
        assert!(gamma > 0.0 && gamma <= 1.0);
        // Poison recovery: ordering-only guard (see `ingest_batch`).
        let _ops = self.ops.lock().unwrap_or_else(|e| e.into_inner());
        let (tx, rx) = mpsc::sync_channel::<()>(self.txs.len());
        for shard in 0..self.txs.len() {
            self.send(shard, ShardMsg::Decay { gamma, reply: tx.clone() });
        }
        drop(tx);
        for _ in 0..self.txs.len() {
            let _ = rx.recv();
        }
    }

    /// Collect a clone of every shard's *owned* accumulator (FIFO
    /// ordering: observes every ingest acked before the call, and — via
    /// the facade ops lock — one consistent decay epoch across shards).
    /// Broadcast-then-collect, so per-shard queue drains overlap
    /// instead of summing.
    pub fn owned_stats(&self) -> Vec<IncrementalSki> {
        // Poison recovery: ordering-only guard (see `ingest_batch`).
        let _ops = self.ops.lock().unwrap_or_else(|e| e.into_inner());
        let rxs: Vec<_> = (0..self.txs.len())
            .map(|shard| {
                let (tx, rx) = mpsc::sync_channel::<IncrementalSki>(1);
                self.send(shard, ShardMsg::OwnedStats { reply: tx });
                rx
            })
            .collect();
        rxs.into_iter()
            .map(|rx| {
                rx.recv()
                    // PANIC-OK: a partial stats set would silently
                    // corrupt the additive merge — a dropped reply
                    // (clone panicked; effectively OOM) must fail the
                    // merge loudly, not produce wrong statistics.
                    .expect("shard worker dropped stats reply")
            })
            .collect()
    }

    /// Fold every shard's owned statistics into one global accumulator
    /// (equals a single-trainer build over the full stream to ~1e-13).
    pub fn merged_stats(&self) -> IncrementalSki {
        merge::merge_owned(
            self.plan.global().clone(),
            self.cfg.msgp.seed,
            &self.owned_stats(),
        )
    }

    /// A whole-domain trainer over the merged statistics, carrying the
    /// current hyperparameters — the "combined global snapshot" used for
    /// whole-domain evaluation and re-optimization.
    pub fn merged_trainer(&self) -> StreamTrainer {
        // Poison recovery: the hypers tuple is replaced whole.
        let (kernel, sigma2) = self.hypers.lock().unwrap_or_else(|e| e.into_inner()).clone();
        let cfg = StreamConfig {
            msgp: self.cfg.msgp.clone(),
            reservoir: self.cfg.reservoir,
            ..StreamConfig::default()
        };
        merge::merged_trainer(kernel, sigma2, cfg, self.plan.global().clone(), &self.owned_stats())
    }

    /// Whole-domain hyperparameter re-optimization: pool the per-shard
    /// reservoir snapshots (each taken under the lock its shard's decay
    /// holds), fit a batch MSGP on the *global* grid, run `iters` Adam
    /// steps, broadcast the learned hypers to every shard (each
    /// rebuilds its operator, refreshes, publishes), and return the
    /// snapshot LML — or `None` while the reservoirs are empty.
    pub fn reoptimize_global(&self, iters: usize, lr: f64) -> anyhow::Result<Option<f64>> {
        let d = self.plan.global().dim();
        // Snapshot phase, under the ops lock: a consistent view of the
        // reservoirs and current hypers. The (slow) fit below runs
        // *outside* the lock so ingest/decay/merge keep flowing — the
        // learned hypers then describe a snapshot at most one epoch
        // stale, which a later re-opt corrects.
        //
        // Each reservoir is a uniform sample of *its own shard's*
        // stream, so equal-weight pooling would over-represent
        // low-traffic shards and bias the fitted hypers toward sparse
        // regions. Subsample shard s proportionally to its seen stream
        // length, approximating one uniform reservoir over the union.
        let (parts, kernel, sigma2) = {
            // Poison recovery: ordering-only guard (see `ingest_batch`).
            let _ops = self.ops.lock().unwrap_or_else(|e| e.into_inner());
            let mut parts: Vec<(Vec<f64>, Vec<f64>, usize)> =
                Vec::with_capacity(self.reservoirs.len());
            for reservoir in &self.reservoirs {
                // Poison recovery: reservoirs stay well-formed across a
                // panicking holder (offers are applied one at a time).
                let g = reservoir.lock().unwrap_or_else(|e| e.into_inner());
                parts.push((g.x.clone(), g.y.clone(), g.seen));
            }
            // Poison recovery: the hypers tuple is replaced whole.
            let (kernel, sigma2) = self.hypers.lock().unwrap_or_else(|e| e.into_inner()).clone();
            (parts, kernel, sigma2)
        };
        let seen_total: usize = parts.iter().map(|p| p.2).sum();
        if seen_total == 0 {
            return Ok(None);
        }
        let target = self.cfg.reservoir.max(1);
        let mut rng = Rng::new(self.cfg.msgp.seed ^ 0x5e0f_u64);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for (px, py, seen) in parts {
            let len = py.len();
            if len == 0 {
                continue;
            }
            let share = target as f64 * seen as f64 / seen_total as f64;
            let quota = (share.round() as usize).clamp(1, len);
            let mut idx: Vec<usize> = (0..len).collect();
            rng.shuffle(&mut idx);
            for &i in idx.iter().take(quota) {
                x.extend_from_slice(&px[i * d..(i + 1) * d]);
                y.push(py[i]);
            }
        }
        if y.is_empty() {
            return Ok(None);
        }
        let snapshot = Dataset { x, d, y };
        let mut cfg = self.cfg.msgp.clone();
        cfg.n_per_dim = self.plan.global().shape();
        let mut model = MsgpModel::fit_with_grid(
            kernel,
            sigma2,
            snapshot,
            self.plan.global().clone(),
            cfg,
        )?;
        model.train(iters, lr)?;
        let lml = model.lml();
        // Broadcast phase, under the ops lock again: hypers adoption is
        // atomic across shards with respect to ingest/decay/merge.
        // Poison recovery: ordering-only guard / whole-tuple store.
        let _ops = self.ops.lock().unwrap_or_else(|e| e.into_inner());
        *self.hypers.lock().unwrap_or_else(|e| e.into_inner()) =
            (model.kernel.clone(), model.sigma2);
        self.metrics.reopt_count.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::sync_channel::<()>(self.txs.len());
        for shard in 0..self.txs.len() {
            self.send(
                shard,
                ShardMsg::SetHypers {
                    kernel: model.kernel.clone(),
                    sigma2: model.sigma2,
                    reply: tx.clone(),
                },
            );
        }
        drop(tx);
        for _ in 0..self.txs.len() {
            let _ = rx.recv();
        }
        Ok(Some(lml))
    }

    /// Blended, shard-routed prediction (serving-path shortcut for
    /// callers not going through the coordinator).
    pub fn predict_batch(&self, points: &[f64]) -> (Vec<f64>, Vec<f64>) {
        self.serving.predict_batch(points)
    }

    /// `/shards` introspection payload: one line per shard with its
    /// owned slab, local grid size, and live counters.
    pub fn summary(&self) -> String {
        let ax = &self.plan.global().axes[self.plan.axis()];
        let mut s = format!(
            "shards={} axis={} halo={} blend={}\n",
            self.plan.shards(),
            self.plan.axis(),
            self.plan.halo(),
            self.plan.blend()
        );
        for i in 0..self.plan.shards() {
            let (lo, hi) = (self.plan.cuts()[i], self.plan.cuts()[i + 1]);
            let sm = &self.metrics.shards[i];
            s.push_str(&format!(
                "shard[{i}] owns=[{:.3}, {:.3}) m={} ingested={} halo={} refreshes={} queue_depth={}\n",
                ax.coord(lo),
                ax.coord(hi),
                self.plan.local_grid(i).m(),
                sm.ingested.load(Ordering::Relaxed),
                sm.halo_ingested.load(Ordering::Relaxed),
                sm.refreshes.load(Ordering::Relaxed),
                sm.queue_depth.load(Ordering::Relaxed),
            ));
        }
        s
    }

    /// `/shards?verbose=1` payload: [`Self::summary`] with each shard
    /// line extended by the remaining live metric counters (CG
    /// iterations, last refresh wall-clock, routed predictions,
    /// reservoir occupancy).
    pub fn summary_verbose(&self) -> String {
        let ax = &self.plan.global().axes[self.plan.axis()];
        let mut s = format!(
            "shards={} axis={} halo={} blend={}\n",
            self.plan.shards(),
            self.plan.axis(),
            self.plan.halo(),
            self.plan.blend()
        );
        for i in 0..self.plan.shards() {
            let (lo, hi) = (self.plan.cuts()[i], self.plan.cuts()[i + 1]);
            let sm = &self.metrics.shards[i];
            s.push_str(&format!(
                "shard[{i}] owns=[{:.3}, {:.3}) m={} ingested={} halo={} refreshes={} \
                 queue_depth={} cg_iters={} last_refresh_us={} routed={} reservoir={}\n",
                ax.coord(lo),
                ax.coord(hi),
                self.plan.local_grid(i).m(),
                sm.ingested.load(Ordering::Relaxed),
                sm.halo_ingested.load(Ordering::Relaxed),
                sm.refreshes.load(Ordering::Relaxed),
                sm.queue_depth.load(Ordering::Relaxed),
                sm.refresh_cg_iters.load(Ordering::Relaxed),
                sm.last_refresh_us.load(Ordering::Relaxed),
                sm.routed_predictions.load(Ordering::Relaxed),
                sm.reservoir_points.load(Ordering::Relaxed),
            ));
        }
        s
    }

    fn shutdown_inner(&mut self) {
        self.txs.clear(); // closing every channel stops the workers
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ShardedTrainer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}
