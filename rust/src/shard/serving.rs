//! Shard-aware serving: O(1) routing of each prediction to its owning
//! shard, with partition-of-unity blending across the halo so the served
//! surface is continuous at shard seams.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::state::{ServingModel, ShardSlots};
use crate::shard::plan::ShardPlan;

/// The serving side of a sharded deployment: the shard plan plus a
/// shard-indexed table of hot-swappable model slots (one
/// [`crate::coordinator::state::ModelSlot`] per shard, each swapped
/// atomically and independently by its trainer thread).
pub struct ShardedServing {
    plan: Arc<ShardPlan>,
    slots: ShardSlots,
}

impl ShardedServing {
    /// Build the table from one initial model per shard (a prior model
    /// until the first refresh publishes).
    pub fn new(plan: Arc<ShardPlan>, initial: Vec<ServingModel>) -> Self {
        assert_eq!(initial.len(), plan.shards());
        for (s, m) in initial.iter().enumerate() {
            assert_eq!(m.grid, plan.local_grid(s), "slot {s} grid must match the plan");
        }
        ShardedServing { plan, slots: ShardSlots::new(initial) }
    }

    /// The shard plan.
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Snapshot shard `s`'s current model.
    pub fn snapshot(&self, s: usize) -> Arc<ServingModel> {
        self.slots.get(s)
    }

    /// Atomically publish a refreshed model for shard `s` (called by the
    /// shard's trainer thread; readers in flight keep their snapshots).
    pub fn publish(&self, s: usize, model: ServingModel) {
        assert_eq!(model.grid, self.plan.local_grid(s), "published grid must match the plan");
        self.slots.swap(s, model);
    }

    /// Predict a batch of points *all owned by* `shard` (the batcher
    /// groups jobs by owning shard before dispatch). The owner's
    /// snapshot serves every point; points inside a blend zone
    /// additionally gather the neighbor's prediction and mix with the
    /// plan's partition-of-unity weights. Each involved slot is
    /// snapshotted once per call — a concurrent swap can never tear the
    /// batch.
    pub fn predict_routed(&self, shard: usize, points: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let d = self.plan.global().dim();
        debug_assert_eq!(points.len() % d, 0);
        let owner = self.slots.get(shard);
        let (mut means, mut vars) = owner.predict_batch(points);
        if self.plan.blend() == 0 {
            return (means, vars);
        }
        // Gather the blend-zone points per neighbor (at most two
        // neighbors for a seam-straddling batch).
        let mut groups: HashMap<usize, (Vec<f64>, Vec<(usize, f64)>)> = HashMap::new();
        for (i, x) in points.chunks_exact(d).enumerate() {
            if let Some((nb, w_owner)) = self.plan.blend_neighbor(x, shard) {
                let e = groups.entry(nb).or_default();
                e.0.extend_from_slice(x);
                e.1.push((i, w_owner));
            }
        }
        for (nb, (pts, idx)) in groups {
            let model = self.slots.get(nb);
            let (nm, nv) = model.predict_batch(&pts);
            for (j, &(i, w)) in idx.iter().enumerate() {
                // Mixture moments, not a plain average: the
                // mean-disagreement term keeps the served variance
                // honest exactly when the two snapshots differ (e.g.
                // one shard refreshed while its neighbor is stale).
                let (m1, v1) = (means[i], vars[i]);
                let (m2, v2) = (nm[j], nv[j]);
                means[i] = w * m1 + (1.0 - w) * m2;
                vars[i] = w * v1 + (1.0 - w) * v2 + w * (1.0 - w) * (m1 - m2) * (m1 - m2);
            }
        }
        (means, vars)
    }

    /// Predict an arbitrary batch: group by owning shard (O(1) per
    /// point), serve each group via [`Self::predict_routed`], and
    /// scatter the results back into input order.
    pub fn predict_batch(&self, points: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let d = self.plan.global().dim();
        assert_eq!(points.len() % d, 0);
        let k = points.len() / d;
        let mut groups: HashMap<usize, (Vec<f64>, Vec<usize>)> = HashMap::new();
        for (i, x) in points.chunks_exact(d).enumerate() {
            let e = groups.entry(self.plan.owner_of(x)).or_default();
            e.0.extend_from_slice(x);
            e.1.push(i);
        }
        let mut means = vec![0.0; k];
        let mut vars = vec![0.0; k];
        for (shard, (pts, idx)) in groups {
            let (gm, gv) = self.predict_routed(shard, &pts);
            for (j, &i) in idx.iter().enumerate() {
                means[i] = gm[j];
                vars[i] = gv[j];
            }
        }
        (means, vars)
    }
}
