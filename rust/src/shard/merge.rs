//! Additive merge of per-shard sufficient statistics.
//!
//! The SKI statistics (`W^T y`, the banded Gram `W^T W`, per-cell mass,
//! probe accumulators) are sums over observations, and every observation
//! is *owned* by exactly one shard (halo copies live in a separate
//! accumulator that merge never touches). Each shard's local grid is an
//! exact sub-grid of the global grid, so its owned accumulator lifts
//! onto the global grid by a whole-cell index shift and adds — the
//! merged result equals a single-trainer build over the union of the
//! shards' streams (to float rounding, ~1e-13 relative).

use crate::gp::msgp::KernelSpec;
use crate::grid::Grid;
use crate::stream::{IncrementalSki, StreamConfig, StreamTrainer};

/// Fold per-shard *owned* accumulators into one global accumulator.
/// `parts` must share the probe count; each part's grid must be a
/// sub-grid of `global` (the shard plan guarantees both).
pub fn merge_owned(global: Grid, seed: u64, parts: &[IncrementalSki]) -> IncrementalSki {
    assert!(!parts.is_empty(), "nothing to merge");
    let n_probes = parts[0].probes().len();
    // Offset the probe-RNG seed away from every worker accumulator's
    // (`seed ^ 2i` / `seed ^ (2i+1)`): continued ingestion on the
    // merged accumulator must not replay eps draws already baked into
    // the merged probe sums, or `E[q q^T] != G`.
    let mut out = IncrementalSki::new(global, n_probes, 1, seed ^ 0x4d52_4745_u64);
    for p in parts {
        out.accumulate_shifted(p);
    }
    out
}

/// Build a whole-domain trainer from merged statistics: the combined
/// global snapshot used for whole-domain hyper re-optimization and for
/// exactness checks against an unsharded trainer. The returned trainer
/// refreshes (and re-optimizes) exactly like one that ingested the full
/// stream itself — its statistics *are* that trainer's statistics.
pub fn merged_trainer(
    kernel: KernelSpec,
    sigma2: f64,
    cfg: StreamConfig,
    global: Grid,
    parts: &[IncrementalSki],
) -> StreamTrainer {
    let merged = merge_owned(global, cfg.msgp.seed, parts);
    StreamTrainer::from_stats(kernel, sigma2, cfg, merged)
}
