//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! Used for (i) the per-factor eigendecompositions of Kronecker-structured
//! `K_{U,U}` (section 3.1 of the paper), which are small (grid points per
//! dimension), and (ii) the subspace-distance metric of the projection
//! experiments (Eq. 13), which needs orthogonal projectors from `P P^T`.

use super::dense::Mat;

/// Result of a symmetric eigendecomposition `A = Q diag(vals) Q^T`.
#[derive(Clone, Debug)]
pub struct SymEig {
    /// Eigenvalues in ascending order.
    pub vals: Vec<f64>,
    /// Orthonormal eigenvectors as *columns* of `q`.
    pub q: Mat,
}

/// Jacobi eigendecomposition of a symmetric matrix. O(n^3) with a small
/// constant; fine for the <= few-thousand sizes it is used at.
pub fn sym_eig(a: &Mat) -> SymEig {
    let n = a.rows;
    assert_eq!(a.cols, n, "sym_eig needs a square matrix");
    let mut m = a.clone();
    let mut q = Mat::eye(n);
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if off.sqrt() < 1e-13 * (1.0 + m_frob(&m)) {
            break;
        }
        for p in 0..n {
            for r in p + 1..n {
                let apq = m[(p, r)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(r, r)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply the rotation G(p, r, theta) to both sides.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkr = m[(k, r)];
                    m[(k, p)] = c * mkp - s * mkr;
                    m[(k, r)] = s * mkp + c * mkr;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mrk = m[(r, k)];
                    m[(p, k)] = c * mpk - s * mrk;
                    m[(r, k)] = s * mpk + c * mrk;
                }
                for k in 0..n {
                    let qkp = q[(k, p)];
                    let qkr = q[(k, r)];
                    q[(k, p)] = c * qkp - s * qkr;
                    q[(k, r)] = s * qkp + c * qkr;
                }
            }
        }
    }
    // Extract and sort ascending.
    let mut idx: Vec<usize> = (0..n).collect();
    let vals_raw: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&a, &b| vals_raw[a].partial_cmp(&vals_raw[b]).unwrap());
    let vals: Vec<f64> = idx.iter().map(|&i| vals_raw[i]).collect();
    let mut qs = Mat::zeros(n, n);
    for (new_c, &old_c) in idx.iter().enumerate() {
        for r in 0..n {
            qs[(r, new_c)] = q[(r, old_c)];
        }
    }
    SymEig { vals, q: qs }
}

fn m_frob(m: &Mat) -> f64 {
    m.data.iter().map(|v| v * v).sum::<f64>().sqrt()
}

/// Spectral (2-)norm of a symmetric matrix: max |eigenvalue|.
pub fn sym_norm2(a: &Mat) -> f64 {
    sym_eig(a).vals.iter().fold(0.0f64, |acc, v| acc.max(v.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 2.0;
        let e = sym_eig(&a);
        assert!((e.vals[0] - 1.0).abs() < 1e-12);
        assert!((e.vals[1] - 2.0).abs() < 1e-12);
        assert!((e.vals[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstruction() {
        let n = 6;
        let b = Mat::from_fn(n, n, |r, c| ((r as f64) - (c as f64) * 0.5).sin());
        let mut a = b.matmul(&b.t());
        for i in 0..n {
            a[(i, i)] += 0.5;
        }
        let e = sym_eig(&a);
        // Rebuild A = Q diag Q^T.
        let mut rec = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += e.q[(i, k)] * e.vals[k] * e.q[(j, k)];
                }
                rec[(i, j)] = s;
            }
        }
        for i in 0..n {
            for j in 0..n {
                assert!((rec[(i, j)] - a[(i, j)]).abs() < 1e-8, "({i},{j})");
            }
        }
    }

    #[test]
    fn orthonormal_vectors() {
        let n = 5;
        let a = Mat::from_fn(n, n, |r, c| 1.0 / (1.0 + (r as f64 - c as f64).abs()));
        let e = sym_eig(&a);
        let qtq = e.q.t().matmul(&e.q);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq[(i, j)] - want).abs() < 1e-9);
            }
        }
    }
}
