//! Dense linear-algebra substrate: complex scalars, FFTs, dense matrices,
//! Cholesky and symmetric eigendecompositions.
//!
//! Everything here is written from scratch (no BLAS/LAPACK dependency) so
//! the structure-exploiting fast paths in [`crate::structure`] are fully
//! self-contained and portable.

pub mod complex;
pub mod fft;
pub mod dense;
pub mod cholesky;
pub mod eigen;

pub use complex::C64;
pub use dense::Mat;
