//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! This is the O(n^3) workhorse behind the exact-GP baseline (section 2 of
//! the paper) and the m x m inducing blocks of FITC/SSGP/SVI. MSGP itself
//! never calls this on an n x n matrix — that is the whole point.

use super::dense::Mat;

/// A lower-triangular Cholesky factor `L` with `L L^T = A`.
#[derive(Clone, Debug)]
pub struct Chol {
    /// The factor, stored densely (upper triangle is zero).
    pub l: Mat,
}

impl Chol {
    /// Factor an SPD matrix. Returns `None` if a non-positive pivot is hit
    /// (matrix not positive definite to working precision).
    pub fn new(a: &Mat) -> Option<Chol> {
        let n = a.rows;
        assert_eq!(a.cols, n, "Cholesky needs a square matrix");
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                // s = A[i][j] - sum_k L[i][k] L[j][k]
                let mut s = a[(i, j)];
                let (ri, rj) = (l.row(i), l.row(j));
                for k in 0..j {
                    s -= ri[k] * rj[k];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l[(i, i)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(Chol { l })
    }

    /// Dimension of the factored matrix.
    pub fn n(&self) -> usize {
        self.l.rows
    }

    /// Solve `A x = b` via forward + back substitution.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut y = self.forward(b);
        self.backward_in_place(&mut y);
        y
    }

    /// Forward substitution: solve `L y = b`.
    pub fn forward(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let row = self.l.row(i);
            let mut s = b[i];
            for k in 0..i {
                s -= row[k] * y[k];
            }
            y[i] = s / row[i];
        }
        y
    }

    /// Back substitution in place: solve `L^T x = y`.
    pub fn backward_in_place(&self, y: &mut [f64]) {
        let n = self.n();
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l[(k, i)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
    }

    /// `log |A| = 2 sum_i log L_ii`.
    pub fn logdet(&self) -> f64 {
        (0..self.n()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Solve against a matrix RHS, column by column.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.n();
        assert_eq!(b.rows, n);
        let mut out = Mat::zeros(n, b.cols);
        let mut col = vec![0.0; n];
        for c in 0..b.cols {
            for r in 0..n {
                col[r] = b[(r, c)];
            }
            let x = self.solve(&col);
            for r in 0..n {
                out[(r, c)] = x[r];
            }
        }
        out
    }

    /// Inverse of `A` (used only on small m x m blocks).
    pub fn inverse(&self) -> Mat {
        self.solve_mat(&Mat::eye(self.n()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize) -> Mat {
        // A = B B^T + n I is SPD.
        let b = Mat::from_fn(n, n, |r, c| ((r * 7 + c * 3) % 5) as f64 - 2.0);
        let mut a = b.matmul(&b.t());
        for i in 0..n {
            a[(i, i)] += n as f64;
        }
        a
    }

    #[test]
    fn factor_and_solve() {
        let a = spd(8);
        let ch = Chol::new(&a).unwrap();
        let x_true: Vec<f64> = (0..8).map(|i| (i as f64 * 0.3).sin()).collect();
        let b = a.matvec(&x_true);
        let x = ch.solve(&b);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-9);
        }
    }

    #[test]
    fn logdet_matches_lu_expansion() {
        let a = spd(5);
        let ch = Chol::new(&a).unwrap();
        // Compare against determinant from solving e_i systems (product of
        // pivots via recursion is messy; instead check exp(logdet) on a
        // matrix with a known determinant).
        let mut d = Mat::eye(4);
        d[(0, 0)] = 2.0;
        d[(1, 1)] = 3.0;
        d[(2, 2)] = 4.0;
        d[(3, 3)] = 5.0;
        let chd = Chol::new(&d).unwrap();
        assert!((chd.logdet() - (120.0f64).ln()).abs() < 1e-12);
        assert!(ch.logdet().is_finite());
    }

    #[test]
    fn non_spd_rejected() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(Chol::new(&a).is_none());
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let a = spd(6);
        let inv = Chol::new(&a).unwrap().inverse();
        let prod = a.matmul(&inv);
        for i in 0..6 {
            for j in 0..6 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - want).abs() < 1e-8);
            }
        }
    }
}
