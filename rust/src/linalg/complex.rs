//! Minimal complex-arithmetic type used by the FFT and circulant algebra.
//!
//! We deliberately avoid an external `num-complex` dependency: the set of
//! operations needed by the crate is small and keeping it in-tree lets the
//! FFT inner loops stay `#[inline]`-friendly.

use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// A double-precision complex number.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct C64 {
    /// Real part.
    pub re: f64,
    /// Imaginary part.
    pub im: f64,
}

impl C64 {
    /// Complex zero.
    pub const ZERO: C64 = C64 { re: 0.0, im: 0.0 };
    /// Complex one.
    pub const ONE: C64 = C64 { re: 1.0, im: 0.0 };

    /// Construct from real and imaginary parts.
    #[inline(always)]
    pub fn new(re: f64, im: f64) -> Self {
        C64 { re, im }
    }

    /// Construct a purely real complex number.
    #[inline(always)]
    pub fn real(re: f64) -> Self {
        C64 { re, im: 0.0 }
    }

    /// `e^{i theta}` = `cos theta + i sin theta`.
    #[inline(always)]
    pub fn cis(theta: f64) -> Self {
        let (s, c) = theta.sin_cos();
        C64 { re: c, im: s }
    }

    /// Complex conjugate.
    #[inline(always)]
    pub fn conj(self) -> Self {
        C64 { re: self.re, im: -self.im }
    }

    /// Squared magnitude `re^2 + im^2`.
    #[inline(always)]
    pub fn norm_sqr(self) -> f64 {
        self.re * self.re + self.im * self.im
    }

    /// Magnitude.
    #[inline(always)]
    pub fn abs(self) -> f64 {
        self.norm_sqr().sqrt()
    }

    /// Multiplication by a real scalar.
    #[inline(always)]
    pub fn scale(self, s: f64) -> Self {
        C64 { re: self.re * s, im: self.im * s }
    }

    /// Complex square root (principal branch).
    pub fn sqrt(self) -> Self {
        let r = self.abs();
        let re = ((r + self.re) * 0.5).max(0.0).sqrt();
        let im_mag = ((r - self.re) * 0.5).max(0.0).sqrt();
        C64 { re, im: if self.im >= 0.0 { im_mag } else { -im_mag } }
    }
}

impl Add for C64 {
    type Output = C64;
    #[inline(always)]
    fn add(self, o: C64) -> C64 {
        C64 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl AddAssign for C64 {
    #[inline(always)]
    fn add_assign(&mut self, o: C64) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl Sub for C64 {
    type Output = C64;
    #[inline(always)]
    fn sub(self, o: C64) -> C64 {
        C64 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl SubAssign for C64 {
    #[inline(always)]
    fn sub_assign(&mut self, o: C64) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl Mul for C64 {
    type Output = C64;
    #[inline(always)]
    fn mul(self, o: C64) -> C64 {
        C64 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl MulAssign for C64 {
    #[inline(always)]
    fn mul_assign(&mut self, o: C64) {
        *self = *self * o;
    }
}

impl Div for C64 {
    type Output = C64;
    #[inline(always)]
    fn div(self, o: C64) -> C64 {
        let d = o.norm_sqr();
        C64 {
            re: (self.re * o.re + self.im * o.im) / d,
            im: (self.im * o.re - self.re * o.im) / d,
        }
    }
}

impl Neg for C64 {
    type Output = C64;
    #[inline(always)]
    fn neg(self) -> C64 {
        C64 { re: -self.re, im: -self.im }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = C64::new(1.0, 2.0);
        let b = C64::new(3.0, -1.0);
        assert_eq!(a + b, C64::new(4.0, 1.0));
        assert_eq!(a - b, C64::new(-2.0, 3.0));
        assert_eq!(a * b, C64::new(5.0, 5.0));
        let q = (a / b) * b;
        assert!((q.re - a.re).abs() < 1e-12 && (q.im - a.im).abs() < 1e-12);
    }

    #[test]
    fn cis_and_conj() {
        let z = C64::cis(std::f64::consts::FRAC_PI_2);
        assert!(z.re.abs() < 1e-12 && (z.im - 1.0).abs() < 1e-12);
        assert_eq!(z.conj().im, -z.im);
    }

    #[test]
    fn sqrt_branch() {
        for &(re, im) in &[(4.0, 0.0), (0.0, 2.0), (-1.0, 0.0), (3.0, -4.0)] {
            let z = C64::new(re, im);
            let s = z.sqrt();
            let back = s * s;
            assert!((back.re - re).abs() < 1e-10, "{re} {im}");
            assert!((back.im - im).abs() < 1e-10, "{re} {im}");
        }
    }
}
