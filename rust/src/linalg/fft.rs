//! Fast Fourier transforms: iterative radix-2 Cooley–Tukey for power-of-two
//! lengths and Bluestein's chirp-z algorithm for arbitrary lengths, plus a
//! multi-dimensional transform over the axes of a dense tensor.
//!
//! Circulant eigenvalue computations ([`crate::structure::circulant`]) need
//! FFTs at the *exact* grid size `m` (which users choose freely), hence the
//! Bluestein fallback; Toeplitz matrix–vector products are free to pad to
//! the next power of two and always hit the radix-2 path.
//!
//! [`FftPlan`] caches twiddle factors and (for Bluestein) the transformed
//! chirp so repeated transforms of one size — the common case inside CG
//! iterations — do no trigonometry.

use super::complex::C64;
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Round `n` up to the next power of two.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// A cached FFT plan for a fixed transform length.
#[derive(Debug)]
pub struct FftPlan {
    n: usize,
    /// Twiddles for the radix-2 kernel of size `work_len` (== `n` when `n`
    /// is a power of two, else the Bluestein convolution length).
    twiddles: Vec<C64>,
    work_len: usize,
    /// Bluestein state: chirp `w_k = e^{-i pi k^2 / n}` and the forward
    /// FFT of the zero-padded conjugate chirp.
    bluestein: Option<BluesteinState>,
}

#[derive(Debug)]
struct BluesteinState {
    chirp: Vec<C64>,
    chirp_fft: Vec<C64>,
}

impl FftPlan {
    /// Build a plan for length-`n` transforms.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "FFT length must be >= 1");
        if n.is_power_of_two() {
            FftPlan { n, twiddles: make_twiddles(n), work_len: n, bluestein: None }
        } else {
            let m = next_pow2(2 * n - 1);
            let twiddles = make_twiddles(m);
            // chirp[k] = e^{-i pi k^2 / n}
            let mut chirp = vec![C64::ZERO; n];
            for k in 0..n {
                // Reduce k^2 mod 2n to keep the angle argument small and
                // the trigonometry accurate for large n.
                let k2 = (k * k) % (2 * n);
                chirp[k] = C64::cis(-std::f64::consts::PI * k2 as f64 / n as f64);
            }
            // b[k] = conj(chirp[|k|]) zero-padded to m, wrapped.
            let mut b = vec![C64::ZERO; m];
            b[0] = chirp[0].conj();
            for k in 1..n {
                b[k] = chirp[k].conj();
                b[m - k] = chirp[k].conj();
            }
            fft_pow2(&mut b, &twiddles, false);
            FftPlan { n, twiddles, work_len: m, bluestein: Some(BluesteinState { chirp, chirp_fft: b }) }
        }
    }

    /// Transform length this plan was built for.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if the plan length is zero (never; kept for clippy symmetry).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// In-place forward DFT (no normalization): `X_k = sum_j x_j e^{-2 pi i jk/n}`.
    pub fn forward(&self, x: &mut [C64]) {
        self.transform(x, false)
    }

    /// In-place inverse DFT **with** `1/n` normalization.
    pub fn inverse(&self, x: &mut [C64]) {
        self.transform(x, true);
        let s = 1.0 / self.n as f64;
        for v in x.iter_mut() {
            *v = v.scale(s);
        }
    }

    fn transform(&self, x: &mut [C64], inverse: bool) {
        assert_eq!(x.len(), self.n, "FFT length mismatch: plan {} vs input {}", self.n, x.len());
        match &self.bluestein {
            None => fft_pow2(x, &self.twiddles, inverse),
            Some(bs) => self.bluestein_transform(x, bs, inverse),
        }
    }

    fn bluestein_transform(&self, x: &mut [C64], bs: &BluesteinState, inverse: bool) {
        let n = self.n;
        let m = self.work_len;
        // Inverse transform = conjugate trick: F^{-1}(x) * n = conj(F(conj(x))).
        if inverse {
            for v in x.iter_mut() {
                *v = v.conj();
            }
        }
        let mut a = vec![C64::ZERO; m];
        for k in 0..n {
            a[k] = x[k] * bs.chirp[k];
        }
        fft_pow2(&mut a, &self.twiddles, false);
        for (av, bv) in a.iter_mut().zip(bs.chirp_fft.iter()) {
            *av = *av * *bv;
        }
        fft_pow2(&mut a, &self.twiddles, true);
        let s = 1.0 / m as f64;
        for k in 0..n {
            x[k] = a[k].scale(s) * bs.chirp[k];
        }
        if inverse {
            for v in x.iter_mut() {
                *v = v.conj();
            }
        }
    }
}

fn make_twiddles(n: usize) -> Vec<C64> {
    // Twiddles for the forward transform, one per element of the half-size
    // butterfly at the largest stage; stages reuse strided prefixes.
    let half = n / 2;
    let mut tw = Vec::with_capacity(half.max(1));
    for k in 0..half.max(1) {
        tw.push(C64::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64));
    }
    tw
}

/// Iterative radix-2 Cooley–Tukey, `x.len()` must be a power of two.
/// `twiddles` must be the table for exactly this length.
fn fft_pow2(x: &mut [C64], twiddles: &[C64], inverse: bool) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    if n <= 1 {
        return;
    }
    // Bit-reversal permutation.
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            x.swap(i, j);
        }
    }
    // Butterflies. Twiddle for stage of length `len` at position k is
    // twiddles[k * (n/len)] (stride-decimated main table).
    let mut len = 2;
    while len <= n {
        let stride = n / len;
        let half = len / 2;
        for start in (0..n).step_by(len) {
            for k in 0..half {
                let mut w = twiddles[k * stride];
                if inverse {
                    w = w.conj();
                }
                let u = x[start + k];
                let v = x[start + k + half] * w;
                x[start + k] = u + v;
                x[start + k + half] = u - v;
            }
        }
        len <<= 1;
    }
}

thread_local! {
    static PLAN_CACHE: RefCell<HashMap<usize, Rc<FftPlan>>> = RefCell::new(HashMap::new());
}

/// Fetch (or build) a thread-local cached plan for length `n`.
pub fn plan(n: usize) -> Rc<FftPlan> {
    PLAN_CACHE.with(|c| {
        c.borrow_mut()
            .entry(n)
            .or_insert_with(|| Rc::new(FftPlan::new(n)))
            .clone()
    })
}

/// Forward DFT of a real signal; returns the full complex spectrum.
pub fn rfft(x: &[f64]) -> Vec<C64> {
    let mut buf: Vec<C64> = x.iter().map(|&v| C64::real(v)).collect();
    plan(x.len()).forward(&mut buf);
    buf
}

/// Inverse DFT returning only the real parts (caller asserts the spectrum
/// is conjugate-symmetric, e.g. eigenvalues of a symmetric circulant).
pub fn irfft_real(spec: &[C64]) -> Vec<f64> {
    let mut buf = spec.to_vec();
    plan(spec.len()).inverse(&mut buf);
    buf.into_iter().map(|z| z.re).collect()
}

/// Multi-dimensional FFT over a dense row-major tensor of shape `shape`.
/// Transforms every axis in turn (`F = F_1 (x) ... (x) F_D`).
pub fn fftn(data: &mut [C64], shape: &[usize], inverse: bool) {
    let total: usize = shape.iter().product();
    assert_eq!(data.len(), total, "fftn: data/shape mismatch");
    let d = shape.len();
    // Strides for row-major layout.
    let mut strides = vec![1usize; d];
    for i in (0..d.saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    let mut scratch: Vec<C64> = Vec::new();
    for ax in 0..d {
        let n = shape[ax];
        if n == 1 {
            continue;
        }
        let p = plan(n);
        let stride = strides[ax];
        if stride != 1 {
            // Only strided axes gather into scratch; keeping the
            // contiguous (last-axis / 1-D) path allocation-free matters
            // because fftn sits inside CG iteration loops.
            scratch.resize(n, C64::ZERO);
        }
        // Iterate over all 1-D lines along axis `ax`.
        let outer: usize = shape[..ax].iter().product();
        let inner: usize = shape[ax + 1..].iter().product();
        for o in 0..outer {
            for i in 0..inner {
                let base = o * stride * n + i;
                if stride == 1 {
                    let line = &mut data[base..base + n];
                    if inverse {
                        p.inverse(line);
                    } else {
                        p.forward(line);
                    }
                } else {
                    for k in 0..n {
                        scratch[k] = data[base + k * stride];
                    }
                    if inverse {
                        p.inverse(&mut scratch);
                    } else {
                        p.forward(&mut scratch);
                    }
                    for k in 0..n {
                        data[base + k * stride] = scratch[k];
                    }
                }
            }
        }
    }
}

/// Reference O(n^2) DFT used by the tests.
#[doc(hidden)]
pub fn dft_naive(x: &[C64], inverse: bool) -> Vec<C64> {
    let n = x.len();
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut out = vec![C64::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        for (j, &v) in x.iter().enumerate() {
            *o += v * C64::cis(sign * 2.0 * std::f64::consts::PI * (j * k % n) as f64 / n as f64);
        }
    }
    if inverse {
        for v in out.iter_mut() {
            *v = v.scale(1.0 / n as f64);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: &[C64], b: &[C64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((*x - *y).abs() < tol, "{x:?} vs {y:?}");
        }
    }

    #[test]
    fn pow2_matches_naive() {
        for &n in &[1usize, 2, 4, 8, 64, 128] {
            let x: Vec<C64> = (0..n).map(|i| C64::new((i as f64).sin(), (i as f64 * 0.7).cos())).collect();
            let mut got = x.clone();
            plan(n).forward(&mut got);
            close(&got, &dft_naive(&x, false), 1e-9 * n as f64);
        }
    }

    #[test]
    fn bluestein_matches_naive() {
        for &n in &[3usize, 5, 6, 7, 12, 100, 255] {
            let x: Vec<C64> = (0..n).map(|i| C64::new((i as f64).cos(), (i as f64 * 1.3).sin())).collect();
            let mut got = x.clone();
            plan(n).forward(&mut got);
            close(&got, &dft_naive(&x, false), 1e-8 * n as f64);
        }
    }

    #[test]
    fn roundtrip() {
        for &n in &[8usize, 12, 31, 128, 1000] {
            let x: Vec<C64> = (0..n).map(|i| C64::new(i as f64, -(i as f64) * 0.5)).collect();
            let mut y = x.clone();
            let p = plan(n);
            p.forward(&mut y);
            p.inverse(&mut y);
            close(&y, &x, 1e-8 * n as f64);
        }
    }

    #[test]
    fn rfft_symmetric_input_gives_real_spectrum() {
        // Even (circularly symmetric) real input -> real spectrum.
        let n = 16;
        let mut x = vec![0.0f64; n];
        for i in 0..n {
            let d = i.min(n - i) as f64;
            x[i] = (-d * d / 8.0).exp();
        }
        let spec = rfft(&x);
        for z in &spec {
            assert!(z.im.abs() < 1e-10, "{z:?}");
        }
    }

    #[test]
    fn fftn_matches_axiswise_naive() {
        let shape = [3usize, 4, 5];
        let total: usize = shape.iter().product();
        let x: Vec<C64> = (0..total).map(|i| C64::new((i as f64).sin(), (i as f64).cos())).collect();
        let mut got = x.clone();
        fftn(&mut got, &shape, false);
        let mut want = x;
        // axis 2 (contiguous lines)
        for o in 0..12 {
            let line: Vec<C64> = want[o * 5..o * 5 + 5].to_vec();
            let f = dft_naive(&line, false);
            want[o * 5..o * 5 + 5].copy_from_slice(&f);
        }
        // axis 1
        for a in 0..3 {
            for c in 0..5 {
                let line: Vec<C64> = (0..4).map(|b| want[a * 20 + b * 5 + c]).collect();
                let f = dft_naive(&line, false);
                for b in 0..4 {
                    want[a * 20 + b * 5 + c] = f[b];
                }
            }
        }
        // axis 0
        for b in 0..4 {
            for c in 0..5 {
                let line: Vec<C64> = (0..3).map(|a| want[a * 20 + b * 5 + c]).collect();
                let f = dft_naive(&line, false);
                for a in 0..3 {
                    want[a * 20 + b * 5 + c] = f[a];
                }
            }
        }
        close(&got, &want, 1e-8);
    }

    #[test]
    fn fftn_roundtrip() {
        let shape = [4usize, 6];
        let total = 24;
        let x: Vec<C64> = (0..total).map(|i| C64::real(i as f64)).collect();
        let mut y = x.clone();
        fftn(&mut y, &shape, false);
        fftn(&mut y, &shape, true);
        close(&y, &x, 1e-9);
    }
}
