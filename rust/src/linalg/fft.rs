//! Fast Fourier transforms: iterative radix-2 Cooley–Tukey for power-of-two
//! lengths and Bluestein's chirp-z algorithm for arbitrary lengths, plus a
//! multi-dimensional transform over the axes of a dense tensor and a
//! **batched multi-RHS engine** for the structured MVMs that dominate CG
//! iterations.
//!
//! Circulant eigenvalue computations ([`crate::structure::circulant`]) need
//! FFTs at the *exact* grid size `m` (which users choose freely), hence the
//! Bluestein fallback; Toeplitz matrix–vector products are free to pad to
//! the next power of two and always hit the radix-2 path.
//!
//! [`FftPlan`] caches twiddle factors, the bit-reversal permutation, and
//! (for Bluestein) the transformed chirp, so repeated transforms of one
//! size — the common case inside CG iterations — do no trigonometry. The
//! thread-local plan cache is size-capped (FIFO eviction) so grid
//! auto-expansion and per-shard worker threads cannot grow it without
//! bound.
//!
//! The batched layer amortizes that per-transform setup across many lines:
//!
//! * [`FftPlan::forward_batch`] / [`FftPlan::inverse_batch`] transform a
//!   contiguous `[batch, n]` buffer reusing one twiddle/bit-reversal table
//!   (and, for Bluestein, one convolution scratch) across all lines.
//! * [`fftn_batch`] transforms a `[batch, shape...]` tensor; strided axes
//!   are processed in cache-blocked panels of adjacent lines instead of
//!   the per-line gather/scatter of [`fftn`], so the dominant cost becomes
//!   sequential memory traffic.
//! * [`apply_real_spectrum_batch`] applies a real diagonal spectrum to a
//!   block of real vectors. On even last-axis lengths it runs the **true
//!   real-input FFT** (rfft): each length-`n` real line is transformed
//!   through one length-`n/2` complex transform plus an O(n) untangle,
//!   and the conjugate-symmetric spectrum is kept in **half form**
//!   (`n/2 + 1` coefficients per line) through the remaining axes —
//!   halving transform *length*, not just transform *count*. Odd last
//!   axes fall back to the PR-4 two-for-one pairing (`z = x + i y`),
//!   which halves transform count instead.
//!
//! Both batched layers fan their work out over the in-tree thread pool
//! ([`crate::parallel`]): [`fftn_batch`] dispatches contiguous line
//! chunks and cache-blocked strided panels as pool tasks, and
//! [`apply_real_spectrum_batch`] splits its row block across workers,
//! each with a per-worker thread-local [`Workspace`]. Tasks perform
//! bit-identical arithmetic on disjoint slices, so results are
//! *identical* across thread counts; `MSGP_THREADS=1` (or a busy /
//! nested pool) degrades to the serial path. Cumulative dispatch and
//! rfft counters are exported for `/metrics` and the op-count tests
//! ([`parallel_panels_total`], [`rfft_half_lines_total`]).

use crate::parallel::{self, SendSlicePtr};

use super::complex::C64;
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

/// Round `n` up to the next power of two.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// A cached FFT plan for a fixed transform length.
#[derive(Debug)]
pub struct FftPlan {
    n: usize,
    /// Twiddles for the radix-2 kernel of size `work_len` (== `n` when `n`
    /// is a power of two, else the Bluestein convolution length).
    twiddles: Vec<C64>,
    /// Bit-reversal permutation for the radix-2 kernel (size `work_len`).
    bitrev: Vec<u32>,
    work_len: usize,
    /// Bluestein state: chirp `w_k = e^{-i pi k^2 / n}` and the forward
    /// FFT of the zero-padded conjugate chirp.
    bluestein: Option<BluesteinState>,
}

#[derive(Debug)]
struct BluesteinState {
    chirp: Vec<C64>,
    chirp_fft: Vec<C64>,
}

impl FftPlan {
    /// Build a plan for length-`n` transforms.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "FFT length must be >= 1");
        if n.is_power_of_two() {
            FftPlan {
                n,
                twiddles: make_twiddles(n),
                bitrev: make_bitrev(n),
                work_len: n,
                bluestein: None,
            }
        } else {
            let m = next_pow2(2 * n - 1);
            let twiddles = make_twiddles(m);
            let bitrev = make_bitrev(m);
            // chirp[k] = e^{-i pi k^2 / n}
            let mut chirp = vec![C64::ZERO; n];
            for k in 0..n {
                // Reduce k^2 mod 2n to keep the angle argument small and
                // the trigonometry accurate for large n.
                let k2 = (k * k) % (2 * n);
                chirp[k] = C64::cis(-std::f64::consts::PI * k2 as f64 / n as f64);
            }
            // b[k] = conj(chirp[|k|]) zero-padded to m, wrapped.
            let mut b = vec![C64::ZERO; m];
            b[0] = chirp[0].conj();
            for k in 1..n {
                b[k] = chirp[k].conj();
                b[m - k] = chirp[k].conj();
            }
            fft_pow2(&mut b, &twiddles, &bitrev, false);
            FftPlan {
                n,
                twiddles,
                bitrev,
                work_len: m,
                bluestein: Some(BluesteinState { chirp, chirp_fft: b }),
            }
        }
    }

    /// Transform length this plan was built for.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if the plan length is zero (never; kept for clippy symmetry).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// In-place forward DFT (no normalization): `X_k = sum_j x_j e^{-2 pi i jk/n}`.
    pub fn forward(&self, x: &mut [C64]) {
        self.transform(x, false)
    }

    /// In-place inverse DFT **with** `1/n` normalization.
    pub fn inverse(&self, x: &mut [C64]) {
        self.transform(x, true);
        let s = 1.0 / self.n as f64;
        for v in x.iter_mut() {
            *v = v.scale(s);
        }
    }

    /// Forward DFT of every contiguous length-`n` line of `data`
    /// (`data.len()` must be a multiple of `n`). One twiddle /
    /// bit-reversal table — and, on the Bluestein path, one convolution
    /// scratch — is reused across all lines.
    pub fn forward_batch(&self, data: &mut [C64]) {
        let mut blue = Vec::new();
        self.batch_transform(data, false, &mut blue);
    }

    /// Inverse DFT (with `1/n` normalization) of every contiguous
    /// length-`n` line of `data`.
    pub fn inverse_batch(&self, data: &mut [C64]) {
        let mut blue = Vec::new();
        self.batch_transform(data, true, &mut blue);
    }

    /// Batched kernel behind [`Self::forward_batch`] /
    /// [`Self::inverse_batch`], with a caller-owned Bluestein scratch so
    /// tight loops ([`fftn_batch`]) stay allocation-free.
    fn batch_transform(&self, data: &mut [C64], inverse: bool, blue: &mut Vec<C64>) {
        assert_eq!(
            data.len() % self.n,
            0,
            "batched FFT: buffer {} not a multiple of plan length {}",
            data.len(),
            self.n
        );
        match &self.bluestein {
            None => {
                for line in data.chunks_exact_mut(self.n) {
                    fft_pow2(line, &self.twiddles, &self.bitrev, inverse);
                }
            }
            Some(bs) => {
                blue.resize(self.work_len, C64::ZERO);
                for line in data.chunks_exact_mut(self.n) {
                    self.bluestein_with(line, bs, inverse, blue);
                }
            }
        }
        if inverse {
            let s = 1.0 / self.n as f64;
            for v in data.iter_mut() {
                *v = v.scale(s);
            }
        }
    }

    fn transform(&self, x: &mut [C64], inverse: bool) {
        assert_eq!(x.len(), self.n, "FFT length mismatch: plan {} vs input {}", self.n, x.len());
        match &self.bluestein {
            None => fft_pow2(x, &self.twiddles, &self.bitrev, inverse),
            Some(bs) => {
                let mut a = vec![C64::ZERO; self.work_len];
                self.bluestein_with(x, bs, inverse, &mut a);
            }
        }
    }

    /// Bluestein chirp-z transform of one line, using the caller's
    /// work-length scratch `a` (contents overwritten). The result is
    /// unnormalized; inverse normalization happens in the wrappers.
    fn bluestein_with(&self, x: &mut [C64], bs: &BluesteinState, inverse: bool, a: &mut [C64]) {
        let n = self.n;
        debug_assert_eq!(a.len(), self.work_len);
        // Inverse transform = conjugate trick: F^{-1}(x) * n = conj(F(conj(x))).
        if inverse {
            for v in x.iter_mut() {
                *v = v.conj();
            }
        }
        a.fill(C64::ZERO);
        for k in 0..n {
            a[k] = x[k] * bs.chirp[k];
        }
        fft_pow2(a, &self.twiddles, &self.bitrev, false);
        for (av, bv) in a.iter_mut().zip(bs.chirp_fft.iter()) {
            *av = *av * *bv;
        }
        fft_pow2(a, &self.twiddles, &self.bitrev, true);
        let s = 1.0 / self.work_len as f64;
        for k in 0..n {
            x[k] = a[k].scale(s) * bs.chirp[k];
        }
        if inverse {
            for v in x.iter_mut() {
                *v = v.conj();
            }
        }
    }
}

fn make_twiddles(n: usize) -> Vec<C64> {
    // Twiddles for the forward transform, one per element of the half-size
    // butterfly at the largest stage; stages reuse strided prefixes.
    let half = n / 2;
    let mut tw = Vec::with_capacity(half.max(1));
    for k in 0..half.max(1) {
        tw.push(C64::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64));
    }
    tw
}

/// Bit-reversal permutation table for a power-of-two length `n`
/// (`u32` halves the table footprint; every supported length fits).
fn make_bitrev(n: usize) -> Vec<u32> {
    debug_assert!(n.is_power_of_two());
    let mut br = vec![0u32; n];
    for i in 1..n {
        br[i] = br[i >> 1] >> 1 | if i & 1 == 1 { (n >> 1) as u32 } else { 0 };
    }
    br
}

/// Iterative radix-2 Cooley–Tukey, `x.len()` must be a power of two.
/// `twiddles` / `bitrev` must be the tables for exactly this length.
fn fft_pow2(x: &mut [C64], twiddles: &[C64], bitrev: &[u32], inverse: bool) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    debug_assert_eq!(bitrev.len(), n);
    if n <= 1 {
        return;
    }
    // Bit-reversal permutation (table-driven; the table is built once per
    // plan and shared by every line of a batch).
    for i in 0..n {
        let j = bitrev[i] as usize;
        if i < j {
            x.swap(i, j);
        }
    }
    // Butterflies. Twiddle for stage of length `len` at position k is
    // twiddles[k * (n/len)] (stride-decimated main table).
    let mut len = 2;
    while len <= n {
        let stride = n / len;
        let half = len / 2;
        for start in (0..n).step_by(len) {
            for k in 0..half {
                let mut w = twiddles[k * stride];
                if inverse {
                    w = w.conj();
                }
                let u = x[start + k];
                let v = x[start + k + half] * w;
                x[start + k] = u + v;
                x[start + k + half] = u - v;
            }
        }
        len <<= 1;
    }
}

/// Per-thread plan-cache capacity. One plan per distinct transform
/// length; grid auto-expansion and per-shard worker threads request new
/// lengths over time, so the cache evicts FIFO beyond this cap instead
/// of growing without bound. Evicted plans stay alive for as long as a
/// caller still holds their `Rc`.
const PLAN_CACHE_CAP: usize = 64;

thread_local! {
    static PLAN_CACHE: RefCell<(HashMap<usize, Rc<FftPlan>>, VecDeque<usize>)> =
        RefCell::new((HashMap::new(), VecDeque::new()));
}

/// Fetch (or build) a thread-local cached plan for length `n`.
pub fn plan(n: usize) -> Rc<FftPlan> {
    PLAN_CACHE.with(|c| {
        let mut guard = c.borrow_mut();
        let (map, order) = &mut *guard;
        if let Some(p) = map.get(&n) {
            return p.clone();
        }
        if map.len() >= PLAN_CACHE_CAP {
            if let Some(old) = order.pop_front() {
                map.remove(&old);
            }
        }
        let p = Rc::new(FftPlan::new(n));
        map.insert(n, p.clone());
        order.push_back(n);
        p
    })
}

/// Number of plans currently held by this thread's cache (test hook for
/// the size cap).
#[doc(hidden)]
pub fn plan_cache_len() -> usize {
    PLAN_CACHE.with(|c| c.borrow().0.len())
}

/// Minimum buffer size (complex elements per axis pass, or f64 elements
/// per real block) before the batched kernels fan out over the thread
/// pool — below this the dispatch overhead exceeds the transform work.
const PAR_MIN_ELEMS: usize = 4096;

/// Cumulative parallel task-chunks (line chunks + strided panels + row
/// blocks) dispatched onto the pool by the batched engine. Exported at
/// `/metrics` as `fft_parallel_panels_total`.
static FFT_PARALLEL_PANELS: AtomicU64 = AtomicU64::new(0);

/// Cumulative length-`n/2` half transforms performed by the rfft path
/// (forward + inverse). The op-count tests pin that the half-spectrum
/// route really runs half-length last-axis transforms.
static RFFT_HALF_LINES: AtomicU64 = AtomicU64::new(0);

/// Total parallel task-chunks dispatched by the batched FFT engine.
pub fn parallel_panels_total() -> u64 {
    FFT_PARALLEL_PANELS.load(Ordering::Relaxed)
}

/// Total half-length line transforms performed by the rfft path.
pub fn rfft_half_lines_total() -> u64 {
    RFFT_HALF_LINES.load(Ordering::Relaxed)
}

/// Task budget for a parallel region: a couple of chunks per thread
/// bounds the claim-queue contention while still smoothing load
/// imbalance ([`parallel::for_each_range`] clamps to the item count).
fn par_tasks() -> usize {
    parallel::threads() * 2
}

thread_local! {
    /// Per-worker gather/Bluestein scratch for pool tasks dispatched by
    /// [`fftn_batch`] / [`apply_axis_spectrum_packed`]. Distinct from
    /// any caller-owned scratch, so a submitter that participates in its
    /// own region never aliases the workspace it already borrows.
    static PAR_SCRATCH: RefCell<FftScratch> = RefCell::new(FftScratch::default());
    /// Per-worker full workspace for pool tasks dispatched by
    /// [`apply_real_spectrum_batch`] (each row chunk runs the whole
    /// serial kernel).
    static PAR_WS: RefCell<Workspace> = RefCell::new(Workspace::default());
}

fn with_par_scratch<R>(f: impl FnOnce(&mut FftScratch) -> R) -> R {
    PAR_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

fn with_par_workspace<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    PAR_WS.with(|s| f(&mut s.borrow_mut()))
}

/// Cached state for the true real-input FFT of an even length `n`: the
/// length-`n/2` complex plan plus the untangling twiddles
/// `w_k = e^{-2 pi i k / n}`, `k in 0..=n/2`. A length-`n` real line is
/// transformed by packing even/odd samples into one length-`n/2` complex
/// line, transforming, and untangling into the `n/2 + 1` coefficients of
/// the conjugate-symmetric half spectrum.
#[derive(Debug)]
pub struct RfftPlan {
    n: usize,
    /// Length-`n/2` complex plan shared with the main plan cache.
    half: Rc<FftPlan>,
    /// `e^{-2 pi i k / n}` for `k in 0..=n/2`.
    tw: Vec<C64>,
}

impl RfftPlan {
    /// Real transform length this plan was built for (even, >= 2).
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if the plan length is zero (never; kept for clippy symmetry).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

thread_local! {
    static RFFT_CACHE: RefCell<(HashMap<usize, Rc<RfftPlan>>, VecDeque<usize>)> =
        RefCell::new((HashMap::new(), VecDeque::new()));
}

/// Fetch (or build) a thread-local cached rfft plan for the even length
/// `n` (size-capped FIFO cache, like [`plan`]).
pub fn rfft_plan(n: usize) -> Rc<RfftPlan> {
    assert!(n >= 2 && n % 2 == 0, "rfft length must be even and >= 2, got {n}");
    RFFT_CACHE.with(|c| {
        let mut guard = c.borrow_mut();
        let (map, order) = &mut *guard;
        if let Some(p) = map.get(&n) {
            return p.clone();
        }
        if map.len() >= PLAN_CACHE_CAP {
            if let Some(old) = order.pop_front() {
                map.remove(&old);
            }
        }
        let m2 = n / 2;
        let tw = (0..=m2)
            .map(|k| C64::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64))
            .collect();
        let p = Rc::new(RfftPlan { n, half: plan(m2), tw });
        map.insert(n, p.clone());
        order.push_back(n);
        p
    })
}

/// Forward DFT of a real signal; returns the full complex spectrum.
pub fn rfft(x: &[f64]) -> Vec<C64> {
    let mut buf: Vec<C64> = x.iter().map(|&v| C64::real(v)).collect();
    plan(x.len()).forward(&mut buf);
    buf
}

/// Inverse DFT returning only the real parts (caller asserts the spectrum
/// is conjugate-symmetric, e.g. eigenvalues of a symmetric circulant).
pub fn irfft_real(spec: &[C64]) -> Vec<f64> {
    let mut buf = spec.to_vec();
    plan(spec.len()).inverse(&mut buf);
    buf.into_iter().map(|z| z.re).collect()
}

/// Multi-dimensional FFT over a dense row-major tensor of shape `shape`.
/// Transforms every axis in turn (`F = F_1 (x) ... (x) F_D`).
///
/// This is the single-tensor reference path; the batched engine
/// ([`fftn_batch`]) additionally amortizes plan setup across lines and
/// replaces the per-line gather/scatter below with cache-blocked panels.
pub fn fftn(data: &mut [C64], shape: &[usize], inverse: bool) {
    let total: usize = shape.iter().product();
    assert_eq!(data.len(), total, "fftn: data/shape mismatch");
    let d = shape.len();
    // Strides for row-major layout.
    let mut strides = vec![1usize; d];
    for i in (0..d.saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    let mut scratch: Vec<C64> = Vec::new();
    for ax in 0..d {
        let n = shape[ax];
        if n == 1 {
            continue;
        }
        let p = plan(n);
        let stride = strides[ax];
        if stride != 1 {
            // Only strided axes gather into scratch; keeping the
            // contiguous (last-axis / 1-D) path allocation-free matters
            // because fftn sits inside CG iteration loops.
            scratch.resize(n, C64::ZERO);
        }
        // Iterate over all 1-D lines along axis `ax`.
        let outer: usize = shape[..ax].iter().product();
        let inner: usize = shape[ax + 1..].iter().product();
        for o in 0..outer {
            for i in 0..inner {
                let base = o * stride * n + i;
                if stride == 1 {
                    let line = &mut data[base..base + n];
                    if inverse {
                        p.inverse(line);
                    } else {
                        p.forward(line);
                    }
                } else {
                    for k in 0..n {
                        scratch[k] = data[base + k * stride];
                    }
                    if inverse {
                        p.inverse(&mut scratch);
                    } else {
                        p.forward(&mut scratch);
                    }
                    for k in 0..n {
                        data[base + k * stride] = scratch[k];
                    }
                }
            }
        }
    }
}

/// Gather / Bluestein scratch for the batched transforms. Reusing one
/// across calls keeps the batched hot paths allocation-free.
#[derive(Clone, Debug, Default)]
pub struct FftScratch {
    /// Cache-blocked panel of gathered lines (strided axes).
    panel: Vec<C64>,
    /// Bluestein convolution buffer (non-power-of-two lengths).
    blue: Vec<C64>,
}

/// Number of adjacent lines gathered per panel on strided axes: small
/// enough that a panel of the longest supported lines stays cache-
/// resident, large enough that gathers read whole cache lines.
const PANEL: usize = 8;

/// Multi-dimensional FFT of `batch` independent row-major tensors stored
/// contiguously (`data.len() == batch * prod(shape)`). The batch axis is
/// never transformed. Strided axes are processed in cache-blocked panels
/// of [`PANEL`] adjacent lines — the gather then reads contiguous runs
/// instead of one element per stride — and every line of an axis shares
/// one plan (twiddles, bit-reversal table, Bluestein scratch).
///
/// Large buffers fan each axis pass out over the thread pool
/// ([`crate::parallel`]): contiguous-line chunks and strided panels are
/// independent transforms over disjoint elements, so the parallel result
/// is bit-identical to the serial one. With one thread (or a busy /
/// nested pool) the serial path below runs unchanged.
// lint:hot
pub fn fftn_batch(
    data: &mut [C64],
    batch: usize,
    shape: &[usize],
    inverse: bool,
    scratch: &mut FftScratch,
) {
    let _sp = crate::span!("fft.fftn_batch");
    fftn_batch_axes(data, batch, shape, shape.len(), inverse, scratch)
}

/// [`fftn_batch`] over only the first `upto` axes of each tensor — the
/// rfft half-spectrum pipeline transforms the leading axes of the half
/// tensor with this and handles the (half-length) last axis itself.
// lint:hot
fn fftn_batch_axes(
    data: &mut [C64],
    batch: usize,
    shape: &[usize],
    upto: usize,
    inverse: bool,
    scratch: &mut FftScratch,
) {
    let per: usize = shape.iter().product();
    assert_eq!(data.len(), batch * per, "fftn_batch: data/shape mismatch");
    for ax in 0..upto {
        let n = shape[ax];
        if n == 1 {
            continue;
        }
        let p = plan(n);
        let inner: usize = shape[ax + 1..].iter().product();
        if inner == 1 {
            // Contiguous lines tile the whole buffer.
            let total_lines = data.len() / n;
            if total_lines >= 2 && data.len() >= PAR_MIN_ELEMS && parallel::available() {
                let ptr = SendSlicePtr::new(data);
                let p_ref: &FftPlan = &p;
                let fanned = parallel::for_each_range(total_lines, par_tasks(), &|r| {
                    // SAFETY: line ranges are disjoint across tasks and
                    // in bounds; the region completes before `data`'s
                    // borrow ends.
                    let lines = unsafe { ptr.range(r.start * n..r.end * n) };
                    with_par_scratch(|sc| p_ref.batch_transform(lines, inverse, &mut sc.blue));
                });
                FFT_PARALLEL_PANELS.fetch_add(fanned as u64, Ordering::Relaxed);
            } else {
                p.batch_transform(data, inverse, &mut scratch.blue);
            }
            continue;
        }
        let outer: usize = batch * shape[..ax].iter().product::<usize>();
        // Panels tile the (outer x inner) line grid; panels are disjoint
        // element sets even within one outer group, so they parallelize
        // directly.
        let ppo = inner.div_ceil(PANEL);
        let total_panels = outer * ppo;
        if total_panels >= 2 && data.len() >= PAR_MIN_ELEMS && parallel::available() {
            let ptr = SendSlicePtr::new(data);
            let p_ref: &FftPlan = &p;
            let fanned = parallel::for_each_range(total_panels, par_tasks(), &|r| {
                with_par_scratch(|sc| {
                    sc.panel.resize(PANEL * n, C64::ZERO);
                    for t in r {
                        let o = t / ppo;
                        let i0 = (t % ppo) * PANEL;
                        let pw = PANEL.min(inner - i0);
                        let base = o * n * inner + i0;
                        for k in 0..n {
                            let src = base + k * inner;
                            for q in 0..pw {
                                // SAFETY: each (o, i0) panel reads and
                                // writes a distinct element set.
                                sc.panel[q * n + k] = unsafe { ptr.read(src + q) };
                            }
                        }
                        p_ref.batch_transform(&mut sc.panel[..pw * n], inverse, &mut sc.blue);
                        for k in 0..n {
                            let dst = base + k * inner;
                            for q in 0..pw {
                                // SAFETY: as above — disjoint panels.
                                unsafe { ptr.write(dst + q, sc.panel[q * n + k]) };
                            }
                        }
                    }
                });
            });
            FFT_PARALLEL_PANELS.fetch_add(fanned as u64, Ordering::Relaxed);
            continue;
        }
        scratch.panel.resize(PANEL * n, C64::ZERO);
        for o in 0..outer {
            let base_o = o * n * inner;
            let mut i0 = 0;
            while i0 < inner {
                let pw = PANEL.min(inner - i0);
                // Gather `pw` adjacent lines: contiguous reads of `pw`
                // elements per grid row, sequential writes per line.
                for k in 0..n {
                    let src = base_o + k * inner + i0;
                    for q in 0..pw {
                        scratch.panel[q * n + k] = data[src + q];
                    }
                }
                p.batch_transform(&mut scratch.panel[..pw * n], inverse, &mut scratch.blue);
                for k in 0..n {
                    let dst = base_o + k * inner + i0;
                    for q in 0..pw {
                        data[dst + q] = scratch.panel[q * n + k];
                    }
                }
                i0 += pw;
            }
        }
    }
}

/// Reusable buffers for the batched real-MVM engine: the packed complex
/// lines (two-for-one pairs, or the rfft path's half-length even/odd
/// packing), the half-spectrum tensor, and FFT gather scratch. One
/// `Workspace` per solver / trainer keeps every structured
/// `matvec_batch` allocation-free; pool workers keep their own in TLS.
#[derive(Clone, Debug, Default)]
pub struct Workspace {
    /// Packed complex lines: two-for-one pairs (`ceil(b/2) x m`) on the
    /// pair path, even/odd-packed half lines (`lines x n/2`) on the
    /// rfft path.
    pub(crate) packed: Vec<C64>,
    /// Gather / Bluestein scratch shared by the batched transforms.
    pub(crate) scratch: FftScratch,
    /// Half-spectrum tensor (`lines x (n/2 + 1)`) for the rfft path.
    pub(crate) half: Vec<C64>,
}

impl Workspace {
    /// Fresh (empty) workspace; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }
}

thread_local! {
    static TLS_WS: RefCell<Workspace> = RefCell::new(Workspace::default());
}

/// Run `f` with this thread's shared [`Workspace`] — the compatibility
/// shim that lets the single-vector `matvec` wrappers reuse the batched
/// engine without allocating scratch per call. Callers must not call
/// [`with_workspace`] re-entrantly from inside `f` (the structured-MVM
/// wrappers never do: only leaf `*_batch` kernels run under it).
pub fn with_workspace<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    TLS_WS.with(|w| f(&mut w.borrow_mut()))
}

/// Pack the rows of a real `rows x m` block into `ceil(rows/2)` complex
/// lines: line `j` is `row_{2j} + i row_{2j+1}` (imaginary part zero for
/// the unpaired last row of an odd block).
pub fn pack_real_pairs(block: &[f64], m: usize, out: &mut Vec<C64>) {
    assert!(m > 0 && block.len() % m == 0, "pack_real_pairs: block/m mismatch");
    let rows = block.len() / m;
    let pairs = rows.div_ceil(2);
    out.clear();
    out.resize(pairs * m, C64::ZERO);
    for j in 0..pairs {
        let re = &block[2 * j * m..(2 * j + 1) * m];
        let line = &mut out[j * m..(j + 1) * m];
        if 2 * j + 1 < rows {
            let im = &block[(2 * j + 1) * m..(2 * j + 2) * m];
            for k in 0..m {
                line[k] = C64::new(re[k], im[k]);
            }
        } else {
            for k in 0..m {
                line[k] = C64::real(re[k]);
            }
        }
    }
}

/// Inverse of [`pack_real_pairs`] after real-linear processing: row `2j`
/// is the real part of line `j`, row `2j+1` the imaginary part.
pub fn unpack_real_pairs(packed: &[C64], m: usize, rows: usize, out: &mut [f64]) {
    assert_eq!(out.len(), rows * m, "unpack_real_pairs: out/rows mismatch");
    let pairs = rows.div_ceil(2);
    assert_eq!(packed.len(), pairs * m, "unpack_real_pairs: packed/rows mismatch");
    for j in 0..pairs {
        let line = &packed[j * m..(j + 1) * m];
        for k in 0..m {
            out[2 * j * m + k] = line[k].re;
        }
        if 2 * j + 1 < rows {
            for k in 0..m {
                out[(2 * j + 1) * m + k] = line[k].im;
            }
        }
    }
}

/// Split the forward spectrum `z` of a packed pair `x + i y` (`x`, `y`
/// real) into the individual spectra, using conjugate symmetry:
/// `X_k = (Z_k + conj(Z_{-k})) / 2`, `Y_k = -i (Z_k - conj(Z_{-k})) / 2`
/// (indices mod `n`). Used by the tests to pin the two-for-one packing
/// and available to callers that need the separate spectra.
pub fn split_packed_spectrum(z: &[C64], x_spec: &mut [C64], y_spec: &mut [C64]) {
    let n = z.len();
    assert_eq!(x_spec.len(), n);
    assert_eq!(y_spec.len(), n);
    for k in 0..n {
        let zk = z[k];
        let zr = z[(n - k) % n].conj();
        x_spec[k] = (zk + zr).scale(0.5);
        let d = zk - zr;
        y_spec[k] = C64::new(d.im * 0.5, -d.re * 0.5);
    }
}

/// Apply a real diagonal spectrum (in the multi-dimensional Fourier
/// basis over `shape`) to every row of a real `b x m` block:
/// `out_r = F^{-1} diag(f(spec)) F block_r`. This one kernel powers the
/// circulant, BCCB, separable square-root, and spectral-preconditioner
/// `matvec_batch` paths.
///
/// Route selection (both exact; the spectra here come from symmetric
/// kernels, so they are conjugate-even and the operator is real):
///
/// * **even last axis** — the true rfft: each real line runs one
///   length-`n/2` complex transform plus an O(n) untangle, and the
///   remaining axes transform the **half-form** spectrum tensor
///   (`n/2 + 1` last-axis coefficients), halving transform *length*.
///   This also speeds up single-vector (`rows == 1`) applies, which the
///   pairing below cannot.
/// * **odd last axis** — the PR-4 two-for-one pairing (`z = x + i y`):
///   a real spectrum commutes with the packing, halving transform
///   *count* across the batch.
///
/// Multi-row blocks additionally split across the thread pool
/// ([`crate::parallel`]), each worker running the serial kernel on its
/// row chunk with a per-worker thread-local [`Workspace`]. Rows are
/// independent on the rfft path, so results are bit-identical across
/// thread counts (the pair path chunks on pair boundaries for the same
/// guarantee).
// lint:hot
pub fn apply_real_spectrum_batch<F: Fn(f64) -> f64 + Sync>(
    block: &[f64],
    out: &mut [f64],
    shape: &[usize],
    spec: &[f64],
    f: F,
    ws: &mut Workspace,
) {
    let _sp = crate::span!("fft.real_spectrum_batch");
    let m: usize = shape.iter().product();
    assert_eq!(spec.len(), m, "spectrum length vs shape");
    assert!(m > 0 && block.len() % m == 0, "block is b x m row-major");
    assert_eq!(out.len(), block.len());
    let rows = block.len() / m;
    let n_last = *shape.last().expect("non-empty shape");
    let use_rfft = n_last % 2 == 0 && n_last >= 2;
    // Row-chunk units: single rows on the rfft path, whole pairs on the
    // pair path (so chunking never splits a packed pair).
    let unit = if use_rfft { 1 } else { 2 };
    let units = rows.div_ceil(unit);
    if units >= 2 && block.len() >= PAR_MIN_ELEMS && parallel::available() {
        let out_ptr = SendSlicePtr::new(out);
        let f_ref = &f;
        let fanned = parallel::for_each_range(units, par_tasks(), &|r| {
            let r0 = r.start * unit;
            let r1 = (r.end * unit).min(rows);
            // SAFETY: row ranges are disjoint across tasks and the
            // region completes before `out`'s borrow ends.
            let ob = unsafe { out_ptr.range(r0 * m..r1 * m) };
            with_par_workspace(|pws| {
                apply_real_spectrum_serial(
                    &block[r0 * m..r1 * m],
                    ob,
                    shape,
                    spec,
                    f_ref,
                    use_rfft,
                    pws,
                )
            });
        });
        FFT_PARALLEL_PANELS.fetch_add(fanned as u64, Ordering::Relaxed);
        return;
    }
    apply_real_spectrum_serial(block, out, shape, spec, &f, use_rfft, ws);
}

/// Serial kernel behind [`apply_real_spectrum_batch`] (also the per-task
/// body of its parallel row split).
// lint:hot
fn apply_real_spectrum_serial<F: Fn(f64) -> f64>(
    block: &[f64],
    out: &mut [f64],
    shape: &[usize],
    spec: &[f64],
    f: &F,
    use_rfft: bool,
    ws: &mut Workspace,
) {
    if use_rfft {
        apply_real_spectrum_rfft(block, out, shape, spec, f, ws);
        return;
    }
    let m: usize = shape.iter().product();
    let rows = block.len() / m;
    let pairs = rows.div_ceil(2);
    let Workspace { packed, scratch, .. } = ws;
    pack_real_pairs(block, m, packed);
    fftn_batch(packed, pairs, shape, false, scratch);
    for line in packed.chunks_exact_mut(m) {
        for (z, &e) in line.iter_mut().zip(spec) {
            *z = z.scale(f(e));
        }
    }
    fftn_batch(packed, pairs, shape, true, scratch);
    unpack_real_pairs(packed, m, rows, out);
}

/// The true real-input route of [`apply_real_spectrum_batch`] (even last
/// axis `n`): forward rfft every length-`n` line through one
/// length-`n/2` transform + untangle, transform the leading axes of the
/// resulting **half tensor** (`n/2 + 1` last-axis coefficients), scale
/// by the half-form spectrum, and invert the pipeline. Exactness rests
/// on the conjugate-even symmetry of both the real input and the
/// (symmetric-kernel) spectrum.
// lint:hot
fn apply_real_spectrum_rfft<F: Fn(f64) -> f64>(
    block: &[f64],
    out: &mut [f64],
    shape: &[usize],
    spec: &[f64],
    f: &F,
    ws: &mut Workspace,
) {
    let d = shape.len();
    let n = shape[d - 1];
    let m: usize = shape.iter().product();
    let rows = block.len() / m;
    let m2 = n / 2;
    let h = m2 + 1;
    let rest = m / n;
    let lines = rows * rest;
    let rp = rfft_plan(n);
    let Workspace { packed, scratch, half } = ws;
    // --- forward rfft per line: even/odd pack, half transform, untangle ---
    packed.clear();
    packed.resize(lines * m2, C64::ZERO);
    for (l, line) in block.chunks_exact(n).enumerate() {
        let z = &mut packed[l * m2..(l + 1) * m2];
        for (j, zj) in z.iter_mut().enumerate() {
            *zj = C64::new(line[2 * j], line[2 * j + 1]);
        }
    }
    rp.half.batch_transform(packed, false, &mut scratch.blue);
    RFFT_HALF_LINES.fetch_add(lines as u64, Ordering::Relaxed);
    half.clear();
    half.resize(lines * h, C64::ZERO);
    for l in 0..lines {
        let z = &packed[l * m2..(l + 1) * m2];
        let x = &mut half[l * h..(l + 1) * h];
        for (k, xk) in x.iter_mut().enumerate() {
            // E_k = (Z_k + conj(Z_{-k})) / 2, O_k = -i (Z_k - conj(Z_{-k})) / 2,
            // X_k = E_k + w^k O_k (indices mod n/2; k = n/2 wraps to 0).
            let zk = z[k % m2];
            let zmk = z[(m2 - k) % m2].conj();
            let e = (zk + zmk).scale(0.5);
            let dd = zk - zmk;
            let o = C64::new(dd.im * 0.5, -dd.re * 0.5);
            *xk = e + rp.tw[k] * o;
        }
    }
    // --- leading axes transform the half tensor ---
    // Half-form shape in a stack buffer: this runs once per structured
    // MVM, and the grid rank never approaches the cap.
    assert!(d <= 16, "tensor rank exceeds the rfft stack shape buffer");
    let mut shape_h_buf = [0usize; 16];
    shape_h_buf[..d].copy_from_slice(shape);
    shape_h_buf[d - 1] = h;
    let shape_h = &shape_h_buf[..d];
    fftn_batch_axes(half, rows, shape_h, d - 1, false, scratch);
    // --- diagonal scale in half form: spec index (rest, k), k <= n/2 ---
    for row in half.chunks_exact_mut(rest * h) {
        for (r_idx, line) in row.chunks_exact_mut(h).enumerate() {
            let sline = &spec[r_idx * n..r_idx * n + h];
            for (z, &e) in line.iter_mut().zip(sline) {
                *z = z.scale(f(e));
            }
        }
    }
    // --- inverse: leading axes, then inverse rfft per line ---
    fftn_batch_axes(half, rows, shape_h, d - 1, true, scratch);
    for l in 0..lines {
        let x = &half[l * h..(l + 1) * h];
        let z = &mut packed[l * m2..(l + 1) * m2];
        for (k, zk) in z.iter_mut().enumerate() {
            // E_k = (X_k + conj(X_{n/2 - k})) / 2,
            // w^k O_k = (X_k - conj(X_{n/2 - k})) / 2, Z_k = E_k + i O_k.
            let a = x[k];
            let b = x[m2 - k].conj();
            let e = (a + b).scale(0.5);
            let wo = (a - b).scale(0.5);
            let o = rp.tw[k].conj() * wo;
            *zk = C64::new(e.re - o.im, e.im + o.re);
        }
    }
    // The half-length inverse's 1/(n/2) normalization is exactly the
    // packed signal's: no further scaling by 2.
    rp.half.batch_transform(packed, true, &mut scratch.blue);
    RFFT_HALF_LINES.fetch_add(lines as u64, Ordering::Relaxed);
    for (l, oline) in out.chunks_exact_mut(n).enumerate() {
        let z = &packed[l * m2..(l + 1) * m2];
        for (j, &zj) in z.iter().enumerate() {
            oline[2 * j] = zj.re;
            oline[2 * j + 1] = zj.im;
        }
    }
}

/// Apply a real 1-D spectrum along one axis of a batch of packed complex
/// tensors, zero-padding every line from `n` to `spec.len()` (the
/// circulant-embedding length) and truncating back after the inverse
/// transform — the batched kernel behind the exact Toeplitz and
/// Kronecker-of-Toeplitz MVMs. `outer` counts line groups before the
/// axis (batch folded in), `inner` is the trailing stride.
// lint:hot
pub(crate) fn apply_axis_spectrum_packed(
    data: &mut [C64],
    outer: usize,
    n: usize,
    inner: usize,
    spec: &[f64],
    scratch: &mut FftScratch,
) {
    let a = spec.len();
    assert!(a >= n, "embedding {a} shorter than axis {n}");
    let p = plan(a);
    if inner == 1 {
        // Contiguous lines: whole line groups are disjoint slices, so
        // group chunks fan out over the pool directly.
        if outer >= 2 && data.len() >= PAR_MIN_ELEMS && parallel::available() {
            let ptr = SendSlicePtr::new(data);
            let p_ref: &FftPlan = &p;
            let fanned = parallel::for_each_range(outer, par_tasks(), &|r| {
                // SAFETY: group ranges are disjoint across tasks.
                let lines = unsafe { ptr.range(r.start * n..r.end * n) };
                with_par_scratch(|sc| {
                    axis_spectrum_contiguous(lines, r.end - r.start, n, p_ref, spec, sc)
                });
            });
            FFT_PARALLEL_PANELS.fetch_add(fanned as u64, Ordering::Relaxed);
        } else {
            axis_spectrum_contiguous(data, outer, n, &p, spec, scratch);
        }
        return;
    }
    // Strided axis: (outer x panel) grid of disjoint cache-blocked
    // panels, parallelized exactly like the fftn_batch strided pass.
    let ppo = inner.div_ceil(PANEL);
    let total_panels = outer * ppo;
    if total_panels >= 2 && data.len() >= PAR_MIN_ELEMS && parallel::available() {
        let ptr = SendSlicePtr::new(data);
        let p_ref: &FftPlan = &p;
        let fanned = parallel::for_each_range(total_panels, par_tasks(), &|r| {
            with_par_scratch(|sc| {
                sc.panel.resize(PANEL * a, C64::ZERO);
                for t in r {
                    let o = t / ppo;
                    let i0 = (t % ppo) * PANEL;
                    let pw = PANEL.min(inner - i0);
                    let base = o * n * inner + i0;
                    for q in 0..pw {
                        sc.panel[q * a + n..(q + 1) * a].fill(C64::ZERO);
                    }
                    for k in 0..n {
                        let src = base + k * inner;
                        for q in 0..pw {
                            // SAFETY: disjoint panels (see fftn_batch).
                            sc.panel[q * a + k] = unsafe { ptr.read(src + q) };
                        }
                    }
                    spectrum_lines(&mut sc.panel[..pw * a], p_ref, spec, &mut sc.blue);
                    for k in 0..n {
                        let dst = base + k * inner;
                        for q in 0..pw {
                            // SAFETY: disjoint panels.
                            unsafe { ptr.write(dst + q, sc.panel[q * a + k]) };
                        }
                    }
                }
            });
        });
        FFT_PARALLEL_PANELS.fetch_add(fanned as u64, Ordering::Relaxed);
        return;
    }
    scratch.panel.resize(PANEL * a, C64::ZERO);
    for o in 0..outer {
        let base_o = o * n * inner;
        let mut i0 = 0;
        while i0 < inner {
            let pw = PANEL.min(inner - i0);
            for q in 0..pw {
                scratch.panel[q * a + n..(q + 1) * a].fill(C64::ZERO);
            }
            for k in 0..n {
                let src = base_o + k * inner + i0;
                for q in 0..pw {
                    scratch.panel[q * a + k] = data[src + q];
                }
            }
            spectrum_lines(&mut scratch.panel[..pw * a], &p, spec, &mut scratch.blue);
            for k in 0..n {
                let dst = base_o + k * inner + i0;
                for q in 0..pw {
                    data[dst + q] = scratch.panel[q * a + k];
                }
            }
            i0 += pw;
        }
    }
}

/// Serial contiguous-group kernel of [`apply_axis_spectrum_packed`]
/// (`inner == 1`): zero-pad each length-`n` line to the embedding length
/// in cache-blocked panels, transform-scale-invert, truncate back.
// lint:hot
fn axis_spectrum_contiguous(
    data: &mut [C64],
    groups: usize,
    n: usize,
    p: &FftPlan,
    spec: &[f64],
    scratch: &mut FftScratch,
) {
    let a = spec.len();
    scratch.panel.resize(PANEL * a, C64::ZERO);
    let mut o0 = 0;
    while o0 < groups {
        let pw = PANEL.min(groups - o0);
        for q in 0..pw {
            let line = &data[(o0 + q) * n..(o0 + q + 1) * n];
            scratch.panel[q * a..q * a + n].copy_from_slice(line);
            scratch.panel[q * a + n..(q + 1) * a].fill(C64::ZERO);
        }
        spectrum_lines(&mut scratch.panel[..pw * a], p, spec, &mut scratch.blue);
        for q in 0..pw {
            data[(o0 + q) * n..(o0 + q + 1) * n].copy_from_slice(&scratch.panel[q * a..q * a + n]);
        }
        o0 += pw;
    }
}

/// Forward-transform, scale by `spec`, and inverse-transform every
/// contiguous `spec.len()`-line of `lines` with one plan.
fn spectrum_lines(lines: &mut [C64], p: &FftPlan, spec: &[f64], blue: &mut Vec<C64>) {
    p.batch_transform(lines, false, blue);
    for line in lines.chunks_exact_mut(spec.len()) {
        for (z, &e) in line.iter_mut().zip(spec) {
            *z = z.scale(e);
        }
    }
    p.batch_transform(lines, true, blue);
}

/// Reference O(n^2) DFT used by the tests.
#[doc(hidden)]
pub fn dft_naive(x: &[C64], inverse: bool) -> Vec<C64> {
    let n = x.len();
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut out = vec![C64::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        for (j, &v) in x.iter().enumerate() {
            *o += v * C64::cis(sign * 2.0 * std::f64::consts::PI * (j * k % n) as f64 / n as f64);
        }
    }
    if inverse {
        for v in out.iter_mut() {
            *v = v.scale(1.0 / n as f64);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: &[C64], b: &[C64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((*x - *y).abs() < tol, "{x:?} vs {y:?}");
        }
    }

    #[test]
    fn pow2_matches_naive() {
        for &n in &[1usize, 2, 4, 8, 64, 128] {
            let x: Vec<C64> = (0..n).map(|i| C64::new((i as f64).sin(), (i as f64 * 0.7).cos())).collect();
            let mut got = x.clone();
            plan(n).forward(&mut got);
            close(&got, &dft_naive(&x, false), 1e-9 * n as f64);
        }
    }

    #[test]
    fn bluestein_matches_naive() {
        for &n in &[3usize, 5, 6, 7, 12, 100, 255] {
            let x: Vec<C64> = (0..n).map(|i| C64::new((i as f64).cos(), (i as f64 * 1.3).sin())).collect();
            let mut got = x.clone();
            plan(n).forward(&mut got);
            close(&got, &dft_naive(&x, false), 1e-8 * n as f64);
        }
    }

    #[test]
    fn roundtrip() {
        for &n in &[8usize, 12, 31, 128, 1000] {
            let x: Vec<C64> = (0..n).map(|i| C64::new(i as f64, -(i as f64) * 0.5)).collect();
            let mut y = x.clone();
            let p = plan(n);
            p.forward(&mut y);
            p.inverse(&mut y);
            close(&y, &x, 1e-8 * n as f64);
        }
    }

    #[test]
    fn rfft_symmetric_input_gives_real_spectrum() {
        // Even (circularly symmetric) real input -> real spectrum.
        let n = 16;
        let mut x = vec![0.0f64; n];
        for i in 0..n {
            let d = i.min(n - i) as f64;
            x[i] = (-d * d / 8.0).exp();
        }
        let spec = rfft(&x);
        for z in &spec {
            assert!(z.im.abs() < 1e-10, "{z:?}");
        }
    }

    #[test]
    fn fftn_matches_axiswise_naive() {
        let shape = [3usize, 4, 5];
        let total: usize = shape.iter().product();
        let x: Vec<C64> = (0..total).map(|i| C64::new((i as f64).sin(), (i as f64).cos())).collect();
        let mut got = x.clone();
        fftn(&mut got, &shape, false);
        let mut want = x;
        // axis 2 (contiguous lines)
        for o in 0..12 {
            let line: Vec<C64> = want[o * 5..o * 5 + 5].to_vec();
            let f = dft_naive(&line, false);
            want[o * 5..o * 5 + 5].copy_from_slice(&f);
        }
        // axis 1
        for a in 0..3 {
            for c in 0..5 {
                let line: Vec<C64> = (0..4).map(|b| want[a * 20 + b * 5 + c]).collect();
                let f = dft_naive(&line, false);
                for b in 0..4 {
                    want[a * 20 + b * 5 + c] = f[b];
                }
            }
        }
        // axis 0
        for b in 0..4 {
            for c in 0..5 {
                let line: Vec<C64> = (0..3).map(|a| want[a * 20 + b * 5 + c]).collect();
                let f = dft_naive(&line, false);
                for a in 0..3 {
                    want[a * 20 + b * 5 + c] = f[a];
                }
            }
        }
        close(&got, &want, 1e-8);
    }

    #[test]
    fn fftn_roundtrip() {
        let shape = [4usize, 6];
        let total = 24;
        let x: Vec<C64> = (0..total).map(|i| C64::real(i as f64)).collect();
        let mut y = x.clone();
        fftn(&mut y, &shape, false);
        fftn(&mut y, &shape, true);
        close(&y, &x, 1e-9);
    }

    /// Property: the batched transform equals the per-line reference for
    /// mixed power-of-two / Bluestein shapes, forward and inverse, for
    /// batches large enough to exercise the panel tail paths.
    #[test]
    fn prop_fftn_batch_matches_per_line_fftn() {
        let shapes: [&[usize]; 6] =
            [&[8], &[12], &[4, 6], &[3, 5], &[2, 3, 4], &[5, 1, 7]];
        for shape in shapes {
            let per: usize = shape.iter().product();
            for &batch in &[1usize, 2, 3, 5] {
                let data: Vec<C64> = (0..batch * per)
                    .map(|i| C64::new((i as f64 * 0.61).sin(), (i as f64 * 0.37).cos()))
                    .collect();
                for &inverse in &[false, true] {
                    let mut got = data.clone();
                    let mut scratch = FftScratch::default();
                    fftn_batch(&mut got, batch, shape, inverse, &mut scratch);
                    let mut want = data.clone();
                    for item in want.chunks_exact_mut(per) {
                        fftn(item, shape, inverse);
                    }
                    close(&got, &want, 1e-9 * per as f64);
                }
            }
        }
    }

    /// Property: forward_batch/inverse_batch round-trip every line, for
    /// both radix-2 and Bluestein plans.
    #[test]
    fn prop_batch_roundtrip() {
        for &n in &[4usize, 12, 31, 64] {
            let p = plan(n);
            let lines = 5;
            let x: Vec<C64> =
                (0..lines * n).map(|i| C64::new(i as f64 * 0.3, -(i as f64) * 0.7)).collect();
            let mut y = x.clone();
            p.forward_batch(&mut y);
            p.inverse_batch(&mut y);
            close(&y, &x, 1e-8 * n as f64);
        }
    }

    /// The two-for-one packing is exact: the packed spectrum splits into
    /// the individual real-input spectra, and pack -> forward -> inverse
    /// -> unpack reproduces both rows.
    #[test]
    fn two_for_one_packing_round_trips() {
        for &n in &[8usize, 12, 33] {
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.9).sin() + 0.3).collect();
            let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.4).cos() - 0.1).collect();
            let block: Vec<f64> = x.iter().chain(y.iter()).copied().collect();
            let mut packed = Vec::new();
            pack_real_pairs(&block, n, &mut packed);
            assert_eq!(packed.len(), n);
            let p = plan(n);
            p.forward(&mut packed);
            // Split must match the individually transformed spectra.
            let mut xs = vec![C64::ZERO; n];
            let mut ys = vec![C64::ZERO; n];
            split_packed_spectrum(&packed, &mut xs, &mut ys);
            close(&xs, &rfft(&x), 1e-9 * n as f64);
            close(&ys, &rfft(&y), 1e-9 * n as f64);
            // And the packed round-trip recovers both rows.
            p.inverse(&mut packed);
            let mut back = vec![0.0; 2 * n];
            unpack_real_pairs(&packed, n, 2, &mut back);
            for (g, w) in back.iter().zip(&block) {
                assert!((g - w).abs() < 1e-10, "{g} vs {w}");
            }
        }
    }

    /// Odd batches pad the unpaired last row with a zero imaginary part.
    #[test]
    fn two_for_one_handles_odd_batches() {
        let n = 10;
        let rows = 3;
        let block: Vec<f64> = (0..rows * n).map(|i| (i as f64 * 0.17).sin()).collect();
        let spec = vec![1.0; n]; // identity spectrum
        let mut out = vec![0.0; rows * n];
        let mut ws = Workspace::new();
        apply_real_spectrum_batch(&block, &mut out, &[n], &spec, |e| e, &mut ws);
        for (g, w) in out.iter().zip(&block) {
            assert!((g - w).abs() < 1e-10, "{g} vs {w}");
        }
    }

    /// apply_real_spectrum_batch equals the per-vector reference
    /// (forward, scale, inverse) on a 2-D Bluestein shape.
    #[test]
    fn spectrum_batch_matches_per_vector() {
        let shape = [6usize, 5];
        let m = 30;
        let rows = 4;
        let spec: Vec<f64> = (0..m).map(|i| 0.5 + (i as f64 * 0.23).cos().abs()).collect();
        let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.13).sin()).collect();
        let mut got = vec![0.0; rows * m];
        let mut ws = Workspace::new();
        apply_real_spectrum_batch(&block, &mut got, &shape, &spec, |e| e, &mut ws);
        for r in 0..rows {
            let mut buf: Vec<C64> =
                block[r * m..(r + 1) * m].iter().map(|&v| C64::real(v)).collect();
            fftn(&mut buf, &shape, false);
            for (z, &e) in buf.iter_mut().zip(&spec) {
                *z = z.scale(e);
            }
            fftn(&mut buf, &shape, true);
            for (k, z) in buf.iter().enumerate() {
                let g = got[r * m + k];
                assert!((g - z.re).abs() < 1e-10, "row {r}: {g} vs {}", z.re);
            }
        }
    }

    /// The thread-local plan cache stays under its size cap no matter how
    /// many distinct lengths a thread requests.
    #[test]
    fn plan_cache_is_size_capped() {
        for n in 2..(3 * PLAN_CACHE_CAP + 2) {
            let p = plan(n);
            assert_eq!(p.len(), n);
            assert!(
                plan_cache_len() <= PLAN_CACHE_CAP,
                "cache grew to {} (> {PLAN_CACHE_CAP})",
                plan_cache_len()
            );
        }
        // Evicted lengths rebuild transparently.
        let p = plan(2);
        assert_eq!(p.len(), 2);
    }

    /// Conjugate-even spectrum over an arbitrary shape: the real FFT of
    /// a tensor symmetric under index negation (like every kernel
    /// spectrum in the crate).
    fn symmetric_spectrum(shape: &[usize]) -> Vec<f64> {
        let m: usize = shape.iter().product();
        let d = shape.len();
        let mut c = vec![C64::ZERO; m];
        for (flat, v) in c.iter_mut().enumerate() {
            let mut rem = flat;
            let mut r2 = 0.0;
            for a in (0..d).rev() {
                let i = rem % shape[a];
                rem /= shape[a];
                let dist = i.min(shape[a] - i) as f64;
                r2 += dist * dist;
            }
            *v = C64::real((-0.5 * r2 / 4.0).exp() + 0.1);
        }
        fftn(&mut c, shape, false);
        c.into_iter().map(|z| z.re).collect()
    }

    /// Full-complex reference for `apply_real_spectrum_batch`: pack each
    /// row as a complex tensor, transform all axes at full length, scale,
    /// invert, take real parts.
    fn apply_spectrum_reference(block: &[f64], shape: &[usize], spec: &[f64]) -> Vec<f64> {
        let m: usize = shape.iter().product();
        let rows = block.len() / m;
        let mut out = vec![0.0; block.len()];
        for r in 0..rows {
            let mut buf: Vec<C64> =
                block[r * m..(r + 1) * m].iter().map(|&v| C64::real(v)).collect();
            fftn(&mut buf, shape, false);
            for (z, &e) in buf.iter_mut().zip(spec) {
                *z = z.scale(e);
            }
            fftn(&mut buf, shape, true);
            for (o, z) in out[r * m..(r + 1) * m].iter_mut().zip(&buf) {
                *o = z.re;
            }
        }
        out
    }

    /// The rfft half-spectrum route matches the full complex transform
    /// to 1e-12 on even last axes (1-D and multi-D, including Bluestein
    /// leading axes and odd row counts), and really performs
    /// length-`n/2` last-axis transforms (pinned via the op counter).
    #[test]
    fn rfft_half_spectrum_matches_full_transform() {
        let shapes: [&[usize]; 5] = [&[16], &[8], &[4, 10], &[3, 8], &[5, 2]];
        for shape in shapes {
            let m: usize = shape.iter().product();
            let n = *shape.last().unwrap();
            let rest = m / n;
            let spec = symmetric_spectrum(shape);
            for &rows in &[1usize, 3] {
                let block: Vec<f64> =
                    (0..rows * m).map(|i| (i as f64 * 0.37).sin() - 0.2).collect();
                let before = rfft_half_lines_total();
                let mut got = vec![0.0; rows * m];
                let mut ws = Workspace::new();
                apply_real_spectrum_batch(&block, &mut got, shape, &spec, |e| e, &mut ws);
                // Forward + inverse half transforms for every line (other
                // tests may add to the global counter concurrently, so
                // pin a lower bound).
                assert!(
                    rfft_half_lines_total() - before >= 2 * (rows * rest) as u64,
                    "rfft path must run half-length last-axis transforms ({shape:?})"
                );
                let want = apply_spectrum_reference(&block, shape, &spec);
                for (g, w) in got.iter().zip(&want) {
                    assert!((g - w).abs() < 1e-12, "{shape:?} rows={rows}: {g} vs {w}");
                }
            }
        }
    }

    /// Identity spectrum through the rfft route is an exact round trip.
    #[test]
    fn rfft_roundtrip_identity_spectrum() {
        for &n in &[2usize, 4, 10, 12, 100] {
            let rows = 3;
            let block: Vec<f64> = (0..rows * n).map(|i| (i as f64 * 0.61).cos() + 0.4).collect();
            let spec = vec![1.0; n];
            let mut got = vec![0.0; rows * n];
            let mut ws = Workspace::new();
            apply_real_spectrum_batch(&block, &mut got, &[n], &spec, |e| e, &mut ws);
            for (g, w) in got.iter().zip(&block) {
                assert!((g - w).abs() < 1e-12, "n={n}: {g} vs {w}");
            }
        }
    }

    /// Acceptance (tentpole): `fftn_batch` is bit-identical across
    /// thread counts — parallel tasks transform disjoint lines with the
    /// same arithmetic. The shape exercises a strided power-of-two axis
    /// and a contiguous Bluestein axis above the parallel threshold.
    #[test]
    fn fftn_batch_identical_across_thread_counts() {
        let shape = [32usize, 33];
        let batch = 8;
        let per: usize = shape.iter().product();
        let data: Vec<C64> = (0..batch * per)
            .map(|i| C64::new((i as f64 * 0.23).sin(), (i as f64 * 0.71).cos()))
            .collect();
        let run_with = |threads: usize| -> Vec<C64> {
            crate::parallel::configure(crate::parallel::ParallelConfig { threads });
            let mut buf = data.clone();
            let mut scratch = FftScratch::default();
            fftn_batch(&mut buf, batch, &shape, false, &mut scratch);
            buf
        };
        let serial = run_with(1);
        let parallel = run_with(4);
        crate::parallel::configure(crate::parallel::ParallelConfig { threads: 0 });
        for (a, b) in serial.iter().zip(&parallel) {
            assert!(
                a.re == b.re && a.im == b.im,
                "thread count changed the result: {a:?} vs {b:?}"
            );
        }
    }

    /// Acceptance (tentpole): the batched real-spectrum apply is
    /// bit-identical across thread counts (rows are independent on the
    /// rfft path; the pair path chunks on pair boundaries).
    #[test]
    fn apply_real_spectrum_identical_across_thread_counts() {
        for shape in [&[1024usize][..], &[33, 35][..]] {
            let m: usize = shape.iter().product();
            let rows = 8;
            let spec = symmetric_spectrum(shape);
            let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.13).sin()).collect();
            let run_with = |threads: usize| -> Vec<f64> {
                crate::parallel::configure(crate::parallel::ParallelConfig { threads });
                let mut out = vec![0.0; rows * m];
                let mut ws = Workspace::new();
                apply_real_spectrum_batch(&block, &mut out, shape, &spec, |e| e, &mut ws);
                out
            };
            let serial = run_with(1);
            let parallel = run_with(4);
            crate::parallel::configure(crate::parallel::ParallelConfig { threads: 0 });
            for (a, b) in serial.iter().zip(&parallel) {
                assert!(a == b, "{shape:?}: thread count changed the result: {a} vs {b}");
            }
        }
    }

    /// Parallel fan-out is observable: a large batched transform at 4
    /// threads bumps the panel-dispatch counter (the `/metrics` signal).
    #[test]
    fn parallel_dispatch_increments_panel_counter() {
        let shape = [64usize, 64];
        let batch = 4;
        let per: usize = shape.iter().product();
        let mut buf: Vec<C64> =
            (0..batch * per).map(|i| C64::new(i as f64 * 1e-3, 0.0)).collect();
        let before = parallel_panels_total();
        // Concurrent tests can hold the pool (inline fallback, no
        // dispatch) or temporarily reconfigure the global thread count
        // to 1 (the determinism tests do) — so re-pin the config before
        // every attempt and back off between attempts; ~50 spaced
        // collisions in a row is implausible.
        let mut scratch = FftScratch::default();
        let mut bumped = false;
        for _ in 0..50 {
            crate::parallel::configure(crate::parallel::ParallelConfig { threads: 4 });
            fftn_batch(&mut buf, batch, &shape, false, &mut scratch);
            if parallel_panels_total() > before {
                bumped = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        crate::parallel::configure(crate::parallel::ParallelConfig { threads: 0 });
        assert!(bumped, "parallel dispatch must bump fft_parallel_panels_total");
    }
}
