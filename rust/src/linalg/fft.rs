//! Fast Fourier transforms: iterative radix-2 Cooley–Tukey for power-of-two
//! lengths and Bluestein's chirp-z algorithm for arbitrary lengths, plus a
//! multi-dimensional transform over the axes of a dense tensor and a
//! **batched multi-RHS engine** for the structured MVMs that dominate CG
//! iterations.
//!
//! Circulant eigenvalue computations ([`crate::structure::circulant`]) need
//! FFTs at the *exact* grid size `m` (which users choose freely), hence the
//! Bluestein fallback; Toeplitz matrix–vector products are free to pad to
//! the next power of two and always hit the radix-2 path.
//!
//! [`FftPlan`] caches twiddle factors, the bit-reversal permutation, and
//! (for Bluestein) the transformed chirp, so repeated transforms of one
//! size — the common case inside CG iterations — do no trigonometry. The
//! thread-local plan cache is size-capped (FIFO eviction) so grid
//! auto-expansion and per-shard worker threads cannot grow it without
//! bound.
//!
//! The batched layer amortizes that per-transform setup across many lines:
//!
//! * [`FftPlan::forward_batch`] / [`FftPlan::inverse_batch`] transform a
//!   contiguous `[batch, n]` buffer reusing one twiddle/bit-reversal table
//!   (and, for Bluestein, one convolution scratch) across all lines.
//! * [`fftn_batch`] transforms a `[batch, shape...]` tensor; strided axes
//!   are processed in cache-blocked panels of adjacent lines instead of
//!   the per-line gather/scatter of [`fftn`], so the dominant cost becomes
//!   sequential memory traffic.
//! * [`apply_real_spectrum_batch`] packs *pairs of real vectors* into one
//!   complex line (`z = x + i y`, the classic two-for-one trick): a real
//!   diagonal spectrum commutes with the packing, so every real-input
//!   structured MVM (circulant, Toeplitz embedding, BCCB, separable
//!   Kronecker square root) does half the FFT work on a batch.

use super::complex::C64;
use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::rc::Rc;

/// Round `n` up to the next power of two.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// A cached FFT plan for a fixed transform length.
#[derive(Debug)]
pub struct FftPlan {
    n: usize,
    /// Twiddles for the radix-2 kernel of size `work_len` (== `n` when `n`
    /// is a power of two, else the Bluestein convolution length).
    twiddles: Vec<C64>,
    /// Bit-reversal permutation for the radix-2 kernel (size `work_len`).
    bitrev: Vec<u32>,
    work_len: usize,
    /// Bluestein state: chirp `w_k = e^{-i pi k^2 / n}` and the forward
    /// FFT of the zero-padded conjugate chirp.
    bluestein: Option<BluesteinState>,
}

#[derive(Debug)]
struct BluesteinState {
    chirp: Vec<C64>,
    chirp_fft: Vec<C64>,
}

impl FftPlan {
    /// Build a plan for length-`n` transforms.
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "FFT length must be >= 1");
        if n.is_power_of_two() {
            FftPlan {
                n,
                twiddles: make_twiddles(n),
                bitrev: make_bitrev(n),
                work_len: n,
                bluestein: None,
            }
        } else {
            let m = next_pow2(2 * n - 1);
            let twiddles = make_twiddles(m);
            let bitrev = make_bitrev(m);
            // chirp[k] = e^{-i pi k^2 / n}
            let mut chirp = vec![C64::ZERO; n];
            for k in 0..n {
                // Reduce k^2 mod 2n to keep the angle argument small and
                // the trigonometry accurate for large n.
                let k2 = (k * k) % (2 * n);
                chirp[k] = C64::cis(-std::f64::consts::PI * k2 as f64 / n as f64);
            }
            // b[k] = conj(chirp[|k|]) zero-padded to m, wrapped.
            let mut b = vec![C64::ZERO; m];
            b[0] = chirp[0].conj();
            for k in 1..n {
                b[k] = chirp[k].conj();
                b[m - k] = chirp[k].conj();
            }
            fft_pow2(&mut b, &twiddles, &bitrev, false);
            FftPlan {
                n,
                twiddles,
                bitrev,
                work_len: m,
                bluestein: Some(BluesteinState { chirp, chirp_fft: b }),
            }
        }
    }

    /// Transform length this plan was built for.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if the plan length is zero (never; kept for clippy symmetry).
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// In-place forward DFT (no normalization): `X_k = sum_j x_j e^{-2 pi i jk/n}`.
    pub fn forward(&self, x: &mut [C64]) {
        self.transform(x, false)
    }

    /// In-place inverse DFT **with** `1/n` normalization.
    pub fn inverse(&self, x: &mut [C64]) {
        self.transform(x, true);
        let s = 1.0 / self.n as f64;
        for v in x.iter_mut() {
            *v = v.scale(s);
        }
    }

    /// Forward DFT of every contiguous length-`n` line of `data`
    /// (`data.len()` must be a multiple of `n`). One twiddle /
    /// bit-reversal table — and, on the Bluestein path, one convolution
    /// scratch — is reused across all lines.
    pub fn forward_batch(&self, data: &mut [C64]) {
        let mut blue = Vec::new();
        self.batch_transform(data, false, &mut blue);
    }

    /// Inverse DFT (with `1/n` normalization) of every contiguous
    /// length-`n` line of `data`.
    pub fn inverse_batch(&self, data: &mut [C64]) {
        let mut blue = Vec::new();
        self.batch_transform(data, true, &mut blue);
    }

    /// Batched kernel behind [`Self::forward_batch`] /
    /// [`Self::inverse_batch`], with a caller-owned Bluestein scratch so
    /// tight loops ([`fftn_batch`]) stay allocation-free.
    fn batch_transform(&self, data: &mut [C64], inverse: bool, blue: &mut Vec<C64>) {
        assert_eq!(
            data.len() % self.n,
            0,
            "batched FFT: buffer {} not a multiple of plan length {}",
            data.len(),
            self.n
        );
        match &self.bluestein {
            None => {
                for line in data.chunks_exact_mut(self.n) {
                    fft_pow2(line, &self.twiddles, &self.bitrev, inverse);
                }
            }
            Some(bs) => {
                blue.resize(self.work_len, C64::ZERO);
                for line in data.chunks_exact_mut(self.n) {
                    self.bluestein_with(line, bs, inverse, blue);
                }
            }
        }
        if inverse {
            let s = 1.0 / self.n as f64;
            for v in data.iter_mut() {
                *v = v.scale(s);
            }
        }
    }

    fn transform(&self, x: &mut [C64], inverse: bool) {
        assert_eq!(x.len(), self.n, "FFT length mismatch: plan {} vs input {}", self.n, x.len());
        match &self.bluestein {
            None => fft_pow2(x, &self.twiddles, &self.bitrev, inverse),
            Some(bs) => {
                let mut a = vec![C64::ZERO; self.work_len];
                self.bluestein_with(x, bs, inverse, &mut a);
            }
        }
    }

    /// Bluestein chirp-z transform of one line, using the caller's
    /// work-length scratch `a` (contents overwritten). The result is
    /// unnormalized; inverse normalization happens in the wrappers.
    fn bluestein_with(&self, x: &mut [C64], bs: &BluesteinState, inverse: bool, a: &mut [C64]) {
        let n = self.n;
        debug_assert_eq!(a.len(), self.work_len);
        // Inverse transform = conjugate trick: F^{-1}(x) * n = conj(F(conj(x))).
        if inverse {
            for v in x.iter_mut() {
                *v = v.conj();
            }
        }
        a.fill(C64::ZERO);
        for k in 0..n {
            a[k] = x[k] * bs.chirp[k];
        }
        fft_pow2(a, &self.twiddles, &self.bitrev, false);
        for (av, bv) in a.iter_mut().zip(bs.chirp_fft.iter()) {
            *av = *av * *bv;
        }
        fft_pow2(a, &self.twiddles, &self.bitrev, true);
        let s = 1.0 / self.work_len as f64;
        for k in 0..n {
            x[k] = a[k].scale(s) * bs.chirp[k];
        }
        if inverse {
            for v in x.iter_mut() {
                *v = v.conj();
            }
        }
    }
}

fn make_twiddles(n: usize) -> Vec<C64> {
    // Twiddles for the forward transform, one per element of the half-size
    // butterfly at the largest stage; stages reuse strided prefixes.
    let half = n / 2;
    let mut tw = Vec::with_capacity(half.max(1));
    for k in 0..half.max(1) {
        tw.push(C64::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64));
    }
    tw
}

/// Bit-reversal permutation table for a power-of-two length `n`
/// (`u32` halves the table footprint; every supported length fits).
fn make_bitrev(n: usize) -> Vec<u32> {
    debug_assert!(n.is_power_of_two());
    let mut br = vec![0u32; n];
    for i in 1..n {
        br[i] = br[i >> 1] >> 1 | if i & 1 == 1 { (n >> 1) as u32 } else { 0 };
    }
    br
}

/// Iterative radix-2 Cooley–Tukey, `x.len()` must be a power of two.
/// `twiddles` / `bitrev` must be the tables for exactly this length.
fn fft_pow2(x: &mut [C64], twiddles: &[C64], bitrev: &[u32], inverse: bool) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    debug_assert_eq!(bitrev.len(), n);
    if n <= 1 {
        return;
    }
    // Bit-reversal permutation (table-driven; the table is built once per
    // plan and shared by every line of a batch).
    for i in 0..n {
        let j = bitrev[i] as usize;
        if i < j {
            x.swap(i, j);
        }
    }
    // Butterflies. Twiddle for stage of length `len` at position k is
    // twiddles[k * (n/len)] (stride-decimated main table).
    let mut len = 2;
    while len <= n {
        let stride = n / len;
        let half = len / 2;
        for start in (0..n).step_by(len) {
            for k in 0..half {
                let mut w = twiddles[k * stride];
                if inverse {
                    w = w.conj();
                }
                let u = x[start + k];
                let v = x[start + k + half] * w;
                x[start + k] = u + v;
                x[start + k + half] = u - v;
            }
        }
        len <<= 1;
    }
}

/// Per-thread plan-cache capacity. One plan per distinct transform
/// length; grid auto-expansion and per-shard worker threads request new
/// lengths over time, so the cache evicts FIFO beyond this cap instead
/// of growing without bound. Evicted plans stay alive for as long as a
/// caller still holds their `Rc`.
const PLAN_CACHE_CAP: usize = 64;

thread_local! {
    static PLAN_CACHE: RefCell<(HashMap<usize, Rc<FftPlan>>, VecDeque<usize>)> =
        RefCell::new((HashMap::new(), VecDeque::new()));
}

/// Fetch (or build) a thread-local cached plan for length `n`.
pub fn plan(n: usize) -> Rc<FftPlan> {
    PLAN_CACHE.with(|c| {
        let mut guard = c.borrow_mut();
        let (map, order) = &mut *guard;
        if let Some(p) = map.get(&n) {
            return p.clone();
        }
        if map.len() >= PLAN_CACHE_CAP {
            if let Some(old) = order.pop_front() {
                map.remove(&old);
            }
        }
        let p = Rc::new(FftPlan::new(n));
        map.insert(n, p.clone());
        order.push_back(n);
        p
    })
}

/// Number of plans currently held by this thread's cache (test hook for
/// the size cap).
#[doc(hidden)]
pub fn plan_cache_len() -> usize {
    PLAN_CACHE.with(|c| c.borrow().0.len())
}

/// Forward DFT of a real signal; returns the full complex spectrum.
pub fn rfft(x: &[f64]) -> Vec<C64> {
    let mut buf: Vec<C64> = x.iter().map(|&v| C64::real(v)).collect();
    plan(x.len()).forward(&mut buf);
    buf
}

/// Inverse DFT returning only the real parts (caller asserts the spectrum
/// is conjugate-symmetric, e.g. eigenvalues of a symmetric circulant).
pub fn irfft_real(spec: &[C64]) -> Vec<f64> {
    let mut buf = spec.to_vec();
    plan(spec.len()).inverse(&mut buf);
    buf.into_iter().map(|z| z.re).collect()
}

/// Multi-dimensional FFT over a dense row-major tensor of shape `shape`.
/// Transforms every axis in turn (`F = F_1 (x) ... (x) F_D`).
///
/// This is the single-tensor reference path; the batched engine
/// ([`fftn_batch`]) additionally amortizes plan setup across lines and
/// replaces the per-line gather/scatter below with cache-blocked panels.
pub fn fftn(data: &mut [C64], shape: &[usize], inverse: bool) {
    let total: usize = shape.iter().product();
    assert_eq!(data.len(), total, "fftn: data/shape mismatch");
    let d = shape.len();
    // Strides for row-major layout.
    let mut strides = vec![1usize; d];
    for i in (0..d.saturating_sub(1)).rev() {
        strides[i] = strides[i + 1] * shape[i + 1];
    }
    let mut scratch: Vec<C64> = Vec::new();
    for ax in 0..d {
        let n = shape[ax];
        if n == 1 {
            continue;
        }
        let p = plan(n);
        let stride = strides[ax];
        if stride != 1 {
            // Only strided axes gather into scratch; keeping the
            // contiguous (last-axis / 1-D) path allocation-free matters
            // because fftn sits inside CG iteration loops.
            scratch.resize(n, C64::ZERO);
        }
        // Iterate over all 1-D lines along axis `ax`.
        let outer: usize = shape[..ax].iter().product();
        let inner: usize = shape[ax + 1..].iter().product();
        for o in 0..outer {
            for i in 0..inner {
                let base = o * stride * n + i;
                if stride == 1 {
                    let line = &mut data[base..base + n];
                    if inverse {
                        p.inverse(line);
                    } else {
                        p.forward(line);
                    }
                } else {
                    for k in 0..n {
                        scratch[k] = data[base + k * stride];
                    }
                    if inverse {
                        p.inverse(&mut scratch);
                    } else {
                        p.forward(&mut scratch);
                    }
                    for k in 0..n {
                        data[base + k * stride] = scratch[k];
                    }
                }
            }
        }
    }
}

/// Gather / Bluestein scratch for the batched transforms. Reusing one
/// across calls keeps the batched hot paths allocation-free.
#[derive(Clone, Debug, Default)]
pub struct FftScratch {
    /// Cache-blocked panel of gathered lines (strided axes).
    panel: Vec<C64>,
    /// Bluestein convolution buffer (non-power-of-two lengths).
    blue: Vec<C64>,
}

/// Number of adjacent lines gathered per panel on strided axes: small
/// enough that a panel of the longest supported lines stays cache-
/// resident, large enough that gathers read whole cache lines.
const PANEL: usize = 8;

/// Multi-dimensional FFT of `batch` independent row-major tensors stored
/// contiguously (`data.len() == batch * prod(shape)`). The batch axis is
/// never transformed. Strided axes are processed in cache-blocked panels
/// of [`PANEL`] adjacent lines — the gather then reads contiguous runs
/// instead of one element per stride — and every line of an axis shares
/// one plan (twiddles, bit-reversal table, Bluestein scratch).
pub fn fftn_batch(
    data: &mut [C64],
    batch: usize,
    shape: &[usize],
    inverse: bool,
    scratch: &mut FftScratch,
) {
    let per: usize = shape.iter().product();
    assert_eq!(data.len(), batch * per, "fftn_batch: data/shape mismatch");
    let d = shape.len();
    for ax in 0..d {
        let n = shape[ax];
        if n == 1 {
            continue;
        }
        let p = plan(n);
        let inner: usize = shape[ax + 1..].iter().product();
        if inner == 1 {
            // Contiguous lines tile the whole buffer: one batched pass.
            p.batch_transform(data, inverse, &mut scratch.blue);
            continue;
        }
        let outer: usize = batch * shape[..ax].iter().product::<usize>();
        scratch.panel.resize(PANEL * n, C64::ZERO);
        for o in 0..outer {
            let base_o = o * n * inner;
            let mut i0 = 0;
            while i0 < inner {
                let pw = PANEL.min(inner - i0);
                // Gather `pw` adjacent lines: contiguous reads of `pw`
                // elements per grid row, sequential writes per line.
                for k in 0..n {
                    let src = base_o + k * inner + i0;
                    for q in 0..pw {
                        scratch.panel[q * n + k] = data[src + q];
                    }
                }
                p.batch_transform(&mut scratch.panel[..pw * n], inverse, &mut scratch.blue);
                for k in 0..n {
                    let dst = base_o + k * inner + i0;
                    for q in 0..pw {
                        data[dst + q] = scratch.panel[q * n + k];
                    }
                }
                i0 += pw;
            }
        }
    }
}

/// Reusable buffers for the batched real-MVM engine: the two-for-one
/// packed lines plus FFT gather scratch. One `Workspace` per solver /
/// trainer keeps every structured `matvec_batch` allocation-free.
#[derive(Clone, Debug, Default)]
pub struct Workspace {
    /// Two-for-one packed complex lines (`ceil(b/2) x m`).
    pub(crate) packed: Vec<C64>,
    /// Gather / Bluestein scratch shared by the batched transforms.
    pub(crate) scratch: FftScratch,
}

impl Workspace {
    /// Fresh (empty) workspace; buffers grow on first use.
    pub fn new() -> Self {
        Self::default()
    }
}

thread_local! {
    static TLS_WS: RefCell<Workspace> = RefCell::new(Workspace::default());
}

/// Run `f` with this thread's shared [`Workspace`] — the compatibility
/// shim that lets the single-vector `matvec` wrappers reuse the batched
/// engine without allocating scratch per call. Callers must not call
/// [`with_workspace`] re-entrantly from inside `f` (the structured-MVM
/// wrappers never do: only leaf `*_batch` kernels run under it).
pub fn with_workspace<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    TLS_WS.with(|w| f(&mut w.borrow_mut()))
}

/// Pack the rows of a real `rows x m` block into `ceil(rows/2)` complex
/// lines: line `j` is `row_{2j} + i row_{2j+1}` (imaginary part zero for
/// the unpaired last row of an odd block).
pub fn pack_real_pairs(block: &[f64], m: usize, out: &mut Vec<C64>) {
    assert!(m > 0 && block.len() % m == 0, "pack_real_pairs: block/m mismatch");
    let rows = block.len() / m;
    let pairs = rows.div_ceil(2);
    out.clear();
    out.resize(pairs * m, C64::ZERO);
    for j in 0..pairs {
        let re = &block[2 * j * m..(2 * j + 1) * m];
        let line = &mut out[j * m..(j + 1) * m];
        if 2 * j + 1 < rows {
            let im = &block[(2 * j + 1) * m..(2 * j + 2) * m];
            for k in 0..m {
                line[k] = C64::new(re[k], im[k]);
            }
        } else {
            for k in 0..m {
                line[k] = C64::real(re[k]);
            }
        }
    }
}

/// Inverse of [`pack_real_pairs`] after real-linear processing: row `2j`
/// is the real part of line `j`, row `2j+1` the imaginary part.
pub fn unpack_real_pairs(packed: &[C64], m: usize, rows: usize, out: &mut [f64]) {
    assert_eq!(out.len(), rows * m, "unpack_real_pairs: out/rows mismatch");
    let pairs = rows.div_ceil(2);
    assert_eq!(packed.len(), pairs * m, "unpack_real_pairs: packed/rows mismatch");
    for j in 0..pairs {
        let line = &packed[j * m..(j + 1) * m];
        for k in 0..m {
            out[2 * j * m + k] = line[k].re;
        }
        if 2 * j + 1 < rows {
            for k in 0..m {
                out[(2 * j + 1) * m + k] = line[k].im;
            }
        }
    }
}

/// Split the forward spectrum `z` of a packed pair `x + i y` (`x`, `y`
/// real) into the individual spectra, using conjugate symmetry:
/// `X_k = (Z_k + conj(Z_{-k})) / 2`, `Y_k = -i (Z_k - conj(Z_{-k})) / 2`
/// (indices mod `n`). Used by the tests to pin the two-for-one packing
/// and available to callers that need the separate spectra.
pub fn split_packed_spectrum(z: &[C64], x_spec: &mut [C64], y_spec: &mut [C64]) {
    let n = z.len();
    assert_eq!(x_spec.len(), n);
    assert_eq!(y_spec.len(), n);
    for k in 0..n {
        let zk = z[k];
        let zr = z[(n - k) % n].conj();
        x_spec[k] = (zk + zr).scale(0.5);
        let d = zk - zr;
        y_spec[k] = C64::new(d.im * 0.5, -d.re * 0.5);
    }
}

/// Apply a real diagonal spectrum (in the multi-dimensional Fourier basis
/// over `shape`) to every row of a real `b x m` block, two rows per
/// complex transform: `out_r = F^{-1} diag(f(spec)) F block_r`. Because
/// the spectrum is real, the operator is a real matrix and commutes with
/// the `x + i y` packing, so the result is the exact batched MVM with
/// half the transforms. This one kernel powers the circulant, BCCB and
/// separable square-root `matvec_batch` paths.
pub fn apply_real_spectrum_batch(
    block: &[f64],
    out: &mut [f64],
    shape: &[usize],
    spec: &[f64],
    f: impl Fn(f64) -> f64,
    ws: &mut Workspace,
) {
    let m: usize = shape.iter().product();
    assert_eq!(spec.len(), m, "spectrum length vs shape");
    assert!(m > 0 && block.len() % m == 0, "block is b x m row-major");
    assert_eq!(out.len(), block.len());
    let rows = block.len() / m;
    let pairs = rows.div_ceil(2);
    let Workspace { packed, scratch } = ws;
    pack_real_pairs(block, m, packed);
    fftn_batch(packed, pairs, shape, false, scratch);
    for line in packed.chunks_exact_mut(m) {
        for (z, &e) in line.iter_mut().zip(spec) {
            *z = z.scale(f(e));
        }
    }
    fftn_batch(packed, pairs, shape, true, scratch);
    unpack_real_pairs(packed, m, rows, out);
}

/// Apply a real 1-D spectrum along one axis of a batch of packed complex
/// tensors, zero-padding every line from `n` to `spec.len()` (the
/// circulant-embedding length) and truncating back after the inverse
/// transform — the batched kernel behind the exact Toeplitz and
/// Kronecker-of-Toeplitz MVMs. `outer` counts line groups before the
/// axis (batch folded in), `inner` is the trailing stride.
pub(crate) fn apply_axis_spectrum_packed(
    data: &mut [C64],
    outer: usize,
    n: usize,
    inner: usize,
    spec: &[f64],
    scratch: &mut FftScratch,
) {
    let a = spec.len();
    assert!(a >= n, "embedding {a} shorter than axis {n}");
    let p = plan(a);
    scratch.panel.resize(PANEL * a, C64::ZERO);
    if inner == 1 {
        // Contiguous lines: panel over adjacent groups.
        let mut o0 = 0;
        while o0 < outer {
            let pw = PANEL.min(outer - o0);
            for q in 0..pw {
                let line = &data[(o0 + q) * n..(o0 + q + 1) * n];
                scratch.panel[q * a..q * a + n].copy_from_slice(line);
                scratch.panel[q * a + n..(q + 1) * a].fill(C64::ZERO);
            }
            spectrum_lines(&mut scratch.panel[..pw * a], &p, spec, &mut scratch.blue);
            for q in 0..pw {
                data[(o0 + q) * n..(o0 + q + 1) * n]
                    .copy_from_slice(&scratch.panel[q * a..q * a + n]);
            }
            o0 += pw;
        }
        return;
    }
    for o in 0..outer {
        let base_o = o * n * inner;
        let mut i0 = 0;
        while i0 < inner {
            let pw = PANEL.min(inner - i0);
            for q in 0..pw {
                scratch.panel[q * a + n..(q + 1) * a].fill(C64::ZERO);
            }
            for k in 0..n {
                let src = base_o + k * inner + i0;
                for q in 0..pw {
                    scratch.panel[q * a + k] = data[src + q];
                }
            }
            spectrum_lines(&mut scratch.panel[..pw * a], &p, spec, &mut scratch.blue);
            for k in 0..n {
                let dst = base_o + k * inner + i0;
                for q in 0..pw {
                    data[dst + q] = scratch.panel[q * a + k];
                }
            }
            i0 += pw;
        }
    }
}

/// Forward-transform, scale by `spec`, and inverse-transform every
/// contiguous `spec.len()`-line of `lines` with one plan.
fn spectrum_lines(lines: &mut [C64], p: &FftPlan, spec: &[f64], blue: &mut Vec<C64>) {
    p.batch_transform(lines, false, blue);
    for line in lines.chunks_exact_mut(spec.len()) {
        for (z, &e) in line.iter_mut().zip(spec) {
            *z = z.scale(e);
        }
    }
    p.batch_transform(lines, true, blue);
}

/// Reference O(n^2) DFT used by the tests.
#[doc(hidden)]
pub fn dft_naive(x: &[C64], inverse: bool) -> Vec<C64> {
    let n = x.len();
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut out = vec![C64::ZERO; n];
    for (k, o) in out.iter_mut().enumerate() {
        for (j, &v) in x.iter().enumerate() {
            *o += v * C64::cis(sign * 2.0 * std::f64::consts::PI * (j * k % n) as f64 / n as f64);
        }
    }
    if inverse {
        for v in out.iter_mut() {
            *v = v.scale(1.0 / n as f64);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: &[C64], b: &[C64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((*x - *y).abs() < tol, "{x:?} vs {y:?}");
        }
    }

    #[test]
    fn pow2_matches_naive() {
        for &n in &[1usize, 2, 4, 8, 64, 128] {
            let x: Vec<C64> = (0..n).map(|i| C64::new((i as f64).sin(), (i as f64 * 0.7).cos())).collect();
            let mut got = x.clone();
            plan(n).forward(&mut got);
            close(&got, &dft_naive(&x, false), 1e-9 * n as f64);
        }
    }

    #[test]
    fn bluestein_matches_naive() {
        for &n in &[3usize, 5, 6, 7, 12, 100, 255] {
            let x: Vec<C64> = (0..n).map(|i| C64::new((i as f64).cos(), (i as f64 * 1.3).sin())).collect();
            let mut got = x.clone();
            plan(n).forward(&mut got);
            close(&got, &dft_naive(&x, false), 1e-8 * n as f64);
        }
    }

    #[test]
    fn roundtrip() {
        for &n in &[8usize, 12, 31, 128, 1000] {
            let x: Vec<C64> = (0..n).map(|i| C64::new(i as f64, -(i as f64) * 0.5)).collect();
            let mut y = x.clone();
            let p = plan(n);
            p.forward(&mut y);
            p.inverse(&mut y);
            close(&y, &x, 1e-8 * n as f64);
        }
    }

    #[test]
    fn rfft_symmetric_input_gives_real_spectrum() {
        // Even (circularly symmetric) real input -> real spectrum.
        let n = 16;
        let mut x = vec![0.0f64; n];
        for i in 0..n {
            let d = i.min(n - i) as f64;
            x[i] = (-d * d / 8.0).exp();
        }
        let spec = rfft(&x);
        for z in &spec {
            assert!(z.im.abs() < 1e-10, "{z:?}");
        }
    }

    #[test]
    fn fftn_matches_axiswise_naive() {
        let shape = [3usize, 4, 5];
        let total: usize = shape.iter().product();
        let x: Vec<C64> = (0..total).map(|i| C64::new((i as f64).sin(), (i as f64).cos())).collect();
        let mut got = x.clone();
        fftn(&mut got, &shape, false);
        let mut want = x;
        // axis 2 (contiguous lines)
        for o in 0..12 {
            let line: Vec<C64> = want[o * 5..o * 5 + 5].to_vec();
            let f = dft_naive(&line, false);
            want[o * 5..o * 5 + 5].copy_from_slice(&f);
        }
        // axis 1
        for a in 0..3 {
            for c in 0..5 {
                let line: Vec<C64> = (0..4).map(|b| want[a * 20 + b * 5 + c]).collect();
                let f = dft_naive(&line, false);
                for b in 0..4 {
                    want[a * 20 + b * 5 + c] = f[b];
                }
            }
        }
        // axis 0
        for b in 0..4 {
            for c in 0..5 {
                let line: Vec<C64> = (0..3).map(|a| want[a * 20 + b * 5 + c]).collect();
                let f = dft_naive(&line, false);
                for a in 0..3 {
                    want[a * 20 + b * 5 + c] = f[a];
                }
            }
        }
        close(&got, &want, 1e-8);
    }

    #[test]
    fn fftn_roundtrip() {
        let shape = [4usize, 6];
        let total = 24;
        let x: Vec<C64> = (0..total).map(|i| C64::real(i as f64)).collect();
        let mut y = x.clone();
        fftn(&mut y, &shape, false);
        fftn(&mut y, &shape, true);
        close(&y, &x, 1e-9);
    }

    /// Property: the batched transform equals the per-line reference for
    /// mixed power-of-two / Bluestein shapes, forward and inverse, for
    /// batches large enough to exercise the panel tail paths.
    #[test]
    fn prop_fftn_batch_matches_per_line_fftn() {
        let shapes: [&[usize]; 6] =
            [&[8], &[12], &[4, 6], &[3, 5], &[2, 3, 4], &[5, 1, 7]];
        for shape in shapes {
            let per: usize = shape.iter().product();
            for &batch in &[1usize, 2, 3, 5] {
                let data: Vec<C64> = (0..batch * per)
                    .map(|i| C64::new((i as f64 * 0.61).sin(), (i as f64 * 0.37).cos()))
                    .collect();
                for &inverse in &[false, true] {
                    let mut got = data.clone();
                    let mut scratch = FftScratch::default();
                    fftn_batch(&mut got, batch, shape, inverse, &mut scratch);
                    let mut want = data.clone();
                    for item in want.chunks_exact_mut(per) {
                        fftn(item, shape, inverse);
                    }
                    close(&got, &want, 1e-9 * per as f64);
                }
            }
        }
    }

    /// Property: forward_batch/inverse_batch round-trip every line, for
    /// both radix-2 and Bluestein plans.
    #[test]
    fn prop_batch_roundtrip() {
        for &n in &[4usize, 12, 31, 64] {
            let p = plan(n);
            let lines = 5;
            let x: Vec<C64> =
                (0..lines * n).map(|i| C64::new(i as f64 * 0.3, -(i as f64) * 0.7)).collect();
            let mut y = x.clone();
            p.forward_batch(&mut y);
            p.inverse_batch(&mut y);
            close(&y, &x, 1e-8 * n as f64);
        }
    }

    /// The two-for-one packing is exact: the packed spectrum splits into
    /// the individual real-input spectra, and pack -> forward -> inverse
    /// -> unpack reproduces both rows.
    #[test]
    fn two_for_one_packing_round_trips() {
        for &n in &[8usize, 12, 33] {
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.9).sin() + 0.3).collect();
            let y: Vec<f64> = (0..n).map(|i| (i as f64 * 0.4).cos() - 0.1).collect();
            let block: Vec<f64> = x.iter().chain(y.iter()).copied().collect();
            let mut packed = Vec::new();
            pack_real_pairs(&block, n, &mut packed);
            assert_eq!(packed.len(), n);
            let p = plan(n);
            p.forward(&mut packed);
            // Split must match the individually transformed spectra.
            let mut xs = vec![C64::ZERO; n];
            let mut ys = vec![C64::ZERO; n];
            split_packed_spectrum(&packed, &mut xs, &mut ys);
            close(&xs, &rfft(&x), 1e-9 * n as f64);
            close(&ys, &rfft(&y), 1e-9 * n as f64);
            // And the packed round-trip recovers both rows.
            p.inverse(&mut packed);
            let mut back = vec![0.0; 2 * n];
            unpack_real_pairs(&packed, n, 2, &mut back);
            for (g, w) in back.iter().zip(&block) {
                assert!((g - w).abs() < 1e-10, "{g} vs {w}");
            }
        }
    }

    /// Odd batches pad the unpaired last row with a zero imaginary part.
    #[test]
    fn two_for_one_handles_odd_batches() {
        let n = 10;
        let rows = 3;
        let block: Vec<f64> = (0..rows * n).map(|i| (i as f64 * 0.17).sin()).collect();
        let spec = vec![1.0; n]; // identity spectrum
        let mut out = vec![0.0; rows * n];
        let mut ws = Workspace::new();
        apply_real_spectrum_batch(&block, &mut out, &[n], &spec, |e| e, &mut ws);
        for (g, w) in out.iter().zip(&block) {
            assert!((g - w).abs() < 1e-10, "{g} vs {w}");
        }
    }

    /// apply_real_spectrum_batch equals the per-vector reference
    /// (forward, scale, inverse) on a 2-D Bluestein shape.
    #[test]
    fn spectrum_batch_matches_per_vector() {
        let shape = [6usize, 5];
        let m = 30;
        let rows = 4;
        let spec: Vec<f64> = (0..m).map(|i| 0.5 + (i as f64 * 0.23).cos().abs()).collect();
        let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.13).sin()).collect();
        let mut got = vec![0.0; rows * m];
        let mut ws = Workspace::new();
        apply_real_spectrum_batch(&block, &mut got, &shape, &spec, |e| e, &mut ws);
        for r in 0..rows {
            let mut buf: Vec<C64> =
                block[r * m..(r + 1) * m].iter().map(|&v| C64::real(v)).collect();
            fftn(&mut buf, &shape, false);
            for (z, &e) in buf.iter_mut().zip(&spec) {
                *z = z.scale(e);
            }
            fftn(&mut buf, &shape, true);
            for (k, z) in buf.iter().enumerate() {
                let g = got[r * m + k];
                assert!((g - z.re).abs() < 1e-10, "row {r}: {g} vs {}", z.re);
            }
        }
    }

    /// The thread-local plan cache stays under its size cap no matter how
    /// many distinct lengths a thread requests.
    #[test]
    fn plan_cache_is_size_capped() {
        for n in 2..(3 * PLAN_CACHE_CAP + 2) {
            let p = plan(n);
            assert_eq!(p.len(), n);
            assert!(
                plan_cache_len() <= PLAN_CACHE_CAP,
                "cache grew to {} (> {PLAN_CACHE_CAP})",
                plan_cache_len()
            );
        }
        // Evicted lengths rebuild transparently.
        let p = plan(2);
        assert_eq!(p.len(), 2);
    }
}
