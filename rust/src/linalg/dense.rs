//! Row-major dense matrices with just enough functionality for the exact-GP
//! baseline, the inducing-point baselines (FITC/SSGP/SVI) and the
//! projection experiments.

/// A row-major dense `rows x cols` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage, `data[r * cols + c]`.
    pub data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutably borrow row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Matrix product `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj loop order: stream over `other`'s rows for cache friendliness.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows).map(|r| dot(self.row(r), v)).collect()
    }

    /// `self^T * v`.
    pub fn tmatvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len());
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            let s = v[r];
            for (o, &a) in out.iter_mut().zip(row) {
                *o += s * a;
            }
        }
        out
    }

    /// Elementwise scaled addition: `self += s * other`.
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Scale every entry.
    pub fn scale(&mut self, s: f64) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Frobenius norm.
    pub fn frob(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Trace (square matrices).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Solve `self * x = b` by Gaussian elimination with partial pivoting.
    /// `self` is consumed as workspace. For SPD systems prefer
    /// [`crate::linalg::cholesky::Chol`].
    pub fn solve(mut self, b: &[f64]) -> Option<Vec<f64>> {
        let n = self.rows;
        assert_eq!(self.cols, n);
        assert_eq!(b.len(), n);
        let mut x = b.to_vec();
        for col in 0..n {
            // Pivot.
            let mut piv = col;
            let mut best = self[(col, col)].abs();
            for r in col + 1..n {
                let v = self[(r, col)].abs();
                if v > best {
                    best = v;
                    piv = r;
                }
            }
            if best < 1e-300 {
                return None;
            }
            if piv != col {
                for c in 0..n {
                    let tmp = self[(col, c)];
                    self[(col, c)] = self[(piv, c)];
                    self[(piv, c)] = tmp;
                }
                x.swap(col, piv);
            }
            let d = self[(col, col)];
            for r in col + 1..n {
                let f = self[(r, col)] / d;
                if f == 0.0 {
                    continue;
                }
                for c in col..n {
                    let v = self[(col, c)];
                    self[(r, c)] -= f * v;
                }
                x[r] -= f * x[col];
            }
        }
        // Back substitution.
        for col in (0..n).rev() {
            let mut s = x[col];
            for c in col + 1..n {
                s -= self[(col, c)] * x[c];
            }
            x[col] = s / self[(col, col)];
        }
        Some(x)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline(always)]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline(always)]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

/// Dot product of two slices.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation; the compiler vectorizes this reliably.
    let mut acc = [0.0f64; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// `y += s * x`.
#[inline]
pub fn axpy(y: &mut [f64], s: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += s * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |r, c| (r * 3 + c) as f64);
        let i = Mat::eye(3);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matvec_tmatvec() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.matvec(&[1., 0., -1.]), vec![-2., -2.]);
        assert_eq!(a.tmatvec(&[1., -1.]), vec![-3., -3., -3.]);
    }

    #[test]
    fn solve_random() {
        let a = Mat::from_vec(3, 3, vec![4., 1., 0., 1., 3., 1., 0., 1., 2.]);
        let x_true = [1., -2., 0.5];
        let b = a.matvec(&x_true);
        let x = a.solve(&b).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_singular_returns_none() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 2., 4.]);
        assert!(a.solve(&[1., 2.]).is_none());
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_fn(2, 4, |r, c| (r + 10 * c) as f64);
        assert_eq!(a.t().t(), a);
    }
}
