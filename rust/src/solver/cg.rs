//! Preconditioned linear conjugate gradients (LCG).
//!
//! MSGP inference solves `(K_SKI + sigma^2 I)^{-1} y` with CG, whose per-
//! iteration cost is one MVM — O(n + m log m) with the SKI structure
//! (section 4). Circulant/BCCB preconditioners (section 5.2) act as cheap
//! approximate inverses and cut the iteration count substantially.
//!
//! The streaming/sharded m-domain refresh operator
//! `B = sigma^2 I + sf2 S G S` (with `S = K_UU^{1/2}` the circulant
//! square root and `G = W^T W` the banded Gram) supports a pluggable
//! [`Preconditioner`]:
//!
//! * [`Preconditioner::Jacobi`] — the diagonal
//!   `d_i = sigma^2 + sf2 s0^2 G_ii` built from the tracked `diag(G)`
//!   and the constant circulant diagonal `s0` of `S`. O(m) setup, O(m)
//!   per application; corrects point-wise occupancy variation only.
//! * [`Preconditioner::Spectral`] — a true BCCB approximate inverse
//!   `M^{-1} = (sigma^2 I + sf2 rho C)^{-1}` with `C = S S` the
//!   multi-level (Whittle) circulant approximation of `K_UU` and
//!   `rho = trace(G) / m` the mean cell occupancy standing in for
//!   `G ~= rho I`. Applied exactly in O(m log m) in the Fourier domain,
//!   it collapses the spectral spread of `C` — the dominant source of
//!   ill-conditioning on smooth kernels — which a diagonal cannot touch.
//!
//! The enum is *consumed by the refresh paths*
//! ([`crate::stream::trainer`]), not by [`cg_solve`] itself, whose
//! `precond` argument stays an explicit closure.
//!
//! [`cg_solve_block`] is the multi-RHS form: `b` systems against one
//! operator advance in lockstep, each column running the exact scalar CG
//! recurrence it would run alone (so per-column iterates match
//! [`cg_solve`] bit-for-bit up to operator rounding) while the operator
//! and preconditioner are applied to the whole block at once — one
//! batched FFT pass per iteration instead of one per RHS. Columns that
//! reach tolerance are **physically compacted out** of the block handed
//! to the operator, so uneven warm starts stop paying for finished
//! systems ([`BlockCgResult::apply_cols`] accounts for the columns
//! actually applied). The batched applies themselves fan out over the
//! in-tree thread pool ([`crate::parallel`]) through the FFT engine.
//! The streaming m-domain refresh uses this to solve the mean and all
//! `n_s` variance-probe systems as a single block.

use crate::linalg::dense::{axpy, dot};

/// Which preconditioner the m-domain refresh builds for
/// `B = sigma^2 I + sf2 S G S` (see the [module docs](self) for the
/// operator algebra of each variant).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Preconditioner {
    /// Unpreconditioned CG.
    #[default]
    None,
    /// Diagonal scaling `sigma^2 + sf2 s0^2 diag(G)`.
    Jacobi,
    /// BCCB approximate inverse `(sigma^2 I + sf2 rho C)^{-1}`, applied
    /// in O(m log m) via the circulant eigendecomposition.
    Spectral,
}

impl Preconditioner {
    /// Display name (used by benches and `/metrics`).
    pub fn name(self) -> &'static str {
        match self {
            Preconditioner::None => "none",
            Preconditioner::Jacobi => "jacobi",
            Preconditioner::Spectral => "spectral",
        }
    }
}

/// CG stopping options.
#[derive(Clone, Copy, Debug)]
pub struct CgOptions {
    /// Relative residual tolerance `||r|| / ||b||`.
    pub tol: f64,
    /// Iteration cap.
    pub max_iter: usize,
    /// Warm start: when `true`, the contents of `x` on entry are used as
    /// the initial guess `x0` (the streaming trainer passes the previous
    /// solution); when `false` (the default), `x` is zeroed first so a
    /// stale buffer can never poison a cold solve.
    pub warm_start: bool,
    /// Preconditioner for the streaming m-domain refresh operator
    /// `sigma^2 I + sf2 S G S` (see [`Preconditioner`]). `None` by
    /// default at this level; the streaming/sharded configs default to
    /// `Spectral`. The choice is consumed by the refresh paths, not by
    /// [`cg_solve`] itself (whose `precond` argument stays explicit).
    pub precondition: Preconditioner,
    /// Soft wall-clock deadline for [`cg_solve_block`]: checked once per
    /// block iteration (never mid-iteration, so per-column arithmetic is
    /// untouched). When it passes, the solve stops and reports
    /// [`BlockCgResult::deadline_hit`]; the caller decides whether the
    /// partial solution is servable. The streaming refresh wires this
    /// from `MSGP_REFRESH_DEADLINE_MS` to keep a degraded-but-live
    /// serving snapshot instead of blocking on a pathological solve.
    /// `None` (the default) means no deadline. Scalar [`cg_solve`]
    /// ignores it.
    pub deadline: Option<std::time::Instant>,
}

impl Default for CgOptions {
    fn default() -> Self {
        CgOptions {
            tol: 1e-8,
            max_iter: 1000,
            warm_start: false,
            precondition: Preconditioner::None,
            deadline: None,
        }
    }
}

impl CgOptions {
    /// Same options with warm starting enabled.
    pub fn warm(mut self) -> Self {
        self.warm_start = true;
        self
    }

    /// Same options with Jacobi preconditioning selected.
    pub fn jacobi(mut self) -> Self {
        self.precondition = Preconditioner::Jacobi;
        self
    }

    /// Same options with spectral (BCCB) preconditioning selected.
    pub fn spectral(mut self) -> Self {
        self.precondition = Preconditioner::Spectral;
        self
    }

    /// Same options with a soft block-solve deadline `ms` milliseconds
    /// from now (`None` clears any deadline).
    pub fn with_deadline_ms(mut self, ms: Option<u64>) -> Self {
        self.deadline =
            ms.map(|v| std::time::Instant::now() + std::time::Duration::from_millis(v));
        self
    }
}

/// Outcome of a CG solve.
#[derive(Clone, Debug)]
pub struct CgResult {
    /// Iterations used.
    pub iters: usize,
    /// Final relative residual.
    pub rel_residual: f64,
    /// Whether the tolerance was reached within the iteration cap.
    pub converged: bool,
}

/// Reusable CG buffers — keeps the hot loop allocation-free.
#[derive(Clone, Debug, Default)]
pub struct CgWorkspace {
    r: Vec<f64>,
    z: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
}

impl CgWorkspace {
    /// Create a workspace for systems of size `n`.
    pub fn new(n: usize) -> Self {
        CgWorkspace { r: vec![0.0; n], z: vec![0.0; n], p: vec![0.0; n], ap: vec![0.0; n] }
    }

    fn resize(&mut self, n: usize) {
        if self.r.len() != n {
            self.r.resize(n, 0.0);
            self.z.resize(n, 0.0);
            self.p.resize(n, 0.0);
            self.ap.resize(n, 0.0);
        }
    }
}

/// Solve `A x = b` with preconditioned CG.
///
/// * `apply_a(v, out)` computes `out = A v`.
/// * `precond(v, out)` computes `out = M^{-1} v` (pass an identity copy for
///   unpreconditioned CG).
/// * `x` holds the initial guess on entry and the solution on exit.
// lint:hot
pub fn cg_solve(
    mut apply_a: impl FnMut(&[f64], &mut [f64]),
    mut precond: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    x: &mut [f64],
    opts: CgOptions,
    ws: &mut CgWorkspace,
) -> CgResult {
    let _sp = crate::span!("cg.solve");
    let n = b.len();
    assert_eq!(x.len(), n);
    ws.resize(n);
    if !opts.warm_start {
        x.fill(0.0);
    }
    let bnorm = dot(b, b).sqrt();
    if bnorm == 0.0 {
        x.fill(0.0);
        return CgResult { iters: 0, rel_residual: 0.0, converged: true };
    }
    // r = b - A x (with x = x0 when warm starting, x = 0 otherwise).
    apply_a(x, &mut ws.ap);
    for i in 0..n {
        ws.r[i] = b[i] - ws.ap[i];
    }
    precond(&ws.r, &mut ws.z);
    ws.p.copy_from_slice(&ws.z);
    let mut rz = dot(&ws.r, &ws.z);
    let mut rel = dot(&ws.r, &ws.r).sqrt() / bnorm;
    let mut iters = 0;
    while rel > opts.tol && iters < opts.max_iter {
        apply_a(&ws.p, &mut ws.ap);
        let pap = dot(&ws.p, &ws.ap);
        if pap <= 0.0 || !pap.is_finite() {
            // Not SPD to working precision (e.g. aggressive circulant
            // approximation); bail with what we have.
            break;
        }
        let alpha = rz / pap;
        axpy(x, alpha, &ws.p);
        axpy(&mut ws.r, -alpha, &ws.ap);
        rel = dot(&ws.r, &ws.r).sqrt() / bnorm;
        iters += 1;
        if rel <= opts.tol {
            break;
        }
        precond(&ws.r, &mut ws.z);
        let rz_new = dot(&ws.r, &ws.z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            ws.p[i] = ws.z[i] + beta * ws.p[i];
        }
    }
    CgResult { iters, rel_residual: rel, converged: rel <= opts.tol }
}

/// Outcome of a lockstep multi-RHS CG solve.
#[derive(Clone, Debug)]
pub struct BlockCgResult {
    /// Lockstep block iterations (the slowest column's count).
    pub block_iters: usize,
    /// Iteration at which each column converged (or froze on a
    /// non-SPD breakdown / the iteration cap) — comparable to the
    /// sequential [`CgResult::iters`] per system.
    pub col_iters: Vec<usize>,
    /// Final per-column relative residuals.
    pub rel_residuals: Vec<f64>,
    /// Every column reached the tolerance within the iteration cap.
    pub converged: bool,
    /// Total *columns* pushed through `apply_a` (the initial full-block
    /// residual plus one **compacted** active block per iteration).
    /// Without compaction this would be `(block_iters + 1) * cols`;
    /// with it, converged columns stop paying operator applies, so on
    /// uneven warm starts `apply_cols` is strictly smaller. The G-apply
    /// accounting tests pin against this.
    pub apply_cols: usize,
    /// The solve stopped because [`CgOptions::deadline`] passed (some
    /// columns froze mid-flight with their current iterates). Always
    /// `false` when no deadline is set.
    pub deadline_hit: bool,
}

/// Reusable block-CG buffers (`cols` systems of size `n` each) — keeps
/// the lockstep hot loop allocation-free. The `*c` buffers hold the
/// physically compacted active block handed to the batched operator /
/// preconditioner.
#[derive(Clone, Debug, Default)]
pub struct BlockCgWorkspace {
    r: Vec<f64>,
    z: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
    /// Compacted active search directions (`live x n`).
    pc: Vec<f64>,
    /// Compacted operator outputs (`live x n`).
    apc: Vec<f64>,
    /// Compacted active residuals (`live x n`).
    rc: Vec<f64>,
    /// Compacted preconditioned residuals (`live x n`).
    zc: Vec<f64>,
    rz: Vec<f64>,
    bnorm: Vec<f64>,
    rel: Vec<f64>,
    active: Vec<bool>,
    /// Indices of the still-iterating columns, in column order.
    live: Vec<usize>,
}

impl BlockCgWorkspace {
    /// Create a workspace for `cols` systems of size `n`.
    pub fn new(n: usize, cols: usize) -> Self {
        let mut ws = Self::default();
        ws.resize(n, cols);
        ws
    }

    fn resize(&mut self, n: usize, cols: usize) {
        let total = n * cols;
        if self.r.len() != total {
            self.r.resize(total, 0.0);
            self.z.resize(total, 0.0);
            self.p.resize(total, 0.0);
            self.ap.resize(total, 0.0);
            self.pc.resize(total, 0.0);
            self.apc.resize(total, 0.0);
            self.rc.resize(total, 0.0);
            self.zc.resize(total, 0.0);
        }
        if self.rz.len() != cols {
            self.rz.resize(cols, 0.0);
            self.bnorm.resize(cols, 0.0);
            self.rel.resize(cols, 0.0);
            self.active.resize(cols, false);
        }
        self.live.clear();
    }
}

/// Solve `A X = B` for `cols = b.len() / n` right-hand sides with
/// lockstep preconditioned CG and per-column convergence masking.
///
/// * `apply_a(v, out)` computes the **batched** operator apply
///   `out = A v` column-by-column over a row-major `cols x n` block.
/// * `precond(v, out)` computes the batched `out = M^{-1} v`.
/// * `b` / `x` are row-major `cols x n` blocks; `x` holds the per-column
///   initial guesses on entry (honored when `opts.warm_start`) and the
///   solutions on exit.
///
/// Each column runs the scalar CG recurrence of [`cg_solve`] with its own
/// `alpha`/`beta`/residual, so per-column results match `cols` sequential
/// solves (up to the rounding of the batched operator); converged or
/// broken-down columns stop participating while the block keeps
/// iterating until all columns finish. The payoff: one batched operator
/// + preconditioner application per iteration instead of one *solve*
/// per RHS.
///
/// **Active-column compaction**: finished columns are physically
/// compacted out of the block handed to `apply_a` / `precond` — each
/// iteration packs the live search directions (and residuals)
/// contiguously, applies the operator to that `live x n` sub-block
/// only, and scatters the updates back by column index. Uneven warm
/// starts therefore never pay full-block operator work until the
/// slowest column finishes ([`BlockCgResult::apply_cols`] accounts for
/// exactly the columns applied). Both closures must accept any
/// `k x n` block with `k <= cols` (all in-crate batched operators key
/// their width off `v.len()`). Compaction does not change any column's
/// arithmetic: each column sees the identical scalar recurrence at
/// every block composition.
// lint:hot
pub fn cg_solve_block(
    mut apply_a: impl FnMut(&[f64], &mut [f64]),
    mut precond: impl FnMut(&[f64], &mut [f64]),
    b: &[f64],
    x: &mut [f64],
    n: usize,
    opts: CgOptions,
    ws: &mut BlockCgWorkspace,
) -> BlockCgResult {
    let _sp = crate::span!("cg.block_solve");
    assert!(n > 0 && b.len() % n == 0, "b is cols x n row-major");
    let cols = b.len() / n;
    assert_eq!(x.len(), b.len());
    ws.resize(n, cols);
    if !opts.warm_start {
        x.fill(0.0);
    }
    // Initial residual block: one batched full-block apply (covers warm
    // starts).
    apply_a(x, &mut ws.ap);
    let mut apply_cols = cols;
    for i in 0..b.len() {
        ws.r[i] = b[i] - ws.ap[i];
    }
    precond(&ws.r, &mut ws.z);
    ws.p.copy_from_slice(&ws.z);
    // lint:allow(alloc, "per-solve result buffer, cols words; the per-
    // iteration loop below is allocation-free")
    let mut col_iters = vec![0usize; cols];
    ws.live.clear();
    for c in 0..cols {
        let (lo, hi) = (c * n, (c + 1) * n);
        let bc = &b[lo..hi];
        ws.bnorm[c] = dot(bc, bc).sqrt();
        if ws.bnorm[c] == 0.0 {
            // Zero RHS: solution is zero, converged immediately.
            x[lo..hi].fill(0.0);
            ws.rel[c] = 0.0;
            ws.active[c] = false;
            continue;
        }
        ws.rz[c] = dot(&ws.r[lo..hi], &ws.z[lo..hi]);
        ws.rel[c] = dot(&ws.r[lo..hi], &ws.r[lo..hi]).sqrt() / ws.bnorm[c];
        ws.active[c] = ws.rel[c] > opts.tol;
        if ws.active[c] {
            ws.live.push(c);
        }
    }
    let mut iters = 0usize;
    let mut deadline_hit = false;
    while !ws.live.is_empty() && iters < opts.max_iter {
        // Soft deadline: abort *between* block iterations only, so no
        // column ever sees a torn scalar recurrence. Checked before the
        // operator apply — the expensive part of the iteration.
        if let Some(dl) = opts.deadline {
            if std::time::Instant::now() >= dl {
                deadline_hit = true;
                break;
            }
        }
        // Compact the live search directions and apply the operator to
        // the active sub-block only.
        let nl = ws.live.len();
        for (j, &c) in ws.live.iter().enumerate() {
            ws.pc[j * n..(j + 1) * n].copy_from_slice(&ws.p[c * n..(c + 1) * n]);
        }
        apply_a(&ws.pc[..nl * n], &mut ws.apc[..nl * n]);
        apply_cols += nl;
        for j in 0..nl {
            let c = ws.live[j];
            let (clo, chi) = (j * n, (j + 1) * n);
            let (lo, hi) = (c * n, (c + 1) * n);
            let mut pap = dot(&ws.pc[clo..chi], &ws.apc[clo..chi]);
            // Chaos hook: force this column onto the non-SPD bail path.
            crate::failpoint!("cg.nonspd", { pap = f64::NAN });
            if pap <= 0.0 || !pap.is_finite() {
                // This column's operator is not SPD to working precision;
                // freeze it with what it has (mirrors cg_solve's bail).
                ws.active[c] = false;
                col_iters[c] = iters;
                continue;
            }
            let alpha = ws.rz[c] / pap;
            axpy(&mut x[lo..hi], alpha, &ws.pc[clo..chi]);
            axpy(&mut ws.r[lo..hi], -alpha, &ws.apc[clo..chi]);
            ws.rel[c] = dot(&ws.r[lo..hi], &ws.r[lo..hi]).sqrt() / ws.bnorm[c];
            if ws.rel[c] <= opts.tol {
                ws.active[c] = false;
                col_iters[c] = iters + 1;
            }
        }
        iters += 1;
        // Physically drop finished columns before the preconditioner.
        let active = &ws.active;
        ws.live.retain(|&c| active[c]);
        if ws.live.is_empty() {
            break;
        }
        let nl = ws.live.len();
        for (j, &c) in ws.live.iter().enumerate() {
            ws.rc[j * n..(j + 1) * n].copy_from_slice(&ws.r[c * n..(c + 1) * n]);
        }
        precond(&ws.rc[..nl * n], &mut ws.zc[..nl * n]);
        for j in 0..nl {
            let c = ws.live[j];
            let (clo, chi) = (j * n, (j + 1) * n);
            let rz_new = dot(&ws.rc[clo..chi], &ws.zc[clo..chi]);
            let beta = rz_new / ws.rz[c];
            ws.rz[c] = rz_new;
            for (pi, &zi) in
                ws.p[c * n..(c + 1) * n].iter_mut().zip(&ws.zc[clo..chi])
            {
                *pi = zi + beta * *pi;
            }
        }
    }
    // Columns still live hit the iteration cap.
    for &c in &ws.live {
        col_iters[c] = iters;
        ws.active[c] = false;
    }
    ws.live.clear();
    let converged = ws.rel.iter().all(|&r| r <= opts.tol);
    BlockCgResult {
        block_iters: iters,
        col_iters,
        // lint:allow(alloc, "result assembly, once per solve")
        rel_residuals: ws.rel.clone(),
        converged,
        apply_cols,
        deadline_hit,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn spd(n: usize) -> Mat {
        let b = Mat::from_fn(n, n, |r, c| (((r + 2) * (c + 3)) % 7) as f64 * 0.2);
        let mut a = b.matmul(&b.t());
        for i in 0..n {
            a[(i, i)] += 1.0 + i as f64 * 0.1;
        }
        a
    }

    #[test]
    fn solves_spd_system() {
        let n = 24;
        let a = spd(n);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let b = a.matvec(&x_true);
        let mut x = vec![0.0; n];
        let mut ws = CgWorkspace::new(n);
        let res = cg_solve(
            |v, out| out.copy_from_slice(&a.matvec(v)),
            |v, out| out.copy_from_slice(v),
            &b,
            &mut x,
            CgOptions { tol: 1e-10, max_iter: 500, warm_start: false, ..Default::default() },
            &mut ws,
        );
        assert!(res.converged, "{res:?}");
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-7);
        }
    }

    #[test]
    fn preconditioner_reduces_iterations() {
        // Diagonal-dominant ill-conditioned system; Jacobi preconditioner
        // must not increase the iteration count.
        let n = 64;
        let mut a = spd(n);
        for i in 0..n {
            a[(i, i)] += (i as f64 + 1.0) * 10.0;
        }
        let b: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let opts =
            CgOptions { tol: 1e-10, max_iter: 2000, warm_start: false, ..Default::default() };
        let mut ws = CgWorkspace::new(n);
        let mut x0 = vec![0.0; n];
        let plain = cg_solve(
            |v, out| out.copy_from_slice(&a.matvec(v)),
            |v, out| out.copy_from_slice(v),
            &b,
            &mut x0,
            opts,
            &mut ws,
        );
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        let mut x1 = vec![0.0; n];
        let pre = cg_solve(
            |v, out| out.copy_from_slice(&a.matvec(v)),
            |v, out| {
                for i in 0..v.len() {
                    out[i] = v[i] / diag[i];
                }
            },
            &b,
            &mut x1,
            opts,
            &mut ws,
        );
        assert!(pre.converged && plain.converged);
        assert!(pre.iters <= plain.iters, "pre {} vs plain {}", pre.iters, plain.iters);
        for (p, q) in x0.iter().zip(&x1) {
            assert!((p - q).abs() < 1e-6);
        }
    }

    #[test]
    fn warm_start_uses_fewer_iterations_than_cold() {
        // Solve A x = b, then re-solve against a slightly perturbed rhs:
        // warm-starting from the previous solution must converge in
        // strictly fewer iterations than a cold start (and to the same
        // answer).
        let n = 48;
        let a = spd(n);
        let b0: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin()).collect();
        let opts =
            CgOptions { tol: 1e-10, max_iter: 2000, warm_start: false, ..Default::default() };
        let mut ws = CgWorkspace::new(n);
        let mut x = vec![0.0; n];
        let first = cg_solve(
            |v, out| out.copy_from_slice(&a.matvec(v)),
            |v, out| out.copy_from_slice(v),
            &b0,
            &mut x,
            opts,
            &mut ws,
        );
        assert!(first.converged);
        // Perturb the rhs by 1%.
        let b1: Vec<f64> = b0.iter().enumerate().map(|(i, v)| v + 0.01 * (i as f64).cos()).collect();
        let mut x_warm = x.clone();
        let warm = cg_solve(
            |v, out| out.copy_from_slice(&a.matvec(v)),
            |v, out| out.copy_from_slice(v),
            &b1,
            &mut x_warm,
            opts.warm(),
            &mut ws,
        );
        let mut x_cold = x.clone(); // contents ignored: warm_start = false zeroes it
        let cold = cg_solve(
            |v, out| out.copy_from_slice(&a.matvec(v)),
            |v, out| out.copy_from_slice(v),
            &b1,
            &mut x_cold,
            opts,
            &mut ws,
        );
        assert!(warm.converged && cold.converged);
        assert!(
            warm.iters < cold.iters,
            "warm {} !< cold {}",
            warm.iters,
            cold.iters
        );
        for (p, q) in x_warm.iter().zip(&x_cold) {
            assert!((p - q).abs() < 1e-6);
        }
    }

    #[test]
    fn cold_start_ignores_stale_x_contents() {
        let n = 16;
        let a = spd(n);
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.11).cos()).collect();
        let b = a.matvec(&x_true);
        let mut x = vec![1e6; n]; // garbage that a cold start must discard
        let mut ws = CgWorkspace::new(n);
        let res = cg_solve(
            |v, out| out.copy_from_slice(&a.matvec(v)),
            |v, out| out.copy_from_slice(v),
            &b,
            &mut x,
            CgOptions { tol: 1e-10, max_iter: 500, warm_start: false, ..Default::default() },
            &mut ws,
        );
        assert!(res.converged);
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-7);
        }
    }

    /// Lockstep block CG reproduces the per-system sequential solves:
    /// same solutions, same per-column iteration counts, and the block
    /// iteration count equals the slowest column's.
    #[test]
    fn block_solve_matches_sequential_solves() {
        let n = 32;
        let a = spd(n);
        let cols = 4;
        let b: Vec<f64> = (0..cols * n).map(|i| (i as f64 * 0.21).sin()).collect();
        let opts =
            CgOptions { tol: 1e-12, max_iter: 2000, warm_start: false, ..Default::default() };
        // Sequential reference.
        let mut xs_seq = vec![0.0; cols * n];
        let mut seq_iters = Vec::new();
        let mut ws = CgWorkspace::new(n);
        for c in 0..cols {
            let res = cg_solve(
                |v, out| out.copy_from_slice(&a.matvec(v)),
                |v, out| out.copy_from_slice(v),
                &b[c * n..(c + 1) * n],
                &mut xs_seq[c * n..(c + 1) * n],
                opts,
                &mut ws,
            );
            assert!(res.converged);
            seq_iters.push(res.iters);
        }
        // Block path: the batched apply runs the identical dense MVM per
        // column (deriving its width from the compacted block), so
        // iterates match exactly.
        let mut xs_blk = vec![0.0; cols * n];
        let mut bws = BlockCgWorkspace::new(n, cols);
        let res = cg_solve_block(
            |v, out| {
                for c in 0..v.len() / n {
                    out[c * n..(c + 1) * n].copy_from_slice(&a.matvec(&v[c * n..(c + 1) * n]));
                }
            },
            |v, out| out.copy_from_slice(v),
            &b,
            &mut xs_blk,
            n,
            opts,
            &mut bws,
        );
        assert!(res.converged, "{res:?}");
        assert_eq!(res.col_iters, seq_iters, "lockstep columns must match sequential");
        assert_eq!(
            res.block_iters,
            *seq_iters.iter().max().unwrap(),
            "block iterations = slowest column"
        );
        // Compaction accounting: never more column-applies than the
        // uncompacted lockstep, never fewer than one per iteration plus
        // the initial block.
        assert!(res.apply_cols <= (res.block_iters + 1) * cols);
        assert!(res.apply_cols >= res.block_iters + cols);
        for (g, w) in xs_blk.iter().zip(&xs_seq) {
            assert!((g - w).abs() < 1e-12, "{g} vs {w}");
        }
    }

    /// Converged columns are compacted out: a warm-started column stops
    /// early while a cold one keeps iterating, the finished column's
    /// solution is untouched afterwards, and the operator-work
    /// accounting shows it stopped paying for applies.
    #[test]
    fn block_solve_compacts_converged_columns() {
        let n = 48;
        let a = spd(n);
        let opts = CgOptions { tol: 1e-10, max_iter: 2000, warm_start: false, ..Default::default() };
        let apply = |v: &[f64], out: &mut [f64]| {
            for c in 0..v.len() / n {
                out[c * n..(c + 1) * n].copy_from_slice(&a.matvec(&v[c * n..(c + 1) * n]));
            }
        };
        let id = |v: &[f64], out: &mut [f64]| out.copy_from_slice(v);
        // Solve column 0 alone first to get a near-exact warm start.
        let b: Vec<f64> = (0..2 * n).map(|i| 1.0 + (i as f64 * 0.4).cos()).collect();
        let mut x0 = vec![0.0; n];
        let mut ws = CgWorkspace::new(n);
        let pre = cg_solve(
            |v, out| out.copy_from_slice(&a.matvec(v)),
            |v, out| out.copy_from_slice(v),
            &b[..n],
            &mut x0,
            CgOptions { tol: 1e-6, ..opts },
            &mut ws,
        );
        assert!(pre.converged);
        // Block: column 0 warm-started near its solution, column 1 cold.
        let mut x = vec![0.0; 2 * n];
        x[..n].copy_from_slice(&x0);
        let mut bws = BlockCgWorkspace::new(n, 2);
        let res = cg_solve_block(apply, id, &b, &mut x, n, opts.warm(), &mut bws);
        assert!(res.converged);
        assert!(
            res.col_iters[0] < res.col_iters[1],
            "warm column must finish first: {:?}",
            res.col_iters
        );
        assert_eq!(res.block_iters, res.col_iters[1]);
        // Compaction: the early column stopped riding through the
        // operator, so total column-applies are strictly fewer than the
        // uncompacted lockstep would pay.
        assert!(
            res.apply_cols < (res.block_iters + 1) * 2,
            "apply_cols {} vs uncompacted {}",
            res.apply_cols,
            (res.block_iters + 1) * 2
        );
        assert_eq!(
            res.apply_cols,
            2 + res.col_iters[0] + res.col_iters[1],
            "each column pays the initial block plus its own iterations"
        );
        // The finished column's solution solves its system.
        let want = {
            let mut w = vec![0.0; n];
            let mut ws2 = CgWorkspace::new(n);
            cg_solve(
                |v, out| out.copy_from_slice(&a.matvec(v)),
                |v, out| out.copy_from_slice(v),
                &b[..n],
                &mut w,
                opts,
                &mut ws2,
            );
            w
        };
        for (g, w) in x[..n].iter().zip(&want) {
            assert!((g - w).abs() < 1e-7, "{g} vs {w}");
        }
    }

    /// Acceptance (satellite): on a block with one hard and many easy
    /// columns (uneven warm starts — the refresh scenario where most
    /// probe systems barely changed), compaction performs strictly
    /// fewer operator column-applies than the uncompacted lockstep
    /// block — pinned by counting the columns actually pushed through
    /// `apply_a`.
    #[test]
    fn compaction_beats_uncompacted_on_uneven_block() {
        let n = 40;
        let mut a = spd(n);
        for i in 0..n {
            a[(i, i)] += (i as f64).powi(2) * 3.0;
        }
        let cols = 6;
        let b: Vec<f64> = (0..cols * n).map(|i| (i as f64 * 0.29).sin() + 0.7).collect();
        // Easy columns 1.. are warm-started at their exact solutions
        // (dense solve); the hard column 0 starts cold.
        let mut x = vec![0.0; cols * n];
        for c in 1..cols {
            let sol = a.clone().solve(&b[c * n..(c + 1) * n]).expect("SPD system");
            x[c * n..(c + 1) * n].copy_from_slice(&sol);
        }
        let mut applied_cols = 0usize;
        let mut bws = BlockCgWorkspace::new(n, cols);
        let res = cg_solve_block(
            |v, out| {
                let k = v.len() / n;
                applied_cols += k;
                for c in 0..k {
                    out[c * n..(c + 1) * n].copy_from_slice(&a.matvec(&v[c * n..(c + 1) * n]));
                }
            },
            |v, out| out.copy_from_slice(v),
            &b,
            &mut x,
            n,
            CgOptions { tol: 1e-8, max_iter: 2000, warm_start: true, ..Default::default() },
            &mut bws,
        );
        assert!(res.converged, "{res:?}");
        assert_eq!(applied_cols, res.apply_cols, "accounting must match the closure's count");
        assert!(res.block_iters >= 1, "the hard column must actually iterate");
        let uncompacted = (res.block_iters + 1) * cols;
        assert!(
            res.apply_cols < uncompacted,
            "compaction must save operator work: {} vs {}",
            res.apply_cols,
            uncompacted
        );
        // Easy columns really finished before the hard one.
        let max_easy = *res.col_iters[1..].iter().max().unwrap();
        assert!(
            max_easy < res.col_iters[0],
            "easy columns must converge first: {:?}",
            res.col_iters
        );
    }

    /// Warm-started block solves honor per-column initial guesses, just
    /// like the sequential path.
    #[test]
    fn block_solve_warm_start_beats_cold() {
        let n = 24;
        let a = spd(n);
        let cols = 3;
        let b: Vec<f64> = (0..cols * n).map(|i| (i as f64 * 0.13).sin()).collect();
        let opts = CgOptions { tol: 1e-12, max_iter: 1000, warm_start: false, ..Default::default() };
        // First solve cold, then perturb the RHS and re-solve warm.
        let mut x = vec![0.0; cols * n];
        let mut bws = BlockCgWorkspace::new(n, cols);
        let apply = |v: &[f64], out: &mut [f64]| {
            for c in 0..v.len() / n {
                out[c * n..(c + 1) * n].copy_from_slice(&a.matvec(&v[c * n..(c + 1) * n]));
            }
        };
        let id = |v: &[f64], out: &mut [f64]| out.copy_from_slice(v);
        let cold = cg_solve_block(apply, id, &b, &mut x, n, opts, &mut bws);
        assert!(cold.converged);
        let b2: Vec<f64> =
            b.iter().enumerate().map(|(i, v)| v + 0.01 * (i as f64).cos()).collect();
        let mut x_warm = x.clone();
        let warm = cg_solve_block(apply, id, &b2, &mut x_warm, n, opts.warm(), &mut bws);
        let mut x_cold = vec![0.0; cols * n];
        let cold2 = cg_solve_block(apply, id, &b2, &mut x_cold, n, opts, &mut bws);
        assert!(warm.converged && cold2.converged);
        assert!(
            warm.block_iters < cold2.block_iters,
            "warm {} !< cold {}",
            warm.block_iters,
            cold2.block_iters
        );
        for (p, q) in x_warm.iter().zip(&x_cold) {
            assert!((p - q).abs() < 1e-8);
        }
    }

    /// A zero RHS column converges instantly with a zero solution while
    /// the other columns solve normally.
    #[test]
    fn block_solve_zero_rhs_column() {
        let n = 16;
        let a = spd(n);
        let mut b = vec![0.0; 2 * n];
        for i in 0..n {
            b[n + i] = (i as f64 * 0.3).sin();
        }
        let mut x = vec![1.0; 2 * n]; // garbage a cold start must discard
        let mut bws = BlockCgWorkspace::new(n, 2);
        let res = cg_solve_block(
            |v, out| {
                for c in 0..v.len() / n {
                    out[c * n..(c + 1) * n].copy_from_slice(&a.matvec(&v[c * n..(c + 1) * n]));
                }
            },
            |v, out| out.copy_from_slice(v),
            &b,
            &mut x,
            n,
            CgOptions { tol: 1e-10, max_iter: 500, warm_start: false, ..Default::default() },
            &mut bws,
        );
        assert!(res.converged);
        assert_eq!(res.col_iters[0], 0);
        assert!(x[..n].iter().all(|&v| v == 0.0));
        assert!(x[n..].iter().any(|&v| v != 0.0));
    }

    /// An already-expired deadline stops the block solve before the
    /// first iteration (the abort happens *between* iterations), and is
    /// reported; without a deadline the flag stays false.
    #[test]
    fn block_solve_deadline_aborts_and_reports() {
        let n = 24;
        let a = spd(n);
        let b: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin() + 1.0).collect();
        let apply = |v: &[f64], out: &mut [f64]| {
            for c in 0..v.len() / n {
                out[c * n..(c + 1) * n].copy_from_slice(&a.matvec(&v[c * n..(c + 1) * n]));
            }
        };
        let id = |v: &[f64], out: &mut [f64]| out.copy_from_slice(v);
        let mut bws = BlockCgWorkspace::new(n, 1);
        let mut x = vec![0.0; n];
        let opts = CgOptions {
            tol: 1e-12,
            max_iter: 2000,
            deadline: Some(std::time::Instant::now()),
            ..Default::default()
        };
        let res = cg_solve_block(apply, id, &b, &mut x, n, opts, &mut bws);
        assert!(res.deadline_hit);
        assert_eq!(res.block_iters, 0, "expired deadline stops before iterating");
        assert!(!res.converged);
        let mut x2 = vec![0.0; n];
        let res2 = cg_solve_block(
            apply,
            id,
            &b,
            &mut x2,
            n,
            CgOptions { tol: 1e-12, max_iter: 2000, ..Default::default() },
            &mut bws,
        );
        assert!(res2.converged && !res2.deadline_hit);
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let mut x = vec![1.0; 4];
        let mut ws = CgWorkspace::new(4);
        let res = cg_solve(
            |v, out| out.copy_from_slice(v),
            |v, out| out.copy_from_slice(v),
            &[0.0; 4],
            &mut x,
            CgOptions::default(),
            &mut ws,
        );
        assert!(res.converged);
        assert_eq!(x, vec![0.0; 4]);
    }
}
