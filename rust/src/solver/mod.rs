//! Iterative solvers.

pub mod cg;

pub use cg::{cg_solve, CgOptions, CgResult, CgWorkspace, Preconditioner};
