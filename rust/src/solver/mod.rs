//! Iterative solvers: scalar preconditioned CG and its lockstep
//! multi-RHS block form (one batched operator apply per iteration).

pub mod cg;

pub use cg::{
    cg_solve, cg_solve_block, BlockCgResult, BlockCgWorkspace, CgOptions, CgResult, CgWorkspace,
    Preconditioner,
};
