//! Iterative solvers: scalar preconditioned CG and its lockstep
//! multi-RHS block form — one batched operator apply per iteration,
//! converged columns physically compacted out of the block, and the
//! batched applies fanned out over the in-tree thread pool
//! ([`crate::parallel`]) by the FFT engine underneath. Intra-solve
//! threading composes with shard-level worker threads: the pool serves
//! one region at a time, so concurrent shard refreshes run their solves
//! serially per shard while a lone refresh uses every core.

pub mod cg;

pub use cg::{
    cg_solve, cg_solve_block, BlockCgResult, BlockCgWorkspace, CgOptions, CgResult, CgWorkspace,
    Preconditioner,
};
