//! `repro` — the MSGP reproduction CLI.
//!
//! Subcommands:
//!
//! * `repro exp --fig <1|2|3|4|5|6> [--full]` — regenerate a paper figure
//!   (6 = the appendix A.3 extended circulant benchmark).
//! * `repro serve [--requests N] [--workers K] [--native]` — run the
//!   serving benchmark through the coordinator (PJRT artifacts when
//!   available, native otherwise).
//! * `repro smoke` — train a small model end-to-end and print SMAE (quick
//!   health check of the whole stack).

use msgp::bench::experiments;
use msgp::coordinator::EngineSpec;

fn usage() -> ! {
    eprintln!(
        "usage:\n  repro exp --fig <1|2|3|4|5|6> [--full]\n  repro serve [--requests N] [--workers K] [--native] [--artifacts DIR]\n  repro smoke"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut it = args.iter();
    match it.next().map(String::as_str) {
        Some("exp") => {
            let mut fig = None;
            let mut full = false;
            let mut iter = args[1..].iter();
            while let Some(a) = iter.next() {
                match a.as_str() {
                    "--fig" => fig = iter.next().and_then(|v| v.parse::<u32>().ok()),
                    "--full" => full = true,
                    _ => usage(),
                }
            }
            match fig {
                Some(1) => experiments::fig1_circulant(full),
                Some(2) => experiments::fig2_training(full),
                Some(3) => experiments::fig3_prediction(full),
                Some(4) => experiments::fig4_accuracy(full),
                Some(5) => experiments::fig5_projections(full),
                Some(6) => experiments::fig1_circulant(true), // appendix sweep
                _ => usage(),
            }
        }
        Some("serve") => {
            let mut requests = 20_000usize;
            let mut workers = 4usize;
            let mut native = false;
            let mut artifacts = "artifacts".to_string();
            let mut iter = args[1..].iter();
            while let Some(a) = iter.next() {
                match a.as_str() {
                    "--requests" => {
                        requests = iter.next().and_then(|v| v.parse().ok()).unwrap_or(requests)
                    }
                    "--workers" => {
                        workers = iter.next().and_then(|v| v.parse().ok()).unwrap_or(workers)
                    }
                    "--native" => native = true,
                    "--artifacts" => {
                        artifacts = iter.next().cloned().unwrap_or(artifacts)
                    }
                    _ => usage(),
                }
            }
            let engine = if native {
                EngineSpec::Native
            } else {
                EngineSpec::Pjrt(artifacts.clone().into())
            };
            let (thr, p50, p99, metrics) =
                experiments::serving_benchmark(engine, requests, workers);
            println!("throughput: {thr:.0} predictions/s");
            println!("latency: p50 <= {p50} us, p99 <= {p99} us");
            println!("metrics: {}", metrics.summary());
        }
        Some("smoke") => {
            use msgp::data::{gen_stress_1d, smae};
            use msgp::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
            use msgp::kernels::{KernelType, ProductKernel};
            let data = gen_stress_1d(2000, 0.05, 1);
            let kernel =
                KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 0.5, 0.5));
            let cfg = MsgpConfig { n_per_dim: vec![512], ..Default::default() };
            let mut model = MsgpModel::fit(kernel, 0.05, data, cfg)?;
            let trace = model.train(20, 0.1)?;
            let test = gen_stress_1d(500, 0.0, 99);
            let pred = model.predict_mean(&test.x);
            println!(
                "smoke: n=2000 m=512, lml {:.1} -> {:.1}, test SMAE {:.4}, cg iters {}",
                trace[0],
                model.lml(),
                smae(&pred, &test.y),
                model.last_cg.iters
            );
        }
        _ => usage(),
    }
    Ok(())
}
