//! Structure-exploiting operators: the fast algebra that makes MSGP
//! massively scalable.
//!
//! * [`circulant`] — symmetric circulant matrices, their FFT
//!   eigendecomposition, and the five circulant approximations to a
//!   Toeplitz matrix compared in Figure 1 of the paper (Strang, T. Chan,
//!   Tyrtyshnikov, Helgason, Whittle).
//! * [`toeplitz`] — symmetric Toeplitz matrices with O(m log m)
//!   matrix–vector products via circulant embedding (section 3.2).
//! * [`kronecker`] — Kronecker products of small dense factors with fast
//!   MVMs and factorized eigendecompositions (section 3.1).
//! * [`bttb`] — block-Toeplitz-Toeplitz-block operators for
//!   multi-dimensional grids without a factorizing kernel, and their BCCB
//!   Whittle approximations (section 5.3).
//!
//! Every operator exposes both a single-vector `matvec` (allocating only
//! its output) and an allocation-free `matvec_batch(&self, block, out,
//! ws)` over a row-major `b x m` block, built on the batched two-for-one
//! real-FFT engine in [`crate::linalg::fft`]: pairs of real RHS share
//! one complex transform, and strided axes are processed in
//! cache-blocked panels. The block-CG m-domain refresh
//! ([`crate::stream::trainer`]) rides these paths to apply its operator
//! to the mean and every variance probe at once.

pub mod circulant;
pub mod toeplitz;
pub mod kronecker;
pub mod bttb;
