//! Block-Toeplitz-Toeplitz-block (BTTB) operators and their BCCB Whittle
//! approximations (paper section 5.3).
//!
//! A translation-invariant kernel `k(x - z)` on a regular D-dimensional
//! grid gives a symmetric BTTB covariance **without** needing the kernel
//! to factorize across dimensions (unlike Kronecker methods). Exact MVMs
//! use a dimension-wise circulant embedding and a multi-dimensional FFT;
//! the Whittle periodic summation generalizes to a `(2w+1)^D`-term sum and
//! yields a block-circulant-with-circulant-blocks (BCCB) approximation
//! whose eigendecomposition is `C = F^H diag(F c) F`, carrying all the
//! Toeplitz-case benefits over to multivariate data.

use crate::linalg::fft::{
    apply_real_spectrum_batch, fftn, fftn_batch, next_pow2, with_workspace, Workspace,
};
use crate::linalg::C64;

/// A symmetric BTTB operator for a stationary kernel on a regular grid.
#[derive(Clone, Debug)]
pub struct Bttb {
    /// Grid shape `n_1 x ... x n_D`.
    pub shape: Vec<usize>,
    /// Embedding shape (per-dim power of two `>= 2 n_d - 1`).
    embed_shape: Vec<usize>,
    /// FFT of the embedded kernel tensor. The embedding is even under
    /// index negation (symmetric kernel), so its spectrum is real; only
    /// the real parts are stored, which also makes the two-for-one
    /// batched MVM exact.
    spectrum: Vec<f64>,
}

impl Bttb {
    /// Build from a kernel-of-lag closure. `kfn` receives the lag vector in
    /// *grid steps* (can be fractional only if you scale outside; here it is
    /// integral lags cast to f64) and must be symmetric under sign flips.
    pub fn new(shape: &[usize], kfn: &dyn Fn(&[f64]) -> f64) -> Self {
        let d = shape.len();
        let embed_shape: Vec<usize> =
            shape.iter().map(|&n| if n == 1 { 1 } else { next_pow2(2 * n - 1) }).collect();
        let total: usize = embed_shape.iter().product();
        let mut tensor = vec![C64::ZERO; total];
        // Fill k at wrapped lags: index i_d encodes lag i_d (if < n_d) or
        // i_d - e_d (negative part); zero elsewhere (padding).
        let mut idx = vec![0usize; d];
        let mut lag = vec![0f64; d];
        'outer: loop {
            let mut ok = true;
            for a in 0..d {
                let e = embed_shape[a];
                let n = shape[a];
                let i = idx[a];
                let l = if i < n {
                    i as i64
                } else if i + n > e {
                    i as i64 - e as i64 // negative lag in (-(n-1) .. -1]
                } else {
                    ok = false;
                    0
                };
                lag[a] = l as f64;
            }
            if ok {
                let mut flat = 0usize;
                for a in 0..d {
                    flat = flat * embed_shape[a] + idx[a];
                }
                tensor[flat] = C64::real(kfn(&lag));
            }
            // Increment multi-index.
            for a in (0..d).rev() {
                idx[a] += 1;
                if idx[a] < embed_shape[a] {
                    continue 'outer;
                }
                idx[a] = 0;
            }
            break;
        }
        fftn(&mut tensor, &embed_shape, false);
        let spectrum = tensor.into_iter().map(|z| z.re).collect();
        Bttb { shape: shape.to_vec(), embed_shape, spectrum }
    }

    /// Total dimension `m = prod shape`.
    pub fn m(&self) -> usize {
        self.shape.iter().product()
    }

    /// Exact MVM `K v` via the circulant embedding: O(m log m).
    /// Allocates only the returned vector (embedding tensor and FFT
    /// scratch come from the thread-shared batched-engine workspace).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.m()];
        with_workspace(|ws| self.matvec_batch(x, &mut out, ws));
        out
    }

    /// Exact batched MVM `K Y` for a row-major `b x m` block: pairs of
    /// real vectors are scattered into the corners of one complex
    /// embedding tensor each (two-for-one — the embedding spectrum is
    /// real), transformed with [`fftn_batch`]'s cache-blocked panels
    /// (which fan out over the thread pool on large tensors), scaled,
    /// and gathered back. Allocation-free given a warm [`Workspace`].
    pub fn matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let m = self.m();
        assert!(m > 0 && block.len() % m == 0, "block is b x m row-major");
        assert_eq!(out.len(), block.len());
        let rows = block.len() / m;
        let pairs = rows.div_ceil(2);
        let total: usize = self.embed_shape.iter().product();
        let Workspace { packed, scratch, .. } = ws;
        packed.clear();
        packed.resize(pairs * total, C64::ZERO);
        for j in 0..pairs {
            let re = &block[2 * j * m..(2 * j + 1) * m];
            let im = if 2 * j + 1 < rows {
                Some(&block[(2 * j + 1) * m..(2 * j + 2) * m])
            } else {
                None
            };
            let tensor = &mut packed[j * total..(j + 1) * total];
            self.for_each_corner(|flat_small, flat_big| {
                tensor[flat_big] = C64::new(
                    re[flat_small],
                    im.map_or(0.0, |v| v[flat_small]),
                );
            });
        }
        fftn_batch(packed, pairs, &self.embed_shape, false, scratch);
        for tensor in packed.chunks_exact_mut(total) {
            for (b, &s) in tensor.iter_mut().zip(&self.spectrum) {
                *b = b.scale(s);
            }
        }
        fftn_batch(packed, pairs, &self.embed_shape, true, scratch);
        for j in 0..pairs {
            let tensor = &packed[j * total..(j + 1) * total];
            // Split the output block around the pair boundary so the two
            // destination rows borrow disjointly.
            let (head, tail) = out.split_at_mut((2 * j + 1) * m);
            let re_out = &mut head[2 * j * m..];
            let im_out = if 2 * j + 1 < rows { Some(&mut tail[..m]) } else { None };
            match im_out {
                Some(im_out) => self.for_each_corner(|flat_small, flat_big| {
                    re_out[flat_small] = tensor[flat_big].re;
                    im_out[flat_small] = tensor[flat_big].im;
                }),
                None => self.for_each_corner(|flat_small, flat_big| {
                    re_out[flat_small] = tensor[flat_big].re;
                }),
            }
        }
    }

    /// Iterate over the `shape` corner inside the embedding tensor,
    /// passing (flat index in small tensor, flat index in big tensor).
    fn for_each_corner(&self, mut f: impl FnMut(usize, usize)) {
        let d = self.shape.len();
        let mut idx = vec![0usize; d];
        let mut small = 0usize;
        'outer: loop {
            let mut big = 0usize;
            for a in 0..d {
                big = big * self.embed_shape[a] + idx[a];
            }
            f(small, big);
            small += 1;
            for a in (0..d).rev() {
                idx[a] += 1;
                if idx[a] < self.shape[a] {
                    continue 'outer;
                }
                idx[a] = 0;
            }
            break;
        }
    }
}

/// A BCCB (block-circulant with circulant blocks) matrix: the
/// multi-dimensional analogue of [`super::circulant::Circulant`],
/// represented by its first column as a tensor on the grid.
#[derive(Clone, Debug)]
pub struct Bccb {
    /// Grid shape.
    pub shape: Vec<usize>,
    /// Eigenvalues = `Re(F c)` (length `m`), real by symmetry.
    pub eigs: Vec<f64>,
}

impl Bccb {
    /// Build the Whittle BCCB approximation of a stationary kernel on the
    /// grid: `c_i = sum_{|j|_inf <= wraps} k(i + j * n)` (a `(2w+1)^D`-term
    /// periodic summation). `kfn` takes the lag vector in grid steps.
    pub fn whittle(shape: &[usize], wraps: usize, kfn: &dyn Fn(&[f64]) -> f64) -> Self {
        let d = shape.len();
        let m: usize = shape.iter().product();
        let mut c = vec![0.0f64; m];
        let mut idx = vec![0usize; d];
        let w = wraps as i64;
        let mut flat = 0usize;
        'outer: loop {
            // Sum over all wrap offsets j in {-w..w}^D.
            let mut sum = 0.0;
            let mut joff = vec![-w; d];
            'wraps: loop {
                let mut lag = vec![0f64; d];
                for a in 0..d {
                    lag[a] = idx[a] as f64 + joff[a] as f64 * shape[a] as f64;
                }
                sum += kfn(&lag);
                for a in (0..d).rev() {
                    joff[a] += 1;
                    if joff[a] <= w {
                        continue 'wraps;
                    }
                    joff[a] = -w;
                }
                break;
            }
            c[flat] = sum;
            flat += 1;
            for a in (0..d).rev() {
                idx[a] += 1;
                if idx[a] < shape[a] {
                    continue 'outer;
                }
                idx[a] = 0;
            }
            break;
        }
        let mut buf: Vec<C64> = c.iter().map(|&v| C64::real(v)).collect();
        fftn(&mut buf, shape, false);
        let eigs = buf.into_iter().map(|z| z.re).collect();
        Bccb { shape: shape.to_vec(), eigs }
    }

    /// Total dimension.
    pub fn m(&self) -> usize {
        self.shape.iter().product()
    }

    /// MVM `C v` via multi-dimensional FFTs.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        self.apply_spectrum(x, |e| e)
    }

    /// Solve `(C + jitter I) v = x` in the Fourier domain (eigenvalues
    /// clipped at zero before shifting — keeps the preconditioner PSD).
    pub fn solve(&self, x: &[f64], jitter: f64) -> Vec<f64> {
        self.apply_spectrum(x, |e| 1.0 / (e.max(0.0) + jitter))
    }

    /// Apply the symmetric square root `C^{1/2} v` (clipped eigenvalues).
    pub fn sqrt_matvec(&self, x: &[f64]) -> Vec<f64> {
        self.apply_spectrum(x, |e| e.max(0.0).sqrt())
    }

    /// `log |C + sigma2 I|` with eigenvalue clipping, as in section 5.2.
    pub fn logdet(&self, sigma2: f64) -> f64 {
        self.eigs.iter().map(|&e| (e.max(0.0) + sigma2).ln()).sum()
    }

    /// Approximate eigenvalues (clipped at zero).
    pub fn eigenvalues_clipped(&self) -> Vec<f64> {
        self.eigs.iter().map(|&e| e.max(0.0)).collect()
    }

    /// Batched MVM `C Y` over a row-major `b x m` block, two RHS per
    /// complex transform (the BCCB spectrum is real).
    pub fn matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut Workspace) {
        apply_real_spectrum_batch(block, out, &self.shape, &self.eigs, |e| e, ws);
    }

    /// Batched [`Self::solve`] over a row-major `b x m` block.
    pub fn solve_batch(&self, block: &[f64], out: &mut [f64], jitter: f64, ws: &mut Workspace) {
        apply_real_spectrum_batch(
            block,
            out,
            &self.shape,
            &self.eigs,
            |e| 1.0 / (e.max(0.0) + jitter),
            ws,
        );
    }

    /// Batched [`Self::sqrt_matvec`] over a row-major `b x m` block.
    pub fn sqrt_matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut Workspace) {
        apply_real_spectrum_batch(block, out, &self.shape, &self.eigs, |e| e.max(0.0).sqrt(), ws);
    }

    fn apply_spectrum(&self, x: &[f64], f: impl Fn(f64) -> f64 + Sync) -> Vec<f64> {
        assert_eq!(x.len(), self.m());
        let mut out = vec![0.0; x.len()];
        with_workspace(|ws| apply_real_spectrum_batch(x, &mut out, &self.shape, &self.eigs, f, ws));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    /// Non-separable 2-D kernel (depends on the Euclidean norm of the lag,
    /// so it does NOT factor across dimensions — the BTTB use case).
    fn k_iso(lag: &[f64]) -> f64 {
        let r2: f64 = lag.iter().map(|l| l * l).sum();
        (-0.5 * r2 / 9.0).exp()
    }

    fn dense_bttb(shape: &[usize], kfn: &dyn Fn(&[f64]) -> f64) -> Mat {
        let m: usize = shape.iter().product();
        let d = shape.len();
        let unflat = |mut f: usize| -> Vec<i64> {
            let mut idx = vec![0i64; d];
            for a in (0..d).rev() {
                idx[a] = (f % shape[a]) as i64;
                f /= shape[a];
            }
            idx
        };
        Mat::from_fn(m, m, |i, j| {
            let a = unflat(i);
            let b = unflat(j);
            let lag: Vec<f64> = a.iter().zip(&b).map(|(x, y)| (x - y) as f64).collect();
            kfn(&lag)
        })
    }

    #[test]
    fn bttb_matvec_matches_dense() {
        let shape = [5usize, 4];
        let b = Bttb::new(&shape, &k_iso);
        let dense = dense_bttb(&shape, &k_iso);
        let x: Vec<f64> = (0..20).map(|i| ((i * 3 % 11) as f64) - 5.0).collect();
        let got = b.matvec(&x);
        let want = dense.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn bttb_3d_matvec_matches_dense() {
        let shape = [3usize, 3, 2];
        let b = Bttb::new(&shape, &k_iso);
        let dense = dense_bttb(&shape, &k_iso);
        let x: Vec<f64> = (0..18).map(|i| (i as f64 * 0.7).sin()).collect();
        let got = b.matvec(&x);
        let want = dense.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn bttb_matvec_batch_matches_per_vector() {
        let shape = [5usize, 4];
        let b = Bttb::new(&shape, &k_iso);
        let m = b.m();
        for rows in 1..=3 {
            let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.37).sin()).collect();
            let mut got = vec![0.0; rows * m];
            let mut ws = Workspace::new();
            b.matvec_batch(&block, &mut got, &mut ws);
            for r in 0..rows {
                let want = b.matvec(&block[r * m..(r + 1) * m]);
                for (g, w) in got[r * m..(r + 1) * m].iter().zip(&want) {
                    assert!((g - w).abs() < 1e-9, "rows={rows} r={r}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn bccb_batch_ops_match_per_vector() {
        let shape = [6usize, 5];
        let bccb = Bccb::whittle(&shape, 2, &k_iso);
        let m = bccb.m();
        let rows = 3;
        let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.19).cos()).collect();
        let mut ws = Workspace::new();
        let mut got = vec![0.0; rows * m];
        bccb.solve_batch(&block, &mut got, 0.5, &mut ws);
        for r in 0..rows {
            let want = bccb.solve(&block[r * m..(r + 1) * m], 0.5);
            for (g, w) in got[r * m..(r + 1) * m].iter().zip(&want) {
                assert!((g - w).abs() < 1e-10, "solve: {g} vs {w}");
            }
        }
        bccb.sqrt_matvec_batch(&block, &mut got, &mut ws);
        for r in 0..rows {
            let want = bccb.sqrt_matvec(&block[r * m..(r + 1) * m]);
            for (g, w) in got[r * m..(r + 1) * m].iter().zip(&want) {
                assert!((g - w).abs() < 1e-10, "sqrt: {g} vs {w}");
            }
        }
    }

    #[test]
    fn bccb_whittle_logdet_converges_to_exact() {
        // The Whittle BCCB log-determinant error is a boundary effect and
        // must decay as the grid grows (Gray 2005, Lemma 4.5).
        let sigma2 = 0.1;
        let rel_err = |side: usize| -> f64 {
            let shape = [side, side];
            let dense = dense_bttb(&shape, &k_iso);
            let mut shifted = dense.clone();
            for i in 0..shifted.rows {
                shifted[(i, i)] += sigma2;
            }
            let exact = crate::linalg::cholesky::Chol::new(&shifted).unwrap().logdet();
            let approx = Bccb::whittle(&shape, 2, &k_iso).logdet(sigma2);
            (approx - exact).abs() / exact.abs()
        };
        let e16 = rel_err(16);
        let e24 = rel_err(24);
        assert!(e16 < 0.08, "rel err at 16^2: {e16}");
        assert!(e24 < e16, "no decay: {e16} -> {e24}");
        assert!(e24 < 0.05, "rel err at 24^2: {e24}");
    }

    #[test]
    fn bccb_solve_inverts_matvec() {
        let shape = [8usize, 6];
        let bccb = Bccb::whittle(&shape, 2, &k_iso);
        let x: Vec<f64> = (0..48).map(|i| (i as f64 * 0.21).cos()).collect();
        let y = {
            let mut v = bccb.matvec(&x);
            for (vi, xi) in v.iter_mut().zip(&x) {
                *vi += 0.5 * xi;
            }
            v
        };
        let back = bccb.solve(&y, 0.5);
        for (b, xi) in back.iter().zip(&x) {
            assert!((b - xi).abs() < 1e-8);
        }
    }

    #[test]
    fn bccb_sqrt_squares_back() {
        let shape = [6usize, 5];
        let bccb = Bccb::whittle(&shape, 2, &k_iso);
        let x: Vec<f64> = (0..30).map(|i| i as f64 - 15.0).collect();
        let got = bccb.sqrt_matvec(&bccb.sqrt_matvec(&x));
        let want = bccb.matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-7);
        }
    }
}
