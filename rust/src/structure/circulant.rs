//! Symmetric circulant matrices and circulant approximations to symmetric
//! Toeplitz matrices (paper section 5.2).
//!
//! A symmetric circulant `C = circ(c)` is diagonalized by the DFT,
//! `C = F^H diag(F c) F / a` (Eq. 12), so its eigenvalues are the DFT of
//! its first column, MVMs cost two FFTs, and `log |C + s^2 I|` is a single
//! FFT plus a sum of logs — the key to the paper's fast marginal-likelihood
//! evaluations.
//!
//! Five circulant approximations of a Toeplitz matrix `T = toep(k)` are
//! implemented, matching Figure 1 of the paper:
//!
//! * **Strang** (1986) — copy the first half of `k`, reflect.
//! * **T. Chan** (1988) — the Frobenius-optimal circulant.
//! * **Tyrtyshnikov** (1992) — the superoptimal circulant
//!   `argmin_C ||I - C^{-1} T||_F`.
//! * **Helgason** — single-wraparound fold (`c_i = k_i + k_{m-i}`).
//! * **Whittle** (1954) — periodic summation `c_i = sum_j k_{i+jm}`,
//!   truncated at `w` wraps; the paper's recommended choice.

use crate::linalg::fft::{apply_real_spectrum_batch, plan, rfft, with_workspace, Workspace};
use crate::linalg::C64;

/// Which circulant approximation of a Toeplitz matrix to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CirculantKind {
    /// Strang's preconditioner: `c_i = k_i` for `i <= m/2`, reflected.
    Strang,
    /// T. Chan's Frobenius-optimal circulant.
    Chan,
    /// Tyrtyshnikov's superoptimal circulant (O(m^2) construction here).
    Tyrtyshnikov,
    /// One-fold wraparound symmetrization.
    Helgason,
    /// Whittle periodic summation (the paper's choice), with `w` wraps
    /// supplied separately.
    Whittle,
}

impl CirculantKind {
    /// All variants, in the order plotted in Figure 1.
    pub const ALL: [CirculantKind; 5] = [
        CirculantKind::Strang,
        CirculantKind::Chan,
        CirculantKind::Tyrtyshnikov,
        CirculantKind::Helgason,
        CirculantKind::Whittle,
    ];

    /// Display name as used in the paper's legends.
    pub fn name(self) -> &'static str {
        match self {
            CirculantKind::Strang => "strang",
            CirculantKind::Chan => "tchan",
            CirculantKind::Tyrtyshnikov => "tyrtyshnikov",
            CirculantKind::Helgason => "helgason",
            CirculantKind::Whittle => "whittle",
        }
    }
}

/// A symmetric circulant matrix represented by its first column.
#[derive(Clone, Debug)]
pub struct Circulant {
    /// First column `c` (length `m`).
    pub c: Vec<f64>,
    /// Eigenvalues = `Re(F c)` (real by symmetry), cached at construction.
    pub eigs: Vec<f64>,
}

impl Circulant {
    /// Wrap a first column. The column should satisfy `c_i = c_{m-i}`
    /// (symmetric circulant); eigenvalues are computed immediately.
    pub fn new(c: Vec<f64>) -> Self {
        let eigs = rfft(&c).into_iter().map(|z| z.re).collect();
        Circulant { c, eigs }
    }

    /// Dimension.
    pub fn m(&self) -> usize {
        self.c.len()
    }

    /// Matrix–vector product via two FFTs: `C y = F^{-1}(diag(F c) F y)`.
    /// Allocates only the returned vector; the complex FFT buffer comes
    /// from the thread-shared batched-engine workspace (see
    /// [`Self::matvec_into`] for the fully allocation-free form).
    pub fn matvec(&self, y: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; y.len()];
        with_workspace(|ws| self.matvec_into(y, &mut out, ws));
        out
    }

    /// [`Self::matvec`] into a caller-provided output through a reusable
    /// [`Workspace`]: zero allocations.
    pub fn matvec_into(&self, y: &[f64], out: &mut [f64], ws: &mut Workspace) {
        assert_eq!(y.len(), self.m());
        apply_real_spectrum_batch(y, out, &[self.m()], &self.eigs, |e| e, ws);
    }

    /// Batched MVM `C Y` for a row-major `b x m` block `Y`, routed
    /// through [`apply_real_spectrum_batch`]: half-length rfft
    /// transforms on even `m`, two-for-one pair packing on odd `m`, and
    /// a thread-pool row split on large blocks (results identical at
    /// any thread count). Allocation-free given a warm [`Workspace`].
    pub fn matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut Workspace) {
        apply_real_spectrum_batch(block, out, &[self.m()], &self.eigs, |e| e, ws);
    }

    /// Solve `(C + jitter I) x = y` in the Fourier domain, O(m log m).
    /// Eigenvalues are clipped at zero before inverting, matching
    /// [`Self::logdet`] and [`Self::sqrt_circulant`]: a Whittle/Helgason
    /// approximation of a PSD Toeplitz matrix can carry slightly
    /// negative eigenvalues, and an unclipped `1 / (e + jitter)` with
    /// `e ~= -jitter` amplifies that direction catastrophically (or
    /// flips its sign, breaking positive-definiteness). The solve is
    /// therefore exact for the *clipped* (PSD) circulant.
    pub fn solve(&self, y: &[f64], jitter: f64) -> Vec<f64> {
        let mut out = vec![0.0; y.len()];
        with_workspace(|ws| self.solve_into(y, &mut out, jitter, ws));
        out
    }

    /// [`Self::solve`] into a caller-provided output through a reusable
    /// [`Workspace`]: zero allocations.
    pub fn solve_into(&self, y: &[f64], out: &mut [f64], jitter: f64, ws: &mut Workspace) {
        assert_eq!(y.len(), self.m());
        apply_real_spectrum_batch(
            y,
            out,
            &[self.m()],
            &self.eigs,
            |e| 1.0 / (e.max(0.0) + jitter),
            ws,
        );
    }

    /// Batched [`Self::solve`] over a row-major `b x m` block, two RHS
    /// per complex transform.
    pub fn solve_batch(&self, block: &[f64], out: &mut [f64], jitter: f64, ws: &mut Workspace) {
        apply_real_spectrum_batch(
            block,
            out,
            &[self.m()],
            &self.eigs,
            |e| 1.0 / (e.max(0.0) + jitter),
            ws,
        );
    }

    /// `log |C + sigma2 I|` with eigenvalue clipping at zero, as in the
    /// paper: `log|toep(k) + s^2 I| ~= 1^T log(max(F c, 0) + s^2 1)`.
    pub fn logdet(&self, sigma2: f64) -> f64 {
        self.eigs.iter().map(|&e| (e.max(0.0) + sigma2).ln()).sum()
    }

    /// Symmetric square root as another circulant (eigenvalues clipped at
    /// zero before the square root). `S S = C` when `C` is PSD; used to
    /// draw grid samples for the stochastic variance estimator (5.1.2).
    pub fn sqrt_circulant(&self) -> Circulant {
        let m = self.m();
        let p = plan(m);
        let mut buf: Vec<C64> = self.eigs.iter().map(|&e| C64::real(e.max(0.0).sqrt())).collect();
        p.inverse(&mut buf);
        Circulant::new(buf.into_iter().map(|z| z.re).collect())
    }
}

/// Build the chosen circulant approximation to the symmetric Toeplitz
/// matrix `toep(k)` with first column `k` (length `m`).
///
/// For [`CirculantKind::Whittle`], `kernel_tail` supplies kernel values
/// beyond the grid: `kernel_tail(j)` must return `k(j * delta)` for lags
/// `j >= m` up to `j < (wraps+1) * m`; the periodic summation
/// `c_i = sum_{|j| <= wraps} k_{i + j m}` is then evaluated exactly. Pass
/// `wraps = 0` to fold only the in-grid tail (equivalent to Helgason).
pub fn circulant_approx(
    kind: CirculantKind,
    k: &[f64],
    wraps: usize,
    kernel_tail: Option<&dyn Fn(usize) -> f64>,
) -> Circulant {
    let m = k.len();
    assert!(m >= 2);
    let c = match kind {
        CirculantKind::Strang => {
            // c_i = k_i for i <= m/2, c_i = k_{m-i} for i > m/2.
            let mut c = vec![0.0; m];
            for (i, ci) in c.iter_mut().enumerate() {
                *ci = if i <= m / 2 { k[i] } else { k[m - i] };
            }
            c
        }
        CirculantKind::Chan => {
            // Frobenius-optimal: diagonal averages of toep(k).
            // For symmetric T: c_j = ((m - j) k_j + j k_{m-j}) / m.
            let mut c = vec![0.0; m];
            for (j, cj) in c.iter_mut().enumerate() {
                let kj = k[j];
                let kmj = if j == 0 { k[0] } else { k[m - j] };
                *cj = ((m - j) as f64 * kj + j as f64 * kmj) / m as f64;
            }
            c
        }
        CirculantKind::Tyrtyshnikov => {
            // Superoptimal: eigenvalues lambda = lambda(chan(T T^T)) / lambda(chan(T)).
            // chan(M) of a general symmetric M has c_j = (1/m) * sum over the
            // mod-m diagonal j of M. We form the diagonal sums of T T^T in
            // O(m^2) (used only in the Fig-1 benchmark at moderate m).
            let chan_t = circulant_approx(CirculantKind::Chan, k, 0, None);
            // diagSums[d] = sum_{i-k === d (mod m)} (T T^T)_{ik}
            // (T T^T)_{ik} = sum_l t_{|i-l|} t_{|k-l|}
            let mut diag_sums = vec![0.0; m];
            for i in 0..m {
                for kk in 0..m {
                    let mut s = 0.0;
                    for l in 0..m {
                        s += k[i.abs_diff(l)] * k[kk.abs_diff(l)];
                    }
                    let d = (i + m - kk) % m;
                    diag_sums[d] += s;
                }
            }
            let c2: Vec<f64> = diag_sums.iter().map(|v| v / m as f64).collect();
            let eig2 = rfft(&c2);
            let eig1 = rfft(&chan_t.c);
            // lambda_tyr = eig2 / eig1, then back-transform to a column.
            let mut lam: Vec<C64> = eig2
                .iter()
                .zip(&eig1)
                .map(|(a, b)| C64::real(a.re / b.re.max(1e-300)))
                .collect();
            plan(m).inverse(&mut lam);
            lam.into_iter().map(|z| z.re).collect()
        }
        CirculantKind::Helgason => {
            // Single symmetrizing fold: c_0 = k_0, c_i = k_i + k_{m-i}.
            let mut c = vec![0.0; m];
            c[0] = k[0];
            for i in 1..m {
                c[i] = k[i] + k[m - i];
            }
            c
        }
        CirculantKind::Whittle => {
            // Periodic summation c_i = sum_{j=-w..w} k(i + j m), using the
            // kernel tail for out-of-grid lags. With k symmetric,
            // k(-(i+jm)) = k(i+jm), so negative j folds to k(jm - i).
            let tail = |lag: usize| -> f64 {
                if lag < m {
                    k[lag]
                } else if let Some(f) = kernel_tail {
                    f(lag)
                } else {
                    0.0
                }
            };
            let mut c = vec![0.0; m];
            for (i, ci) in c.iter_mut().enumerate() {
                let mut s = tail(i);
                for j in 1..=wraps.max(1) {
                    s += tail(j * m + i); // k_{i + jm}
                    s += tail(j * m - i); // k_{i - jm} = k_{jm - i} by symmetry
                }
                *ci = s;
            }
            c
        }
    };
    Circulant::new(c)
}

/// Embed a symmetric Toeplitz first column `k` (length `m`) into a
/// circulant of length `a >= 2m - 1` for exact MVMs:
/// `c = [k_0 .. k_{m-1}, 0 .. 0, k_{m-1} .. k_1]`.
pub fn embed_for_mvm(k: &[f64], a: usize) -> Vec<f64> {
    let m = k.len();
    assert!(a >= 2 * m - 1, "embedding too small: {a} < {}", 2 * m - 1);
    let mut c = vec![0.0; a];
    c[..m].copy_from_slice(k);
    for i in 1..m {
        c[a - i] = k[i];
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn dense_circ(c: &[f64]) -> Mat {
        let m = c.len();
        Mat::from_fn(m, m, |i, j| c[(i + m - j) % m])
    }

    fn se_col(m: usize, ell: f64) -> Vec<f64> {
        (0..m).map(|i| (-0.5 * (i as f64 / ell).powi(2)).exp()).collect()
    }

    #[test]
    fn matvec_matches_dense() {
        let c = vec![4.0, 1.0, 0.5, 0.25, 0.5, 1.0];
        let circ = Circulant::new(c.clone());
        let y: Vec<f64> = (0..6).map(|i| (i as f64).cos()).collect();
        let got = circ.matvec(&y);
        let want = dense_circ(&c).matvec(&y);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn solve_inverts_matvec() {
        let c = vec![4.0, 1.0, 0.5, 0.25, 0.5, 1.0];
        let circ = Circulant::new(c);
        let y: Vec<f64> = (0..6).map(|i| i as f64 - 2.0).collect();
        let ay: Vec<f64> = {
            let mut v = circ.matvec(&y);
            for (vi, yi) in v.iter_mut().zip(&y) {
                *vi += 0.1 * yi;
            }
            v
        };
        let x = circ.solve(&ay, 0.1);
        for (xi, yi) in x.iter().zip(&y) {
            assert!((xi - yi).abs() < 1e-9);
        }
    }

    #[test]
    fn solve_clips_negative_eigenvalues() {
        // Sign-indefinite symmetric circulant: eigs_k = 1 + 4 cos(2 pi
        // k / 6), so k = 3 gives exactly -3. With `jitter = 3` the
        // unclipped solve would divide by `-3 + 3 = 0` and blow up; the
        // clipped solve must stay finite and invert the PSD-projected
        // circulant (whose action is `sqrt_circulant` applied twice,
        // since the square root clips the same way).
        let c = Circulant::new(vec![1.0, 2.0, 0.0, 0.0, 0.0, 2.0]);
        let min_eig = c.eigs.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((min_eig - (-3.0)).abs() < 1e-9, "min eig {min_eig}");
        let jitter = 3.0;
        let y: Vec<f64> = (0..6).map(|i| (i as f64 * 0.9).sin() + 0.5).collect();
        let x = c.solve(&y, jitter);
        let ynorm = dot_norm(&y);
        assert!(x.iter().all(|v| v.is_finite()), "{x:?}");
        assert!(
            dot_norm(&x) <= ynorm / jitter + 1e-9,
            "amplified beyond the clipped bound: ||x|| = {}",
            dot_norm(&x)
        );
        let s = c.sqrt_circulant();
        let mut back = s.matvec(&s.matvec(&x));
        for (b, &xi) in back.iter_mut().zip(&x) {
            *b += jitter * xi;
        }
        for (b, w) in back.iter().zip(&y) {
            assert!((b - w).abs() < 1e-9, "{b} vs {w}");
        }
    }

    fn dot_norm(v: &[f64]) -> f64 {
        v.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    #[test]
    fn eigenvalues_match_dense() {
        let c = se_col(8, 2.0);
        let circ = circulant_approx(CirculantKind::Chan, &c, 0, None);
        let dense = dense_circ(&circ.c);
        let eig = crate::linalg::eigen::sym_eig(&dense);
        let mut ours = circ.eigs.clone();
        ours.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (o, w) in ours.iter().zip(&eig.vals) {
            assert!((o - w).abs() < 1e-8, "{o} vs {w}");
        }
    }

    #[test]
    fn whittle_beats_strang_on_se_logdet() {
        // The Figure-1 claim in miniature: the Whittle approximation's
        // logdet error is (much) smaller than Strang's for an SE kernel.
        let m = 256;
        let ell = 8.0;
        let k = se_col(m, ell);
        let sigma2 = 0.01;
        // Exact logdet via dense Cholesky of toep(k) + s^2 I.
        let t = Mat::from_fn(m, m, |i, j| k[i.abs_diff(j)] + if i == j { sigma2 } else { 0.0 });
        let exact = crate::linalg::cholesky::Chol::new(&t).unwrap().logdet();
        let tail = |lag: usize| (-0.5 * (lag as f64 / ell).powi(2)).exp();
        let whittle = circulant_approx(CirculantKind::Whittle, &k, 3, Some(&tail)).logdet(sigma2);
        let strang = circulant_approx(CirculantKind::Strang, &k, 0, None).logdet(sigma2);
        let ew = (whittle - exact).abs() / exact.abs();
        let es = (strang - exact).abs() / exact.abs();
        assert!(ew < 0.01, "whittle rel err {ew}");
        assert!(ew <= es, "whittle {ew} vs strang {es}");
    }

    #[test]
    fn chan_is_frobenius_optimal() {
        // Among our approximations, T.Chan must minimize ||C - T||_F.
        let m = 32;
        let k = se_col(m, 3.0);
        let t = Mat::from_fn(m, m, |i, j| k[i.abs_diff(j)]);
        let frob = |c: &Circulant| {
            let d = dense_circ(&c.c);
            let mut s = 0.0;
            for i in 0..m {
                for j in 0..m {
                    s += (d[(i, j)] - t[(i, j)]).powi(2);
                }
            }
            s.sqrt()
        };
        let chan = frob(&circulant_approx(CirculantKind::Chan, &k, 0, None));
        for kind in [CirculantKind::Strang, CirculantKind::Helgason] {
            let other = frob(&circulant_approx(kind, &k, 0, None));
            assert!(chan <= other + 1e-9, "{kind:?}: {chan} vs {other}");
        }
    }

    #[test]
    fn embedding_gives_exact_toeplitz_mvm() {
        let m = 10;
        let k = se_col(m, 2.5);
        let a = 32;
        let c = embed_for_mvm(&k, a);
        let circ = Circulant::new(c);
        let y: Vec<f64> = (0..m).map(|i| (i as f64 * 0.4).sin()).collect();
        let mut pad = vec![0.0; a];
        pad[..m].copy_from_slice(&y);
        let full = circ.matvec(&pad);
        let t = Mat::from_fn(m, m, |i, j| k[i.abs_diff(j)]);
        let want = t.matvec(&y);
        for i in 0..m {
            assert!((full[i] - want[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn matvec_batch_matches_per_vector() {
        let c = Circulant::new(vec![4.0, 1.0, 0.5, 0.25, 0.5, 1.0]);
        let m = c.m();
        for rows in 1..=5 {
            let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.31).sin()).collect();
            let mut got = vec![0.0; rows * m];
            let mut ws = crate::linalg::fft::Workspace::new();
            c.matvec_batch(&block, &mut got, &mut ws);
            for r in 0..rows {
                let want = c.matvec(&block[r * m..(r + 1) * m]);
                for (g, w) in got[r * m..(r + 1) * m].iter().zip(&want) {
                    assert!((g - w).abs() < 1e-10, "rows={rows} r={r}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn solve_batch_matches_per_vector() {
        let c = Circulant::new(vec![4.0, 1.0, 0.5, 0.25, 0.5, 1.0]);
        let m = c.m();
        let rows = 3;
        let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.7).cos()).collect();
        let mut got = vec![0.0; rows * m];
        let mut ws = crate::linalg::fft::Workspace::new();
        c.solve_batch(&block, &mut got, 0.1, &mut ws);
        for r in 0..rows {
            let want = c.solve(&block[r * m..(r + 1) * m], 0.1);
            for (g, w) in got[r * m..(r + 1) * m].iter().zip(&want) {
                assert!((g - w).abs() < 1e-10, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn sqrt_circulant_squares_back() {
        let k = se_col(16, 4.0);
        let tail = |lag: usize| (-0.5 * (lag as f64 / 4.0).powi(2)).exp();
        let c = circulant_approx(CirculantKind::Whittle, &k, 3, Some(&tail));
        let s = c.sqrt_circulant();
        let y: Vec<f64> = (0..16).map(|i| ((i * 7 % 5) as f64) - 2.0).collect();
        let got = s.matvec(&s.matvec(&y));
        let want = c.matvec(&y);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-8);
        }
    }
}
