//! Symmetric Toeplitz matrices (paper section 3.2).
//!
//! A stationary kernel on a regular 1-D grid produces a symmetric Toeplitz
//! covariance `T = toep(k)`. MVMs are computed exactly in O(m log m) by
//! embedding into a power-of-two circulant; the log-determinant is either
//! exact (O(m^2), dense Cholesky — the "MSGP with Toeplitz" ablation of
//! Figure 2) or approximated by a circulant (section 5.2, the MSGP path).

use super::circulant::{embed_for_mvm, Circulant};
use crate::linalg::fft::{
    apply_axis_spectrum_packed, next_pow2, pack_real_pairs, unpack_real_pairs, with_workspace,
    Workspace,
};

/// A symmetric Toeplitz matrix represented by its first column, with the
/// circulant embedding for fast MVMs prepared at construction.
#[derive(Clone, Debug)]
pub struct SymToeplitz {
    /// First column `k` (length `m`).
    pub k: Vec<f64>,
    /// Power-of-two circulant embedding used for MVMs.
    embed: Circulant,
    /// Embedding length.
    a: usize,
}

impl SymToeplitz {
    /// Build from the first column.
    pub fn new(k: Vec<f64>) -> Self {
        let m = k.len();
        assert!(m >= 1);
        let a = next_pow2((2 * m).saturating_sub(1)).max(1);
        let embed = Circulant::new(embed_for_mvm(&k, a));
        SymToeplitz { k, embed, a }
    }

    /// Dimension.
    pub fn m(&self) -> usize {
        self.k.len()
    }

    /// Exact MVM via circulant embedding: O(m log m). Allocates only the
    /// returned vector (the embedding pad and FFT buffers come from the
    /// thread-shared batched-engine workspace).
    pub fn matvec(&self, y: &[f64]) -> Vec<f64> {
        let m = self.m();
        assert_eq!(y.len(), m);
        let mut out = vec![0.0; m];
        with_workspace(|ws| self.matvec_batch(y, &mut out, ws));
        out
    }

    /// Exact MVM into a caller-provided output buffer, reusing `scratch`
    /// (resized to the embedding length); allocation-free hot path for
    /// callers that already own a real scratch vector. New code should
    /// prefer [`Self::matvec_batch`].
    pub fn matvec_into(&self, y: &[f64], out: &mut [f64], scratch: &mut Vec<f64>) {
        let m = self.m();
        assert_eq!(y.len(), m);
        assert_eq!(out.len(), m);
        scratch.clear();
        scratch.resize(2 * self.a, 0.0);
        let (pad, full) = scratch.split_at_mut(self.a);
        pad[..m].copy_from_slice(y);
        with_workspace(|ws| self.embed.matvec_into(pad, full, ws));
        out.copy_from_slice(&full[..m]);
    }

    /// Exact batched MVM `T Y` for a row-major `b x m` block: every line
    /// is zero-padded into the power-of-two circulant embedding, pairs of
    /// real lines share one complex transform (two-for-one), and the
    /// embedding spectrum is applied with one cached plan for the whole
    /// block. Allocation-free given a warm [`Workspace`]; large blocks
    /// fan their embedding transforms out over the thread pool via
    /// [`apply_axis_spectrum_packed`] (results identical at any thread
    /// count).
    pub fn matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let m = self.m();
        assert!(block.len() % m == 0, "block is b x m row-major");
        assert_eq!(out.len(), block.len());
        let rows = block.len() / m;
        let pairs = rows.div_ceil(2);
        let Workspace { packed, scratch, .. } = ws;
        pack_real_pairs(block, m, packed);
        apply_axis_spectrum_packed(packed, pairs, m, 1, self.embed_eigs(), scratch);
        unpack_real_pairs(packed, m, rows, out);
    }

    /// Eigenvalues of the power-of-two circulant embedding — the spectrum
    /// the batched Toeplitz / Kronecker MVMs apply along this factor's
    /// axis (its length is the embedding length).
    pub(crate) fn embed_eigs(&self) -> &[f64] {
        &self.embed.eigs
    }

    /// Exact `log |T + sigma2 I|` via dense Cholesky — O(m^3) memory-light
    /// fallback used by the Toeplitz ablation and in tests. Returns `None`
    /// if the shifted matrix is not positive definite.
    pub fn logdet_exact(&self, sigma2: f64) -> Option<f64> {
        let m = self.m();
        let t = crate::linalg::Mat::from_fn(m, m, |i, j| {
            self.k[i.abs_diff(j)] + if i == j { sigma2 } else { 0.0 }
        });
        crate::linalg::cholesky::Chol::new(&t).map(|c| c.logdet())
    }

    /// Trace of `T` (just `m * k_0`).
    pub fn trace(&self) -> f64 {
        self.m() as f64 * self.k[0]
    }

    /// `log |T + sigma2 I|` via the Levinson–Durbin recursion — the
    /// classical O(m^2) Toeplitz log-determinant that limits Toeplitz
    /// methods to m ~ 10^4 when kernel learning is required (section 3.2).
    /// This is the "MSGP with Toeplitz (rather than circulant)" ablation
    /// of Figure 2. Returns `None` if a prediction-error variance goes
    /// non-positive (matrix not PD to working precision).
    pub fn logdet_levinson(&self, sigma2: f64) -> Option<f64> {
        let m = self.m();
        let mut r = self.k.clone();
        r[0] += sigma2;
        // Durbin recursion on the autocorrelation sequence: the log
        // determinant is the sum of the log prediction-error variances.
        let mut e = r[0];
        if e <= 0.0 {
            return None;
        }
        let mut logdet = e.ln();
        let mut a = vec![0.0f64; m]; // AR coefficients a_1..a_{k}
        let mut a_prev = vec![0.0f64; m];
        for k in 1..m {
            // reflection coefficient
            let mut acc = r[k];
            for j in 1..k {
                acc -= a[j] * r[k - j];
            }
            let kappa = acc / e;
            a_prev[..k].copy_from_slice(&a[..k]);
            a[k] = kappa;
            for j in 1..k {
                a[j] = a_prev[j] - kappa * a_prev[k - j];
            }
            e *= 1.0 - kappa * kappa;
            if e <= 0.0 || !e.is_finite() {
                return None;
            }
            logdet += e.ln();
        }
        Some(logdet)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn matvec_matches_dense() {
        for &m in &[1usize, 2, 5, 17, 64] {
            let k: Vec<f64> = (0..m).map(|i| (-0.3 * i as f64).exp()).collect();
            let t = SymToeplitz::new(k.clone());
            let y: Vec<f64> = (0..m).map(|i| ((i * 3 % 7) as f64) - 3.0).collect();
            let got = t.matvec(&y);
            let dense = Mat::from_fn(m, m, |i, j| k[i.abs_diff(j)]);
            let want = dense.matvec(&y);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "m={m}");
            }
        }
    }

    #[test]
    fn matvec_into_is_consistent() {
        let m = 33;
        let k: Vec<f64> = (0..m).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let t = SymToeplitz::new(k);
        let y: Vec<f64> = (0..m).map(|i| (i as f64).sin()).collect();
        let mut out = vec![0.0; m];
        let mut scratch = Vec::new();
        t.matvec_into(&y, &mut out, &mut scratch);
        assert_eq!(out, t.matvec(&y));
    }

    #[test]
    fn matvec_batch_matches_per_vector() {
        let m = 19;
        let k: Vec<f64> = (0..m).map(|i| (-0.2 * i as f64).exp()).collect();
        let t = SymToeplitz::new(k);
        for rows in 1..=4 {
            let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.23).sin()).collect();
            let mut got = vec![0.0; rows * m];
            let mut ws = Workspace::new();
            t.matvec_batch(&block, &mut got, &mut ws);
            for r in 0..rows {
                let want = t.matvec(&block[r * m..(r + 1) * m]);
                for (g, w) in got[r * m..(r + 1) * m].iter().zip(&want) {
                    assert!((g - w).abs() < 1e-9, "rows={rows} r={r}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn levinson_logdet_matches_cholesky() {
        for &(m, ell) in &[(16usize, 2.0f64), (40, 5.0), (64, 1.0)] {
            let k: Vec<f64> = (0..m).map(|i| (-0.5 * (i as f64 / ell).powi(2)).exp()).collect();
            let t = SymToeplitz::new(k);
            let sigma2 = 0.05;
            let lev = t.logdet_levinson(sigma2).unwrap();
            let chol = t.logdet_exact(sigma2).unwrap();
            assert!((lev - chol).abs() < 1e-8 * (1.0 + chol.abs()), "m={m}: {lev} vs {chol}");
        }
    }

    #[test]
    fn logdet_exact_matches_cholesky_identity() {
        let m = 20;
        let mut k = vec![0.0; m];
        k[0] = 2.5; // T = 2.5 I
        let t = SymToeplitz::new(k);
        let ld = t.logdet_exact(0.5).unwrap();
        assert!((ld - (m as f64) * 3.0f64.ln()).abs() < 1e-10);
    }
}
