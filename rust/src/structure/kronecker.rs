//! Kronecker-product operators (paper section 3.1).
//!
//! For a product kernel on a rectilinear grid, `K_{U,U} = K_1 (x) ... (x) K_P`.
//! MVMs with a Kronecker product cost `O(P m^{1+1/P})` via axis-wise
//! application of the factors to the reshaped operand tensor, and the
//! eigendecomposition factorizes over the (small) per-dimension matrices.
//!
//! In MSGP the factors are symmetric Toeplitz ([`KronToeplitz`]) and the
//! nested Toeplitz structure is exploited through the circulant
//! approximations of section 5.2 instead of dense eigendecompositions —
//! this is the "multi-level circulant" unification the paper describes.

use super::circulant::{circulant_approx, Circulant, CirculantKind};
use super::toeplitz::SymToeplitz;
use crate::linalg::dense::Mat;
use crate::linalg::eigen::{sym_eig, SymEig};
use crate::linalg::fft::{
    apply_axis_spectrum_packed, apply_real_spectrum_batch, pack_real_pairs, unpack_real_pairs,
    with_workspace, Workspace,
};

/// Apply a linear operator `op: R^{shape[axis]} -> R^{shape[axis]}` along
/// one axis of a row-major tensor, in place (via scratch).
pub fn apply_along_axis(
    data: &mut [f64],
    shape: &[usize],
    axis: usize,
    mut op: impl FnMut(&[f64], &mut [f64]),
) {
    let d = shape.len();
    let n = shape[axis];
    let outer: usize = shape[..axis].iter().product();
    let inner: usize = shape[axis + 1..].iter().product();
    let mut line = vec![0.0; n];
    let mut out = vec![0.0; n];
    for o in 0..outer {
        for i in 0..inner {
            let base = o * n * inner + i;
            for k in 0..n {
                line[k] = data[base + k * inner];
            }
            op(&line, &mut out);
            for k in 0..n {
                data[base + k * inner] = out[k];
            }
        }
    }
    let _ = d;
}

/// Dense Kronecker MVM: `(A_1 (x) ... (x) A_P) x` with dense factors.
pub fn kron_matvec(factors: &[Mat], x: &[f64]) -> Vec<f64> {
    let shape: Vec<usize> = factors.iter().map(|f| f.rows).collect();
    let total: usize = shape.iter().product();
    assert_eq!(x.len(), total);
    let mut data = x.to_vec();
    for (axis, f) in factors.iter().enumerate() {
        assert_eq!(f.rows, f.cols, "kron factors must be square");
        apply_along_axis(&mut data, &shape, axis, |line, out| {
            let r = f.matvec(line);
            out.copy_from_slice(&r);
        });
    }
    data
}

/// Materialize a dense Kronecker product (tests / tiny sizes only).
pub fn kron_dense(factors: &[Mat]) -> Mat {
    let mut acc = Mat::from_vec(1, 1, vec![1.0]);
    for f in factors {
        let mut next = Mat::zeros(acc.rows * f.rows, acc.cols * f.cols);
        for i in 0..acc.rows {
            for j in 0..acc.cols {
                let a = acc[(i, j)];
                for r in 0..f.rows {
                    for c in 0..f.cols {
                        next[(i * f.rows + r, j * f.cols + c)] = a * f[(r, c)];
                    }
                }
            }
        }
        acc = next;
    }
    acc
}

/// Eigendecomposition of a Kronecker product of symmetric factors:
/// per-factor Jacobi decompositions; eigenvalues are all products.
pub struct KronEig {
    /// Per-factor decompositions (in factor order).
    pub factors: Vec<SymEig>,
}

impl KronEig {
    /// Decompose each dense factor.
    pub fn new(mats: &[Mat]) -> Self {
        KronEig { factors: mats.iter().map(sym_eig).collect() }
    }

    /// All eigenvalues of the Kronecker product (length = product of sizes),
    /// in row-major tensor order (not sorted).
    pub fn eigenvalues(&self) -> Vec<f64> {
        let mut vals = vec![1.0f64];
        for f in &self.factors {
            let mut next = Vec::with_capacity(vals.len() * f.vals.len());
            for &a in &vals {
                for &b in &f.vals {
                    next.push(a * b);
                }
            }
            vals = next;
        }
        vals
    }

    /// MVM with `Q` (the Kronecker product of the factor eigenvector
    /// matrices): used to apply `K^{1/2}` etc. in tests.
    pub fn q_matvec(&self, x: &[f64], transpose: bool) -> Vec<f64> {
        let shape: Vec<usize> = self.factors.iter().map(|f| f.q.rows).collect();
        let mut data = x.to_vec();
        for (axis, f) in self.factors.iter().enumerate() {
            apply_along_axis(&mut data, &shape, axis, |line, out| {
                let r = if transpose { f.q.tmatvec(line) } else { f.q.matvec(line) };
                out.copy_from_slice(&r);
            });
        }
        data
    }
}

/// A Kronecker product of symmetric Toeplitz factors — the structure of
/// `K_{U,U}` for a product kernel on a rectilinear grid (Eq. 11) — with
/// circulant (Whittle by default) spectral approximations per factor.
#[derive(Clone, Debug)]
pub struct KronToeplitz {
    /// Per-dimension Toeplitz factors.
    pub factors: Vec<SymToeplitz>,
    /// Per-dimension circulant approximations (for eigenvalues / logdet /
    /// square-root sampling).
    pub circulants: Vec<Circulant>,
    /// Cached separable square-root spectrum: the Kronecker product of
    /// the per-factor `sqrt(max(eig, 0))` spectra, row-major over the
    /// grid (length `m`). Lets [`Self::sqrt_matvec`] /
    /// [`Self::sqrt_matvec_batch`] apply `K^{1/2}` as one diagonal in
    /// the multi-dimensional Fourier basis instead of rebuilding a
    /// `sqrt_circulant` per factor per call.
    sqrt_spec: Vec<f64>,
}

/// Kronecker product of the per-factor clipped square-root spectra,
/// row-major tensor order (matches [`KronToeplitz::approx_eigenvalues`]
/// with the square root pushed inside the product — all terms are
/// non-negative, so the two orders agree).
fn product_sqrt_spec(circulants: &[Circulant]) -> Vec<f64> {
    let mut vals = vec![1.0f64];
    for c in circulants {
        let mut next = Vec::with_capacity(vals.len() * c.eigs.len());
        for &a in &vals {
            for &b in &c.eigs {
                next.push(a * b.max(0.0).sqrt());
            }
        }
        vals = next;
    }
    vals
}

impl KronToeplitz {
    /// Build from per-dimension first columns, with a Whittle circulant
    /// approximation per factor. `tails[d](lag)` returns the kernel value
    /// at out-of-grid integer lag for dimension `d` (used by the periodic
    /// summation); `wraps` controls the truncation of the Whittle sum.
    pub fn new_whittle(
        cols: Vec<Vec<f64>>,
        wraps: usize,
        tails: &[&dyn Fn(usize) -> f64],
    ) -> Self {
        assert_eq!(cols.len(), tails.len());
        let circulants: Vec<Circulant> = cols
            .iter()
            .zip(tails)
            .map(|(k, t)| circulant_approx(CirculantKind::Whittle, k, wraps, Some(*t)))
            .collect();
        let factors = cols.into_iter().map(SymToeplitz::new).collect();
        let sqrt_spec = product_sqrt_spec(&circulants);
        KronToeplitz { factors, circulants, sqrt_spec }
    }

    /// Build with a chosen circulant kind (no tail: Strang/Chan/... don't
    /// need one).
    pub fn new_with_kind(cols: Vec<Vec<f64>>, kind: CirculantKind) -> Self {
        let circulants: Vec<Circulant> =
            cols.iter().map(|k| circulant_approx(kind, k, 0, None)).collect();
        let factors = cols.into_iter().map(SymToeplitz::new).collect();
        let sqrt_spec = product_sqrt_spec(&circulants);
        KronToeplitz { factors, circulants, sqrt_spec }
    }

    /// Grid shape (per-dimension sizes).
    pub fn shape(&self) -> Vec<usize> {
        self.factors.iter().map(|f| f.m()).collect()
    }

    /// Total dimension `m = prod shape`.
    pub fn m(&self) -> usize {
        self.shape().iter().product()
    }

    /// Exact MVM `K_{U,U} v` via per-axis Toeplitz MVMs: O(P m log m_max).
    /// Allocates only the returned vector (batched-engine workspace
    /// shared per thread; see [`Self::matvec_batch`]).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.m());
        let mut out = vec![0.0; x.len()];
        with_workspace(|ws| self.matvec_batch(x, &mut out, ws));
        out
    }

    /// Exact batched MVM `K_{U,U} Y` for a row-major `b x m` block: pairs
    /// of real vectors are packed into one complex tensor (two-for-one),
    /// and each factor's circulant-embedding spectrum is applied along
    /// its axis in cache-blocked panels with per-line zero-padding —
    /// O(P m log m_max) per pair of RHS instead of per RHS.
    /// Allocation-free given a warm [`Workspace`]; the per-axis panel
    /// passes fan out over the thread pool on large blocks (results
    /// identical at any thread count).
    pub fn matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut Workspace) {
        let shape = self.shape();
        let m = self.m();
        assert!(m > 0 && block.len() % m == 0, "block is b x m row-major");
        assert_eq!(out.len(), block.len());
        let rows = block.len() / m;
        let pairs = rows.div_ceil(2);
        let Workspace { packed, scratch, .. } = ws;
        pack_real_pairs(block, m, packed);
        for (axis, f) in self.factors.iter().enumerate() {
            let n = shape[axis];
            let inner: usize = shape[axis + 1..].iter().product();
            let outer = pairs * (m / (n * inner));
            apply_axis_spectrum_packed(packed, outer, n, inner, f.embed_eigs(), scratch);
        }
        unpack_real_pairs(packed, m, rows, out);
    }

    /// Approximate eigenvalues of `K_{U,U}`: Kronecker product of the
    /// per-factor circulant spectra (clipped at zero), row-major order.
    pub fn approx_eigenvalues(&self) -> Vec<f64> {
        let mut vals = vec![1.0f64];
        for c in &self.circulants {
            let mut next = Vec::with_capacity(vals.len() * c.eigs.len());
            for &a in &vals {
                for &b in &c.eigs {
                    next.push(a * b.max(0.0));
                }
            }
            vals = next;
        }
        vals
    }

    /// Approximate `log |K_{U,U} + sigma2 I|` from the circulant spectra.
    pub fn logdet_whittle(&self, sigma2: f64) -> f64 {
        self.approx_eigenvalues().iter().map(|&e| (e + sigma2).ln()).sum()
    }

    /// Apply the approximate symmetric square root `K^{1/2} v` — the
    /// Kronecker product of the per-factor circulant square roots — as
    /// one cached separable spectrum in the multi-dimensional Fourier
    /// basis (no per-call `sqrt_circulant` rebuilds).
    pub fn sqrt_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.m());
        let mut out = vec![0.0; x.len()];
        with_workspace(|ws| self.sqrt_matvec_batch(x, &mut out, ws));
        out
    }

    /// Batched [`Self::sqrt_matvec`] over a row-major `b x m` block, two
    /// RHS per complex transform. The workhorse of the block-CG m-domain
    /// refresh, which applies `S` to the mean and every variance probe
    /// in one call.
    pub fn sqrt_matvec_batch(&self, block: &[f64], out: &mut [f64], ws: &mut Workspace) {
        apply_real_spectrum_batch(block, out, &self.shape(), &self.sqrt_spec, |e| e, ws);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn se(m: usize, ell: f64) -> Vec<f64> {
        (0..m).map(|i| (-0.5 * (i as f64 / ell).powi(2)).exp()).collect()
    }

    #[test]
    fn kron_matvec_matches_dense() {
        let a = Mat::from_fn(2, 2, |r, c| (r * 2 + c + 1) as f64);
        let b = Mat::from_fn(3, 3, |r, c| ((r + 1) * (c + 2)) as f64 * 0.1);
        let x: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        let got = kron_matvec(&[a.clone(), b.clone()], &x);
        let want = kron_dense(&[a, b]).matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-10);
        }
    }

    #[test]
    fn kron_eig_matches_dense_eig() {
        let a = Mat::from_fn(3, 3, |r, c| if r == c { 2.0 } else { 0.3 });
        let b = Mat::from_fn(2, 2, |r, c| if r == c { 1.5 } else { -0.2 });
        let ke = KronEig::new(&[a.clone(), b.clone()]);
        let mut got = ke.eigenvalues();
        got.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let dense = kron_dense(&[a, b]);
        let want = crate::linalg::eigen::sym_eig(&dense).vals;
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn kron_toeplitz_matvec_matches_dense() {
        let k1 = se(4, 1.5);
        let k2 = se(3, 2.0);
        let kt = KronToeplitz::new_with_kind(vec![k1.clone(), k2.clone()], CirculantKind::Chan);
        let d1 = Mat::from_fn(4, 4, |i, j| k1[i.abs_diff(j)]);
        let d2 = Mat::from_fn(3, 3, |i, j| k2[i.abs_diff(j)]);
        let x: Vec<f64> = (0..12).map(|i| ((i * 5 % 7) as f64) - 3.0).collect();
        let got = kt.matvec(&x);
        let want = kron_dense(&[d1, d2]).matvec(&x);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn kron_toeplitz_matvec_batch_matches_per_vector() {
        let kt = KronToeplitz::new_with_kind(vec![se(5, 1.5), se(4, 2.0)], CirculantKind::Chan);
        let m = kt.m();
        for rows in 1..=3 {
            let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.29).sin()).collect();
            let mut got = vec![0.0; rows * m];
            let mut ws = Workspace::new();
            kt.matvec_batch(&block, &mut got, &mut ws);
            for r in 0..rows {
                let want = kt.matvec(&block[r * m..(r + 1) * m]);
                for (g, w) in got[r * m..(r + 1) * m].iter().zip(&want) {
                    assert!((g - w).abs() < 1e-9, "rows={rows} r={r}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn sqrt_matvec_batch_matches_per_vector() {
        let kt = KronToeplitz::new_whittle(
            vec![se(6, 2.0), se(5, 1.0)],
            3,
            &[
                &|lag| (-0.5 * (lag as f64 / 2.0).powi(2)).exp(),
                &|lag| (-0.5 * (lag as f64 / 1.0).powi(2)).exp(),
            ],
        );
        let m = kt.m();
        let rows = 3;
        let block: Vec<f64> = (0..rows * m).map(|i| (i as f64 * 0.41).cos()).collect();
        let mut got = vec![0.0; rows * m];
        let mut ws = Workspace::new();
        kt.sqrt_matvec_batch(&block, &mut got, &mut ws);
        for r in 0..rows {
            let want = kt.sqrt_matvec(&block[r * m..(r + 1) * m]);
            for (g, w) in got[r * m..(r + 1) * m].iter().zip(&want) {
                assert!((g - w).abs() < 1e-10, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn whittle_logdet_close_to_exact_2d() {
        let m1 = 32;
        let m2 = 16;
        let e1 = 4.0;
        let e2 = 2.0;
        let kt = KronToeplitz::new_whittle(
            vec![se(m1, e1), se(m2, e2)],
            3,
            &[
                &|lag| (-0.5 * (lag as f64 / e1).powi(2)).exp(),
                &|lag| (-0.5 * (lag as f64 / e2).powi(2)).exp(),
            ],
        );
        let sigma2 = 0.1;
        // Exact logdet via per-factor dense eigendecompositions.
        let d1 = Mat::from_fn(m1, m1, |i, j| se(m1, e1)[i.abs_diff(j)]);
        let d2 = Mat::from_fn(m2, m2, |i, j| se(m2, e2)[i.abs_diff(j)]);
        let ke = KronEig::new(&[d1, d2]);
        let exact: f64 = ke.eigenvalues().iter().map(|&v| (v.max(0.0) + sigma2).ln()).sum();
        let approx = kt.logdet_whittle(sigma2);
        let rel = (approx - exact).abs() / exact.abs();
        assert!(rel < 0.05, "rel err {rel}");
    }

    #[test]
    fn sqrt_matvec_squares_to_whittle_matvec() {
        let kt = KronToeplitz::new_whittle(
            vec![se(8, 2.0), se(4, 1.0)],
            3,
            &[
                &|lag| (-0.5 * (lag as f64 / 2.0).powi(2)).exp(),
                &|lag| (-0.5 * (lag as f64 / 1.0).powi(2)).exp(),
            ],
        );
        let x: Vec<f64> = (0..32).map(|i| (i as f64 * 0.3).cos()).collect();
        let got = kt.sqrt_matvec(&kt.sqrt_matvec(&x));
        // S^2 = C (whittle circulant product), not exactly K_UU; compare to
        // the circulant-product MVM.
        let shape = kt.shape();
        let mut want = x;
        for (axis, c) in kt.circulants.iter().enumerate() {
            apply_along_axis(&mut want, &shape, axis, |line, out| {
                let r = c.matvec(line);
                out.copy_from_slice(&r);
            });
        }
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-7);
        }
    }
}
