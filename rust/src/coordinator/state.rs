//! Serving-time model state: the O(1)-prediction precomputes frozen out
//! of a trained MSGP model, and a versioned store for hot-swapping.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::gp::msgp::MsgpModel;
use crate::grid::Grid;
use crate::interp::SparseInterp;

/// Frozen state needed to serve predictions from a trained MSGP model:
/// everything request-time is a sparse gather against these vectors
/// (paper section 5.1).
#[derive(Clone, Debug)]
pub struct ServingModel {
    /// Inducing grid geometry.
    pub grid: Grid,
    /// `sf2 * K_UU W^T alpha` (mean precompute), length `m`.
    pub u_mean: Vec<f64>,
    /// Stochastic explained-variance grid vector, length `m`.
    pub nu_u: Vec<f64>,
    /// `k(x, x) = sf2`.
    pub kss: f64,
    /// Noise variance (added to the latent variance for y-space bands).
    pub sigma2: f64,
    /// f32 copies of the grid vectors, precomputed once for the PJRT
    /// path (avoids a per-batch conversion on the hot path).
    u_mean_f32: Vec<f32>,
    nu_u_f32: Vec<f32>,
}

impl ServingModel {
    /// Extract the serving state from a trained model (computes the
    /// variance precompute if it has not been built yet).
    pub fn from_msgp(model: &mut MsgpModel) -> Self {
        if model.nu_u.is_none() {
            model.precompute_variance();
        }
        Self::from_parts(
            model.grid.clone(),
            model.u_mean.clone(),
            // PANIC-OK: `precompute_variance` above guarantees `nu_u`.
            model.nu_u.clone().unwrap(),
            model.kernel.sf2(),
            model.sigma2,
        )
    }

    /// Assemble a serving model from raw precomputes (the streaming
    /// trainer's refresh path — no [`MsgpModel`] involved).
    pub fn from_parts(
        grid: Grid,
        u_mean: Vec<f64>,
        nu_u: Vec<f64>,
        kss: f64,
        sigma2: f64,
    ) -> Self {
        assert_eq!(u_mean.len(), grid.m());
        assert_eq!(nu_u.len(), grid.m());
        ServingModel {
            grid,
            u_mean_f32: u_mean.iter().map(|&v| v as f32).collect(),
            nu_u_f32: nu_u.iter().map(|&v| v as f32).collect(),
            u_mean,
            nu_u,
            kss,
            sigma2,
        }
    }

    /// Input dimensionality.
    pub fn dim(&self) -> usize {
        self.grid.dim()
    }

    /// Grid size.
    pub fn m(&self) -> usize {
        self.grid.m()
    }

    /// Native-engine batched prediction: sparse `W_*` gather on the CPU.
    /// Returns `(means, variances)`; variances are observation-space
    /// (`+ sigma2`) to match the PJRT artifacts.
    pub fn predict_batch(&self, points: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let w = SparseInterp::build(points, &self.grid);
        let mean = w.matvec(&self.u_mean);
        let explained = w.matvec(&self.nu_u);
        let var = explained
            .iter()
            .map(|&e| (self.kss - e).max(0.0) + self.sigma2)
            .collect();
        (mean, var)
    }

    /// Convert physical coordinates to f32 grid units (the layout the
    /// PJRT artifacts expect), clamping one cell inside the boundary.
    pub fn to_grid_units_f32(&self, points: &[f64]) -> Vec<f32> {
        let d = self.dim();
        let mut out = Vec::with_capacity(points.len());
        for (i, &x) in points.iter().enumerate() {
            let ax = &self.grid.axes[i % d];
            let u = ax.to_units(x).clamp(1.0, (ax.n - 2) as f64);
            out.push(u as f32);
        }
        out
    }

    /// Grid vectors as f32 (precomputed; for the PJRT path).
    pub fn grid_vecs_f32(&self) -> (&[f32], &[f32]) {
        (&self.u_mean_f32, &self.nu_u_f32)
    }
}

/// The live-model slot: a single hot-swappable `Arc<ServingModel>`.
///
/// Readers (`get`) take a cheap clone of the `Arc` and work against an
/// immutable snapshot; the ingest loop publishes a refreshed model with
/// `swap`. A batch in flight keeps serving its snapshot — a swap can
/// never tear a model mid-batch, and a reader sees either the old or the
/// new model in full.
#[derive(Debug)]
pub struct ModelSlot {
    inner: RwLock<Arc<ServingModel>>,
}

impl ModelSlot {
    /// Slot holding an initial model.
    pub fn new(model: ServingModel) -> Self {
        ModelSlot { inner: RwLock::new(Arc::new(model)) }
    }

    /// Snapshot of the current model (cheap: one `Arc` clone).
    pub fn get(&self) -> Arc<ServingModel> {
        // Poison recovery: the guarded value is a bare `Arc` replaced
        // atomically in `swap` — it is well-formed even if some holder
        // panicked, so serving continues through supervised restarts.
        self.inner.read().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Atomically publish a new model; returns the previous snapshot.
    pub fn swap(&self, model: ServingModel) -> Arc<ServingModel> {
        // Poison recovery: see `get`.
        let mut w = self.inner.write().unwrap_or_else(|e| e.into_inner());
        std::mem::replace(&mut *w, Arc::new(model))
    }
}

/// A shard-indexed table of hot-swappable model slots: one
/// [`ModelSlot`] per spatial shard, each swapped atomically and
/// independently by its shard's trainer thread. Readers snapshot only
/// the slots a batch actually touches, so one shard refreshing never
/// stalls (or tears) predictions served by the others.
#[derive(Debug)]
pub struct ShardSlots {
    slots: Vec<ModelSlot>,
}

impl ShardSlots {
    /// Build a table from one initial model per shard.
    pub fn new(models: Vec<ServingModel>) -> Self {
        assert!(!models.is_empty(), "shard table needs at least one slot");
        ShardSlots { slots: models.into_iter().map(ModelSlot::new).collect() }
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when the table has no slots (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Snapshot shard `s`'s current model.
    pub fn get(&self, s: usize) -> Arc<ServingModel> {
        self.slots[s].get()
    }

    /// Atomically publish a new model for shard `s`.
    pub fn swap(&self, s: usize, model: ServingModel) -> Arc<ServingModel> {
        self.slots[s].swap(model)
    }
}

/// A versioned, hot-swappable store of serving models.
#[derive(Default)]
pub struct ModelStore {
    inner: RwLock<HashMap<String, Arc<ServingModel>>>,
}

impl ModelStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Install (or replace) a model under a name. Readers holding the old
    /// `Arc` finish their batches on the old version — swap is atomic.
    pub fn install(&self, name: &str, model: ServingModel) {
        // Poison recovery: each map entry is replaced whole, so the map
        // is well-formed across a panicking holder.
        self.inner
            .write()
            .unwrap_or_else(|e| e.into_inner())
            .insert(name.to_string(), Arc::new(model));
    }

    /// Fetch a model by name.
    pub fn get(&self, name: &str) -> Option<Arc<ServingModel>> {
        // Poison recovery: see `install`.
        self.inner.read().unwrap_or_else(|e| e.into_inner()).get(name).cloned()
    }

    /// Remove a model.
    pub fn remove(&self, name: &str) -> bool {
        // Poison recovery: see `install`.
        self.inner.write().unwrap_or_else(|e| e.into_inner()).remove(name).is_some()
    }

    /// Installed model names.
    pub fn names(&self) -> Vec<String> {
        // Poison recovery: see `install`.
        self.inner.read().unwrap_or_else(|e| e.into_inner()).keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_stress_1d;
    use crate::gp::msgp::{KernelSpec, MsgpConfig};
    use crate::kernels::{KernelType, ProductKernel};

    fn serving_model() -> ServingModel {
        let data = gen_stress_1d(200, 0.05, 7);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let cfg = MsgpConfig { n_per_dim: vec![128], n_var_samples: 20, ..Default::default() };
        let mut model = MsgpModel::fit(kernel, 0.01, data, cfg).unwrap();
        ServingModel::from_msgp(&mut model)
    }

    #[test]
    fn predict_batch_matches_model_fast_paths() {
        let data = gen_stress_1d(200, 0.05, 7);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let cfg = MsgpConfig { n_per_dim: vec![128], n_var_samples: 20, ..Default::default() };
        let mut model = MsgpModel::fit(kernel, 0.01, data, cfg).unwrap();
        let sm = ServingModel::from_msgp(&mut model);
        let xs: Vec<f64> = (0..20).map(|i| -8.0 + 0.8 * i as f64).collect();
        let (mean, var) = sm.predict_batch(&xs);
        let want_mean = model.predict_mean(&xs);
        let want_var = model.predict_var(&xs);
        for (a, b) in mean.iter().zip(&want_mean) {
            assert!((a - b).abs() < 1e-12);
        }
        for (a, b) in var.iter().zip(&want_var) {
            assert!((a - (b + model.sigma2)).abs() < 1e-12);
        }
    }

    #[test]
    fn grid_units_roundtrip_and_clamp() {
        let sm = serving_model();
        let ax = &sm.grid.axes[0];
        let mid = ax.coord(ax.n / 2);
        let u = sm.to_grid_units_f32(&[mid, 1e9, -1e9]);
        assert!((u[0] as f64 - ax.n as f64 / 2.0).abs() < 1e-3);
        assert!(u[1] as f64 <= (ax.n - 2) as f64);
        assert!(u[2] >= 1.0);
    }

    #[test]
    fn model_slot_swap_returns_previous_snapshot() {
        let sm = serving_model();
        let slot = ModelSlot::new(sm.clone());
        let held = slot.get();
        let mut sm2 = sm;
        sm2.sigma2 = 42.0;
        let old = slot.swap(sm2);
        // The pre-swap handle and the returned snapshot are the same
        // version; new readers see the new model.
        assert!(Arc::ptr_eq(&held, &old));
        assert!((slot.get().sigma2 - 42.0).abs() < 1e-12);
        assert!(held.sigma2 < 1.0);
    }

    #[test]
    fn from_parts_matches_from_msgp() {
        let data = gen_stress_1d(200, 0.05, 7);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let cfg = MsgpConfig { n_per_dim: vec![128], n_var_samples: 20, ..Default::default() };
        let mut model = MsgpModel::fit(kernel, 0.01, data, cfg).unwrap();
        let a = ServingModel::from_msgp(&mut model);
        let b = ServingModel::from_parts(
            model.grid.clone(),
            model.u_mean.clone(),
            model.nu_u.clone().unwrap(),
            model.kernel.sf2(),
            model.sigma2,
        );
        let xs: Vec<f64> = (0..10).map(|i| -4.0 + i as f64).collect();
        let (ma, va) = a.predict_batch(&xs);
        let (mb, vb) = b.predict_batch(&xs);
        assert_eq!(ma, mb);
        assert_eq!(va, vb);
    }

    #[test]
    fn store_swap_is_atomic_for_readers() {
        let store = ModelStore::new();
        let sm = serving_model();
        store.install("prod", sm.clone());
        let held = store.get("prod").unwrap();
        let mut sm2 = sm;
        sm2.sigma2 = 99.0;
        store.install("prod", sm2);
        // Old handle still serves the old version.
        assert!(held.sigma2 < 1.0);
        assert!((store.get("prod").unwrap().sigma2 - 99.0).abs() < 1e-12);
        assert!(store.remove("prod"));
        assert!(store.get("prod").is_none());
    }
}
