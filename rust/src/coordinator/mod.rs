//! Layer-3 serving coordinator.
//!
//! MSGP's O(1)-per-point predictions (paper section 5.1) make a trained GP
//! servable like any other model: all request-time work is a sparse
//! interpolation against two precomputed grid vectors. This module turns
//! that into a serving system:
//!
//! * [`state`] — [`state::ServingModel`]: the frozen precomputes
//!   (`u_mean`, `nu_U`, grid geometry, hypers) extracted from a trained
//!   [`crate::gp::msgp::MsgpModel`], plus a versioned model store.
//! * [`router`] — picks the execution backend per batch: a compiled PJRT
//!   artifact for bucket sizes that were AOT-compiled (`make artifacts`),
//!   or the native Rust engine otherwise.
//! * [`batcher`] — dynamic batching: requests are collected up to a
//!   deadline or bucket capacity, padded to the bucket size, executed,
//!   and the replies fanned back out.
//! * [`server`] — the front-end: a thread-backed queue with blocking and
//!   async submission, graceful shutdown, and metrics. Online servers
//!   ([`server::Server::start_online`]) add a background ingest/refresh
//!   thread that absorbs streamed observations through the `/ingest`
//!   route and hot-swaps refreshed snapshots into the live
//!   [`state::ModelSlot`].
//! * [`metrics`] — latency histograms, throughput counters, the
//!   streaming ingest/refresh counters, per-shard
//!   ingest/refresh/queue-depth counters for sharded servers, and the
//!   per-route `http_*` front-door families.
//! * [`http`] — the real network front door: a dependency-free
//!   HTTP/1.1 transport ([`http::HttpServer`]) with keep-alive,
//!   pipelining, a worker pool, per-request trace spans, and per-route
//!   latency/status metrics, dispatching into [`server::Server`].
//!
//! Sharded deployments ([`server::Server::start_sharded`]) swap the
//! single [`state::ModelSlot`] for a [`state::ShardSlots`] table inside
//! [`crate::shard::ShardedServing`]; the batcher groups each flush by
//! owning shard ([`batcher::run_sharded`]) and the `/shards` route
//! exposes the live layout.

pub mod state;
pub mod router;
pub mod batcher;
pub mod http;
pub mod metrics;
pub mod server;

pub use batcher::{BatcherConfig, IngestBatch, Job, Prediction, Request};
pub use http::{HttpConfig, HttpServer};
pub use metrics::{HttpErrClass, HttpMetrics, Metrics, ShardMetrics};
pub use router::{Engine, EngineSpec, Route, Router};
pub use server::Server;
pub use state::{ModelSlot, ModelStore, ServingModel, ShardSlots};
