//! The serving front-end: a thread-backed job queue with blocking and
//! asynchronous submission, metrics, graceful shutdown — and, for online
//! servers, a background ingest/refresh thread that absorbs streamed
//! observations and hot-swaps refreshed model snapshots into the live
//! [`ModelSlot`].

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use super::batcher::{self, BatcherConfig, IngestBatch, Job, Prediction, Request};
use super::metrics::{Metrics, WorkerKind};
use super::router::{metrics_format, query_flag, query_param, EngineSpec, MetricsFormat, Route};
use super::state::{ModelSlot, ServingModel};
use crate::cluster::ClusterNode;
use crate::fault::{
    self, Checkpoint, CkptConfig, CkptTrigger, Supervisor, SupervisorPolicy, Verdict,
};
use crate::obs::trace::Tracer;
use crate::shard::ShardedTrainer;
use crate::stream::{RefreshStats, StreamConfig, StreamTrainer};
use crate::util::json::Json;

/// A running prediction (and optionally ingestion) server for one model
/// — or, via [`Server::start_sharded`], for a spatially sharded fleet of
/// per-shard models served behind one front door.
pub struct Server {
    tx: Option<SyncSender<Job>>,
    handle: Option<std::thread::JoinHandle<()>>,
    ingest_handle: Option<std::thread::JoinHandle<()>>,
    /// Shared metrics.
    pub metrics: Arc<Metrics>,
    /// Live model slot (readable for diagnostics; swapped by the ingest
    /// thread on refresh). `None` on sharded servers, whose slots live
    /// in the [`crate::shard::ShardedServing`] table.
    pub slot: Option<Arc<ModelSlot>>,
    /// The sharded trainer facade (sharded servers only).
    sharded: Option<Arc<ShardedTrainer>>,
    /// The cluster node (multi-process servers only): predictions are
    /// answered synchronously from its merged slot, ingest routes to
    /// its owned stripe, and `/cluster` + `/peers` introspect it.
    cluster: Option<Arc<ClusterNode>>,
    dim: usize,
    streaming: bool,
}

impl Server {
    /// Start a static server: the batcher thread serves one frozen model.
    pub fn start(model: ServingModel, engine: EngineSpec, cfg: BatcherConfig) -> Server {
        let slot = Arc::new(ModelSlot::new(model));
        Self::start_with_slot(slot, engine, cfg, None, None)
    }

    /// Start an online server: the `/ingest` route feeds the stream
    /// trainer on a background thread, which refreshes the prediction
    /// caches every `trainer.cfg.refresh_every` ingested points (plus
    /// hyper re-opts every `reopt_every`) and atomically swaps the new
    /// snapshot into the live slot. Prediction batches always execute
    /// against a consistent snapshot.
    ///
    /// When `MSGP_CKPT_DIR` is set, the newest valid checkpoint in it is
    /// restored first (the sufficient statistics are additive, so the
    /// replayed refresh reproduces the pre-crash model bit-for-bit) and
    /// the ingest thread persists updated checkpoints on the configured
    /// cadence plus at graceful shutdown. `MSGP_REFRESH_DEADLINE_MS`
    /// arms the refresh soft deadline when the config leaves it unset.
    pub fn start_online(
        mut trainer: StreamTrainer,
        engine: EngineSpec,
        cfg: BatcherConfig,
    ) -> Server {
        fault::init_from_env();
        if trainer.cfg.refresh_deadline_ms.is_none() {
            trainer.cfg.refresh_deadline_ms =
                std::env::var("MSGP_REFRESH_DEADLINE_MS").ok().and_then(|v| v.parse::<u64>().ok());
        }
        let ckpt = CkptConfig::from_env();
        let mut restored_seq = None;
        if let Some(path) = ckpt.unsharded_path() {
            if let Some(dir) = path.parent() {
                // Best-effort: a missing checkpoint directory surfaces
                // later as ckpt_write_errors_total, not a startup panic.
                let _ = std::fs::create_dir_all(dir);
            }
            if let Some((c, from)) = fault::load_newest(&path) {
                let seq = c.seq;
                match restore_trainer(c, trainer.cfg.clone()) {
                    Some(t) => {
                        crate::log_info!(
                            "restored checkpoint seq={seq} n={} from {}",
                            t.ski().n(),
                            from.display()
                        );
                        trainer = t;
                        restored_seq = Some(seq);
                    }
                    None => crate::log_warn!(
                        "checkpoint {} is incompatible with the configured stream (ignoring)",
                        from.display()
                    ),
                }
            }
        }
        // A restored trainer is dirty (`dirty_points = n`), so this
        // initial publish replays the refresh from the statistics alone
        // — recovery completes before the server accepts traffic.
        let slot = Arc::new(ModelSlot::new(trainer.serving_model()));
        let (itx, irx) = mpsc::sync_channel::<IngestBatch>(1024);
        let server = Self::start_with_slot(slot, engine, cfg, Some(itx), Some((irx, trainer, ckpt)));
        if let Some(seq) = restored_seq {
            server.metrics.ckpt_restores_total.inc();
            server.metrics.ckpt_last_seq.store(seq, Ordering::Relaxed);
        }
        server
    }

    fn start_with_slot(
        slot: Arc<ModelSlot>,
        engine: EngineSpec,
        cfg: BatcherConfig,
        ingest_tx: Option<SyncSender<IngestBatch>>,
        ingest_loop: Option<(Receiver<IngestBatch>, StreamTrainer, CkptConfig)>,
    ) -> Server {
        crate::obs::trace::init_from_env();
        crate::obs::log::init_from_env();
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::sync_channel::<Job>(4096);
        let dim = slot.get().dim();
        let streaming = ingest_tx.is_some();
        let slot2 = slot.clone();
        let met2 = metrics.clone();
        let handle = std::thread::Builder::new()
            .name("msgp-batcher".into())
            .spawn(move || batcher::run(rx, engine, slot2, cfg, met2, ingest_tx))
            // PANIC-OK: thread spawn fails only on resource exhaustion at
            // startup; there is no server to degrade into yet.
            .expect("spawn batcher");
        let ingest_handle = ingest_loop.map(|(irx, trainer, ckpt)| {
            let slot3 = slot.clone();
            let met3 = metrics.clone();
            std::thread::Builder::new()
                .name("msgp-ingest".into())
                .spawn(move || run_ingest(irx, trainer, slot3, met3, ckpt))
                // PANIC-OK: startup-time spawn, same as the batcher above.
                .expect("spawn ingest")
        });
        Server {
            tx: Some(tx),
            handle: Some(handle),
            ingest_handle,
            metrics,
            slot: Some(slot),
            sharded: None,
            cluster: None,
            dim,
            streaming,
        }
    }

    /// Serve a running [`ClusterNode`] behind the standard front door:
    /// predictions answer synchronously from the node's merged local
    /// model (never a network hop), `/ingest` feeds the node's owned
    /// shard stripe, `/flush` cuts + ships + publishes, and the
    /// `/cluster` and `/peers` routes expose membership, replica, and
    /// transport state. The server shares the node's metrics registry,
    /// so `/metrics` carries the `peer_*` families.
    pub fn start_cluster(node: Arc<ClusterNode>) -> Server {
        crate::obs::trace::init_from_env();
        crate::obs::log::init_from_env();
        fault::init_from_env();
        let metrics = node.metrics();
        let slot = node.slot();
        let dim = node.dim();
        Server {
            tx: None,
            handle: None,
            ingest_handle: None,
            metrics,
            slot: Some(slot),
            sharded: None,
            cluster: Some(node),
            dim,
            streaming: true,
        }
    }

    /// The cluster node, when this is a cluster server.
    pub fn cluster(&self) -> Option<&Arc<ClusterNode>> {
        self.cluster.as_ref()
    }

    /// Start a sharded server: predictions flow through a batcher that
    /// groups each flush by owning shard and serves it from the
    /// shard-indexed slot table (with seam blending); `/ingest` routes
    /// directly to the [`ShardedTrainer`] facade, whose workers refresh
    /// and hot-swap their slots independently. The server shares the
    /// trainer's metrics, so `/metrics` carries the per-shard counters.
    pub fn start_sharded(trainer: ShardedTrainer, cfg: BatcherConfig) -> Server {
        crate::obs::trace::init_from_env();
        crate::obs::log::init_from_env();
        fault::init_from_env();
        let trainer = Arc::new(trainer);
        let metrics = trainer.metrics.clone();
        let serving = trainer.serving();
        let dim = trainer.plan().global().dim();
        let (tx, rx) = mpsc::sync_channel::<Job>(4096);
        let met2 = metrics.clone();
        let handle = std::thread::Builder::new()
            .name("msgp-shard-batcher".into())
            .spawn(move || batcher::run_sharded(rx, serving, cfg, met2))
            // PANIC-OK: startup-time spawn; nothing is serving yet.
            .expect("spawn batcher");
        Server {
            tx: Some(tx),
            handle: Some(handle),
            ingest_handle: None,
            metrics,
            slot: None,
            sharded: Some(trainer),
            cluster: None,
            dim,
            streaming: true,
        }
    }

    /// The sharded trainer facade, when this is a sharded server (for
    /// decay epochs, whole-domain re-opts, and merged snapshots).
    pub fn shard_trainer(&self) -> Option<&Arc<ShardedTrainer>> {
        self.sharded.as_ref()
    }

    /// `/shards` introspection payload (sharded servers only).
    pub fn shards_summary(&self) -> Option<String> {
        self.sharded.as_ref().map(|t| t.summary())
    }

    /// `/shards?verbose=1` payload: the per-shard layout lines extended
    /// with the shard's live metric counters (sharded servers only).
    pub fn shards_summary_verbose(&self) -> Option<String> {
        self.sharded.as_ref().map(|t| t.summary_verbose())
    }

    /// Input dimensionality the server was started with (points posted
    /// to `/predict` carry `dim` coordinates each).
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// `/metrics` payload in the requested rendering (the legacy
    /// one-line summary or Prometheus text exposition).
    pub fn metrics_text(&self, format: MetricsFormat) -> String {
        match format {
            MetricsFormat::Summary => self.metrics.summary(),
            MetricsFormat::Prometheus => self.metrics.render_prometheus(),
        }
    }

    /// `/healthz` payload: a JSON readiness probe with last-refresh
    /// age, reservoir size, and the deepest shard queue — the signals
    /// a load harness needs to know whether the deployment is keeping
    /// up. A static (non-streaming) server is ready by construction
    /// and reports `last_refresh_age_us: null`.
    pub fn healthz(&self) -> String {
        self.health().1
    }

    /// Readiness with a verdict: `(healthy, json_body)`. The body
    /// always carries the probe fields; when unhealthy, `status` flips
    /// to `"unhealthy"` and `reason` says why — the HTTP front door
    /// maps that to a 503 so load balancers stop routing here.
    /// Unhealthy when (a) `MSGP_STALE_MS` is set, the server streams,
    /// and the last published refresh is older than that budget; (b) a
    /// supervised worker was poisoned (its restart budget is spent); or
    /// (c) a checkpoint recovery replay is still running.
    pub fn health(&self) -> (bool, String) {
        let age = self.metrics.last_refresh_age_us();
        let mut reasons: Vec<String> = Vec::new();
        if self.streaming {
            if let Some(limit_ms) =
                std::env::var("MSGP_STALE_MS").ok().and_then(|v| v.parse::<u64>().ok())
            {
                if let Some(us) = age {
                    if us > limit_ms.saturating_mul(1000) {
                        reasons.push(format!(
                            "stale: last refresh {}ms ago exceeds MSGP_STALE_MS={limit_ms}",
                            us / 1000
                        ));
                    }
                }
            }
        }
        let poisoned = self.metrics.worker_poisoned.get();
        if poisoned > 0 {
            reasons.push(format!("{poisoned} supervised worker(s) poisoned"));
        }
        if self.metrics.recovering.get() > 0 {
            reasons.push("checkpoint recovery replay in progress".to_string());
        }
        let healthy = reasons.is_empty();
        let mut pairs = vec![
            (
                "status",
                Json::Str(if healthy { "ok" } else { "unhealthy" }.to_string()),
            ),
            (
                "reason",
                if healthy { Json::Null } else { Json::Str(reasons.join("; ")) },
            ),
            ("degraded", Json::Bool(self.metrics.degraded_mode.get() > 0)),
            ("streaming", Json::Bool(self.streaming)),
            ("shards", Json::Num(self.metrics.shards.len() as f64)),
            (
                "refresh_count",
                Json::Num(self.metrics.refresh_count.get() as f64),
            ),
            (
                "last_refresh_age_us",
                match age {
                    Some(us) => Json::Num(us as f64),
                    None => Json::Null,
                },
            ),
            (
                "reservoir_points",
                Json::Num(self.metrics.total_reservoir_points() as f64),
            ),
            (
                "max_shard_queue_depth",
                Json::Num(self.metrics.max_shard_queue_depth() as f64),
            ),
            (
                "ingested_points_total",
                Json::Num(self.metrics.ingested_points_total.get() as f64),
            ),
        ];
        if let Some(node) = &self.cluster {
            pairs.push(("node", Json::Num(node.node_id() as f64)));
            pairs.push(("peers_down", Json::Num(node.peers_down() as f64)));
            pairs.push(("recovering", Json::Bool(node.recovering())));
        }
        let body = Json::obj(pairs).to_string();
        (healthy, body)
    }

    /// `/failpoints`: inspect and (re)configure the failpoint registry.
    /// `?set=name:action@prob;...` installs specs (the `:` separator
    /// form, because `=` delimits query pairs), `?clear=1` disarms
    /// everything; either way the response is the post-change registry
    /// snapshot. Errors (malformed specs) surface as `Err` so the HTTP
    /// layer can answer 400.
    pub fn handle_failpoints(&self, path: &str) -> Result<String, String> {
        if query_flag(path, "clear") {
            fault::clear_all();
        }
        if let Some(spec) = query_param(path, "set") {
            if spec.is_empty() {
                return Err("empty failpoint spec".to_string());
            }
            fault::configure(spec)?;
        }
        let rows: Vec<Json> = fault::snapshot()
            .into_iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::Str(s.name)),
                    ("action", Json::Str(s.action)),
                    ("prob", Json::Num(s.prob)),
                    ("hits", Json::Num(s.hits as f64)),
                    ("fires", Json::Num(s.fires as f64)),
                ])
            })
            .collect();
        Ok(Json::obj(vec![
            ("armed", Json::Bool(fault::armed())),
            ("failpoints", Json::Arr(rows)),
        ])
        .to_string())
    }

    /// Dispatch a GET-style route to its text payload — the in-process
    /// half of the HTTP front door ([`super::http::HttpServer`] and the
    /// CI smoke job both drive the router through this). The raw query
    /// string is honored: `/metrics?format=prom`, `/shards?verbose=1`,
    /// and `/trace?clear=1` (drain the rings after the dump, so
    /// repeated scrapes don't re-export stale spans). Returns `None`
    /// for body-carrying routes (`/predict`, `/ingest` — use
    /// [`Self::predict`] / [`Self::ingest`]), for `/models` (served
    /// from installed-artifact state, not the server), for `/shards` on
    /// unsharded servers, and for unknown paths.
    pub fn handle_path(&self, path: &str) -> Option<String> {
        match Route::parse(path)? {
            Route::Metrics => Some(self.metrics_text(metrics_format(path))),
            Route::Health => Some(self.healthz()),
            Route::Trace => {
                let dump = Tracer::dump_json();
                if query_flag(path, "clear") {
                    Tracer::clear();
                }
                Some(dump)
            }
            Route::Shards => {
                if query_flag(path, "verbose") {
                    self.shards_summary_verbose()
                } else {
                    self.shards_summary()
                }
            }
            Route::Failpoints => self.handle_failpoints(path).ok(),
            Route::Cluster => self.cluster.as_ref().map(|n| n.cluster_summary().to_string()),
            Route::Peers => self.cluster.as_ref().map(|n| n.peers_summary().to_string()),
            Route::Predict | Route::Ingest | Route::Models => None,
        }
    }

    /// Predict with the cluster's bounded-staleness report: the usual
    /// prediction plus `Some(age_ms)` when the point's owner node is
    /// down and the answer came from a local replica (the HTTP layer
    /// surfaces it as `X-Msgp-Staleness`). `None` on non-cluster
    /// servers.
    pub fn cluster_predict(&self, x: &[f64]) -> Option<(Prediction, Option<u64>)> {
        let node = self.cluster.as_ref()?;
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let (mean, var, staleness_ms) = node.predict_one(x);
        self.metrics.completed.fetch_add(1, Ordering::Relaxed);
        Some((Prediction { mean, var }, staleness_ms))
    }

    /// Submit a point; returns a receiver for the reply.
    pub fn submit(&self, x: Vec<f64>) -> anyhow::Result<Receiver<anyhow::Result<Prediction>>> {
        anyhow::ensure!(x.len() == self.dim, "point dim {} vs model dim {}", x.len(), self.dim);
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        if let Some(node) = &self.cluster {
            // Cluster predictions are always local (the merged replica
            // view) and never block on the network, so answer inline.
            let (mean, var, _staleness) = node.predict_one(&x);
            let _ = rtx.send(Ok(Prediction { mean, var }));
            self.metrics.completed.fetch_add(1, Ordering::Relaxed);
            return Ok(rrx);
        }
        self.tx
            .as_ref()
            // PANIC-OK: `tx` is Some until shutdown_inner, which takes
            // `&mut self`, so no shared-reference caller can race it.
            .expect("server running")
            .send(Job::Predict(Request { x, reply: rtx, t0: Instant::now() }))
            .map_err(|_| anyhow::anyhow!("server shut down"))?;
        Ok(rrx)
    }

    /// Blocking predict.
    pub fn predict(&self, x: Vec<f64>) -> anyhow::Result<Prediction> {
        self.submit(x)?
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped reply"))?
    }

    /// `/ingest`: absorb a batch of observations (row-major `k x D`
    /// inputs). Blocks until the stream trainer has applied the batch;
    /// returns the number of points absorbed. The serving model is
    /// unaffected until the next refresh swap.
    pub fn ingest(&self, xs: Vec<f64>, ys: Vec<f64>) -> anyhow::Result<usize> {
        anyhow::ensure!(self.streaming, "server has no stream trainer (use start_online)");
        anyhow::ensure!(
            xs.len() == ys.len() * self.dim,
            "ingest shape: xs {} vs {} points x dim {}",
            xs.len(),
            ys.len(),
            self.dim
        );
        // Reject non-finite values at the front door: a NaN coordinate
        // would silently corrupt the sufficient statistics (its stencil
        // degenerates to cell 0) and a NaN target poisons `W^T y`.
        anyhow::ensure!(
            xs.iter().all(|v| v.is_finite()) && ys.iter().all(|v| v.is_finite()),
            "ingest rejects non-finite coordinates/targets"
        );
        if let Some(node) = &self.cluster {
            // Cluster ingest keeps only the points whose owner shard
            // lives on this node; callers fan the stream to every node.
            // While the node is catching up after a restart this fails
            // with `cluster::Recovering` (the HTTP front door maps it
            // to 503): accepted points would be lost to the catch-up
            // adoption, so the caller must gate on recovery and retry.
            return node.ingest(&xs, &ys).map_err(anyhow::Error::new);
        }
        if let Some(t) = &self.sharded {
            // Sharded ingest bypasses the batch queue: the facade routes
            // per shard and blocks until every owning worker acks.
            return Ok(t.ingest_batch(&xs, &ys));
        }
        self.ingest_inner(xs, ys, false)
    }

    /// Force a refresh + model swap now (deterministic cut-over: after
    /// this returns, new prediction batches see every previously acked
    /// ingest).
    pub fn flush_stream(&self) -> anyhow::Result<usize> {
        anyhow::ensure!(self.streaming, "server has no stream trainer (use start_online)");
        if let Some(node) = &self.cluster {
            node.flush();
            return Ok(0);
        }
        if let Some(t) = &self.sharded {
            t.flush();
            return Ok(0);
        }
        self.ingest_inner(Vec::new(), Vec::new(), true)
    }

    fn ingest_inner(&self, xs: Vec<f64>, ys: Vec<f64>, refresh_now: bool) -> anyhow::Result<usize> {
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.tx
            .as_ref()
            // PANIC-OK: same invariant as `submit` — `tx` outlives every
            // shared reference to the server.
            .expect("server running")
            .send(Job::Ingest(IngestBatch { xs, ys, reply: rtx, refresh_now }))
            .map_err(|_| anyhow::anyhow!("server shut down"))?;
        rrx.recv().map_err(|_| anyhow::anyhow!("server dropped ingest ack"))?
    }

    /// Graceful shutdown: close the queue, drain, join the threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.tx.take(); // closing the channel stops the batcher loop
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        // The batcher owns the ingest sender; its exit closes the ingest
        // channel, which stops the ingest thread.
        if let Some(h) = self.ingest_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Mirror one refresh's [`RefreshStats`] into the metrics registry
/// (wall, CG iterations, pool width, per-stage wall-clocks).
fn record_refresh_metrics(metrics: &Metrics, s: &RefreshStats) {
    metrics.record_refresh(s.wall);
    metrics.record_refresh_cg(s.mean_iters as u64, s.var_iters_total as u64);
    metrics.record_refresh_threads(s.threads as u64);
    metrics.record_refresh_stages(
        s.stage_rhs.as_micros() as u64,
        s.block_solve.as_micros() as u64,
        s.map_back.as_micros() as u64,
    );
}

/// Rebuild a stream trainer around checkpointed sufficient statistics,
/// or `None` when the checkpoint does not fit the configured stream
/// (sharded layout, or a probe-count mismatch that would invalidate the
/// variance accumulators). The restored trainer is dirty
/// (`dirty_points = n`), so the first `serving_model()` call replays
/// the refresh and reconstructs every cache from the statistics alone.
fn restore_trainer(ckpt: Checkpoint, cfg: StreamConfig) -> Option<StreamTrainer> {
    if ckpt.skis.len() != 1 {
        return None;
    }
    let ski = ckpt.skis.into_iter().next()?;
    if ski.probes().len() != cfg.msgp.n_var_samples.max(1) {
        return None;
    }
    Some(StreamTrainer::from_stats(ckpt.kernel, ckpt.sigma2, cfg, ski))
}

/// Cadence bookkeeping the ingest loop keeps across batches (and across
/// supervised restarts after an injected or organic panic).
struct IngestState {
    since_reopt: usize,
    // Swap cadence is tracked separately from `dirty_points`: a
    // re-optimization refreshes the caches (zeroing `dirty_points`)
    // and MUST publish, otherwise the automatic swap would starve
    // whenever `reopt_every <= refresh_every`.
    since_swap: usize,
    // Preconditioner fallbacks observed so far (the trainer counts them
    // cumulatively; the metric mirrors the deltas).
    fallbacks_seen: u64,
    trigger: CkptTrigger,
    seq: u64,
}

/// Write one checkpoint of the trainer's current statistics (atomic
/// tmp+fsync+rename with rotation). Failures are absorbed into
/// `ckpt_write_errors_total` — a full disk must not take serving down.
fn write_checkpoint(
    trainer: &StreamTrainer,
    metrics: &Metrics,
    ckpt: &CkptConfig,
    st: &mut IngestState,
) {
    let path = match ckpt.unsharded_path() {
        Some(p) => p,
        None => return,
    };
    let t0 = Instant::now();
    let c = Checkpoint {
        seq: st.seq + 1,
        kernel: trainer.kernel.clone(),
        sigma2: trainer.sigma2,
        skis: vec![trainer.ski().clone()],
    };
    match fault::write_atomic(&path, &c) {
        Ok(()) => {
            st.seq += 1;
            st.trigger.note_written();
            metrics.record_ckpt_write(st.seq, t0.elapsed());
        }
        Err(e) => {
            metrics.ckpt_write_errors_total.inc();
            crate::log_warn!("checkpoint write failed (serving continues): {e}");
        }
    }
}

/// The ingest/refresh loop (the online server's background thread): apply
/// batches to the stream trainer, count them, publish refreshed
/// snapshots on the configured cadence, and persist checkpoints of the
/// sufficient statistics. Each batch runs under a panic supervisor:
/// a panicking batch is dropped (its caller sees a clean channel error,
/// not a hang), the worker restarts with backoff, and repeated failures
/// inside the policy window poison the worker — flipping `/healthz`
/// unhealthy — rather than looping hot.
fn run_ingest(
    rx: Receiver<IngestBatch>,
    mut trainer: StreamTrainer,
    slot: Arc<ModelSlot>,
    metrics: Arc<Metrics>,
    ckpt: CkptConfig,
) {
    let mut st = IngestState {
        since_reopt: 0,
        since_swap: 0,
        fallbacks_seen: trainer.precond_fallbacks,
        trigger: CkptTrigger::default(),
        // Continue the restored sequence so rotation keeps strictly
        // newer checkpoints distinguishable after a crash-restart.
        seq: metrics.ckpt_last_seq.get(),
    };
    let mut sup = Supervisor::new(SupervisorPolicy::default(), 0x1276 ^ std::process::id() as u64);
    while let Ok(batch) = rx.recv() {
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            ingest_one(&mut trainer, &slot, &metrics, &ckpt, &mut st, batch);
        }));
        if outcome.is_err() {
            // The batch's reply sender unwound with the closure, so the
            // blocked caller sees "server dropped ingest ack" instead of
            // hanging forever.
            metrics.record_worker_restart(WorkerKind::Ingest);
            match sup.on_failure() {
                Verdict::Restart(backoff) => {
                    crate::log_warn!(
                        "ingest worker panicked; restarting after {}ms",
                        backoff.as_millis()
                    );
                    std::thread::sleep(backoff);
                }
                Verdict::Poison => {
                    metrics.worker_poisoned.fetch_add(1, Ordering::Relaxed);
                    crate::log_error!(
                        "ingest worker poisoned after repeated panics; /healthz now fails"
                    );
                    break;
                }
            }
        }
    }
    // Graceful shutdown: persist the final statistics so a restart
    // resumes from exactly what this process acked.
    if ckpt.enabled() && trainer.n() > 0 {
        write_checkpoint(&trainer, &metrics, &ckpt, &mut st);
    }
}

/// One supervised iteration of the ingest loop.
fn ingest_one(
    trainer: &mut StreamTrainer,
    slot: &ModelSlot,
    metrics: &Metrics,
    ckpt: &CkptConfig,
    st: &mut IngestState,
    batch: IngestBatch,
) {
    let _sp_batch = crate::span!("ingest.batch");
    crate::failpoint!("ingest.batch");
    let refresh_every = trainer.cfg.refresh_every.max(1);
    let reopt_every = trainer.cfg.reopt_every;
    let k = batch.ys.len();
    let rejected_before = trainer.rejected_points;
    trainer.ingest_batch(&batch.xs, &batch.ys);
    let rejected = trainer.rejected_points - rejected_before;
    let applied = k - rejected;
    if k > 0 {
        metrics.ingested_points_total.fetch_add(applied as u64, Ordering::Relaxed);
        metrics.ingest_rejected_total.fetch_add(rejected as u64, Ordering::Relaxed);
        if applied > 0 {
            metrics.ingest_batches.fetch_add(1, Ordering::Relaxed);
        }
        st.since_reopt += applied;
        st.since_swap += applied;
    }
    metrics.reservoir_points.store(trainer.reservoir_len() as u64, Ordering::Relaxed);
    // Ack as soon as the points are absorbed — a cadence-triggered
    // refresh must not stall the ingest caller (and, transitively,
    // overflow the ingest queue). `flush_stream` callers asked for a
    // swap-before-ack guarantee, so they wait.
    let mut reply = Some(batch.reply);
    if !batch.refresh_now {
        if let Some(r) = reply.take() {
            let _ = r.send(Ok(applied));
        }
    }
    let mut need_swap = batch.refresh_now;
    if reopt_every > 0 && st.since_reopt >= reopt_every {
        st.since_reopt = 0;
        match trainer.reoptimize() {
            Ok(Some(_)) => {
                metrics.reopt_count.fetch_add(1, Ordering::Relaxed);
                // reoptimize() ran a full refresh internally.
                record_refresh_metrics(metrics, &trainer.last_refresh);
                need_swap = true; // new hypers + refreshed caches: publish
            }
            Ok(None) => {}
            Err(e) => {
                crate::log_error!("stream re-optimization failed (keeping hypers): {e}")
            }
        }
    }
    if st.since_swap >= refresh_every {
        need_swap = true;
    }
    if need_swap {
        // The "refresh" span wraps the whole publish cycle, so a
        // trace decomposes it into the stage children recorded by
        // `refresh_mdomain` (stage_rhs / block_solve / map_back)
        // plus the slot swap below.
        let _sp_refresh = crate::span!("refresh");
        let refreshes_before = trainer.refresh_count;
        let sm = trainer.serving_model(); // refreshes if dirty
        let refreshed = trainer.refresh_count > refreshes_before;
        if refreshed && trainer.last_refresh.deadline_hit {
            // Degradation tier: the refresh overran its soft deadline
            // and aborted between CG iterations. Keep serving the
            // last-good snapshot; the trainer stays dirty (with the
            // partial warm starts retained), so the next cadence point
            // retries. `/healthz` reports `degraded: true` meanwhile.
            metrics.degraded_mode.store(1, Ordering::Relaxed);
            record_refresh_metrics(metrics, &trainer.last_refresh);
        } else {
            let t_swap = Instant::now();
            {
                let _sp_swap = crate::span!("refresh.slot_swap");
                slot.swap(sm);
            }
            metrics.last_swap_us.store(t_swap.elapsed().as_micros() as u64, Ordering::Relaxed);
            st.since_swap = 0;
            metrics.degraded_mode.store(0, Ordering::Relaxed);
            // Only count a refresh when one actually ran (a flush on a
            // clean trainer republishes the cached snapshot).
            if refreshed {
                record_refresh_metrics(metrics, &trainer.last_refresh);
            }
        }
    }
    if trainer.precond_fallbacks > st.fallbacks_seen {
        metrics
            .precond_fallbacks
            .fetch_add(trainer.precond_fallbacks - st.fallbacks_seen, Ordering::Relaxed);
        st.fallbacks_seen = trainer.precond_fallbacks;
    }
    if ckpt.enabled() {
        st.trigger.note_points(applied);
        if st.trigger.due(ckpt) {
            write_checkpoint(trainer, metrics, ckpt, st);
        }
    }
    if let Some(r) = reply {
        let _ = r.send(Ok(applied));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_stress_1d;
    use crate::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
    use crate::grid::{Grid, GridAxis};
    use crate::kernels::{KernelType, ProductKernel};
    use crate::stream::StreamConfig;

    fn serving_model() -> ServingModel {
        let data = gen_stress_1d(150, 0.05, 5);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let cfg = MsgpConfig { n_per_dim: vec![64], n_var_samples: 8, ..Default::default() };
        let mut model = MsgpModel::fit(kernel, 0.01, data, cfg).unwrap();
        ServingModel::from_msgp(&mut model)
    }

    #[test]
    fn blocking_predict_roundtrip() {
        let model = serving_model();
        let direct = model.predict_batch(&[1.5]);
        let server = Server::start(model, EngineSpec::Native, BatcherConfig::default());
        let p = server.predict(vec![1.5]).unwrap();
        assert!((p.mean - direct.0[0]).abs() < 1e-12);
        assert!((p.var - direct.1[0]).abs() < 1e-12);
        server.shutdown();
    }

    #[test]
    fn concurrent_submitters_all_get_replies() {
        let model = serving_model();
        let server = Arc::new(Server::start(model, EngineSpec::Native, BatcherConfig::default()));
        let mut joins = Vec::new();
        for t in 0..8 {
            let s = server.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let x = -9.0 + (t * 50 + i) as f64 * 0.04;
                    let p = s.predict(vec![x]).unwrap();
                    assert!(p.mean.is_finite() && p.var >= 0.0);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(
            server.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
            400
        );
    }

    #[test]
    fn wrong_dim_rejected_eagerly() {
        let model = serving_model();
        let server = Server::start(model, EngineSpec::Native, BatcherConfig::default());
        assert!(server.submit(vec![0.0, 1.0]).is_err());
    }

    #[test]
    fn static_server_rejects_ingest() {
        let server = Server::start(serving_model(), EngineSpec::Native, BatcherConfig::default());
        assert!(server.ingest(vec![0.5], vec![1.0]).is_err());
        assert!(server.flush_stream().is_err());
    }

    #[test]
    fn online_server_learns_from_ingested_stream() {
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 128)]);
        let cfg = StreamConfig {
            msgp: MsgpConfig { n_per_dim: vec![128], n_var_samples: 8, ..Default::default() },
            refresh_every: 1_000_000, // only explicit flushes swap
            ..Default::default()
        };
        let trainer = StreamTrainer::new(kernel, 0.01, grid, cfg);
        let server = Server::start_online(trainer, EngineSpec::Native, BatcherConfig::default());
        // Before any data: prior prediction (mean 0, var ~ kss + sigma2).
        let prior = server.predict(vec![0.0]).unwrap();
        assert!(prior.mean.abs() < 1e-9, "prior mean {}", prior.mean);
        assert!(prior.var > 0.9, "prior var {}", prior.var);
        // Stream the training set, then cut over.
        let data = gen_stress_1d(800, 0.05, 5);
        for chunk in 0..8 {
            let lo = chunk * 100;
            let hi = lo + 100;
            let k = server
                .ingest(data.x[lo..hi].to_vec(), data.y[lo..hi].to_vec())
                .unwrap();
            assert_eq!(k, 100);
        }
        server.flush_stream().unwrap();
        // After the swap the model explains the stress function.
        let p = server.predict(vec![1.5]).unwrap();
        let want = crate::data::stress_fn(1.5);
        assert!((p.mean - want).abs() < 0.1, "{} vs {want}", p.mean);
        assert!(p.var < prior.var, "posterior var must shrink");
        assert_eq!(
            server.metrics.ingested_points_total.load(Ordering::Relaxed),
            800
        );
        assert!(server.metrics.refresh_count.load(Ordering::Relaxed) >= 1);
        let s = server.metrics.summary();
        assert!(s.contains("ingested_points_total=800"), "{s}");
        server.shutdown();
    }

    #[test]
    fn healthz_and_handle_path_serve_observability_routes() {
        let server = Server::start(serving_model(), EngineSpec::Native, BatcherConfig::default());
        // /healthz: well-formed JSON with the probe fields.
        let health = Json::parse(&server.healthz()).expect("healthz is JSON");
        assert_eq!(health.get("status").and_then(|v| v.as_str()), Some("ok"));
        assert_eq!(health.get("last_refresh_age_us"), Some(&Json::Null));
        assert_eq!(health.get("max_shard_queue_depth").and_then(|v| v.as_f64()), Some(0.0));
        // handle_path dispatches the GET routes.
        let via_route = server.handle_path("/healthz").expect("healthz routed");
        assert_eq!(Json::parse(&via_route).unwrap(), health);
        let summary = server.handle_path("/metrics").expect("metrics routed");
        assert!(summary.contains("submitted="), "{summary}");
        let prom = server.handle_path("/metrics?format=prom").expect("prom routed");
        assert!(prom.contains("# TYPE submitted counter"), "{prom}");
        let trace = server.handle_path("/trace").expect("trace routed");
        assert!(Json::parse(&trace).unwrap().get("traceEvents").is_some());
        // Body-carrying / inapplicable routes are not served here.
        assert!(server.handle_path("/predict").is_none());
        assert!(server.handle_path("/shards").is_none());
        assert!(server.handle_path("/nope").is_none());
        server.shutdown();
    }

    #[test]
    fn online_ingest_updates_health_probe_fields() {
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let grid = Grid::new(vec![GridAxis::span(-12.0, 13.0, 64)]);
        let cfg = StreamConfig {
            msgp: MsgpConfig { n_per_dim: vec![64], n_var_samples: 4, ..Default::default() },
            refresh_every: 1_000_000,
            ..Default::default()
        };
        let trainer = StreamTrainer::new(kernel, 0.01, grid, cfg);
        let server = Server::start_online(trainer, EngineSpec::Native, BatcherConfig::default());
        let data = gen_stress_1d(200, 0.05, 11);
        server.ingest(data.x.clone(), data.y.clone()).unwrap();
        server.flush_stream().unwrap();
        let health = Json::parse(&server.healthz()).unwrap();
        assert_eq!(health.get("ingested_points_total").and_then(|v| v.as_f64()), Some(200.0));
        assert!(health.get("last_refresh_age_us").and_then(|v| v.as_f64()).is_some());
        assert_eq!(health.get("reservoir_points").and_then(|v| v.as_f64()), Some(200.0));
        // The flush published a refresh: the per-stage gauges carry it.
        let s = server.metrics.summary();
        assert!(s.contains("last_refresh_block_solve_us="), "{s}");
        server.shutdown();
    }

    #[test]
    fn health_flips_unhealthy_when_a_worker_is_poisoned() {
        let server = Server::start(serving_model(), EngineSpec::Native, BatcherConfig::default());
        let (healthy, body) = server.health();
        assert!(healthy, "{body}");
        server.metrics.worker_poisoned.fetch_add(1, Ordering::Relaxed);
        let (healthy, body) = server.health();
        assert!(!healthy);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("status").and_then(|v| v.as_str()), Some("unhealthy"));
        let reason = j.get("reason").and_then(|v| v.as_str()).unwrap_or_default().to_string();
        assert!(reason.contains("poisoned"), "{reason}");
        server.metrics.worker_poisoned.store(0, Ordering::Relaxed);
        server.shutdown();
    }

    #[test]
    fn failpoints_route_reports_registry_and_rejects_bad_specs() {
        let server = Server::start(serving_model(), EngineSpec::Native, BatcherConfig::default());
        // Structural check only — other tests in this binary may own the
        // global registry, so don't assert on its contents.
        let body = server.handle_path("/failpoints").expect("failpoints routed");
        let j = Json::parse(&body).unwrap();
        assert!(j.get("armed").is_some(), "{body}");
        assert!(matches!(j.get("failpoints"), Some(Json::Arr(_))), "{body}");
        // A malformed spec is a clean error (which HTTP maps to 400).
        assert!(server.handle_failpoints("/failpoints?set=bogus").is_err());
        server.shutdown();
    }
}
