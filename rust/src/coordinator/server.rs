//! The serving front-end: a thread-backed request queue with blocking and
//! asynchronous submission, metrics, and graceful shutdown.

use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::Arc;
use std::time::Instant;

use super::batcher::{self, BatcherConfig, Prediction, Request};
use super::metrics::Metrics;
use super::router::EngineSpec;
use super::state::ServingModel;

/// A running prediction server for one model.
pub struct Server {
    tx: Option<SyncSender<Request>>,
    handle: Option<std::thread::JoinHandle<()>>,
    /// Shared metrics.
    pub metrics: Arc<Metrics>,
    dim: usize,
}

impl Server {
    /// Start the batcher thread.
    pub fn start(model: ServingModel, engine: EngineSpec, cfg: BatcherConfig) -> Server {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::sync_channel::<Request>(4096);
        let dim = model.dim();
        let model = Arc::new(model);
        let met2 = metrics.clone();
        let handle = std::thread::Builder::new()
            .name("msgp-batcher".into())
            .spawn(move || batcher::run(rx, engine, model, cfg, met2))
            .expect("spawn batcher");
        Server { tx: Some(tx), handle: Some(handle), metrics, dim }
    }

    /// Submit a point; returns a receiver for the reply.
    pub fn submit(&self, x: Vec<f64>) -> anyhow::Result<Receiver<anyhow::Result<Prediction>>> {
        anyhow::ensure!(x.len() == self.dim, "point dim {} vs model dim {}", x.len(), self.dim);
        let (rtx, rrx) = mpsc::sync_channel(1);
        self.metrics.submitted.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("server running")
            .send(Request { x, reply: rtx, t0: Instant::now() })
            .map_err(|_| anyhow::anyhow!("server shut down"))?;
        Ok(rrx)
    }

    /// Blocking predict.
    pub fn predict(&self, x: Vec<f64>) -> anyhow::Result<Prediction> {
        self.submit(x)?
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped reply"))?
    }

    /// Graceful shutdown: close the queue, drain, join the thread.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.tx.take(); // closing the channel stops the batcher loop
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_stress_1d;
    use crate::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
    use crate::kernels::{KernelType, ProductKernel};

    fn serving_model() -> ServingModel {
        let data = gen_stress_1d(150, 0.05, 5);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let cfg = MsgpConfig { n_per_dim: vec![64], n_var_samples: 8, ..Default::default() };
        let mut model = MsgpModel::fit(kernel, 0.01, data, cfg).unwrap();
        ServingModel::from_msgp(&mut model)
    }

    #[test]
    fn blocking_predict_roundtrip() {
        let model = serving_model();
        let direct = model.predict_batch(&[1.5]);
        let server = Server::start(model, EngineSpec::Native, BatcherConfig::default());
        let p = server.predict(vec![1.5]).unwrap();
        assert!((p.mean - direct.0[0]).abs() < 1e-12);
        assert!((p.var - direct.1[0]).abs() < 1e-12);
        server.shutdown();
    }

    #[test]
    fn concurrent_submitters_all_get_replies() {
        let model = serving_model();
        let server = Arc::new(Server::start(model, EngineSpec::Native, BatcherConfig::default()));
        let mut joins = Vec::new();
        for t in 0..8 {
            let s = server.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..50 {
                    let x = -9.0 + (t * 50 + i) as f64 * 0.04;
                    let p = s.predict(vec![x]).unwrap();
                    assert!(p.mean.is_finite() && p.var >= 0.0);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(
            server.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
            400
        );
    }

    #[test]
    fn wrong_dim_rejected_eagerly() {
        let model = serving_model();
        let server = Server::start(model, EngineSpec::Native, BatcherConfig::default());
        assert!(server.submit(vec![0.0, 1.0]).is_err());
    }
}
