//! Dynamic batching: requests are queued, collected up to a deadline or
//! bucket capacity, executed as one padded batch, and fanned back out.
//!
//! The trade-off mirrors production model servers (e.g. the vLLM router):
//! a short `max_wait` keeps tail latency low under light load; full
//! buckets amortize per-batch overhead (PJRT dispatch, padding) at high
//! load.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::metrics::Metrics;
use super::router::{Backend, EngineSpec, Router};
use super::state::{ModelSlot, ServingModel};
use crate::shard::ShardedServing;

/// A prediction reply.
#[derive(Clone, Debug)]
pub struct Prediction {
    /// Predictive mean.
    pub mean: f64,
    /// Predictive variance (observation space).
    pub var: f64,
}

/// A queued request: one test point plus its reply channel.
pub struct Request {
    /// Test point (length = model dim).
    pub x: Vec<f64>,
    /// Reply channel.
    pub reply: SyncSender<anyhow::Result<Prediction>>,
    /// Enqueue timestamp (for latency accounting).
    pub t0: Instant,
}

/// A batch of observations for the `/ingest` route.
pub struct IngestBatch {
    /// Inputs, row-major `k x D`.
    pub xs: Vec<f64>,
    /// Targets, length `k`.
    pub ys: Vec<f64>,
    /// Acked with the number of points applied once the stream trainer
    /// has absorbed the batch.
    pub reply: SyncSender<anyhow::Result<usize>>,
    /// Force a cache refresh + model swap right after this batch
    /// (deterministic cut-over for tests and admin flushes).
    pub refresh_now: bool,
}

/// A queued coordinator job: the batcher's ingress carries both routes so
/// ingestion observes the same arrival order as predictions.
pub enum Job {
    /// `/predict`: collected into padded prediction batches.
    Predict(Request),
    /// `/ingest`: forwarded to the stream-trainer thread.
    Ingest(IngestBatch),
}

/// Batcher configuration.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Maximum time the *oldest* queued request may wait before a flush.
    pub max_wait: Duration,
    /// Flush as soon as this many requests are queued (normally the
    /// largest router bucket).
    pub max_batch: usize,
    /// Eager mode: flush as soon as the ingress queue is drained instead
    /// of waiting out `max_wait`. Under closed-loop clients (every caller
    /// blocked on its reply) waiting longer cannot grow the batch — it
    /// only adds latency; new batches still form while the previous one
    /// executes. Disable for open-loop traffic where arrivals are spread
    /// out and larger buckets pay off.
    pub eager: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_wait: Duration::from_millis(1), max_batch: 256, eager: true }
    }
}

/// The batcher loop: owns the job receiver; runs until the channel
/// closes. Called on a dedicated thread by [`super::server::Server`].
/// The engine (possibly a PJRT runtime, which is not `Send`) is built
/// here, on the thread that uses it.
///
/// Prediction jobs are collected into padded batches and executed
/// against the *current* [`ModelSlot`] snapshot (read once per batch, so
/// a concurrent swap can never tear a batch). Ingest jobs are forwarded
/// to the stream-trainer thread via `ingest_tx` in arrival order.
pub fn run(
    rx: Receiver<Job>,
    engine: EngineSpec,
    slot: Arc<ModelSlot>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
    ingest_tx: Option<SyncSender<IngestBatch>>,
) {
    let router = Router::new(engine.build());
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    let mut accept = |job: Job, pending: &mut Vec<Request>| match job {
        Job::Predict(r) => pending.push(r),
        Job::Ingest(b) => match &ingest_tx {
            Some(tx) => {
                if let Err(mpsc::TrySendError::Full(b)) | Err(mpsc::TrySendError::Disconnected(b)) =
                    tx.try_send(b)
                {
                    // Back-pressure or a dead trainer: fail the batch
                    // rather than stalling the predict path.
                    let _ = b
                        .reply
                        .send(Err(anyhow::anyhow!("ingest queue unavailable (full or closed)")));
                }
            }
            None => {
                let _ = b
                    .reply
                    .send(Err(anyhow::anyhow!("server has no stream trainer (use start_online)")));
            }
        },
    };
    loop {
        if !collect(&rx, &mut pending, &cfg, &mut accept) {
            return; // channel closed: drain done, exit
        }
        if pending.is_empty() {
            continue; // the wake-up was an ingest; keep waiting
        }
        // Execute against the live snapshot and fan out.
        let model = slot.get();
        flush(&mut pending, &router, &model, &metrics);
    }
}

/// The batch-collection phases shared by [`run`] and [`run_sharded`]:
/// block for the first job, drain whatever is already queued (free
/// batching), then — unless eager — keep accumulating until the oldest
/// request's deadline or capacity. Returns `false` when the ingress
/// channel closed with nothing pending (the loop should exit).
fn collect(
    rx: &Receiver<Job>,
    pending: &mut Vec<Request>,
    cfg: &BatcherConfig,
    accept: &mut dyn FnMut(Job, &mut Vec<Request>),
) -> bool {
    // Phase 1: block for the first job (or shutdown).
    if pending.is_empty() {
        match rx.recv() {
            Ok(job) => accept(job, pending),
            Err(_) => return false,
        }
        if pending.is_empty() {
            return true; // the job was a non-predict; caller re-loops
        }
    }
    // Phase 2: drain whatever is already queued (free batching).
    while pending.len() < cfg.max_batch {
        match rx.try_recv() {
            Ok(job) => accept(job, pending),
            Err(_) => break,
        }
    }
    // Phase 3: unless eager, keep accumulating until the oldest
    // request's deadline or capacity.
    if !cfg.eager {
        let deadline = pending[0].t0 + cfg.max_wait;
        while pending.len() < cfg.max_batch {
            let now = Instant::now();
            let Some(left) = deadline.checked_duration_since(now) else { break };
            match rx.recv_timeout(left) {
                Ok(job) => accept(job, pending),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
    }
    true
}

/// The sharded batcher loop: same collection phases as [`run`], but the
/// flush *groups jobs by their owning shard before dispatch* — each
/// shard group executes as one batch against that shard's slot (with
/// halo blending handled by [`ShardedServing::predict_routed`]), so a
/// seam-heavy batch touches at most the two neighboring snapshots and a
/// refresh on one shard never stalls predictions owned by another.
/// Ingest jobs are rejected here: sharded servers route `/ingest`
/// directly to the [`crate::shard::ShardedTrainer`] facade.
pub fn run_sharded(
    rx: Receiver<Job>,
    serving: Arc<ShardedServing>,
    cfg: BatcherConfig,
    metrics: Arc<Metrics>,
) {
    let mut pending: Vec<Request> = Vec::with_capacity(cfg.max_batch);
    let mut accept = |job: Job, pending: &mut Vec<Request>| match job {
        Job::Predict(r) => pending.push(r),
        Job::Ingest(b) => {
            let _ = b.reply.send(Err(anyhow::anyhow!(
                "sharded servers ingest via the trainer facade, not the batch queue"
            )));
        }
    };
    loop {
        if !collect(&rx, &mut pending, &cfg, &mut accept) {
            return;
        }
        if pending.is_empty() {
            continue;
        }
        flush_sharded(&mut pending, &serving, &metrics);
    }
}

/// Group the pending requests by owning shard and dispatch one batch
/// per group.
fn flush_sharded(pending: &mut Vec<Request>, serving: &ShardedServing, metrics: &Metrics) {
    if pending.is_empty() {
        return;
    }
    let _sp = crate::span!("predict.flush_sharded");
    let d = serving.plan().global().dim();
    let nshards = serving.plan().shards();
    let mut groups: Vec<Vec<Request>> = (0..nshards).map(|_| Vec::new()).collect();
    for r in pending.drain(..) {
        let s = serving.plan().owner_of(&r.x);
        groups[s].push(r);
    }
    for (s, group) in groups.into_iter().enumerate() {
        if group.is_empty() {
            continue;
        }
        let k = group.len();
        let mut points = Vec::with_capacity(k * d);
        for r in &group {
            points.extend_from_slice(&r.x);
        }
        let (means, vars) = serving.predict_routed(s, &points);
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics.native_batches.fetch_add(1, Ordering::Relaxed);
        if let Some(sm) = metrics.shards.get(s) {
            sm.routed_predictions.fetch_add(k as u64, Ordering::Relaxed);
        }
        for (i, req) in group.into_iter().enumerate() {
            metrics.record_latency(req.t0.elapsed());
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            let _ = req
                .reply
                .send(Ok(Prediction { mean: means[i], var: vars[i] }));
        }
    }
}

fn flush(
    pending: &mut Vec<Request>,
    router: &Router,
    model: &ServingModel,
    metrics: &Metrics,
) {
    if pending.is_empty() {
        return;
    }
    let _sp = crate::span!("predict.flush");
    let d = model.dim();
    let k = pending.len();
    let mut points = Vec::with_capacity(k * d);
    for r in pending.iter() {
        points.extend_from_slice(&r.x);
    }
    let result = router.execute(model, &points);
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    let bucket = router.pick_bucket(k).unwrap_or(k);
    metrics
        .padded_slots
        .fetch_add(bucket.saturating_sub(k) as u64, Ordering::Relaxed);
    match result {
        Ok((means, vars, backend)) => {
            match backend {
                Backend::Pjrt => metrics.pjrt_batches.fetch_add(1, Ordering::Relaxed),
                Backend::Native => metrics.native_batches.fetch_add(1, Ordering::Relaxed),
            };
            for (i, req) in pending.drain(..).enumerate() {
                // Count + record *before* waking the caller so metrics are
                // consistent the moment a reply is observable.
                metrics.record_latency(req.t0.elapsed());
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req
                    .reply
                    .send(Ok(Prediction { mean: means[i], var: vars[i] }));
            }
        }
        Err(e) => {
            // Fan the error out to every caller (stringly, so it clones).
            let msg = format!("batch execution failed: {e}");
            for req in pending.drain(..) {
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(Err(anyhow::anyhow!(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gen_stress_1d;
    use crate::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
    use crate::kernels::{KernelType, ProductKernel};
    use std::sync::mpsc;

    fn serving_model() -> ServingModel {
        let data = gen_stress_1d(120, 0.05, 3);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let cfg = MsgpConfig { n_per_dim: vec![64], n_var_samples: 8, ..Default::default() };
        let mut model = MsgpModel::fit(kernel, 0.01, data, cfg).unwrap();
        ServingModel::from_msgp(&mut model)
    }

    /// Property sweep (proptest substitute): across random request
    /// counts, arrival patterns and batch configs, every request gets
    /// exactly one reply and replies match the direct computation.
    #[test]
    fn property_no_request_dropped_and_results_exact() {
        let model = serving_model();
        let slot = Arc::new(ModelSlot::new(model.clone()));
        let mut rng = crate::util::Rng::new(42);
        for trial in 0..15 {
            let (tx, rx) = mpsc::sync_channel::<Job>(1024);
            let metrics = Arc::new(Metrics::new());
            let cfg = BatcherConfig {
                max_wait: Duration::from_micros(200 + 300 * (trial % 4) as u64),
                max_batch: [1usize, 3, 8, 64][trial % 4],
                eager: trial % 2 == 0,
            };
            let s2 = slot.clone();
            let met2 = metrics.clone();
            let handle = std::thread::spawn(move || {
                run(rx, EngineSpec::Native, s2, cfg, met2, None);
            });
            let k = 1 + rng.below(200);
            let mut replies = Vec::new();
            let mut xs = Vec::new();
            for _ in 0..k {
                let x = rng.uniform_in(-9.0, 9.0);
                let (rtx, rrx) = mpsc::sync_channel(1);
                tx.send(Job::Predict(Request { x: vec![x], reply: rtx, t0: Instant::now() }))
                    .unwrap();
                metrics.submitted.fetch_add(1, Ordering::Relaxed);
                xs.push(x);
                replies.push(rrx);
                if rng.uniform() < 0.1 {
                    std::thread::sleep(Duration::from_micros(300));
                }
            }
            drop(tx); // close channel -> batcher drains and exits
            let (want_mean, want_var) = model.predict_batch(&xs);
            for (i, r) in replies.into_iter().enumerate() {
                let p = r
                    .recv_timeout(Duration::from_secs(10))
                    .expect("reply delivered")
                    .expect("no batch error");
                assert!(
                    (p.mean - want_mean[i]).abs() < 1e-9,
                    "trial {trial} req {i}: {} vs {}",
                    p.mean,
                    want_mean[i]
                );
                assert!((p.var - want_var[i]).abs() < 1e-9);
            }
            handle.join().unwrap();
            assert_eq!(
                metrics.completed.load(Ordering::Relaxed),
                k as u64,
                "trial {trial}"
            );
        }
    }

    #[test]
    fn max_batch_bounds_flush_size() {
        let slot = Arc::new(ModelSlot::new(serving_model()));
        let (tx, rx) = mpsc::sync_channel::<Job>(1024);
        let metrics = Arc::new(Metrics::new());
        let cfg = BatcherConfig { max_wait: Duration::from_millis(50), max_batch: 4, eager: false };
        let s2 = slot.clone();
        let met2 = metrics.clone();
        let handle = std::thread::spawn(move || {
            run(rx, EngineSpec::Native, s2, cfg, met2, None);
        });
        let mut replies = Vec::new();
        for i in 0..16 {
            let (rtx, rrx) = mpsc::sync_channel(1);
            tx.send(Job::Predict(Request {
                x: vec![i as f64 * 0.5 - 4.0],
                reply: rtx,
                t0: Instant::now(),
            }))
            .unwrap();
            replies.push(rrx);
        }
        drop(tx);
        for r in replies {
            r.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        }
        handle.join().unwrap();
        // 16 requests, max_batch 4 -> at least 4 batches.
        assert!(metrics.batches.load(Ordering::Relaxed) >= 4);
    }

    #[test]
    fn ingest_without_trainer_is_rejected() {
        let slot = Arc::new(ModelSlot::new(serving_model()));
        let (tx, rx) = mpsc::sync_channel::<Job>(16);
        let metrics = Arc::new(Metrics::new());
        let met2 = metrics.clone();
        let s2 = slot.clone();
        let handle = std::thread::spawn(move || {
            run(rx, EngineSpec::Native, s2, BatcherConfig::default(), met2, None);
        });
        let (rtx, rrx) = mpsc::sync_channel(1);
        tx.send(Job::Ingest(IngestBatch {
            xs: vec![0.5],
            ys: vec![1.0],
            reply: rtx,
            refresh_now: false,
        }))
        .unwrap();
        let err = rrx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert!(err.is_err(), "ingest must fail on a non-streaming server");
        drop(tx);
        handle.join().unwrap();
    }
}
