//! Routing: choose the execution backend and batch bucket for each batch.
//!
//! Buckets mirror the AOT-compiled artifact shapes (`aot.py` BUCKETS). A
//! batch of size `k` is padded to the smallest bucket `>= k`; if `k`
//! exceeds the largest bucket the batch is chunked. Batches whose
//! (dim, bucket) pair has a compiled PJRT artifact run there; everything
//! else falls back to the native Rust engine, which handles any shape.

use std::path::PathBuf;

use super::state::ServingModel;
use crate::runtime::Runtime;

/// How to construct the execution backend. The PJRT client is not `Send`
/// (it wraps `Rc` internals), so the spec crosses threads and the actual
/// [`Engine`] is built *inside* the batcher thread.
#[derive(Clone, Debug)]
pub enum EngineSpec {
    /// Pure-Rust sparse interpolation (any shape).
    Native,
    /// Load PJRT artifacts from this directory; native fallback for
    /// shapes without a compiled executable.
    Pjrt(PathBuf),
}

impl EngineSpec {
    /// Materialize the engine (call on the thread that will use it).
    /// PJRT load failures degrade to the native engine with a warning.
    pub fn build(&self) -> Engine {
        match self {
            EngineSpec::Native => Engine::Native,
            EngineSpec::Pjrt(dir) => match Runtime::load(dir) {
                Ok(rt) => Engine::Pjrt(rt),
                Err(e) => {
                    crate::log_warn!("PJRT unavailable ({e}); using native engine");
                    Engine::Native
                }
            },
        }
    }
}

/// Execution backend (thread-local; see [`EngineSpec`]).
pub enum Engine {
    /// Pure-Rust sparse interpolation (any shape).
    Native,
    /// PJRT artifacts for compiled buckets, native fallback otherwise.
    Pjrt(Runtime),
}

/// Coordinator front-door routes — the request surface a production
/// deployment exposes over HTTP. [`Route::parse`] maps a path to the
/// handler the [`super::server::Server`] implements: `/predict` and
/// `/ingest` flow through the batcher queue, `/metrics` and `/models`
/// are served from shared state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Prediction request (batched, answered from the live model slot).
    Predict,
    /// Streaming ingestion (batched, absorbed by the stream trainer).
    Ingest,
    /// Metrics summary.
    Metrics,
    /// Installed model listing.
    Models,
    /// Shard-layout introspection (sharded servers: per-shard owned
    /// slab, grid size, ingest/refresh counters, queue depth).
    Shards,
    /// Readiness / liveness probe (JSON: readiness, last-refresh age,
    /// reservoir size, max shard queue depth).
    Health,
    /// Chrome trace-event JSON dump of the current tracing window
    /// (see [`crate::obs::trace`]).
    Trace,
    /// Failpoint inspection and (re)configuration
    /// (`?set=name:action@prob`, `?clear=1`; see [`crate::fault`]).
    Failpoints,
    /// Cluster introspection (cluster servers: node id, per-shard
    /// ownership + point counts, replication epochs; see
    /// [`crate::cluster`]).
    Cluster,
    /// Peer membership + health (cluster servers: per-peer liveness,
    /// heartbeat age, queue depth, reconnect counters).
    Peers,
}

/// Rendering requested for the `/metrics` route.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricsFormat {
    /// Legacy one-line `key=value` summary (the default).
    Summary,
    /// Prometheus text exposition (`?format=prom`).
    Prometheus,
}

/// Split a request path into `(path, query)` at the first `?`.
pub fn split_query(path: &str) -> (&str, Option<&str>) {
    match path.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (path, None),
    }
}

/// Look up a query parameter by key: `query_param("/t?a=1&b", "a")` →
/// `Some("1")`; a bare key (`"b"`) yields `Some("")`; a missing key
/// yields `None`.
pub fn query_param<'a>(path: &'a str, key: &str) -> Option<&'a str> {
    let (_, query) = split_query(path);
    for pair in query?.split('&') {
        match pair.split_once('=') {
            Some((k, v)) if k == key => return Some(v),
            None if pair == key => return Some(""),
            _ => {}
        }
    }
    None
}

/// True when `key` is present and not explicitly disabled: `?clear=1`,
/// `?clear=true`, and bare `?clear` all enable; `?clear=0`,
/// `?clear=false`, and an absent key do not.
pub fn query_flag(path: &str, key: &str) -> bool {
    match query_param(path, key) {
        Some(v) => v != "0" && v != "false",
        None => false,
    }
}

/// Parse the `/metrics` format selector from a request path's query
/// string (`format=prom` | `format=prometheus` → Prometheus; anything
/// else → the legacy summary).
pub fn metrics_format(path: &str) -> MetricsFormat {
    match query_param(path, "format") {
        Some("prom") | Some("prometheus") => MetricsFormat::Prometheus,
        _ => MetricsFormat::Summary,
    }
}

impl Route {
    /// Parse a request path (ignoring any query string).
    pub fn parse(path: &str) -> Option<Route> {
        let p = path.split('?').next().unwrap_or(path).trim_end_matches('/');
        match p {
            "/predict" | "predict" => Some(Route::Predict),
            "/ingest" | "ingest" => Some(Route::Ingest),
            "/metrics" | "metrics" => Some(Route::Metrics),
            "/models" | "models" => Some(Route::Models),
            "/shards" | "shards" => Some(Route::Shards),
            "/healthz" | "healthz" | "/health" | "health" => Some(Route::Health),
            "/trace" | "trace" => Some(Route::Trace),
            "/failpoints" | "failpoints" => Some(Route::Failpoints),
            "/cluster" | "cluster" => Some(Route::Cluster),
            "/peers" | "peers" => Some(Route::Peers),
            _ => None,
        }
    }
}

/// Batch router.
pub struct Router {
    /// Backend.
    pub engine: Engine,
    /// Ascending bucket sizes used for padding.
    pub buckets: Vec<usize>,
}

/// Outcome of one routed execution (for metrics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Ran on the native engine.
    Native,
    /// Ran on a PJRT executable.
    Pjrt,
}

impl Router {
    /// Router with the standard buckets (must match `aot.py`).
    pub fn new(engine: Engine) -> Self {
        Router { engine, buckets: vec![8, 32, 128, 256] }
    }

    /// Smallest bucket `>= k`, or `None` if `k` exceeds the largest.
    pub fn pick_bucket(&self, k: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= k)
    }

    /// Execute a batch of points (row-major `k x dim`, physical
    /// coordinates) against `model`. Handles padding, chunking, backend
    /// selection, and un-padding. Returns `(means, vars, backend_used)`.
    pub fn execute(
        &self,
        model: &ServingModel,
        points: &[f64],
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>, Backend)> {
        let d = model.dim();
        let k = points.len() / d;
        // PANIC-OK: the bucket ladder is validated non-empty at build.
        let max_bucket = *self.buckets.last().unwrap();
        if k > max_bucket {
            // Chunk recursively.
            let mut means = Vec::with_capacity(k);
            let mut vars = Vec::with_capacity(k);
            let mut used = Backend::Native;
            for chunk in points.chunks(max_bucket * d) {
                let (m, v, b) = self.execute(model, chunk)?;
                means.extend(m);
                vars.extend(v);
                used = b;
            }
            return Ok((means, vars, used));
        }
        let bucket = self.pick_bucket(k).unwrap_or(max_bucket);
        if let Engine::Pjrt(rt) = &self.engine {
            let name = format!("predict_meanvar_{}d_b{}", d, bucket);
            if let Some(art) = rt.get(&name) {
                if art.meta.m == model.grid.shape() {
                    return self.execute_pjrt(rt, &name, model, points, bucket);
                }
            }
        }
        let (mean, var) = model.predict_batch(points);
        Ok((mean, var, Backend::Native))
    }

    fn execute_pjrt(
        &self,
        rt: &Runtime,
        name: &str,
        model: &ServingModel,
        points: &[f64],
        bucket: usize,
    ) -> anyhow::Result<(Vec<f64>, Vec<f64>, Backend)> {
        let d = model.dim();
        let k = points.len() / d;
        // Pad by repeating the last point (harmless: results discarded).
        let mut padded = points.to_vec();
        let last = points[(k - 1) * d..k * d].to_vec();
        for _ in k..bucket {
            padded.extend_from_slice(&last);
        }
        let units = model.to_grid_units_f32(&padded);
        let (um, nu) = model.grid_vecs_f32();
        let (mean32, var32) = rt.predict_meanvar(
            name,
            &units,
            &um,
            &nu,
            model.kss as f32,
            model.sigma2 as f32,
        )?;
        let means = mean32[..k].iter().map(|&v| v as f64).collect();
        let vars = var32[..k].iter().map(|&v| v as f64).collect();
        Ok((means, vars, Backend::Pjrt))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::state::ServingModel;
    use crate::data::gen_stress_1d;
    use crate::gp::msgp::{KernelSpec, MsgpConfig, MsgpModel};
    use crate::kernels::{KernelType, ProductKernel};

    fn serving_model() -> ServingModel {
        let data = gen_stress_1d(150, 0.05, 9);
        let kernel = KernelSpec::Product(ProductKernel::iso(KernelType::SE, 1, 1.0, 1.0));
        let cfg = MsgpConfig { n_per_dim: vec![96], n_var_samples: 10, ..Default::default() };
        let mut model = MsgpModel::fit(kernel, 0.01, data, cfg).unwrap();
        ServingModel::from_msgp(&mut model)
    }

    #[test]
    fn routes_parse() {
        assert_eq!(Route::parse("/predict"), Some(Route::Predict));
        assert_eq!(Route::parse("/ingest"), Some(Route::Ingest));
        assert_eq!(Route::parse("/ingest?batch=64"), Some(Route::Ingest));
        assert_eq!(Route::parse("/metrics/"), Some(Route::Metrics));
        assert_eq!(Route::parse("/models"), Some(Route::Models));
        assert_eq!(Route::parse("/shards"), Some(Route::Shards));
        assert_eq!(Route::parse("/shards?verbose=1"), Some(Route::Shards));
        assert_eq!(Route::parse("/healthz"), Some(Route::Health));
        assert_eq!(Route::parse("/healthz/"), Some(Route::Health));
        assert_eq!(Route::parse("/trace"), Some(Route::Trace));
        assert_eq!(Route::parse("/failpoints"), Some(Route::Failpoints));
        assert_eq!(Route::parse("/failpoints?clear=1"), Some(Route::Failpoints));
        assert_eq!(Route::parse("/cluster"), Some(Route::Cluster));
        assert_eq!(Route::parse("/peers"), Some(Route::Peers));
        assert_eq!(Route::parse("/peers/"), Some(Route::Peers));
        assert_eq!(Route::parse("/nope"), None);
    }

    #[test]
    fn metrics_format_parses_query() {
        assert_eq!(metrics_format("/metrics"), MetricsFormat::Summary);
        assert_eq!(metrics_format("/metrics?format=prom"), MetricsFormat::Prometheus);
        assert_eq!(metrics_format("/metrics?format=prometheus"), MetricsFormat::Prometheus);
        assert_eq!(metrics_format("/metrics?a=1&format=prom"), MetricsFormat::Prometheus);
        assert_eq!(metrics_format("/metrics?format=txt"), MetricsFormat::Summary);
        // The format selector never changes the route itself.
        assert_eq!(Route::parse("/metrics?format=prom"), Some(Route::Metrics));
    }

    #[test]
    fn query_helpers_parse_params_and_flags() {
        assert_eq!(split_query("/trace?clear=1"), ("/trace", Some("clear=1")));
        assert_eq!(split_query("/trace"), ("/trace", None));
        assert_eq!(query_param("/s?verbose=1&x=a%20b", "x"), Some("a%20b"));
        assert_eq!(query_param("/s?verbose=1", "verbose"), Some("1"));
        assert_eq!(query_param("/s?verbose", "verbose"), Some(""));
        assert_eq!(query_param("/s?verbose=1", "missing"), None);
        assert_eq!(query_param("/s", "verbose"), None);
        assert!(query_flag("/trace?clear=1", "clear"));
        assert!(query_flag("/trace?clear=true", "clear"));
        assert!(query_flag("/trace?clear", "clear"));
        assert!(!query_flag("/trace?clear=0", "clear"));
        assert!(!query_flag("/trace?clear=false", "clear"));
        assert!(!query_flag("/trace", "clear"));
    }

    #[test]
    fn bucket_selection_is_minimal_cover() {
        let r = Router::new(Engine::Native);
        assert_eq!(r.pick_bucket(1), Some(8));
        assert_eq!(r.pick_bucket(8), Some(8));
        assert_eq!(r.pick_bucket(9), Some(32));
        assert_eq!(r.pick_bucket(256), Some(256));
        assert_eq!(r.pick_bucket(257), None);
    }

    #[test]
    fn native_execution_matches_direct_predict() {
        let sm = serving_model();
        let r = Router::new(Engine::Native);
        let xs: Vec<f64> = (0..13).map(|i| -7.0 + i as f64).collect();
        let (mean, var, backend) = r.execute(&sm, &xs).unwrap();
        assert_eq!(backend, Backend::Native);
        let (wm, wv) = sm.predict_batch(&xs);
        assert_eq!(mean, wm);
        assert_eq!(var, wv);
    }

    #[test]
    fn oversized_batches_are_chunked() {
        let sm = serving_model();
        let r = Router::new(Engine::Native);
        let xs: Vec<f64> = (0..600).map(|i| -9.0 + 0.03 * i as f64).collect();
        let (mean, var, _) = r.execute(&sm, &xs).unwrap();
        assert_eq!(mean.len(), 600);
        assert_eq!(var.len(), 600);
        let (wm, _) = sm.predict_batch(&xs);
        for (a, b) in mean.iter().zip(&wm) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
